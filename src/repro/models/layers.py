"""Dense model building blocks: norms, RoPE, attention (GQA / MLA /
local-global), MLPs.  Pure JAX; sharding via logical-axis constraints
(`repro.parallel.sharding.constrain`), which are no-ops without a mesh so the
same code serves CPU smoke tests and the 512-device dry-run.

Attention is q-chunked ("lazy flash"): queries are processed in chunks of
``Q_CHUNK`` via lax.scan so score tensors never exceed
(B, H, Q_CHUNK, T) — the XLA fallback path for long prefill.  The Pallas
flash kernel (repro.kernels.flash_attention) is the TPU runtime path; both
are validated against each other in tests.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel.sharding import active_mesh, constrain, fsdp_use

Q_CHUNK = 1024
NEG_INF = -2.0e38

# Force python-unrolling of inner chunk loops (attention q-chunks, chunked
# CE).  lax.map lowers to a while loop whose body XLA cost_analysis counts
# ONCE regardless of trip count, silently undercounting chunked ops — the
# dry-run's 1-/2-superblock cost probes set this so every chunk is counted.
# Production programs keep lax.map (HLO size stays O(1) in chunk count).
FORCE_UNROLL_CHUNKS = False


def _attn_shard_plan(n_heads: int) -> Tuple[str, int]:
    """(seq_axis, padded_head_count) for sharding attention on 'model'.

    When the head count divides the 'model' axis, heads shard there and seq
    stays unsharded.  Otherwise (e.g. musicgen's 24 heads on a 16-way axis)
    attention would silently REPLICATE across 'model'.  Two escapes, by
    measured preference (EXPERIMENTS.md §Perf, musicgen hillclimb):

      1. pad heads at runtime to the next multiple of the axis (zero wq/wo
         rows: dead heads contribute exactly 0) when the waste is <= 50% —
         heads then shard cleanly, no resharding collectives;
      2. otherwise context-parallel the query/seq dim ('seq_sp' -> 'model'),
         which trades the replication for enter/exit reshards and f32
         dk/dv partial-sum all-reduces.
    """
    mesh = active_mesh()
    if mesh is None or "model" not in mesh.shape:
        return "seq", n_heads
    m = mesh.shape["model"]
    if n_heads % m == 0:
        return "seq", n_heads
    h_pad = -(-n_heads // m) * m
    if (h_pad - n_heads) / n_heads <= 0.5:
        return "seq", h_pad
    return "seq_sp", n_heads


def _pad_heads(arr: jax.Array, h_pad: int, axis: int) -> jax.Array:
    h = arr.shape[axis]
    if h == h_pad:
        return arr
    pad = [(0, 0)] * arr.ndim
    pad[axis] = (0, h_pad - h)
    return jnp.pad(arr, pad)


# ---------------------------------------------------------------------------
# Init helpers.  Params are dicts of arrays; every init returns (params, axes)
# where axes mirrors the structure with logical-axis tuples.
# ---------------------------------------------------------------------------

def dense_init(key, shape, axes, in_axis=0, dtype=jnp.float32):
    if isinstance(in_axis, int):
        fan_in = shape[in_axis]
    else:
        fan_in = math.prod(shape[i] for i in in_axis)
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, dtype) * scale), axes


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------

def init_norm(cfg: ArchConfig, key) -> Tuple[Dict, Dict]:
    if cfg.norm == "nonparam_ln":
        return {}, {}
    return ({"scale": jnp.ones((cfg.d_model,), jnp.float32)},
            {"scale": ("norm",)})


def apply_norm(cfg: ArchConfig, p: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    # (A mixed-precision variant computing the sum-square via an f32-
    # accumulating dot was tried and REFUTED — XLA already fuses this chain,
    # and the extra dot op made the counted bytes slightly worse.  See
    # EXPERIMENTS.md §Perf, gemma3 iteration 3.)
    xf = x.astype(jnp.float32)
    if cfg.norm == "nonparam_ln":
        mu = xf.mean(-1, keepdims=True)
        var = xf.var(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE.
# ---------------------------------------------------------------------------

def rope_tables(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) -> sin/cos tables (..., dim//2)."""
    half = dim // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x (..., S, H, hd); sin/cos (S, hd//2) broadcast over batch/heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s = sin[..., None, :]          # (S, 1, hd/2) -> broadcast over heads
    c = cos[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                           axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (with optional sliding window).
# ---------------------------------------------------------------------------

def init_attention(cfg: ArchConfig, key) -> Tuple[Dict, Dict]:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["wq"], a["wq"] = dense_init(ks[0], (D, H, hd), ("embed", "w_heads", "head_dim"))
    p["wk"], a["wk"] = dense_init(ks[1], (D, KV, hd), ("embed", "w_kv_heads", "head_dim"))
    p["wv"], a["wv"] = dense_init(ks[2], (D, KV, hd), ("embed", "w_kv_heads", "head_dim"))
    p["wo"], a["wo"] = dense_init(ks[3], (H, hd, D), ("w_heads", "head_dim", "embed"),
                                  in_axis=(0, 1))
    return p, a


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, T, KV, hd) -> (B, T, H, hd) by repeating each kv head H/KV times."""
    B, T, KV, hd = k.shape
    rep = n_heads // KV
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def _attn_mask(q_pos: jax.Array, k_pos: jax.Array, window: Optional[int],
               k_valid: Optional[jax.Array] = None) -> jax.Array:
    """Additive mask (..., Sq, Tk): causal, optional sliding window."""
    ok = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= (q_pos[:, None] - k_pos[None, :]) < window
    if k_valid is not None:
        ok &= k_valid[None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attention(cfg: ArchConfig, p: Dict, x: jax.Array,
              k: jax.Array, v: jax.Array,
              q_pos: jax.Array, k_pos: jax.Array,
              window: Optional[int] = None,
              k_valid: Optional[jax.Array] = None) -> jax.Array:
    """Core attention: x (B,S,D) queries against k/v (B,T,KV,hd).

    Query-chunked when S > Q_CHUNK to bound the score tensor.
    """
    H, hd = cfg.n_heads, cfg.hd
    sa, h_eff = _attn_shard_plan(H)
    wq = _pad_heads(fsdp_use(p["wq"], ("embed", "w_heads", "head_dim"),
                             x.dtype), h_eff, 1)
    wo = _pad_heads(fsdp_use(p["wo"], ("w_heads", "head_dim", "embed"),
                             x.dtype), h_eff, 0)
    q = jnp.einsum("bsd,dhk->bshk", x, wq)
    sin, cos = rope_tables(q_pos, hd, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    # fold the 1/sqrt(hd) scale into q (B,S,H,hd) — two orders of magnitude
    # smaller than the (B,H,Sq,T) score tensor it would otherwise multiply
    q = q * jnp.asarray(1.0 / math.sqrt(hd), q.dtype)
    q = constrain(q, ("batch", sa, "heads", "head_dim"))
    kf = _pad_heads(_expand_kv(k, H), h_eff, 2)
    vf = _pad_heads(_expand_kv(v, H), h_eff, 2)

    @jax.checkpoint
    def chunk_attn(qc, qp, kc, vc, kp, kval):
        # rematted: the backward recomputes this chunk's scores instead of
        # storing (bq, T) softmax weights for every chunk/layer — the XLA
        # analogue of flash-attention memory behaviour.
        qc = constrain(qc, ("batch", sa, "heads", "head_dim"))
        s = jnp.einsum("bshk,bthk->bhst", qc, kc,
                       preferred_element_type=jnp.float32)
        s = s + _attn_mask(qp, kp, window, kval)
        w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        o = jnp.einsum("bhst,bthk->bshk", w, vc)
        return constrain(o, ("batch", sa, "heads", "head_dim"))

    S = x.shape[1]
    T = kf.shape[1]
    if S <= Q_CHUNK:
        o = chunk_attn(q, q_pos, kf, vf, k_pos, k_valid)
    else:
        assert S % Q_CHUNK == 0, f"seq {S} must be divisible by {Q_CHUNK}"
        nc = S // Q_CHUNK
        if nc <= 8 or FORCE_UNROLL_CHUNKS:
            # Python-unrolled with STATIC per-chunk k/v slices: chunk i can
            # only attend keys below hi = T-S+(i+1)*C (causal) and, for
            # sliding-window layers, above hi-C-window — the fully-masked
            # score blocks are then never computed.  Saves ~(nc-1)/2nc of
            # score FLOPs+bytes for causal, ~1 - (C+w)/T for local layers
            # (EXPERIMENTS.md §Perf, musicgen/gemma3 hillclimbs).
            outs = []
            for i in range(nc):
                sl = slice(i * Q_CHUNK, (i + 1) * Q_CHUNK)
                hi = T - S + (i + 1) * Q_CHUNK
                lo = 0 if window is None else max(0, hi - Q_CHUNK - window)
                outs.append(chunk_attn(
                    q[:, sl], q_pos[sl], kf[:, lo:hi], vf[:, lo:hi],
                    k_pos[lo:hi],
                    None if k_valid is None else k_valid[lo:hi]))
            o = jnp.concatenate(outs, axis=1)
        else:
            # long prefill: uniform chunks via lax.map keep HLO size O(1);
            # local layers still use a constant-width banded k slice.
            qs = q.reshape(q.shape[0], nc, Q_CHUNK, h_eff, hd).swapaxes(0, 1)
            ps = q_pos.reshape(nc, Q_CHUNK)
            if window is not None and Q_CHUNK + window < T:
                width = Q_CHUNK + window
                kv_ = (jnp.zeros((T,), jnp.bool_) if k_valid is None
                       else k_valid)

                def banded(args):
                    qc, qp, i = args
                    hi = T - S + (i + 1) * Q_CHUNK
                    lo = jnp.maximum(hi - width, 0)
                    kc = jax.lax.dynamic_slice_in_dim(kf, lo, width, axis=1)
                    vc = jax.lax.dynamic_slice_in_dim(vf, lo, width, axis=1)
                    kp = jax.lax.dynamic_slice_in_dim(k_pos, lo, width)
                    kv = (None if k_valid is None else
                          jax.lax.dynamic_slice_in_dim(kv_, lo, width))
                    return chunk_attn(qc, qp, kc, vc, kp, kv)

                o = jax.lax.map(banded, (qs, ps, jnp.arange(nc)))
            else:
                o = jax.lax.map(
                    lambda args: chunk_attn(args[0], args[1], kf, vf,
                                            k_pos, k_valid), (qs, ps))
            o = o.swapaxes(0, 1).reshape(q.shape[0], S, h_eff, hd)

    y = jnp.einsum("bshk,hkd->bsd", o, wo)
    return constrain(y, ("batch", "seq", "act_embed"))


def project_kv(cfg: ArchConfig, p: Dict, x: jax.Array, k_pos: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """K/V projections (+RoPE on K) for tokens x at positions k_pos."""
    kv_ax = ("embed", "w_kv_heads", "head_dim")
    k = jnp.einsum("btd,dgk->btgk", x, fsdp_use(p["wk"], kv_ax, x.dtype))
    v = jnp.einsum("btd,dgk->btgk", x, fsdp_use(p["wv"], kv_ax, x.dtype))
    sin, cos = rope_tables(k_pos, cfg.hd, cfg.rope_theta)
    k = apply_rope(k, sin, cos)
    k = constrain(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = constrain(v, ("batch", "seq", "kv_heads", "head_dim"))
    return k, v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention).
# ---------------------------------------------------------------------------

def init_mla(cfg: ArchConfig, key) -> Tuple[Dict, Dict]:
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    dn, dr, dv, r = m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim, m.kv_lora_rank
    ks = jax.random.split(key, 5)
    p, a = {}, {}
    p["wq"], a["wq"] = dense_init(ks[0], (D, H, dn + dr), ("embed", "w_heads", "head_dim"))
    p["wdkv"], a["wdkv"] = dense_init(ks[1], (D, r + dr), ("embed", "kv_lora"))
    p["wuk"], a["wuk"] = dense_init(ks[2], (r, H, dn), ("kv_lora", "w_heads", "head_dim"))
    p["wuv"], a["wuv"] = dense_init(ks[3], (r, H, dv), ("kv_lora", "w_heads", "head_dim"))
    p["wo"], a["wo"] = dense_init(ks[4], (H, dv, D), ("w_heads", "head_dim", "embed"),
                                  in_axis=(0, 1))
    return p, a


def mla_compress(cfg: ArchConfig, p: Dict, x: jax.Array, k_pos: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """x -> (c_kv (B,T,r), k_rope (B,T,dr)) — this pair is the whole KV cache."""
    m = cfg.mla
    ckr = jnp.einsum("btd,dr->btr", x,
                 fsdp_use(p["wdkv"], ("embed", "kv_lora"), x.dtype))
    c_kv, k_rope = ckr[..., :m.kv_lora_rank], ckr[..., m.kv_lora_rank:]
    sin, cos = rope_tables(k_pos, m.qk_rope_dim, cfg.rope_theta)
    k_rope = apply_rope(k_rope[..., None, :], sin, cos)[..., 0, :]
    return c_kv, k_rope


def mla_attention(cfg: ArchConfig, p: Dict, x: jax.Array,
                  c_kv: jax.Array, k_rope: jax.Array,
                  q_pos: jax.Array, k_pos: jax.Array,
                  k_valid: Optional[jax.Array] = None) -> jax.Array:
    """MLA attention over the compressed cache.

    Baseline (paper-faithful deployment): decompress K/V per head from c_kv.
    ``cfg.mla.absorbed_decode``: absorb W_uk into the query and W_uv into the
    output projection so attention runs directly in the rank-r latent space —
    the beyond-paper §Perf variant (cache reads drop from H*(dn+dv) to
    r + dr per token).
    """
    m = cfg.mla
    H = cfg.n_heads
    dn, dr, dv = m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x,
               fsdp_use(p["wq"], ("embed", "w_heads", "head_dim"),
                        x.dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    sin, cos = rope_tables(q_pos, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos)
    scale = 1.0 / math.sqrt(dn + dr)

    if m.absorbed_decode:
        # q_lat (B,S,H,r) = q_nope @ wuk^T ; scores vs c_kv directly.
        # k_rope is shared across heads, so the rope term contracts (B,T,dr)
        # against per-head q_rope without materializing per-head K.
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, p["wuk"].astype(x.dtype))
        s = (jnp.einsum("bshr,btr->bhst", q_lat, c_kv,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bshr,btr->bhst", q_rope, k_rope,
                          preferred_element_type=jnp.float32)) * scale
        s = s + _attn_mask(q_pos, k_pos, None, k_valid)
        w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        o_lat = jnp.einsum("bhst,btr->bshr", w, c_kv)
        o = jnp.einsum("bshr,rhv->bshv", o_lat, p["wuv"].astype(x.dtype))
    else:
        k_nope = jnp.einsum("btr,rhn->bthn", c_kv, p["wuk"].astype(x.dtype))
        v = jnp.einsum("btr,rhv->bthv", c_kv, p["wuv"].astype(x.dtype))
        k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                    k_rope.shape[:2] + (H, dr))
        s = (jnp.einsum("bshn,bthn->bhst", q_nope, k_nope,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bshn,bthn->bhst", q_rope, k_rope_h,
                          preferred_element_type=jnp.float32)) * scale
        s = s + _attn_mask(q_pos, k_pos, None, k_valid)
        w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        o = jnp.einsum("bhst,bthv->bshv", w, v)

    y = jnp.einsum("bshv,hvd->bsd", o,
               fsdp_use(p["wo"], ("w_heads", "head_dim", "embed"),
                        x.dtype))
    return constrain(y, ("batch", "seq", "act_embed"))


# ---------------------------------------------------------------------------
# MLPs.
# ---------------------------------------------------------------------------

def init_mlp(cfg: ArchConfig, key, d_ff: Optional[int] = None) -> Tuple[Dict, Dict]:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p, a = {}, {}
    p["wi"], a["wi"] = dense_init(ks[0], (D, F), ("embed", "mlp"))
    p["wo"], a["wo"] = dense_init(ks[1], (F, D), ("mlp", "embed"))
    if cfg.mlp == "swiglu":
        p["wg"], a["wg"] = dense_init(ks[2], (D, F), ("embed", "mlp"))
    return p, a


def apply_mlp(cfg: ArchConfig, p: Dict, x: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x,
                   fsdp_use(p["wi"], ("embed", "mlp"), x.dtype))
    h = constrain(h, ("batch", "seq", "mlp_act"))
    if cfg.mlp == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x,
                       fsdp_use(p["wg"], ("embed", "mlp"), x.dtype))
        h = jax.nn.silu(h) * g
    elif cfg.mlp == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    y = jnp.einsum("bsf,fd->bsd", h,
                   fsdp_use(p["wo"], ("mlp", "embed"), x.dtype))
    return constrain(y, ("batch", "seq", "act_embed"))
