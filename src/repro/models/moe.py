"""Mixture-of-Experts with shard_map expert parallelism.

Design (DESIGN.md §5): activations enter the MoE block replicated over the
'model' mesh axis (batch sharded over 'pod'/'data'); expert weights are
sharded over 'model' (EP) with their d_model dim on 'data' (FSDP).  Inside
``shard_map`` each model-rank:

  1. computes the router redundantly (deterministic across ranks),
  2. selects, for each of its E/16 local experts, the top-C tokens by gate
     weight (fixed capacity C = T*k/E * capacity_factor — sort-free dispatch
     via lax.top_k),
  3. runs the expert MLPs as one batched matmul (E_local, C, d) x
     (E_local, d, f),
  4. scatter-adds weighted expert outputs into a (T, d) buffer and
     merges across ranks with a single psum.

The psum merge is the paper-faithful baseline; §Perf replaces it with a
reduce-scatter + sequence-sharded residual stream for the collective-bound
hillclimb.  Token dropping (beyond capacity) is the standard fixed-capacity
behaviour; dropped tokens fall through on the residual stream.

Without an active mesh (CPU smoke tests) the same math runs single-shard
with all experts local.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.parallel.sharding import (active_mesh, constrain, shard_map,
                                     spec_for)

from .layers import apply_mlp, dense_init, init_mlp
# NOTE: no fsdp_use() here — the expert FFN runs inside shard_map
# (manual axes), where mesh sharding constraints are disallowed and
# the expert weights are already per-shard slices.


def init_moe(cfg: ArchConfig, key) -> Tuple[Dict, Dict]:
    m = cfg.moe
    D, E, F = cfg.d_model, m.n_experts, m.d_ff_expert
    ks = jax.random.split(key, 6)
    p, a = {}, {}
    p["router"], a["router"] = dense_init(ks[0], (D, E), ("embed", None))
    p["wi"], a["wi"] = dense_init(ks[1], (E, D, F), ("experts", "embed", "mlp"),
                                  in_axis=1)
    p["wo"], a["wo"] = dense_init(ks[2], (E, F, D), ("experts", "mlp", "embed"),
                                  in_axis=1)
    if cfg.mlp == "swiglu":
        p["wg"], a["wg"] = dense_init(ks[3], (E, D, F),
                                      ("experts", "embed", "mlp"), in_axis=1)
    if m.n_shared:
        sh, sha = init_mlp(cfg, ks[4], d_ff=m.n_shared * m.d_ff_expert)
        p["shared"], a["shared"] = sh, sha
    if m.dense_residual:
        dr, dra = init_mlp(cfg, ks[5], d_ff=cfg.d_ff)
        p["dense"], a["dense"] = dr, dra
    return p, a


def _expert_ffn(cfg: ArchConfig, p: Dict, xg: jax.Array) -> jax.Array:
    """Batched expert MLP: xg (E_loc, C, D) -> (E_loc, C, D)."""
    h = jnp.einsum("ecd,edf->ecf", xg, p["wi"].astype(xg.dtype))
    if cfg.mlp == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", xg, p["wg"].astype(xg.dtype))
        h = jax.nn.silu(h) * g
    elif cfg.mlp == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(xg.dtype))


def _moe_shard(cfg: ArchConfig, p: Dict, x: jax.Array,
               shard_idx: int, n_shards: int, capacity: int) -> jax.Array:
    """MoE math for one model-rank holding E/n_shards experts.

    x (B, S, D) — the rank's (data-sharded) tokens, full feature dim.
    Returns this rank's contribution (B, S, D) (to be psum-merged).
    """
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E = m.n_experts
    e_loc = E // n_shards
    xt = x.reshape(T, D)

    gates = jax.nn.softmax(
        jnp.einsum("td,de->te", xt, p["router"].astype(xt.dtype))
        .astype(jnp.float32), axis=-1)                    # (T, E)
    topv, topi = jax.lax.top_k(gates, m.top_k)            # (T, k)
    topv = topv / (topv.sum(-1, keepdims=True) + 1e-9)
    # dense (T, E) weight matrix of the top-k selection
    sel = jnp.zeros((T, E), jnp.float32)
    sel = sel.at[jnp.arange(T)[:, None], topi].set(topv)  # scatter top-k
    w_loc = jax.lax.dynamic_slice_in_dim(sel, shard_idx * e_loc, e_loc, 1)

    # fixed-capacity per-expert token selection (top-C by gate weight)
    wv, idx = jax.lax.top_k(w_loc.T, capacity)            # (e_loc, C)
    valid = wv > 0.0
    xg = xt[idx]                                          # (e_loc, C, D) gather
    yg = _expert_ffn(cfg, p, xg)
    yg = yg * (wv * valid)[..., None].astype(yg.dtype)
    # scatter-add back to token buffer
    yt = jnp.zeros((T, D), yg.dtype)
    yt = yt.at[idx.reshape(-1)].add(yg.reshape(-1, D))
    return yt.reshape(B, S, D)


def moe_block(cfg: ArchConfig, p: Dict, x: jax.Array) -> jax.Array:
    """Full MoE block (router + experts + optional shared/dense paths)."""
    m = cfg.moe
    mesh = active_mesh()
    n_shards = mesh.shape["model"] if (mesh and "model" in mesh.shape.keys()) else 1

    B, S, D = x.shape
    # capacity per expert per rank, from the rank-local token count
    t_local = (B * S) // _data_shards(mesh)
    capacity = max(1, int(math.ceil(t_local * m.top_k / m.n_experts
                                    * m.capacity_factor)))

    if mesh is None or n_shards == 1:
        y = _moe_shard(cfg, p, x, 0, 1, max(1, int(math.ceil(
            B * S * m.top_k / m.n_experts * m.capacity_factor))))
    else:
        batch_spec = spec_for((B, S, D), ("batch", "seq", "act_embed"))
        expert3 = P("model", None, None)
        has_gate = "wg" in p
        operands = [x, p["router"], p["wi"], p["wo"]]
        specs = [batch_spec, P(None, None), expert3, expert3]
        if has_gate:
            operands.append(p["wg"])
            specs.append(expert3)

        def shard_fn(xb, router, wi, wo, *rest):
            pl_ = {"router": router, "wi": wi, "wo": wo}
            if rest:
                pl_["wg"] = rest[0]
            ridx = jax.lax.axis_index("model")
            y = _moe_shard(cfg, pl_, xb, ridx, n_shards, capacity)
            return jax.lax.psum(y, "model")

        y = shard_map(
            shard_fn, mesh=mesh,
            in_specs=tuple(specs),
            out_specs=batch_spec,
            check_vma=False,
        )(*operands)

    if m.n_shared:
        y = y + apply_mlp(cfg, p["shared"], x)
    if m.dense_residual:
        y = y + apply_mlp(cfg, p["dense"], x)
    return constrain(y, ("batch", "seq", "act_embed"))


def _data_shards(mesh) -> int:
    if mesh is None:
        return 1
    n = 1
    for ax in ("pod", "data"):
        if ax in mesh.shape.keys():
            n *= mesh.shape[ax]
    return n
