"""Mamba2 (SSD — state-space duality) blocks: chunked scan for train/prefill,
O(1) recurrent state for decode.

Chunked SSD (paper: arXiv:2405.21060): the sequence is split into chunks of
``cfg.ssm.chunk``; within a chunk the contribution is an attention-like
masked matmul (the "dual" form, MXU-friendly), across chunks a short
lax.scan carries the (nh, hd, ds) state.  The pure-jnp implementation here is
also the oracle for the ``kernels/ssd_scan`` Pallas kernel.

Shapes: x (B,S,nh,hd); B/C projections (B,S,ds) (single group, shared across
heads, as in Mamba2); dt (B,S,nh); A (nh,) negative reals.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel.sharding import constrain, fsdp_use

from .layers import dense_init


def init_mamba(cfg: ArchConfig, key) -> Tuple[Dict, Dict]:
    s = cfg.ssm
    D = cfg.d_model
    di = s.d_inner(D)
    nh = s.n_heads(D)
    ds = s.d_state
    ks = jax.random.split(key, 8)
    p, a = {}, {}
    p["wz"], a["wz"] = dense_init(ks[0], (D, di), ("embed", "ssm_inner"))
    p["wx"], a["wx"] = dense_init(ks[1], (D, di), ("embed", "ssm_inner"))
    p["wB"], a["wB"] = dense_init(ks[2], (D, ds), ("embed", "ssm_state"))
    p["wC"], a["wC"] = dense_init(ks[3], (D, ds), ("embed", "ssm_state"))
    p["wdt"], a["wdt"] = dense_init(ks[4], (D, nh), ("embed", None))
    p["conv"] = jax.random.normal(ks[5], (s.d_conv, di + 2 * ds)) * 0.1
    a["conv"] = ("conv", None)
    p["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, nh))      # A = -exp(A_log)
    a["A_log"] = (None,)
    p["dt_bias"] = jnp.zeros((nh,))
    a["dt_bias"] = (None,)
    p["Dskip"] = jnp.ones((nh,))
    a["Dskip"] = (None,)
    p["norm_scale"] = jnp.ones((di,))
    a["norm_scale"] = (None,)
    p["wo"], a["wo"] = dense_init(ks[6], (di, D), ("ssm_inner", "embed"))
    return p, a


def _causal_conv(u: jax.Array, kernel: jax.Array,
                 tail: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv, width W: u (B,S,C), kernel (W,C).

    ``tail`` (B,W-1,C) is the conv state from previous tokens (decode)."""
    W = kernel.shape[0]
    if tail is None:
        pad = jnp.zeros(u.shape[:1] + (W - 1,) + u.shape[2:], u.dtype)
    else:
        pad = tail.astype(u.dtype)
    up = jnp.concatenate([pad, u], axis=1)
    out = sum(up[:, i:i + u.shape[1], :] * kernel[i].astype(u.dtype)
              for i in range(W))
    return out


def ssd_chunked(xw: jax.Array, da: jax.Array, Bm: jax.Array, Cm: jax.Array,
                chunk: int, init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    xw (B,S,nh,hd): dt-weighted inputs (x * dt)
    da (B,S,nh):    per-step log-decay (dt * A, negative)
    Bm, Cm (B,S,ds)
    init_state (B,nh,hd,ds) or None
    returns y (B,S,nh,hd), final_state (B,nh,hd,ds)
    """
    B, S, nh, hd = xw.shape
    ds = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    xw = xw.reshape(B, nc, chunk, nh, hd)
    da = da.reshape(B, nc, chunk, nh).astype(jnp.float32)
    Bm = Bm.reshape(B, nc, chunk, ds)
    Cm = Cm.reshape(B, nc, chunk, ds)

    cum = jnp.cumsum(da, axis=2)                        # (B,nc,L,nh)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Li,Lj,nh)
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    # Mask INSIDE the exponent: at non-causal positions seg > 0 and exp(seg)
    # overflows; masking after exp makes the VJP compute 0*inf = NaN.
    L = jnp.exp(jnp.where(causal, seg, -jnp.inf))       # intra-chunk decay

    scores = jnp.einsum("bcis,bcjs->bcij", Cm, Bm,
                        preferred_element_type=jnp.float32)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp",
                         scores, L, xw.astype(jnp.float32))

    # End-of-chunk states: sum_j exp(cum_end - cum_j) * B_j (x) xw_j
    w_end = jnp.exp(cum[:, :, -1:, :] - cum)            # (B,nc,L,nh)
    chunk_state = jnp.einsum("bcjs,bcjh,bcjhp->bchps",
                             Bm, w_end, xw.astype(jnp.float32))
    chunk_decay = jnp.exp(cum[:, :, -1, :])             # (B,nc,nh)

    s0 = (jnp.zeros((B, nh, hd, ds), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(state, inputs):
        cstate, cdecay = inputs                          # (B,nh,hd,ds),(B,nh)
        new = state * cdecay[:, :, None, None] + cstate
        return new, state                                # emit state *before* chunk

    final, prev_states = jax.lax.scan(
        step, s0,
        (chunk_state.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)             # (B,nc,nh,hd,ds)

    y_inter = jnp.einsum("bcis,bchps,bcih->bcihp",
                         Cm, prev_states, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(B, S, nh, hd)
    return y.astype(xw.dtype), final


def ssd_reference(xw, da, Bm, Cm, init_state=None):
    """O(S) sequential recurrence — ground truth for tests."""
    B, S, nh, hd = xw.shape
    ds = Bm.shape[-1]
    s0 = (jnp.zeros((B, nh, hd, ds), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(state, t):
        decay = jnp.exp(da[:, t].astype(jnp.float32))     # (B,nh)
        upd = jnp.einsum("bs,bhp->bhps", Bm[:, t], xw[:, t].astype(jnp.float32))
        state = state * decay[:, :, None, None] + upd
        y = jnp.einsum("bs,bhps->bhp", Cm[:, t], state)
        return state, y

    final, ys = jax.lax.scan(step, s0, jnp.arange(S))
    return ys.swapaxes(0, 1).astype(xw.dtype), final


def mamba_block(cfg: ArchConfig, p: Dict, x: jax.Array,
                cache: Optional[Dict] = None,
                use_kernel: bool = False) -> Tuple[jax.Array, Optional[Dict]]:
    """Full Mamba2 block.  x (B,S,D).

    cache = {"conv": (B, W-1, di+2ds), "state": (B,nh,hd,ds)}; pass a cache
    dict for decode/prefill-with-state; returns (y, new_cache or None).
    """
    s = cfg.ssm
    D = cfg.d_model
    di, nh, ds = s.d_inner(D), s.n_heads(D), s.d_state
    B, S, _ = x.shape

    z = jnp.einsum("bsd,de->bse", x, fsdp_use(p["wz"], ("embed", "ssm_inner"), x.dtype))
    xs = jnp.einsum("bsd,de->bse", x, fsdp_use(p["wx"], ("embed", "ssm_inner"), x.dtype))
    Bm = jnp.einsum("bsd,de->bse", x, fsdp_use(p["wB"], ("embed", "ssm_state"), x.dtype))
    Cm = jnp.einsum("bsd,de->bse", x, fsdp_use(p["wC"], ("embed", "ssm_state"), x.dtype))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x,
                   fsdp_use(p["wdt"], ("embed", None), x.dtype)
                   ).astype(jnp.float32)
        + p["dt_bias"])                                   # (B,S,nh)

    u = jnp.concatenate([xs, Bm, Cm], axis=-1)
    tail = cache["conv"] if cache is not None else None
    u = jax.nn.silu(_causal_conv(u, p["conv"], tail))
    new_tail = None
    if cache is not None:
        full = (jnp.concatenate([tail.astype(u.dtype),
                                 jnp.concatenate([xs, Bm, Cm], -1)], axis=1)
                if tail is not None else jnp.concatenate([xs, Bm, Cm], -1))
        new_tail = full[:, -(s.d_conv - 1):, :]
    xs, Bm, Cm = (u[..., :di], u[..., di:di + ds], u[..., di + ds:])

    xh = xs.reshape(B, S, nh, s.head_dim)
    xh = constrain(xh, ("batch", "seq", "heads", "head_dim"))
    A = -jnp.exp(p["A_log"])                              # (nh,)
    da = dt * A
    xw = xh * dt[..., None].astype(xh.dtype)

    init_state = cache["state"] if cache is not None else None
    if S == 1:
        # decode: one recurrence step, no chunking
        y, final = ssd_reference(xw, da, Bm, Cm, init_state)
    elif use_kernel:
        from repro.kernels.ssd_scan import ops as ssd_ops
        y, final = ssd_ops.ssd(xw, da, Bm, Cm, s.chunk, init_state)
    else:
        pad = (-S) % s.chunk
        if pad:
            xw = jnp.pad(xw, ((0, 0), (0, pad), (0, 0), (0, 0)))
            da = jnp.pad(da, ((0, 0), (0, pad)) + ((0, 0),) * (da.ndim - 2))
            Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
            Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        y, final = ssd_chunked(xw, da, Bm, Cm, s.chunk, init_state)
        y = y[:, :S]

    y = y + xh * p["Dskip"][:, None].astype(xh.dtype)
    y = y.reshape(B, S, di)
    # gated RMSNorm then out-projection
    g = y * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(g.astype(jnp.float32)), -1, keepdims=True)
    g = (g.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6)
         * p["norm_scale"]).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", g,
                 fsdp_use(p["wo"], ("ssm_inner", "embed"), x.dtype))
    out = constrain(out, ("batch", "seq", "act_embed"))

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_tail, "state": final}
    return out, new_cache


def mamba_cache_spec(cfg: ArchConfig, batch: int):
    """Abstract (shape, dtype, logical-axes) for one block's cache."""
    s = cfg.ssm
    D = cfg.d_model
    di, nh, ds = s.d_inner(D), s.n_heads(D), s.d_state
    return {
        "conv": ((batch, s.d_conv - 1, di + 2 * ds), jnp.bfloat16,
                 ("batch", None, None)),
        "state": ((batch, nh, s.head_dim, ds), jnp.float32,
                  ("batch", "heads", "head_dim", "ssm_state")),
    }
