"""DecoderLM: one composable decoder assembled from an ``ArchConfig``.

All ten assigned architectures are instances of this class (dense / MoE /
MLA / hybrid Mamba2 / pure SSM / audio / VLM backbones).  Layers are grouped
into *superblocks* (``cfg.pattern``) and stacked with ``jax.lax.scan`` so HLO
size and compile time are independent of depth; zamba2's weight-shared
attention block is passed into the scan as a closure (unstacked).

Three entry points:
  forward(params, batch)                 -> logits (train / scoring)
  prefill(params, batch)                 -> (cache, logits)
  decode_step(params, cache, tokens)     -> (logits, cache)

KV caches are ring buffers with an explicit position buffer (``k_pos``), so
sliding-window (gemma3 local), full-context, MLA-compressed, and SSM state
caches all share one masking rule: a slot is attendable iff its stored
position is in [q_pos - window, q_pos].
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.parallel.sharding import constrain, fsdp_use

from . import layers, moe as moe_mod, ssm as ssm_mod

Params = Dict[str, Any]
COMPUTE_DTYPE = jnp.bfloat16


def _kind_key(kind: str, j: int) -> str:
    return f"{kind}_{j}"


class DecoderLM:
    def __init__(self, cfg: ArchConfig, remat: bool = True,
                 use_ssd_kernel: bool = False):
        self.cfg = cfg
        self.remat = remat
        self.use_ssd_kernel = use_ssd_kernel

    # ------------------------------------------------------------------ init
    def init(self, key) -> Tuple[Params, Params]:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        p: Params = {}
        a: Params = {}
        if cfg.frontend != "audio_frames":
            # Embed table: vocab replicated, d_model sharded on 'model' — the
            # token gather then needs no collective (batch-sharded indices x
            # dim-sharded operand); a vocab-sharded table would all-gather
            # the entire table per step.  The output head (a matmul) shards
            # its vocab dim cleanly instead.
            p["embed"] = jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * 0.02
            a["embed"] = (None, "embed_td")
        p["head"], a["head"] = layers.dense_init(
            keys[1], (cfg.d_model, cfg.vocab), ("embed", "w_vocab"))
        p["final_norm"], a["final_norm"] = layers.init_norm(cfg, keys[2])

        blocks: Params = {}
        blocks_a: Params = {}
        bkeys = jax.random.split(keys[3], len(cfg.pattern))
        for j, kind in enumerate(cfg.pattern):
            if kind == "shared_attn":
                continue
            sb_keys = jax.random.split(bkeys[j], cfg.n_superblocks)
            # vmap stacks params over superblocks; axes (static strings) come
            # from a single non-vmapped call.
            bp = jax.vmap(lambda k, kind=kind: self._init_block(kind, k)[0])(sb_keys)
            _, ba = self._init_block(kind, bkeys[j])
            blocks[_kind_key(kind, j)] = bp
            blocks_a[_kind_key(kind, j)] = jax.tree.map(
                lambda ax: (None,) + ax, ba,
                is_leaf=lambda x: isinstance(x, tuple) and all(
                    isinstance(e, (str, type(None))) for e in x))
        p["blocks"], a["blocks"] = blocks, blocks_a

        if "shared_attn" in cfg.pattern:
            p["shared"], a["shared"] = self._init_block("global", keys[4])
        return p, a

    def _init_block(self, kind: str, key) -> Tuple[Params, Params]:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        if kind == "mamba":
            mp, ma = ssm_mod.init_mamba(cfg, ks[0])
            np_, na = layers.init_norm(cfg, ks[1])
            return ({"ln": np_, "mamba": mp}, {"ln": na, "mamba": ma})
        p: Params = {}
        a: Params = {}
        p["ln1"], a["ln1"] = layers.init_norm(cfg, ks[0])
        if cfg.mla is not None:
            p["attn"], a["attn"] = layers.init_mla(cfg, ks[1])
        else:
            p["attn"], a["attn"] = layers.init_attention(cfg, ks[1])
        p["ln2"], a["ln2"] = layers.init_norm(cfg, ks[2])
        if cfg.moe is not None:
            p["ffn"], a["ffn"] = moe_mod.init_moe(cfg, ks[3])
        else:
            p["ffn"], a["ffn"] = layers.init_mlp(cfg, ks[3])
        return p, a

    # ----------------------------------------------------------- embeddings
    def embed_inputs(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        if cfg.frontend == "audio_frames":
            x = batch["frame_emb"].astype(COMPUTE_DTYPE)
        elif cfg.frontend == "vision_patches":
            tok = params["embed"][batch["tokens"]].astype(COMPUTE_DTYPE)
            x = jnp.concatenate(
                [batch["patch_emb"].astype(COMPUTE_DTYPE), tok], axis=1)
        else:
            x = params["embed"][batch["tokens"]].astype(COMPUTE_DTYPE)
        return constrain(x, ("batch", "seq", "act_embed"))

    # ---------------------------------------------------------------- blocks
    def _apply_block(self, kind: str, p: Params, x: jax.Array,
                     cache: Optional[Params], write_cache: bool,
                     pos0: jax.Array) -> Tuple[jax.Array, Optional[Params]]:
        """One block on x (B,S,D); returns (x, new_cache_slice)."""
        cfg = self.cfg
        B, S, D = x.shape
        q_pos = pos0 + jnp.arange(S)
        new_cache: Optional[Params] = None

        if kind == "mamba":
            h = layers.apply_norm(cfg, p["ln"], x)
            y, nc = ssm_mod.mamba_block(cfg, p["mamba"], h, cache=cache,
                                        use_kernel=self.use_ssd_kernel)
            return x + y, nc

        window = cfg.window if kind == "local" else None
        h = layers.apply_norm(cfg, p["ln1"], x)

        # Cache READ vs WRITE are separate concerns:
        #  * decode (S == 1) attends over (prior ring buffer ∥ current k/v) —
        #    attending over the *written* buffer would be wrong whenever a
        #    chunk exceeds the window, and the position mask hides stale
        #    slots either way;
        #  * prefill (S > 1) starts from an empty cache, so it attends over
        #    the RAW current k/v only (full-forward semantics) — attending
        #    over the concat doubles prefill_32k's buffers and score width
        #    for rows that are all masked invalid (EXPERIMENTS.md §Dry-run).
        # The write itself is independent and goes to ``new_cache``.
        read_cache = cache is not None and S == 1
        if cfg.mla is not None:
            ckv, krope = layers.mla_compress(cfg, p["attn"], h, q_pos)
            if cache is not None:
                _, _, _, new_cache = _cache_write_mla(
                    cache, ckv, krope, q_pos, write_cache)
            if read_cache:
                ckv_all = jnp.concatenate(
                    [cache["ckv"], ckv.astype(cache["ckv"].dtype)], axis=1)
                krope_all = jnp.concatenate(
                    [cache["krope"], krope.astype(cache["krope"].dtype)], axis=1)
                k_pos = jnp.concatenate([cache["k_pos"], q_pos])
                valid = k_pos >= 0
            else:
                ckv_all, krope_all, k_pos, valid = ckv, krope, q_pos, None
            y = layers.mla_attention(cfg, p["attn"], h, ckv_all, krope_all,
                                     q_pos, jnp.maximum(k_pos, 0), k_valid=valid)
        else:
            k, v = layers.project_kv(cfg, p["attn"], h, q_pos)
            if cache is not None:
                _, _, _, new_cache = _cache_write_kv(
                    cache, k, v, q_pos, write_cache)
            if read_cache:
                k_all = jnp.concatenate(
                    [cache["k"], k.astype(cache["k"].dtype)], axis=1)
                v_all = jnp.concatenate(
                    [cache["v"], v.astype(cache["v"].dtype)], axis=1)
                k_pos = jnp.concatenate([cache["k_pos"], q_pos])
                valid = k_pos >= 0
            else:
                k_all, v_all, k_pos, valid = k, v, q_pos, None
            y = layers.attention(cfg, p["attn"], h, k_all, v_all,
                                 q_pos, jnp.maximum(k_pos, 0),
                                 window=window, k_valid=valid)
        x = x + y

        h = layers.apply_norm(cfg, p["ln2"], x)
        if cfg.moe is not None:
            y = moe_mod.moe_block(cfg, p["ffn"], h)
        else:
            y = layers.apply_mlp(cfg, p["ffn"], h)
        return x + y, new_cache

    # ------------------------------------------------------------- superblock
    def _superblock(self, carry, xs, shared_p: Optional[Params],
                    write_cache: bool):
        """Scan body: apply one superblock (cfg.pattern) of blocks."""
        cfg = self.cfg
        x, pos0 = carry
        block_p, cache_sb = xs
        new_cache_sb: Params = {}
        for j, kind in enumerate(cfg.pattern):
            key = _kind_key(kind, j)
            if kind == "shared_attn":
                p_j = shared_p
            else:
                p_j = block_p[key]
            c_j = None if cache_sb is None else cache_sb.get(key)
            apply = functools.partial(
                self._apply_block, "global" if kind == "shared_attn" else kind,
                write_cache=write_cache)
            if self.remat:
                # nested remat: the outer (superblock) checkpoint keeps only
                # scan carries; this inner one means the superblock's
                # backward recompute holds one *block's* internals at a time
                # instead of all of them.
                apply = jax.checkpoint(apply)
            x, nc = apply(p_j, x, c_j, pos0=pos0)
            if nc is not None:
                new_cache_sb[key] = nc
        return (x, pos0), (new_cache_sb or None)

    def _run_blocks(self, params: Params, x: jax.Array, pos0: jax.Array,
                    cache: Optional[Params], write_cache: bool
                    ) -> Tuple[jax.Array, Optional[Params]]:
        cfg = self.cfg
        shared_p = params.get("shared")
        body = functools.partial(self._superblock, shared_p=shared_p,
                                 write_cache=write_cache)
        # Remat is per-block only (inside _superblock).  An additional outer
        # checkpoint(nothing_saveable) around the scan body made every block
        # forward run ~3x (fwd + outer recompute + inner recompute); saving
        # the (B,S,D) block boundaries instead costs ~n_layers * 50 MB/device
        # and removes one full forward recompute (EXPERIMENTS.md §Perf,
        # musicgen iteration 4 — confirmed on all three hillclimb cells).
        if cfg.n_superblocks <= 2:
            # Unrolled: straight-line HLO so XLA cost analysis counts every
            # superblock (a lax.scan body is counted once regardless of trip
            # count) — the dry-run extrapolates per-superblock costs from
            # 1- and 2-superblock lowerings.  Also exercised by smoke tests.
            carry = (x, pos0)
            caches = []
            for i in range(cfg.n_superblocks):
                p_i = jax.tree.map(lambda l: l[i], params["blocks"])
                c_i = (None if cache is None
                       else jax.tree.map(lambda l: l[i], cache))
                carry, nc = body(carry, (p_i, c_i))
                caches.append(nc)
            x, _ = carry
            new_cache = (None if caches[0] is None else
                         jax.tree.map(lambda *ls: jnp.stack(ls), *caches))
            return x, new_cache
        (x, _), new_cache = jax.lax.scan(
            body, (x, pos0), (params["blocks"], cache))
        return x, new_cache

    # ------------------------------------------------------------------ api
    def forward(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        """Full-sequence logits (training / scoring path, no cache)."""
        x = self.embed_inputs(params, batch)
        x, _ = self._run_blocks(params, x, jnp.int32(0), None, False)
        return self._head(params, x)

    def loss(self, params: Params, batch: Dict[str, jax.Array],
             chunk_tokens: int = 4096) -> jax.Array:
        """Chunked cross-entropy: the (tokens, vocab) logits matrix is never
        materialized — the head matmul + CE run per token-chunk under remat
        (backward recomputes each chunk's logits).  At gemma3 scale this is
        the difference between ~10 GiB of loss buffers and ~0.2 GiB."""
        cfg = self.cfg
        x = self.embed_inputs(params, batch)
        x, _ = self._run_blocks(params, x, jnp.int32(0), None, False)
        x = layers.apply_norm(cfg, params["final_norm"], x)
        if cfg.frontend == "vision_patches":
            x = x[:, cfg.vision_tokens:]
        labels = batch["labels"]
        B, S, D = x.shape
        xt = x.reshape(B * S, D)
        lt = labels.reshape(B * S)
        n = B * S
        n_chunks = max(1, n // max(chunk_tokens, 1))
        while n % n_chunks:
            n_chunks -= 1
        head = params["head"]

        @jax.checkpoint
        def chunk_nll(args):
            xc, lc = args
            logits = jnp.einsum(
                "td,dv->tv", xc,
                fsdp_use(head, ("embed", "w_vocab"), xc.dtype))
            logits = constrain(logits, ("batch", "vocab_act"))
            lf = logits.astype(jnp.float32)
            lse = jax.nn.logsumexp(lf, axis=-1)
            ll = jnp.take_along_axis(lf, lc[:, None], axis=-1)[:, 0]
            return (lse - ll).sum()

        if n_chunks == 1:
            total = chunk_nll((xt, lt))
        else:
            xc = xt.reshape(n_chunks, n // n_chunks, D)
            lc = lt.reshape(n_chunks, n // n_chunks)
            if layers.FORCE_UNROLL_CHUNKS and n_chunks <= 64:
                # cost probes: count every chunk (lax.map bodies are counted
                # once by cost_analysis — see layers.FORCE_UNROLL_CHUNKS)
                total = sum(chunk_nll((xc[i], lc[i]))
                            for i in range(n_chunks))
            else:
                total = jax.lax.map(chunk_nll, (xc, lc)).sum()
        return total / n

    def _head(self, params: Params, x: jax.Array) -> jax.Array:
        x = layers.apply_norm(self.cfg, params["final_norm"], x)
        logits = jnp.einsum(
            "bsd,dv->bsv", x,
            fsdp_use(params["head"], ("embed", "w_vocab"), x.dtype))
        return constrain(logits, ("batch", "seq", "vocab_act"))

    # -- serving ----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Tuple[Params, Params]:
        """(cache, logical-axes) pytrees; leaves stacked over superblocks."""
        cfg = self.cfg
        n_sb = cfg.n_superblocks
        cache: Params = {}
        axes: Params = {}
        for j, kind in enumerate(cfg.pattern):
            key = _kind_key(kind, j)
            if kind == "mamba":
                spec = ssm_mod.mamba_cache_spec(cfg, batch)
                cache[key] = {
                    name: jnp.zeros((n_sb,) + shp, dt)
                    for name, (shp, dt, ax) in spec.items()}
                axes[key] = {name: (None,) + ax
                             for name, (shp, dt, ax) in spec.items()}
                continue
            T = cfg.window if kind == "local" else max_len
            if cfg.mla is not None:
                m = cfg.mla
                cache[key] = {
                    "ckv": jnp.zeros((n_sb, batch, T, m.kv_lora_rank),
                                     COMPUTE_DTYPE),
                    "krope": jnp.zeros((n_sb, batch, T, m.qk_rope_dim),
                                       COMPUTE_DTYPE),
                    "k_pos": jnp.full((n_sb, T), -1, jnp.int32),
                }
                axes[key] = {
                    "ckv": (None, "batch", "cache_seq", "kv_lora"),
                    "krope": (None, "batch", "cache_seq", None),
                    "k_pos": (None, "cache_seq"),
                }
            else:
                KV, hd = cfg.n_kv_heads, cfg.hd
                cache[key] = {
                    "k": jnp.zeros((n_sb, batch, T, KV, hd), COMPUTE_DTYPE),
                    "v": jnp.zeros((n_sb, batch, T, KV, hd), COMPUTE_DTYPE),
                    "k_pos": jnp.full((n_sb, T), -1, jnp.int32),
                }
                axes[key] = {
                    "k": (None, "batch", "cache_seq", "kv_heads", "head_dim"),
                    "v": (None, "batch", "cache_seq", "kv_heads", "head_dim"),
                    "k_pos": (None, "cache_seq"),
                }
        return ({"pos": jnp.int32(0), "layers": cache},
                {"pos": (), "layers": axes})

    def prefill(self, params: Params, batch: Dict[str, jax.Array],
                cache: Params) -> Tuple[Params, jax.Array]:
        """Run the prompt through the model, filling the cache."""
        x = self.embed_inputs(params, batch)
        S = x.shape[1]
        x, new_layers = self._run_blocks(params, x, jnp.int32(0),
                                         cache["layers"], True)
        logits = self._head(params, x[:, -1:])
        return {"pos": jnp.int32(S), "layers": new_layers}, logits

    def decode_step(self, params: Params, cache: Params,
                    tokens: jax.Array) -> Tuple[jax.Array, Params]:
        """One decode step: tokens (B,1) -> logits (B,1,V), updated cache."""
        cfg = self.cfg
        if cfg.frontend == "audio_frames":
            # audio stub: decode consumes the embedding of the last emitted
            # codebook token through the (stub) frontend = embed via head^T.
            x = jnp.take(params["head"].T, tokens[:, 0], axis=0)[:, None, :]
            x = x.astype(COMPUTE_DTYPE)
        else:
            x = params["embed"][tokens].astype(COMPUTE_DTYPE)
        x = constrain(x, ("batch", "seq", "act_embed"))
        pos = cache["pos"]
        x, new_layers = self._run_blocks(params, x, pos, cache["layers"], True)
        logits = self._head(params, x)
        return logits, {"pos": pos + tokens.shape[1], "layers": new_layers}


# ---------------------------------------------------------------------------
# Cache write helpers (ring buffers with explicit position tracking).
# ---------------------------------------------------------------------------

def _ring_write(buf: jax.Array, new: jax.Array, pos_buf: jax.Array,
                q_pos: jax.Array, axis: int = 1):
    """Write new (B,S,...) into ring buffer (B,T,...) at q_pos % T."""
    T = buf.shape[axis]
    S = new.shape[axis]
    if S >= T:
        # keep the last T entries (prefill longer than the window), rolled so
        # the ring invariant ``slot(p) = p % T`` holds — decode writes rely on
        # it to evict exactly the oldest (out-of-window) entry.
        tail = jax.lax.slice_in_dim(new, S - T, S, axis=axis)
        tail_pos = jax.lax.slice_in_dim(q_pos, S - T, S, axis=0)
        shift = tail_pos[0] % T
        tail = jnp.roll(tail, shift, axis=axis)
        tail_pos = jnp.roll(tail_pos, shift, axis=0)
        return tail.astype(buf.dtype), tail_pos
    start = q_pos[0] % T
    idx = (start + jnp.arange(S)) % T      # wraparound with static shapes
    out = _scatter_axis(buf, new.astype(buf.dtype), idx, axis)
    pos_out = pos_buf.at[idx].set(q_pos)
    return out, pos_out


def _scatter_axis(buf: jax.Array, new: jax.Array, idx: jax.Array, axis: int):
    moved = jnp.moveaxis(buf, axis, 0)
    new_m = jnp.moveaxis(new, axis, 0)
    moved = moved.at[idx].set(new_m)
    return jnp.moveaxis(moved, 0, axis)


def _cache_write_kv(cache: Params, k: jax.Array, v: jax.Array,
                    q_pos: jax.Array, write: bool):
    kb, vb, pb = cache["k"], cache["v"], cache["k_pos"]
    if not write:
        return kb, vb, pb, None
    kn, pn = _ring_write(kb, k, pb, q_pos)
    vn, _ = _ring_write(vb, v, pb, q_pos)
    return kn, vn, pn, {"k": kn, "v": vn, "k_pos": pn}


def _cache_write_mla(cache: Params, ckv: jax.Array, krope: jax.Array,
                     q_pos: jax.Array, write: bool):
    cb, rb, pb = cache["ckv"], cache["krope"], cache["k_pos"]
    if not write:
        return cb, rb, pb, None
    cn, pn = _ring_write(cb, ckv, pb, q_pos)
    rn, _ = _ring_write(rb, krope, pb, q_pos)
    return cn, rn, pn, {"ckv": cn, "krope": rn, "k_pos": pn}


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
