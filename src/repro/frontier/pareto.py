"""Pure array-level Pareto dominance over mixed min/max axes.

The quorum-space frontier (§5/§6) compares systems on axes that pull in
different directions — latency quantiles shrink with smaller fast quorums
while fault tolerance grows with larger ones — and the streamed scores
carry a *known* uncertainty: sketch quantiles are exact only up to the
DDSketch relative error, Monte-Carlo rates only up to binomial noise.
This module computes the maximal (non-dominated) set under dominance that
respects both:

  orient      every axis is flipped so "larger is better" uniformly
              (``Axis.maximize``); NaN scores (nothing decided) orient to
              -inf, i.e. worst.
  quantize    each axis snaps to an epsilon grid *before* comparison —
              absolute steps of ``eps`` for rates/counts, log-scale steps
              of ratio ``sketch_gamma(eps)`` for sketch-valued latency
              axes (``Axis.relative``), the exact bucket geometry of
              ``montecarlo.streaming``.  Values indistinguishable at the
              measurement's precision land in one cell and compare equal.
  dominate    on the quantized matrix, j dominates i iff j is >= on every
              axis and > on at least one.  Quantized dominance is a strict
              partial order (irreflexive, transitive), which is what makes
              the frontier well-behaved:

    * no frontier point is dominated (by construction);
    * every excluded point is dominated by some *frontier* point (follow
      the dominance chain — finite strict partial orders have maximal
      elements above every element);
    * exact ties (equal quantized vectors) never dominate each other, so
      duplicates and within-epsilon copies are kept or excluded together;
    * membership depends only on the multiset of value vectors, so the
      frontier is invariant under input permutation and duplicated rows.

The kernel is plain numpy over an (M, A) value matrix — O(M^2 A) compares,
blocked so the pairwise tensor never exceeds a few MB.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

import jax

# Relative (log-grid) quantization floors tiny values here so log() is
# defined; matches the streaming sketch's lower edge.
_REL_MIN = 1e-12


@dataclass(frozen=True)
class Axis:
    """One frontier axis: a name, a direction, and a measurement precision.

    ``maximize``  False (default) = smaller is better (latencies, rates);
                  True = larger is better (fault tolerance).
    ``eps``       quantization step: scores closer than this are ties.
                  0.0 compares raw values exactly.
    ``relative``  interpret ``eps`` as a *relative* error (DDSketch-style
                  log buckets with growth ``(1+eps)/(1-eps)``) instead of
                  an absolute step — the right grid for sketch quantiles,
                  whose guarantee is relative.
    """

    name: str
    maximize: bool = False
    eps: float = 0.0
    relative: bool = False

    def __post_init__(self) -> None:
        if self.eps < 0:
            raise ValueError(f"axis {self.name!r}: eps must be >= 0")
        if self.relative and not self.eps:
            raise ValueError(f"axis {self.name!r}: relative quantization "
                             f"needs eps > 0")


def quantize(values: np.ndarray,
             axes: Sequence[Axis]) -> np.ndarray:
    """(M, A) raw scores -> (M, A) float64 oriented-and-quantized matrix.

    Output columns are "larger is better" on every axis; eps-quantized
    columns hold integral cell indices (as float64), eps=0 columns the raw
    values.  NaN maps to -inf (worst) after orientation, so systems that
    never decided sort below everything without poisoning comparisons.
    """
    v = np.asarray(values, np.float64)
    if v.ndim != 2 or v.shape[1] != len(axes):
        raise ValueError(f"values {v.shape} inconsistent with "
                         f"{len(axes)} axes")
    out = np.empty_like(v)
    with np.errstate(invalid="ignore", divide="ignore"):
        for a, ax in enumerate(axes):
            col = v[:, a]
            if ax.relative:
                # the streaming sketch's bucket geometry: cells grow by
                # gamma = (1+eps)/(1-eps); +0.5 centers cells so exact
                # bucket representatives (bucket_value outputs) sit
                # mid-cell, never on a boundary
                gamma = (1.0 + ax.eps) / (1.0 - ax.eps)
                col = np.floor(np.log(np.maximum(col, _REL_MIN))
                               / math.log(gamma) + 0.5)
            elif ax.eps:
                col = np.floor(col / ax.eps + 0.5)
            oriented = col if ax.maximize else -col
            out[:, a] = np.where(np.isnan(v[:, a]), -np.inf, oriented)
    return out


def dominates(oriented: np.ndarray, j: int, i: int) -> bool:
    """Does row j dominate row i in an oriented/quantized matrix?"""
    return bool((oriented[j] >= oriented[i]).all()
                and (oriented[j] > oriented[i]).any())


def maximal_mask(oriented: np.ndarray, *, block: int = 512) -> np.ndarray:
    """(M,) bool: rows of an oriented ("larger is better", already
    quantized) matrix that no other row dominates.  Exact ties survive
    together.  Blocked O(M^2 A) numpy; no sorting, no recursion."""
    o = np.asarray(oriented, np.float64)
    m = o.shape[0]
    keep = np.ones(m, bool)
    for lo in range(0, m, block):
        hi = min(lo + block, m)
        blk = o[lo:hi]                                   # (B, A)
        ge = (o[None, :, :] >= blk[:, None, :]).all(-1)  # [b, j]: j >= b
        gt = (o[None, :, :] > blk[:, None, :]).any(-1)
        keep[lo:hi] = ~(ge & gt).any(axis=1)
    return keep


def pareto_mask(values: np.ndarray, axes: Sequence[Axis]) -> np.ndarray:
    """(M,) bool frontier membership of raw scores under ``axes``."""
    return maximal_mask(quantize(values, axes))


# ---------------------------------------------------------------------------
# FrontierResult: scores + membership as one queryable pytree.
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclass
class FrontierResult:
    """A scored quorum-space frontier.

    ``labels``   per-system labels (aux data; one per row)
    ``axes``     the ``Axis`` tuple the mask was computed under (aux)
    ``values``   (M, A) raw scores, axis order matching ``axes``
    ``mask``     (M,) bool frontier membership
    ``streams``  optional dict of the ``StreamSummary`` states the scores
                 were extracted from (e.g. ``{"fast": ..., "race": ...}``)
                 — mergeable / re-queryable for other quantiles
    """

    labels: Tuple[str, ...]
    axes: Tuple[Axis, ...]
    values: Any
    mask: Any
    streams: Optional[Dict[str, Any]] = None

    def tree_flatten(self):
        return ((self.values, self.mask, self.streams),
                (self.labels, self.axes))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux[0], aux[1], children[0], children[1], children[2])

    # -- queries -----------------------------------------------------------
    @property
    def axis_names(self) -> Tuple[str, ...]:
        return tuple(a.name for a in self.axes)

    @property
    def frontier_indices(self) -> Tuple[int, ...]:
        return tuple(int(i) for i in np.flatnonzero(np.asarray(self.mask)))

    @property
    def frontier_labels(self) -> Tuple[str, ...]:
        return tuple(self.labels[i] for i in self.frontier_indices)

    def row(self, which) -> Dict[str, float]:
        """One system's scores by label or index, plus membership."""
        i = which if isinstance(which, int) else self.labels.index(which)
        vals = np.asarray(self.values)
        out = {a.name: float(vals[i, k]) for k, a in enumerate(self.axes)}
        out["on_frontier"] = bool(np.asarray(self.mask)[i])
        return out

    def table(self, frontier_only: bool = True) -> str:
        """Human-readable score table, frontier members by default."""
        vals = np.asarray(self.values)
        mask = np.asarray(self.mask)
        idx = [i for i in range(len(self.labels))
               if mask[i] or not frontier_only]
        head = ["system", *self.axis_names, "frontier"]
        body = [[self.labels[i],
                 *(f"{vals[i, k]:.4g}" for k in range(len(self.axes))),
                 "*" if mask[i] else ""] for i in idx]
        widths = [max(len(r[c]) for r in [head] + body)
                  for c in range(len(head))]
        fmt = lambda r: "  ".join(s.ljust(w) for s, w in zip(r, widths))
        rule = "  ".join("-" * w for w in widths)
        return "\n".join([fmt(head), rule, *map(fmt, body)])

    def to_dict(self, frontier_only: bool = True) -> Dict[str, float]:
        """Flatten to ``{label.axis: scalar}`` (benchmark CSV shape), plus
        ``n_systems`` / ``n_frontier`` and per-label membership bits."""
        vals = np.asarray(self.values)
        mask = np.asarray(self.mask)
        flat: Dict[str, float] = {
            "n_systems": float(len(self.labels)),
            "n_frontier": float(int(mask.sum())),
        }
        for i, label in enumerate(self.labels):
            if frontier_only and not mask[i]:
                continue
            for k, a in enumerate(self.axes):
                flat[f"{label}.{a.name}"] = float(vals[i, k])
            flat[f"{label}.on_frontier"] = float(mask[i])
        return flat
