"""Streamed quorum-space Pareto frontier (DESIGN.md §8).

The paper's payoff (§5/§6) is a *space* of FFPaxos-valid quorum systems
trading latency against fault tolerance.  This package walks that space
end to end:

  ``families``   enumerate FFP-valid systems per family — the full
                 cardinality space (Eqs. 13/14) at any n, 3xC grids over
                 factorizations of n, weighted voting — as labeled
                 ``Member``s lowering into one shared mask batch
  ``score``      stream the whole batch through ``fast_path_stream`` /
                 ``race_stream`` (10^7 trials in fixed memory, common
                 random numbers, one compile per path) and extract the
                 frontier axes, p99.9 tail included
  ``pareto``     the pure array-level dominance kernel: mixed min/max
                 axes, epsilon ties matched to sketch precision, and the
                 ``FrontierResult`` pytree with ``.table()``/``.to_dict()``

Front doors: ``repro.api.frontier(...)`` and ``Experiment.frontier()``.
"""
from . import families, pareto, score  # noqa: F401
from .families import (Member, all_families, cardinality_family,  # noqa: F401
                       family, grid_family, relaxed_family, weighted_family)
from .pareto import (Axis, FrontierResult, dominates,  # noqa: F401
                     maximal_mask, pareto_mask, quantize)
from .score import default_axes, score_systems  # noqa: F401
