"""Streamed scorer: one mask batch, two stream passes, five frontier axes.

``score_systems`` evaluates an entire family batch through the streaming
engine (DESIGN.md §7) and extracts the per-system axes the quorum-space
tradeoff is about:

  fast_p50_ms    conflict-free fast-path median        (minimize)
  race_p999_ms   p99.9 commit latency under a K-way    (minimize)
                 race — the tail axis only streamed
                 trial counts make meaningful, and the
                 axis that finally prices q2c (the
                 recovery quorum dominates the tail)
  p_recovery     P(coordinated recovery | race)        (minimize)
  ft_fast        steady-state fast-path crash budget   (maximize)
  ft_phase1      crashes survivable for recovery       (maximize)
  ft_classic     classic phase-2 crash budget          (maximize —
                 without it, systems whose races never
                 recover tie on every axis across all
                 q2c choices and the frontier degenerates)

Everything latency-shaped comes from exactly two ``StreamSummary`` states —
one ``fast_path_stream`` pass and one ``race_stream`` pass over the whole
batch — so every system sees identical sampled delays (common random
numbers) and one compile covers the entire family per engine path.  Fault
tolerance is arithmetic for cardinality specs and brute force over the
masks otherwise (embedding-invariant: zero-weight acceptors never help a
crash set kill a quorum).

Latency axes carry the sketch's relative ``precision`` as their dominance
epsilon and the rate axis a 3-sigma binomial epsilon at the streamed trial
count, so the Pareto mask never splits ties the measurement cannot
actually resolve (``pareto.quantize``).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quorum import QuorumMasks, QuorumSpec
from repro.montecarlo import engine, streaming

from .families import Member
from .pareto import Axis, FrontierResult, pareto_mask

DEFAULT_TRIALS = 1_000_000
DEFAULT_DELTA_MS = 0.2
# Smaller than streaming.DEFAULT_CHUNK: the race path materializes
# (M, chunk, n) gathers per system inside the scan, and frontier batches
# run to hundreds of systems.
DEFAULT_CHUNK = 8_192

AXIS_NAMES = ("fast_p50_ms", "race_p999_ms", "p_recovery", "ft_fast",
              "ft_phase1", "ft_classic")


def default_axes(precision: float = streaming.DEFAULT_PRECISION,
                 trials: int = DEFAULT_TRIALS) -> Tuple[Axis, ...]:
    """The standard six-axis frontier, epsilons matched to what the
    measurement can resolve: sketch precision on latencies (relative,
    log-grid), 3-sigma binomial noise on the recovery rate, exact on the
    integral fault-tolerance axes."""
    rate_eps = 3.0 * math.sqrt(0.25 / max(trials, 1))
    return (Axis("fast_p50_ms", maximize=False, eps=precision,
                 relative=True),
            Axis("race_p999_ms", maximize=False, eps=precision,
                 relative=True),
            Axis("p_recovery", maximize=False, eps=rate_eps),
            Axis("ft_fast", maximize=True),
            Axis("ft_phase1", maximize=True),
            Axis("ft_classic", maximize=True))


def _as_masks(systems: Sequence, n: Optional[int]) -> Tuple[List[QuorumMasks],
                                                            List, int]:
    """Normalize Members / systems / raw masks to one shared cluster size.
    Returns (masks, native systems, n)."""
    native, masks = [], []
    for s in systems:
        if isinstance(s, Member):
            native.append(s.system)
            masks.append(s.masks())
        elif isinstance(s, QuorumMasks):
            native.append(s)
            masks.append(s)
        else:
            native.append(s)
            masks.append(s.to_masks())
    target = max(m.n for m in masks) if n is None else n
    masks = [m if m.n == target else m.embed(target) for m in masks]
    return masks, native, target


def _fault_tolerance(system, masks: QuorumMasks) -> Dict[str, int]:
    """Crash budgets: arithmetic for cardinality specs (any n), brute
    force over the mask encoding otherwise."""
    if isinstance(system, QuorumSpec):
        return system.fault_tolerance()
    return masks.fault_tolerance()


def score_systems(systems: Sequence, *,
                  trials: int = DEFAULT_TRIALS,
                  n: Optional[int] = None,
                  k_proposers: int = 2,
                  delta_ms: float = DEFAULT_DELTA_MS,
                  delay=None,
                  chunk: int = DEFAULT_CHUNK,
                  precision: float = streaming.DEFAULT_PRECISION,
                  shard: bool = True,
                  use_kernel: bool = False,
                  k_max="auto",
                  seed: int = 0,
                  regimes=None,
                  recovery: str = "coordinated",
                  axes: Optional[Sequence[Axis]] = None) -> FrontierResult:
    """Score a family batch and return its Pareto frontier.

    ``systems`` is any mix of ``families.Member``, quorum systems, or raw
    ``QuorumMasks``; smaller systems embed into the largest cluster size
    present (or an explicit ``n``).  The whole batch streams through
    ``fast_path_stream`` and ``race_stream`` at ``trials`` trials each —
    one compile per engine path, fixed memory, trial axis sharded over
    local devices when ``shard`` — and the five default axes (or a custom
    ``axes`` tuple matching ``AXIS_NAMES``) feed ``pareto.pareto_mask``.

    ``k_max`` selects the sort-free streamed lowering (DESIGN.md §9):
    ``"auto"`` (default) derives the per-phase top-k selection depths from
    the mask table, ``None`` keeps the full-sort reference path, and an
    explicit int / 3-tuple pins the depths.  Integer outputs (decide bits,
    counts, histograms — hence every frontier axis) are bit-identical
    across all settings; only wall clock changes.

    ``regimes`` (a ``MarkovRegimes`` or its config dict) modulates both
    stream passes through Markov failure epochs; the scored axes then
    read the regime-merged totals, so the frontier prices the *mixture*
    the workload declares rather than a single i.i.d. environment.

    ``recovery`` selects the collision-recovery rule priced by the race
    pass (``engine.RECOVERY_MODES``); ``p_recovery`` is rule-invariant (the
    entry condition is), but the tail axis re-prices q2c vs q2f.
    """
    masks, native, n = _as_masks(systems, n)
    labels = tuple(m.label or f"system{i}" for i, m in enumerate(masks))
    table = engine.build_mask_table(masks)
    axes = tuple(axes) if axes is not None else default_axes(precision,
                                                             trials)

    key = jax.random.PRNGKey(seed)
    k_fast, k_race = jax.random.split(key)
    offsets = delta_ms * jnp.arange(k_proposers, dtype=jnp.float32)

    fast = streaming.fast_path_stream(k_fast, table, delay, n=n,
                                      trials=trials, chunk=chunk,
                                      precision=precision, shard=shard,
                                      k_max=k_max, regimes=regimes)
    race = streaming.race_stream(k_race, table, offsets, delay, n=n,
                                 k_proposers=k_proposers, trials=trials,
                                 chunk=chunk, precision=precision,
                                 use_kernel=use_kernel, shard=shard,
                                 k_max=k_max, regimes=regimes,
                                 recovery=recovery)

    fast_p50 = np.asarray(fast.quantile(0.5), np.float64)
    race_p999 = np.asarray(race.quantile(0.999), np.float64)
    p_rec = (np.asarray(race.n_recovery, np.float64)
             / np.maximum(np.asarray(race.n_trials, np.float64), 1.0))
    ft = [_fault_tolerance(s, m) for s, m in zip(native, masks)]
    values = np.stack([
        fast_p50,
        race_p999,
        p_rec,
        np.array([f["steady_state_fast"] for f in ft], np.float64),
        np.array([f["phase1"] for f in ft], np.float64),
        np.array([f["phase2_classic"] for f in ft], np.float64),
    ], axis=1)

    return FrontierResult(labels=labels, axes=axes, values=values,
                          mask=pareto_mask(values, axes),
                          streams={"fast": fast, "race": race})
