"""Families of FFPaxos-valid quorum systems for frontier sweeps.

The paper's §5/§6 point is that Eqs. 13/14 admit a *space* of quorum
systems; this module enumerates that space family by family, following the
constructions the Flexible/Relaxed Paxos line of work actually proposes:

  cardinality   every (q1, q2c, q2f) triple valid under Eqs. 13/14, at any
                n — the full counting space the paper's §5 examples live in
  grid          3xC grid systems (§6 closing remark) over every C with
                3C <= n, embedded into the n-acceptor cluster; fast quorums
                are row pairs, classic quorums columns
  weighted      Gifford-style weighted voting with h heavyweight acceptors
                and FFP-valid weight thresholds (the weight-space analogues
                of Eqs. 13/14), at two phase-1 aggressiveness levels

Every generator yields ``Member`` records: a label, the *native* system
(usable by the model checker and DES at its natural size), and a
``masks(n)`` lowering that relabels and embeds into the target cluster so
a whole mixed-family batch shares one ``build_mask_table`` call.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Iterator, List, Optional, Sequence

from repro.core.quorum import (ExplicitQuorumSystem, QuorumMasks, QuorumSpec,
                               WeightedQuorumSystem, all_relaxed_specs,
                               all_valid_specs, ffp_card_ok, relaxed_card_ok)


@dataclass(frozen=True)
class Member:
    """One labeled family member.

    ``system`` is the native quorum system (its own natural ``n``);
    ``masks(n)`` lowers it into the shared mask batch of an n-acceptor
    cluster, carrying ``label`` so frontier rows stay identifiable.
    """

    label: str
    system: object          # QuorumSystem protocol object

    def masks(self, n: Optional[int] = None) -> QuorumMasks:
        m = replace(self.system.to_masks(), label=self.label)
        if n is not None and n != m.n:
            m = m.embed(n)
        return m


# ---------------------------------------------------------------------------
# Cardinality: the full Eq. 13/14 space.
# ---------------------------------------------------------------------------

def cardinality_family(n: int) -> List[Member]:
    """Every FFP-valid cardinality triple for a cluster of ``n`` (Eqs.
    13/14), in deterministic (q1, q2c, q2f) order.  This is the *full*
    space — 271 systems at n=11 — not a pre-filtered frontier; dominance
    is the scorer's job."""
    out = []
    for spec in all_valid_specs(n):
        assert ffp_card_ok(n, spec.q1, spec.q2c, spec.q2f)
        out.append(Member(spec.label, spec))
    return out


# ---------------------------------------------------------------------------
# Relaxed Paxos (arXiv 2203.03058): Eq.14 alone, per-round phase-1 sizes.
# ---------------------------------------------------------------------------

def relaxed_family(n: int) -> List[Member]:
    """Every Relaxed-Paxos-valid cardinality triple that FFP Eq.13
    *rejects* — the systems the relaxation newly admits (125 at n=11), in
    deterministic (q1, q2f, q2c) order.  Triples that also satisfy Eq.13
    coincide with their FFP ``QuorumSpec`` (``q1_full == q1``) and already
    live in ``cardinality_family``, so a joint sweep over both families
    never scores the same system twice.  Members are
    ``RelaxedQuorumSpec``s: safety comes from per-round phase-1 quorums
    (``q1_full`` above classic rounds), model-checked clean at n <= 5; the
    lowered masks carry the hot-path (q1, q2c, q2f) triple the engine
    scores, so FFP + relaxed batches share one compile."""
    out = []
    for spec in all_relaxed_specs(n):
        assert relaxed_card_ok(n, spec.q1, spec.q2c, spec.q2f)
        assert not ffp_card_ok(n, spec.q1, spec.q2c, spec.q2f)
        out.append(Member(spec.label, spec))
    return out


# ---------------------------------------------------------------------------
# Grid: 3xC systems over every factorization-compatible width.
# ---------------------------------------------------------------------------

def grid_family(n: int) -> List[Member]:
    """All 3xC grid systems fitting an n-acceptor cluster (3C <= n; the
    §6 pigeonhole construction is only FFP-valid with exactly 3 rows).
    Widths where 3C < n embed — the spare acceptors join no quorum."""
    out = []
    for cols in range(1, n // 3 + 1):
        g = ExplicitQuorumSystem.grid(cols).validate()
        out.append(Member(f"grid.3x{cols}", g))
    return out


# ---------------------------------------------------------------------------
# Weighted: Gifford voting under the FFP weight inequalities.
# ---------------------------------------------------------------------------

def weighted_family(n: int, heavy_counts: Sequence[int] = (1, 2, 3),
                    heavy_weight: int = 2) -> List[Member]:
    """Weighted systems with ``h`` heavyweight acceptors (weight
    ``heavy_weight``, the rest weight 1), for each ``h`` in
    ``heavy_counts`` with h < n.  Two phase-1 levels per weighting — the
    paper-headline-shaped ceil(3W/4) and the Fast-Paxos-shaped
    ceil(2W/3)+1 — each completed with the minimal valid phase-2
    thresholds (t1 + t2c > W, t1 + 2*t2f > 2W).  Every member is
    ``validate()``d against the weight-space Eqs. 13/14."""
    out, seen = [], set()
    for h in heavy_counts:
        if not 1 <= h < n:
            continue
        weights = (heavy_weight,) * h + (1,) * (n - h)
        total = sum(weights)
        for tag, t1 in (("p34", math.ceil(3 * total / 4)),
                        ("p23", (2 * total) // 3 + 1)):
            t2c = total - t1 + 1
            t2f = (2 * total - t1) // 2 + 1
            if not (1 <= t2c <= total and 1 <= t2f <= total):
                continue
            key = (weights, t1, t2c, t2f)
            if key in seen:
                continue
            seen.add(key)
            w = WeightedQuorumSystem(weights, t1, t2c, t2f).validate()
            out.append(Member(f"weighted.{h}x{heavy_weight}.{tag}", w))
    return out


# ---------------------------------------------------------------------------
# Combined enumeration.
# ---------------------------------------------------------------------------

FAMILIES = ("cardinality", "relaxed", "grid", "weighted")


def family(name: str, n: int) -> List[Member]:
    """Enumerate one family by name."""
    if name == "cardinality":
        return cardinality_family(n)
    if name == "relaxed":
        return relaxed_family(n)
    if name == "grid":
        return grid_family(n)
    if name == "weighted":
        return weighted_family(n)
    raise ValueError(f"unknown family {name!r}; pick one of {FAMILIES}")


def all_families(n: int,
                 names: Sequence[str] = FAMILIES) -> List[Member]:
    """Every member of the named families, ready to share one mask batch
    on an n-acceptor cluster (mixed batches lower to the general masked
    engine path; all-cardinality batches keep the "q" specialization)."""
    out: List[Member] = []
    for name in names:
        out.extend(family(name, n))
    labels = [m.label for m in out]
    assert len(set(labels)) == len(labels), "family labels must be unique"
    return out
