"""Vectorized Monte-Carlo model of Fast (Flexible) Paxos commit latency.

This is the JAX-native adaptation of the paper's evaluation (DESIGN.md §2):
one fast-round instance is, analytically, an exercise in *order statistics*
over per-message network delays plus a *vote tally* — both embarrassingly
parallel across instances.  We vmap/jit over 10^5–10^6 instances so quorum-
system sweeps (the paper's §5 tradeoff space) run in milliseconds, and we
cross-validate the model against the discrete-event simulator
(``tests/test_sim_cross_validation.py``).

Latency model (mirrors ``simulator.LatencyModel``): one-way delay =
``base + LogNormal(mu, sigma)`` ms, i.i.d. per message.

Fast path (no conflict):
    client --> acceptor_a   (d1[a])
    acceptor_a --> learner  (d2[a])
    commit when q2f acceptor paths completed:
        latency = kth_smallest_a(d1[a] + d2[a], k=q2f)

Collision race (Fig. 2c): proposers A (t=0) and B (t=Δ) target one instance;
acceptor a votes for whichever proposal arrives first.  If either value
gathers q2f votes the other aborts; otherwise the coordinator enters
*coordinated recovery* (observed ~3x less often under the paper's FFP
config, since q2f drops from 9 to 7 on n=11).

The vote tally across (instances x acceptors) is the compute hot-spot and is
served by the ``kernels/quorum_tally`` Pallas kernel (with a pure-jnp oracle
in ``kernels/quorum_tally/ref.py``); set ``use_kernel=False`` to force the
reference path.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .quorum import QuorumSpec


@dataclass(frozen=True)
class LatencyParams:
    base_ms: float = 0.25
    mu: float = -1.20
    sigma: float = 0.55

    def as_tuple(self) -> Tuple[float, float, float]:
        return (self.base_ms, self.mu, self.sigma)


def _one_way(key: jax.Array, shape, p: LatencyParams) -> jax.Array:
    return p.base_ms + jnp.exp(p.mu + p.sigma * jax.random.normal(key, shape))


def kth_smallest(x: jax.Array, k: int, axis: int = -1) -> jax.Array:
    """k-th order statistic (1-indexed) along ``axis``."""
    return jnp.sort(x, axis=axis).take(k - 1, axis=axis)


# ---------------------------------------------------------------------------
# Fast path latency (Fig. 2a model).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4))
def fast_path_latency(key: jax.Array, n: int, q2f: int, samples: int,
                      lat: LatencyParams = LatencyParams()) -> jax.Array:
    """Commit latency of ``samples`` conflict-free fast-round instances."""
    k1, k2 = jax.random.split(key)
    d1 = _one_way(k1, (samples, n), lat)          # client -> acceptors
    d2 = _one_way(k2, (samples, n), lat)          # acceptors -> learner
    return kth_smallest(d1 + d2, q2f, axis=-1)


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4))
def classic_path_latency(key: jax.Array, n: int, q2c: int, samples: int,
                         lat: LatencyParams = LatencyParams()) -> jax.Array:
    """Leader-relayed classic commit (Multi-Paxos steady state): client ->
    leader -> acceptors -> leader."""
    k0, k1, k2 = jax.random.split(key, 3)
    d0 = _one_way(k0, (samples,), lat)            # client -> leader
    d1 = _one_way(k1, (samples, n), lat)          # leader -> acceptors
    d2 = _one_way(k2, (samples, n), lat)          # acceptors -> leader
    return d0 + kth_smallest(d1 + d2, q2c, axis=-1)


# ---------------------------------------------------------------------------
# Collision race (Fig. 2b / 2c model).
# ---------------------------------------------------------------------------

def _tally(votes: jax.Array, n_values: int, use_kernel: bool) -> jax.Array:
    """Count votes per value: (S, n) int32 -> (S, n_values) int32."""
    if use_kernel:
        from repro.kernels.quorum_tally import ops as qt_ops
        return qt_ops.tally_votes(votes, n_values)
    from repro.kernels.quorum_tally import ref as qt_ref
    return qt_ref.tally_votes(votes, n_values)


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4, 5, 7, 8))
def conflict_race(key: jax.Array, n: int, q1: int, q2f: int, q2c: int,
                  samples: int, delta_ms: float | jax.Array = 0.5,
                  lat: LatencyParams = LatencyParams(),
                  use_kernel: bool = False) -> Dict[str, jax.Array]:
    """Two proposals race for one instance; B starts ``delta_ms`` after A.

    Returns per-sample outcome flags and end-to-end decision latency
    (measured from A's submission, like the paper's instance latency):

      a_wins_fast / b_wins_fast : one value reached q2f (loser aborts)
      recovery                  : no value reached q2f -> coordinated recovery
      latency_ms                : commit time of the decided value
    """
    kA, kB, kr1, kr2, kr3 = jax.random.split(key, 5)
    dA = _one_way(kA, (samples, n), lat)              # A -> acceptors
    dB = _one_way(kB, (samples, n), lat)              # B -> acceptors
    tA = dA
    tB = delta_ms + dB
    votes = (tB < tA).astype(jnp.int32)               # 0: A, 1: B
    counts = _tally(votes, 2, use_kernel)             # (S, 2)
    a_cnt, b_cnt = counts[:, 0], counts[:, 1]
    a_fast = a_cnt >= q2f
    b_fast = b_cnt >= q2f
    recovery = ~(a_fast | b_fast)

    vote_time = jnp.where(votes == 0, tA, tB)         # when each acceptor voted
    d_ret = _one_way(kr1, (samples, n), lat)          # acceptor -> learner
    arrive = vote_time + d_ret                        # 2b arrival at learner

    # Fast-path commit: q2f-th smallest 2b arrival among same-value voters.
    big = jnp.float32(1e9)
    a_arr = jnp.where(votes == 0, arrive, big)
    b_arr = jnp.where(votes == 1, arrive, big)
    t_a_fast = kth_smallest(a_arr, q2f, axis=-1)
    t_b_fast = kth_smallest(b_arr, q2f, axis=-1)

    # Recovery: coordinator needs a phase-1 quorum (q1) of round-1 votes to
    # run IsPickableVal, then one classic round trip committing with q2c.
    t_detect = kth_smallest(arrive, q1, axis=-1)
    d_2a = _one_way(kr2, (samples, n), lat)
    d_2b = _one_way(kr3, (samples, n), lat)
    t_recover = t_detect + kth_smallest(d_2a + d_2b, q2c, axis=-1)

    latency = jnp.where(a_fast, t_a_fast,
               jnp.where(b_fast, t_b_fast, t_recover))
    return {
        "a_wins_fast": a_fast,
        "b_wins_fast": b_fast,
        "recovery": recovery,
        "latency_ms": latency,
    }


def conflict_probability(key: jax.Array, spec: QuorumSpec, delta_ms: float,
                         samples: int = 100_000,
                         lat: LatencyParams = LatencyParams(),
                         use_kernel: bool = False) -> float:
    """P(coordinated recovery) for a given inter-command interval (Fig. 2c)."""
    out = conflict_race(key, spec.n, spec.q1, spec.q2f, spec.q2c,
                        samples, delta_ms, lat, use_kernel)
    return float(out["recovery"].mean())


def latency_summary(lat_ms: jax.Array) -> Dict[str, float]:
    q = jnp.quantile(lat_ms, jnp.array([0.5, 0.95, 0.99]))
    return {
        "mean_ms": float(lat_ms.mean()),
        "p50_ms": float(q[0]),
        "p95_ms": float(q[1]),
        "p99_ms": float(q[2]),
    }


# ---------------------------------------------------------------------------
# Mixed workload (Fig. 2b model): fraction p of commands race, rest are clean.
# ---------------------------------------------------------------------------

def mixed_workload_latency(key: jax.Array, spec: QuorumSpec,
                           conflict_frac: float, delta_ms: float,
                           samples: int = 100_000,
                           lat: LatencyParams = LatencyParams(),
                           use_kernel: bool = False) -> Dict[str, float]:
    k1, k2, k3 = jax.random.split(key, 3)
    n_conf = max(1, int(samples * conflict_frac))
    n_free = samples - n_conf
    free = fast_path_latency(k1, spec.n, spec.q2f, n_free, lat)
    race = conflict_race(k2, spec.n, spec.q1, spec.q2f, spec.q2c,
                         n_conf, delta_ms, lat, use_kernel)
    all_lat = jnp.concatenate([free, race["latency_ms"]])
    out = latency_summary(all_lat)
    out["recovery_rate"] = float(race["recovery"].mean()) * conflict_frac
    return out
