"""Compatibility shim over ``repro.montecarlo`` (the batched scenario engine).

This module used to *be* the vectorized Monte-Carlo model of Fast (Flexible)
Paxos commit latency; the implementation now lives in
``repro.montecarlo.engine`` (DESIGN.md §2), which generalizes it to K
proposers, pluggable delay models, and whole quorum-spec tables evaluated
under one compile.  The public API here is preserved exactly — one spec at a
time, the original signatures — so existing callers and the cross-validation
suite (``tests/test_sim_cross_validation.py``) keep working:

  LatencyParams            shifted-lognormal delay parameters
  kth_smallest             k-th order statistic helper
  fast_path_latency        Fig. 2a conflict-free fast path
  classic_path_latency     leader-relayed classic commit
  conflict_race            two proposals race for one instance (Fig. 2b/2c)
  conflict_probability     P(coordinated recovery) at a given Δ
  mixed_workload_latency   Fig. 2b blend of clean and racing commands
  latency_summary          quantile summary of a latency sample

New code should target ``repro.montecarlo`` (or the declarative
``repro.api.Experiment``) directly: the shim pays one engine call per spec,
while the engine scores an entire quorum-system table in a single call.
Importing this module emits a ``DeprecationWarning``.
"""
from __future__ import annotations

import warnings
from typing import Dict

import jax
import jax.numpy as jnp

from repro.montecarlo import engine, scenarios
from repro.montecarlo.latency import ShiftedLognormalDelay

from .quorum import QuorumSpec

warnings.warn(
    "repro.core.jax_sim is a deprecated one-spec-at-a-time shim; build a "
    "table with repro.montecarlo.build_mask_table (or use "
    "repro.api.Experiment) to score whole quorum-system batches per call",
    DeprecationWarning, stacklevel=2)

# The old LatencyParams dataclass is the lognormal delay model: same fields
# (base_ms, mu, sigma), same as_tuple(); now also a pytree the engine traces.
LatencyParams = ShiftedLognormalDelay

_DEFAULT = LatencyParams()


def kth_smallest(x: jax.Array, k: int, axis: int = -1) -> jax.Array:
    """k-th order statistic (1-indexed) along ``axis``."""
    return jnp.sort(x, axis=axis).take(k - 1, axis=axis)


def fast_path_latency(key: jax.Array, n: int, q2f: int, samples: int,
                      lat: LatencyParams = _DEFAULT) -> jax.Array:
    """Commit latency of ``samples`` conflict-free fast-round instances."""
    table = engine.cardinality_table(jnp.array([[n, n, q2f]], jnp.int32), n)
    return engine.fast_path(key, table, lat, n=n, samples=samples)[0]


def classic_path_latency(key: jax.Array, n: int, q2c: int, samples: int,
                         lat: LatencyParams = _DEFAULT) -> jax.Array:
    """Leader-relayed classic commit (Multi-Paxos steady state): client ->
    leader -> acceptors -> leader."""
    table = engine.cardinality_table(jnp.array([[n, q2c, n]], jnp.int32), n)
    return engine.classic_path(key, table, lat, n=n, samples=samples)[0]


def conflict_race(key: jax.Array, n: int, q1: int, q2f: int, q2c: int,
                  samples: int, delta_ms: float | jax.Array = 0.5,
                  lat: LatencyParams = _DEFAULT,
                  use_kernel: bool = False) -> Dict[str, jax.Array]:
    """Two proposals race for one instance; B starts ``delta_ms`` after A.

    Returns per-sample outcome flags and end-to-end decision latency
    (measured from A's submission, like the paper's instance latency):

      a_wins_fast / b_wins_fast : one value reached q2f (loser aborts)
      recovery                  : no value reached q2f -> coordinated recovery
      latency_ms                : commit time of the decided value
    """
    table = engine.cardinality_table(jnp.array([[q1, q2c, q2f]], jnp.int32),
                                     n)
    offsets = jnp.stack([jnp.float32(0.0), jnp.asarray(delta_ms, jnp.float32)])
    out = engine.race(key, table, offsets, lat, n=n, k_proposers=2,
                      samples=samples, use_kernel=use_kernel)
    winner, reached = out["fast_winner"][0], out["reached_fast"][0]
    return {
        "a_wins_fast": reached & (winner == 0),
        "b_wins_fast": reached & (winner == 1),
        "recovery": out["recovery"][0] | out["undecided"][0],
        "latency_ms": out["latency_ms"][0],
    }


def conflict_probability(key: jax.Array, spec: QuorumSpec, delta_ms: float,
                         samples: int = 100_000,
                         lat: LatencyParams = _DEFAULT,
                         use_kernel: bool = False) -> float:
    """P(coordinated recovery) for a given inter-command interval (Fig. 2c)."""
    out = conflict_race(key, spec.n, spec.q1, spec.q2f, spec.q2c,
                        samples, delta_ms, lat, use_kernel)
    return float(out["recovery"].mean())


def latency_summary(lat_ms: jax.Array) -> Dict[str, float]:
    s = engine.summarize(lat_ms)
    return {k: float(v) for k, v in s.items()}


def mixed_workload_latency(key: jax.Array, spec: QuorumSpec,
                           conflict_frac: float, delta_ms: float,
                           samples: int = 100_000,
                           lat: LatencyParams = _DEFAULT,
                           use_kernel: bool = False) -> Dict[str, float]:
    scen = scenarios.mixed_workload(conflict_frac, delta_ms, k=2, n=spec.n,
                                    delay=lat)
    table = engine.build_mask_table([spec])
    s = scen.summary(key, table, samples, use_kernel)
    out = {k: float(v[0]) for k, v in s.items() if k != "undecided_rate"}
    return out
