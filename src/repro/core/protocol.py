"""Fast Flexible Paxos protocol logic, faithful to the paper's Appendix A.

The module is deliberately split into two layers:

* **pure logic** — ``RoundSystem`` (round → fast/classic, coordinator-of-round,
  per-round quorum predicates) and ``pick_values`` (the TLA+ ``IsPickableVal``
  rule, including the O4 condition evaluated against *phase-2* quorums — the
  paper's modification of Fast Paxos' Figure 2 rule).  These functions are
  shared verbatim by the discrete-event simulator, the TLC-lite model checker
  and the cluster control plane, so one implementation is validated three ways.

* **node classes** — ``Acceptor``, ``Coordinator``, ``Learner`` consume and
  emit ``Message`` values; transport (delays, loss, duplication) is supplied
  by the caller (see ``simulator.py``).

Classic Paxos and Fast Paxos are *configurations* of the same code: Fast Paxos
is FFP with ``q1 = q2c = qc`` and ``q2f = qf`` (the paper's §2.3 framing), and
Paxos is the degenerate no-fast-round case.  The baselines the paper compares
against therefore share every code path except quorum sizes.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field, replace
from typing import Dict, FrozenSet, Hashable, Iterable, List, Optional, Sequence, Set, Tuple

from .quorum import QuorumSpec

Value = Hashable

# Sentinels (the TLA+ spec's ``any`` and ``none``).
ANY = "__ANY__"
NONE = "__NONE__"


# ---------------------------------------------------------------------------
# Messages (the TLA+ ``Message`` set).
# ---------------------------------------------------------------------------

@dataclass(frozen=True, order=True)
class Phase1a:
    rnd: int


@dataclass(frozen=True, order=True)
class Phase1b:
    rnd: int
    vrnd: int
    vval: Value
    acc: int


@dataclass(frozen=True, order=True)
class Phase2a:
    rnd: int
    val: Value          # may be ANY in fast rounds


@dataclass(frozen=True, order=True)
class Phase2b:
    rnd: int
    val: Value
    acc: int


@dataclass(frozen=True, order=True)
class Proposal:
    """A client value sent directly to acceptors (fast-round path)."""
    val: Value


Message = object


# ---------------------------------------------------------------------------
# Round system: fast/classic rounds, coordinators, quorums.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RoundSystem:
    """Assigns round numbers to coordinators and fast/classic kinds.

    Round 0 is "no round".  By default odd rounds starting at 1 are *fast*
    (steady state) and even rounds are *classic* (recovery), matching the
    deployment style of §6: the system sits in a fast round; collisions are
    resolved by the coordinator moving to the next (classic) round.

    ``spec`` may be any ``QuorumSystem`` — a cardinality ``QuorumSpec``, an
    ``ExplicitQuorumSystem`` (grids, hand-built sets, ...), or anything else
    exposing ``to_explicit()`` (e.g. ``WeightedQuorumSystem``), which is
    lowered to its enumerated explicit form on construction.  Everything
    downstream — ``pick_values``, the learner, the model checker, the
    discrete-event simulator — speaks only the set-level predicates
    ``contains_q1``/``contains_q2``/``q1_subsets``, which degrade to the
    original cardinality comparisons when ``spec`` is a ``QuorumSpec``.
    """

    spec: object                  # QuorumSpec | ExplicitQuorumSystem
    n_coordinators: int = 1
    fast_rounds: str = "odd"      # "odd" | "all" | "none"

    def __post_init__(self) -> None:
        # Lower anything that is neither cardinality nor already explicit
        # (weighted voting, future families) through the QuorumSystem
        # protocol; QuorumSpec keeps its O(1) counting predicates.
        spec = self.spec
        if not isinstance(spec, QuorumSpec) and not hasattr(spec, "p1"):
            if not hasattr(spec, "to_explicit"):
                raise TypeError(
                    f"RoundSystem needs a QuorumSpec, an explicit system, or "
                    f"a QuorumSystem with to_explicit(); got {type(spec)!r}")
            object.__setattr__(self, "spec", spec.to_explicit())

    def is_fast(self, rnd: int) -> bool:
        if rnd <= 0:
            return False
        if self.fast_rounds == "all":
            return True
        if self.fast_rounds == "none":
            return False
        return rnd % 2 == 1

    def coord_of(self, rnd: int) -> int:
        return rnd % self.n_coordinators

    @property
    def cardinality(self) -> bool:
        return isinstance(self.spec, QuorumSpec)

    def _q1_size(self, rnd: int) -> int:
        """Phase-1 quorum size of round ``rnd`` (cardinality systems).

        Plain FFP specs use one q1 for every round (§5).  Relaxed Paxos
        specs (``RelaxedQuorumSpec``) expose ``q1_for``: rounds whose
        history contains a classic round need the Eq.13-restoring
        ``q1_full``; rounds above nothing but fast rounds (the steady-state
        hot path and its first recovery) keep the relaxed ``q1``.
        """
        spec = self.spec
        if hasattr(spec, "q1_for"):
            return spec.q1_for(any(not self.is_fast(j)
                                   for j in range(1, rnd)))
        return spec.q1

    # -- quorum sizes (cardinality systems only) ----------------------------
    def q1(self, rnd: int) -> int:          # phase-1 (fast or classic: §5)
        if not self.cardinality:
            raise TypeError("q1() is a cardinality-system accessor; use "
                            "contains_q1()/q1_subsets() for explicit systems")
        return self._q1_size(rnd)

    def q2(self, rnd: int) -> int:          # phase-2 depends on round kind
        if not self.cardinality:
            raise TypeError("q2() is a cardinality-system accessor; use "
                            "contains_q2() for explicit systems")
        return self.spec.q2f if self.is_fast(rnd) else self.spec.q2c

    # -- quorum predicates over acceptor-id sets ----------------------------
    def contains_q1(self, acceptors: Iterable[int], rnd: int) -> bool:
        """Does the set contain (a superset of) some phase-1 quorum?"""
        s = set(acceptors)
        if self.cardinality:
            return len(s) >= self._q1_size(rnd)
        return any(q <= s for q in self.spec.p1)

    def contains_q2(self, acceptors: Iterable[int], rnd: int) -> bool:
        """Does the set contain some phase-2 quorum of round ``rnd``?"""
        s = set(acceptors)
        if self.cardinality:
            return len(s) >= self.q2(rnd)
        qs = self.spec.p2f if self.is_fast(rnd) else self.spec.p2c
        return any(q <= s for q in qs)

    def q1_subsets(self, available: Iterable[int],
                   rnd: int) -> Iterable[Tuple[int, ...]]:
        """Every phase-1 quorum drawn from ``available`` (sorted tuples).
        For cardinality systems these are the size-q1 combinations; for
        explicit systems, the enumerated quorums contained in the set."""
        avail = sorted(set(available))
        if self.cardinality:
            yield from itertools.combinations(avail, self._q1_size(rnd))
            return
        s = set(avail)
        for q in self.spec.p1:
            if q <= s:
                yield tuple(sorted(q))

    # Backwards-compatible aliases (the original >=-threshold predicates).
    def is_q1(self, acceptors: Iterable[int], rnd: int) -> bool:
        return self.contains_q1(acceptors, rnd)

    def is_q2(self, acceptors: Iterable[int], rnd: int) -> bool:
        return self.contains_q2(acceptors, rnd)


# ---------------------------------------------------------------------------
# IsPickableVal — the coordinator's phase-2 value-picking rule.
# ---------------------------------------------------------------------------

def pick_values(rs: RoundSystem,
                i: int,
                msgs: Sequence[Phase1b],
                proposed: Set[Value]) -> Set[Value]:
    """Return every value v for which TLA+ ``IsPickableVal(Q, i, M, v)`` holds.

    ``msgs`` are the round-i phase-1b messages from a phase-1 quorum Q (one
    per acceptor).  The O4 condition is evaluated against *phase-2* quorums of
    round k (the paper's modification): O4(w) asks whether some phase-2
    round-k quorum R could have decided w given what Q reported, i.e. whether
    the acceptors *outside* Q together with the members of Q that voted (k, w)
    can still form a round-k phase-2 quorum.
    """
    assert msgs, "phase-1 quorum must be non-empty"
    by_acc = {m.acc: m for m in msgs}
    assert len(by_acc) == len(msgs), "one phase-1b message per acceptor"
    Q = set(by_acc)

    k = max(m.vrnd for m in msgs)
    if k == 0:
        # Nothing voted below round i: any proposed value, or ANY in fast rounds.
        picks: Set[Value] = set(proposed)
        if rs.is_fast(i):
            picks.add(ANY)
        return picks

    V = {m.vval for m in msgs if m.vrnd == k}
    if len(V) == 1:
        return set(V)

    # Multiple values seen at round k (k must be fast): O4 elimination.
    # O4(w) asks whether some round-k phase-2 quorum could have decided w
    # given what Q reported: the acceptors outside Q (whose round-k votes Q
    # cannot see) plus the members of Q that voted (k, w) must still contain
    # a round-k phase-2 quorum.  For cardinality systems this reduces to the
    # original ``outside + in_q_voted_w >= q2(k)`` arithmetic.
    outside = set(range(rs.spec.n)) - Q

    def o4(w: Value) -> bool:
        voted_w = {m.acc for m in msgs if m.vrnd == k and m.vval == w}
        return rs.contains_q2(outside | voted_w, k)

    winners = {w for w in V if o4(w)}
    if winners:
        # TLA+: v = CHOOSE w ∈ V : O4(w).  Eq.12 guarantees at most one value
        # can actually be decided, but more than one may *pass* O4 when no
        # value was decided; any single deterministic choice is safe.  We
        # return the full O4-passing set and let callers choose
        # deterministically (min) — the model checker explores each.
        return winners
    return set(proposed)


def _canonical_key(v: Value) -> Tuple:
    """Total order over heterogeneous values for deterministic CHOOSE.

    Numbers compare numerically (``repr`` ordered them lexicographically:
    ``repr(10) < repr(2)``), strings lexicographically, everything else by
    type name then ``repr``.  The leading rank tag keeps the tuple
    comparison from ever comparing across types.
    """
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        if isinstance(v, str):
            return (1, v)
        return (2, type(v).__name__, repr(v))
    return (0, v)


def choose_value(picks: Set[Value],
                 counts: Optional[Dict[Value, int]] = None) -> Value:
    """Deterministic CHOOSE over a pick set (prefer concrete over ANY).

    ``counts`` (round-k vote tallies) biases the free choice towards the
    plurality value.  This only matters when *no* value passed O4 — for any
    valid phase-1 quorum at most one value can pass O4 (Eq. 12), so when it
    does the pick set is a singleton and the preference is inert.  Preferring
    the plurality value is the liveness-optimal recovery heuristic: it is the
    value closest to a phase-2 quorum in the collision round.

    Ties sort by ``(-count, canonical key)`` in one pass — numeric values
    order numerically, so the choice is stable across value types.
    """
    concrete = [v for v in picks if v != ANY]
    if concrete:
        tally = counts or {}
        return min(concrete,
                   key=lambda v: (-tally.get(v, 0), _canonical_key(v)))
    return ANY


# ---------------------------------------------------------------------------
# Node state machines.
# ---------------------------------------------------------------------------

@dataclass
class Acceptor:
    """TLA+ acceptor: variables rnd, vrnd, vval."""

    aid: int
    rs: RoundSystem
    rnd: int = 0
    vrnd: int = 0
    vval: Value = ANY

    def on_phase1a(self, m: Phase1a) -> Optional[Phase1b]:
        if self.rnd < m.rnd:
            self.rnd = m.rnd
            return Phase1b(m.rnd, self.vrnd, self.vval, self.aid)
        return None

    def on_phase2a(self, m: Phase2a, proposed_val: Optional[Value] = None) -> Optional[Phase2b]:
        """Vote in round m.rnd.  If m.val is ANY, ``proposed_val`` is the
        client value this acceptor received first (fast path)."""
        if self.rnd > m.rnd or self.vrnd >= m.rnd:
            return None
        v = m.val
        if v == ANY:
            if proposed_val is None:
                return None
            v = proposed_val
        self.rnd = m.rnd
        self.vrnd = m.rnd
        self.vval = v
        return Phase2b(m.rnd, v, self.aid)

    def last_msg(self) -> Message:
        """TLA+ accLastMsg — for retransmission."""
        if self.vrnd < self.rnd:
            return Phase1b(self.rnd, self.vrnd, self.vval, self.aid)
        return Phase2b(self.rnd, self.vval, self.aid)

    def uncoordinated_recovery(self, i: int, p1b_msgs: Sequence[Phase1b],
                               proposed: Set[Value]) -> Optional[Phase2b]:
        """Recover from a round-i collision by voting directly in round i+1
        (must be fast).  ``p1b_msgs`` is P2bToP1b(Q, i) for a phase-1 quorum Q
        of round i+1.

        The guard mirrors the TLA+ Phase2b enabling condition for a round
        i+1 vote — ``rnd <= i+1 /\\ vrnd < i+1`` — so an acceptor that
        already *promised* round i+1 (rnd == i+1 from a Phase1a) can still
        vote in it; only a vote in i+1 or a promise beyond it disables the
        action.  (The old ``self.rnd > i`` rejection was strictly tighter
        than the spec: it silently excluded promised-but-unvoted acceptors,
        shrinking the recovery quorum for no safety gain.)
        """
        if not self.rs.is_fast(i + 1) or self.rnd > i + 1 \
                or self.vrnd >= i + 1:
            return None
        if not self.rs.is_q1({m.acc for m in p1b_msgs}, i + 1):
            return None
        picks = pick_values(self.rs, i + 1, list(p1b_msgs), proposed)
        counts: Dict[Value, int] = {}
        for m in p1b_msgs:
            if m.vrnd == i:
                counts[m.vval] = counts.get(m.vval, 0) + 1
        v = choose_value(picks - {ANY}, counts)
        if v == ANY:
            return None
        self.rnd = i + 1
        self.vrnd = i + 1
        self.vval = v
        return Phase2b(i + 1, v, self.aid)


def p2b_to_p1b(msgs: Iterable[Phase2b], i: int) -> List[Phase1b]:
    """TLA+ P2bToP1b: reinterpret round-i phase-2b votes as round-i+1
    phase-1b messages (collision recovery without an explicit phase 1)."""
    return [Phase1b(i + 1, i, m.val, m.acc) for m in msgs if m.rnd == i]


@dataclass
class Coordinator:
    """TLA+ coordinator: variables crnd, cval; drives phase 1 and phase 2."""

    cid: int
    rs: RoundSystem
    crnd: int = 0
    cval: Value = NONE
    am_leader: bool = True
    p1b: Dict[int, Dict[int, Phase1b]] = field(default_factory=dict)   # rnd -> acc -> msg
    p2b: Dict[int, Dict[int, Phase2b]] = field(default_factory=dict)   # rnd -> acc -> msg

    # -- phase 1 -----------------------------------------------------------
    def start_round(self, i: int) -> Optional[Phase1a]:
        """Phase1a(c, i)."""
        if not self.am_leader or self.rs.coord_of(i) != self.cid or self.crnd >= i:
            return None
        self.crnd = i
        self.cval = NONE
        return Phase1a(i)

    def on_phase1b(self, m: Phase1b) -> None:
        self.p1b.setdefault(m.rnd, {})[m.acc] = m

    def try_phase2a(self, proposed: Set[Value]) -> Optional[Phase2a]:
        """Phase2a(c, v): once a phase-1 quorum reported, pick and send v."""
        i = self.crnd
        if i == 0 or self.cval != NONE or not self.am_leader:
            return None
        msgs = list(self.p1b.get(i, {}).values())
        if not self.rs.is_q1({m.acc for m in msgs}, i):
            return None
        picks = pick_values(self.rs, i, msgs, proposed)
        if not picks:
            return None
        v = choose_value(picks)
        if v == ANY and not self.rs.is_fast(i):
            v = choose_value(picks - {ANY})
            if v == ANY:
                return None
        self.cval = v
        return Phase2a(i, v)

    # -- collision recovery --------------------------------------------------
    def on_phase2b(self, m: Phase2b) -> None:
        self.p2b.setdefault(m.rnd, {})[m.acc] = m

    def coordinated_recovery(self, proposed: Set[Value]) -> Optional[Phase2a]:
        """CoordinatedRecovery(c, v): observe a round-i collision through
        phase-2b messages and jump straight to phase 2 of round i+1."""
        i = self.crnd
        if not self.am_leader or self.cval != ANY or self.rs.coord_of(i + 1) != self.cid:
            return None
        msgs = p2b_to_p1b(self.p2b.get(i, {}).values(), i)
        if not self.rs.is_q1({m.acc for m in msgs}, i + 1):
            return None
        picks = pick_values(self.rs, i + 1, msgs, proposed) - {ANY}
        if not picks:
            return None
        counts: Dict[Value, int] = {}
        for m in msgs:
            if m.vrnd == i:
                counts[m.vval] = counts.get(m.vval, 0) + 1
        v = choose_value(picks, counts)
        self.cval = v
        self.crnd = i + 1
        return Phase2a(i + 1, v)

    def last_msg(self) -> Optional[Message]:
        """TLA+ coordLastMsg."""
        if self.crnd == 0:
            return None
        if self.cval == NONE:
            return Phase1a(self.crnd)
        return Phase2a(self.crnd, self.cval)


@dataclass
class Learner:
    """Watches phase-2b votes; learns v once a phase-2 quorum voted (i, v)."""

    rs: RoundSystem
    votes: Dict[int, Dict[int, Value]] = field(default_factory=dict)  # rnd -> acc -> val
    learned: Set[Value] = field(default_factory=set)

    def on_phase2b(self, m: Phase2b) -> Optional[Value]:
        self.votes.setdefault(m.rnd, {})[m.acc] = m.val
        by_val: Dict[Value, Set[int]] = {}
        for acc, val in self.votes[m.rnd].items():
            by_val.setdefault(val, set()).add(acc)
        for val, accs in by_val.items():
            if self.rs.contains_q2(accs, m.rnd):
                self.learned.add(val)
                return val
        return None

    def collision_suspected(self, rnd: int) -> bool:
        """True when round-rnd votes can no longer reach any single-value
        phase-2 quorum: for every value, even if all outstanding acceptors
        voted for it, its voters would not contain a quorum."""
        votes = self.votes.get(rnd, {})
        if not votes:
            return False
        by_val: Dict[Value, Set[int]] = {}
        for acc, val in votes.items():
            by_val.setdefault(val, set()).add(acc)
        if len(by_val) <= 1:
            return False
        outstanding = set(range(self.rs.spec.n)) - set(votes)
        return not any(self.rs.contains_q2(accs | outstanding, rnd)
                       for accs in by_val.values())
