"""Discrete-event simulator of a Fast (Flexible) Paxos deployment.

Reproduces the paper's §6 evaluation environment in simulation: the paper ran
Paxi on 11 AWS EC2 m5a.large VMs in one region; we are CPU-only on one host,
so the *network* is simulated — per-message one-way delays drawn from a
shifted-lognormal distribution fit to same-region EC2 RTTs (~0.5 ms median
one-way, heavy right tail).  Both algorithms under comparison run over
identical sampled delays (common random numbers), so latency *ratios* — the
paper's claim — are preserved by construction.

The simulated deployment matches §6's steady state:

* a stable coordinator has pre-executed phase-1 for every instance (the
  Multi-Paxos-style ``any`` message is already at the acceptors), so clients
  send proposals *directly* to acceptors (the fast path);
* each acceptor votes for the first proposal it receives per instance and
  sends phase-2b to the coordinator (the learner);
* the coordinator learns a value once a fast phase-2 quorum (q2f) votes for
  it; on a collision (no value can reach q2f) it runs *coordinated recovery*:
  picks a value per ``IsPickableVal`` from the round-i votes reinterpreted as
  round-i+1 phase-1b messages, and commits it in a classic round with q2c.

``recovery="uncoordinated"`` swaps the collision path for the leaderless
rule (arXiv 1710.08047): acceptors broadcast their round-1 votes to each
other, and each acceptor that can locally prove the fast round dead over a
phase-1 quorum of observed votes runs ``Acceptor.uncoordinated_recovery``
— voting directly in (fast) round 2 — so the learner commits once q2f
round-2 votes agree, skipping the coordinator round trip.

Node and protocol behaviour comes from ``repro.core.protocol`` — the same
state machines validated by the model checker.
"""
from __future__ import annotations

import heapq
import itertools
import math
import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from .protocol import (ANY, Acceptor, Coordinator, Learner, Phase1b, Phase2a,
                       Phase2b, RoundSystem, choose_value, p2b_to_p1b,
                       pick_values)
from .quorum import ExplicitQuorumSystem, QuorumSpec


# ---------------------------------------------------------------------------
# Network model.
# ---------------------------------------------------------------------------

@dataclass
class LatencyModel:
    """Shifted-lognormal one-way delay (EC2 same-region m5a profile).

    one_way = base + LogNormal(mu, sigma)   [milliseconds]

    Defaults give ~0.25 ms floor, ~0.55 ms median, ~1 ms p95 one-way —
    consistent with the ~1.5-2 ms fast-path commit latencies in Fig. 2a.
    """

    base_ms: float = 0.25
    mu: float = -1.20       # ln(0.30)
    sigma: float = 0.55
    loss_prob: float = 0.0

    def sample(self, rng: random.Random) -> Optional[float]:
        if self.loss_prob and rng.random() < self.loss_prob:
            return None
        return self.base_ms + rng.lognormvariate(self.mu, self.sigma)


# ---------------------------------------------------------------------------
# Event loop.
# ---------------------------------------------------------------------------

@dataclass(order=True)
class _Event:
    time: float
    seq: int
    fn: Callable = field(compare=False)


class EventLoop:
    def __init__(self) -> None:
        self._q: List[_Event] = []
        self._seq = itertools.count()
        self.now = 0.0

    def at(self, t: float, fn: Callable) -> None:
        heapq.heappush(self._q, _Event(t, next(self._seq), fn))

    def after(self, dt: float, fn: Callable) -> None:
        self.at(self.now + dt, fn)

    def run(self, until: float = math.inf) -> None:
        while self._q and self._q[0].time <= until:
            ev = heapq.heappop(self._q)
            self.now = ev.time
            ev.fn()


# ---------------------------------------------------------------------------
# Per-instance consensus record at the coordinator.
# ---------------------------------------------------------------------------

@dataclass
class InstanceState:
    learner: Learner
    votes_r1: Dict[int, object] = field(default_factory=dict)   # acc -> val
    decided: Optional[object] = None
    decide_time: Optional[float] = None
    recovered: bool = False
    recovery_sent: bool = False
    r2_votes: Dict[int, object] = field(default_factory=dict)


@dataclass
class InstanceResult:
    instance: int
    value: object
    proposer: int
    submit_time: float
    decide_time: Optional[float]
    outcome: str           # "fast" | "recovered" | "aborted" | "lost"

    @property
    def latency_ms(self) -> Optional[float]:
        if self.decide_time is None:
            return None
        return self.decide_time - self.submit_time


RECOVERY_MODES = ("coordinated", "uncoordinated")


class FastPaxosSim:
    """One simulated cluster running either Fast Paxos or Fast Flexible Paxos
    (the difference is purely the quorum system).  ``spec`` may be any
    ``QuorumSystem`` — a cardinality ``QuorumSpec``, an
    ``ExplicitQuorumSystem`` (grid, hand-built, ...), or a system lowered
    through ``to_explicit()`` (e.g. ``WeightedQuorumSystem``): all quorum
    checks route through the set-level ``RoundSystem`` predicates.

    ``recovery`` selects the collision rule: ``"coordinated"`` (default)
    routes recovery through the coordinator's classic round 2 (q2c),
    ``"uncoordinated"`` lets acceptors vote directly in a fast round 2
    (q2f) from their own peer-broadcast view of round 1."""

    def __init__(self, spec: "QuorumSpec | ExplicitQuorumSystem",
                 latency: LatencyModel | None = None,
                 seed: int = 0, crashed: Sequence[int] = (),
                 recovery: str = "coordinated") -> None:
        if recovery not in RECOVERY_MODES:
            raise ValueError(f"unknown recovery rule {recovery!r}; "
                             f"pick one of {RECOVERY_MODES}")
        self.recovery = recovery
        self.spec = spec.validate()
        # Uncoordinated recovery votes happen *in* round 2, so round 2 must
        # be fast there; the coordinated path keeps the classic round 2.
        fast_rounds = "all" if recovery == "uncoordinated" else "odd"
        self.rs = RoundSystem(spec, n_coordinators=1, fast_rounds=fast_rounds)
        self.lat = latency or LatencyModel()
        self.rng = random.Random(seed)
        self.loop = EventLoop()
        self.n = self.rs.spec.n
        self.crashed: Set[int] = set(crashed)
        # Per-instance acceptor vote registries (steady-state fast round 1:
        # phase-1 already ran; acceptors accept the first proposal per slot).
        self.acc_vote: List[Dict[int, object]] = [dict() for _ in range(self.n)]
        self.instances: Dict[int, InstanceState] = {}
        self.results: Dict[Tuple[int, object], InstanceResult] = {}
        self.recovery_entries = 0
        self.fast_decides = 0
        # Uncoordinated-mode state: per-acceptor view of peer round-1 votes,
        # per-acceptor set of instances already recovered in round 2, and the
        # set of instances counted in ``recovery_entries``.
        self.peer_seen: List[Dict[int, Dict[int, object]]] = \
            [dict() for _ in range(self.n)]
        self.uncoord_voted: List[Set[int]] = [set() for _ in range(self.n)]
        self._rec_instances: Set[int] = set()

    # -- client API ----------------------------------------------------------
    def submit(self, t: float, instance: int, value: object, proposer: int = 0) -> None:
        """Client submits ``value`` for ``instance`` at time t (fast path:
        straight to every acceptor)."""
        self.results[(instance, value)] = InstanceResult(
            instance, value, proposer, t, None, "lost")
        self.loop.at(t, lambda: self._broadcast_proposal(instance, value))

    def _broadcast_proposal(self, instance: int, value: object) -> None:
        for a in range(self.n):
            if a in self.crashed:
                continue
            d = self.lat.sample(self.rng)
            if d is None:
                continue
            self.loop.after(d, lambda a=a: self._acceptor_recv(a, instance, value))

    # -- acceptor fast-path vote ----------------------------------------------
    def _acceptor_recv(self, a: int, instance: int, value: object) -> None:
        votes = self.acc_vote[a]
        if instance in votes:           # already voted in round 1 of this slot
            return
        if instance in self.uncoord_voted[a]:
            return                      # already voted round 2 (vrnd = 2 > 1)
        votes[instance] = value
        d = self.lat.sample(self.rng)
        if d is not None:
            self.loop.after(d, lambda: self._coord_recv_2b(instance, 1, a, value))
        if self.recovery == "uncoordinated":
            # 2b goes to the peer acceptors too (one-way each); the voter
            # observes its own vote immediately.
            self._acceptor_recv_peer_2b(a, instance, a, value)
            for b in range(self.n):
                if b == a or b in self.crashed:
                    continue
                d = self.lat.sample(self.rng)
                if d is None:
                    continue
                self.loop.after(d, lambda b=b: self._acceptor_recv_peer_2b(
                    b, instance, a, value))

    # -- uncoordinated recovery (acceptor side) -------------------------------
    def _acceptor_recv_peer_2b(self, b: int, instance: int, a: int,
                               value: object) -> None:
        seen = self.peer_seen[b].setdefault(instance, {})
        if a in seen:
            return
        seen[a] = value
        self._maybe_uncoord_recover(b, instance)

    def _fast_round_dead(self, seen: Dict[int, object]) -> bool:
        """Local collision proof: no value can reach a fast round-1 quorum
        even if every acceptor this view is missing voted for it (the same
        predicate as ``Learner.collision_suspected``, over a peer view)."""
        by_val: Dict[object, Set[int]] = {}
        for acc, val in seen.items():
            by_val.setdefault(val, set()).add(acc)
        if len(by_val) <= 1:
            return False
        outstanding = set(range(self.n)) - set(seen)
        return not any(self.rs.contains_q2(accs | outstanding, 1)
                       for accs in by_val.values())

    def _maybe_uncoord_recover(self, b: int, instance: int) -> None:
        """UncoordRecovery(b): once acceptor b's peer view holds a round-2
        phase-1 quorum of round-1 votes and proves the fast round dead, b
        picks per ``IsPickableVal`` and votes directly in (fast) round 2."""
        if instance in self.uncoord_voted[b]:
            return
        seen = self.peer_seen[b][instance]
        if not self.rs.contains_q1(seen, 2) or not self._fast_round_dead(seen):
            return
        acc = Acceptor(b, self.rs, rnd=1, vrnd=1, vval=self.acc_vote[b][instance]) \
            if instance in self.acc_vote[b] else Acceptor(b, self.rs)
        msgs = [Phase1b(2, 1, v, a) for a, v in seen.items()]
        m2b = acc.uncoordinated_recovery(1, msgs, set(seen.values()))
        if m2b is None:
            return
        self.uncoord_voted[b].add(instance)
        if instance not in self._rec_instances:
            self._rec_instances.add(instance)
            self.recovery_entries += 1
        d = self.lat.sample(self.rng)
        if d is None:
            return
        self.loop.after(d, lambda: self._coord_recv_2b(instance, 2, b, m2b.val))

    # -- coordinator / learner --------------------------------------------------
    def _inst(self, instance: int) -> InstanceState:
        if instance not in self.instances:
            self.instances[instance] = InstanceState(Learner(self.rs))
        return self.instances[instance]

    def _coord_recv_2b(self, instance: int, rnd: int, a: int, value: object) -> None:
        ist = self._inst(instance)
        if ist.decided is not None:
            return
        if rnd == 1:
            ist.votes_r1.setdefault(a, value)
        else:
            ist.r2_votes.setdefault(a, value)
        learned = ist.learner.on_phase2b(Phase2b(rnd, value, a))
        if learned is not None:
            ist.decided = learned
            ist.decide_time = self.loop.now
            if rnd == 1:
                self.fast_decides += 1
            self._finalize(instance, ist, outcome="fast" if rnd == 1 else "recovered")
            return
        if rnd == 1 and self.recovery == "coordinated" \
                and not ist.recovery_sent and ist.learner.collision_suspected(1):
            self._start_recovery(instance, ist)

    def _start_recovery(self, instance: int, ist: InstanceState) -> None:
        """Coordinated recovery: round-1 2b votes become round-2 1b messages
        (needs a phase-1 quorum of them), pick per IsPickableVal, commit
        classically with q2c."""
        votes = ist.votes_r1
        if not self.rs.contains_q1(votes, 2):
            # Wait for more votes — re-check on each arrival.
            return
        ist.recovery_sent = True
        self.recovery_entries += 1
        msgs = [Phase1b(2, 1, v, a) for a, v in votes.items()]
        picks = pick_values(self.rs, 2, msgs, set(votes.values())) - {ANY}
        v = choose_value(picks)
        for a in range(self.n):
            if a in self.crashed:
                continue
            d = self.lat.sample(self.rng)
            if d is None:
                continue
            self.loop.after(d, lambda a=a, v=v: self._acceptor_recv_2a_r2(a, instance, v))

    def _acceptor_recv_2a_r2(self, a: int, instance: int, v: object) -> None:
        # Classic round 2 vote (rnd[a] <= 2, vrnd[a] < 2 always holds here:
        # acceptors only voted in round 1 for this slot).
        d = self.lat.sample(self.rng)
        if d is None:
            return
        self.loop.after(d, lambda: self._coord_recv_2b(instance, 2, a, v))

    def _finalize(self, instance: int, ist: InstanceState, outcome: str) -> None:
        for (inst, value), res in self.results.items():
            if inst != instance or res.decide_time is not None:
                continue
            if value == ist.decided:
                res.decide_time = ist.decide_time
                res.outcome = outcome
            else:
                res.decide_time = ist.decide_time
                res.outcome = "aborted"

    # -- driver -----------------------------------------------------------------
    def run(self, until_ms: float = math.inf) -> List[InstanceResult]:
        self.loop.run(until=until_ms)
        return list(self.results.values())


# ---------------------------------------------------------------------------
# Workload generators (§6).
# ---------------------------------------------------------------------------

def conflict_free_workload(sim: FastPaxosSim, n_requests: int, rate_per_s: float,
                           seed: int = 1) -> None:
    """§6 Fig. 2a: steady stream, one instance per command (no conflicts)."""
    rng = random.Random(seed)
    t = 0.0
    mean_gap_ms = 1000.0 / rate_per_s
    for i in range(n_requests):
        t += rng.expovariate(1.0 / mean_gap_ms)
        sim.submit(t, instance=i, value=f"v{i}", proposer=i % 4)


def conflict_workload(sim: FastPaxosSim, n_requests: int, rate_per_s: float,
                      conflict_frac: float = 0.10, seed: int = 1) -> int:
    """§6 Fig. 2b/2c: ~conflict_frac of commands share an instance with the
    *next* command (two clients race for the same slot).  Returns the number
    of potential conflict pairs generated."""
    rng = random.Random(seed)
    t = 0.0
    mean_gap_ms = 1000.0 / rate_per_s
    inst = 0
    pairs = 0
    i = 0
    while i < n_requests:
        t += rng.expovariate(1.0 / mean_gap_ms)
        if rng.random() < conflict_frac and i + 1 < n_requests:
            gap = rng.expovariate(1.0 / mean_gap_ms)
            sim.submit(t, instance=inst, value=f"v{i}", proposer=0)
            sim.submit(t + gap, instance=inst, value=f"v{i + 1}", proposer=1)
            pairs += 1
            i += 2
            t += gap
        else:
            sim.submit(t, instance=inst, value=f"v{i}", proposer=i % 4)
            i += 1
        inst += 1
    return pairs


def latency_stats(results: Sequence[InstanceResult]) -> Dict[str, float]:
    lats = sorted(r.latency_ms for r in results
                  if r.latency_ms is not None and r.outcome in ("fast", "recovered"))
    if not lats:
        return {"count": 0}
    q = lambda p: lats[min(len(lats) - 1, int(p * len(lats)))]
    return {
        "count": len(lats),
        "mean_ms": sum(lats) / len(lats),
        "p50_ms": q(0.50),
        "p95_ms": q(0.95),
        "p99_ms": q(0.99),
        "max_ms": lats[-1],
    }
