"""TLC-lite: exhaustive breadth-first exploration of the Fast Flexible Paxos
specification (Appendix A of the paper) on small configurations.

The paper validates its claim by model-checking a TLA+ spec with TLC.  We do
the same in Python: states are explored breadth-first from ``Init`` under the
full action set (Propose, Phase1a/1b/2a/2b, CoordinatedRecovery,
UncoordinatedRecovery), and the invariants

  Nontriviality:  learned ⊆ proposed
  Consistency:    |learned| ≤ 1

are asserted in every reachable state.  ``learned`` is *derived* from the
message history (v is learned in round i iff a phase-2 round-i quorum all
voted (i, v)), which keeps the state vector small.

Two usage modes, mirroring the paper:

* positive — valid quorum specs (Eqs. 13/14 hold) must explore cleanly;
* negative — a spec violating Eq.14 (e.g. n=3, q1=2, q2c=2, q2f=2) must
  yield a reachable Consistency violation, demonstrating the checker has
  teeth and that the paper's requirements are tight.

Message loss is not modelled: for *safety*, losing messages only removes
behaviours (nodes act on a monotonically growing ``sentMsg``, exactly as in
the TLA+ spec, where LoseMsg only shrinks the set a node can react to).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Dict, FrozenSet, Iterator, List, Optional, Sequence, Set, Tuple

from .protocol import ANY, NONE, Phase1b, RoundSystem, pick_values
from .quorum import ExplicitQuorumSystem, QuorumSpec

# Compact message encodings: ('1a', i) | ('1b', i, vrnd, vval, acc)
#                           | ('2a', i, val) | ('2b', i, val, acc)
Msg = Tuple
# State: (rnds, vrnds, vvals, crnd, cval, sentMsg frozenset, proposed frozenset)
State = Tuple[Tuple[int, ...], Tuple[int, ...], Tuple, int, object, FrozenSet[Msg], FrozenSet]

A_ANY = ANY
C_NONE = NONE


@dataclass
class CheckResult:
    ok: bool
    states: int
    violation: Optional[str] = None
    trace: Optional[List[str]] = None
    truncated: bool = False

    def __bool__(self) -> bool:
        return self.ok


def _learned(sent: FrozenSet[Msg], rs: RoundSystem) -> Set:
    votes: Dict[int, Dict[object, Set[int]]] = {}
    for m in sent:
        if m[0] == "2b":
            _, i, val, acc = m
            votes.setdefault(i, {}).setdefault(val, set()).add(acc)
    out: Set = set()
    for i, by_val in votes.items():
        for val, accs in by_val.items():
            if rs.contains_q2(accs, i):
                out.add(val)
    return out


def explore(spec: "QuorumSpec | ExplicitQuorumSystem",
            values: Sequence = (1, 2),
            max_round: int = 2,
            fast_rounds: str = "odd",
            max_states: int = 400_000,
            uncoordinated: bool = False) -> CheckResult:
    """BFS the reachable state space; check invariants in every state.

    ``spec`` may be any ``QuorumSystem`` — a cardinality ``QuorumSpec``, an
    ``ExplicitQuorumSystem`` (grid, hand-built), or a system lowered through
    ``to_explicit()`` (e.g. weighted voting): quorum checks route through
    the set-level ``RoundSystem`` predicates, so the checker validates
    arbitrary mask-encodable systems — the differential backstop for the
    Monte-Carlo engine's general quorum support."""
    rs = RoundSystem(spec, n_coordinators=1, fast_rounds=fast_rounds)
    n = rs.spec.n
    rounds = list(range(1, max_round + 1))

    init: State = (
        tuple([0] * n), tuple([0] * n), tuple([A_ANY] * n),
        0, C_NONE, frozenset(), frozenset(),
    )
    parent: Dict[State, Tuple[Optional[State], str]] = {init: (None, "Init")}
    queue: deque = deque([init])
    explored = 0

    while queue:
        st = queue.popleft()
        explored += 1
        if explored > max_states:
            return CheckResult(True, explored - 1, truncated=True)

        rnds, vrnds, vvals, crnd, cval, sent, proposed = st

        # ---- invariants --------------------------------------------------
        learned = _learned(sent, rs)
        if not learned <= set(proposed):
            return CheckResult(False, explored, "Nontriviality", _trace(parent, st))
        if len(learned) > 1:
            return CheckResult(False, explored, "Consistency", _trace(parent, st))

        # ---- successors ----------------------------------------------------
        for nxt, label in _successors(st, rs, values, rounds, uncoordinated):
            if nxt not in parent:
                parent[nxt] = (st, label)
                queue.append(nxt)

    return CheckResult(True, explored)


def _successors(st: State, rs: RoundSystem, values, rounds,
                uncoordinated: bool) -> Iterator[Tuple[State, str]]:
    rnds, vrnds, vvals, crnd, cval, sent, proposed = st
    n = rs.spec.n

    # Propose(v)
    for v in values:
        if v not in proposed:
            yield ((rnds, vrnds, vvals, crnd, cval, sent, proposed | {v}),
                   f"Propose({v})")

    # Phase1a(c, i)
    for i in rounds:
        if crnd < i:
            yield ((rnds, vrnds, vvals, i, C_NONE, sent | {("1a", i)}, proposed),
                   f"Phase1a({i})")

    # Phase1b(i, a)
    for i in rounds:
        if ("1a", i) not in sent:
            continue
        for a in range(n):
            if rnds[a] < i:
                m = ("1b", i, vrnds[a], vvals[a], a)
                nr = _set(rnds, a, i)
                yield ((nr, vrnds, vvals, crnd, cval, sent | {m}, proposed),
                       f"Phase1b({i},{a})")

    # Phase2a(c, v): needs a phase-1 quorum of 1b messages for round crnd.
    if crnd > 0 and cval == C_NONE:
        got = {m[4]: m for m in sent if m[0] == "1b" and m[1] == crnd}
        for Q in rs.q1_subsets(got, crnd):
            msgs = [Phase1b(crnd, got[a][2], got[a][3], a) for a in Q]
            for v in pick_values(rs, crnd, msgs, set(proposed)):
                if v == ANY and not rs.is_fast(crnd):
                    continue
                m = ("2a", crnd, v)
                yield ((rnds, vrnds, vvals, crnd, v, sent | {m}, proposed),
                       f"Phase2a({crnd},{v})")

    # Phase2b(i, a, v)
    for m in sent:
        if m[0] != "2a":
            continue
        _, i, val = m
        cands = list(proposed) if val == ANY else [val]
        for a in range(n):
            if rnds[a] <= i and vrnds[a] < i:
                for v in cands:
                    nr = _set(rnds, a, i)
                    nvr = _set(vrnds, a, i)
                    nvv = _set(vvals, a, v)
                    mm = ("2b", i, v, a)
                    yield ((nr, nvr, nvv, crnd, cval, sent | {mm}, proposed),
                           f"Phase2b({i},{a},{v})")

    # CoordinatedRecovery(c, v): coordinator saw a fast round crnd with cval=ANY.
    i = crnd
    if cval == A_ANY and (i + 1) in rounds:
        p2b = {m[3]: m for m in sent if m[0] == "2b" and m[1] == i}
        for Q in rs.q1_subsets(p2b, i + 1):
            msgs = [Phase1b(i + 1, i, p2b[a][2], a) for a in Q]
            picks = pick_values(rs, i + 1, msgs, set(proposed)) - {ANY}
            for v in picks:
                m = ("2a", i + 1, v)
                yield ((rnds, vrnds, vvals, i + 1, v, sent | {m}, proposed),
                       f"CoordRecovery({i + 1},{v})")

    # UncoordinatedRecovery(i, a, v)
    if uncoordinated:
        for i in rounds:
            if (i + 1) not in rounds or not rs.is_fast(i + 1):
                continue
            p2b = {m[3]: m for m in sent if m[0] == "2b" and m[1] == i}
            for a in range(n):
                # TLA+ Phase2b enabling condition for a round-(i+1) vote:
                # rnd <= i+1 /\ vrnd < i+1 (a promise of i+1 alone does not
                # disable the vote — mirrors Acceptor.uncoordinated_recovery)
                if rnds[a] > i + 1 or vrnds[a] >= i + 1:
                    continue
                for Q in rs.q1_subsets(p2b, i + 1):
                    msgs = [Phase1b(i + 1, i, p2b[b][2], b) for b in Q]
                    picks = pick_values(rs, i + 1, msgs, set(proposed)) - {ANY}
                    for v in picks:
                        nr = _set(rnds, a, i + 1)
                        nvr = _set(vrnds, a, i + 1)
                        nvv = _set(vvals, a, v)
                        mm = ("2b", i + 1, v, a)
                        yield ((nr, nvr, nvv, crnd, cval, sent | {mm}, proposed),
                               f"UncoordRecovery({i + 1},{a},{v})")


def _set(t: Tuple, i: int, v) -> Tuple:
    lst = list(t)
    lst[i] = v
    return tuple(lst)


def _trace(parent: Dict[State, Tuple[Optional[State], str]], st: State) -> List[str]:
    out: List[str] = []
    cur: Optional[State] = st
    while cur is not None:
        prev, label = parent[cur]
        out.append(label)
        cur = prev
    return list(reversed(out))
