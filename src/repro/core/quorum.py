"""Quorum systems and the intersection requirements of the Paxos family.

This module is the heart of the paper: first-class ``QuorumSystem`` objects
plus checkers for every intersection requirement discussed in the paper —

  Paxos           (Eq.1)   any two quorums intersect
  Flexible Paxos  (Eq.3)   every phase-1 quorum intersects every phase-2 quorum
  Fast Paxos      (Eq.5-7) classic/classic, fast/fast/classic, fast/fast/fast
  Fast Flexible   (Eq.11)  every Q1 intersects every classic Q2
  Paxos           (Eq.12)  every Q1 intersects every *pair* of fast Q2s

and their cardinality forms (Eqs. 2, 4, 8-10, 13-14).

Quorum systems are represented explicitly as frozensets of acceptor ids so the
set-based requirements can be checked exactly; cardinality systems enumerate
lazily (validity is proved arithmetically, enumerated only on demand).
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import (Dict, FrozenSet, Iterable, Iterator, Protocol,
                    Sequence, Tuple, runtime_checkable)

import numpy as np

Acceptor = int
Quorum = FrozenSet[Acceptor]

# Threshold assigned to padding quorum rows in mask encodings: with zero
# weights no indicator can ever reach it, so padded rows never satisfy.
PAD_THRESHOLD = float(2 ** 30)


@runtime_checkable
class QuorumSystem(Protocol):
    """What every evaluation backend asks of a quorum system.

    ``QuorumSpec``, ``ExplicitQuorumSystem`` and ``WeightedQuorumSystem``
    all satisfy it, so one object can be model-checked, DES-simulated and
    Monte-Carlo-swept without reshaping its inputs:

      ``to_masks()``     lowers to the engine's mask encoding — the single
                         lowering every Monte-Carlo path consumes;
      ``to_explicit()``  enumerates the quorums for the set-level protocol
                         predicates (model checker, discrete-event sim);
      ``is_valid()``     the FFP intersection requirements in the system's
                         native form (Eqs. 11-14).
    """

    n: int

    def is_valid(self) -> bool: ...

    def validate(self) -> "QuorumSystem": ...

    def to_masks(self) -> "QuorumMasks": ...

    def to_explicit(self) -> "ExplicitQuorumSystem": ...


# ---------------------------------------------------------------------------
# Set-level intersection predicates (the paper's Eqs. 1, 3, 5-7, 11, 12).
# ---------------------------------------------------------------------------

def pairwise_intersect(qs: Iterable[Quorum], qs2: Iterable[Quorum] | None = None) -> bool:
    """Eq.1 / Eq.3 / Eq.5 / Eq.11: every quorum in ``qs`` meets every one in ``qs2``."""
    qs = list(qs)
    qs2 = qs if qs2 is None else list(qs2)
    return all(q & p for q in qs for p in qs2)


def triple_intersect(a: Iterable[Quorum], b: Iterable[Quorum], c: Iterable[Quorum]) -> bool:
    """Eq.6 / Eq.7 / Eq.12: every (Q,Q',Q'') in a x b x c has a common element."""
    a, b, c = list(a), list(b), list(c)
    return all(q & p & r for q in a for p in b for r in c)


# ---------------------------------------------------------------------------
# Cardinality forms (Eqs. 2, 4, 8-10, 13, 14).
# ---------------------------------------------------------------------------

def paxos_card_ok(n: int, q: int) -> bool:
    return 2 * q > n                                   # Eq.2


def flexible_card_ok(n: int, q1: int, q2: int) -> bool:
    return q1 + q2 > n                                 # Eq.4


def fast_paxos_card_ok(n: int, qc: int, qf: int) -> bool:
    return (2 * qc > n                                 # Eq.8
            and qc + 2 * qf > 2 * n                    # Eq.9
            and 3 * qf > 2 * n)                        # Eq.10


def ffp_card_ok(n: int, q1: int, q2c: int, q2f: int) -> bool:
    """The paper's relaxed requirements (Eqs. 13 & 14)."""
    return (q1 + q2c > n                               # Eq.13
            and q1 + 2 * q2f > 2 * n)                  # Eq.14


def relaxed_card_ok(n: int, q1: int, q2c: int, q2f: int) -> bool:
    """Relaxed Paxos cardinality requirement (arXiv 2203.03058).

    Relaxed Paxos observes that Eq.13 (``q1 + q2c > n``) is only needed by
    phase 1 of rounds that have a *classic* round below them; the hot-path
    recovery round (the first round after the steady-state fast round) only
    needs Eq.14's pair intersection with the fast round.  The per-system
    requirement therefore drops to Eq.14 alone — ``q2c`` is a free choice —
    provided later rounds enlarge their phase-1 quorums to
    ``max(q1, n + 1 - q2c)`` (``RelaxedQuorumSpec.q1_full``), which restores
    Eq.13 exactly where it is needed.
    """
    return 1 <= q2c <= n and q1 + 2 * q2f > 2 * n    # Eq.14 only


def ffp_min_q2f(n: int, q1: int) -> int:
    """Smallest valid fast phase-2 quorum for a given phase-1 quorum (Eq.14)."""
    return max(1, (2 * n - q1) // 2 + 1)


def ffp_min_q2c(n: int, q1: int) -> int:
    """Smallest valid classic phase-2 quorum for a given phase-1 quorum (Eq.13)."""
    return max(1, n - q1 + 1)


def fast_paxos_suggested(n: int, variant: str = "three_quarters") -> Tuple[int, int]:
    """Fast Paxos' own suggested (qc, qf) pairs from Section 2.3."""
    if variant == "two_thirds":
        q = (2 * n) // 3 + 1
        return q, q
    if variant == "three_quarters":
        return n // 2 + 1, math.ceil(3 * n / 4)
    raise ValueError(f"unknown variant {variant!r}")


# ---------------------------------------------------------------------------
# Membership-mask encoding (DESIGN.md §2): the lingua franca between the
# set-level quorum systems here and the batched Monte-Carlo engine / Pallas
# masked-tally kernel.  Per phase, a (G, n) float32 weight matrix plus a (G,)
# threshold vector; an acceptor subset S (0/1 indicator x) satisfies quorum
# row g iff  W[g] . x >= t[g].  The three system families all fit:
#
#   cardinality  one row of ones, threshold q          (G = 1)
#   weighted     one row of weights, phase threshold   (G = 1)
#   explicit     one row per quorum: membership indicator, threshold |Q|
#                (the row "saturates" only when every member is present)
#
# Padding rows carry zero weight and threshold PAD_THRESHOLD, so they are
# never satisfied; padding acceptor columns carry zero weight.
# ---------------------------------------------------------------------------

@dataclass(frozen=True, eq=False)
class QuorumMasks:
    """Mask encoding of one quorum system's three phases (numpy, host-side).

    ``p1``/``p2c``/``p2f`` weights are (G, n) float32; thresholds (G,)
    float32.  Build via the ``to_masks()`` method of ``QuorumSpec``,
    ``ExplicitQuorumSystem`` or ``WeightedQuorumSystem``.
    """

    n: int
    p1_w: np.ndarray
    p1_t: np.ndarray
    p2c_w: np.ndarray
    p2c_t: np.ndarray
    p2f_w: np.ndarray
    p2f_t: np.ndarray
    label: str = ""

    def __post_init__(self) -> None:
        for ph in ("p1", "p2c", "p2f"):
            w, t = getattr(self, ph + "_w"), getattr(self, ph + "_t")
            if w.ndim != 2 or w.shape[1] != self.n or t.shape != (w.shape[0],):
                raise ValueError(
                    f"{ph}: weights {w.shape} / thresholds {t.shape} "
                    f"inconsistent with n={self.n}")
            if (w < 0).any() or (t <= 0).any():
                raise ValueError(f"{ph}: weights must be >= 0, thresholds > 0")

    @property
    def groups(self) -> Tuple[int, int, int]:
        """(G1, G2c, G2f) quorum-row counts."""
        return (self.p1_w.shape[0], self.p2c_w.shape[0], self.p2f_w.shape[0])

    def pad_groups(self, g1: int, g2c: int, g2f: int) -> "QuorumMasks":
        """Pad each phase to the given row count with never-satisfied rows."""
        def pad(w, t, g):
            G = w.shape[0]
            if g < G:
                raise ValueError(f"cannot pad {G} rows down to {g}")
            return (np.concatenate([w, np.zeros((g - G, self.n), np.float32)]),
                    np.concatenate([t, np.full(g - G, PAD_THRESHOLD,
                                               np.float32)]))
        p1w, p1t = pad(self.p1_w, self.p1_t, g1)
        p2cw, p2ct = pad(self.p2c_w, self.p2c_t, g2c)
        p2fw, p2ft = pad(self.p2f_w, self.p2f_t, g2f)
        return QuorumMasks(self.n, p1w, p1t, p2cw, p2ct, p2fw, p2ft,
                           self.label)

    def embed(self, n: int) -> "QuorumMasks":
        """Re-express over a larger cluster: acceptors >= self.n get zero
        weight everywhere (present but never counted), letting systems of
        different natural sizes share one batched mask table."""
        if n < self.n:
            raise ValueError(f"cannot embed n={self.n} into n={n}")
        def wide(w):
            return np.concatenate(
                [w, np.zeros((w.shape[0], n - self.n), np.float32)], axis=1)
        return QuorumMasks(n, wide(self.p1_w), self.p1_t, wide(self.p2c_w),
                           self.p2c_t, wide(self.p2f_w), self.p2f_t,
                           self.label)

    def cardinality_q(self) -> "Tuple[int, int, int] | None":
        """(q1, q2c, q2f) when every phase is a single all-ones row with an
        integral threshold — the encoding ``QuorumSpec.to_masks`` emits.
        ``None`` otherwise.  ``build_mask_table`` uses this to select the
        k-th-order-statistic specialization for all-cardinality tables."""
        qs = []
        for ph in ("p1", "p2c", "p2f"):
            w, t = getattr(self, ph + "_w"), getattr(self, ph + "_t")
            if w.shape[0] != 1 or not (w == 1.0).all():
                return None
            q = float(t[0])
            if q != int(q) or not (1 <= q <= self.n):
                return None
            qs.append(int(q))
        return (qs[0], qs[1], qs[2])

    # -- reference semantics (used by differential tests) -------------------
    def satisfied(self, members: Iterable[Acceptor], phase: str) -> bool:
        """Does the acceptor set satisfy some quorum row of ``phase``?"""
        x = np.zeros(self.n, np.float32)
        x[list(set(members))] = 1.0
        w = getattr(self, phase + "_w")
        t = getattr(self, phase + "_t")
        return bool(((w @ x) >= t).any())

    def fault_tolerance(self) -> Dict[str, int]:
        """Max crashes each phase survives (some quorum stays intact),
        by brute force over crash sets — small n only."""
        def phase_ft(w, t):
            f = 0
            while f < self.n:
                for crash in itertools.combinations(range(self.n), f + 1):
                    alive = np.ones(self.n, np.float32)
                    alive[list(crash)] = 0.0
                    if not ((w @ alive) >= t).any():
                        return f
                f += 1
            return f
        ft1 = phase_ft(self.p1_w, self.p1_t)
        ft2c = phase_ft(self.p2c_w, self.p2c_t)
        ft2f = phase_ft(self.p2f_w, self.p2f_t)
        return {"phase1": ft1, "phase2_classic": ft2c, "phase2_fast": ft2f,
                "steady_state_classic": ft2c, "steady_state_fast": ft2f}


def _card_masks(n: int, q1: int, q2c: int, q2f: int,
                label: str = "") -> QuorumMasks:
    ones = np.ones((1, n), np.float32)
    return QuorumMasks(n, ones, np.array([q1], np.float32),
                       ones.copy(), np.array([q2c], np.float32),
                       ones.copy(), np.array([q2f], np.float32), label)


def _explicit_masks(n: int, p1: Sequence[Quorum], p2c: Sequence[Quorum],
                    p2f: Sequence[Quorum], label: str = "") -> QuorumMasks:
    def rows(qs):
        w = np.zeros((len(qs), n), np.float32)
        for g, q in enumerate(qs):
            w[g, list(q)] = 1.0
        return w, np.array([len(q) for q in qs], np.float32)
    p1w, p1t = rows(p1)
    p2cw, p2ct = rows(p2c)
    p2fw, p2ft = rows(p2f)
    return QuorumMasks(n, p1w, p1t, p2cw, p2ct, p2fw, p2ft, label)


# ---------------------------------------------------------------------------
# Quorum systems.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class QuorumSpec:
    """Quorum configuration of a Fast Flexible Paxos deployment.

    ``q1``  phase-1 quorums (identical for fast and classic rounds — §5)
    ``q2c`` phase-2 quorums for classic rounds
    ``q2f`` phase-2 quorums for fast rounds
    """

    n: int
    q1: int
    q2c: int
    q2f: int

    def __post_init__(self) -> None:
        for name in ("q1", "q2c", "q2f"):
            v = getattr(self, name)
            if not (1 <= v <= self.n):
                raise ValueError(f"{name}={v} out of range for n={self.n}")

    # -- validity ----------------------------------------------------------
    def is_valid(self) -> bool:
        return ffp_card_ok(self.n, self.q1, self.q2c, self.q2f)

    def validate(self) -> "QuorumSpec":
        if not self.is_valid():
            raise ValueError(
                f"quorum spec violates FFP intersection requirements: "
                f"n={self.n} q1={self.q1} q2c={self.q2c} q2f={self.q2f} "
                f"(need q1+q2c>n and q1+2*q2f>2n)")
        return self

    # -- constructors ------------------------------------------------------
    @classmethod
    def paper_headline(cls, n: int = 11) -> "QuorumSpec":
        """§5/§6 example: n=11, q1=9, q2f=7, q2c=3."""
        if n == 11:
            return cls(11, 9, 3, 7).validate()
        # generalized: q1 = n - ceil(n/4), then minimal phase-2 quorums.
        q1 = n - max(1, n // 4)
        return cls(n, q1, ffp_min_q2c(n, q1), ffp_min_q2f(n, q1)).validate()

    @classmethod
    def fast_paxos(cls, n: int, variant: str = "three_quarters") -> "QuorumSpec":
        """Fast Paxos baseline expressed in FFP vocabulary (q1=qc, q2c=qc, q2f=qf)."""
        qc, qf = fast_paxos_suggested(n, variant)
        return cls(n, qc, qc, qf).validate()

    @classmethod
    def majority_fast(cls, n: int) -> "QuorumSpec":
        """§5 liveness-limited extreme: majority fast quorums need q1 = n."""
        q2f = n // 2 + 1
        q1 = 2 * n - 2 * q2f + 1
        return cls(n, q1, ffp_min_q2c(n, q1), q2f).validate()

    # -- enumeration (for the set-based checkers & the model checker) -------
    def phase1_quorums(self, acceptors: Sequence[Acceptor] | None = None) -> Iterator[Quorum]:
        yield from _combos(self.n, self.q1, acceptors)

    def phase2c_quorums(self, acceptors: Sequence[Acceptor] | None = None) -> Iterator[Quorum]:
        yield from _combos(self.n, self.q2c, acceptors)

    def phase2f_quorums(self, acceptors: Sequence[Acceptor] | None = None) -> Iterator[Quorum]:
        yield from _combos(self.n, self.q2f, acceptors)

    def check_sets(self) -> bool:
        """Verify Eqs. 11 & 12 by explicit set enumeration (small n only)."""
        p1 = list(self.phase1_quorums())
        p2c = list(self.phase2c_quorums())
        p2f = list(self.phase2f_quorums())
        return (pairwise_intersect(p1, p2c)
                and triple_intersect(p1, p2f, p2f))

    # -- mask export (DESIGN.md §2) ----------------------------------------
    def to_masks(self) -> QuorumMasks:
        """One all-ones row per phase with the cardinality threshold — the
        engine's mask path on this encoding is bit-identical to its
        threshold path."""
        return _card_masks(self.n, self.q1, self.q2c, self.q2f, self.label)

    def to_explicit(self) -> "ExplicitQuorumSystem":
        """Enumerate the size-q quorums (for the set-level backends)."""
        return ExplicitQuorumSystem.from_spec(self)

    @property
    def label(self) -> str:
        return f"card[{self.q1},{self.q2c},{self.q2f}]"

    # -- convenience -------------------------------------------------------
    def fault_tolerance(self) -> dict:
        """How many acceptor crashes each path tolerates while staying live."""
        return {
            "phase1": self.n - self.q1,
            "phase2_classic": self.n - self.q2c,
            "phase2_fast": self.n - self.q2f,
            # steady-state Multi-Paxos-style operation only needs phase-2:
            "steady_state_classic": self.n - self.q2c,
            "steady_state_fast": self.n - self.q2f,
        }


def _combos(n: int, k: int, acceptors: Sequence[Acceptor] | None) -> Iterator[Quorum]:
    ids = range(n) if acceptors is None else acceptors
    for c in itertools.combinations(ids, k):
        yield frozenset(c)


@dataclass(frozen=True)
class RelaxedQuorumSpec(QuorumSpec):
    """Relaxed Paxos quorum configuration (arXiv 2203.03058).

    Validity is ``relaxed_card_ok`` — Eq.14 alone, so ``q2c`` may drop all
    the way to 1 even when ``q1 + q2c <= n``.  Safety is preserved by making
    phase-1 quorum size *per round*: the steady-state fast round and the
    recovery round directly above it use ``q1`` (they only ever need pair
    intersection with fast quorums, Eq.14); any round with a classic round
    below it uses ``q1_full = max(q1, n + 1 - q2c)``, restoring Eq.13 for
    exactly the rounds whose phase 1 must see a classic round's vote.
    ``RoundSystem`` consults ``q1_for`` to apply this (the model checker,
    DES and coordinator all route through it).

    ``to_masks()`` lowers the *hot-path* triple (q1, q2c, q2f) — the fast
    round plus its first recovery, which is what the Monte-Carlo engine
    scores — so all-cardinality batches mixing FFP and Relaxed systems
    share one mask table and one compile.
    """

    def is_valid(self) -> bool:
        return relaxed_card_ok(self.n, self.q1, self.q2c, self.q2f)

    def validate(self) -> "RelaxedQuorumSpec":
        if not self.is_valid():
            raise ValueError(
                f"quorum spec violates the Relaxed Paxos requirement: "
                f"n={self.n} q1={self.q1} q2c={self.q2c} q2f={self.q2f} "
                f"(need q1+2*q2f>2n)")
        return self

    @property
    def q1_full(self) -> int:
        """Phase-1 size for rounds with a classic round below (Eq.13)."""
        return max(self.q1, self.n + 1 - self.q2c)

    def q1_for(self, classic_below: bool) -> int:
        """Per-round phase-1 quorum size — the relaxation's whole trick."""
        return self.q1_full if classic_below else self.q1

    def check_sets(self) -> bool:
        """Relaxed set-level requirement: hot-path phase-1 quorums triple-
        intersect fast-quorum pairs (Eq.12); *full* phase-1 quorums meet
        every classic quorum (Eq.11)."""
        p1_hot = list(self.phase1_quorums())
        p1_full = list(_combos(self.n, self.q1_full, None))
        p2c = list(self.phase2c_quorums())
        p2f = list(self.phase2f_quorums())
        return (pairwise_intersect(p1_full, p2c)
                and triple_intersect(p1_hot, p2f, p2f))

    def to_explicit(self) -> "ExplicitQuorumSystem":
        raise TypeError(
            "RelaxedQuorumSpec has per-round phase-1 quorums (q1 on the hot "
            "path, q1_full above classic rounds); a flat ExplicitQuorumSystem "
            "cannot represent that — keep the cardinality spec (RoundSystem, "
            "the DES and the model checker all consume it directly)")

    def fault_tolerance(self) -> dict:
        """Crash budgets with the per-round phase-1 relaxation priced in:
        ``phase1`` reports the *guaranteed* budget ``n - q1_full`` — once a
        classic round has run, every later phase 1 needs ``q1_full``
        acceptors, so that is the size the system must always be able to
        form.  The hot-path detection quorum stays ``q1`` (it shows up in
        the latency axes instead)."""
        ft = super().fault_tolerance()
        ft["phase1"] = self.n - self.q1_full
        return ft

    @property
    def label(self) -> str:
        return f"relaxed[{self.q1},{self.q2c},{self.q2f}]"


# ---------------------------------------------------------------------------
# Explicit (non-cardinality) quorum systems — §6 "quorum systems that are not
# based solely on quorum cardinality".  These exercise the *set-level*
# requirement checkers, demonstrating that the framework accepts any system
# satisfying Eqs. 11/12, not just counting systems.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ExplicitQuorumSystem:
    """A fully enumerated quorum system over acceptors 0..n-1."""

    n: int
    p1: Tuple[Quorum, ...]
    p2c: Tuple[Quorum, ...]
    p2f: Tuple[Quorum, ...]

    def is_valid(self) -> bool:
        return (pairwise_intersect(self.p1, self.p2c)
                and triple_intersect(self.p1, self.p2f, self.p2f))

    def validate(self) -> "ExplicitQuorumSystem":
        if not self.is_valid():
            raise ValueError("explicit quorum system violates Eq.11/Eq.12")
        return self

    @classmethod
    def from_spec(cls, spec: QuorumSpec) -> "ExplicitQuorumSystem":
        return cls(spec.n,
                   tuple(spec.phase1_quorums()),
                   tuple(spec.phase2c_quorums()),
                   tuple(spec.phase2f_quorums()))

    def to_masks(self) -> QuorumMasks:
        """One membership-indicator row per quorum, threshold |Q| (a row
        saturates only once every member is present)."""
        return _explicit_masks(self.n, self.p1, self.p2c, self.p2f,
                               self.label)

    def to_explicit(self) -> "ExplicitQuorumSystem":
        return self

    def embed(self, n: int) -> "ExplicitQuorumSystem":
        """Re-express over a larger cluster: the extra acceptors join no
        quorum (mirrors ``QuorumMasks.embed``, but keeps the set-level form
        so the system still runs on the DES / model-check backends)."""
        if n < self.n:
            raise ValueError(f"cannot embed n={self.n} into n={n}")
        return ExplicitQuorumSystem(n, self.p1, self.p2c, self.p2f)

    @property
    def label(self) -> str:
        return f"explicit[n={self.n}]"

    @classmethod
    def grid(cls, cols: int, rows: int = 3) -> "ExplicitQuorumSystem":
        """A 3xC grid system (non-cardinality example for §6's closing remark).

        phase-1        = one full row ∪ one full column
        phase-2 classic = one column
        phase-2 fast    = two full rows

        Eq.11: a row meets every column.  Eq.12: with exactly three rows, any
        two fast quorums (two rows each) share a row r* by pigeonhole; any
        phase-1 quorum's column hits r*, giving the triple intersection.  The
        pigeonhole argument needs rows == 3 — larger grids admit two fast
        quorums with disjoint row pairs, violating Eq.12 (checked by
        ``is_valid`` and exercised in tests)."""
        if rows != 3:
            raise ValueError("grid construction is only FFP-valid for rows=3")
        n = rows * cols
        idx = lambda r, c: r * cols + c

        def row(r):
            return frozenset(idx(r, c) for c in range(cols))

        def col(c):
            return frozenset(idx(r, c) for r in range(rows))

        p2c = tuple(col(c) for c in range(cols))
        p1 = tuple(row(r) | col(c) for r in range(rows) for c in range(cols))
        p2f = tuple(row(r1) | row(r2)
                    for r1 in range(rows) for r2 in range(rows) if r1 < r2)
        return cls(n, p1, p2c, p2f)


@dataclass(frozen=True)
class WeightedQuorumSystem:
    """Weighted voting (Gifford '79) generalized to FFP thresholds.

    Each acceptor i carries weight w[i]; a set is a quorum for a phase when
    its total weight exceeds the phase threshold.  Validity of the FFP
    requirements for weighted systems:

      Eq.11  t1 + t2c >  W         (any Q1, Q2c overlap)
      Eq.12  t1 + 2*t2f > 2*W      (any Q1 and two Q2f share an acceptor)

    mirroring the cardinality forms with weights in place of counts.
    """

    weights: Tuple[int, ...]
    t1: int
    t2c: int
    t2f: int

    @property
    def n(self) -> int:
        return len(self.weights)

    @property
    def total(self) -> int:
        return sum(self.weights)

    def is_valid(self) -> bool:
        W = self.total
        return self.t1 + self.t2c > W and self.t1 + 2 * self.t2f > 2 * W

    def validate(self) -> "WeightedQuorumSystem":
        if not self.is_valid():
            raise ValueError("weighted system violates FFP thresholds")
        return self

    def is_quorum(self, members: Iterable[Acceptor], phase: str) -> bool:
        w = sum(self.weights[a] for a in set(members))
        t = {"p1": self.t1, "p2c": self.t2c, "p2f": self.t2f}[phase]
        return w >= t

    def enumerate(self, phase: str) -> Iterator[Quorum]:
        """Minimal quorums of a phase (exponential; small n only)."""
        ids = range(self.n)
        for r in range(1, self.n + 1):
            for c in itertools.combinations(ids, r):
                if self.is_quorum(c, phase):
                    s = frozenset(c)
                    if all(not self.is_quorum(s - {a}, phase) for a in s):
                        yield s

    def to_explicit(self) -> ExplicitQuorumSystem:
        """Enumerate minimal quorums into an explicit system (small n)."""
        return ExplicitQuorumSystem(self.n, tuple(self.enumerate("p1")),
                                    tuple(self.enumerate("p2c")),
                                    tuple(self.enumerate("p2f")))

    def to_masks(self) -> QuorumMasks:
        """One weighted row per phase (Gifford-style voting thresholds)."""
        w = np.asarray(self.weights, np.float32)[None, :]
        return QuorumMasks(self.n, w, np.array([self.t1], np.float32),
                           w.copy(), np.array([self.t2c], np.float32),
                           w.copy(), np.array([self.t2f], np.float32),
                           self.label)

    @property
    def label(self) -> str:
        return f"weighted[t1={self.t1},t2c={self.t2c},t2f={self.t2f}]"


def all_valid_specs(n: int) -> Iterator[QuorumSpec]:
    """Every cardinality spec valid under Eqs. 13/14 for a cluster of n."""
    for q1 in range(1, n + 1):
        for q2c in range(ffp_min_q2c(n, q1), n + 1):
            for q2f in range(ffp_min_q2f(n, q1), n + 1):
                yield QuorumSpec(n, q1, q2c, q2f)


def all_relaxed_specs(n: int) -> Iterator[RelaxedQuorumSpec]:
    """Every Relaxed-Paxos-valid cardinality spec (Eq.14 only) that FFP
    Eq.13 *rejects* — the systems the relaxation newly admits.  (A triple
    that also satisfies Eq.13 behaves identically to its FFP ``QuorumSpec``
    — ``q1_full == q1`` — so only the strictly-new points are yielded.)"""
    for q1 in range(1, n + 1):
        for q2f in range(ffp_min_q2f(n, q1), n + 1):
            for q2c in range(1, ffp_min_q2c(n, q1)):
                yield RelaxedQuorumSpec(n, q1, q2c, q2f)
