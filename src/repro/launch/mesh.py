"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before any jax import
and only then calls ``make_production_mesh``.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16x16 = 256 chips per pod; 2 pods = 512.

    Axes: 'data' carries batch + FSDP sharding, 'model' carries tensor/expert
    parallelism, 'pod' (multi-pod) is pure data parallelism across the DCN.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for single-device smoke runs."""
    return jax.make_mesh((1, 1), ("data", "model"))
