"""Abstract (no-allocation) state construction for the dry-run.

``jax.eval_shape`` gives ShapeDtypeStructs for params/opt-state/caches; the
logical-axes side data (static strings) is captured out-of-band during the
same trace, so a 480B-parameter model "initializes" in milliseconds.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec, input_specs
from repro.models.model import DecoderLM
from repro.training.optimizer import Optimizer


def eval_shape_with_axes(fn: Callable, *args) -> Tuple[Any, Any]:
    """fn(*args) -> (pytree, axes); returns (ShapeDtypeStruct tree, axes)."""
    cap: Dict[str, Any] = {}

    def wrapper(*a):
        out, axes = fn(*a)
        cap["axes"] = axes
        return out

    shapes = jax.eval_shape(wrapper, *args)
    return shapes, cap["axes"]


def abstract_params(model: DecoderLM):
    key = jax.random.PRNGKey(0)
    return eval_shape_with_axes(model.init, key)


def abstract_opt_state(opt: Optimizer, params_abstract, param_axes):
    state = jax.eval_shape(opt.init, params_abstract)
    return state, opt.state_axes(param_axes)


def abstract_cache(model: DecoderLM, batch: int, max_len: int):
    return eval_shape_with_axes(
        lambda: model.init_cache(batch, max_len))


def batch_axes(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, tuple]:
    """Logical axes for every entry of input_specs(cfg, shape)."""
    specs = input_specs(cfg, shape)
    out = {}
    for name, s in specs.items():
        if name in ("tokens", "labels"):
            out[name] = ("batch",) + (None,) * (len(s.shape) - 1)
        elif name in ("frame_emb", "patch_emb"):
            out[name] = ("batch",) + (None,) * (len(s.shape) - 2) + ("act_embed",)
        else:
            raise KeyError(name)
    return out
