"""Production serving launcher: prefill + batched incremental decode.

  PYTHONPATH=src python -m repro.launch.serve --arch <id> [--tokens N]
      [--smoke] [--dry-run --shape decode_32k|long_500k|prefill_32k]

--dry-run lowers the FULL config's serve_step (or prefill) for the
production mesh; otherwise a reduced config serves a synthetic request
batch on the local devices (same code path).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    if args.dry_run:
        import os
        import subprocess
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape]
        if args.multi_pod:
            cmd.append("--multi-pod")
        raise SystemExit(subprocess.call(cmd, env=dict(
            os.environ, PYTHONPATH="src:.")))

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduced_config
    from repro.models.model import DecoderLM

    cfg = get_config(args.arch)
    if args.smoke or jax.default_backend() != "tpu":
        cfg = reduced_config(cfg)
        print(f"[smoke] {args.arch} reduced")
    model = DecoderLM(cfg, remat=False)
    params, _ = model.init(jax.random.PRNGKey(0))

    B, P = args.batch, args.prompt_len
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 1, cfg.vocab)
    cache, _ = model.init_cache(B, P + args.tokens + 8)
    t0 = time.perf_counter()
    cache, logits = model.prefill(params, {"tokens": toks}, cache)
    print(f"[prefill] {B}x{P} in {(time.perf_counter()-t0)*1e3:.0f} ms")

    decode = jax.jit(model.decode_step)
    nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for _ in range(args.tokens):
        logits, cache = decode(params, cache, nxt)
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(nxt)
    dt = time.perf_counter() - t0
    print(f"[decode] {args.tokens} steps x {B} reqs: "
          f"{B*args.tokens/dt:.0f} tok/s")


if __name__ == "__main__":
    main()
