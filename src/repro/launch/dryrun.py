import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh, prove it fits (memory_analysis) and extract roofline
inputs (cost_analysis + collective bytes from the optimized HLO).

The two lines above MUST precede every other import — jax locks the device
count at first initialization.  This module is the ONLY place the 512
placeholder devices exist; tests and benches see one device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3_12b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ARCH_IDS, SHAPES, ArchConfig, ShapeSpec,
                                get_config, input_specs)
from repro.launch.abstract import (abstract_cache, abstract_opt_state,
                                   abstract_params, batch_axes,
                                   eval_shape_with_axes)
from repro.launch.mesh import make_production_mesh
from repro.models.model import DecoderLM
from repro.parallel.sharding import (default_rules, named_sharding,
                                     sharding_ctx, tree_shardings)
from repro.training import optimizer as opt_mod
from repro.training.trainer import make_serve_step, make_train_step

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", ".."))


def choose_optimizer(cfg: ArchConfig):
    """AdamW by default; Adafactor for the O(100B) MoE (opt-state memory)."""
    if cfg.param_count() > 1e11:
        return opt_mod.adafactor(lr=1e-2), "adafactor"
    return opt_mod.adamw(lr=3e-4), "adamw"


def replicated(mesh):
    from jax.sharding import NamedSharding, PartitionSpec
    return NamedSharding(mesh, PartitionSpec())


def microbatch_policy(cfg: ArchConfig) -> int:
    """Grad-accumulation factor for train_4k: sized so per-microbatch
    activations fit HBM (production knob, exercised by the dry-run)."""
    if cfg.d_model >= 3000:
        return 8
    if cfg.d_model >= 1500:
        return 4
    return 2


def _compile_one(cfg: ArchConfig, shape: ShapeSpec, mesh, rules, opt,
                 n_micro: int = 1):
    """Lower + compile one program; returns (compiled, timings)."""
    model = DecoderLM(cfg, remat=shape.is_train)
    t0 = time.perf_counter()
    with sharding_ctx(mesh, rules):
        p_abs, p_axes = abstract_params(model)
        p_sh = tree_shardings(p_abs, p_axes, mesh, rules)
        in_abs = dict(input_specs(cfg, shape))
        b_axes = batch_axes(cfg, shape)
        if shape.is_train and n_micro > 1:
            in_abs = {k: jax.ShapeDtypeStruct(
                (n_micro, v.shape[0] // n_micro) + v.shape[1:], v.dtype)
                for k, v in in_abs.items()}
            b_axes = {k: (None,) + ax for k, ax in b_axes.items()}
        in_sh = {k: named_sharding(v.shape, b_axes[k], mesh, rules)
                 for k, v in in_abs.items()}
        rng_abs = jax.eval_shape(lambda: jax.random.PRNGKey(0))

        if shape.is_train:
            o_abs, o_axes = abstract_opt_state(opt, p_abs, p_axes)
            o_sh = tree_shardings(o_abs, o_axes, mesh, rules)
            ts = make_train_step(model, opt, n_microbatches=n_micro,
                                 param_axes=p_axes)

            def step(params, opt_state, batch, rng):
                p, o, _, metrics = ts(params, opt_state, None, batch, rng)
                return p, o, metrics

            fn = jax.jit(step, in_shardings=(p_sh, o_sh, in_sh, replicated(mesh)),
                         donate_argnums=(0, 1))
            args = (p_abs, o_abs, in_abs, rng_abs)
        elif shape.kind == "prefill":
            c_abs, c_axes = abstract_cache(model, shape.global_batch,
                                           shape.seq_len)
            c_sh = tree_shardings(c_abs, c_axes, mesh, rules)

            def prefill(params, cache, batch):
                return model.prefill(params, batch, cache)

            fn = jax.jit(prefill, in_shardings=(p_sh, c_sh, in_sh),
                         donate_argnums=(1,))
            args = (p_abs, c_abs, in_abs)
        else:  # decode / long_decode: serve_step over a seq_len-deep cache
            c_abs, c_axes = abstract_cache(model, shape.global_batch,
                                           shape.seq_len)
            c_sh = tree_shardings(c_abs, c_axes, mesh, rules)
            serve = make_serve_step(model)
            tok_sh = named_sharding((shape.global_batch, 1), ("batch", None),
                                    mesh, rules)
            fn = jax.jit(serve, in_shardings=(p_sh, c_sh, tok_sh),
                         donate_argnums=(1,))
            args = (p_abs, c_abs, in_abs["tokens"])

        lowered = fn.lower(*args)
        lower_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        compiled = lowered.compile()
        compile_s = time.perf_counter() - t1
    return compiled, {"lower_s": round(lower_s, 2),
                      "compile_s": round(compile_s, 2)}


def _cost_and_collectives(compiled) -> Dict[str, Any]:
    import benchmarks.roofline as rl
    out: Dict[str, Any] = {}
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    out["cost"] = {"flops": float(ca.get("flops", 0.0)),
                   "bytes accessed": float(ca.get("bytes accessed", 0.0)),
                   "transcendentals": float(ca.get("transcendentals", 0.0))}
    hlo = compiled.as_text()
    out["collectives"] = rl.collective_summary(rl.parse_collectives(hlo))
    out["hlo_bytes"] = len(hlo)
    return out


def _memory_analysis(compiled) -> Dict[str, Any]:
    mem: Dict[str, Any] = {}
    ma = compiled.memory_analysis()
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        if hasattr(ma, k):
            mem[k] = int(getattr(ma, k))
    mem["per_device_total"] = (
        mem.get("argument_size_in_bytes", 0)
        - mem.get("alias_size_in_bytes", 0)
        + mem.get("output_size_in_bytes", 0)
        + mem.get("temp_size_in_bytes", 0))
    return mem


def _lerp_costs(c1: Dict[str, Any], c2: Dict[str, Any], n_super: int
                ) -> Dict[str, Any]:
    """Linear extrapolation: total = L1 + (n-1)*(L2-L1) for every additive
    cost term (flops, bytes, collective link bytes...)."""
    def ext(a, b):
        # clamp: boundary-only costs (e.g. one-off all-to-alls) can make the
        # per-superblock delta negative, which must not extrapolate below 0.
        return max(a + (n_super - 1) * (b - a), 0.0)

    cost = {k: ext(c1["cost"][k], c2["cost"][k]) for k in c1["cost"]}
    col1, col2 = c1["collectives"], c2["collectives"]
    coll = {
        "link_bytes": ext(col1["link_bytes"], col2["link_bytes"]),
        "dcn_bytes": ext(col1["dcn_bytes"], col2["dcn_bytes"]),
        "count": col2["count"],
        "promoted_count": col2.get("promoted_count", 0),
        "by_kind": {k: ext(col1["by_kind"].get(k, 0.0),
                           col2["by_kind"].get(k, 0.0))
                    for k in set(col1["by_kind"]) | set(col2["by_kind"])},
    }
    return {"cost": cost, "collectives": coll}


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False,
               rules=None, extra: Optional[Dict[str, Any]] = None,
               skip_probe: bool = False) -> Dict[str, Any]:
    """Lower+compile one (arch, shape, mesh) cell; return the record.

    Three compilations: the FULL scanned program (compile-success proof +
    memory analysis) and 1-/2-superblock unrolled probes whose cost delta
    gives exact per-superblock FLOPs/bytes/collectives (XLA cost analysis
    counts a while-loop body once regardless of trip count, so the scanned
    program's raw numbers undercount; see EXPERIMENTS.md §Method).
    """
    cfg = get_config(arch)
    if extra:
        cfg = dataclasses.replace(cfg, **extra)
    shape = SHAPES[shape_name]
    ok, why = cfg.supports_shape(shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
    }
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules or default_rules()
    opt, opt_name = choose_optimizer(cfg)
    rec["optimizer"] = opt_name

    # 1) full program: the dry-run proof + memory analysis (microbatched for
    #    train shapes — the production grad-accumulation configuration)
    n_micro = microbatch_policy(cfg) if shape.is_train else 1
    rec["n_microbatches"] = n_micro
    compiled, times = _compile_one(cfg, shape, mesh, rules, opt,
                                   n_micro=n_micro)
    rec.update(times)
    try:
        rec["memory"] = _memory_analysis(compiled)
    except Exception as e:          # pragma: no cover
        rec["memory"] = {"error": repr(e)}
    raw = _cost_and_collectives(compiled)
    rec["raw_scanned"] = {"cost": raw["cost"],
                          "collectives": raw["collectives"]}
    rec["hlo_bytes"] = raw["hlo_bytes"]
    del compiled

    # 2) cost probes at 1 and 2 superblocks (unrolled — including the inner
    #    attention/CE chunk loops, so cost_analysis counts every chunk; the
    #    full program above keeps lax.map for O(1) HLO size)
    n_super = cfg.n_superblocks
    per = len([k for k in cfg.pattern if k != "shared_attn"]) or 1
    if not skip_probe and n_super > 2:
        from repro.models import layers as layers_mod
        cfg1 = dataclasses.replace(cfg, n_layers=per)
        cfg2 = dataclasses.replace(cfg, n_layers=2 * per)
        layers_mod.FORCE_UNROLL_CHUNKS = True
        # Cap the unroll at 8 chunks by coarsening the probe's q-chunk (the
        # 32-chunk prefill probes otherwise take >10 min EACH to compile on
        # this 1-core container).  Honesty tradeoff, documented in
        # EXPERIMENTS.md §Method: causal banding is counted at the nc=8
        # average (0.5625*T vs production nc=32's 0.516*T — a ~9% OVERcount
        # of causal score bytes), while local-window bands are counted at
        # (C+w)/C per row vs production's (1024+w)/1024 — an undercount for
        # w < C; both bounded and consistent across cells.
        old_qc = layers_mod.Q_CHUNK
        layers_mod.Q_CHUNK = max(1024, shape.seq_len // 8)
        try:
            comp1, t1 = _compile_one(cfg1, shape, mesh, rules, opt)
            c1 = _cost_and_collectives(comp1)
            del comp1
            comp2, t2 = _compile_one(cfg2, shape, mesh, rules, opt)
            c2 = _cost_and_collectives(comp2)
            del comp2
        finally:
            layers_mod.FORCE_UNROLL_CHUNKS = False
            layers_mod.Q_CHUNK = old_qc
        rec["probe_compile_s"] = round(t1["compile_s"] + t2["compile_s"], 2)
        ext = _lerp_costs(c1, c2, n_super)
        rec["cost"] = ext["cost"]
        rec["collectives"] = ext["collectives"]
    else:
        rec["cost"] = raw["cost"]
        rec["collectives"] = raw["collectives"]

    import benchmarks.roofline as rl
    rec["roofline"] = rl.roofline_terms(
        rec["cost"], rec["collectives"], cfg, shape, rec["chips"])
    rec["status"] = "ok"
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}.{shape}.{'multi' if mp else 'single'}"
            try:
                rec = lower_cell(arch, shape, multi_pod=mp)
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "status": "error",
                       "mesh": "2x16x16" if mp else "16x16",
                       "error": repr(e),
                       "traceback": traceback.format_exc()}
                failures += 1
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=1)
            status = rec["status"]
            extra = ""
            if status == "ok":
                mem = rec["memory"].get("per_device_total", 0) / 2**30
                dom = rec.get("roofline", {}).get("dominant", "?")
                extra = (f" mem/dev={mem:.2f}GiB flops={rec['cost']['flops']:.2e}"
                         f" dominant={dom}"
                         f" compile={rec['compile_s']}s")
            elif status == "skipped":
                extra = f" ({rec['reason'][:60]})"
            else:
                extra = f" ERROR {rec['error'][:120]}"
            print(f"[{status:7s}] {tag}{extra}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
