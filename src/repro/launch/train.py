"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch <id> [--steps N]
      [--smoke] [--dry-run] [--mesh 16x16|2x16x16] [--compression int8|topk]

Modes:
  --smoke    (default on CPU) run the REDUCED config for N real steps on the
             local devices — the same train_step, optimizer, checkpoint and
             control-plane path as production, just small.
  --dry-run  lower + compile the FULL config for the production mesh and
             print memory/cost analysis (delegates to repro.launch.dryrun).
  full       on a real TPU slice (jax.default_backend() == 'tpu') the full
             config runs on the production mesh with FSDP/TP sharding.

The control plane (Fast Flexible Paxos, n=11) commits checkpoint manifests,
data cursors, and straggler verdicts in all modes.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default=None, choices=[None, "int8", "topk"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    if args.dry_run:
        # Re-exec through the dryrun module so XLA_FLAGS is set before any
        # jax import (device count locks at first init).
        import os
        import subprocess
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape]
        if args.multi_pod:
            cmd.append("--multi-pod")
        raise SystemExit(subprocess.call(cmd, env=dict(
            os.environ, PYTHONPATH="src:.")))

    import jax

    from repro.cluster.coordinator import ControlPlane
    from repro.configs import get_config, reduced_config
    from repro.core.quorum import QuorumSpec
    from repro.models.model import DecoderLM
    from repro.training.data import DataConfig, SyntheticPipeline
    from repro.training.optimizer import adamw, cosine_schedule
    from repro.training.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    on_tpu = jax.default_backend() == "tpu"
    if args.smoke or not on_tpu:
        cfg = reduced_config(cfg)
        print(f"[smoke] {args.arch} reduced to d_model={cfg.d_model} "
              f"n_layers={cfg.n_layers} vocab={cfg.vocab}")

    if cfg.frontend:
        print(f"[note] {args.arch} uses a stub frontend ({cfg.frontend}); "
              "the smoke loop trains the backbone on token batches.")
        cfg = dataclasses.replace(cfg, frontend=None)

    model = DecoderLM(cfg, remat=True)
    pipe = SyntheticPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))
    plane = ControlPlane(QuorumSpec.paper_headline(11), seed=0)
    tr = Trainer(model, adamw(lr=1e-3, schedule=cosine_schedule(warmup=10, total=1000)), pipe,
                 TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=10,
                               n_microbatches=args.microbatches,
                               compression=args.compression),
                 plane=plane)
    tr.init(jax.random.PRNGKey(0))
    if tr.try_restore():
        print(f"[resume] restored step {tr.step} cursor {tr.cursor}")
    n_params = sum(x.size for x in jax.tree.leaves(tr.params))
    print(f"[train] {n_params/1e6:.1f}M params, {args.steps} steps, "
          f"devices={jax.device_count()}")
    for _ in range(args.steps):
        m = tr.run(1)
        if tr.step % 5 == 0:
            print(f"  step {tr.step:4d} loss {m['loss']:.4f} "
                  f"grad_norm {m['grad_norm']:.3f} "
                  f"({m['step_s']*1e3:.0f} ms)")
    tr.save()
    print(f"[done] final loss {tr.history[-1]['loss']:.4f}; "
          f"manifest committed via control plane "
          f"(step {plane.latest_checkpoint()['step']})")


if __name__ == "__main__":
    main()
