"""Failure detection and straggler mitigation for the training cluster.

* ``PhiAccrualDetector`` — the standard phi-accrual detector (Hayashibara et
  al.) over heartbeat inter-arrival times; hosts whose phi exceeds the
  threshold are *suspected* and proposed for eviction through the consensus
  control plane (the eviction itself is an epoch change, so all hosts agree
  on the survivor set before re-forming the mesh).

* ``StragglerPolicy`` — per-step host timing statistics; hosts slower than
  ``quantile + k * IQR`` for ``patience`` consecutive steps receive a
  consensus-committed verdict (``"demote"``: drop from the data-parallel
  group at the next epoch; ``"duplicate"``: backup-task its shard).  Using
  the *fast path* for verdicts means any host can raise one without routing
  through a leader — exactly the paper's leaderless-commit use case — and
  racing verdicts for the same step collapse to one decision via the
  collision-recovery path.
"""
from __future__ import annotations

import math
import statistics
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from .coordinator import ControlPlane


class PhiAccrualDetector:
    """Phi-accrual failure detector over heartbeat arrival times."""

    def __init__(self, threshold: float = 8.0, window: int = 100,
                 min_std_ms: float = 5.0) -> None:
        self.threshold = threshold
        self.window = window
        self.min_std_ms = min_std_ms
        self._arrivals: Dict[int, Deque[float]] = defaultdict(
            lambda: deque(maxlen=window))
        self._last: Dict[int, float] = {}

    def heartbeat(self, host: int, t_ms: float) -> None:
        if host in self._last:
            self._arrivals[host].append(t_ms - self._last[host])
        self._last[host] = t_ms

    def phi(self, host: int, now_ms: float) -> float:
        if host not in self._last or len(self._arrivals[host]) < 2:
            return 0.0
        gaps = list(self._arrivals[host])
        mean = statistics.fmean(gaps)
        # Floor the std at 20% of the mean interval: perfectly regular
        # heartbeats would otherwise make any jitter look like death.
        std = max(statistics.pstdev(gaps), self.min_std_ms, 0.2 * mean)
        elapsed = now_ms - self._last[host]
        # phi = -log10 P(gap > elapsed) under Normal(mean, std)
        z = (elapsed - mean) / std
        p_later = 0.5 * math.erfc(z / math.sqrt(2.0))
        return -math.log10(max(p_later, 1e-300))

    def suspected(self, hosts: Sequence[int], now_ms: float) -> List[int]:
        return [h for h in hosts if self.phi(h, now_ms) > self.threshold]


@dataclass
class StragglerPolicy:
    """Quantile-gap straggler detection over per-host step durations."""

    plane: ControlPlane
    k_iqr: float = 3.0
    patience: int = 3
    _strikes: Dict[int, int] = field(default_factory=lambda: defaultdict(int))

    def observe_step(self, step: int, host_times_ms: Dict[int, float],
                     reporter: int = 0) -> Optional[List[int]]:
        """Feed one step's per-host durations; returns hosts verdicted slow
        (and commits the verdict through consensus), else None."""
        times = sorted(host_times_ms.values())
        if len(times) < 4:
            return None
        q1 = times[len(times) // 4]
        q3 = times[(3 * len(times)) // 4]
        cutoff = q3 + self.k_iqr * max(q3 - q1, 1e-6)
        slow = [h for h, t in host_times_ms.items() if t > cutoff]
        for h in host_times_ms:
            if h in slow:
                self._strikes[h] += 1
            else:
                self._strikes[h] = 0
        verdicted = [h for h in slow if self._strikes[h] >= self.patience]
        if not verdicted:
            return None
        self.plane.commit_straggler_verdict(step, verdicted, action="demote",
                                            host=reporter)
        for h in verdicted:
            self._strikes[h] = 0
        return verdicted
