from .coordinator import ConsensusLog, ControlPlane
from .membership import MembershipEpoch, MembershipManager
from .failure import PhiAccrualDetector, StragglerPolicy

__all__ = [
    "ConsensusLog", "ControlPlane",
    "MembershipEpoch", "MembershipManager",
    "PhiAccrualDetector", "StragglerPolicy",
]
