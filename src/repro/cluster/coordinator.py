"""Consensus-backed control plane for the training framework.

``ConsensusLog`` is a replicated log whose every slot is decided by Fast
Flexible Paxos — the paper's technique as a first-class feature.  Training
hosts commit *cluster events* (checkpoint manifests, membership epochs,
data-pipeline cursors, straggler verdicts) leaderlessly on the fast path:
any host proposes directly to the acceptor group and the event commits after
one round trip to a **q2f** quorum (7 of 11 under the paper's headline
config, vs Fast Paxos' 9 of 11).  Collisions — two hosts proposing different
events for the same slot — are resolved by coordinated recovery exactly as in
``repro.core.protocol``; the loser's event is re-proposed on the next slot.

Transport here is in-process and deterministic (this container is a single
host); delivery order and acceptor failures are injectable so tests can force
every conflict/recovery path.  The protocol state machines are the same ones
validated by the TLC-lite model checker.
"""
from __future__ import annotations

import json
import random
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.protocol import (ANY, Acceptor, Learner, Phase1b, Phase2a,
                                 Phase2b, RoundSystem, choose_value,
                                 pick_values)
from repro.core.quorum import QuorumSpec


@dataclass
class SlotOutcome:
    slot: int
    value: Any
    fast: bool                 # decided on the fast path?
    recovered: bool            # went through coordinated recovery?
    votes: Dict[int, Any]      # acceptor -> round-1 vote (diagnostics)

    @property
    def outcome(self) -> str:
        return "fast" if self.fast else (
            "recovered" if self.recovered else "failed")


class ConsensusLog:
    """A replicated log; each slot is one Fast Flexible Paxos instance.

    Steady state mirrors §6: a stable coordinator has pre-executed phase-1
    with the ``any`` value for every slot, so proposals go straight to the
    acceptors (round 1, fast).  Recovery runs in round 2 (classic).
    """

    def __init__(self, spec: QuorumSpec, seed: int = 0) -> None:
        self.spec = spec.validate()
        self.rs = RoundSystem(spec, n_coordinators=1, fast_rounds="odd")
        self.rng = random.Random(seed)
        self.n = spec.n
        self.crashed: Set[int] = set()
        # acceptor round-1 vote per slot: slot -> {acc: value}
        self._votes: Dict[int, Dict[int, Any]] = {}
        self.decided: Dict[int, SlotOutcome] = {}
        self.next_slot = 0
        self.stats = {"fast": 0, "recovered": 0, "aborted_proposals": 0}

    # ------------------------------------------------------------------ api
    def crash(self, acc: int) -> None:
        self.crashed.add(acc)

    def recover_node(self, acc: int) -> None:
        self.crashed.discard(acc)

    def live(self) -> List[int]:
        return [a for a in range(self.n) if a not in self.crashed]

    def propose(self, value: Any, slot: Optional[int] = None) -> SlotOutcome:
        """Propose ``value`` on the fast path; returns the slot outcome (which
        may carry a *different* value if we lost a race for the slot)."""
        out = self.propose_racing([value], slot=slot)
        return out

    def propose_racing(self, values: Sequence[Any], slot: Optional[int] = None,
                       arrival_orders: Optional[Sequence[Sequence[int]]] = None
                       ) -> SlotOutcome:
        """Deliver several racing proposals for one slot.

        ``arrival_orders[i]`` is the order in which proposal i reaches the
        acceptors; interleaving is round-robin over proposals (deterministic,
        injectable) so tests can force exact vote splits.
        """
        s = self.next_slot if slot is None else slot
        if s in self.decided:
            self.stats["aborted_proposals"] += len(values)
            return self.decided[s]
        if slot is None:
            self.next_slot += 1

        votes = self._votes.setdefault(s, {})
        live = self.live()
        orders = (list(arrival_orders) if arrival_orders is not None
                  else [self.rng.sample(live, len(live)) for _ in values])
        # Round-robin interleaved delivery: proposal i's next acceptor, etc.
        idx = [0] * len(values)
        progressed = True
        while progressed:
            progressed = False
            for i, v in enumerate(values):
                if idx[i] < len(orders[i]):
                    a = orders[i][idx[i]]
                    idx[i] += 1
                    progressed = True
                    if a not in self.crashed and a not in votes:
                        votes[a] = v          # first proposal wins the vote

        outcome = self._learn(s, votes, values)
        if outcome is None:
            raise RuntimeError(
                f"slot {s}: no value can commit and recovery lacks a phase-1 "
                f"quorum ({len(votes)} < q1={self.spec.q1}) — cluster has "
                f"lost liveness; repair acceptors or reconfigure")
        self.decided[s] = outcome
        return outcome

    # ------------------------------------------------------------- internals
    def _learn(self, slot: int, votes: Dict[int, Any],
               proposed: Sequence[Any]) -> Optional[SlotOutcome]:
        learner = Learner(self.rs)
        decided = None
        for a, v in votes.items():
            decided = learner.on_phase2b(Phase2b(1, v, a)) or decided
        if decided is not None:
            self.stats["fast"] += 1
            return SlotOutcome(slot, decided, fast=True, recovered=False,
                               votes=dict(votes))
        # Coordinated recovery (round 2, classic): round-1 votes double as
        # round-2 phase-1b messages; pick per IsPickableVal; commit with q2c.
        if len(votes) < self.rs.q1(2):
            return None
        msgs = [Phase1b(2, 1, v, a) for a, v in votes.items()]
        picks = pick_values(self.rs, 2, msgs, set(proposed)) - {ANY}
        v = choose_value(picks)
        acks = [a for a in self.live()][: self.rs.q2(2)]
        if len(acks) < self.rs.q2(2):
            return None
        self.stats["recovered"] += 1
        return SlotOutcome(slot, v, fast=False, recovered=True,
                           votes=dict(votes))


# ---------------------------------------------------------------------------
# Typed control-plane records.
# ---------------------------------------------------------------------------

def _record(kind: str, **payload: Any) -> str:
    """Records are canonical JSON strings (hashable: consensus values must be)."""
    return json.dumps({"kind": kind, **payload}, sort_keys=True)


def _parse(rec: str) -> Dict[str, Any]:
    return json.loads(rec)


class ControlPlane:
    """Materialized view over a ``ConsensusLog`` with typed events.

    This is the single source of truth for the training cluster: checkpoint
    manifests, membership epochs, data cursors, and straggler verdicts all
    commit through the paper's fast path before any host acts on them.
    """

    def __init__(self, spec: QuorumSpec, seed: int = 0) -> None:
        self.log = ConsensusLog(spec, seed=seed)

    # -- checkpoints --------------------------------------------------------
    def commit_checkpoint(self, step: int, shards: Dict[str, str],
                          data_cursor: int, host: int = 0) -> SlotOutcome:
        rec = _record("checkpoint", step=step, shards=shards,
                      data_cursor=data_cursor, host=host)
        return self.log.propose(rec)

    def latest_checkpoint(self) -> Optional[Dict[str, Any]]:
        return self._latest("checkpoint")

    # -- membership ---------------------------------------------------------
    def commit_epoch(self, epoch: int, hosts: Sequence[int],
                     mesh_shape: Sequence[int], host: int = 0) -> SlotOutcome:
        rec = _record("epoch", epoch=epoch, hosts=sorted(hosts),
                      mesh_shape=list(mesh_shape), host=host)
        return self.log.propose(rec)

    def current_epoch(self) -> Optional[Dict[str, Any]]:
        return self._latest("epoch")

    # -- data pipeline cursors ----------------------------------------------
    def commit_cursor(self, step: int, cursor: int, host: int = 0) -> SlotOutcome:
        return self.log.propose(_record("cursor", step=step, cursor=cursor,
                                        host=host))

    def latest_cursor(self) -> Optional[Dict[str, Any]]:
        return self._latest("cursor")

    # -- straggler verdicts ---------------------------------------------------
    def commit_straggler_verdict(self, step: int, slow_hosts: Sequence[int],
                                 action: str, host: int = 0) -> SlotOutcome:
        return self.log.propose(_record("straggler", step=step,
                                        slow_hosts=sorted(slow_hosts),
                                        action=action, host=host))

    # -- generic -------------------------------------------------------------
    def _latest(self, kind: str) -> Optional[Dict[str, Any]]:
        best = None
        for slot in sorted(self.log.decided):
            rec = _parse(self.log.decided[slot].value)
            if rec["kind"] == kind:
                best = rec | {"slot": slot}
        return best

    def history(self) -> List[Dict[str, Any]]:
        return [_parse(self.log.decided[s].value) | {"slot": s}
                for s in sorted(self.log.decided)]
