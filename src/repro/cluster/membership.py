"""Elastic cluster membership driven by consensus.

Membership changes (scale-up, scale-down, failure eviction) are *epochs*
committed through the Fast Flexible Paxos control plane.  Every epoch fixes:

* the live host set,
* the device mesh shape the trainer should build (largest (data, model) grid
  that fits the hosts, model axis preserved — elastic data parallelism),
* the quorum spec of the *acceptor group itself* when acceptors change,
  recomputed from the paper's Eqs. 13/14 so the relaxed intersection
  requirements hold at every size.

Hosts act on an epoch only after its commit — a host that misses the commit
keeps training on the old epoch until it observes the new one, and the
gradient all-reduce membership is keyed by epoch id so mixed-epoch steps
cannot silently aggregate.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.quorum import QuorumSpec, ffp_min_q2c, ffp_min_q2f

from .coordinator import ControlPlane


def quorum_policy(n: int) -> QuorumSpec:
    """The paper's §5 tradeoff applied as policy: spend a large phase-1
    quorum (rare) to buy the smallest valid phase-2 quorums (hot path).

    q1 = n - floor(n/4)   (tolerates n/4 crashes for recovery)
    q2f, q2c = minimal per Eqs. 14/13.
    """
    if n < 3:
        raise ValueError("need >= 3 acceptors")
    q1 = n - max(1, n // 4)
    return QuorumSpec(n, q1, ffp_min_q2c(n, q1), ffp_min_q2f(n, q1)).validate()


def plan_mesh(n_hosts: int, model_parallel: int, devices_per_host: int = 4
              ) -> Tuple[int, int]:
    """Largest (data, model) mesh for the host count; model axis fixed by the
    architecture's sharding needs, data axis absorbs elasticity."""
    total = n_hosts * devices_per_host
    if total < model_parallel:
        raise ValueError(f"{total} devices cannot host model_parallel={model_parallel}")
    return total // model_parallel, model_parallel


@dataclass
class MembershipEpoch:
    epoch: int
    hosts: Tuple[int, ...]
    mesh_shape: Tuple[int, int]
    quorums: QuorumSpec


class MembershipManager:
    """Drives epochs through the control plane and exposes the current view."""

    def __init__(self, plane: ControlPlane, initial_hosts: Sequence[int],
                 model_parallel: int = 16, devices_per_host: int = 4) -> None:
        self.plane = plane
        self.model_parallel = model_parallel
        self.devices_per_host = devices_per_host
        self._epoch = 0
        self.commit(sorted(initial_hosts))

    # ------------------------------------------------------------------ api
    def commit(self, hosts: Sequence[int]) -> MembershipEpoch:
        hosts = sorted(set(hosts))
        self._epoch += 1
        mesh = plan_mesh(len(hosts), self.model_parallel, self.devices_per_host)
        out = self.plane.commit_epoch(self._epoch, hosts, mesh)
        # The committed record is authoritative — a racing epoch proposal may
        # have won the slot; re-read the view.
        return self.current()

    def scale_up(self, new_hosts: Sequence[int]) -> MembershipEpoch:
        cur = self.current()
        return self.commit(list(cur.hosts) + list(new_hosts))

    def scale_down(self, remove: Sequence[int]) -> MembershipEpoch:
        cur = self.current()
        keep = [h for h in cur.hosts if h not in set(remove)]
        return self.commit(keep)

    def evict_failed(self, failed: Sequence[int]) -> MembershipEpoch:
        return self.scale_down(failed)

    def current(self) -> MembershipEpoch:
        rec = self.plane.current_epoch()
        assert rec is not None, "no membership epoch committed yet"
        hosts = tuple(rec["hosts"])
        n_acc = max(3, min(len(hosts), 11))   # acceptor group: <=11 hosts
        return MembershipEpoch(
            epoch=rec["epoch"],
            hosts=hosts,
            mesh_shape=tuple(rec["mesh_shape"]),
            quorums=quorum_policy(n_acc),
        )
