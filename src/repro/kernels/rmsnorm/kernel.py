"""Pallas TPU fused RMSNorm: one HBM read + one write per row (the unfused
XLA path reads x twice — once for the moment, once for the scale-multiply —
unless the fusion pass catches it; the kernel makes the fusion structural).

Grid over row blocks; each block (BLOCK_R, D) is normalized entirely in VMEM.
D is assumed lane-aligned (all assigned archs have d_model % 128 == 0; the
wrapper pads otherwise).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 256


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float, d: int):
    x = x_ref[...].astype(jnp.float32)                  # (R, Dp)
    dp = x.shape[-1]
    if dp != d:                                          # masked mean for pad
        col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
        x = jnp.where(col < d, x, 0.0)
    ms = jnp.sum(jnp.square(x), axis=-1, keepdims=True) / d
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
            interpret: bool = True) -> jax.Array:
    orig_shape = x.shape
    d = orig_shape[-1]
    xr = x.reshape(-1, d)
    R = xr.shape[0]
    rpad = (-R) % BLOCK_R
    dpad = (-d) % 128
    if rpad or dpad:
        xr = jnp.pad(xr, ((0, rpad), (0, dpad)))
    sc = jnp.pad(scale, (0, dpad)) if dpad else scale
    dp = d + dpad

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps, d=d),
        grid=(xr.shape[0] // BLOCK_R,),
        in_specs=[pl.BlockSpec((BLOCK_R, dp), lambda i: (i, 0)),
                  pl.BlockSpec((dp,), lambda i: (0,))],
        out_specs=pl.BlockSpec((BLOCK_R, dp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, x.dtype),
        interpret=interpret,
    )(xr, sc)
    return out[:R, :d].reshape(orig_shape)
