"""Jitted public wrapper for fused RMSNorm."""
from __future__ import annotations

import jax

from . import kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def rmsnorm(x, scale, eps: float = 1e-6):
    return kernel.rmsnorm(x, scale, eps=eps, interpret=not _on_tpu())
