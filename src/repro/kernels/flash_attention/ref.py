"""Pure-jnp oracle for blockwise (flash) attention."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import jax

NEG_INF = -2.0e38


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = True, window: Optional[int] = None) -> jnp.ndarray:
    """q (B,H,S,hd), k/v (B,KV,T,hd) with H % KV == 0 -> (B,H,S,hd).

    Softmax in f32; causal assumes queries are the last S positions of the
    T-long key sequence (q position i corresponds to absolute T - S + i).
    """
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    rep = H // KV
    kf = jnp.repeat(k, rep, axis=1)
    vf = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhsd,bhtd->bhst", q, kf,
                   preferred_element_type=jnp.float32) / jnp.sqrt(
                       jnp.float32(hd))
    q_pos = jnp.arange(S) + (T - S)
    k_pos = jnp.arange(T)
    ok = k_pos[None, :] <= q_pos[:, None]
    if not causal:
        ok = jnp.ones_like(ok)
    if window is not None:
        ok &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(ok[None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w.astype(q.dtype), vf)
