"""Jitted public wrapper for flash attention (interpret on CPU, native on TPU)."""
from __future__ import annotations

from typing import Optional

import jax

from . import kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              causal: bool = True, window: Optional[int] = None,
              block_q: int = 512, block_k: int = 512) -> jax.Array:
    return kernel.flash_attention(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=not _on_tpu())
