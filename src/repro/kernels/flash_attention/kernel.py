"""Pallas TPU flash attention (forward), GQA-aware, causal + sliding window.

Tiling: grid = (B*H, S/block_q, T/block_k); the kv axis is minor-most so each
(batch-head, q-block) accumulates over kv blocks sequentially on-core with
running-softmax statistics in VMEM scratch (the standard TPU flash pattern —
HBM traffic is O(S*hd + T*hd) per head instead of O(S*T)).

GQA: the kv BlockSpec index_map folds the query head onto its kv group
(h // (H/KV)), so kv heads are never materialized H-wide in HBM.

VMEM working set per step (block_q=block_k=512, hd=256, f32):
q 512x256x4 = 512 KiB, k/v 2x512 KiB, scores 512x512x4 = 1 MiB,
acc+stats ~0.6 MiB — ~3 MiB total, well under the ~16 MiB v5e budget.

Causal masking is positional (absolute position = q_offset + row), matching
the convention that queries are the last S positions of the T-long key
sequence (covers both self-attention S == T and decode-style S < T).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, block_q: int, block_k: int,
                  causal: bool, window: Optional[int], q_offset: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = q_offset + iq * block_q
    k_start = ik * block_k
    # Skip kv blocks fully in the causal future of this q block.
    run = jnp.asarray(True)
    if causal:
        run = k_start <= q_start + block_q - 1
    if window is not None:
        run = jnp.logical_and(
            run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)                 # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                 # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)

        qp = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kp = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        ok = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            ok &= kp <= qp
        if window is not None:
            ok &= (qp - kp) < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _fin():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = True) -> jax.Array:
    """q (B,H,S,hd), k/v (B,KV,T,hd) -> (B,H,S,hd)."""
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    assert H % KV == 0
    rep = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    scale = 1.0 / (hd ** 0.5)
    q_offset = T - S

    qr = q.reshape(B * H, S, hd)
    kr = k.reshape(B * KV, T, hd)
    vr = v.reshape(B * KV, T, hd)
    grid = (B * H, S // block_q, T // block_k)

    def q_index(bh, iq, ik):
        return (bh, iq, 0)

    def kv_index(bh, iq, ik):
        b, h = bh // H, bh % H
        return (b * KV + h // rep, ik, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, causal=causal, window=window,
                          q_offset=q_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), q_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), q_index),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, S, hd)
