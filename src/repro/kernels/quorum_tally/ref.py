"""Pure-jnp oracle for the quorum vote tally."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tally_votes(votes: jnp.ndarray, n_values: int) -> jnp.ndarray:
    """Count votes per value.

    votes: (S, n) integer array, entries in [0, n_values).
    returns: (S, n_values) int32 counts.
    """
    one_hot = (votes[..., None] == jnp.arange(n_values, dtype=votes.dtype))
    return one_hot.sum(axis=-2).astype(jnp.int32)


def quorum_reached(votes: jnp.ndarray, n_values: int, q: int) -> jnp.ndarray:
    """(S,) bool: some value gathered >= q votes."""
    return (tally_votes(votes, n_values) >= q).any(axis=-1)


def tally_decide(votes: jnp.ndarray, n_values: int, q) -> tuple:
    """Oracle for the fused tally+decide kernel.

    Returns (counts (S, V) int32, winner (S,) int32 argmax count with
    first-max tie-break, max_count (S,) int32, reached (S,) bool
    max count >= q)."""
    counts = tally_votes(votes, n_values)
    winner = counts.argmax(axis=-1).astype(jnp.int32)
    max_count = counts.max(axis=-1)
    return counts, winner, max_count, max_count >= q


def masked_tally(votes: jnp.ndarray, weights: jnp.ndarray,
                 thresholds: jnp.ndarray, n_values: int) -> jnp.ndarray:
    """Oracle for the masked-tally kernel: per-quorum satisfied value.

    votes:      (S, n) int32, entries in [0, n_values); < 0 means "no vote".
    weights:    (G, n) float32 per-quorum acceptor weights.
    thresholds: (G,)  float32; quorum g is satisfied by value v when the
                weights of the acceptors voting v sum to >= thresholds[g].

    Returns (S, G) int32: the smallest value id satisfying quorum g (at most
    one exists for any system whose fast quorums pairwise intersect), or -1
    when no value does — which is always the case for padding rows
    (zero weights, PAD_THRESHOLD).
    """
    hit = (votes[:, None, :] == jnp.arange(n_values,
                                           dtype=votes.dtype)[None, :, None])
    wsum = jnp.einsum("svn,gn->svg", hit.astype(weights.dtype), weights)
    sat = wsum >= thresholds                               # (S, V, G)
    first = jnp.argmax(sat, axis=1).astype(jnp.int32)      # lowest value id
    return jnp.where(sat.any(axis=1), first, -1)


def stream_tally_decide_hist(votes: jnp.ndarray, w2f: jnp.ndarray,
                             t2f: jnp.ndarray, val_sat: jnp.ndarray,
                             t_rec: jnp.ndarray, valid: jnp.ndarray, *,
                             n_values: int, precision: float, bins: int,
                             undecided_ms: float):
    """Oracle for the fused streaming kernel: masked tally + decide +
    block-local DDSketch histogram, reduced over one chunk of trials.

    votes       (S, n) int32 round-1 votes (< 0 = no vote)
    w2f / t2f   (M, G, n) / (M, G) fast-phase quorum masks per system
    val_sat     (M, S, K) f32 per-value fast-quorum 2b saturation instants
    t_rec       (M, S) f32 coordinated-recovery commit times
    valid       (S,) bool trial-validity mask (False = padding trial)

    Returns ``(hist, stats)``: hist (M, bins) int32 bucket counts over
    *decided* valid trials, stats a dict of per-system (M,) reductions —
    ``n_fast`` / ``n_recovery`` / ``n_undecided`` int32 counts, ``sum_ms``
    f32 decided-latency sum, ``max_ms`` f32 decided-latency max (-inf when
    nothing decided).  Bucketing matches
    ``repro.montecarlo.streaming.bucket_index`` bit-for-bit.
    """
    from repro.montecarlo.streaming import bucket_index
    M, G, n = w2f.shape
    per_q = masked_tally(votes, w2f.reshape(M * G, n), t2f.reshape(M * G),
                         n_values).reshape(-1, M, G)       # (S, M, G)
    nohit = jnp.int32(n_values)
    best = jnp.where(per_q < 0, nohit, per_q).min(axis=-1).T   # (M, S)
    reached = best < nohit
    widx = jnp.clip(best, 0, n_values - 1)
    t_fast = jnp.take_along_axis(val_sat, widx[..., None],
                                 axis=-1)[..., 0]          # (M, S)
    fast_ok = reached & (t_fast < undecided_ms)
    lat = jnp.where(fast_ok, t_fast, t_rec)
    und = lat >= undecided_ms
    v = valid[None, :]
    fast = fast_ok & v
    rec = ~fast_ok & ~und & v
    undv = und & v
    decided = fast | rec
    idx = bucket_index(lat, precision)
    hist = jax.vmap(lambda i, u: jnp.zeros((bins,), jnp.int32).at[i].add(u))(
        idx, decided.astype(jnp.int32))
    stats = {
        "n_fast": fast.sum(axis=-1).astype(jnp.int32),
        "n_recovery": rec.sum(axis=-1).astype(jnp.int32),
        "n_undecided": undv.sum(axis=-1).astype(jnp.int32),
        "sum_ms": jnp.where(decided, lat, 0.0).sum(axis=-1),
        "max_ms": jnp.where(decided, lat, -jnp.inf).max(axis=-1),
    }
    return hist, stats
