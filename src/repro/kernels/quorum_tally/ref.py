"""Pure-jnp oracle for the quorum vote tally."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tally_votes(votes: jnp.ndarray, n_values: int) -> jnp.ndarray:
    """Count votes per value.

    votes: (S, n) integer array, entries in [0, n_values).
    returns: (S, n_values) int32 counts.
    """
    one_hot = (votes[..., None] == jnp.arange(n_values, dtype=votes.dtype))
    return one_hot.sum(axis=-2).astype(jnp.int32)


def quorum_reached(votes: jnp.ndarray, n_values: int, q: int) -> jnp.ndarray:
    """(S,) bool: some value gathered >= q votes."""
    return (tally_votes(votes, n_values) >= q).any(axis=-1)


def tally_decide(votes: jnp.ndarray, n_values: int, q) -> tuple:
    """Oracle for the fused tally+decide kernel.

    Returns (counts (S, V) int32, winner (S,) int32 argmax count with
    first-max tie-break, max_count (S,) int32, reached (S,) bool
    max count >= q)."""
    counts = tally_votes(votes, n_values)
    winner = counts.argmax(axis=-1).astype(jnp.int32)
    max_count = counts.max(axis=-1)
    return counts, winner, max_count, max_count >= q


def masked_tally(votes: jnp.ndarray, weights: jnp.ndarray,
                 thresholds: jnp.ndarray, n_values: int) -> jnp.ndarray:
    """Oracle for the masked-tally kernel: per-quorum satisfied value.

    votes:      (S, n) int32, entries in [0, n_values); < 0 means "no vote".
    weights:    (G, n) float32 per-quorum acceptor weights.
    thresholds: (G,)  float32; quorum g is satisfied by value v when the
                weights of the acceptors voting v sum to >= thresholds[g].

    Returns (S, G) int32: the smallest value id satisfying quorum g (at most
    one exists for any system whose fast quorums pairwise intersect), or -1
    when no value does — which is always the case for padding rows
    (zero weights, PAD_THRESHOLD).
    """
    hit = (votes[:, None, :] == jnp.arange(n_values,
                                           dtype=votes.dtype)[None, :, None])
    wsum = jnp.einsum("svn,gn->svg", hit.astype(weights.dtype), weights)
    sat = wsum >= thresholds                               # (S, V, G)
    first = jnp.argmax(sat, axis=1).astype(jnp.int32)      # lowest value id
    return jnp.where(sat.any(axis=1), first, -1)


def _prefix_sat(x: jnp.ndarray, w: jnp.ndarray, t: jnp.ndarray, k: int,
                big) -> jnp.ndarray:
    """Top-k-prefix masked saturation: min over quorum rows of the earliest
    instant row g of every system crosses its threshold, from *unsorted*
    arrivals.

    x (S, n) f32 arrivals; w (M, G, n) f32 weights; t (M, G) thresholds.
    Only the k smallest arrivals per trial are consulted — exact whenever
    k >= ``engine.saturation_depths`` for this table.  Unreached rows get
    the ``big`` sentinel.  Returns (M, S) f32.
    """
    k = min(int(k), x.shape[-1])
    neg, idx = jax.lax.top_k(-x, k)                        # stable ties
    srt = -neg                                             # (S, k) ascending
    wp = jnp.take(w, idx, axis=2)                          # (M, G, S, k)
    csum = jnp.cumsum(wp, axis=-1)
    ok = csum >= t[:, :, None, None]                       # (M, G, S, k)
    ii = jnp.argmax(ok, axis=-1)                           # first crossing
    reached = ok[..., -1]
    tt = jnp.take_along_axis(
        jnp.broadcast_to(srt, ok.shape), ii[..., None], axis=-1)[..., 0]
    return jnp.where(reached, tt, big).min(axis=1)         # (M, S)


def stream_tally_decide_hist(votes: jnp.ndarray, val_arr: jnp.ndarray,
                             arrive: jnp.ndarray, classic: jnp.ndarray,
                             w1: jnp.ndarray, t1: jnp.ndarray,
                             w2c: jnp.ndarray, t2c: jnp.ndarray,
                             w2f: jnp.ndarray, t2f: jnp.ndarray,
                             valid: jnp.ndarray, *, n_values: int,
                             k_sat: tuple, precision: float, bins: int,
                             undecided_ms: float):
    """Oracle for the fused streaming megakernel: masked tally + top-k
    saturation selection + decide + block-local DDSketch histogram, reduced
    over one chunk of *raw* (unsorted) trials.

    votes       (S, n)    int32 round-1 votes (< 0 = no vote)
    val_arr     (S, K, n) f32 per-value 2b arrival times (LOST when not cast)
    arrive      (S, n)    f32 phase-1 arrival times
    classic     (S, n)    f32 phase-2 classic arrival times
    w*/t*       (M, G, n) / (M, G) quorum masks per phase and system
    valid       (S,) bool trial-validity mask (False = padding trial)
    k_sat       (k1, k2c, k2f) static per-phase selection depths
                (``engine.saturation_depths``)

    Returns ``(hist, stats)``: hist (M, bins) int32 bucket counts over
    *decided* valid trials, stats a dict of per-system (M,) reductions —
    ``n_fast`` / ``n_recovery`` / ``n_undecided`` int32 counts, ``sum_ms``
    f32 decided-latency sum, ``max_ms`` f32 decided-latency max (-inf when
    nothing decided).  Bucketing matches
    ``repro.montecarlo.streaming.bucket_index`` bit-for-bit.
    """
    from repro.montecarlo.streaming import bucket_index
    M, G, n = w2f.shape
    k1, k2c, k2f = k_sat
    big = jnp.float32(2.0 * undecided_ms)
    per_q = masked_tally(votes, w2f.reshape(M * G, n), t2f.reshape(M * G),
                         n_values).reshape(-1, M, G)       # (S, M, G)
    nohit = jnp.int32(n_values)
    best = jnp.where(per_q < 0, nohit, per_q).min(axis=-1).T   # (M, S)
    reached = best < nohit
    widx = jnp.clip(best, 0, n_values - 1)
    # winner's raw per-value 2b arrival lanes, then its fast saturation.
    win_x = jnp.take_along_axis(
        jnp.broadcast_to(val_arr, (M,) + val_arr.shape),
        widx[:, :, None, None], axis=2)[:, :, 0, :]        # (M, S, n)
    t_fast = jax.vmap(
        lambda x, wm, tm: _prefix_sat(x, wm[None], tm[None], k2f, big)[0]
    )(win_x, w2f, t2f)                                     # (M, S)
    t_rec = (_prefix_sat(arrive, w1, t1, k1, big)
             + _prefix_sat(classic, w2c, t2c, k2c, big))   # (M, S)
    fast_ok = reached & (t_fast < undecided_ms)
    lat = jnp.where(fast_ok, t_fast, t_rec)
    und = lat >= undecided_ms
    v = valid[None, :]
    fast = fast_ok & v
    rec = ~fast_ok & ~und & v
    undv = und & v
    decided = fast | rec
    idx = bucket_index(lat, precision)
    hist = jax.vmap(lambda i, u: jnp.zeros((bins,), jnp.int32).at[i].add(u))(
        idx, decided.astype(jnp.int32))
    stats = {
        "n_fast": fast.sum(axis=-1).astype(jnp.int32),
        "n_recovery": rec.sum(axis=-1).astype(jnp.int32),
        "n_undecided": undv.sum(axis=-1).astype(jnp.int32),
        "sum_ms": jnp.where(decided, lat, 0.0).sum(axis=-1),
        "max_ms": jnp.where(decided, lat, -jnp.inf).max(axis=-1),
    }
    return hist, stats
