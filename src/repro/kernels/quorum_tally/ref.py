"""Pure-jnp oracle for the quorum vote tally."""
from __future__ import annotations

import jax.numpy as jnp


def tally_votes(votes: jnp.ndarray, n_values: int) -> jnp.ndarray:
    """Count votes per value.

    votes: (S, n) integer array, entries in [0, n_values).
    returns: (S, n_values) int32 counts.
    """
    one_hot = (votes[..., None] == jnp.arange(n_values, dtype=votes.dtype))
    return one_hot.sum(axis=-2).astype(jnp.int32)


def quorum_reached(votes: jnp.ndarray, n_values: int, q: int) -> jnp.ndarray:
    """(S,) bool: some value gathered >= q votes."""
    return (tally_votes(votes, n_values) >= q).any(axis=-1)


def tally_decide(votes: jnp.ndarray, n_values: int, q) -> tuple:
    """Oracle for the fused tally+decide kernel.

    Returns (counts (S, V) int32, winner (S,) int32 argmax count with
    first-max tie-break, max_count (S,) int32, reached (S,) bool
    max count >= q)."""
    counts = tally_votes(votes, n_values)
    winner = counts.argmax(axis=-1).astype(jnp.int32)
    max_count = counts.max(axis=-1)
    return counts, winner, max_count, max_count >= q


def masked_tally(votes: jnp.ndarray, weights: jnp.ndarray,
                 thresholds: jnp.ndarray, n_values: int) -> jnp.ndarray:
    """Oracle for the masked-tally kernel: per-quorum satisfied value.

    votes:      (S, n) int32, entries in [0, n_values); < 0 means "no vote".
    weights:    (G, n) float32 per-quorum acceptor weights.
    thresholds: (G,)  float32; quorum g is satisfied by value v when the
                weights of the acceptors voting v sum to >= thresholds[g].

    Returns (S, G) int32: the smallest value id satisfying quorum g (at most
    one exists for any system whose fast quorums pairwise intersect), or -1
    when no value does — which is always the case for padding rows
    (zero weights, PAD_THRESHOLD).
    """
    hit = (votes[:, None, :] == jnp.arange(n_values,
                                           dtype=votes.dtype)[None, :, None])
    wsum = jnp.einsum("svn,gn->svg", hit.astype(weights.dtype), weights)
    sat = wsum >= thresholds                               # (S, V, G)
    first = jnp.argmax(sat, axis=1).astype(jnp.int32)      # lowest value id
    return jnp.where(sat.any(axis=1), first, -1)
