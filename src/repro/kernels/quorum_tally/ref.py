"""Pure-jnp oracle for the quorum vote tally."""
from __future__ import annotations

import jax.numpy as jnp


def tally_votes(votes: jnp.ndarray, n_values: int) -> jnp.ndarray:
    """Count votes per value.

    votes: (S, n) integer array, entries in [0, n_values).
    returns: (S, n_values) int32 counts.
    """
    one_hot = (votes[..., None] == jnp.arange(n_values, dtype=votes.dtype))
    return one_hot.sum(axis=-2).astype(jnp.int32)


def quorum_reached(votes: jnp.ndarray, n_values: int, q: int) -> jnp.ndarray:
    """(S,) bool: some value gathered >= q votes."""
    return (tally_votes(votes, n_values) >= q).any(axis=-1)


def tally_decide(votes: jnp.ndarray, n_values: int, q) -> tuple:
    """Oracle for the fused tally+decide kernel.

    Returns (counts (S, V) int32, winner (S,) int32 argmax count with
    first-max tie-break, max_count (S,) int32, reached (S,) bool
    max count >= q)."""
    counts = tally_votes(votes, n_values)
    winner = counts.argmax(axis=-1).astype(jnp.int32)
    max_count = counts.max(axis=-1)
    return counts, winner, max_count, max_count >= q
