"""Jitted public wrapper for the quorum-tally kernel.

On CPU (this container) the Pallas kernel runs in interpret mode for
correctness validation; on TPU set ``interpret=False`` (the default flips on
TPU backends automatically).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def tally_votes(votes: jax.Array, n_values: int) -> jax.Array:
    """(S, n) votes -> (S, n_values) counts via the Pallas kernel."""
    return kernel.tally_votes(votes, n_values, interpret=not _on_tpu())


def quorum_reached(votes: jax.Array, n_values: int, q: int) -> jax.Array:
    return (tally_votes(votes, n_values) >= q).any(axis=-1)
