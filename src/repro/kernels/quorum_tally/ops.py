"""Jitted public wrapper for the quorum-tally kernel.

On CPU (this container) the Pallas kernel runs in interpret mode for
correctness validation; on TPU set ``interpret=False`` (the default flips on
TPU backends automatically).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def tally_votes(votes: jax.Array, n_values: int) -> jax.Array:
    """(S, n) votes -> (S, n_values) counts via the Pallas kernel."""
    return kernel.tally_votes(votes, n_values, interpret=not _on_tpu())


def quorum_reached(votes: jax.Array, n_values: int, q: int) -> jax.Array:
    return (tally_votes(votes, n_values) >= q).any(axis=-1)


def tally_decide(votes: jax.Array, n_values: int, q) -> tuple:
    """Fused (counts, winner, max_count, reached) in one kernel pass; ``q``
    is traced (SMEM scalar), so threshold sweeps reuse one compile."""
    return kernel.tally_decide(votes, n_values, q, interpret=not _on_tpu())


def masked_tally(votes: jax.Array, weights: jax.Array, thresholds: jax.Array,
                 n_values: int) -> jax.Array:
    """(S, n) votes x (G, n) quorum-mask rows -> (S, G) satisfied-value ids
    (-1 when no value saturates the row); weights/thresholds are traced, so
    sweeping quorum systems reuses one compile."""
    return kernel.masked_tally(votes, weights, thresholds, n_values,
                               interpret=not _on_tpu())


def stream_tally_decide_hist(votes: jax.Array, val_arr: jax.Array,
                             arrive: jax.Array, classic: jax.Array,
                             w1: jax.Array, t1: jax.Array,
                             w2c: jax.Array, t2c: jax.Array,
                             w2f: jax.Array, t2f: jax.Array,
                             valid: jax.Array, *, n_values: int,
                             k_sat: tuple, precision: float, bins: int,
                             undecided_ms: float):
    """Block-resident streaming megakernel over one *raw* trial chunk:
    masked tally + in-register top-k saturation selection + decide +
    DDSketch histogram + count/sum/max in a single VMEM pass (see
    ``ref.stream_tally_decide_hist`` for shapes/semantics).  No sorted
    (chunk, n) array ever materializes.  Used by
    ``repro.montecarlo.streaming`` on the masked-race path when
    ``use_kernel``."""
    return kernel.stream_tally_decide_hist(
        votes, val_arr, arrive, classic, w1, t1, w2c, t2c, w2f, t2f, valid,
        n_values=n_values, k_sat=tuple(int(k) for k in k_sat),
        precision=precision, bins=bins, undecided_ms=undecided_ms,
        interpret=not _on_tpu())
