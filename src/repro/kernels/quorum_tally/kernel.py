"""Pallas TPU kernels: batched quorum vote tally, and fused tally+decide.

The Monte-Carlo simulator's hot loop counts, for every simulated consensus
instance, how many acceptors voted for each candidate value — an
(instances x acceptors) -> (instances x values) histogram.  On TPU the
instance axis is tiled into VMEM blocks (the acceptor axis, n <= 128, lives
in the lane dimension) and each block computes its histogram with a
broadcast-compare + reduction on the VPU; no MXU needed.

Block shape: (BLOCK_S, n_pad) int32 in VMEM with n padded to the 128-lane
boundary; output block (BLOCK_S, n_values_pad).  For S = 10^6, n = 11,
V = 2 the working set per block is BLOCK_S * 128 * 4 B = 512 KiB at
BLOCK_S = 1024 — comfortably inside the ~16 MiB v5e VMEM alongside the
output tile.

``tally_decide`` extends the tally into the decision reduction the engine
needs anyway: per-instance winning value (argmax count, first-max tie-break),
its count, and a quorum-reached flag against a threshold ``q`` held in SMEM —
one VMEM pass instead of tally + three follow-up reductions over HBM.  The
decide columns come back packed in a single (BLOCK_S, LANE) int32 tile
(lane 0 winner, lane 1 max count, lane 2 reached) so the output keeps the
128-lane layout; the wrapper unpacks.  See DESIGN.md §3.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quorum import PAD_THRESHOLD

BLOCK_S = 1024
LANE = 128


def _tally_kernel(votes_ref, out_ref, *, n: int, n_values: int):
    votes = votes_ref[...]                                   # (BS, n_pad) int32
    n_pad = votes.shape[-1]
    acc_valid = jax.lax.broadcasted_iota(jnp.int32, (1, n_pad), 1) < n
    # one value per iteration: compare + masked reduce over the lane axis.
    vals_pad = out_ref.shape[-1]
    cols = []
    for v in range(n_values):
        hit = jnp.where(acc_valid, (votes == v).astype(jnp.int32), 0)
        cols.append(hit.sum(axis=-1))                        # (BS,)
    for v in range(n_values, vals_pad):
        cols.append(jnp.zeros_like(cols[0]))
    out_ref[...] = jnp.stack(cols, axis=-1)                  # (BS, vals_pad)


@functools.partial(jax.jit, static_argnums=(1, 2))
def tally_votes(votes: jax.Array, n_values: int, interpret: bool = True) -> jax.Array:
    """(S, n) int32 votes in [0, n_values) -> (S, n_values) int32 counts."""
    S, n = votes.shape
    n_pad = max(LANE, ((n + LANE - 1) // LANE) * LANE)
    vals_pad = max(LANE, ((n_values + LANE - 1) // LANE) * LANE)
    s_pad = ((S + BLOCK_S - 1) // BLOCK_S) * BLOCK_S
    votes_p = jnp.full((s_pad, n_pad), -1, jnp.int32).at[:S, :n].set(
        votes.astype(jnp.int32))

    out = pl.pallas_call(
        functools.partial(_tally_kernel, n=n, n_values=n_values),
        grid=(s_pad // BLOCK_S,),
        in_specs=[pl.BlockSpec((BLOCK_S, n_pad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_S, vals_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s_pad, vals_pad), jnp.int32),
        interpret=interpret,
    )(votes_p)
    return out[:S, :n_values]


# ---------------------------------------------------------------------------
# Fused tally + decide.
# ---------------------------------------------------------------------------

def _tally_decide_kernel(votes_ref, q_ref, counts_ref, decide_ref,
                         *, n: int, n_values: int):
    votes = votes_ref[...]                                   # (BS, n_pad) int32
    n_pad = votes.shape[-1]
    acc_valid = jax.lax.broadcasted_iota(jnp.int32, (1, n_pad), 1) < n
    vals_pad = counts_ref.shape[-1]
    cols = []
    for v in range(n_values):
        hit = jnp.where(acc_valid, (votes == v).astype(jnp.int32), 0)
        cols.append(hit.sum(axis=-1))                        # (BS,)
    # running argmax over the (small, static) value axis; strict > keeps the
    # first-max tie-break of jnp.argmax.
    max_cnt = cols[0]
    winner = jnp.zeros_like(cols[0])
    for v in range(1, n_values):
        better = cols[v] > max_cnt
        winner = jnp.where(better, v, winner)
        max_cnt = jnp.maximum(max_cnt, cols[v])
    reached = (max_cnt >= q_ref[0, 0]).astype(jnp.int32)

    for v in range(n_values, vals_pad):
        cols.append(jnp.zeros_like(cols[0]))
    counts_ref[...] = jnp.stack(cols, axis=-1)               # (BS, vals_pad)

    lane = jax.lax.broadcasted_iota(jnp.int32, decide_ref.shape, 1)
    decide_ref[...] = jnp.where(
        lane == 0, winner[:, None],
        jnp.where(lane == 1, max_cnt[:, None],
                  jnp.where(lane == 2, reached[:, None], 0)))


# ---------------------------------------------------------------------------
# Masked tally: arbitrary quorum systems as (G, n) weight rows.
# ---------------------------------------------------------------------------

def _masked_tally_kernel(votes_ref, w_ref, t_ref, out_ref, *, n_values: int):
    """One VMEM pass per votes block: for every quorum row g and value v,
    does the masked weight of v's voters reach t[g]?

    The per-value hit matrix (BLOCK_S, n_pad) contracts against the resident
    (G_pad, n_pad) weight matrix on the MXU — one 128x128-friendly matmul per
    value — and the running minimum keeps the smallest satisfying value id.
    Padding is inert by construction: padded acceptor columns carry zero
    weight (and vote -1, matching no value), padded quorum rows carry
    threshold PAD_THRESHOLD (never reached).
    """
    votes = votes_ref[...]                                 # (BS, n_pad) int32
    w = w_ref[...]                                         # (G_pad, n_pad) f32
    t = t_ref[...]                                         # (1, G_pad) f32
    out = jnp.full((votes.shape[0], w.shape[0]), -1, jnp.int32)
    for v in range(n_values - 1, -1, -1):   # descending: lowest id wins
        hit = (votes == v).astype(jnp.float32)             # (BS, n_pad)
        wsum = jax.lax.dot_general(hit, w, (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32)
        out = jnp.where(wsum >= t, v, out)                 # (BS, G_pad)
    out_ref[...] = out


@functools.partial(jax.jit, static_argnums=(3, 4))
def masked_tally(votes: jax.Array, weights: jax.Array, thresholds: jax.Array,
                 n_values: int, interpret: bool = True) -> jax.Array:
    """(S, n) votes x (G, n) quorum weights -> (S, G) satisfied-value ids.

    Semantics match ``ref.masked_tally``: entry (s, g) is the smallest value
    id whose voters' masked weight reaches ``thresholds[g]``, else -1.
    Weights and thresholds are traced operands (the whole mask table of a
    sweep lives in VMEM), so swapping systems never recompiles.
    """
    S, n = votes.shape
    G = weights.shape[0]
    if weights.shape != (G, n) or thresholds.shape != (G,):
        raise ValueError(f"weights {weights.shape} / thresholds "
                         f"{thresholds.shape} inconsistent with votes (S, {n})")
    n_pad = max(LANE, ((n + LANE - 1) // LANE) * LANE)
    g_pad = max(LANE, ((G + LANE - 1) // LANE) * LANE)
    s_pad = ((S + BLOCK_S - 1) // BLOCK_S) * BLOCK_S
    votes_p = jnp.full((s_pad, n_pad), -1, jnp.int32).at[:S, :n].set(
        votes.astype(jnp.int32))
    w_p = jnp.zeros((g_pad, n_pad), jnp.float32).at[:G, :n].set(
        weights.astype(jnp.float32))
    # padded rows: zero weight and an unreachable threshold -> never satisfied
    t_p = jnp.full((1, g_pad), jnp.float32(PAD_THRESHOLD)).at[0, :G].set(
        thresholds.astype(jnp.float32))

    out = pl.pallas_call(
        functools.partial(_masked_tally_kernel, n_values=n_values),
        grid=(s_pad // BLOCK_S,),
        in_specs=[
            pl.BlockSpec((BLOCK_S, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((g_pad, n_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, g_pad), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_S, g_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s_pad, g_pad), jnp.int32),
        interpret=interpret,
    )(votes_p, w_p, t_p)
    return out[:S, :G]


# ---------------------------------------------------------------------------
# Streaming fusion: selection network + masked tally + decide + histogram.
# ---------------------------------------------------------------------------

# Smaller trial blocks than the standalone tallies: the (BLOCK, bins_pad)
# one-hot histogram tile rides in VMEM next to the votes block.
BLOCK_STREAM = 512


def _select_sat(x, w, t, k: int, big):
    """In-register k-step selection network: earliest masked saturation of
    every quorum row, straight from *unsorted* arrivals.

    ``x (BS, n_pad)`` raw arrival times (+inf on padding lanes, so real
    entries — including LOST sentinels — are always extracted first),
    ``w (G_pad, n_pad)`` row weights, ``t (1, G_pad)`` thresholds.

    Each of the ``k`` static steps extracts the current minimum (ties to
    the lowest lane, the stable-argsort order), accumulates the selected
    acceptor's weight into every row via one MXU contraction, and records
    the extraction instant for rows that just crossed their threshold.
    After k >= the table's saturation depth (``engine.saturation_depths``)
    every saturable row has crossed, so the result equals the full-sort
    ``engine._sat_time`` — bit-identical when weights are integral (exact
    f32 partial sums; the jnp path's cumsum is then the same sequence).
    Unreached rows keep the ``big`` sentinel.  Returns the min over rows.
    """
    bs, n_pad = x.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (bs, n_pad), 1)
    csum = jnp.zeros((bs, w.shape[0]), jnp.float32)
    sat = jnp.full((bs, w.shape[0]), big, jnp.float32)
    done = jnp.zeros(csum.shape, jnp.bool_)
    for _ in range(k):
        cur = x.min(axis=-1, keepdims=True)              # (BS, 1)
        first = jnp.where(x == cur, iota, n_pad).min(axis=-1, keepdims=True)
        onehot = (iota == first).astype(jnp.float32)     # (BS, n_pad)
        wsel = jax.lax.dot_general(onehot, w, (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32)
        csum = csum + wsel                               # (BS, G_pad)
        newly = (csum >= t) & ~done
        sat = jnp.where(newly, cur, sat)
        done = done | newly
        x = jnp.where(iota == first, jnp.inf, x)         # extract the lane
    return sat.min(axis=-1)                              # (BS,)


def _stream_kernel(votes_ref, val_ref, arr_ref, cls_ref, w1_ref, t1_ref,
                   w2c_ref, t2c_ref, w2f_ref, t2f_ref, valid_ref,
                   hist_ref, stats_ref, *, n_values: int, k_sat: tuple,
                   precision: float, bins: int, undecided_ms: float):
    """One (system m, trial block s) grid step, everything VMEM-resident:

    * masked tally of the votes block against system m's fast-quorum rows
      (per-value MXU contraction, exactly ``_masked_tally_kernel``),
    * select the winning value's raw 2b arrival lane block and run the
      ``k_sat``-deep selection networks (``_select_sat``) for the fast,
      phase-1 and phase-2c saturation instants — the raw arrival block
      never exists in sorted form anywhere,
    * decide: winner's fast saturation, else detection + classic recovery,
    * classify fast / recovery / undecided (gated on the validity mask),
    * block-local DDSketch update: log-bucket index per decided trial, then
      a one-hot lane compare summed over the block,
    * running (M,)-shaped reductions: counts, latency sum, latency max.

    Outputs are revisited across the s grid dimension (index map pins them
    to block m), so the kernel initializes at s == 0 and accumulates after
    — the whole chunk reduces without leaving VMEM.
    """
    from repro.montecarlo.streaming import bucket_index
    s = pl.program_id(1)
    k1, k2c, k2f = k_sat
    big = jnp.float32(2.0 * undecided_ms)
    votes = votes_ref[...]                               # (BS, n_pad) int32
    w2f = w2f_ref[0]                                     # (G_pad, n_pad) f32
    t2f = t2f_ref[0]                                     # (1, G_pad) f32
    valid = valid_ref[...][0] != 0                       # (BS,) bool
    bs, n_pad = votes.shape

    # masked tally: smallest value id saturating any fast row (else V).
    best = jnp.full((bs, w2f.shape[0]), n_values, jnp.int32)
    for v in range(n_values - 1, -1, -1):   # descending: lowest id wins
        hit = (votes == v).astype(jnp.float32)
        wsum = jax.lax.dot_general(hit, w2f, (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32)
        best = jnp.where(wsum >= t2f, v, best)           # (BS, G_pad)
    best = best.min(axis=-1)                             # (BS,)
    reached = best < n_values
    widx = jnp.clip(best, 0, n_values - 1)

    # winner's raw per-value 2b arrival lanes: static one-hot gather over K.
    win_x = val_ref[:, 0:n_pad]
    for k in range(1, n_values):
        win_x = jnp.where((widx == k)[:, None],
                          val_ref[:, k * n_pad:(k + 1) * n_pad], win_x)

    t_fast = _select_sat(win_x, w2f, t2f, k2f, big)
    t_det = _select_sat(arr_ref[...], w1_ref[0], t1_ref[0], k1, big)
    t_cls = _select_sat(cls_ref[...], w2c_ref[0], t2c_ref[0], k2c, big)
    rec = t_det + t_cls
    fast_ok = reached & (t_fast < undecided_ms)
    lat = jnp.where(fast_ok, t_fast, rec)
    und = lat >= undecided_ms
    fast = fast_ok & valid
    recb = ~fast_ok & ~und & valid
    undb = und & valid
    decided = fast | recb

    # block-local histogram: one-hot bucket compare, summed over the block.
    idx = bucket_index(lat, precision)                   # (BS,)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (votes.shape[0],
                                                 hist_ref.shape[-1]), 1)
    onehot = ((lanes == idx[:, None]) & decided[:, None]).astype(jnp.int32)
    hist_blk = onehot.sum(axis=0)[None, :]               # (1, bins_pad)

    f32 = jnp.float32
    lane = jax.lax.broadcasted_iota(jnp.int32, stats_ref.shape, 1)
    stat_blk = jnp.where(
        lane == 0, fast.sum().astype(f32),
        jnp.where(lane == 1, recb.sum().astype(f32),
                  jnp.where(lane == 2, undb.sum().astype(f32),
                            jnp.where(lane == 3,
                                      jnp.where(decided, lat, 0.0).sum(),
                                      jnp.where(lane == 4,
                                                jnp.where(decided, lat,
                                                          -jnp.inf).max(),
                                                0.0)))))

    @pl.when(s == 0)
    def _init():
        hist_ref[...] = hist_blk
        stats_ref[...] = stat_blk

    @pl.when(s != 0)
    def _accumulate():
        hist_ref[...] += hist_blk
        prev = stats_ref[...]
        stats_ref[...] = jnp.where(lane == 4, jnp.maximum(prev, stat_blk),
                                   prev + stat_blk)


@functools.partial(jax.jit, static_argnames=("n_values", "k_sat", "precision",
                                             "bins", "undecided_ms",
                                             "interpret"))
def stream_tally_decide_hist(votes: jax.Array, val_arr: jax.Array,
                             arrive: jax.Array, classic: jax.Array,
                             w1: jax.Array, t1: jax.Array,
                             w2c: jax.Array, t2c: jax.Array,
                             w2f: jax.Array, t2f: jax.Array,
                             valid: jax.Array, *, n_values: int,
                             k_sat: tuple, precision: float, bins: int,
                             undecided_ms: float, interpret: bool = True):
    """Fused sample→decide→sketch megakernel over a *raw* trial chunk.

    Takes the unsorted draw block straight from ``engine._draw_race``:

      votes   (S, n)    int32 per-acceptor 2b value ids (< 0: no vote)
      val_arr (S, K, n) f32 per-value 2b arrival times (LOST where not cast)
      arrive  (S, n)    f32 phase-1 arrival times
      classic (S, n)    f32 phase-2 classic arrival times

    plus the (M, G, n)/(M, G) mask tables for all three phases and the
    static per-phase selection depths ``k_sat = (k1, k2c, k2f)`` from
    ``engine.saturation_depths``.  Semantics of
    ``ref.stream_tally_decide_hist`` (same shapes, same bucketing).  Counts
    and histograms are bit-identical to the oracle for integral weights;
    the f32 latency sum accumulates block-by-block so it matches to float
    tolerance only.  Trial counts per call must stay below 2^24 (exact f32
    integers) — the streaming driver calls once per chunk, far below that.
    """
    S, n = votes.shape
    M, G1, _ = w1.shape
    G2c = w2c.shape[1]
    G2f = w2f.shape[1]
    K = val_arr.shape[1]
    if val_arr.shape != (S, K, n) or arrive.shape != (S, n) \
            or classic.shape != (S, n) or valid.shape != (S,) \
            or w2c.shape[::2] != (M, n) or w2f.shape[::2] != (M, n) \
            or t1.shape != (M, G1) or t2c.shape != (M, G2c) \
            or t2f.shape != (M, G2f):
        raise ValueError(
            f"inconsistent stream shapes: votes {votes.shape}, val_arr "
            f"{val_arr.shape}, arrive {arrive.shape}, classic "
            f"{classic.shape}, w1 {w1.shape}, w2c {w2c.shape}, w2f "
            f"{w2f.shape}, valid {valid.shape}")
    if S >= 2 ** 24:
        raise ValueError(f"chunk of {S} trials overflows exact f32 counts; "
                         f"stream smaller chunks")
    if len(k_sat) != 3 or not all(1 <= int(k) <= n for k in k_sat):
        raise ValueError(f"k_sat {k_sat} out of range for n={n}")
    bs = BLOCK_STREAM
    n_pad = max(LANE, ((n + LANE - 1) // LANE) * LANE)
    b_pad = max(LANE, ((bins + LANE - 1) // LANE) * LANE)
    s_pad = ((S + bs - 1) // bs) * bs
    inf = jnp.float32(jnp.inf)

    def pad_masks(w, t):
        G = w.shape[1]
        g_pad = max(LANE, ((G + LANE - 1) // LANE) * LANE)
        w_p = jnp.zeros((M, g_pad, n_pad), jnp.float32).at[:, :G, :n].set(
            w.astype(jnp.float32))
        t_p = jnp.full((M, 1, g_pad), jnp.float32(PAD_THRESHOLD)).at[
            :, 0, :G].set(t.astype(jnp.float32))
        return w_p, t_p, g_pad

    def pad_arrivals(x):
        # +inf on padding lanes/rows: never extracted before a real entry.
        return jnp.full((s_pad, n_pad), inf).at[:S, :n].set(
            x.astype(jnp.float32))

    votes_p = jnp.full((s_pad, n_pad), -1, jnp.int32).at[:S, :n].set(
        votes.astype(jnp.int32))
    val_p = jnp.full((s_pad, K, n_pad), inf).at[:S, :, :n].set(
        val_arr.astype(jnp.float32)).reshape(s_pad, K * n_pad)
    arr_p = pad_arrivals(arrive)
    cls_p = pad_arrivals(classic)
    w1_p, t1_p, g1_pad = pad_masks(w1, t1)
    w2c_p, t2c_p, g2c_pad = pad_masks(w2c, t2c)
    w2f_p, t2f_p, g2f_pad = pad_masks(w2f, t2f)
    valid_p = jnp.zeros((1, s_pad), jnp.int32).at[0, :S].set(
        valid.astype(jnp.int32))

    hist, stats = pl.pallas_call(
        functools.partial(_stream_kernel, n_values=n_values,
                          k_sat=tuple(int(k) for k in k_sat),
                          precision=precision, bins=bins,
                          undecided_ms=undecided_ms),
        grid=(M, s_pad // bs),
        in_specs=[
            pl.BlockSpec((bs, n_pad), lambda m, s: (s, 0)),
            pl.BlockSpec((bs, K * n_pad), lambda m, s: (s, 0)),
            pl.BlockSpec((bs, n_pad), lambda m, s: (s, 0)),
            pl.BlockSpec((bs, n_pad), lambda m, s: (s, 0)),
            pl.BlockSpec((1, g1_pad, n_pad), lambda m, s: (m, 0, 0)),
            pl.BlockSpec((1, 1, g1_pad), lambda m, s: (m, 0, 0)),
            pl.BlockSpec((1, g2c_pad, n_pad), lambda m, s: (m, 0, 0)),
            pl.BlockSpec((1, 1, g2c_pad), lambda m, s: (m, 0, 0)),
            pl.BlockSpec((1, g2f_pad, n_pad), lambda m, s: (m, 0, 0)),
            pl.BlockSpec((1, 1, g2f_pad), lambda m, s: (m, 0, 0)),
            pl.BlockSpec((1, bs), lambda m, s: (0, s)),
        ],
        out_specs=[
            pl.BlockSpec((1, b_pad), lambda m, s: (m, 0)),
            pl.BlockSpec((1, LANE), lambda m, s: (m, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, b_pad), jnp.int32),
            jax.ShapeDtypeStruct((M, LANE), jnp.float32),
        ],
        interpret=interpret,
    )(votes_p, val_p, arr_p, cls_p, w1_p, t1_p, w2c_p, t2c_p, w2f_p, t2f_p,
      valid_p)
    return hist[:, :bins], {
        "n_fast": stats[:, 0].astype(jnp.int32),
        "n_recovery": stats[:, 1].astype(jnp.int32),
        "n_undecided": stats[:, 2].astype(jnp.int32),
        "sum_ms": stats[:, 3],
        "max_ms": stats[:, 4],
    }


@functools.partial(jax.jit, static_argnums=(1, 3))
def tally_decide(votes: jax.Array, n_values: int, q: jax.Array,
                 interpret: bool = True):
    """Fused histogram + decision: one VMEM pass over (S, n) votes.

    votes: (S, n) int32 in [0, n_values); entries < 0 count as "no vote".
    q:     scalar quorum threshold (traced — lives in SMEM, so sweeping it
           never recompiles).

    Returns ``(counts, winner, max_count, reached)``:
      counts    (S, n_values) int32 per-value vote counts
      winner    (S,) int32 argmax-count value id (first max on ties)
      max_count (S,) int32 the winner's vote count
      reached   (S,) bool  max count >= q
    """
    S, n = votes.shape
    n_pad = max(LANE, ((n + LANE - 1) // LANE) * LANE)
    vals_pad = max(LANE, ((n_values + LANE - 1) // LANE) * LANE)
    s_pad = ((S + BLOCK_S - 1) // BLOCK_S) * BLOCK_S
    votes_p = jnp.full((s_pad, n_pad), -1, jnp.int32).at[:S, :n].set(
        votes.astype(jnp.int32))
    q_arr = jnp.asarray(q, jnp.int32).reshape(1, 1)

    counts, decide = pl.pallas_call(
        functools.partial(_tally_decide_kernel, n=n, n_values=n_values),
        grid=(s_pad // BLOCK_S,),
        in_specs=[
            pl.BlockSpec((BLOCK_S, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_S, vals_pad), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_S, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s_pad, vals_pad), jnp.int32),
            jax.ShapeDtypeStruct((s_pad, LANE), jnp.int32),
        ],
        interpret=interpret,
    )(votes_p, q_arr)
    return (counts[:S, :n_values], decide[:S, 0], decide[:S, 1],
            decide[:S, 2].astype(jnp.bool_))
