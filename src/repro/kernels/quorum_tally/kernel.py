"""Pallas TPU kernel: batched quorum vote tally.

The Monte-Carlo simulator's hot loop counts, for every simulated consensus
instance, how many acceptors voted for each candidate value — an
(instances x acceptors) -> (instances x values) histogram.  On TPU the
instance axis is tiled into VMEM blocks (the acceptor axis, n <= 128, lives
in the lane dimension) and each block computes its histogram with a
broadcast-compare + reduction on the VPU; no MXU needed.

Block shape: (BLOCK_S, n_pad) int32 in VMEM with n padded to the 128-lane
boundary; output block (BLOCK_S, n_values_pad).  For S = 10^6, n = 11,
V = 2 the working set per block is BLOCK_S * 128 * 4 B = 512 KiB at
BLOCK_S = 1024 — comfortably inside the ~16 MiB v5e VMEM alongside the
output tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_S = 1024
LANE = 128


def _tally_kernel(votes_ref, out_ref, *, n: int, n_values: int):
    votes = votes_ref[...]                                   # (BS, n_pad) int32
    n_pad = votes.shape[-1]
    acc_valid = jax.lax.broadcasted_iota(jnp.int32, (1, n_pad), 1) < n
    # one value per iteration: compare + masked reduce over the lane axis.
    vals_pad = out_ref.shape[-1]
    cols = []
    for v in range(n_values):
        hit = jnp.where(acc_valid, (votes == v).astype(jnp.int32), 0)
        cols.append(hit.sum(axis=-1))                        # (BS,)
    for v in range(n_values, vals_pad):
        cols.append(jnp.zeros_like(cols[0]))
    out_ref[...] = jnp.stack(cols, axis=-1)                  # (BS, vals_pad)


@functools.partial(jax.jit, static_argnums=(1, 2))
def tally_votes(votes: jax.Array, n_values: int, interpret: bool = True) -> jax.Array:
    """(S, n) int32 votes in [0, n_values) -> (S, n_values) int32 counts."""
    S, n = votes.shape
    n_pad = max(LANE, ((n + LANE - 1) // LANE) * LANE)
    vals_pad = max(LANE, ((n_values + LANE - 1) // LANE) * LANE)
    s_pad = ((S + BLOCK_S - 1) // BLOCK_S) * BLOCK_S
    votes_p = jnp.full((s_pad, n_pad), -1, jnp.int32).at[:S, :n].set(
        votes.astype(jnp.int32))

    out = pl.pallas_call(
        functools.partial(_tally_kernel, n=n, n_values=n_values),
        grid=(s_pad // BLOCK_S,),
        in_specs=[pl.BlockSpec((BLOCK_S, n_pad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_S, vals_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s_pad, vals_pad), jnp.int32),
        interpret=interpret,
    )(votes_p)
    return out[:S, :n_values]
