"""Oracle for the SSD scan: the O(S) sequential recurrence (independent of
the chunked algorithm the kernel implements)."""
from __future__ import annotations

from repro.models.ssm import ssd_reference


def ssd(xw, da, Bm, Cm, init_state=None):
    """xw (B,S,nh,hd), da (B,S,nh), Bm/Cm (B,S,ds) ->
    (y (B,S,nh,hd), final_state (B,nh,hd,ds))."""
    return ssd_reference(xw, da, Bm, Cm, init_state)
