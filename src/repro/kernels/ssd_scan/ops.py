"""Jitted public wrapper for the SSD scan kernel."""
from __future__ import annotations

import jax

from . import kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssd(xw, da, Bm, Cm, chunk: int = 256, init_state=None):
    return kernel.ssd(xw, da, Bm, Cm, chunk=chunk, init_state=init_state,
                      interpret=not _on_tpu())
