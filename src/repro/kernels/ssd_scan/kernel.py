"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid = (B, S/chunk); the chunk axis is minor-most, so each batch row walks
its chunks sequentially on-core while the inter-chunk SSM state lives in VMEM
scratch — state never round-trips to HBM between chunks (the TPU-native
adaptation of Mamba2's kernel, DESIGN.md §2: on GPU this is a warp-level
scan; on TPU the intra-chunk "dual" form feeds the MXU with (chunk x chunk)
and (chunk x state) matmuls while the carried state stays resident).

Per-block VMEM (chunk=256, nh=24, hd=64, ds=128, f32):
xw 256*24*64*4 = 1.5 MiB, L (256,256,nh) materialized per-head-group via
broadcasting inside einsum ~ 6 MiB transient, state 24*64*128*4 = 0.75 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xw_ref, da_ref, b_ref, c_ref, s0_ref,
                y_ref, fin_ref, state_ref, *, chunk: int):
    ic = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    xw = xw_ref[0].astype(jnp.float32)       # (L, nh, hd)
    da = da_ref[0].astype(jnp.float32)       # (L, nh)
    Bm = b_ref[0].astype(jnp.float32)        # (L, ds)
    Cm = c_ref[0].astype(jnp.float32)        # (L, ds)

    cum = jnp.cumsum(da, axis=0)             # (L, nh)
    seg = cum[:, None, :] - cum[None, :, :]  # (Li, Lj, nh)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    # mask inside the exponent (exp overflows at non-causal positions and the
    # masked-after-exp form has a 0*inf VJP — see models/ssm.py)
    L = jnp.exp(jnp.where((ii >= jj)[:, :, None], seg, -jnp.inf))

    scores = jax.lax.dot_general(             # (Li, Lj) = C_i . B_j
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y_intra = jnp.einsum("ij,ijh,jhp->ihp", scores, L, xw)

    state = state_ref[...]                    # (nh, hd, ds)
    y_inter = jnp.einsum("is,hps,ih->ihp", Cm, state, jnp.exp(cum))

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # update carried state: decay full chunk + inject chunk contributions
    w_end = jnp.exp(cum[-1:, :] - cum)        # (L, nh)
    chunk_state = jnp.einsum("js,jh,jhp->hps", Bm, w_end, xw)
    state_ref[...] = state * jnp.exp(cum[-1])[:, None, None] + chunk_state

    @pl.when(ic == nc - 1)
    def _fin():
        fin_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(xw: jax.Array, da: jax.Array, Bm: jax.Array, Cm: jax.Array,
        chunk: int = 256, init_state: jax.Array | None = None,
        interpret: bool = True):
    """Chunked SSD scan.  xw (B,S,nh,hd), da (B,S,nh), Bm/Cm (B,S,ds)."""
    B, S, nh, hd = xw.shape
    ds = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    if init_state is None:
        init_state = jnp.zeros((B, nh, hd, ds), jnp.float32)

    grid = (B, nc)
    y, fin = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, nh, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, nh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, nh, hd, ds), lambda b, c: (b, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, nh, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, nh, hd, ds), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, nh, hd), xw.dtype),
            jax.ShapeDtypeStruct((B, nh, hd, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((nh, hd, ds), jnp.float32)],
        interpret=interpret,
    )(xw, da, Bm, Cm, init_state)
    return y, fin
