"""musicgen-medium  [audio]  48L d_model=1536 24H (kv=24) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens.  [arXiv:2306.05284; hf]

Backbone only, per the assignment: the EnCodec frontend is a STUB —
``input_specs()`` supplies precomputed frame embeddings (B, S, d_model)
(the sum of the 4 codebook embeddings after the delay pattern).  One LM head
over the 2048-entry codebook vocabulary (the real model has 4 heads, one per
codebook — noted simplification).  MusicGen uses GELU MLPs and LayerNorm.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen_medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    mlp="gelu",
    norm="rmsnorm",
    frontend="audio_frames",
    n_codebooks=4,
    notes="single codebook head (real: 4); rmsnorm for uniformity (real: LN)",
)
