"""arctic-480b  [moe]  35L d_model=7168 56H (GQA kv=8) d_ff=4864(expert)
vocab=32000, MoE 128 experts top-2 + dense residual.
[hf:Snowflake/snowflake-arctic-base; hf]

Arctic's "dense-MoE hybrid": every layer has a dense residual MLP in
parallel with the 128-expert top-2 MoE.  We give the dense residual the same
d_ff as the experts (4864) — the real model's dense path is wider (noted).
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic_480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab=32000,
    mlp="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864, n_shared=0,
                  dense_residual=True),
    notes="dense residual d_ff matched to expert d_ff (real model wider)",
)
