"""deepseek-7b  [dense]  30L d_model=4096 32H (GQA kv=32) d_ff=11008
vocab=102400 — llama architecture.  [arXiv:2401.02954; hf]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek_7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=102400,
    mlp="swiglu",
    norm="rmsnorm",
)
