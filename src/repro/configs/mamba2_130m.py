"""mamba2-130m  [ssm]  24L d_model=768 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]

d_inner = 2*768 = 1536, head_dim 64 -> 24 SSD heads; conv window 4;
chunked SSD with chunk length 256 for train/prefill, recurrent state for
decode (long_500k runs with an O(1) cache).
"""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2_130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    norm="rmsnorm",
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64),
)
