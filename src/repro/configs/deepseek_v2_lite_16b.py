"""deepseek-v2-lite-16b  [moe]  27L d_model=2048 16H d_ff=1408(expert)
vocab=102400, MLA kv_lora=512, MoE 64 routed top-6 + 2 shared.
[arXiv:2405.04434; hf]

Assignment-line discrepancy: the spec reads "MoE 64e top-6" but the note says
"2 shared+160 routed"; HF's official config is 64 routed top-6 + 2 shared —
we follow the primary spec (64).  Real model's first layer is dense
(d_ff=10944); we make all 27 layers MoE (noted simplification).
"""
from .base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek_v2_lite_16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    mlp="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2),
    mla=MLAConfig(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                  v_head_dim=128),
    notes="all layers MoE (real first layer dense); MLA cache = c_kv+k_rope",
)
