"""gemma3-12b  [dense]  48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt family; unverified]

head_dim=256 (per HF config, not d_model/n_heads); sliding window 1024 for
local layers.  rope_theta differs between local (10k) and global (1M) layers
in the real model — we use the global value everywhere (noted simplification).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3_12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    window=1024,
    local_ratio=5,
    notes="single rope_theta; untied head (real model ties embeddings)",
)
