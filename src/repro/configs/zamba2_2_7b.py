"""zamba2-2.7b  [hybrid]  54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention block.
[arXiv:2411.15242; hf]

Pattern: 6 Mamba2 blocks then one *weight-shared* full transformer block
(attention + MLP), 9 superblocks = 54 Mamba layers.  The real model
concatenates the original embedding into the shared block input and uses two
alternating shared blocks + LoRA adapters; we use a single shared block on
the residual stream (noted simplification).
"""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2_2_7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    mlp="swiglu",
    norm="rmsnorm",
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64),
    hybrid_period=6,
    notes="one shared attn block (real: two alternating + LoRA + embed concat)",
)
