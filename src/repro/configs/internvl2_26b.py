"""internvl2-26b  [vlm]  48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT + InternLM2.  [arXiv:2404.16821; hf]

Backbone = InternLM2-20B decoder.  The InternViT-6B frontend is a STUB:
``input_specs()`` supplies 1024 precomputed patch embeddings (B, 1024,
d_model) prepended to the text tokens; loss is computed on the text span.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2_26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    mlp="swiglu",
    norm="rmsnorm",
    frontend="vision_patches",
    vision_tokens=1024,
)
