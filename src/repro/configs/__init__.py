from .base import (ARCH_IDS, SHAPES, ArchConfig, MLAConfig, MoEConfig,
                   ShapeSpec, SSMConfig, get_config, input_specs,
                   reduced_config)

__all__ = ["ARCH_IDS", "SHAPES", "ArchConfig", "MLAConfig", "MoEConfig",
           "ShapeSpec", "SSMConfig", "get_config", "input_specs",
           "reduced_config"]
