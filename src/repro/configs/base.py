"""Architecture configuration system.

One ``ArchConfig`` per assigned architecture (``repro/configs/<id>.py``),
plus input-shape sets (train_4k / prefill_32k / decode_32k / long_500k) and
``input_specs()`` producing ``jax.ShapeDtypeStruct`` stand-ins for the
multi-pod dry-run (no device allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    dense_residual: bool = False      # arctic: dense MLP in parallel with MoE


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    absorbed_decode: bool = False     # beyond-paper perf variant (§Perf)


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    expand: int = 2
    head_dim: int = 64
    d_conv: int = 4
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    mlp: str = "swiglu"              # swiglu | relu2 | gelu
    norm: str = "rmsnorm"            # rmsnorm | nonparam_ln
    rope_theta: float = 10000.0
    # local/global attention (gemma3): `local_ratio` local layers per global
    window: Optional[int] = None
    local_ratio: int = 0
    # subsystems
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_period: int = 0           # zamba2: shared attn block every k layers
    # modality frontend stubs
    frontend: Optional[str] = None   # audio_frames | vision_patches
    n_codebooks: int = 0
    vision_tokens: int = 0
    # numerics
    param_dtype: str = "bfloat16"
    # per-arch notes (assumption changes, simplifications)
    notes: str = ""

    # -- derived -------------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def pattern(self) -> Tuple[str, ...]:
        """Block kinds inside one scanned superblock."""
        if self.family == "ssm":
            return ("mamba",)
        if self.family == "hybrid":
            return ("mamba",) * self.hybrid_period + ("shared_attn",)
        if self.local_ratio:
            return ("local",) * self.local_ratio + ("global",)
        return ("global",)

    @property
    def n_superblocks(self) -> int:
        per = len([k for k in self.pattern if k != "shared_attn"]) or 1
        n = self.n_layers // per
        assert n * per == self.n_layers, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern {self.pattern}")
        return n

    def param_count(self) -> int:
        """Total parameters N (embedding + blocks); used for 6*N*D."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top-k experts only)."""
        return _param_count(self, active_only=True)

    def supports_shape(self, shape: "ShapeSpec") -> Tuple[bool, str]:
        if shape.kind == "long_decode":
            if self.family in ("ssm", "hybrid"):
                return True, "O(1)-state SSM"
            if self.local_ratio:
                return True, "local:global attention (windowed cache)"
            return False, ("pure full-attention arch: long_500k skipped per "
                           "assignment (see DESIGN.md)")
        return True, ""


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode | long_decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "long_decode"),
}

ARCH_IDS = (
    "gemma3_12b", "nemotron_4_15b", "deepseek_7b", "olmo_1b",
    "deepseek_v2_lite_16b", "arctic_480b", "zamba2_2_7b",
    "musicgen_medium", "mamba2_130m", "internvl2_26b",
)


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_')}")
    return mod.CONFIG


def reduced_config(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    per = len([k for k in cfg.pattern if k != "shared_attn"]) or 1
    changes = dict(
        n_layers=2 * per,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_ff=128,
        vocab=128,
        head_dim=16 if cfg.head_dim else 0,
    )
    if cfg.moe:
        changes["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, d_ff_expert=32, n_shared=min(cfg.moe.n_shared, 1))
    if cfg.mla:
        changes["mla"] = dataclasses.replace(
            cfg.mla, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
    if cfg.ssm:
        changes["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16, chunk=32)
    if cfg.vision_tokens:
        changes["vision_tokens"] = 8
    if cfg.window:
        changes["window"] = 32
    return dataclasses.replace(cfg, **changes)


# ---------------------------------------------------------------------------
# Parameter counting (for MODEL_FLOPS = 6*N*D in the roofline).
# ---------------------------------------------------------------------------

def _attn_params(cfg: ArchConfig) -> int:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    if cfg.mla:
        m = cfg.mla
        return (D * H * (m.qk_nope_dim + m.qk_rope_dim)        # Wq
                + D * (m.kv_lora_rank + m.qk_rope_dim)         # Wdkv + Wkr
                + m.kv_lora_rank * H * m.qk_nope_dim           # Wuk
                + m.kv_lora_rank * H * m.v_head_dim            # Wuv
                + H * m.v_head_dim * D)                        # Wo
    return D * H * hd + 2 * D * KV * hd + H * hd * D


def _mlp_params(cfg: ArchConfig, d_ff: int) -> int:
    mult = 3 if cfg.mlp == "swiglu" else 2
    return mult * cfg.d_model * d_ff


def _mamba_params(cfg: ArchConfig) -> int:
    assert cfg.ssm is not None
    D = cfg.d_model
    s = cfg.ssm
    di = s.d_inner(D)
    nh = s.n_heads(D)
    in_proj = D * (2 * di + 2 * s.d_state + nh)
    conv = (di + 2 * s.d_state) * s.d_conv
    out_proj = di * D
    return in_proj + conv + out_proj + 2 * nh + di    # + A, Dskip, norm


def _block_params(cfg: ArchConfig, kind: str, active_only: bool) -> int:
    if kind == "mamba":
        return _mamba_params(cfg)
    p = _attn_params(cfg)
    if cfg.moe:
        m = cfg.moe
        n_exp = m.top_k if active_only else m.n_experts
        p += (n_exp + m.n_shared) * _mlp_params(cfg, m.d_ff_expert)
        p += cfg.d_model * m.n_experts                  # router
        if m.dense_residual:
            p += _mlp_params(cfg, cfg.d_ff)
    else:
        p += _mlp_params(cfg, cfg.d_ff)
    return p


def _param_count(cfg: ArchConfig, active_only: bool = False,
                 flops_multiplicity: bool = False) -> int:
    """Parameter count.  ``flops_multiplicity`` counts shared (weight-tied)
    blocks once per *execution* — use for FLOPs estimates, not storage."""
    total = 0
    # embedding + untied head (audio/vlm stubs have no input table).
    if cfg.frontend is None:
        total += cfg.vocab * cfg.d_model
    total += cfg.vocab * cfg.d_model                    # output head
    per_super = {k: cfg.pattern.count(k) for k in set(cfg.pattern)}
    for kind, cnt in per_super.items():
        blocks = cnt * cfg.n_superblocks
        p = _block_params(cfg, "global" if kind in ("shared_attn", "local") else kind,
                          active_only)
        if kind == "shared_attn" and not flops_multiplicity:
            blocks = 1                                   # weights shared
        total += blocks * p
    return total


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins for every model input.
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract inputs for (arch x shape): weak-type-correct, shardable,
    no allocation.  Keys match the train_step/serve_step signatures."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16

    if shape.is_train or shape.kind == "prefill":
        specs: Dict[str, jax.ShapeDtypeStruct] = {}
        if cfg.frontend == "audio_frames":
            # EnCodec frame embeddings are precomputed by the (stub) frontend.
            specs["frame_emb"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), bf16)
            specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        elif cfg.frontend == "vision_patches":
            V = cfg.vision_tokens
            specs["patch_emb"] = jax.ShapeDtypeStruct((B, V, cfg.d_model), bf16)
            specs["tokens"] = jax.ShapeDtypeStruct((B, S - V), i32)
            specs["labels"] = jax.ShapeDtypeStruct((B, S - V), i32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        return specs

    # decode: one new token against a seq_len-deep cache (cache specs are
    # produced by the model's cache_specs(), not here).
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
