"""olmo-1b  [dense]  16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304
— non-parametric LayerNorm.  [arXiv:2402.00838; hf]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="olmo_1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=50304,
    mlp="swiglu",
    norm="nonparam_ln",
    notes="non-parametric LN (no scale/bias), per OLMo",
)
