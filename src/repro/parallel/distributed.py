"""Multi-host trial mesh: ``jax.distributed`` initialization + a local
multi-process launcher (DESIGN.md §10).

The streaming engine's cross-device reduction (``StreamSummary.axis_merge``
— psum counts/histograms, pmax maxima) is already a valid cross-*host*
reduction: sketch merge is integer-exact, associative and commutative.  All
multi-host support needs is (a) every process agreeing on the global device
grid and (b) per-device work keyed by the *global* device index, so a
2-process x 4-device run and a 1-process x 8-device run are the same
program.  This module supplies (a); ``montecarlo/streaming.py`` derives (b)
from ``lax.axis_index`` over a ``trial_mesh()`` built on ``jax.devices()``
(the global device list — ``process_index * local_count + local_index`` in
enumeration order).

Three entry points:

``initialize()``      read coordinator/process-count/process-id from
                      arguments or the ``REPRO_*`` environment (set by
                      ``launch_local`` and by cluster launch scripts) and
                      bring up ``jax.distributed``.  Idempotent; a no-op
                      for single-process runs, so callers can invoke it
                      unconditionally before touching the backend.
``launch_local()``    the CI-exercisable local mode: N processes x
                      ``--xla_force_host_platform_device_count=D`` forced
                      host devices each (the forced-device trick the
                      8-device CI job already uses), coordinated over a
                      free localhost port.  CPU cross-process collectives
                      run on gloo, which jax only honors when configured
                      *in-process before backend init* — ``initialize()``
                      does that, which is why workers must call it first.
``main()``            CLI: ``python -m repro.parallel.distributed launch
                      --processes 2 --devices-per-process 4 -- <cmd...>``
                      re-runs any command as a cooperating process grid;
                      the ``stream`` subcommand is the fixed-workload
                      worker the multihost acceptance test and the
                      ``stream.multihost`` benchmark row compare layouts
                      with.
"""
from __future__ import annotations

import argparse
import os
import socket
import subprocess
import sys
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

ENV_COORDINATOR = "REPRO_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_PROCESS_ID"

# Error text the CPU backend emits when cross-process collectives are not
# available (no gloo, or a jax too old to route them) — launch/test helpers
# match on it to distinguish "platform can't" from "code broke".
UNSUPPORTED_MARKERS = (
    "Multiprocess computations aren't implemented",
    "cpu_collectives_implementation",
)

_INITIALIZED = False


@dataclass(frozen=True)
class DistInfo:
    """The process-grid coordinates a multi-host run is keyed by."""

    process_index: int
    process_count: int
    local_device_count: int
    global_device_count: int

    @property
    def is_multiprocess(self) -> bool:
        return self.process_count > 1


def _backend_already_up() -> bool:
    """True when an XLA backend client exists (best effort, version-tolerant
    — pinned jax 0.4.x keeps the attribute, and a miss only degrades the
    error message, never correctness)."""
    try:
        from jax._src import xla_bridge
        return bool(getattr(xla_bridge, "_backends", None))
    except Exception:
        return False


def initialize(coordinator: Optional[str] = None,
               num_processes: Optional[int] = None,
               process_id: Optional[int] = None) -> DistInfo:
    """Bring up ``jax.distributed`` from arguments or the ``REPRO_*`` env.

    Single-process (no coordinator configured, or one process) is a no-op,
    so multihost-capable entry points (``benchmarks.quorum_sweep --shard``,
    the ``stream`` worker below) call this unconditionally as their first
    jax-touching statement.  Re-initialization is a no-op too.

    On the CPU backend, cross-process collectives require the gloo
    implementation, selected via ``jax.config`` **before** the backend
    client exists — calling this after ``jax.devices()``/any computation
    raises instead of silently producing a grid that cannot psum.
    """
    global _INITIALIZED
    import jax

    coordinator = coordinator or os.environ.get(ENV_COORDINATOR)
    if num_processes is None:
        num_processes = int(os.environ.get(ENV_NUM_PROCESSES, "1"))
    if process_id is None:
        process_id = int(os.environ.get(ENV_PROCESS_ID, "0"))

    if coordinator is None or num_processes <= 1:
        return info()
    if _INITIALIZED:
        return info()
    if _backend_already_up():
        raise RuntimeError(
            "repro.parallel.distributed.initialize() must run before the "
            "jax backend is first used (it selects the gloo CPU collectives "
            "implementation, which only takes effect at backend creation); "
            "call it at process start, before any jax computation")
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:
        pass          # non-CPU backends / older jax: collectives are native
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)
    _INITIALIZED = True
    return info()


def info() -> DistInfo:
    """The current process-grid coordinates (initializes the backend)."""
    import jax
    return DistInfo(process_index=jax.process_index(),
                    process_count=jax.process_count(),
                    local_device_count=len(jax.local_devices()),
                    global_device_count=len(jax.devices()))


# ---------------------------------------------------------------------------
# Local multi-process launcher (the CI-exercisable mode).
# ---------------------------------------------------------------------------

def _free_port() -> int:
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


# ``_free_port`` closes the probe socket before the coordinator process
# binds the port, so another process can steal it in between; the
# coordinator then dies with EADDRINUSE.  ``launch_local`` retries the
# whole bring-up on a fresh port when a failing process's output matches
# these markers (gRPC and raw-errno spellings).
EADDRINUSE_MARKERS = ("EADDRINUSE", "address already in use",
                      "Address already in use", "Failed to listen")
LAUNCH_PORT_RETRIES = 3


def _is_addr_in_use(text: str) -> bool:
    return any(m in text for m in EADDRINUSE_MARKERS)


def _src_root() -> str:
    # .../src/repro/parallel/distributed.py -> .../src
    return os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))


def launch_local(num_processes: int, devices_per_process: int,
                 argv: Sequence[str], *, env: Optional[Dict[str, str]] = None,
                 timeout_s: float = 900.0,
                 port_retries: int = LAUNCH_PORT_RETRIES) -> List[str]:
    """Run ``argv`` as ``num_processes`` cooperating local processes, each
    seeing ``devices_per_process`` forced host devices.

    Every process gets ``REPRO_COORDINATOR`` (a free localhost port),
    ``REPRO_NUM_PROCESSES`` and ``REPRO_PROCESS_ID``, plus ``XLA_FLAGS``
    rewritten to ``--xla_force_host_platform_device_count=D`` — the command
    itself must call ``initialize()`` before using jax.  Returns the
    captured stdout+stderr of each process (index-ordered); raises
    ``RuntimeError`` with the failing process's output on any non-zero
    exit, and ``NotImplementedError`` when the failure is the platform
    lacking multi-process CPU collectives (so callers can skip, not fail).

    The free-port probe closes its socket before the coordinator binds,
    so the port can be stolen in between; a failure whose output matches
    ``EADDRINUSE_MARKERS`` retries the whole bring-up on a fresh port, up
    to ``port_retries`` times, instead of failing the launch.
    """
    if num_processes < 1 or devices_per_process < 1:
        raise ValueError(f"need at least 1 process and 1 device, got "
                         f"{num_processes} x {devices_per_process}")
    for attempt in range(port_retries + 1):
        try:
            return _launch_once(num_processes, devices_per_process, argv,
                                env=env, timeout_s=timeout_s)
        except NotImplementedError:
            raise                           # platform gap, not a port race
        except RuntimeError as e:
            if attempt < port_retries and _is_addr_in_use(str(e)):
                continue                    # lost the race: fresh port
            raise
    raise AssertionError("unreachable")     # loop always returns or raises


def _launch_once(num_processes: int, devices_per_process: int,
                 argv: Sequence[str], *, env: Optional[Dict[str, str]],
                 timeout_s: float) -> List[str]:
    port = _free_port()
    base = dict(os.environ)
    base.update(env or {})
    xla = [f for f in base.get("XLA_FLAGS", "").split()
           if not f.startswith("--xla_force_host_platform_device_count")]
    xla.append(f"--xla_force_host_platform_device_count={devices_per_process}")
    base["XLA_FLAGS"] = " ".join(xla)
    base["PYTHONPATH"] = os.pathsep.join(
        p for p in (_src_root(), base.get("PYTHONPATH", "")) if p)

    procs = []
    for i in range(num_processes):
        e = dict(base)
        e[ENV_COORDINATOR] = f"localhost:{port}"
        e[ENV_NUM_PROCESSES] = str(num_processes)
        e[ENV_PROCESS_ID] = str(i)
        procs.append(subprocess.Popen(
            list(argv), env=e, stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT, text=True))

    deadline = time.monotonic() + timeout_s
    outs: List[Optional[str]] = [None] * num_processes
    try:
        for i, p in enumerate(procs):
            left = deadline - time.monotonic()
            outs[i], _ = p.communicate(timeout=max(1.0, left))
    except subprocess.TimeoutExpired:
        for p in procs:
            p.kill()
        for i, p in enumerate(procs):
            if outs[i] is None:
                outs[i] = (p.communicate()[0] or "")
        raise RuntimeError(
            f"multi-process launch timed out after {timeout_s:.0f}s; "
            f"process outputs:\n" + "\n---\n".join(o or "" for o in outs))
    failed = [i for i, p in enumerate(procs) if p.returncode != 0]
    if failed:
        blob = "\n---\n".join(f"[proc {i} rc={procs[i].returncode}]\n"
                              f"{outs[i]}" for i in failed)
        if any(m in (outs[i] or "") for i in failed
               for m in UNSUPPORTED_MARKERS):
            raise NotImplementedError(
                f"this platform lacks multi-process CPU collectives "
                f"(gloo): \n{blob}")
        raise RuntimeError(f"multi-process launch failed:\n{blob}")
    return [o or "" for o in outs]


# ---------------------------------------------------------------------------
# Fixed-workload stream worker: the layout-comparison probe.
# ---------------------------------------------------------------------------

def _stream_worker(out_path: str, *, trials: int, chunk: int, seed: int,
                   precision: float) -> None:
    """Run the fixed acceptance workload (paper-headline + Fast Paxos at
    n=11, 2-way race at Δ=0.2 ms) through ``race_stream`` on the global
    trial mesh and — from process 0 — dump the merged ``StreamSummary``
    plus grid metadata to ``out_path`` (npz).

    The workload is pinned so two *layouts* of the same global device count
    (2x4 vs 1x8) are comparable bit-for-bit: same global key, same chunking,
    same per-global-device fold-in keys."""
    dinfo = initialize()
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.quorum import QuorumSpec
    from repro.montecarlo import build_mask_table, streaming
    from repro.parallel import sharding as psharding

    table = build_mask_table([QuorumSpec.paper_headline(11),
                              QuorumSpec.fast_paxos(11)])
    offsets = jnp.array([0.0, 0.2], jnp.float32)
    mesh = psharding.trial_mesh()        # global devices, every process
    t0 = time.perf_counter()
    state = streaming.race_stream(jax.random.PRNGKey(seed), table, offsets,
                                  n=11, k_proposers=2, trials=trials,
                                  chunk=chunk, precision=precision,
                                  shard=mesh)
    jax.block_until_ready(state.hist)
    wall = time.perf_counter() - t0
    # hop off the global mesh before querying quantiles: np leaves make the
    # sketch math process-local (identical everywhere — state is replicated)
    host = jax.tree_util.tree_map(lambda x: np.asarray(x), state)
    if dinfo.process_index != 0:
        return
    qs = np.asarray(host.quantile(jnp.array([0.5, 0.999, 0.9999])))
    np.savez(out_path,
             n_trials=np.asarray(host.n_trials),
             n_fast=np.asarray(host.n_fast),
             n_recovery=np.asarray(host.n_recovery),
             n_undecided=np.asarray(host.n_undecided),
             hist=np.asarray(host.hist),
             max_ms=np.asarray(host.max_ms),
             mean_ms=np.asarray(host.mean_ms),
             p50_ms=qs[0], p999_ms=qs[1], p9999_ms=qs[2],
             wall_s=np.float64(wall),
             process_count=np.int64(dinfo.process_count),
             global_devices=np.int64(dinfo.global_device_count))


def run_stream_layout(num_processes: int, devices_per_process: int,
                      out_path: str, *, trials: int = 50_011,
                      chunk: int = 2_048, seed: int = 0,
                      precision: float = 0.01,
                      timeout_s: float = 600.0) -> Dict[str, "object"]:
    """Launch the fixed stream worker on an (N processes x D devices) local
    grid and return process 0's merged summary as an {name: ndarray} dict.
    The acceptance contract (tests/test_multihost.py, the
    ``stream.multihost`` benchmark row): any two layouts of the same
    N*D are bit-identical in counts and histogram."""
    import numpy as np
    launch_local(
        num_processes, devices_per_process,
        [sys.executable, "-m", "repro.parallel.distributed", "stream",
         "--out", out_path, "--trials", str(trials), "--chunk", str(chunk),
         "--seed", str(seed), "--precision", str(precision)],
        timeout_s=timeout_s)
    with np.load(out_path) as z:
        return {k: z[k] for k in z.files}


# ---------------------------------------------------------------------------
# CLI.
# ---------------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.parallel.distributed",
        description="multi-host trial-mesh launcher / worker")
    sub = ap.add_subparsers(dest="cmd", required=True)

    lp = sub.add_parser("launch", help="run a command as N local processes "
                                       "x D forced host devices each")
    lp.add_argument("--processes", type=int, default=2)
    lp.add_argument("--devices-per-process", type=int, default=4)
    lp.add_argument("--timeout", type=float, default=900.0)
    lp.add_argument("argv", nargs=argparse.REMAINDER,
                    help="command to run (prefix with --)")

    sp = sub.add_parser("stream", help="fixed-workload race_stream worker "
                                       "(called by run_stream_layout)")
    sp.add_argument("--out", required=True)
    sp.add_argument("--trials", type=int, default=50_011)
    sp.add_argument("--chunk", type=int, default=2_048)
    sp.add_argument("--seed", type=int, default=0)
    sp.add_argument("--precision", type=float, default=0.01)

    st = sub.add_parser("selftest", help="probe: psum of global device "
                                         "indices across the grid")
    st.add_argument("--quiet", action="store_true")

    args = ap.parse_args(argv)
    if args.cmd == "launch":
        cmd = list(args.argv)
        if cmd and cmd[0] == "--":
            cmd = cmd[1:]
        if not cmd:
            ap.error("launch needs a command after --")
        outs = launch_local(args.processes, args.devices_per_process, cmd,
                            timeout_s=args.timeout)
        for i, o in enumerate(outs):
            sys.stdout.write(f"--- proc {i} ---\n{o}")
        return 0
    if args.cmd == "stream":
        _stream_worker(args.out, trials=args.trials, chunk=args.chunk,
                       seed=args.seed, precision=args.precision)
        return 0
    # selftest
    dinfo = initialize()
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.parallel import sharding as psharding

    mesh = psharding.trial_mesh()
    ndev = mesh.shape[psharding.TRIAL_AXIS]
    f = psharding.shard_map(
        lambda x: jax.lax.psum(
            jnp.asarray(jax.lax.axis_index(psharding.TRIAL_AXIS), jnp.int32),
            psharding.TRIAL_AXIS),
        mesh=mesh, in_specs=P(), out_specs=P())
    got = int(jax.jit(f)(jnp.int32(0)))
    want = ndev * (ndev - 1) // 2
    ok = got == want
    if not args.quiet:
        print(f"proc {dinfo.process_index}/{dinfo.process_count}: "
              f"{dinfo.global_device_count} global devices, "
              f"psum(axis_index) = {got} (want {want}) "
              f"{'OK' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
