"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Models annotate tensors with *logical* axis names; this module maps them to
mesh axes through a rule table with **divisibility fallbacks**: each logical
name carries a preference list of mesh axis specs, and the first candidate
whose total size divides the tensor dimension wins.  This is how one rule
table serves ten architectures — e.g. `heads` shards over 'model' for
nemotron (48 % 16 == 0) but falls back to replicated for arctic (56 heads),
whose attention then runs data-parallel while its weights stay FSDP-sharded
on 'data' (DESIGN.md §5).

Baseline layout (paper-faithful starting point for §Perf):
  batch        -> ('pod', 'data')     pure DP across pods (DCN), DP within
  weight d_model -> 'data'            FSDP/ZeRO-3: params + opt state sharded
  heads/mlp/experts/vocab -> 'model'  tensor/expert parallelism
  activations d_model / seq -> None   replicated (SP is a §Perf hillclimb)
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                                    # jax >= 0.6: top-level, check_vma kwarg
    from jax import shard_map as _shard_map_impl
    _SHARD_MAP_CHECK_KWARG = "check_vma"
except ImportError:                     # jax 0.4.x: experimental, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map_impl
    _SHARD_MAP_CHECK_KWARG = "check_rep"

AxisSpec = Union[None, str, Tuple[str, ...]]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-portable shard_map (the replication-check kwarg was renamed
    between jax 0.4 and 0.6).  Shared by the MoE expert parallelism
    (``models/moe.py``) and the Monte-Carlo trial sharding
    (``montecarlo/streaming.py``)."""
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs,
                           **{_SHARD_MAP_CHECK_KWARG: check_vma})


# Mesh axis name for the Monte-Carlo trial dimension.  Distinct from the
# model stack's ('pod', 'data', 'model') so a trial mesh can never collide
# with an active model mesh's rule table.
TRIAL_AXIS = "trials"


def trial_mesh(devices: Optional[Sequence] = None) -> Mesh:
    """1-D mesh over all *global* devices for trial-axis sharding.

    ``jax.devices()`` enumerates every process's devices (process-major:
    global index = process_index * local_count + local_index) once
    ``repro.parallel.distributed.initialize()`` has joined a multi-host
    grid — a single-process run sees only its own, so the same mesh
    construction covers both.  The Monte-Carlo trial dimension is
    embarrassingly parallel, so the only collective the streaming engine
    needs is the cross-device summary merge (psum/pmax over
    ``TRIAL_AXIS``), which is integer-exact and therefore also the
    cross-host reduction (DESIGN.md §10)."""
    devices = jax.devices() if devices is None else list(devices)
    return Mesh(np.array(devices), (TRIAL_AXIS,))


def _axis_size(mesh: Mesh, spec: AxisSpec) -> int:
    if spec is None:
        return 1
    if isinstance(spec, str):
        return mesh.shape[spec]
    return int(np.prod([mesh.shape[a] for a in spec]))


@dataclass(frozen=True)
class Rules:
    """logical name -> preference list of mesh axis specs."""
    table: Dict[str, Tuple[AxisSpec, ...]]

    def candidates(self, name: Optional[str]) -> Tuple[AxisSpec, ...]:
        if name is None:
            return (None,)
        if name not in self.table:
            raise KeyError(f"unknown logical axis {name!r}")
        return self.table[name]


def default_rules(sequence_parallel: bool = False,
                  expert_all_to_all: bool = False) -> Rules:
    t: Dict[str, Tuple[AxisSpec, ...]] = {
        # activations
        "batch": (("pod", "data"), ("data",), None),
        "seq": (("model",), None) if sequence_parallel else (None,),
        # context-parallel fallback for attention: when the head count does
        # not divide the 'model' axis (e.g. musicgen's 24 heads on a 16-way
        # axis) the q/scores/output seq dim shards on 'model' instead, so
        # attention compute is never replicated across the model axis.
        "seq_sp": (("model",), None),
        "act_embed": (None,),
        "heads": (("model",), None),
        "kv_heads": (("model",), None),
        "head_dim": (None,),
        "mlp_act": (("model",), None),
        "vocab_act": (("model",), None),
        # KV-cache sequence dim: prefer the widest free sharding.  'data' is
        # taken by batch for decode_32k (cache then shards on 'model'); for
        # long_500k (batch=1) the cache spreads over all 256 chips.
        "cache_seq": (("data", "model"), ("model",), ("data",), None),
        # weights
        "embed": (("data",), None),          # FSDP dim (d_model of weights)
        # the FSDP dim *after* the per-layer gather (unsharded); used by
        # fsdp_use() to force the all-gather to happen on the bf16 cast of a
        # weight rather than its f32 master copy (halves AG link bytes).
        "embed_full": (None,),
        # embed-table d_model: sharded on 'model' so the token gather needs
        # no collective (indices are batch-sharded, operand dim-sharded).
        "embed_td": (("model",), None),
        "mlp": (("model",), None),
        "w_heads": (("model",), None),
        "w_kv_heads": (("model",), None),
        "w_vocab": (("model",), None),
        "experts": (("model",),),
        "kv_lora": (None,),
        "ssm_inner": (("model",), None),
        "ssm_state": (None,),
        "conv": (None,),
        "norm": (None,),
    }
    return Rules(t)


# ---------------------------------------------------------------------------
# Active context.
# ---------------------------------------------------------------------------

class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[Rules] = None


_CTX = _Ctx()


@contextlib.contextmanager
def sharding_ctx(mesh: Optional[Mesh], rules: Optional[Rules] = None):
    """Activate (mesh, rules) for logical constraints; None mesh = no-op."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    _CTX.rules = rules or (default_rules() if mesh is not None else None)
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def spec_for(shape: Sequence[int], axes: Sequence[Optional[str]],
             mesh: Optional[Mesh] = None, rules: Optional[Rules] = None) -> P:
    """Resolve logical axes to a PartitionSpec with divisibility fallback."""
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    if mesh is None or rules is None:
        return P()
    assert len(shape) == len(axes), (shape, axes)
    mesh_axes = set(mesh.shape.keys())
    used: set = set()
    out = []
    for dim, name in zip(shape, axes):
        chosen: AxisSpec = None
        for cand in rules.candidates(name):
            flat = (cand,) if isinstance(cand, str) else (cand or ())
            if any(a not in mesh_axes for a in flat):
                continue                      # e.g. 'pod' on a single-pod mesh
            if any(a in used for a in flat):
                continue
            if cand is not None and dim % _axis_size(mesh, cand) != 0:
                continue
            chosen = cand
            break
        flat = (chosen,) if isinstance(chosen, str) else (chosen or ())
        used.update(flat)
        out.append(chosen)
    return P(*out)


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Apply a logical sharding constraint (identity without a mesh)."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = spec_for(x.shape, axes, mesh, _CTX.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def fsdp_use(w: jax.Array, axes: Sequence[Optional[str]], dtype) -> jax.Array:
    """Cast a weight to its compute dtype and release the FSDP ('embed')
    sharding dim — in that order.

    The per-layer FSDP all-gather then moves the bf16 CAST of the weight
    instead of the f32 master copy: half the link bytes for every weight
    gather, on any backend (EXPERIMENTS.md §Perf, deepseek_7b iteration 2).
    Other dims ('w_heads', 'mlp', ... on 'model') keep their sharding.
    """
    w = w.astype(dtype)
    if _CTX.mesh is None:
        return w
    ax2 = tuple("embed_full" if a == "embed" else a for a in axes)
    return constrain(w, ax2)


def named_sharding(shape: Sequence[int], axes: Sequence[Optional[str]],
                   mesh: Mesh, rules: Optional[Rules] = None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, axes, mesh,
                                        rules or default_rules()))


def tree_shardings(tree, tree_axes, mesh: Mesh,
                   rules: Optional[Rules] = None):
    """Map (pytree of arrays/ShapeDtypeStructs, matching pytree of
    logical-axes tuples) to NamedShardings — used for jit in_shardings of
    params and optimizer state.  The first tree's leaves must be array-like
    (have ``.shape``); the axes tree mirrors its structure with tuple
    leaves."""
    rules = rules or default_rules()
    return jax.tree.map(
        lambda arr, ax: named_sharding(arr.shape, ax, mesh, rules),
        tree, tree_axes)
