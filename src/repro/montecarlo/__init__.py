"""Batched Monte-Carlo scenario engine for the Fast Flexible Paxos evaluation.

Layout (DESIGN.md §2):

  ``latency``    pluggable per-message delay models, registered as JAX pytrees
                 so their parameters are *traced* (no recompile on change):
                 shifted-lognormal (EC2 same-region fit), Pareto heavy tail,
                 multi-region WAN delay matrix, and a loss wrapper.
  ``engine``     the core K-proposer conflict race.  Quorum thresholds
                 (q1, q2c, q2f) are traced arrays: a whole table of specs is
                 evaluated under one ``vmap`` with a single XLA compile — the
                 expensive sampling + sorting work is shared across specs and
                 the per-spec decision logic reduces to gathers and compares.
  ``scenarios``  named scenario builders (conflict-free, K-way race, mixed
                 workload, WAN, lossy acceptors) bundling a delay model with
                 race geometry.

Beyond cardinality thresholds, the engine scores *general* quorum systems
(grids, weighted voting, hand-built explicit sets) encoded as membership
masks: ``build_mask_table`` batches any mix of systems into traced (M, G, n)
weight / (M, G) threshold arrays, and ``race_masked`` / ``fast_path_masked``
evaluate all G quorums of all M systems in the same single-compile pass —
bit-identical to the threshold path on cardinality specs (DESIGN.md §2).

The old per-spec API lives on as a compatibility shim in
``repro.core.jax_sim``.
"""
from . import engine, latency, scenarios  # noqa: F401
from .engine import (build_mask_table, build_spec_table,  # noqa: F401
                     classic_path, fast_path, fast_path_masked, race,
                     race_masked, summarize)
from .latency import (CrashedDelay, LossyDelay, ParetoDelay,  # noqa: F401
                      ShiftedLognormalDelay, WanDelay)
from .scenarios import (Scenario, conflict_free, grid_wan,  # noqa: F401
                        k_way_race, lossy_acceptors, mixed_workload, wan,
                        weighted_acceptors)
