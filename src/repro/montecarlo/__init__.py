"""Batched Monte-Carlo scenario engine for the Fast Flexible Paxos evaluation.

Layout (DESIGN.md §2):

  ``latency``    pluggable per-message delay models, registered as JAX pytrees
                 so their parameters are *traced* (no recompile on change):
                 shifted-lognormal (EC2 same-region fit), Pareto heavy tail,
                 multi-region WAN delay matrix, and a loss wrapper.
  ``engine``     the core K-proposer conflict race.  Quorum thresholds
                 (q1, q2c, q2f) are traced arrays: a whole table of specs is
                 evaluated under one ``vmap`` with a single XLA compile — the
                 expensive sampling + sorting work is shared across specs and
                 the per-spec decision logic reduces to gathers and compares.
  ``scenarios``  named scenario builders (conflict-free, K-way race, mixed
                 workload, WAN, lossy acceptors) bundling a delay model with
                 race geometry.

Every quorum system — cardinality thresholds, grids, weighted voting,
hand-built explicit sets — lowers to ONE encoding: the membership-mask
table (``build_mask_table``, traced (M, G, n) weights / (M, G) thresholds).
``race`` / ``fast_path`` / ``classic_path`` evaluate all G quorums of all M
systems in a single-compile pass; all-cardinality tables carry a ``"q"``
specialization that lowers to k-th-order-statistic gathers, bit-identical
to the general masked path (DESIGN.md §2).

Past one chunk of device memory, the same evaluation streams:
``streaming.race_stream`` / ``fast_path_stream`` / ``classic_path_stream``
reduce chunked trials into a fixed-size mergeable ``StreamSummary``
(DDSketch-style quantile histogram + online counts), sharding the trial
axis over devices — 10^7+ trials on a laptop, tail percentiles included
(DESIGN.md §7).

Beyond i.i.d. draws, ``traces.EmpiricalDelay`` replays a measured latency
trace as a traced quantile table, and ``regimes.MarkovRegimes`` modulates
a streamed run through named failure epochs (baseline / degraded /
partitioned / ...), returning per-regime ``RegimeStreamSummary`` slices —
both declaratively serializable through the ``latency`` registry
(DESIGN.md §12).

The declarative front door over this engine (plus the model checker and
the discrete-event simulator) is ``repro.api.Experiment``; the
quorum-space Pareto frontier built on the streaming drivers is
``repro.frontier`` (DESIGN.md §8).
"""
from . import engine, latency, regimes, scenarios  # noqa: F401
from . import streaming, traces  # noqa: F401
from .engine import (build_mask_table, classic_path,  # noqa: F401
                     fast_path, race, summarize)
from .latency import (CrashedDelay, LossyDelay, ParetoDelay,  # noqa: F401
                      ShiftedLognormalDelay, WanDelay, delay_from_config,
                      delay_kinds, delay_to_config)
from .regimes import MarkovRegimes, RegimeStreamSummary  # noqa: F401
from .scenarios import (RunSpec, Scenario, conflict_free,  # noqa: F401
                        grid_wan, k_way_race, lossy_acceptors,
                        mixed_workload, wan, weighted_acceptors)
from .streaming import (StreamSummary, classic_path_stream,  # noqa: F401
                        fast_path_stream, race_stream)
from .traces import EmpiricalDelay  # noqa: F401
