"""Trace-driven delays: replay a measured latency trace as a quantile table.

Closed-form delay models (``latency.py``) are convenient but every real
deployment study starts from a *measured* trace — the methodology of
"The Performance of Paxos and Fast Paxos" (arxiv 1308.1358), which grounds
its simulations in packet-level RTT measurements.  ``EmpiricalDelay``
brings that trace into the engine without giving up the engine's core
contract (one compile per shape, parameters traced):

  fit (host)     ``EmpiricalDelay.from_trace`` compresses a trace of any
                 length into a FIXED-SIZE quantile grid: ``probs`` is a
                 uniform CDF grid in [0, 1], ``values_ms[i]`` the trace's
                 empirical ``probs[i]``-quantile.  The grid size is a
                 static shape; the grid *contents* are traced leaves, so
                 swapping one measured trace for another re-enters the
                 same compile.
  sample (jit)   inverse-CDF: draw u ~ U[0, 1), locate its bracket with
                 ``jnp.searchsorted`` over ``probs``, and interpolate
                 linearly between the bracketing quantile values.  Sampled
                 quantiles therefore converge to the trace's empirical
                 quantiles up to the grid's own resolution (1 / (Q - 1)
                 in probability), which the property tests pin against the
                 stream sketch's ``precision``.

``EmpiricalDelay`` is a registered pytree with the same ``sample_hops``
interface as every other model, so it composes with ``LossyDelay`` /
``CrashedDelay`` wrappers and drops into any ``Scenario`` / ``Workload``
/ regime environment unchanged.  Loss should be modeled by the wrapper,
not by baking ``LOST_MS`` sentinels into the trace — interpolation across
a finite/sentinel bracket would manufacture delays that never occurred
(``from_trace`` rejects non-finite samples for exactly that reason).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .latency import PROPOSAL

# Default quantile-grid size: 256 points resolve probability to ~0.4%,
# comfortably below the stream sketch's default 1% relative error, while
# keeping the lookup table small enough to live in registers/VMEM.
DEFAULT_GRID = 256


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class EmpiricalDelay:
    """Inverse-CDF replay of a measured one-way latency trace.

    ``probs``      (Q,) strictly increasing CDF grid, probs[0] = 0 and
                   probs[-1] = 1 (uniform when built by ``from_trace``)
    ``values_ms``  (Q,) non-decreasing empirical quantiles of the trace

    Both are traced leaves: refitting to a new trace of the same grid size
    never recompiles.  Hop ``kind`` is ignored — the trace is a single
    marginal distribution; topology-aware replay composes a per-regime or
    per-link ``EmpiricalDelay`` via the regime layer / ``WanDelay``.
    """

    probs: jax.Array
    values_ms: jax.Array

    def sample_hops(self, key: jax.Array, shape,
                    kind: str = PROPOSAL) -> jax.Array:
        u = jax.random.uniform(key, shape, dtype=jnp.float32)
        q = self.probs.shape[0]
        # bracket: probs[j-1] <= u < probs[j]
        j = jnp.clip(jnp.searchsorted(self.probs, u, side="right"), 1, q - 1)
        p_lo = self.probs[j - 1]
        p_hi = self.probs[j]
        v_lo = self.values_ms[j - 1]
        v_hi = self.values_ms[j]
        w = (u - p_lo) / jnp.maximum(p_hi - p_lo, jnp.float32(1e-12))
        return v_lo + w * (v_hi - v_lo)

    def tree_flatten(self):
        return (self.probs, self.values_ms), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)

    # -- host-side construction / validation ------------------------------
    @classmethod
    def from_trace(cls, trace_ms: Sequence[float],
                   n_quantiles: int = DEFAULT_GRID) -> "EmpiricalDelay":
        """Compress a measured trace (any length >= 1) into a fixed-size
        quantile grid.  A degenerate single-sample trace yields a constant
        delay; non-finite samples are rejected (model loss with
        ``LossyDelay``, not sentinel values in the trace)."""
        t = np.asarray(trace_ms, np.float64).ravel()
        if t.size < 1:
            raise ValueError("trace must contain at least one sample")
        if not np.all(np.isfinite(t)):
            raise ValueError(
                "trace contains non-finite samples; drop them and model "
                "loss with LossyDelay instead of sentinel delays")
        if np.any(t < 0):
            raise ValueError("trace contains negative delays")
        if n_quantiles < 2:
            raise ValueError(f"n_quantiles must be >= 2, got {n_quantiles}")
        probs = np.linspace(0.0, 1.0, n_quantiles)
        values = np.quantile(t, probs)
        return cls(probs=jnp.asarray(probs, jnp.float32),
                   values_ms=jnp.asarray(values, jnp.float32)).validate()

    def validate(self) -> "EmpiricalDelay":
        """Host-side invariant checks (concrete arrays only): matching 1-D
        shapes, probs strictly increasing through [0, 1], values monotone
        non-decreasing."""
        p = np.asarray(self.probs, np.float64)
        v = np.asarray(self.values_ms, np.float64)
        if p.ndim != 1 or p.shape != v.shape or p.size < 2:
            raise ValueError(
                f"probs/values_ms must be matching 1-D grids of >= 2 "
                f"points, got {p.shape} / {v.shape}")
        if not (np.all(np.diff(p) > 0) and p[0] >= 0.0 and p[-1] <= 1.0):
            raise ValueError("probs must be strictly increasing within "
                             "[0, 1]")
        if np.any(np.diff(v) < 0):
            raise ValueError("values_ms must be non-decreasing (a quantile "
                             "function cannot invert)")
        if not np.all(np.isfinite(v)):
            raise ValueError("values_ms must be finite; model loss with "
                             "LossyDelay")
        return self

    def quantile(self, q) -> jax.Array:
        """The model's own quantile function (linear interpolation over the
        grid) — what sampled quantiles converge to."""
        return jnp.interp(jnp.asarray(q, jnp.float32), self.probs,
                          self.values_ms)


def _empirical_to_config(model: EmpiricalDelay) -> dict:
    return {"probs": np.asarray(model.probs, np.float64).tolist(),
            "values_ms": np.asarray(model.values_ms, np.float64).tolist()}


def _empirical_from_config(cfg: dict, n=None) -> EmpiricalDelay:
    cfg = dict(cfg)
    if "trace_ms" in cfg:           # raw-trace form: fit at load time
        return EmpiricalDelay.from_trace(
            cfg["trace_ms"], n_quantiles=int(cfg.get("n_quantiles",
                                                     DEFAULT_GRID)))
    return EmpiricalDelay(
        probs=jnp.asarray(cfg["probs"], jnp.float32),
        values_ms=jnp.asarray(cfg["values_ms"], jnp.float32)).validate()


# registered here (not in latency.py) to keep latency.py import-light;
# importing repro.montecarlo pulls this module in and completes the
# registry.
from .latency import register_delay_model  # noqa: E402

register_delay_model("empirical", EmpiricalDelay,
                     _empirical_to_config, _empirical_from_config)
