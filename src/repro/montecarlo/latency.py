"""Pluggable network-delay models for the Monte-Carlo engine.

Each model is a frozen dataclass registered as a JAX pytree whose *leaves are
the distribution parameters*.  That is the load-bearing design decision: the
engine jits over the model, so parameters are traced operands — sweeping a
delay parameter (or swapping fitted values per deployment) never triggers a
recompile, and models can ride through ``vmap``/``grad`` like any other
operand.  Only structural fields (e.g. the number of WAN regions) are static.

The engine asks a model for delays through one method::

    sample_hops(key, shape, kind)

``kind`` names the hop so topology-aware models can vary the distribution per
endpoint pair; i.i.d. models ignore it.  Kinds used by the engine:

  ``proposal``         proposer k -> acceptor a, shape (S, n, K)
  ``to_learner``       acceptor a -> learner,    shape (S, n)
  ``from_coordinator`` coordinator -> acceptor,  shape (S, n)
  ``to_coordinator``   acceptor -> coordinator,  shape (S, n)
  ``client_to_leader`` client -> leader relay,   shape (S,)

A delay >= ``LOST_MS`` means the message never arrives (used by
``LossyDelay``); the engine treats such paths as missing votes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Sentinel one-way delay for a dropped message.  Anything this large is
# treated as "never arrived" by the engine (real delays are a few ms).
LOST_MS = 1e9

PROPOSAL = "proposal"
TO_LEARNER = "to_learner"
FROM_COORDINATOR = "from_coordinator"
TO_COORDINATOR = "to_coordinator"
CLIENT_TO_LEADER = "client_to_leader"


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class ShiftedLognormalDelay:
    """one_way = base + LogNormal(mu, sigma) ms — the EC2 same-region m5a fit
    used by the discrete-event simulator (``simulator.LatencyModel``)."""

    base_ms: float = 0.25
    mu: float = -1.20
    sigma: float = 0.55

    def as_tuple(self) -> Tuple[float, float, float]:
        return (self.base_ms, self.mu, self.sigma)

    def sample_hops(self, key: jax.Array, shape, kind: str = PROPOSAL) -> jax.Array:
        return self.base_ms + jnp.exp(
            self.mu + self.sigma * jax.random.normal(key, shape))

    def tree_flatten(self):
        return (self.base_ms, self.mu, self.sigma), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class ParetoDelay:
    """Heavy-tailed one-way delay: base + scale * (Pareto(alpha) - 1).

    Pareto(alpha) has support [1, inf), so delays start exactly at ``base_ms``
    and fall off polynomially — the classic model for congested links where
    the lognormal's tail is too optimistic.  ``alpha > 1`` keeps the mean
    finite (mean = base + scale / (alpha - 1))."""

    base_ms: float = 0.25
    scale_ms: float = 0.12
    alpha: float = 2.2

    def sample_hops(self, key: jax.Array, shape, kind: str = PROPOSAL) -> jax.Array:
        return self.base_ms + self.scale_ms * (
            jax.random.pareto(key, self.alpha, shape=shape) - 1.0)

    def tree_flatten(self):
        return (self.base_ms, self.scale_ms, self.alpha), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class WanDelay:
    """Multi-region WAN model for geo-distributed deployments.

    ``oneway_ms`` is an (R, R) matrix of deterministic one-way propagation
    delays between regions; every message additionally pays a lognormal
    in-region jitter.  Placement:

      ``acceptor_region``  (n,) region id per acceptor
      ``proposer_region``  (K,) region id per proposer (also the clients)
      ``learner_region``   scalar region id of the learner / coordinator

    All placement arrays are leaves, so moving replicas between regions is a
    traced change (one compile covers every placement of the same shape).
    """

    oneway_ms: jax.Array            # (R, R) float
    acceptor_region: jax.Array      # (n,) int32
    proposer_region: jax.Array      # (K,) int32
    learner_region: jax.Array = field(default_factory=lambda: jnp.int32(0))
    jitter_mu: float = -2.0
    jitter_sigma: float = 0.4

    def _jitter(self, key: jax.Array, shape) -> jax.Array:
        return jnp.exp(self.jitter_mu
                       + self.jitter_sigma * jax.random.normal(key, shape))

    def _base(self, shape, kind: str) -> jax.Array:
        ow, acc = self.oneway_ms, self.acceptor_region
        if kind == PROPOSAL:                   # (S, n, K)
            # tolerate a requested K different from the placement table
            # (e.g. the conflict-free fast path asks for one proposer)
            k_req = shape[-1]
            prop = self.proposer_region[
                jnp.arange(k_req) % self.proposer_region.shape[0]]
            return ow[prop[None, :], acc[:, None]][None]
        if kind in (TO_LEARNER, TO_COORDINATOR):      # (S, n)
            return ow[acc, self.learner_region][None]
        if kind == FROM_COORDINATOR:                  # (S, n)
            return ow[self.learner_region, acc][None]
        if kind == CLIENT_TO_LEADER:                  # (S,)
            return ow[self.proposer_region[0], self.learner_region]
        raise ValueError(f"unknown hop kind {kind!r}")

    def sample_hops(self, key: jax.Array, shape, kind: str = PROPOSAL) -> jax.Array:
        return jnp.broadcast_to(self._base(shape, kind), shape) \
            + self._jitter(key, shape)

    def tree_flatten(self):
        leaves = (self.oneway_ms, self.acceptor_region, self.proposer_region,
                  self.learner_region, self.jitter_mu, self.jitter_sigma)
        return leaves, None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)

    @classmethod
    def symmetric(cls, inter_region_ms: float, n: int, k_proposers: int,
                  n_regions: int = 3, **kw) -> "WanDelay":
        """All region pairs ``inter_region_ms`` apart, zero intra-region
        propagation; acceptors round-robin over regions, proposer k in
        region k mod R, learner in region 0."""
        r = n_regions
        ow = inter_region_ms * (1.0 - jnp.eye(r))
        return cls(oneway_ms=ow,
                   acceptor_region=jnp.arange(n, dtype=jnp.int32) % r,
                   proposer_region=jnp.arange(k_proposers, dtype=jnp.int32) % r,
                   learner_region=jnp.int32(0), **kw)


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class LossyDelay:
    """Wrap any delay model with i.i.d. message loss: with probability
    ``loss_prob`` a hop's delay becomes ``LOST_MS`` (the message is dropped).
    Mirrors ``simulator.LatencyModel.loss_prob``."""

    inner: object
    loss_prob: float = 0.01

    def sample_hops(self, key: jax.Array, shape, kind: str = PROPOSAL) -> jax.Array:
        k_delay, k_loss = jax.random.split(key)
        d = self.inner.sample_hops(k_delay, shape, kind)
        lost = jax.random.uniform(k_loss, shape) < self.loss_prob
        return jnp.where(lost, jnp.asarray(LOST_MS, d.dtype), d)

    def tree_flatten(self):
        return (self.inner, self.loss_prob), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class CrashedDelay:
    """Per-acceptor fault injection: every hop touching a crashed acceptor
    is lost (delay ``LOST_MS``), so crashed nodes never vote and their 2bs
    never arrive.  ``crashed`` is an (n,) bool leaf — which acceptors are
    down is a traced operand, so sweeping crash sets (e.g. a grid row vs a
    grid column) reuses one compile.  Mirrors ``FastPaxosSim(crashed=...)``.
    """

    inner: object
    crashed: jax.Array              # (n,) bool

    def sample_hops(self, key: jax.Array, shape, kind: str = PROPOSAL) -> jax.Array:
        d = self.inner.sample_hops(key, shape, kind)
        if kind == PROPOSAL:                               # (S, n, K)
            mask = self.crashed[None, :, None]
        elif kind in (TO_LEARNER, FROM_COORDINATOR, TO_COORDINATOR):
            mask = self.crashed[None, :]                   # (S, n)
        else:                                              # client -> leader
            return d
        return jnp.where(mask, jnp.asarray(LOST_MS, d.dtype), d)

    def tree_flatten(self):
        return (self.inner, self.crashed), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


def default_delay() -> ShiftedLognormalDelay:
    """The paper-§6 EC2 fit shared with the discrete-event simulator."""
    return ShiftedLognormalDelay()


# ---------------------------------------------------------------------------
# Named registry + declarative serialization (DESIGN.md §12).
#
# Every model registers a ``kind`` name plus to/from-config codecs, so a
# whole delay stack — wrappers included — round-trips through plain JSON:
#
#     {"kind": "lossy", "loss_prob": 0.02,
#      "inner": {"kind": "empirical", "probs": [...], "values_ms": [...]}}
#
# ``delay_from_config`` optionally takes the cluster size ``n`` for kinds
# whose placement depends on it (the symmetric WAN shorthand).  The
# trace-driven ``empirical`` kind registers itself from ``traces.py``.
# ---------------------------------------------------------------------------

_DELAY_REGISTRY: Dict[str, Tuple[type, Callable, Callable]] = {}


def register_delay_model(kind: str, cls: type, to_config: Callable,
                         from_config: Callable) -> None:
    """Register a delay-model kind: ``to_config(model) -> dict`` (without
    the ``kind`` key) and ``from_config(cfg, n=None) -> model``."""
    _DELAY_REGISTRY[kind] = (cls, to_config, from_config)


def delay_kinds() -> Tuple[str, ...]:
    return tuple(sorted(_DELAY_REGISTRY))


def delay_to_config(model) -> Optional[dict]:
    """Serialize any registered delay model (wrappers recurse) to a plain
    JSON-ready dict; ``None`` passes through (= the engine default)."""
    if model is None:
        return None
    for kind, (cls, to_cfg, _) in _DELAY_REGISTRY.items():
        if type(model) is cls:
            return {"kind": kind, **to_cfg(model)}
    raise TypeError(f"unregistered delay model {type(model).__name__}; "
                    f"known kinds: {delay_kinds()}")


def delay_from_config(cfg, n: Optional[int] = None):
    """Inverse of ``delay_to_config``.  Accepts ``None``, an
    already-constructed model (idempotent pass-through), or a
    ``{"kind": ...}`` dict."""
    if cfg is None or not isinstance(cfg, dict):
        return cfg
    kind = cfg.get("kind")
    if kind not in _DELAY_REGISTRY:
        raise ValueError(f"unknown delay kind {kind!r}; "
                         f"known kinds: {delay_kinds()}")
    body = {k: v for k, v in cfg.items() if k != "kind"}
    return _DELAY_REGISTRY[kind][2](body, n)


def _f(x) -> float:
    return float(np.asarray(x))


register_delay_model(
    "lognormal", ShiftedLognormalDelay,
    lambda m: {"base_ms": _f(m.base_ms), "mu": _f(m.mu),
               "sigma": _f(m.sigma)},
    lambda cfg, n=None: ShiftedLognormalDelay(**cfg))

register_delay_model(
    "pareto", ParetoDelay,
    lambda m: {"base_ms": _f(m.base_ms), "scale_ms": _f(m.scale_ms),
               "alpha": _f(m.alpha)},
    lambda cfg, n=None: ParetoDelay(**cfg))


def _wan_to_config(m: WanDelay) -> dict:
    return {"oneway_ms": np.asarray(m.oneway_ms, np.float64).tolist(),
            "acceptor_region": np.asarray(m.acceptor_region,
                                          np.int64).tolist(),
            "proposer_region": np.asarray(m.proposer_region,
                                          np.int64).tolist(),
            "learner_region": int(np.asarray(m.learner_region)),
            "jitter_mu": _f(m.jitter_mu), "jitter_sigma": _f(m.jitter_sigma)}


def _wan_from_config(cfg: dict, n: Optional[int] = None) -> WanDelay:
    cfg = dict(cfg)
    if "inter_region_ms" in cfg:    # symmetric shorthand: needs cluster size
        if n is None:
            raise ValueError(
                "the symmetric WAN delay config needs the cluster size; "
                "pass n= (Workload/Experiment configs resolve it for you)")
        kw = {k: cfg[k] for k in ("jitter_mu", "jitter_sigma") if k in cfg}
        return WanDelay.symmetric(float(cfg["inter_region_ms"]), n,
                                  int(cfg.get("k_proposers", 2)),
                                  int(cfg.get("n_regions", 3)), **kw)
    return WanDelay(
        oneway_ms=jnp.asarray(cfg["oneway_ms"], jnp.float32),
        acceptor_region=jnp.asarray(cfg["acceptor_region"], jnp.int32),
        proposer_region=jnp.asarray(cfg["proposer_region"], jnp.int32),
        learner_region=jnp.int32(cfg.get("learner_region", 0)),
        jitter_mu=float(cfg.get("jitter_mu", -2.0)),
        jitter_sigma=float(cfg.get("jitter_sigma", 0.4)))


register_delay_model("wan", WanDelay, _wan_to_config, _wan_from_config)

register_delay_model(
    "lossy", LossyDelay,
    lambda m: {"loss_prob": _f(m.loss_prob),
               "inner": delay_to_config(m.inner)},
    lambda cfg, n=None: LossyDelay(delay_from_config(cfg["inner"], n),
                                   float(cfg.get("loss_prob", 0.01))))

register_delay_model(
    "crashed", CrashedDelay,
    lambda m: {"crashed": np.asarray(m.crashed, bool).astype(int).tolist(),
               "inner": delay_to_config(m.inner)},
    lambda cfg, n=None: CrashedDelay(
        delay_from_config(cfg["inner"], n),
        jnp.asarray(np.asarray(cfg["crashed"], np.int64) != 0)))
