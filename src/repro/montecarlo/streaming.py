"""Streaming, sharded Monte-Carlo trials: fixed memory at any trial count.

The materializing entry points in ``engine`` allocate a per-trial ``(M, S)``
array for every output, capping trials at device memory and making tail
percentiles (p99.9 — the number WAN operators actually provision for)
statistically meaningless at the trial counts that fit.  This module turns
the same per-chunk computation into a **reduction** (DESIGN.md §7):

  chunk scan      ``lax.scan`` draws, decides and *reduces* one chunk of
                  trials per step, carrying only a fixed-size summary state
                  — peak allocation is one chunk, independent of ``trials``.
  sketch          latency quantiles come from a DDSketch-style fixed-size
                  log-bucket histogram with a guaranteed relative error
                  (``precision``); bucket counts are integers, so sketch
                  merge is exact, associative and commutative.
  shard_map       the trial axis shards over the *global* device grid
                  (``parallel.sharding.trial_mesh`` over ``jax.devices()``
                  — all devices of all processes when ``jax.distributed``
                  is initialized, see ``parallel.distributed``); the
                  cross-device reduction is the summary merge (psum
                  counts/histograms, pmax maxima, count-weighted mean
                  combine), which is already a valid cross-host reduction.

``race_stream`` / ``fast_path_stream`` / ``classic_path_stream`` mirror the
materializing entry points;  ``trials <= chunk`` on a single device falls
back to the materializing path itself (same compile, bit-identical draws)
and reduces its output — the old behaviour survives as the small-T special
case.  Chunk c of a multi-chunk stream draws from ``fold_in(key, c)``;
global device d of a sharded stream re-keys through a second fold-in level,
``fold_in(fold_in(key, DEVICE_FOLD_DOMAIN), d)``, so device key streams can
never collide with chunk keys of a long unsharded stream (chunk indices and
device indices live in *disjoint* fold-in domains — DESIGN.md §10).  A
streamed run is therefore reproducible for a given (trials, chunk, global
device count) — and layout-invariant across process grids of the same
global device count: per-device trial counts and keys depend only on the
global index ``process_index * local_count + local_index``, and the merge
is integer-exact, so 2 processes x 4 devices ≡ 1 process x 8 devices
bit-for-bit on counts and histograms.

Everything is one jit per (table shape, chunking): ``trials`` and the table
contents are traced, so scaling a sweep from 10^5 to 10^7 trials or
swapping same-shape quorum systems re-enters the same compile
(``engine.TRACE_COUNTS['*_stream']``).
"""
from __future__ import annotations

import functools
import math
import warnings
from dataclasses import dataclass, replace
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel import sharding as psharding

from . import engine
from .engine import MASK_KEYS, UNDECIDED_MS
from .latency import default_delay
from .regimes import REGIME_FOLD_DOMAIN, MarkovRegimes, RegimeStreamSummary

DEFAULT_CHUNK = 65536
DEFAULT_PRECISION = 0.01

# Second-level fold-in tag separating the per-device key domain from the
# per-chunk one.  Chunk c draws from fold_in(key, c) with c in [0, n_chunks);
# device d draws from fold_in(fold_in(key, DEVICE_FOLD_DOMAIN), d).  The
# old single-level scheme fold_in(key, 0x5eed + d) collided with chunk
# index 0x5eed + d of a long unsharded stream (0x5eed = 24301 < 2^20 —
# well inside real chunk counts); the extra fold-in level makes the two
# domains disjoint for ANY chunk/device index (regression-tested to
# n_chunks = 2^20 in tests/test_streaming.py).
DEVICE_FOLD_DOMAIN = 0x7FFFFFFF

# Sketch coverage: 10 us .. ~3 hours.  Latencies outside clamp to the edge
# buckets — quantile estimates stay order-correct but the relative-error
# guarantee only holds inside the range (simulated commit latencies are
# ~0.5 ms .. seconds, comfortably inside).
SKETCH_MIN_MS = 1e-2
SKETCH_MAX_MS = 1e7


def sketch_gamma(precision: float) -> float:
    """DDSketch bucket growth factor for a target relative error."""
    return (1.0 + precision) / (1.0 - precision)


def sketch_bins(precision: float) -> int:
    """Bucket count covering [SKETCH_MIN_MS, SKETCH_MAX_MS] at ``precision``
    relative error (plus the clamp bucket 0 for values below the range)."""
    if not 1e-4 <= precision <= 0.2:
        raise ValueError(f"precision (relative quantile error) must be in "
                         f"[1e-4, 0.2], got {precision}")
    g = sketch_gamma(precision)
    return int(math.ceil(math.log(SKETCH_MAX_MS / SKETCH_MIN_MS)
                         / math.log(g))) + 1


def bucket_index(x: jax.Array, precision: float) -> jax.Array:
    """Log-bucket index: bucket i > 0 covers (m0*g^(i-1), m0*g^i].

    The expression is shared verbatim with the fused Pallas kernel
    (``kernels/quorum_tally``) so both paths bucket identically.
    """
    log_g = math.log(sketch_gamma(precision))
    i = jnp.ceil(jnp.log(jnp.maximum(x, SKETCH_MIN_MS) / SKETCH_MIN_MS)
                 / log_g)
    return jnp.clip(i, 0, sketch_bins(precision) - 1).astype(jnp.int32)


def bucket_value(i: jax.Array, precision: float) -> jax.Array:
    """Representative value of bucket i: 2*m0*g^i/(g+1), the point whose
    relative distance to both bucket edges is exactly ``precision``."""
    g = sketch_gamma(precision)
    scale = SKETCH_MIN_MS * 2.0 * g / (g + 1.0)
    return scale * jnp.power(jnp.float32(g), i.astype(jnp.float32) - 1.0)


# ---------------------------------------------------------------------------
# StreamSummary: the fixed-size online state (a registered pytree).
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class StreamSummary:
    """Mergeable per-system summary of any number of streamed trials.

    All fields are per-system vectors (leading M axis); ``hist`` is the
    DDSketch bucket-count matrix over *decided* latencies, following the
    same convention as ``engine.summarize``: undecided instances are
    excluded from the latency statistics and reported as a rate.
    ``precision`` (static aux data) is the sketch's guaranteed relative
    quantile error.
    """

    n_trials: jax.Array       # (M,) int32  valid trials streamed
    n_fast: jax.Array         # (M,) int32  fast-path commits
    n_recovery: jax.Array     # (M,) int32  coordinated recoveries
    n_undecided: jax.Array    # (M,) int32  never decided (loss / crashes)
    mean_ms: jax.Array        # (M,) f32    running mean of decided latencies
    max_ms: jax.Array         # (M,) f32    running max (-inf before any)
    hist: jax.Array           # (M, B) int32 sketch bucket counts (decided)
    precision: float = DEFAULT_PRECISION

    def tree_flatten(self):
        return ((self.n_trials, self.n_fast, self.n_recovery,
                 self.n_undecided, self.mean_ms, self.max_ms, self.hist),
                self.precision)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, precision=aux)

    # -- construction ------------------------------------------------------
    @classmethod
    def zeros(cls, m: int, precision: float = DEFAULT_PRECISION
              ) -> "StreamSummary":
        z = jnp.zeros((m,), jnp.int32)
        return cls(z, z, z, z,
                   jnp.zeros((m,), jnp.float32),
                   jnp.full((m,), -jnp.inf, jnp.float32),
                   jnp.zeros((m, sketch_bins(precision)), jnp.int32),
                   precision)

    @classmethod
    def from_outcomes(cls, out: Dict[str, jax.Array],
                      precision: float = DEFAULT_PRECISION) -> "StreamSummary":
        """Reduce a materialized (M, S) outcome dict (``engine.race`` /
        ``Scenario.run`` shape) into a summary — the T <= chunk case."""
        m, s = out["latency_ms"].shape
        return cls.zeros(m, precision).update(out, jnp.ones((s,), bool))

    # -- derived -----------------------------------------------------------
    @property
    def n_decided(self) -> jax.Array:
        return self.n_fast + self.n_recovery

    @property
    def bins(self) -> int:
        return self.hist.shape[-1]

    # -- online updates ----------------------------------------------------
    def update(self, out: Dict[str, jax.Array],
               valid: jax.Array) -> "StreamSummary":
        """Absorb one chunk: ``out`` is an (M, C) outcome dict, ``valid`` a
        (C,) bool mask (False = padding trial, contributes nothing)."""
        lat = out["latency_ms"]
        v = valid[None, :]
        fast = out["reached_fast"] & v
        rec = out["recovery"] & v
        und = out["undecided"] & v
        decided = fast | rec
        add_cnt = decided.sum(axis=-1)
        add_sum = jnp.where(decided, lat, 0.0).sum(axis=-1)
        add_max = jnp.where(decided, lat, -jnp.inf).max(axis=-1)
        idx = bucket_index(lat, self.precision)
        add_hist = jax.vmap(lambda h, i, u: h.at[i].add(u))(
            jnp.zeros_like(self.hist), idx, decided.astype(self.hist.dtype))
        return self._absorb(
            n_trials=(fast | rec | und).sum(axis=-1).astype(jnp.int32),
            n_fast=fast.sum(axis=-1).astype(jnp.int32),
            n_recovery=rec.sum(axis=-1).astype(jnp.int32),
            n_undecided=und.sum(axis=-1).astype(jnp.int32),
            cnt=add_cnt.astype(jnp.float32), lat_sum=add_sum,
            lat_max=add_max, hist=add_hist)

    def _absorb(self, *, n_trials, n_fast, n_recovery, n_undecided, cnt,
                lat_sum, lat_max, hist) -> "StreamSummary":
        """Merge per-chunk aggregates (the fused kernel's output shape)."""
        n_old = self.n_decided.astype(jnp.float32)
        tot = n_old + cnt
        mean = jnp.where(tot > 0,
                         (self.mean_ms * n_old + lat_sum)
                         / jnp.maximum(tot, 1.0), 0.0)
        return replace(self,
                       n_trials=self.n_trials + n_trials,
                       n_fast=self.n_fast + n_fast,
                       n_recovery=self.n_recovery + n_recovery,
                       n_undecided=self.n_undecided + n_undecided,
                       mean_ms=mean,
                       max_ms=jnp.maximum(self.max_ms, lat_max),
                       hist=self.hist + hist)

    # -- merges ------------------------------------------------------------
    def merge(self, other: "StreamSummary") -> "StreamSummary":
        """Combine two summaries as if their trials had been one stream.
        Counts and histograms are integer sums (exact — merge is associative
        and commutative bit-for-bit); means combine count-weighted."""
        if other.precision != self.precision:
            raise ValueError(
                f"cannot merge sketches of different precision "
                f"({self.precision} vs {other.precision})")
        return self._absorb(
            n_trials=other.n_trials, n_fast=other.n_fast,
            n_recovery=other.n_recovery, n_undecided=other.n_undecided,
            cnt=other.n_decided.astype(jnp.float32),
            lat_sum=other.mean_ms * other.n_decided.astype(jnp.float32),
            lat_max=other.max_ms, hist=other.hist)

    def axis_merge(self, axis_name: str) -> "StreamSummary":
        """Cross-device merge inside ``shard_map``: psum the counts and the
        sketch, pmax the max, count-weighted psum for the mean."""
        ps = lambda x: jax.lax.psum(x, axis_name)
        n_dec = self.n_decided.astype(jnp.float32)
        tot = ps(n_dec)
        mean = jnp.where(tot > 0,
                         ps(self.mean_ms * n_dec) / jnp.maximum(tot, 1.0),
                         0.0)
        return replace(self,
                       n_trials=ps(self.n_trials), n_fast=ps(self.n_fast),
                       n_recovery=ps(self.n_recovery),
                       n_undecided=ps(self.n_undecided),
                       mean_ms=mean,
                       max_ms=jax.lax.pmax(self.max_ms, axis_name),
                       hist=ps(self.hist))

    # -- queries -----------------------------------------------------------
    def quantile(self, q) -> jax.Array:
        """Sketch quantile estimate over decided trials: within
        ``precision`` relative error of the exact empirical quantile for
        latencies inside the sketch range.  ``q`` scalar -> (M,); ``q``
        (Q,) -> (Q, M).  NaN where nothing decided."""
        qv = jnp.atleast_1d(jnp.asarray(q, jnp.float32))
        n = self.n_decided
        cum = jnp.cumsum(self.hist, axis=-1)                   # (M, B)
        rank = jnp.clip(jnp.ceil(qv[:, None] * n[None, :]),
                        1, jnp.maximum(n, 1)[None, :])         # (Q, M)
        idx = jnp.argmax(cum[None, :, :] >= rank[:, :, None], axis=-1)
        val = jnp.where(n[None, :] > 0,
                        bucket_value(idx, self.precision), jnp.nan)
        return val[0] if jnp.ndim(q) == 0 else val

    def summary(self) -> Dict[str, jax.Array]:
        """The normalized summary dict (`engine.summarize` keys, plus the
        p99.9/p99.99 that streaming trial counts make meaningful)."""
        n = jnp.maximum(self.n_trials, 1).astype(jnp.float32)
        has = self.n_decided > 0
        qs = self.quantile(jnp.array([0.5, 0.95, 0.99, 0.999, 0.9999]))
        return {
            "mean_ms": jnp.where(has, self.mean_ms, jnp.nan),
            "p50_ms": qs[0], "p95_ms": qs[1], "p99_ms": qs[2],
            "p999_ms": qs[3], "p9999_ms": qs[4],
            "max_ms": jnp.where(has, self.max_ms, jnp.nan),
            "fast_rate": self.n_fast / n,
            "recovery_rate": self.n_recovery / n,
            "undecided_rate": self.n_undecided / n,
        }


# ---------------------------------------------------------------------------
# Chunked scan driver (+ shard_map over the trial axis).
# ---------------------------------------------------------------------------

def _lat_only_outcomes(lat: jax.Array, fast: bool) -> Dict[str, jax.Array]:
    """Latency-array paths (fast_path / classic_path) as an outcome dict."""
    und = lat >= UNDECIDED_MS
    no = jnp.zeros_like(und)
    return {"latency_ms": lat, "undecided": und,
            "reached_fast": ~und if fast else no,
            "recovery": no if fast else ~und}


def _chunk_outcomes(path: str, key, table, offsets, delay, *, n, k_proposers,
                    chunk, use_kernel, k_sat=None,
                    recovery="coordinated") -> Dict[str, jax.Array]:
    if path == "race":
        return engine._race_outcomes(key, table, offsets, delay, n=n,
                                     k_proposers=k_proposers, samples=chunk,
                                     use_kernel=use_kernel, k_sat=k_sat,
                                     recovery=recovery)
    if path == "fast_path":
        return _lat_only_outcomes(
            engine._fast_path_outcomes(key, table, delay, n=n,
                                       samples=chunk, k_sat=k_sat), fast=True)
    return _lat_only_outcomes(
        engine._classic_path_outcomes(key, table, delay, n=n,
                                      samples=chunk, k_sat=k_sat),
        fast=False)


# ---------------------------------------------------------------------------
# Sort-free cardinality reductions (DESIGN.md §9): no (M, chunk) latency
# matrix is ever materialized.  Cardinality systems share their random
# structure — order statistics of one draw — so per-system chunk statistics
# are gathers from small shared tables keyed by order-statistic column
# (and, for the race, by the per-trial fast-saturation capacity).
# ---------------------------------------------------------------------------

def _card_layout(table, recovery: str = "coordinated") -> tuple:
    """Host-side static pair structure of a concrete cardinality table: the
    distinct (q1, q_rec) recovery pairs (P, 2) and each system's pair id
    (M,), where q_rec is the recovery-commit threshold of the active rule —
    q2c under coordinated recovery, q2f under uncoordinated.  Recovery
    latency depends on a system only through this pair, so P (not M)
    recovery columns cover the whole table."""
    import numpy as np
    q = np.asarray(table["q"])
    cols = [0, 1] if recovery == "coordinated" else [0, 2]
    pairs, inv = np.unique(q[:, cols], axis=0, return_inverse=True)
    return (jnp.asarray(pairs, jnp.int32),
            jnp.asarray(inv.astype(np.int32)))


def _dummy_layout() -> tuple:
    """Placeholder pair layout for paths that never read it (masked tables /
    reference path); keeps the ``_stream`` jit signature uniform."""
    return (jnp.zeros((1, 2), jnp.int32), jnp.zeros((1,), jnp.int32))


def _cols_card_update(state: StreamSummary, cols: jax.Array,
                      col_of_m: jax.Array, valid: jax.Array, *,
                      fast: bool) -> StreamSummary:
    """Absorb a latency chunk whose per-system latency is one of ``Kc``
    shared candidate columns: ``lat[m, c] = cols[c, col_of_m[m]]``.

    One (Kc, bins) histogram scatter (Kc * chunk updates instead of
    M * chunk) plus dense per-column sum/max reductions; every per-system
    quantity is then a gather.  Counts, histogram and max are bit-identical
    to ``state.update`` on the materialized (M, chunk) outcomes; the f32
    latency sum reduces per column, so the running mean matches to float
    tolerance only."""
    B = state.bins
    Kc = cols.shape[1]
    und = cols >= UNDECIDED_MS
    # decided trials land in their sketch bucket, undecided in slot B,
    # padding trials in slot B + 1 (dropped).
    bkey = jnp.where(und, B, bucket_index(cols, state.precision))
    bkey = jnp.where(valid[:, None], bkey, B + 1)
    flat = (jnp.arange(Kc, dtype=jnp.int32)[None, :] * (B + 2)
            + bkey).ravel()
    LH = jnp.zeros((Kc * (B + 2),), jnp.int32).at[flat].add(1)
    LH = LH.reshape(Kc, B + 2)
    rows = LH[col_of_m]                                  # (M, B + 2)
    hist = rows[:, :B]
    n_und = rows[:, B]
    n_dec = hist.sum(axis=-1)
    ok = valid[:, None] & ~und
    col_sum = jnp.where(ok, cols, 0.0).sum(axis=0)       # (Kc,)
    col_max = jnp.where(ok, cols, -jnp.inf).max(axis=0)  # (Kc,)
    zero = jnp.zeros_like(n_dec)
    n_valid = jnp.broadcast_to(valid.sum().astype(jnp.int32),
                               col_of_m.shape)
    return state._absorb(
        n_trials=n_valid,
        n_fast=n_dec if fast else zero,
        n_recovery=zero if fast else n_dec,
        n_undecided=n_und,
        cnt=n_dec.astype(jnp.float32),
        lat_sum=col_sum[col_of_m], lat_max=col_max[col_of_m], hist=hist)


def _race_card_update(state: StreamSummary, key, table, layout, offsets,
                      delay, valid, *, n, k_proposers, chunk, use_kernel,
                      k_sat, recovery="coordinated") -> StreamSummary:
    """Sort-free streamed race chunk for cardinality tables.

    The per-trial *fast capacity* ``fcap = min(max_cnt, #finite winner
    2bs)`` collapses the fast-path decision: system m commits fast exactly
    when ``fcap >= q2f_m`` (both need q2f votes AND the q2f-th winner 2b to
    arrive, and the winner-2b prefix is ascending so the q2f-th is finite
    iff at least q2f are).  Recovery latency depends on m only through its
    (q1, q2c) pair.  So one chunk reduces into:

      * FH (k2f, V, bins): winner-2b column histograms keyed by fcap slot —
        suffix-cumsum over slots, then gather at (q2f-1, q2f) per system;
      * RH (P, V, bins+1): recovery-pair histograms (undecided in the extra
        bucket) keyed by fcap slot — prefix-cumsum, gather at (pair, q2f-1);
      * matching per-slot sums (one-hot matmuls, no scatter) and maxima
        (static loop over the <= n+1 slots).

    Scatter volume drops from M * chunk to (k2f + P) * chunk updates; every
    integer output (decide bits, histogram, counts, max) is bit-identical
    to the materialized ``_decide`` + ``state.update`` path — only the f32
    latency-sum reduction order differs.

    ``recovery`` rides through unchanged: ``layout`` already pairs each
    system with the rule's commit threshold (q2c or q2f) and
    ``_sample_race`` deepens/retargets the classic presort, so the pair
    gather below is rule-agnostic.
    """
    k1, k2c, k2f = k_sat
    draws = engine._sample_race(key, offsets, delay, n=n,
                                k_proposers=k_proposers, samples=chunk,
                                use_kernel=use_kernel, k_sat=k_sat,
                                need_perms=False, recovery=recovery)
    pairs, pair_of_m = layout                            # (P, 2), (M,)
    P_ = pairs.shape[0]
    q2f = table["q"][:, 2]                               # (M,) traced
    B = state.bins
    prec = state.precision
    win = engine._win_sorted(draws)                      # (C, k2f) ascending
    V = k2f + 1                                          # fcap slots 0..k2f

    nfin = (win < UNDECIDED_MS).sum(axis=-1).astype(jnp.int32)
    fcap = jnp.minimum(draws["max_cnt"], nfin)           # (C,) in [0, k2f]
    vkey = jnp.where(valid, fcap, V)                     # V = padding slot

    # ---- fast side: winner-2b prefix columns ------------------------------
    bwin = bucket_index(win, prec)                       # (C, k2f)
    fkey = (jnp.arange(k2f, dtype=jnp.int32)[None, :] * (V + 1)
            + vkey[:, None]) * B + bwin
    FH = jnp.zeros((k2f * (V + 1) * B,), jnp.int32).at[fkey.ravel()].add(1)
    FH = FH.reshape(k2f, V + 1, B)[:, :V]                # drop padding slot
    SFH = jnp.flip(jnp.cumsum(jnp.flip(FH, 1), axis=1), 1)   # suffix over v
    hist_fast = SFH[q2f - 1, q2f]                        # (M, B)

    oh = (vkey[:, None] == jnp.arange(V, dtype=jnp.int32)[None, :]
          ).astype(jnp.float32)                          # (C, V) valid only
    Fsum = jnp.einsum("cj,cv->jv", win, oh)              # (k2f, V)
    SFsum = jnp.flip(jnp.cumsum(jnp.flip(Fsum, 1), axis=1), 1)
    sum_fast = SFsum[q2f - 1, q2f]                       # (M,)

    # per-slot column maxima: static loop over the <= n + 1 slots.
    Fmax = jnp.stack([jnp.where((vkey == v)[:, None], win, -jnp.inf).max(0)
                      for v in range(V)], axis=1)        # (k2f, V)
    SFmax = jnp.flip(jax.lax.cummax(jnp.flip(Fmax, 1), axis=1), 1)
    max_fast = SFmax[q2f - 1, q2f]                       # (M,)

    cnt_v = jnp.zeros((V + 1,), jnp.int32).at[vkey].add(1)[:V]
    scnt = jnp.flip(jnp.cumsum(jnp.flip(cnt_v, 0)), 0)   # suffix counts
    n_fast = scnt[q2f]                                   # (M,)

    # ---- recovery side: (q1, q2c) pair columns ----------------------------
    t_rec = (jnp.take(draws["sorted_arrive"], pairs[:, 0] - 1, axis=1)
             + jnp.take(draws["sorted_classic"], pairs[:, 1] - 1, axis=1))
    dec = t_rec < UNDECIDED_MS                           # (C, P)
    brec = jnp.where(dec, bucket_index(t_rec, prec), B)  # bucket B: undecided
    rkey = (jnp.arange(P_, dtype=jnp.int32)[None, :] * (V + 1)
            + vkey[:, None]) * (B + 1) + brec
    RH = jnp.zeros((P_ * (V + 1) * (B + 1),),
                   jnp.int32).at[rkey.ravel()].add(1)
    RH = RH.reshape(P_, V + 1, B + 1)[:, :V]
    CRH = jnp.cumsum(RH, axis=1)                         # prefix over v
    rec_rows = CRH[pair_of_m, q2f - 1]                   # (M, B + 1)
    hist_rec = rec_rows[:, :B]
    n_und = rec_rows[:, B]
    n_rec = hist_rec.sum(axis=-1)

    Rsum = jnp.einsum("cp,cv->pv", jnp.where(dec, t_rec, 0.0), oh)
    CRsum = jnp.cumsum(Rsum, axis=1)
    sum_rec = CRsum[pair_of_m, q2f - 1]

    Rmax = jnp.stack(
        [jnp.where((vkey == v)[:, None] & dec, t_rec, -jnp.inf).max(0)
         for v in range(V)], axis=1)                     # (P, V)
    CRmax = jax.lax.cummax(Rmax, axis=1)
    max_rec = CRmax[pair_of_m, q2f - 1]

    n_valid = jnp.broadcast_to(valid.sum().astype(jnp.int32), q2f.shape)
    return state._absorb(
        n_trials=n_valid, n_fast=n_fast, n_recovery=n_rec,
        n_undecided=n_und, cnt=(n_fast + n_rec).astype(jnp.float32),
        lat_sum=sum_fast + sum_rec,
        lat_max=jnp.maximum(max_fast, max_rec),
        hist=hist_fast + hist_rec)


def _race_fused_update(state: StreamSummary, key, table, offsets, delay,
                       valid, *, n, k_proposers, chunk, k_sat,
                       recovery="coordinated") -> StreamSummary:
    """Masked-table race chunk through the fused megakernel: the *raw*
    (unsorted) arrival block goes straight into the kernel, which runs the
    k_max-step selection network in-registers, then masked tally + decide +
    latency + one-hot histogram without leaving VMEM (DESIGN.md §3, §9).

    No ``(chunk, n)`` sorted array is ever materialized on this path — the
    engine contributes only the RNG draws and vote structure
    (``_draw_race``); everything system-dependent happens inside the
    kernel grid over (systems, trial blocks).

    The kernel's recovery-commit operands are positional, so uncoordinated
    recovery feeds the phase-2f masks (and the k2f prefix depth) where
    coordinated feeds phase-2c — the classic-leg draws already match the
    rule from ``_draw_race``."""
    raw = engine._draw_race(key, offsets, delay, n=n,
                            k_proposers=k_proposers, samples=chunk,
                            recovery=recovery)
    if recovery == "uncoordinated":
        rec_w, rec_t = table["p2f_w"], table["p2f_t"]
        k_sat = (k_sat[0], k_sat[2], k_sat[2])
    else:
        rec_w, rec_t = table["p2c_w"], table["p2c_t"]
    from repro.kernels.quorum_tally import ops as qt_ops
    hist, stats = qt_ops.stream_tally_decide_hist(
        raw["votes"], raw["val_arr"], raw["arrive"], raw["classic"],
        table["p1_w"], table["p1_t"], rec_w, rec_t,
        table["p2f_w"], table["p2f_t"], valid, n_values=k_proposers,
        k_sat=k_sat, precision=state.precision, bins=state.bins,
        undecided_ms=float(UNDECIDED_MS))
    return state._absorb(
        n_trials=stats["n_fast"] + stats["n_recovery"] + stats["n_undecided"],
        n_fast=stats["n_fast"], n_recovery=stats["n_recovery"],
        n_undecided=stats["n_undecided"],
        cnt=(stats["n_fast"] + stats["n_recovery"]).astype(jnp.float32),
        lat_sum=stats["sum_ms"], lat_max=stats["max_ms"], hist=hist)


# ---------------------------------------------------------------------------
# Markov-modulated regime scan (DESIGN.md §12): the chunk loop sweeps
# through failure epochs instead of one static environment.
# ---------------------------------------------------------------------------

def _regime_zeros(regimes: MarkovRegimes, m: int,
                  precision: float) -> RegimeStreamSummary:
    """The merge identity: zero occupancy, zero per-regime summaries."""
    r = regimes.n_regimes
    z = StreamSummary.zeros(m, precision)
    return RegimeStreamSummary(
        names=regimes.names,
        occupancy=jnp.zeros((r,), jnp.int32),
        by_regime=jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, axis=0), z, *([z] * (r - 1))))


def _regime_device_stream(key, table, offsets, delay, trials, regimes, *,
                          path, n, k_proposers, chunk, n_chunks, n_epochs,
                          precision, use_kernel, k_sat,
                          recovery="coordinated") -> RegimeStreamSummary:
    """One device's chunked scan under a Markov regime chain.

    The chain ``zs`` is sampled up front (``n_epochs`` covers the scan's
    static trial capacity ``n_chunks * chunk``) from its own fold-in
    domain, so chunk keys are untouched.  Trial t of THIS device runs in
    regime ``zs[t // epoch_trials]`` — a pure function of the device key
    and the absolute trial index, which makes regime assignment (and
    hence occupancy counts) invariant under the ``chunk`` size.  Each
    chunk samples hops under the mixed per-trial environment, decides
    once, and scatters its outcomes into R per-regime ``StreamSummary``
    slices via the regime-selected validity masks — counts/histograms
    stay exact integers, so slices merge back to the marginal summary
    with ``StreamSummary.merge`` bit-for-bit.

    With a single regime the chain is constantly 0 and the mixed delay
    samples the base model on the unfolded chunk key: draws, decide bits,
    counts and histograms are bit-identical to the plain i.i.d. stream.
    """
    m = table["p1_w"].shape[0]
    r = regimes.n_regimes
    ep = regimes.epoch_trials
    zs = regimes.sequence(
        jax.random.fold_in(key, jnp.int32(REGIME_FOLD_DOMAIN)), n_epochs)

    def body(carry, i):
        occ, states = carry
        k = jax.random.fold_in(key, i)
        tidx = i * chunk + jnp.arange(chunk, dtype=jnp.int32)
        valid = tidx < trials
        rid = zs[jnp.clip(tidx // ep, 0, n_epochs - 1)]
        out = _chunk_outcomes(path, k, table, offsets,
                              regimes.mixed_delay(rid), n=n,
                              k_proposers=k_proposers, chunk=chunk,
                              use_kernel=use_kernel, k_sat=k_sat,
                              recovery=recovery)
        sel = [valid & (rid == j) for j in range(r)]
        states = tuple(states[j].update(out, sel[j]) for j in range(r))
        occ = occ + jnp.stack([s.sum() for s in sel]).astype(jnp.int32)
        return (occ, states), None

    carry0 = (jnp.zeros((r,), jnp.int32),
              tuple(StreamSummary.zeros(m, precision) for _ in range(r)))
    (occ, states), _ = jax.lax.scan(body, carry0,
                                    jnp.arange(n_chunks, dtype=jnp.int32))
    return RegimeStreamSummary(
        names=regimes.names, occupancy=occ,
        by_regime=jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, axis=0), states[0], *states[1:]))


@functools.partial(jax.jit,
                   static_argnames=("path", "n", "k_proposers", "chunk",
                                    "n_chunks", "n_epochs", "precision",
                                    "use_kernel", "mesh", "k_sat",
                                    "recovery"))
def _stream(key, table, layout, offsets, delay, trials, regimes, *, path, n,
            k_proposers, chunk, n_chunks, n_epochs, precision, use_kernel,
            mesh, k_sat, recovery="coordinated"):
    engine.TRACE_COUNTS[path + "_stream"] += 1
    m = table["p1_w"].shape[0]
    # The fused-kernel and shared-column lowerings assume ONE environment
    # per chunk; a regime mix is per-trial, so regime runs keep the k_sat
    # top-k presorts but decide through the generic outcome path (whose
    # integer outputs are bit-identical by the DESIGN.md §9 contract).
    fused = (path == "race" and use_kernel and "q" not in table
             and k_sat is not None and regimes is None)
    card = "q" in table and k_sat is not None and regimes is None
    if regimes is not None:
        engine.TRACE_COUNTS[path + "_stream_regimes"] += 1
    if fused:
        engine.TRACE_COUNTS["race_stream_fused"] += 1
    elif k_sat is not None:
        engine.TRACE_COUNTS[path + "_stream_sortfree"] += 1

    def device_stream(key, table, layout, offsets, delay, trials, regimes):
        if regimes is not None:
            return _regime_device_stream(
                key, table, offsets, delay, trials, regimes, path=path,
                n=n, k_proposers=k_proposers, chunk=chunk,
                n_chunks=n_chunks, n_epochs=n_epochs, precision=precision,
                use_kernel=use_kernel, k_sat=k_sat, recovery=recovery)
        def body(state, i):
            k = jax.random.fold_in(key, i)
            valid = jnp.arange(chunk, dtype=jnp.int32) \
                < jnp.clip(trials - i * chunk, 0, chunk)
            if fused:
                state = _race_fused_update(state, k, table, offsets, delay,
                                           valid, n=n,
                                           k_proposers=k_proposers,
                                           chunk=chunk, k_sat=k_sat,
                                           recovery=recovery)
            elif card and path == "race":
                state = _race_card_update(state, k, table, layout, offsets,
                                          delay, valid, n=n,
                                          k_proposers=k_proposers,
                                          chunk=chunk,
                                          use_kernel=use_kernel,
                                          k_sat=k_sat, recovery=recovery)
            elif card and path == "fast_path":
                cols = engine._sorted_prefix(
                    engine._fast_path_draws(k, delay, n, chunk), k_sat[2])
                state = _cols_card_update(state, cols, table["q"][:, 2] - 1,
                                          valid, fast=True)
            elif card:                     # classic_path
                d0, pathv = engine._classic_path_draws(k, delay, n, chunk)
                cols = d0[:, None] + engine._sorted_prefix(pathv, k_sat[1])
                state = _cols_card_update(state, cols, table["q"][:, 1] - 1,
                                          valid, fast=False)
            else:
                out = _chunk_outcomes(path, k, table, offsets, delay, n=n,
                                      k_proposers=k_proposers, chunk=chunk,
                                      use_kernel=use_kernel, k_sat=k_sat,
                                      recovery=recovery)
                state = state.update(out, valid)
            return state, None
        state0 = StreamSummary.zeros(m, precision)
        state, _ = jax.lax.scan(body, state0,
                                jnp.arange(n_chunks, dtype=jnp.int32))
        return state

    if mesh is None:
        return device_stream(key, table, layout, offsets, delay, trials,
                             regimes)

    ndev = mesh.shape[psharding.TRIAL_AXIS]

    def per_device(key, table, layout, offsets, delay, trials, regimes):
        # All per-device quantities derive from the GLOBAL device index
        # (process_index * local_count + local_index on a multi-host grid),
        # so any process layout of the same global device count runs the
        # same per-device programs and the integer-exact axis_merge makes
        # the merged summary layout-invariant bit-for-bit.
        d = jax.lax.axis_index(psharding.TRIAL_AXIS)
        t_d = trials // ndev + jnp.where(d < trials % ndev, 1, 0)
        # Second fold-in level = device key domain disjoint from chunk keys.
        k_d = jax.random.fold_in(
            jax.random.fold_in(key, jnp.int32(DEVICE_FOLD_DOMAIN)), d)
        # trials < ndev leaves trailing devices with t_d == 0: they would
        # still scan n_chunks all-invalid chunks.  Short-circuit them to
        # the zeros identity (exact under merge: counts/hist 0, max -inf)
        # — XLA runs only the taken cond branch, so empty devices launch
        # no per-chunk kernels.  The collective merge stays OUTSIDE the
        # cond: every device must participate in the psum/pmax.
        state = jax.lax.cond(
            t_d > 0,
            lambda: device_stream(key=k_d, table=table, layout=layout,
                                  offsets=offsets, delay=delay, trials=t_d,
                                  regimes=regimes),
            lambda: (StreamSummary.zeros(m, precision) if regimes is None
                     else _regime_zeros(regimes, m, precision)))
        if regimes is not None:
            # per-regime slices merge exactly like plain summaries (their
            # leaves just carry a leading R axis); occupancy is an exact
            # integer psum.
            return replace(
                state,
                occupancy=jax.lax.psum(state.occupancy,
                                       psharding.TRIAL_AXIS),
                by_regime=state.by_regime.axis_merge(psharding.TRIAL_AXIS))
        return state.axis_merge(psharding.TRIAL_AXIS)

    return psharding.shard_map(
        per_device, mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(), P(), P()),
        out_specs=P())(key, table, layout, offsets, delay, trials, regimes)


def _resolve_mesh(shard):
    """``shard=True`` -> the global trial mesh (falls back to unsharded on
    a single device, with a ``UserWarning`` so multi-process launch scripts
    that forgot ``distributed.initialize()`` / forced host devices fail
    loudly rather than quietly degrading); an explicit ``Mesh`` is honored
    as-is, 1-device included (the layout was chosen deliberately — e.g. a
    worker that must stay on the collective code path)."""
    if shard is False or shard is None:
        return None
    if shard is True:
        ndev = len(jax.devices())
        if ndev > 1:
            return psharding.trial_mesh()
        warnings.warn(
            f"shard=True but only {ndev} device is visible - running "
            f"unsharded. For a multi-process grid call "
            f"repro.parallel.distributed.initialize() before any jax use; "
            f"for local device parallelism set "
            f"--xla_force_host_platform_device_count in XLA_FLAGS; pass "
            f"shard=False to silence.", UserWarning, stacklevel=4)
        return None
    return shard                       # an explicit Mesh (any device count)


def _resolve_k_sat(table, k_max, n: int):
    """Normalize the ``k_max`` knob to a static ``(k1, k2c, k2f)`` tuple
    (or None = full-sort reference path).  ``"auto"`` derives the depths
    from the concrete table (``engine.saturation_depths``); an int caps all
    three phases; an explicit 3-tuple is clipped to [1, n]."""
    if k_max is None:
        return None
    if k_max == "auto":
        return engine.saturation_depths(table)
    if isinstance(k_max, int):
        k_max = (k_max, k_max, k_max)
    ks = tuple(int(k) for k in k_max)
    if len(ks) != 3:
        raise ValueError(f"k_max must be None, 'auto', an int or a "
                         f"(k1, k2c, k2f) triple, got {k_max!r}")
    depths = engine.saturation_depths(table)
    for req, need in zip(ks, depths):
        if req < need:
            raise ValueError(
                f"k_max={ks} below the table's saturation depths {depths}; "
                f"prefixes that short change results — use 'auto'")
    return tuple(min(n, max(1, k)) for k in ks)


def _stream_entry(path: str, key, table, delay, offsets, *, n, k_proposers,
                  trials, chunk, precision, use_kernel, shard, k_max="auto",
                  regimes=None, recovery="coordinated") -> StreamSummary:
    engine._check_mask_table(table, n)
    engine._check_recovery(recovery)
    if trials < 1:
        raise ValueError(f"trials must be >= 1, got {trials}")
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    sketch_bins(precision)             # validates precision
    if regimes is not None:
        if isinstance(regimes, dict):
            regimes = MarkovRegimes.from_config(regimes, n)
        regimes = regimes.validate().bound(
            delay if delay is not None else default_delay())
    mesh = _resolve_mesh(shard)
    if mesh is None and trials <= chunk and regimes is None:
        # The materializing path IS the T <= chunk special case: same
        # compile as direct engine calls, bit-identical draws, reduced.
        if path == "race":
            out = engine.race(key, table, offsets, delay, n=n,
                              k_proposers=k_proposers, samples=trials,
                              use_kernel=use_kernel, recovery=recovery)
        elif path == "fast_path":
            out = _lat_only_outcomes(
                engine.fast_path(key, table, delay, n=n, samples=trials),
                fast=True)
        else:
            out = _lat_only_outcomes(
                engine.classic_path(key, table, delay, n=n, samples=trials),
                fast=False)
        return StreamSummary.from_outcomes(out, precision)
    k_sat = _resolve_k_sat(table, k_max, n)
    layout = (_card_layout(table, recovery)
              if "q" in table and k_sat is not None else _dummy_layout())
    ndev = 1 if mesh is None else mesh.shape[psharding.TRIAL_AXIS]
    per_device = -(-trials // ndev)                # ceil: busiest device
    n_chunks = -(-per_device // chunk)
    # Regime epochs cover the scan's static per-device trial capacity, so
    # n_epochs is a pure function of the jit geometry (trials stays traced).
    n_epochs = (1 if regimes is None
                else -(-(n_chunks * chunk) // regimes.epoch_trials))
    if delay is None:
        delay = default_delay()
    offsets = (jnp.zeros((1,), jnp.float32) if offsets is None
               else jnp.asarray(offsets, jnp.float32))
    return _stream(key, table, layout, offsets, delay, jnp.int32(trials),
                   regimes, path=path, n=n, k_proposers=k_proposers,
                   chunk=chunk, n_chunks=n_chunks, n_epochs=n_epochs,
                   precision=precision, use_kernel=use_kernel, mesh=mesh,
                   k_sat=k_sat, recovery=recovery)


def race_stream(key, table, offsets, delay=None, *, n: int, k_proposers: int,
                trials: int, chunk: int = DEFAULT_CHUNK,
                precision: float = DEFAULT_PRECISION,
                use_kernel: bool = False, shard: bool = True,
                k_max="auto", regimes=None,
                recovery: str = "coordinated") -> StreamSummary:
    """``engine.race`` at any trial count in fixed memory: chunked
    ``lax.scan`` reduction into a ``StreamSummary``, trial axis sharded
    over local devices when ``shard`` (a bool or an explicit 1-D mesh).
    One compile per (table shape, chunk count); ``trials`` is traced.

    ``k_max`` (default ``"auto"``) selects the sort-free lowering
    (DESIGN.md §9): top-k arrival prefixes at the table's saturation depths
    plus, on cardinality tables, the shared-column chunk reduction — decide
    bits, histograms, counts and maxima are bit-identical to ``k_max=None``
    (the retained full-sort reference path); only the f32 mean accumulates
    in a different order.  With ``use_kernel`` on masked tables the chunk
    runs through the raw-arrivals megakernel instead (requires ``k_max``).

    ``regimes`` (a ``MarkovRegimes`` or its config dict, DESIGN.md §12)
    Markov-modulates the stream through failure epochs and returns a
    ``RegimeStreamSummary`` (per-regime slices + the merged marginal);
    ``None`` keeps the i.i.d. path bit-identical to previous behaviour.

    ``recovery`` (static, ``engine.RECOVERY_MODES``) selects the
    collision-recovery rule; each mode is its own compile of the same
    stream path (one per mode, not per system)."""
    return _stream_entry("race", key, table, delay, offsets, n=n,
                         k_proposers=k_proposers, trials=trials, chunk=chunk,
                         precision=precision, use_kernel=use_kernel,
                         shard=shard, k_max=k_max, regimes=regimes,
                         recovery=recovery)


def fast_path_stream(key, table, delay=None, *, n: int, trials: int,
                     chunk: int = DEFAULT_CHUNK,
                     precision: float = DEFAULT_PRECISION,
                     shard: bool = True, k_max="auto",
                     regimes=None) -> StreamSummary:
    """Streamed conflict-free fast path (k=1): decided instances count as
    fast-path commits, lost ones as undecided.  ``k_max`` / ``regimes`` as
    in ``race_stream``."""
    return _stream_entry("fast_path", key, table, delay, None, n=n,
                         k_proposers=1, trials=trials, chunk=chunk,
                         precision=precision, use_kernel=False, shard=shard,
                         k_max=k_max, regimes=regimes)


def classic_path_stream(key, table, delay=None, *, n: int, trials: int,
                        chunk: int = DEFAULT_CHUNK,
                        precision: float = DEFAULT_PRECISION,
                        shard: bool = True, k_max="auto",
                        regimes=None) -> StreamSummary:
    """Streamed leader-relayed classic path: decided instances count as
    recoveries (there is no fast path to reach).  ``k_max`` / ``regimes``
    as in ``race_stream``."""
    return _stream_entry("classic_path", key, table, delay, None, n=n,
                         k_proposers=1, trials=trials, chunk=chunk,
                         precision=precision, use_kernel=False, shard=shard,
                         k_max=k_max, regimes=regimes)
