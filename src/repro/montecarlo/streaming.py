"""Streaming, sharded Monte-Carlo trials: fixed memory at any trial count.

The materializing entry points in ``engine`` allocate a per-trial ``(M, S)``
array for every output, capping trials at device memory and making tail
percentiles (p99.9 — the number WAN operators actually provision for)
statistically meaningless at the trial counts that fit.  This module turns
the same per-chunk computation into a **reduction** (DESIGN.md §7):

  chunk scan      ``lax.scan`` draws, decides and *reduces* one chunk of
                  trials per step, carrying only a fixed-size summary state
                  — peak allocation is one chunk, independent of ``trials``.
  sketch          latency quantiles come from a DDSketch-style fixed-size
                  log-bucket histogram with a guaranteed relative error
                  (``precision``); bucket counts are integers, so sketch
                  merge is exact, associative and commutative.
  shard_map       the trial axis shards over local devices
                  (``parallel.sharding.trial_mesh``); the cross-device
                  reduction is the summary merge (psum counts/histograms,
                  pmax maxima, count-weighted mean combine).

``race_stream`` / ``fast_path_stream`` / ``classic_path_stream`` mirror the
materializing entry points;  ``trials <= chunk`` on a single device falls
back to the materializing path itself (same compile, bit-identical draws)
and reduces its output — the old behaviour survives as the small-T special
case.  Chunk c of a multi-chunk stream draws from ``fold_in(key, c)`` (and
device d of a sharded stream from ``fold_in(key, 0x5eed + d)``), so a
streamed run is reproducible for a given (trials, chunk, device count) but
is a different — equally valid — sample than the materializing path.

Everything is one jit per (table shape, chunking): ``trials`` and the table
contents are traced, so scaling a sweep from 10^5 to 10^7 trials or
swapping same-shape quorum systems re-enters the same compile
(``engine.TRACE_COUNTS['*_stream']``).
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass, replace
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel import sharding as psharding

from . import engine
from .engine import MASK_KEYS, UNDECIDED_MS
from .latency import default_delay

DEFAULT_CHUNK = 65536
DEFAULT_PRECISION = 0.01

# Sketch coverage: 10 us .. ~3 hours.  Latencies outside clamp to the edge
# buckets — quantile estimates stay order-correct but the relative-error
# guarantee only holds inside the range (simulated commit latencies are
# ~0.5 ms .. seconds, comfortably inside).
SKETCH_MIN_MS = 1e-2
SKETCH_MAX_MS = 1e7


def sketch_gamma(precision: float) -> float:
    """DDSketch bucket growth factor for a target relative error."""
    return (1.0 + precision) / (1.0 - precision)


def sketch_bins(precision: float) -> int:
    """Bucket count covering [SKETCH_MIN_MS, SKETCH_MAX_MS] at ``precision``
    relative error (plus the clamp bucket 0 for values below the range)."""
    if not 1e-4 <= precision <= 0.2:
        raise ValueError(f"precision (relative quantile error) must be in "
                         f"[1e-4, 0.2], got {precision}")
    g = sketch_gamma(precision)
    return int(math.ceil(math.log(SKETCH_MAX_MS / SKETCH_MIN_MS)
                         / math.log(g))) + 1


def bucket_index(x: jax.Array, precision: float) -> jax.Array:
    """Log-bucket index: bucket i > 0 covers (m0*g^(i-1), m0*g^i].

    The expression is shared verbatim with the fused Pallas kernel
    (``kernels/quorum_tally``) so both paths bucket identically.
    """
    log_g = math.log(sketch_gamma(precision))
    i = jnp.ceil(jnp.log(jnp.maximum(x, SKETCH_MIN_MS) / SKETCH_MIN_MS)
                 / log_g)
    return jnp.clip(i, 0, sketch_bins(precision) - 1).astype(jnp.int32)


def bucket_value(i: jax.Array, precision: float) -> jax.Array:
    """Representative value of bucket i: 2*m0*g^i/(g+1), the point whose
    relative distance to both bucket edges is exactly ``precision``."""
    g = sketch_gamma(precision)
    scale = SKETCH_MIN_MS * 2.0 * g / (g + 1.0)
    return scale * jnp.power(jnp.float32(g), i.astype(jnp.float32) - 1.0)


# ---------------------------------------------------------------------------
# StreamSummary: the fixed-size online state (a registered pytree).
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class StreamSummary:
    """Mergeable per-system summary of any number of streamed trials.

    All fields are per-system vectors (leading M axis); ``hist`` is the
    DDSketch bucket-count matrix over *decided* latencies, following the
    same convention as ``engine.summarize``: undecided instances are
    excluded from the latency statistics and reported as a rate.
    ``precision`` (static aux data) is the sketch's guaranteed relative
    quantile error.
    """

    n_trials: jax.Array       # (M,) int32  valid trials streamed
    n_fast: jax.Array         # (M,) int32  fast-path commits
    n_recovery: jax.Array     # (M,) int32  coordinated recoveries
    n_undecided: jax.Array    # (M,) int32  never decided (loss / crashes)
    mean_ms: jax.Array        # (M,) f32    running mean of decided latencies
    max_ms: jax.Array         # (M,) f32    running max (-inf before any)
    hist: jax.Array           # (M, B) int32 sketch bucket counts (decided)
    precision: float = DEFAULT_PRECISION

    def tree_flatten(self):
        return ((self.n_trials, self.n_fast, self.n_recovery,
                 self.n_undecided, self.mean_ms, self.max_ms, self.hist),
                self.precision)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, precision=aux)

    # -- construction ------------------------------------------------------
    @classmethod
    def zeros(cls, m: int, precision: float = DEFAULT_PRECISION
              ) -> "StreamSummary":
        z = jnp.zeros((m,), jnp.int32)
        return cls(z, z, z, z,
                   jnp.zeros((m,), jnp.float32),
                   jnp.full((m,), -jnp.inf, jnp.float32),
                   jnp.zeros((m, sketch_bins(precision)), jnp.int32),
                   precision)

    @classmethod
    def from_outcomes(cls, out: Dict[str, jax.Array],
                      precision: float = DEFAULT_PRECISION) -> "StreamSummary":
        """Reduce a materialized (M, S) outcome dict (``engine.race`` /
        ``Scenario.run`` shape) into a summary — the T <= chunk case."""
        m, s = out["latency_ms"].shape
        return cls.zeros(m, precision).update(out, jnp.ones((s,), bool))

    # -- derived -----------------------------------------------------------
    @property
    def n_decided(self) -> jax.Array:
        return self.n_fast + self.n_recovery

    @property
    def bins(self) -> int:
        return self.hist.shape[-1]

    # -- online updates ----------------------------------------------------
    def update(self, out: Dict[str, jax.Array],
               valid: jax.Array) -> "StreamSummary":
        """Absorb one chunk: ``out`` is an (M, C) outcome dict, ``valid`` a
        (C,) bool mask (False = padding trial, contributes nothing)."""
        lat = out["latency_ms"]
        v = valid[None, :]
        fast = out["reached_fast"] & v
        rec = out["recovery"] & v
        und = out["undecided"] & v
        decided = fast | rec
        add_cnt = decided.sum(axis=-1)
        add_sum = jnp.where(decided, lat, 0.0).sum(axis=-1)
        add_max = jnp.where(decided, lat, -jnp.inf).max(axis=-1)
        idx = bucket_index(lat, self.precision)
        add_hist = jax.vmap(lambda h, i, u: h.at[i].add(u))(
            jnp.zeros_like(self.hist), idx, decided.astype(self.hist.dtype))
        return self._absorb(
            n_trials=(fast | rec | und).sum(axis=-1).astype(jnp.int32),
            n_fast=fast.sum(axis=-1).astype(jnp.int32),
            n_recovery=rec.sum(axis=-1).astype(jnp.int32),
            n_undecided=und.sum(axis=-1).astype(jnp.int32),
            cnt=add_cnt.astype(jnp.float32), lat_sum=add_sum,
            lat_max=add_max, hist=add_hist)

    def _absorb(self, *, n_trials, n_fast, n_recovery, n_undecided, cnt,
                lat_sum, lat_max, hist) -> "StreamSummary":
        """Merge per-chunk aggregates (the fused kernel's output shape)."""
        n_old = self.n_decided.astype(jnp.float32)
        tot = n_old + cnt
        mean = jnp.where(tot > 0,
                         (self.mean_ms * n_old + lat_sum)
                         / jnp.maximum(tot, 1.0), 0.0)
        return replace(self,
                       n_trials=self.n_trials + n_trials,
                       n_fast=self.n_fast + n_fast,
                       n_recovery=self.n_recovery + n_recovery,
                       n_undecided=self.n_undecided + n_undecided,
                       mean_ms=mean,
                       max_ms=jnp.maximum(self.max_ms, lat_max),
                       hist=self.hist + hist)

    # -- merges ------------------------------------------------------------
    def merge(self, other: "StreamSummary") -> "StreamSummary":
        """Combine two summaries as if their trials had been one stream.
        Counts and histograms are integer sums (exact — merge is associative
        and commutative bit-for-bit); means combine count-weighted."""
        if other.precision != self.precision:
            raise ValueError(
                f"cannot merge sketches of different precision "
                f"({self.precision} vs {other.precision})")
        return self._absorb(
            n_trials=other.n_trials, n_fast=other.n_fast,
            n_recovery=other.n_recovery, n_undecided=other.n_undecided,
            cnt=other.n_decided.astype(jnp.float32),
            lat_sum=other.mean_ms * other.n_decided.astype(jnp.float32),
            lat_max=other.max_ms, hist=other.hist)

    def axis_merge(self, axis_name: str) -> "StreamSummary":
        """Cross-device merge inside ``shard_map``: psum the counts and the
        sketch, pmax the max, count-weighted psum for the mean."""
        ps = lambda x: jax.lax.psum(x, axis_name)
        n_dec = self.n_decided.astype(jnp.float32)
        tot = ps(n_dec)
        mean = jnp.where(tot > 0,
                         ps(self.mean_ms * n_dec) / jnp.maximum(tot, 1.0),
                         0.0)
        return replace(self,
                       n_trials=ps(self.n_trials), n_fast=ps(self.n_fast),
                       n_recovery=ps(self.n_recovery),
                       n_undecided=ps(self.n_undecided),
                       mean_ms=mean,
                       max_ms=jax.lax.pmax(self.max_ms, axis_name),
                       hist=ps(self.hist))

    # -- queries -----------------------------------------------------------
    def quantile(self, q) -> jax.Array:
        """Sketch quantile estimate over decided trials: within
        ``precision`` relative error of the exact empirical quantile for
        latencies inside the sketch range.  ``q`` scalar -> (M,); ``q``
        (Q,) -> (Q, M).  NaN where nothing decided."""
        qv = jnp.atleast_1d(jnp.asarray(q, jnp.float32))
        n = self.n_decided
        cum = jnp.cumsum(self.hist, axis=-1)                   # (M, B)
        rank = jnp.clip(jnp.ceil(qv[:, None] * n[None, :]),
                        1, jnp.maximum(n, 1)[None, :])         # (Q, M)
        idx = jnp.argmax(cum[None, :, :] >= rank[:, :, None], axis=-1)
        val = jnp.where(n[None, :] > 0,
                        bucket_value(idx, self.precision), jnp.nan)
        return val[0] if jnp.ndim(q) == 0 else val

    def summary(self) -> Dict[str, jax.Array]:
        """The normalized summary dict (`engine.summarize` keys, plus the
        p99.9 that streaming trial counts make meaningful)."""
        n = jnp.maximum(self.n_trials, 1).astype(jnp.float32)
        has = self.n_decided > 0
        qs = self.quantile(jnp.array([0.5, 0.95, 0.99, 0.999]))
        return {
            "mean_ms": jnp.where(has, self.mean_ms, jnp.nan),
            "p50_ms": qs[0], "p95_ms": qs[1], "p99_ms": qs[2],
            "p999_ms": qs[3],
            "max_ms": jnp.where(has, self.max_ms, jnp.nan),
            "fast_rate": self.n_fast / n,
            "recovery_rate": self.n_recovery / n,
            "undecided_rate": self.n_undecided / n,
        }


# ---------------------------------------------------------------------------
# Chunked scan driver (+ shard_map over the trial axis).
# ---------------------------------------------------------------------------

def _lat_only_outcomes(lat: jax.Array, fast: bool) -> Dict[str, jax.Array]:
    """Latency-array paths (fast_path / classic_path) as an outcome dict."""
    und = lat >= UNDECIDED_MS
    no = jnp.zeros_like(und)
    return {"latency_ms": lat, "undecided": und,
            "reached_fast": ~und if fast else no,
            "recovery": no if fast else ~und}


def _chunk_outcomes(path: str, key, table, offsets, delay, *, n, k_proposers,
                    chunk, use_kernel) -> Dict[str, jax.Array]:
    if path == "race":
        return engine._race_outcomes(key, table, offsets, delay, n=n,
                                     k_proposers=k_proposers, samples=chunk,
                                     use_kernel=use_kernel)
    if path == "fast_path":
        return _lat_only_outcomes(
            engine._fast_path_outcomes(key, table, delay, n=n,
                                       samples=chunk), fast=True)
    return _lat_only_outcomes(
        engine._classic_path_outcomes(key, table, delay, n=n,
                                      samples=chunk), fast=False)


def _race_fused_update(state: StreamSummary, key, table, offsets, delay,
                       valid, *, n, k_proposers, chunk) -> StreamSummary:
    """Masked-table race chunk through the fused block-resident kernel:
    masked tally + decide + histogram never leave VMEM (DESIGN.md §3).

    The system-dependent saturation *times* still come from the presorted
    jnp draws (they are sorts + prefix sums, which the engine already
    shares across systems); the kernel fuses everything downstream of the
    votes: quorum tally, winner/reached, fast-vs-recovery decision, bucket
    histogram and the chunk's count/sum/max reductions.
    """
    draws = engine._sample_race(key, offsets, delay, n=n,
                                k_proposers=k_proposers, samples=chunk,
                                use_kernel=True)
    masks = {k: table[k] for k in MASK_KEYS}

    def times_one(m):
        val_sat = jax.vmap(
            lambda srt, perm: engine._sat_time(srt, perm, m["p2f_w"],
                                               m["p2f_t"]),
            in_axes=1, out_axes=1)(draws["sorted_val_arrive"],
                                   draws["perm_val_arrive"])      # (C, K)
        t_rec = engine._sat_time(draws["sorted_arrive"],
                                 draws["perm_arrive"],
                                 m["p1_w"], m["p1_t"]) \
            + engine._sat_time(draws["sorted_classic"],
                               draws["perm_classic"],
                               m["p2c_w"], m["p2c_t"])            # (C,)
        return val_sat, t_rec

    val_sat, t_rec = jax.vmap(times_one)(masks)       # (M, C, K), (M, C)
    from repro.kernels.quorum_tally import ops as qt_ops
    hist, stats = qt_ops.stream_tally_decide_hist(
        draws["votes"], table["p2f_w"], table["p2f_t"], val_sat, t_rec,
        valid, n_values=k_proposers, precision=state.precision,
        bins=state.bins, undecided_ms=float(UNDECIDED_MS))
    return state._absorb(
        n_trials=stats["n_fast"] + stats["n_recovery"] + stats["n_undecided"],
        n_fast=stats["n_fast"], n_recovery=stats["n_recovery"],
        n_undecided=stats["n_undecided"],
        cnt=(stats["n_fast"] + stats["n_recovery"]).astype(jnp.float32),
        lat_sum=stats["sum_ms"], lat_max=stats["max_ms"], hist=hist)


@functools.partial(jax.jit,
                   static_argnames=("path", "n", "k_proposers", "chunk",
                                    "n_chunks", "precision", "use_kernel",
                                    "mesh"))
def _stream(key, table, offsets, delay, trials, *, path, n, k_proposers,
            chunk, n_chunks, precision, use_kernel, mesh):
    engine.TRACE_COUNTS[path + "_stream"] += 1
    m = table["p1_w"].shape[0]
    fused = path == "race" and use_kernel and "q" not in table

    def device_stream(key, table, offsets, delay, trials):
        def body(state, i):
            k = jax.random.fold_in(key, i)
            valid = jnp.arange(chunk, dtype=jnp.int32) \
                < jnp.clip(trials - i * chunk, 0, chunk)
            if fused:
                state = _race_fused_update(state, k, table, offsets, delay,
                                           valid, n=n,
                                           k_proposers=k_proposers,
                                           chunk=chunk)
            else:
                out = _chunk_outcomes(path, k, table, offsets, delay, n=n,
                                      k_proposers=k_proposers, chunk=chunk,
                                      use_kernel=use_kernel)
                state = state.update(out, valid)
            return state, None
        state0 = StreamSummary.zeros(m, precision)
        state, _ = jax.lax.scan(body, state0,
                                jnp.arange(n_chunks, dtype=jnp.int32))
        return state

    if mesh is None:
        return device_stream(key, table, offsets, delay, trials)

    ndev = mesh.shape[psharding.TRIAL_AXIS]

    def per_device(key, table, offsets, delay, trials):
        d = jax.lax.axis_index(psharding.TRIAL_AXIS)
        t_d = trials // ndev + jnp.where(d < trials % ndev, 1, 0)
        k_d = jax.random.fold_in(key, jnp.int32(0x5eed) + d)
        return device_stream(k_d, table, offsets, delay,
                             t_d).axis_merge(psharding.TRIAL_AXIS)

    return psharding.shard_map(
        per_device, mesh=mesh, in_specs=(P(), P(), P(), P(), P()),
        out_specs=P())(key, table, offsets, delay, trials)


def _resolve_mesh(shard):
    if shard is False or shard is None:
        return None
    if shard is True:
        return psharding.trial_mesh() if len(jax.devices()) > 1 else None
    return shard                       # an explicit Mesh


def _stream_entry(path: str, key, table, delay, offsets, *, n, k_proposers,
                  trials, chunk, precision, use_kernel, shard
                  ) -> StreamSummary:
    engine._check_mask_table(table, n)
    if trials < 1:
        raise ValueError(f"trials must be >= 1, got {trials}")
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    sketch_bins(precision)             # validates precision
    mesh = _resolve_mesh(shard)
    if mesh is None and trials <= chunk:
        # The materializing path IS the T <= chunk special case: same
        # compile as direct engine calls, bit-identical draws, reduced.
        if path == "race":
            out = engine.race(key, table, offsets, delay, n=n,
                              k_proposers=k_proposers, samples=trials,
                              use_kernel=use_kernel)
        elif path == "fast_path":
            out = _lat_only_outcomes(
                engine.fast_path(key, table, delay, n=n, samples=trials),
                fast=True)
        else:
            out = _lat_only_outcomes(
                engine.classic_path(key, table, delay, n=n, samples=trials),
                fast=False)
        return StreamSummary.from_outcomes(out, precision)
    ndev = 1 if mesh is None else mesh.shape[psharding.TRIAL_AXIS]
    per_device = -(-trials // ndev)                # ceil: busiest device
    n_chunks = -(-per_device // chunk)
    if delay is None:
        delay = default_delay()
    offsets = (jnp.zeros((1,), jnp.float32) if offsets is None
               else jnp.asarray(offsets, jnp.float32))
    return _stream(key, table, offsets, delay, jnp.int32(trials), path=path,
                   n=n, k_proposers=k_proposers, chunk=chunk,
                   n_chunks=n_chunks, precision=precision,
                   use_kernel=use_kernel, mesh=mesh)


def race_stream(key, table, offsets, delay=None, *, n: int, k_proposers: int,
                trials: int, chunk: int = DEFAULT_CHUNK,
                precision: float = DEFAULT_PRECISION,
                use_kernel: bool = False, shard: bool = True
                ) -> StreamSummary:
    """``engine.race`` at any trial count in fixed memory: chunked
    ``lax.scan`` reduction into a ``StreamSummary``, trial axis sharded
    over local devices when ``shard`` (a bool or an explicit 1-D mesh).
    One compile per (table shape, chunk count); ``trials`` is traced."""
    return _stream_entry("race", key, table, delay, offsets, n=n,
                         k_proposers=k_proposers, trials=trials, chunk=chunk,
                         precision=precision, use_kernel=use_kernel,
                         shard=shard)


def fast_path_stream(key, table, delay=None, *, n: int, trials: int,
                     chunk: int = DEFAULT_CHUNK,
                     precision: float = DEFAULT_PRECISION,
                     shard: bool = True) -> StreamSummary:
    """Streamed conflict-free fast path (k=1): decided instances count as
    fast-path commits, lost ones as undecided."""
    return _stream_entry("fast_path", key, table, delay, None, n=n,
                         k_proposers=1, trials=trials, chunk=chunk,
                         precision=precision, use_kernel=False, shard=shard)


def classic_path_stream(key, table, delay=None, *, n: int, trials: int,
                        chunk: int = DEFAULT_CHUNK,
                        precision: float = DEFAULT_PRECISION,
                        shard: bool = True) -> StreamSummary:
    """Streamed leader-relayed classic path: decided instances count as
    recoveries (there is no fast path to reach)."""
    return _stream_entry("classic_path", key, table, delay, None, n=n,
                         k_proposers=1, trials=trials, chunk=chunk,
                         precision=precision, use_kernel=False, shard=shard)
