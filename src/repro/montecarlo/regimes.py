"""Markov-modulated failure regimes over the streamed trial axis.

A streamed run today draws every trial from ONE static environment.  Real
deployments sweep through *epochs*: a diurnal baseline, a gray-failure
degradation, an asymmetric partition, a burst of rack-correlated crashes.
This module makes that sweep a first-class, single-compile part of the
streaming engine (DESIGN.md §12):

  regimes        ``MarkovRegimes``: R named regimes, each a FULL delay +
                 fault environment (any ``latency``/``traces`` pytree,
                 ``CrashedDelay``/``LossyDelay`` wrappers included), plus
                 an (R, R) transition matrix and an epoch length in
                 trials.
  chain          the regime of trial ``t`` is ``z[t // epoch_trials]``
                 where ``z`` is a Markov chain stepped once per epoch from
                 its own fold-in key domain (``REGIME_FOLD_DOMAIN`` —
                 disjoint from chunk and device domains).  The epoch
                 mapping lives in TRIAL index space, not chunk space, so
                 regime occupancy is invariant under the ``chunk`` size
                 (property-tested) and the chain prefix is the same for
                 any scan length.
  scan           ``streaming._stream`` samples each chunk under ALL R
                 environments and selects per-trial by regime id
                 (``_RegimeMixedDelay``), then scatters the chunk's
                 outcomes into PER-REGIME ``StreamSummary`` slices — one
                 ``lax.scan``, one compile per table shape, trials and
                 every environment parameter traced.
  merge          per-regime slices ride the existing integer-exact merges:
                 ``axis_merge`` across devices inside ``shard_map``, and
                 ``RegimeStreamSummary.total()`` across regimes — decide
                 counts and histograms are exact sums, so the marginal
                 summary equals a single mixed stream bit-for-bit.

Degenerate single-regime chains keep the i.i.d. contract: with R == 1 the
mixed-delay wrapper passes the chunk key through unfolded, so draws,
decide bits, counts and histograms are bit-identical to the plain
``race_stream``/``fast_path_stream`` on the same key (acceptance-tested).

Declarative configs (the scenario-suite JSON shape, satellite of the
``Workload`` schema)::

    {"epoch_trials": 8192,
     "regimes": [
       {"name": "baseline"},                           # inherit base delay
       {"name": "degraded",
        "delay": {"kind": "pareto", "scale_ms": 0.8},
        "loss_prob": 0.02},
       {"name": "partitioned", "crashed": [0, 1, 2]}],
     "transition": [[0.98, 0.01, 0.01],
                    [0.10, 0.88, 0.02],
                    [0.20, 0.00, 0.80]]}

``MarkovRegimes.from_config`` builds the concrete pytree (resolving
delay kinds through the ``latency`` registry); ``to_config`` inverts it.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .latency import (CrashedDelay, LossyDelay, PROPOSAL, delay_from_config,
                      delay_to_config)

# First-level fold-in tag for the regime chain's key stream.  Chunk c of a
# stream draws from fold_in(key, c); device d re-keys through
# fold_in(fold_in(key, DEVICE_FOLD_DOMAIN=0x7FFFFFFF), d); the regime
# chain steps from fold_in(fold_in(key, REGIME_FOLD_DOMAIN), epoch).  The
# tag sits next to the device domain at the top of int32 space — disjoint
# from any realistic chunk index — and differs from DEVICE_FOLD_DOMAIN,
# so all three key families are collision-free.
REGIME_FOLD_DOMAIN = 0x7FFFFFFE

# Default epoch length: regimes persist for thousands of trials (the
# correlated-failure point), while 10^6-trial runs still see hundreds of
# transitions.
DEFAULT_EPOCH_TRIALS = 8192

_ROW_SUM_TOL = 1e-6


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class MarkovRegimes:
    """R named regime environments + an (R, R) Markov transition matrix.

    ``delays[r]`` is the full delay+fault environment of regime r (any
    registered delay pytree); ``None`` entries inherit the scenario's base
    delay at bind time (``bound``).  ``transition[i, j]`` is
    P(next = j | current = i); rows must sum to 1 (``validate``).  The
    chain starts in regime ``start`` and steps once every
    ``epoch_trials`` trials.

    The transition matrix and every environment parameter are traced
    leaves; only the regime count, names, epoch length and start index are
    static — re-weighting the chain or refitting an environment re-enters
    the same compile.
    """

    names: Tuple[str, ...]
    delays: Tuple[object, ...]
    transition: jax.Array           # (R, R) float32
    epoch_trials: int = DEFAULT_EPOCH_TRIALS
    start: int = 0

    def tree_flatten(self):
        return ((self.delays, self.transition),
                (self.names, self.epoch_trials, self.start))

    @classmethod
    def tree_unflatten(cls, aux, children):
        delays, transition = children
        names, epoch_trials, start = aux
        return cls(names=names, delays=tuple(delays), transition=transition,
                   epoch_trials=epoch_trials, start=start)

    @property
    def n_regimes(self) -> int:
        return len(self.names)

    # -- validation --------------------------------------------------------
    def validate(self) -> "MarkovRegimes":
        """Host-side invariants (concrete transition matrix only): square
        (R, R) matrix matching the regime count, non-negative entries,
        every row summing to 1, valid start index, positive epoch."""
        r = self.n_regimes
        if r < 1:
            raise ValueError("MarkovRegimes needs at least one regime")
        if len(self.delays) != r:
            raise ValueError(f"{r} regime names but {len(self.delays)} "
                             f"delay environments")
        if len(set(self.names)) != r:
            raise ValueError(f"regime names must be unique, "
                             f"got {self.names}")
        t = np.asarray(self.transition, np.float64)
        if t.shape != (r, r):
            raise ValueError(f"transition matrix must be ({r}, {r}) for "
                             f"{r} regimes, got {t.shape}")
        if np.any(t < 0) or not np.all(np.isfinite(t)):
            raise ValueError("transition probabilities must be finite and "
                             ">= 0")
        rows = t.sum(axis=1)
        bad = np.nonzero(np.abs(rows - 1.0) > _ROW_SUM_TOL)[0]
        if bad.size:
            raise ValueError(
                f"transition rows must sum to 1: row(s) "
                f"{[self.names[i] for i in bad]} sum to "
                f"{rows[bad].tolist()}")
        if not 0 <= self.start < r:
            raise ValueError(f"start regime {self.start} out of range "
                             f"[0, {r})")
        if self.epoch_trials < 1:
            raise ValueError(f"epoch_trials must be >= 1, "
                             f"got {self.epoch_trials}")
        return self

    # -- binding -----------------------------------------------------------
    def bound(self, base_delay) -> "MarkovRegimes":
        """Substitute the scenario's base delay into inheriting slots:
        ``None`` becomes the base model itself, deferred loss/crash
        wrappers wrap it (idempotent once every slot is concrete)."""
        def _bind(d):
            if d is None:
                return base_delay
            if isinstance(d, (_DeferredCrash, _DeferredLoss)):
                return d.bind(base_delay)
            return d

        if not any(d is None or isinstance(d, (_DeferredCrash,
                                               _DeferredLoss))
                   for d in self.delays):
            return self
        return replace(self, delays=tuple(_bind(d) for d in self.delays))

    # -- the chain ---------------------------------------------------------
    def sequence(self, key: jax.Array, n_epochs: int) -> jax.Array:
        """(n_epochs,) int32 regime ids: z[0] = start, z[e+1] sampled from
        transition row z[e] under ``fold_in(key, e)``.  A scan prefix —
        z[e] is independent of ``n_epochs``, which is what makes regime
        assignment invariant to chunking (longer scans only append)."""
        cum = jnp.cumsum(self.transition.astype(jnp.float32), axis=1)
        r = self.n_regimes

        def step(z, e):
            u = jax.random.uniform(jax.random.fold_in(key, e), ())
            z_next = jnp.clip(
                jnp.searchsorted(cum[z], u, side="right"), 0, r - 1
            ).astype(jnp.int32)
            return z_next, z

        _, zs = jax.lax.scan(step, jnp.int32(self.start),
                             jnp.arange(n_epochs, dtype=jnp.int32))
        return zs

    def mixed_delay(self, rid: jax.Array) -> "_RegimeMixedDelay":
        """The per-sample environment selector for one chunk: ``rid`` is
        the (chunk,) regime id of each trial."""
        return _RegimeMixedDelay(models=self.delays, rid=rid)

    # -- declarative config ------------------------------------------------
    @classmethod
    def from_config(cls, cfg: Dict, n: Optional[int] = None
                    ) -> "MarkovRegimes":
        """Build from the JSON scenario-suite shape (module docstring).
        ``n`` resolves cluster-size-dependent pieces: per-regime ``crashed``
        lists and symmetric-WAN delay shorthands."""
        if isinstance(cfg, cls):
            return cfg.validate()
        entries = cfg["regimes"]
        if not entries:
            raise ValueError("regime config needs at least one regime")
        names, delays = [], []
        for i, e in enumerate(entries):
            names.append(str(e.get("name", f"regime{i}")))
            d = delay_from_config(e.get("delay"), n)
            loss = float(e.get("loss_prob", 0.0))
            crashed = tuple(e.get("crashed", ()))
            mask = None
            if crashed:
                if n is None:
                    raise ValueError(
                        f"regime {names[-1]!r} crashes acceptors "
                        f"{sorted(crashed)} but the cluster size is "
                        f"unknown; resolve the config with n=")
                m_ = np.zeros((n,), bool)
                m_[np.asarray(sorted(set(crashed)), np.int64)] = True
                mask = jnp.asarray(m_)
            if d is None:
                # loss/crashes on top of the INHERITED base delay: defer
                # the wrap until the scenario binds its model.
                if loss:
                    d = _DeferredLoss(loss, mask)
                elif mask is not None:
                    d = _DeferredCrash(mask)
            else:
                if loss:
                    d = LossyDelay(d, loss)
                if mask is not None:
                    d = CrashedDelay(d, mask)
            delays.append(d)
        out = cls(names=tuple(names), delays=tuple(delays),
                  transition=jnp.asarray(cfg["transition"], jnp.float32),
                  epoch_trials=int(cfg.get("epoch_trials",
                                           DEFAULT_EPOCH_TRIALS)),
                  start=int(cfg.get("start", 0)))
        return out.validate()

    def to_config(self) -> Dict:
        """Invert ``from_config`` (deferred base-delay wrappers serialize
        back to their declarative form)."""
        entries = []
        for name, d in zip(self.names, self.delays):
            e: Dict = {"name": name}
            e.update(_env_to_config(d))
            entries.append(e)
        return {"regimes": entries,
                "transition": np.asarray(self.transition,
                                         np.float64).tolist(),
                "epoch_trials": int(self.epoch_trials),
                "start": int(self.start)}


def _env_to_config(d) -> Dict:
    """One regime environment -> config fields (inverse of the per-entry
    build in ``from_config``)."""
    if d is None:
        return {}
    if isinstance(d, _DeferredCrash):
        return {"crashed": np.nonzero(np.asarray(d.crashed))[0].tolist()}
    if isinstance(d, _DeferredLoss):
        out = {"loss_prob": float(np.asarray(d.loss_prob))}
        if d.crashed is not None:
            out["crashed"] = np.nonzero(np.asarray(d.crashed))[0].tolist()
        return out
    return {"delay": delay_to_config(d)}


# Wrappers for regimes that modify the *inherited* base delay (loss /
# crashes on top of whatever the scenario runs): the inner model is not
# known until ``bound`` time, so they defer the wrap.
@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class _DeferredCrash:
    """Crash these acceptors on top of the scenario's base delay."""

    crashed: jax.Array

    def bind(self, base):
        return CrashedDelay(base, self.crashed)

    def tree_flatten(self):
        return (self.crashed,), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class _DeferredLoss:
    """Loss (and optionally crashes) on top of the scenario's base delay."""

    loss_prob: float
    crashed: Optional[jax.Array] = None

    def bind(self, base):
        d = LossyDelay(base, self.loss_prob)
        return CrashedDelay(d, self.crashed) if self.crashed is not None \
            else d

    def tree_flatten(self):
        return (self.loss_prob, self.crashed), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)


# ---------------------------------------------------------------------------
# Per-sample environment selection inside one chunk.
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class _RegimeMixedDelay:
    """Sample every hop under all R environments and select per trial.

    ``rid`` is the (S,) regime id of each sample in the chunk (S = the
    leading axis of every hop shape).  With R == 1 the single model
    samples on the UNFOLDED key — draws are bit-identical to running that
    model directly, which is the single-regime degeneracy contract.  With
    R > 1 each environment draws from its own fold-in sub-stream
    (environments stay independent even when two regimes share a model),
    and ``jnp.where`` keeps each trial's selected regime.  Sampling cost
    is R x the base model mix — the decide/reduce pipeline (the actual
    hot path) still runs once.
    """

    models: Tuple[object, ...]
    rid: jax.Array                  # (S,) int32

    def sample_hops(self, key: jax.Array, shape,
                    kind: str = PROPOSAL) -> jax.Array:
        if len(self.models) == 1:
            return self.models[0].sample_hops(key, shape, kind)
        sel = self.rid.reshape((-1,) + (1,) * (len(shape) - 1))
        out = None
        for r, m in enumerate(self.models):
            d = m.sample_hops(jax.random.fold_in(key, r), shape, kind)
            out = d if out is None else jnp.where(sel == r, d, out)
        return out

    def tree_flatten(self):
        return (self.models, self.rid), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        models, rid = children
        return cls(models=tuple(models), rid=rid)


# ---------------------------------------------------------------------------
# Per-regime result: stacked StreamSummary slices + occupancy.
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class RegimeStreamSummary:
    """A streamed run decomposed by regime.

    ``by_regime`` is a ``StreamSummary`` whose leaves carry a leading R
    axis — regime r's slice is a full, independently mergeable summary of
    exactly the trials the chain spent in regime r.  ``occupancy`` is the
    (R,) trial count per regime (sums to the run's total trials — the
    chunk-invariance property test pins it).  ``total()`` merges the
    slices back into the marginal summary with the integer-exact
    ``StreamSummary.merge``; the count/quantile-facing surface of
    ``StreamSummary`` is mirrored here and delegates to the total, so a
    ``RegimeStreamSummary`` drops into every consumer of a plain stream
    summary (frontier axes, ``Results``, benchmarks).
    """

    names: Tuple[str, ...]
    occupancy: jax.Array            # (R,) int32 valid trials per regime
    by_regime: "object"             # StreamSummary, leaves (R, M) / (R, M, B)

    def tree_flatten(self):
        return ((self.occupancy, self.by_regime), self.names)

    @classmethod
    def tree_unflatten(cls, aux, children):
        occupancy, by_regime = children
        return cls(names=aux, occupancy=occupancy, by_regime=by_regime)

    @property
    def n_regimes(self) -> int:
        return len(self.names)

    @property
    def precision(self) -> float:
        return self.by_regime.precision

    # -- slicing / merging -------------------------------------------------
    def regime(self, which):
        """Regime slice (by name or index) as a plain ``StreamSummary``."""
        i = which if isinstance(which, int) else self.names.index(which)
        return jax.tree_util.tree_map(lambda x: x[i], self.by_regime)

    def total(self):
        """The marginal summary: integer-exact merge across regimes."""
        return functools.reduce(
            lambda a, b: a.merge(b),
            [self.regime(i) for i in range(self.n_regimes)])

    def merge(self, other: "RegimeStreamSummary") -> "RegimeStreamSummary":
        """Combine two regime-decomposed runs (same regime set)."""
        if self.names != other.names:
            raise ValueError(f"cannot merge different regime sets "
                             f"{self.names} vs {other.names}")
        return RegimeStreamSummary(
            names=self.names,
            occupancy=self.occupancy + other.occupancy,
            by_regime=self.by_regime.merge(other.by_regime))

    # -- StreamSummary-compatible surface (delegates to the total) ---------
    @property
    def n_trials(self):
        return self.total().n_trials

    @property
    def n_fast(self):
        return self.total().n_fast

    @property
    def n_recovery(self):
        return self.total().n_recovery

    @property
    def n_undecided(self):
        return self.total().n_undecided

    @property
    def n_decided(self):
        return self.total().n_decided

    @property
    def max_ms(self):
        return self.total().max_ms

    @property
    def mean_ms(self):
        return self.total().mean_ms

    @property
    def hist(self):
        return self.total().hist

    def quantile(self, q):
        return self.total().quantile(q)

    def summary(self):
        return self.total().summary()

    # -- reporting ---------------------------------------------------------
    def report(self) -> Dict:
        """Host-side per-regime breakdown: occupancy plus each regime's
        normalized summary (scalars for M == 1, lists otherwise)."""
        def _host(v):
            a = np.asarray(v)
            return a.item() if a.size == 1 else a.tolist()

        occ = np.asarray(self.occupancy, np.int64)
        out = {"names": list(self.names), "occupancy": occ.tolist(),
               "occupancy_frac": (occ / max(int(occ.sum()), 1)).tolist(),
               "per_regime": {}}
        for i, name in enumerate(self.names):
            s = self.regime(i)
            out["per_regime"][name] = {k: _host(v)
                                       for k, v in s.summary().items()}
        return out


# ---------------------------------------------------------------------------
# Named presets (the ISSUE's baseline / degraded / partitioned / burst-crash
# vocabulary) — convenience builders for benchmarks and examples.
# ---------------------------------------------------------------------------

def gray_failure(n: int, *, epoch_trials: int = DEFAULT_EPOCH_TRIALS,
                 degraded_scale_ms: float = 0.8, loss_prob: float = 0.02,
                 partition: Sequence[int] = (0, 1, 2),
                 p_fail: float = 0.01, p_recover: float = 0.15
                 ) -> MarkovRegimes:
    """A 3-regime gray-failure chain: healthy baseline, a heavy-tailed
    lossy degradation, and a partition that crashes ``partition``.  The
    baseline inherits the scenario's delay; transitions keep the chain in
    baseline ~98% of epochs."""
    from .latency import ParetoDelay
    cfg_t = [[1.0 - 2 * p_fail, p_fail, p_fail],
             [p_recover, 1.0 - p_recover - p_fail, p_fail],
             [p_recover, 0.0, 1.0 - p_recover]]
    mask = np.zeros((n,), bool)
    mask[np.asarray(sorted(set(partition)), np.int64)] = True
    return MarkovRegimes(
        names=("baseline", "degraded", "partitioned"),
        delays=(None,
                LossyDelay(ParetoDelay(scale_ms=degraded_scale_ms),
                           loss_prob),
                _DeferredCrash(jnp.asarray(mask))),
        transition=jnp.asarray(cfg_t, jnp.float32),
        epoch_trials=epoch_trials).validate()
