"""Named scenario builders: a scenario bundles race geometry (how many
proposers, at what offsets) with a delay model, and knows how to run itself
over a quorum-system *mask* table (``engine.build_mask_table`` — the single
lowering for cardinality, grid, weighted and explicit systems) in one
engine call.

Builders cover the paper's §6 workloads plus the deployments the relaxation
is aimed at:

  conflict_free      Fig. 2a — one proposer, pure fast-path order statistics
  k_way_race         Fig. 2b/2c generalized — K proposers staggered by Δ
  mixed_workload     fraction p of commands race, the rest are clean
  wan                geo-distributed acceptors (multi-region delay matrix)
  lossy_acceptors    i.i.d. message loss on every hop
  grid_wan           §6 closing remark: a 3xC grid system whose rows ARE the
                     WAN regions (returns scenario + masks)
  weighted_acceptors Gifford-style weighted voting with optional crashes
                     (returns scenario + masks)

The last two pair a workload with the quorum system it is built around and
support per-acceptor fault injection (``CrashedDelay``), so quorum structure
and failure placement can be studied together — e.g. crashing a whole grid
row versus the same number of scattered acceptors.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.quorum import (ExplicitQuorumSystem, QuorumMasks,
                               WeightedQuorumSystem)

from . import engine
from .latency import (CrashedDelay, LossyDelay, ShiftedLognormalDelay,
                      WanDelay, default_delay)


@dataclass(frozen=True)
class RunSpec:
    """Execution knobs of a scenario run, carried BY the scenario.

    ``Scenario.run`` / ``summary`` / ``stream`` take only (key, table);
    every execution knob lives here, stated once:
    ``scenario.with_spec(trials=10**7, faults=(0, 3)).stream(key, table)``.

    ``samples`` sizes materializing runs (``run``/``summary``), ``trials``
    streamed ones; ``chunk``/``precision`` default to the streaming
    module's defaults when None.  ``faults`` crashes those acceptor ids
    for the run (``CrashedDelay``); ``regimes`` (a
    ``regimes.MarkovRegimes`` or its config dict) Markov-modulates a
    streamed run through failure epochs (DESIGN.md §12); ``recovery``
    selects the collision-recovery rule (``engine.RECOVERY_MODES``).
    """

    samples: int = 20000
    trials: int = 1_000_000
    chunk: Optional[int] = None
    precision: Optional[float] = None
    use_kernel: bool = False
    shard: bool = True
    k_max: object = "auto"
    faults: Tuple[int, ...] = ()
    regimes: Optional[object] = None
    recovery: str = "coordinated"

    def merged(self, **overrides) -> "RunSpec":
        """This spec with every non-None override applied."""
        kw = {k: v for k, v in overrides.items() if v is not None}
        return replace(self, **kw) if kw else self


@dataclass(frozen=True)
class Scenario:
    """A runnable workload: K proposers at ``offsets_ms`` under ``delay``.

    ``conflict_frac`` < 1 mixes in conflict-free commands: the reported
    per-spec latency distribution is the blend, as in Fig. 2b.  ``spec``
    carries the execution knobs (``RunSpec``).
    """

    name: str
    n: int
    k_proposers: int
    offsets_ms: jax.Array            # (K,)
    delay: object
    conflict_frac: float = 1.0
    spec: RunSpec = RunSpec()

    def with_spec(self, spec: Optional[RunSpec] = None, **kw) -> "Scenario":
        """Carry these execution knobs: ``with_spec(trials=10**7)``
        overrides fields of the current spec; ``with_spec(RunSpec(...))``
        replaces it outright (then applies any overrides)."""
        base = self.spec if spec is None else spec
        if kw:
            base = replace(base, **kw)
        return replace(self, spec=base)

    def with_faults(self, crashed: Sequence[int]) -> "Scenario":
        """Inject per-acceptor crashes: every hop touching a crashed
        acceptor is lost (``CrashedDelay``)."""
        if not len(tuple(crashed)):
            return self
        return replace(self, delay=CrashedDelay(
            self.delay, _crash_mask(self.n, crashed)))

    def run(self, key: jax.Array, table) -> Dict[str, jax.Array]:
        """Evaluate every quorum system in ``table`` (a ``build_mask_table``
        dict — cardinality, grid, weighted and explicit systems all lower to
        it) over ``spec.samples`` instances.

        Returns (M, S)-shaped ``latency_ms`` plus race outcome flags (for the
        racing fraction) — one engine compile per (shape, scenario type).
        Execution knobs come from ``self.spec`` only (``with_spec``)."""
        return self._run(key, table, self.spec)

    def _run(self, key: jax.Array, table,
             spec: RunSpec) -> Dict[str, jax.Array]:
        scen = self.with_faults(spec.faults)
        samples = spec.samples
        m = table["p1_w"].shape[0]
        if self.k_proposers == 1 or self.conflict_frac == 0.0:
            lat = engine.fast_path(key, table, scen.delay, n=self.n,
                                   samples=samples)
            undecided = lat >= engine.UNDECIDED_MS   # fast path never arrived
            return {"latency_ms": lat, "reached_fast": ~undecided,
                    "recovery": jnp.zeros((m, samples), bool),
                    "undecided": undecided,
                    "fast_winner": jnp.where(undecided, -1, 0).astype(
                        jnp.int32)}

        k_race, k_free = jax.random.split(key)
        n_conf = max(1, int(round(samples * self.conflict_frac)))
        out = engine.race(k_race, table, self.offsets_ms, scen.delay,
                          n=self.n, k_proposers=self.k_proposers,
                          samples=n_conf, use_kernel=spec.use_kernel,
                          recovery=spec.recovery)
        n_free = samples - n_conf
        if n_free > 0:
            scen_free = Scenario(self.name, self.n, 1, self.offsets_ms[:1],
                                 scen.delay)
            free = scen_free._run(k_free, table,
                                  replace(spec, samples=n_free, faults=()))
            out = {k: jnp.concatenate([free[k], out[k]], axis=-1)
                   for k in out}
        return out

    def summary(self, key: jax.Array, table) -> Dict[str, jax.Array]:
        """Per-system latency quantiles + outcome rates, each entry (M,).

        Quantiles cover *decided* instances only; instances that never
        gathered enough votes (message loss) are reported separately via
        ``undecided_rate`` instead of polluting the distribution with the
        LOST_MS sentinel (``engine.summarize``)."""
        return engine.summarize(self._run(key, table, self.spec))

    def stream(self, key: jax.Array, table):
        """Streamed evaluation: ``spec.trials`` instances reduced
        chunk-by-chunk into a fixed-size ``streaming.StreamSummary`` (device
        memory is one chunk regardless of the trial count; the trial axis
        shards over local devices when ``spec.shard``).  A mixed workload
        streams its racing and conflict-free fractions separately and
        *merges* the two summaries — sketch merge is exact, so the blend
        matches a single mixed stream.

        ``spec.k_max`` selects the sort-free lowering (DESIGN.md §9):
        "auto" derives per-phase top-k selection depths from the table,
        ``None`` keeps the full-sort reference path; integer outputs are
        identical.  ``spec.regimes`` Markov-modulates the stream through
        failure epochs and returns a ``RegimeStreamSummary`` instead
        (DESIGN.md §12).  Execution knobs come from ``self.spec`` only
        (``with_spec``).
        """
        return self._stream(key, table, self.spec)

    def _stream(self, key: jax.Array, table, spec: RunSpec):
        from . import streaming
        scen = self.with_faults(spec.faults)
        trials = spec.trials
        kw = dict(
            chunk=(streaming.DEFAULT_CHUNK if spec.chunk is None
                   else spec.chunk),
            precision=(streaming.DEFAULT_PRECISION if spec.precision is None
                       else spec.precision),
            shard=spec.shard, k_max=spec.k_max, regimes=spec.regimes)
        if self.k_proposers == 1 or self.conflict_frac == 0.0:
            return streaming.fast_path_stream(key, table, scen.delay,
                                              n=self.n, trials=trials, **kw)
        k_race, k_free = jax.random.split(key)
        n_conf = max(1, int(round(trials * self.conflict_frac)))
        state = streaming.race_stream(k_race, table, self.offsets_ms,
                                      scen.delay, n=self.n,
                                      k_proposers=self.k_proposers,
                                      trials=n_conf,
                                      use_kernel=spec.use_kernel,
                                      recovery=spec.recovery, **kw)
        if trials - n_conf > 0:
            free = streaming.fast_path_stream(k_free, table, scen.delay,
                                              n=self.n,
                                              trials=trials - n_conf, **kw)
            state = state.merge(free)
        return state


# ---------------------------------------------------------------------------
# Builders.
# ---------------------------------------------------------------------------

def conflict_free(n: int = 11, delay=None) -> Scenario:
    """Fig. 2a: a steady conflict-free stream; latency is the q2f-th order
    statistic of client->acceptor->learner paths."""
    return Scenario("conflict_free", n, 1, jnp.zeros((1,)),
                    delay if delay is not None else default_delay())


def k_way_race(k: int, delta_ms: float = 0.5, n: int = 11,
               delay=None) -> Scenario:
    """K proposals race for one instance; proposer i submits at i * Δ.
    k=2, Δ swept reproduces Fig. 2c; larger k models hotter keys."""
    if k < 2:
        raise ValueError("a race needs at least 2 proposers")
    offs = delta_ms * jnp.arange(k, dtype=jnp.float32)
    return Scenario(f"{k}_way_race", n, k, offs,
                    delay if delay is not None else default_delay())


def mixed_workload(conflict_frac: float = 0.10, delta_ms: float = 0.5,
                   k: int = 2, n: int = 11, delay=None) -> Scenario:
    """Fig. 2b: ``conflict_frac`` of commands race (K-way, Δ apart), the
    rest commit conflict-free."""
    base = k_way_race(k, delta_ms, n, delay)
    return replace(base, name="mixed_workload", conflict_frac=conflict_frac)


def wan(n: int = 11, k: int = 2, inter_region_ms: float = 30.0,
        n_regions: int = 3, delta_ms: float = 0.5) -> Scenario:
    """Geo-distributed deployment: acceptors round-robin across
    ``n_regions`` regions ``inter_region_ms`` apart (one-way), proposers in
    distinct regions.  Here quorum choice interacts with *which* acceptors
    are near, not just how many — the regime the relaxation targets."""
    delay = WanDelay.symmetric(inter_region_ms, n, k, n_regions)
    offs = delta_ms * jnp.arange(k, dtype=jnp.float32)
    return Scenario("wan", n, k, offs, delay)


def lossy_acceptors(loss_prob: float = 0.01, k: int = 2,
                    delta_ms: float = 0.5, n: int = 11,
                    inner=None) -> Scenario:
    """Every hop independently drops with ``loss_prob``; lost proposals mean
    missing votes, surfacing as higher recovery and ``undecided`` rates."""
    delay = LossyDelay(inner if inner is not None else default_delay(),
                       loss_prob)
    offs = delta_ms * jnp.arange(k, dtype=jnp.float32)
    return Scenario("lossy_acceptors", n, k, offs, delay)


# ---------------------------------------------------------------------------
# General-quorum-system workloads (the §6 closing remark): each builder
# returns (scenario, masks) — the workload and the quorum system it is
# built around — ready for ``engine.build_mask_table`` + ``Scenario.run``.
# ---------------------------------------------------------------------------

def _crash_mask(n: int, crashed: Sequence[int]) -> jnp.ndarray:
    m = jnp.zeros((n,), bool)
    if len(tuple(crashed)):
        m = m.at[jnp.array(sorted(set(crashed)), jnp.int32)].set(True)
    return m


def grid_wan(cols: int = 3, k: int = 2, inter_region_ms: float = 30.0,
             delta_ms: float = 0.5,
             crashed: Sequence[int] = ()) -> Tuple[Scenario, QuorumMasks]:
    """A 3xC grid quorum system deployed so each grid *row* is a WAN region.

    Acceptor r*cols + c sits in region r; phase-2 classic quorums (columns)
    span all three regions, fast quorums (row pairs) need two full regions —
    quorum choice is now about *which* acceptors, the regime the paper's
    relaxation targets.  ``crashed`` injects acceptor failures (e.g. a whole
    row = a region outage vs the same count scattered across regions).
    """
    system = ExplicitQuorumSystem.grid(cols)
    n, rows = system.n, 3
    ow = inter_region_ms * (1.0 - jnp.eye(rows))
    delay = WanDelay(oneway_ms=ow,
                     acceptor_region=(jnp.arange(n, dtype=jnp.int32) // cols),
                     proposer_region=jnp.arange(k, dtype=jnp.int32) % rows,
                     learner_region=jnp.int32(0))
    if len(tuple(crashed)):
        delay = CrashedDelay(delay, _crash_mask(n, crashed))
    offs = delta_ms * jnp.arange(k, dtype=jnp.float32)
    return Scenario("grid_wan", n, k, offs, delay), system.to_masks()


def weighted_acceptors(weights: Sequence[int] = (2, 2, 2, 1, 1, 1, 1, 1, 1, 1, 1),
                       thresholds: Optional[Tuple[int, int, int]] = None,
                       k: int = 2, delta_ms: float = 0.5,
                       crashed: Sequence[int] = ()) -> Tuple[Scenario, QuorumMasks]:
    """Gifford-style weighted voting: heavyweight acceptors shrink quorum
    *cardinality* on the fast path while the FFP weight inequalities keep
    safety.  Default thresholds mirror the paper-headline shape in weight
    space — t1 = ceil(3W/4), then the minimal valid phase-2 thresholds
    (t1 + t2c > W, t1 + 2*t2f > 2W) — so all three phases tolerate
    crashes; ``crashed`` injects failures (a heavy node costs more than a
    light one).
    """
    n, total = len(weights), sum(weights)
    if thresholds is None:
        t1 = math.ceil(3 * total / 4)
        t2c = total - t1 + 1                    # Eq.13 analogue, weights
        t2f = (2 * total - t1) // 2 + 1         # Eq.14 analogue, weights
        thresholds = (t1, t2c, t2f)
    system = WeightedQuorumSystem(tuple(weights), *thresholds).validate()
    delay = default_delay()
    if len(tuple(crashed)):
        delay = CrashedDelay(delay, _crash_mask(n, crashed))
    offs = delta_ms * jnp.arange(k, dtype=jnp.float32)
    return Scenario("weighted_acceptors", n, k, offs, delay), system.to_masks()
