"""K-proposer conflict-race engine with traced quorum thresholds.

The paper's §5 point is that Eqs. 13/14 admit a *space* of (q1, q2c, q2f)
configurations; evaluating that space is this module's job.  The old
``repro.core.jax_sim`` jitted each spec separately (quorum sizes were
``static_argnums``), so a sweep over the n=11 frontier recompiled dozens of
times.  Here the thresholds are **traced** int32 operands and a whole
(M, 3) spec table is evaluated under one ``vmap`` with a single compile.

The trick (DESIGN.md §2): a race's random structure — who arrives where,
when, and therefore who votes for what — does not depend on the thresholds
at all.  ``_sample_race`` draws and *pre-sorts* everything once:

  sorted per-value 2b arrivals   (S, K, n)   fast-path order statistics
  sorted all-votes 2b arrivals   (S, n)      recovery detection (q1)
  sorted classic round trips     (S, n)      recovery commit (q2c)
  per-value vote counts          (S, K)      via the quorum_tally kernel

``_decide`` then reduces a spec to three gathers and a compare against the
presorted arrays, which is what ``vmap`` maps over the spec table.  Work is
O(sample + sort) once, plus O(M * S) gathers — instead of M full re-runs —
and every spec sees identical sampled delays (common random numbers), so
cross-spec comparisons are variance-free.

All simulated clocks are milliseconds from proposer 0's submission (the
paper's instance latency).  Messages with delay >= ``latency.LOST_MS`` never
arrive: acceptors that see no proposal cast no vote, and instances that
cannot gather q1 votes report ``undecided``.
"""
from __future__ import annotations

import functools
from typing import Dict, Sequence

import jax
import jax.numpy as jnp

from repro.core.quorum import QuorumSpec

from . import latency as lat_mod
from .latency import LOST_MS, default_delay

BIG = jnp.float32(LOST_MS)
# latencies at or beyond this are "never happened" (lost-message sentinel
# arithmetic); shared with scenarios.py so both layers classify identically
UNDECIDED_MS = LOST_MS / 2

# Incremented at trace time inside each jitted entry point; benchmarks assert
# a full spec-table sweep costs exactly one trace (no per-spec re-jit).
TRACE_COUNTS: Dict[str, int] = {"race": 0, "fast_path": 0, "classic_path": 0}


def build_spec_table(specs: Sequence[QuorumSpec]) -> jax.Array:
    """(M, 3) int32 [q1, q2c, q2f] rows; all specs must share one n."""
    ns = {s.n for s in specs}
    if len(ns) != 1:
        raise ValueError(f"spec table mixes cluster sizes {sorted(ns)}")
    return jnp.array([[s.q1, s.q2c, s.q2f] for s in specs], jnp.int32)


def _check_table(spec_table: jax.Array) -> None:
    # out-of-bounds gathers clamp silently in XLA, so a malformed table
    # would otherwise produce wrong numbers instead of an error
    if spec_table.ndim != 2 or spec_table.shape[-1] != 3:
        raise ValueError(
            f"spec_table must be (M, 3) [q1, q2c, q2f] rows, "
            f"got shape {spec_table.shape}")


def _kth(sorted_x: jax.Array, k: jax.Array) -> jax.Array:
    """k-th order statistic (1-indexed, traced k) from a presorted last axis."""
    idx = jnp.clip(k - 1, 0, sorted_x.shape[-1] - 1).astype(jnp.int32)
    idx = jnp.broadcast_to(idx, sorted_x.shape[:-1])[..., None]
    return jnp.take_along_axis(sorted_x, idx, axis=-1)[..., 0]


def _counts_winner(votes: jax.Array, k_proposers: int, use_kernel: bool):
    """(S, n) votes -> ((S, K) counts, (S,) winner, (S,) max count).

    The fused Pallas tally+decide kernel does the whole n-axis reduction in
    one VMEM pass; the threshold it is handed here is a placeholder (0) since
    per-spec thresholds are applied by ``_decide`` — only the spec-independent
    outputs are consumed.
    """
    if use_kernel:
        from repro.kernels.quorum_tally import ops as qt_ops
        counts, winner, max_cnt, _ = qt_ops.tally_decide(votes, k_proposers,
                                                         jnp.int32(0))
    else:
        from repro.kernels.quorum_tally import ref as qt_ref
        counts, winner, max_cnt, _ = qt_ref.tally_decide(votes, k_proposers,
                                                         jnp.int32(0))
    return counts, winner, max_cnt


def _sample_race(key: jax.Array, offsets: jax.Array, delay, *, n: int,
                 k_proposers: int, samples: int, use_kernel: bool) -> Dict:
    """Draw one race per sample and presort everything spec-independent."""
    K = k_proposers
    kp, kl, k2a, k2b = jax.random.split(key, 4)

    d_prop = delay.sample_hops(kp, (samples, n, K), lat_mod.PROPOSAL)
    arrival = jnp.broadcast_to(offsets, (K,)).astype(d_prop.dtype) + d_prop

    # each acceptor votes for the first proposal to arrive; no arrival at all
    # (all K lost) means no vote (-1, ignored by the tally).
    votes = jnp.argmin(arrival, axis=-1).astype(jnp.int32)        # (S, n)
    vote_time = jnp.min(arrival, axis=-1)                         # (S, n)
    voted = vote_time < UNDECIDED_MS
    votes = jnp.where(voted, votes, -1)

    d_ret = delay.sample_hops(kl, (samples, n), lat_mod.TO_LEARNER)
    arrive = jnp.where(voted, vote_time + d_ret, BIG)             # 2b @ learner
    arrive = jnp.where(arrive < UNDECIDED_MS, arrive, BIG)

    counts, winner, max_cnt = _counts_winner(votes, K, use_kernel)

    # per-value 2b arrival times, non-voters masked out, presorted over n.
    val_arr = jnp.where(votes[:, None, :] == jnp.arange(K)[None, :, None],
                        arrive[:, None, :], BIG)                  # (S, K, n)

    # coordinated recovery: one classic round trip after q1 votes are seen.
    d_2a = delay.sample_hops(k2a, (samples, n), lat_mod.FROM_COORDINATOR)
    d_2b = delay.sample_hops(k2b, (samples, n), lat_mod.TO_COORDINATOR)
    classic = d_2a + d_2b
    classic = jnp.where(classic < UNDECIDED_MS, classic, BIG)

    return {
        "counts": counts,                                # (S, K) int32
        "winner": winner,                                # (S,) int32
        "max_cnt": max_cnt,                              # (S,) int32
        "sorted_val_arrive": jnp.sort(val_arr, axis=-1),  # (S, K, n)
        "sorted_arrive": jnp.sort(arrive, axis=-1),       # (S, n)
        "sorted_classic": jnp.sort(classic, axis=-1),     # (S, n)
    }


def _decide(draws: Dict, q1: jax.Array, q2c: jax.Array,
            q2f: jax.Array) -> Dict[str, jax.Array]:
    """Apply one (traced) threshold triple to presorted draws: gathers only."""
    winner = draws["winner"]
    win_sorted = jnp.take_along_axis(
        draws["sorted_val_arrive"], winner[:, None, None], axis=1)[:, 0, :]
    t_fast = _kth(win_sorted, q2f)                                # (S,)
    # a fast commit needs q2f acceptor *votes* AND the learner actually
    # receiving the q2f-th 2b (lost 2bs leave t_fast at the sentinel);
    # otherwise the coordinator falls back to recovery like any collision.
    fast_ok = (draws["max_cnt"] >= q2f) & (t_fast < UNDECIDED_MS)

    t_detect = _kth(draws["sorted_arrive"], q1)
    t_recover = t_detect + _kth(draws["sorted_classic"], q2c)

    latency = jnp.where(fast_ok, t_fast, t_recover)
    undecided = latency >= UNDECIDED_MS
    return {
        "fast_winner": jnp.where(fast_ok, winner, -1),
        "reached_fast": fast_ok,
        "recovery": ~fast_ok & ~undecided,
        "undecided": undecided,
        "latency_ms": latency,
    }


@functools.partial(jax.jit, static_argnames=("n", "k_proposers", "samples",
                                             "use_kernel"))
def race(key: jax.Array, spec_table: jax.Array, offsets: jax.Array,
         delay=None, *, n: int, k_proposers: int, samples: int,
         use_kernel: bool = False) -> Dict[str, jax.Array]:
    """K proposals race for one instance, scored under M quorum specs at once.

    key         PRNG key (delays are shared across specs — common random
                numbers, so spec-vs-spec deltas carry no sampling noise)
    spec_table  (M, 3) int32 [q1, q2c, q2f] rows (traced: new tables of the
                same shape reuse the compile)
    offsets     (K,) proposer submission times in ms (traced)
    delay       a ``repro.montecarlo.latency`` model (traced pytree)

    Returns per-spec-per-sample arrays, each (M, S):
      fast_winner   proposer id that won on the fast path, -1 otherwise
      reached_fast  some value gathered q2f round-1 votes
      recovery      coordinated recovery decided the instance
      undecided     not enough votes ever arrived (message loss)
      latency_ms    decision latency from proposer 0's submission
    """
    _check_table(spec_table)
    TRACE_COUNTS["race"] += 1
    if delay is None:
        delay = default_delay()
    draws = _sample_race(key, offsets, delay, n=n, k_proposers=k_proposers,
                         samples=samples, use_kernel=use_kernel)
    return jax.vmap(lambda q: _decide(draws, q[0], q[1], q[2]))(spec_table)


@functools.partial(jax.jit, static_argnames=("n", "samples"))
def fast_path(key: jax.Array, spec_table: jax.Array, delay=None, *,
              n: int, samples: int) -> jax.Array:
    """(M, S) conflict-free fast-path commit latencies (client -> acceptors
    -> learner, q2f-th order statistic), one compile for the whole table."""
    _check_table(spec_table)
    TRACE_COUNTS["fast_path"] += 1
    if delay is None:
        delay = default_delay()
    k1, k2 = jax.random.split(key)
    d1 = delay.sample_hops(k1, (samples, n, 1), lat_mod.PROPOSAL)[..., 0]
    d2 = delay.sample_hops(k2, (samples, n), lat_mod.TO_LEARNER)
    path = d1 + d2
    path = jnp.where(path < UNDECIDED_MS, path, BIG)   # lost => never arrives
    srt = jnp.sort(path, axis=-1)
    return jax.vmap(lambda q: _kth(srt, q[2]))(spec_table)


@functools.partial(jax.jit, static_argnames=("n", "samples"))
def classic_path(key: jax.Array, spec_table: jax.Array, delay=None, *,
                 n: int, samples: int) -> jax.Array:
    """(M, S) leader-relayed classic commit latencies (q2c-th order
    statistic after the client -> leader hop)."""
    _check_table(spec_table)
    TRACE_COUNTS["classic_path"] += 1
    if delay is None:
        delay = default_delay()
    k0, k1, k2 = jax.random.split(key, 3)
    d0 = delay.sample_hops(k0, (samples,), lat_mod.CLIENT_TO_LEADER)
    d1 = delay.sample_hops(k1, (samples, n), lat_mod.FROM_COORDINATOR)
    d2 = delay.sample_hops(k2, (samples, n), lat_mod.TO_COORDINATOR)
    path = d1 + d2
    path = jnp.where(path < UNDECIDED_MS, path, BIG)   # lost => never arrives
    srt = jnp.sort(path, axis=-1)
    return jax.vmap(lambda q: d0 + _kth(srt, q[1]))(spec_table)


def summarize(latency_ms: jax.Array,
              axis: int = -1) -> Dict[str, jax.Array]:
    """Latency quantiles over the sample axis; works on (S,) or (M, S)."""
    q = jnp.quantile(latency_ms, jnp.array([0.5, 0.95, 0.99]), axis=axis)
    return {
        "mean_ms": latency_ms.mean(axis=axis),
        "p50_ms": q[0],
        "p95_ms": q[1],
        "p99_ms": q[2],
    }
