"""K-proposer conflict-race engine over mask-encoded quorum systems.

The paper's §5 point is that Eqs. 13/14 admit a *space* of quorum systems;
evaluating that space is this module's job.  Every entry point — ``race``,
``fast_path``, ``classic_path`` — scores a whole batch of M systems in one
call with a single XLA compile, and every batch is expressed in **one
lowering**: the membership-mask table built by ``build_mask_table``
(DESIGN.md §2).  Cardinality specs, grids, weighted voting and hand-built
explicit systems all become per-phase (M, G, n) float32 weight matrices
plus (M, G) thresholds — all traced, so same-shape tables reuse a compile.

When *every* system in a table is cardinality-encodable (single all-ones
row per phase, integral threshold), ``build_mask_table`` additionally
stores the thresholds as a ``"q"`` (M, 3) int32 entry and the entry points
select an internal specialization: each masked saturation collapses to a
k-th-order-statistic gather against presorted arrivals.  The two paths are
bit-identical on cardinality systems (guarded by the parity tests in
``tests/test_quorum_systems.py``), so the specialization is purely a
lowering choice, invisible in the results.

The trick that makes one compile possible (DESIGN.md §2): a race's random
structure — who arrives where, when, and therefore who votes for what —
does not depend on the quorum system at all.  ``_sample_race`` draws and
*pre-sorts* everything once:

  sorted per-value 2b arrivals   (S, K, n)   fast-path saturation
  sorted all-votes 2b arrivals   (S, n)      recovery detection (phase 1)
  sorted classic round trips     (S, n)      recovery commit (phase 2c)
  per-value vote counts          (S, K)      via the quorum_tally kernel

``_decide`` (cardinality specialization) and ``_decide_masked`` (general)
then reduce one system to gathers and compares over the presorted arrays,
which is what ``vmap`` maps over the table.  Work is O(sample + sort) once,
plus O(M * S) gathers — instead of M full re-runs — and every system sees
identical sampled delays (common random numbers), so cross-system
comparisons are variance-free.

All simulated clocks are milliseconds from proposer 0's submission (the
paper's instance latency).  Messages with delay >= ``latency.LOST_MS`` never
arrive: acceptors that see no proposal cast no vote, and instances that
cannot gather phase-1 votes report ``undecided``.

Every entry point materializes its per-trial arrays; for trial counts past
device memory use the chunked streaming drivers in
``repro.montecarlo.streaming`` (``race_stream`` / ``fast_path_stream`` /
``classic_path_stream``), which reduce each chunk into a fixed-size
``StreamSummary`` and shard the trial axis over devices.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.quorum import QuorumMasks, QuorumSpec

from . import latency as lat_mod
from .latency import LOST_MS, default_delay

BIG = jnp.float32(LOST_MS)
# latencies at or beyond this are "never happened" (lost-message sentinel
# arithmetic); shared with scenarios.py so both layers classify identically
UNDECIDED_MS = LOST_MS / 2

# Incremented at trace time inside each jitted entry point; benchmarks assert
# a full table sweep costs exactly one trace (no per-system re-jit).  The
# ``*_stream`` keys belong to the chunked drivers in ``streaming.py`` (one
# trace per (table shape, chunking) — the scan reuses it for any trials).
# The ``*_stream_sortfree`` keys count traces of the sort-free streamed
# specializations (top-k prefixes + shared-column reduction, DESIGN.md §9)
# and ``race_stream_fused`` traces of the raw-arrivals megakernel path; each
# increments alongside its base ``*_stream`` key, so "sweep == one compile"
# assertions can pin the exact lowering that ran.
TRACE_COUNTS: Dict[str, int] = {"race": 0, "fast_path": 0, "classic_path": 0,
                                "race_stream": 0, "fast_path_stream": 0,
                                "classic_path_stream": 0,
                                "race_stream_sortfree": 0,
                                "fast_path_stream_sortfree": 0,
                                "classic_path_stream_sortfree": 0,
                                "race_stream_fused": 0,
                                "race_stream_regimes": 0,
                                "fast_path_stream_regimes": 0,
                                "classic_path_stream_regimes": 0}


# ---------------------------------------------------------------------------
# Mask tables: the single quorum lowering (DESIGN.md §2).
# ---------------------------------------------------------------------------

MASK_KEYS = ("p1_w", "p1_t", "p2c_w", "p2c_t", "p2f_w", "p2f_t")


def _sys_label(system, masks: QuorumMasks) -> str:
    label = getattr(masks, "label", "") or getattr(system, "label", "")
    return label or type(system).__name__


def build_mask_table(systems: Sequence, *,
                     specialize: bool = True) -> Dict[str, jax.Array]:
    """Batch M quorum systems into one traced mask table (DESIGN.md §2).

    ``systems`` may mix ``QuorumSpec`` / ``ExplicitQuorumSystem`` /
    ``WeightedQuorumSystem`` (anything with ``to_masks()``) and raw
    ``QuorumMasks``; all must share one n.  Each phase is padded to the
    max row count with never-satisfied rows, giving a dict pytree of
    ``*_w (M, G, n)`` weight and ``*_t (M, G)`` threshold float32 arrays.
    Tables of the same shape are interchangeable without recompiling.

    When every system is cardinality-encodable (one all-ones row per phase,
    integral threshold) the table also carries ``"q"`` — the (M, 3) int32
    thresholds — and the engine entry points lower to the k-th-order-
    statistic specialization, bit-identical to the general masked path.
    ``specialize=False`` suppresses that (the parity tests use it to pit
    the two lowerings against each other)."""
    if not len(systems):
        raise ValueError("mask table needs at least one quorum system")
    masks = [s if isinstance(s, QuorumMasks) else s.to_masks()
             for s in systems]
    n = masks[0].n
    for i, m in enumerate(masks):
        if m.n != n:
            raise ValueError(
                f"mask table mixes cluster sizes: system {i} "
                f"({_sys_label(systems[i], m)}) has n={m.n} but system 0 "
                f"({_sys_label(systems[0], masks[0])}) has n={n}; "
                f"use QuorumMasks.embed() or rebuild the systems on one n")
    g1 = max(m.groups[0] for m in masks)
    g2c = max(m.groups[1] for m in masks)
    g2f = max(m.groups[2] for m in masks)
    padded = [m.pad_groups(g1, g2c, g2f) for m in masks]
    table = {k: jnp.stack([jnp.asarray(getattr(m, k), jnp.float32)
                           for m in padded])
             for k in MASK_KEYS}
    if specialize:
        qs = [m.cardinality_q() for m in masks]
        if all(q is not None for q in qs):
            table["q"] = jnp.array(qs, jnp.int32)
    return table


def _check_mask_table(table, n: int) -> None:
    if not isinstance(table, dict):
        raise TypeError(
            f"expected a build_mask_table() dict, got {type(table).__name__}; "
            f"raw (M, 3) spec tables were removed — build the table with "
            f"build_mask_table([...QuorumSpec...]) or go through "
            f"repro.api.Experiment")
    missing = [k for k in MASK_KEYS if k not in table]
    if missing:
        raise ValueError(f"mask table missing entries {missing}; "
                         f"build with build_mask_table()")
    m_rows = table["p1_w"].shape[0] if table["p1_w"].ndim == 3 else -1
    for ph in ("p1", "p2c", "p2f"):
        w, t = table[ph + "_w"], table[ph + "_t"]
        if w.ndim != 3 or w.shape[-1] != n or t.shape != w.shape[:2]:
            raise ValueError(
                f"mask table phase {ph}: weights {w.shape} / thresholds "
                f"{t.shape} not (M, G, n={n}) / (M, G)")
    if "q" in table and table["q"].shape != (m_rows, 3):
        raise ValueError(
            f"mask table 'q' specialization has shape {table['q'].shape}, "
            f"expected ({m_rows}, 3)")


def saturation_depths(table: Dict[str, jax.Array]) -> Tuple[int, int, int]:
    """Max prefix depths ``(k1, k2c, k2f)`` at which any quorum of the table
    can saturate — the ``k_max`` of the sort-free lowering (DESIGN.md §9).

    For a masked row with weights ``w`` and threshold ``t`` the adversarial
    arrival order is ascending-by-weight, so the deepest position at which
    the row can first saturate (over *every* possible arrival permutation)
    is ``#{prefix sums of sorted(w) < t} + 1``.  Rows that cannot saturate
    at all (total weight < t, e.g. group padding) are excluded: on any
    prefix of that depth they still report "not reached", exactly as on the
    full sort.  Cardinality tables reduce to the column maxima of ``q``.

    Host-side and concrete (a table is concrete at stream entry); the
    result is a static compile key for the prefix shapes.
    """
    import numpy as np
    n = int(table["p1_w"].shape[-1])

    def depth(w, t):
        w = np.asarray(w, np.float64)
        t = np.asarray(t, np.float64)
        cs = np.cumsum(np.sort(w, axis=-1), axis=-1)
        saturable = cs[..., -1] >= t
        k_row = (cs < t[..., None]).sum(axis=-1) + 1
        k_row = np.where(saturable, k_row, 0)
        return int(k_row.max()) if k_row.size else 0

    if "q" in table:
        q = np.asarray(table["q"])
        ks = (int(q[:, 0].max()), int(q[:, 1].max()), int(q[:, 2].max()))
    else:
        ks = (depth(table["p1_w"], table["p1_t"]),
              depth(table["p2c_w"], table["p2c_t"]),
              depth(table["p2f_w"], table["p2f_t"]))
    return tuple(min(n, max(1, k)) for k in ks)


def _topk_ascending(x: jax.Array, k: Optional[int]):
    """Smallest-k ascending prefix of a stable sort over the last axis, plus
    the matching permutation prefix.  ``k`` of None (or >= n) falls back to
    the full argsort — that is the retained reference path, and keeps the
    prefix path bit-identical to it by construction at k == n.

    ``lax.top_k`` breaks ties toward the lower index, the same order as a
    stable ascending argsort, so prefix values AND permutation entries match
    the full sort element-for-element (including tied LOST sentinels)."""
    n = x.shape[-1]
    if k is None or k >= n:
        perm = jnp.argsort(x, axis=-1).astype(jnp.int32)
        return jnp.take_along_axis(x, perm, axis=-1), perm
    neg, idx = jax.lax.top_k(-x, k)
    return -neg, idx.astype(jnp.int32)


def _sorted_prefix(x: jax.Array, k: Optional[int]) -> jax.Array:
    """Values-only ``_topk_ascending`` (lets XLA skip the permutation when a
    lowering consumes only order statistics)."""
    if k is None or k >= x.shape[-1]:
        return jnp.sort(x, axis=-1)
    return -jax.lax.top_k(-x, k)[0]


def _kth(sorted_x: jax.Array, k: jax.Array) -> jax.Array:
    """k-th order statistic (1-indexed, traced k) from a presorted last axis."""
    idx = jnp.clip(k - 1, 0, sorted_x.shape[-1] - 1).astype(jnp.int32)
    idx = jnp.broadcast_to(idx, sorted_x.shape[:-1])[..., None]
    return jnp.take_along_axis(sorted_x, idx, axis=-1)[..., 0]


def _counts_winner(votes: jax.Array, k_proposers: int, use_kernel: bool):
    """(S, n) votes -> ((S, K) counts, (S,) winner, (S,) max count).

    The fused Pallas tally+decide kernel does the whole n-axis reduction in
    one VMEM pass; the threshold it is handed here is a placeholder (0) since
    per-system thresholds are applied by ``_decide`` — only the
    system-independent outputs are consumed.
    """
    if use_kernel:
        from repro.kernels.quorum_tally import ops as qt_ops
        counts, winner, max_cnt, _ = qt_ops.tally_decide(votes, k_proposers,
                                                         jnp.int32(0))
    else:
        from repro.kernels.quorum_tally import ref as qt_ref
        counts, winner, max_cnt, _ = qt_ref.tally_decide(votes, k_proposers,
                                                         jnp.int32(0))
    return counts, winner, max_cnt


# Collision-recovery rules (arXiv 1710.08047): ``coordinated`` is the
# paper's §6 deployment — the coordinator detects the collision from the
# round-1 2bs (phase-1 quorum q1) and commits classically with a q2c quorum
# of round trips.  ``uncoordinated`` lets the acceptors themselves detect
# (same q1-th observation) and vote directly in the next *fast* round, so
# the learner needs a q2f quorum of one-way round-2 votes — no coordinator
# round trip.  The entry condition (fast path failed) is identical, so
# P(recovery) matches across rules; only the recovery *latency* model
# changes: threshold column q2c -> q2f and classic leg d_2a+d_2b -> d_2b.
RECOVERY_MODES = ("coordinated", "uncoordinated")


def _check_recovery(recovery: str) -> None:
    if recovery not in RECOVERY_MODES:
        raise ValueError(f"unknown recovery rule {recovery!r}; "
                         f"pick one of {RECOVERY_MODES}")


def _draw_race(key: jax.Array, offsets: jax.Array, delay, *, n: int,
               k_proposers: int, samples: int,
               recovery: str = "coordinated") -> Dict:
    """Raw race draws: RNG + vote structure only, nothing sorted.

    The presorting lowerings (``_sample_race``) and the raw-arrivals
    megakernel (``kernels/quorum_tally.stream_tally_decide_hist``) both
    start from exactly these arrays, so the two streamed paths consume
    identical sampled delays by construction."""
    K = k_proposers
    kp, kl, k2a, k2b = jax.random.split(key, 4)

    d_prop = delay.sample_hops(kp, (samples, n, K), lat_mod.PROPOSAL)
    arrival = jnp.broadcast_to(offsets, (K,)).astype(d_prop.dtype) + d_prop

    # each acceptor votes for the first proposal to arrive; no arrival at all
    # (all K lost) means no vote (-1, ignored by the tally).
    votes = jnp.argmin(arrival, axis=-1).astype(jnp.int32)        # (S, n)
    vote_time = jnp.min(arrival, axis=-1)                         # (S, n)
    voted = vote_time < UNDECIDED_MS
    votes = jnp.where(voted, votes, -1)

    d_ret = delay.sample_hops(kl, (samples, n), lat_mod.TO_LEARNER)
    arrive = jnp.where(voted, vote_time + d_ret, BIG)             # 2b @ learner
    arrive = jnp.where(arrive < UNDECIDED_MS, arrive, BIG)

    # per-value 2b arrival times, non-voters masked out.
    val_arr = jnp.where(votes[:, None, :] == jnp.arange(K)[None, :, None],
                        arrive[:, None, :], BIG)                  # (S, K, n)

    # recovery commit leg after detection.  Coordinated: one classic round
    # trip (2a out + 2b back).  Uncoordinated: the detecting acceptors vote
    # directly in the next fast round, so only the one-way 2b leg to the
    # learner remains.  Both legs are always drawn (same 4-way key split),
    # so the coordinated draws are bit-identical across modes.
    d_2a = delay.sample_hops(k2a, (samples, n), lat_mod.FROM_COORDINATOR)
    d_2b = delay.sample_hops(k2b, (samples, n), lat_mod.TO_COORDINATOR)
    classic = d_2b if recovery == "uncoordinated" else d_2a + d_2b
    classic = jnp.where(classic < UNDECIDED_MS, classic, BIG)

    return {"votes": votes, "arrive": arrive, "val_arr": val_arr,
            "classic": classic}


def _sample_race(key: jax.Array, offsets: jax.Array, delay, *, n: int,
                 k_proposers: int, samples: int, use_kernel: bool,
                 k_sat: Optional[Tuple[int, int, int]] = None,
                 need_perms: bool = True,
                 recovery: str = "coordinated") -> Dict:
    """Draw one race per sample and presort everything system-independent.

    ``k_sat = (k1, k2c, k2f)`` (static, from ``saturation_depths``) switches
    the three presorts to ``lax.top_k`` prefixes of those depths — every
    downstream gather / saturation only ever reads within the prefix, so
    results are bit-identical to the full sort (``None``, the reference
    path).  ``need_perms=False`` drops the permutations for lowerings that
    consume order statistics only (the cardinality specialization).

    Under ``recovery="uncoordinated"`` the classic leg holds one-way 2b
    hops and its commit threshold is q2f, so the classic presort deepens to
    the k2f prefix (the recovery saturation reads up to position q2f)."""
    raw = _draw_race(key, offsets, delay, n=n, k_proposers=k_proposers,
                     samples=samples, recovery=recovery)
    counts, winner, max_cnt = _counts_winner(raw["votes"], k_proposers,
                                             use_kernel)
    k1, k2c, k2f = k_sat if k_sat is not None else (None, None, None)
    if recovery == "uncoordinated":
        k2c = k2f
    out = {
        "counts": counts,                                # (S, K) int32
        "winner": winner,                                # (S,) int32
        "max_cnt": max_cnt,                              # (S,) int32
        "votes": raw["votes"],                           # (S, n) int32
    }
    if need_perms:
        # presort with explicit permutations: the cardinality specialization
        # consumes only the sorted values, but the masked decide re-weights
        # acceptors in arrival order, so argsort indices ride along (XLA
        # dead-code-eliminates whichever outputs a lowering leaves unused).
        sv, pv = _topk_ascending(raw["val_arr"], k2f)
        sa, pa = _topk_ascending(raw["arrive"], k1)
        sc, pc = _topk_ascending(raw["classic"], k2c)
        out.update(perm_val_arrive=pv, perm_arrive=pa, perm_classic=pc)
    else:
        sv = _sorted_prefix(raw["val_arr"], k2f)
        sa = _sorted_prefix(raw["arrive"], k1)
        sc = _sorted_prefix(raw["classic"], k2c)
    out.update(sorted_val_arrive=sv,      # (S, K, k2f)
               sorted_arrive=sa,          # (S, k1)
               sorted_classic=sc)         # (S, k2c)
    return out


# ---------------------------------------------------------------------------
# Cardinality specialization: k-th-order-statistic gathers.
# ---------------------------------------------------------------------------

def _win_sorted(draws: Dict) -> jax.Array:
    """(S, n) presorted 2b arrivals of each sample's winning value.  In the
    cardinality path the winner (max vote count) is system-independent, so
    this gather is computed once and shared across the whole spec table."""
    return jnp.take_along_axis(
        draws["sorted_val_arrive"], draws["winner"][:, None, None],
        axis=1)[:, 0, :]


def _decide(draws: Dict, win_sorted: jax.Array, q1: jax.Array, q_rec: jax.Array,
            q2f: jax.Array) -> Dict[str, jax.Array]:
    """Apply one (traced) threshold triple to presorted draws: gathers only.

    ``q_rec`` is the recovery-commit threshold — q2c under coordinated
    recovery (classic round trips), q2f under uncoordinated (one-way round-2
    votes); the caller picks the column to match the classic-leg draws."""
    winner = draws["winner"]
    t_fast = _kth(win_sorted, q2f)                                # (S,)
    # a fast commit needs q2f acceptor *votes* AND the learner actually
    # receiving the q2f-th 2b (lost 2bs leave t_fast at the sentinel);
    # otherwise the coordinator falls back to recovery like any collision.
    fast_ok = (draws["max_cnt"] >= q2f) & (t_fast < UNDECIDED_MS)

    t_detect = _kth(draws["sorted_arrive"], q1)
    t_recover = t_detect + _kth(draws["sorted_classic"], q_rec)

    latency = jnp.where(fast_ok, t_fast, t_recover)
    undecided = latency >= UNDECIDED_MS
    return {
        "fast_winner": jnp.where(fast_ok, winner, -1),
        "reached_fast": fast_ok,
        "recovery": ~fast_ok & ~undecided,
        "undecided": undecided,
        "latency_ms": latency,
    }


# ---------------------------------------------------------------------------
# General path: arbitrary quorum systems as masked saturations (DESIGN.md §2).
# ---------------------------------------------------------------------------

def _sat_time(sorted_x: jax.Array, perm: jax.Array, w: jax.Array,
              t: jax.Array) -> jax.Array:
    """Earliest instant some quorum row's masked arrival indicator saturates.

    ``sorted_x (..., n)`` ascending arrival times, ``perm (..., n)`` the
    argsort indices (sorted position -> acceptor id), ``w (G, n)`` weights,
    ``t (G,)`` thresholds.  Row g saturates at the first sorted position
    whose cumulative (arrival-ordered) weight reaches t[g]; its time is the
    value there — the LOST sentinel when the saturating arrival never
    happened, which downstream classifies as "not reached", exactly like the
    cardinality path's k-th order statistic.  Returns the min over rows.

    On an all-ones row with threshold q this is bit-identical to
    ``_kth(sorted_x, q)``: cumulative weight i+1 first reaches q at sorted
    position q-1.
    """
    G = w.shape[0]
    w_perm = jnp.take(w, perm, axis=1)                     # (G, ..., n)
    csum = jnp.cumsum(w_perm, axis=-1)
    ok = csum >= t.reshape((G,) + (1,) * perm.ndim)        # monotone in n
    idx = jnp.argmax(ok, axis=-1).astype(jnp.int32)        # first saturation
    reached = ok[..., -1]
    x = jnp.broadcast_to(sorted_x, csum.shape)
    tt = jnp.take_along_axis(x, idx[..., None], axis=-1)[..., 0]
    tt = jnp.where(reached, tt, BIG)
    return tt.min(axis=0)


def _masked_vote_winner(votes: jax.Array, mask_table: Dict[str, jax.Array],
                        k_proposers: int, use_kernel: bool):
    """Per-sample-per-system fast-quorum vote check: which value (if any)
    gathered a full masked phase-2f quorum of round-1 *votes*.

    All G fast rows of all M systems go through the masked-tally kernel (or
    its jnp oracle) in one flattened pass.  Returns ``winner (S, M) int32``
    (-1 when no value saturates any row) and ``reached (S, M) bool``.
    """
    M, Gf, n = mask_table["p2f_w"].shape
    w_flat = mask_table["p2f_w"].reshape(M * Gf, n)
    t_flat = mask_table["p2f_t"].reshape(M * Gf)
    if use_kernel:
        from repro.kernels.quorum_tally import ops as qt_ops
        per_q = qt_ops.masked_tally(votes, w_flat, t_flat, k_proposers)
    else:
        from repro.kernels.quorum_tally import ref as qt_ref
        per_q = qt_ref.masked_tally(votes, w_flat, t_flat, k_proposers)
    per_q = per_q.reshape(votes.shape[0], M, Gf)           # (S, M, G)
    nohit = jnp.int32(k_proposers)                         # > any value id
    best = jnp.where(per_q < 0, nohit, per_q).min(axis=-1)  # (S, M)
    reached = best < nohit
    winner = jnp.where(reached, best, -1).astype(jnp.int32)
    return winner, reached


def _decide_masked(draws: Dict, masks: Dict[str, jax.Array],
                   winner: jax.Array, reached_votes: jax.Array,
                   rec_phase: str = "p2c") -> Dict[str, jax.Array]:
    """Apply one system's (traced) quorum masks to the presorted draws.

    Mirrors ``_decide`` exactly, with each k-th-order-statistic gather
    replaced by a masked saturation over the system's quorum rows; on
    cardinality-encoded masks the two paths are bit-identical.  ``rec_phase``
    (static) names the recovery-commit quorum phase — "p2c" (coordinated) or
    "p2f" (uncoordinated), matching the classic-leg draws.
    """
    widx = jnp.clip(winner, 0, draws["sorted_val_arrive"].shape[1] - 1)
    win_sorted = jnp.take_along_axis(
        draws["sorted_val_arrive"], widx[:, None, None], axis=1)[:, 0, :]
    win_perm = jnp.take_along_axis(
        draws["perm_val_arrive"], widx[:, None, None], axis=1)[:, 0, :]
    t_fast = _sat_time(win_sorted, win_perm, masks["p2f_w"], masks["p2f_t"])
    # a fast commit needs a full masked quorum of *votes* AND the learner
    # actually receiving every 2b that saturates it (lost 2bs leave t_fast
    # at the sentinel) — the same conjunction as the cardinality path.
    fast_ok = reached_votes & (t_fast < UNDECIDED_MS)

    t_detect = _sat_time(draws["sorted_arrive"], draws["perm_arrive"],
                         masks["p1_w"], masks["p1_t"])
    t_recover = t_detect + _sat_time(draws["sorted_classic"],
                                     draws["perm_classic"],
                                     masks[rec_phase + "_w"],
                                     masks[rec_phase + "_t"])

    latency = jnp.where(fast_ok, t_fast, t_recover)
    undecided = latency >= UNDECIDED_MS
    return {
        "fast_winner": jnp.where(fast_ok, winner, -1),
        "reached_fast": fast_ok,
        "recovery": ~fast_ok & ~undecided,
        "undecided": undecided,
        "latency_ms": latency,
    }


# ---------------------------------------------------------------------------
# Entry points: one per path, each dispatching on the table's lowering.
# The un-jitted ``*_outcomes`` forms are the shared bodies: the jitted
# whole-batch entry points call them once, and the streaming drivers
# (``streaming.py``) call them once per chunk inside a ``lax.scan``.
# ---------------------------------------------------------------------------

def _race_outcomes(key: jax.Array, table: Dict[str, jax.Array],
                   offsets: jax.Array, delay, *, n: int, k_proposers: int,
                   samples: int, use_kernel: bool,
                   k_sat: Optional[Tuple[int, int, int]] = None,
                   recovery: str = "coordinated") -> Dict[str, jax.Array]:
    """One full race evaluation: sample + presort once, decide per system.
    ``k_sat`` (static) presorts top-k prefixes instead of full sorts —
    bit-identical when it upper-bounds the table's saturation depths
    (``saturation_depths``); ``None`` keeps the full-sort reference path."""
    if delay is None:
        delay = default_delay()
    draws = _sample_race(key, offsets, delay, n=n, k_proposers=k_proposers,
                         samples=samples, use_kernel=use_kernel, k_sat=k_sat,
                         need_perms="q" not in table, recovery=recovery)
    rec_col = 1 if recovery == "coordinated" else 2
    if "q" in table:            # cardinality specialization: gathers only
        win_sorted = _win_sorted(draws)
        return jax.vmap(lambda q: _decide(draws, win_sorted, q[0], q[rec_col],
                                          q[2]))(table["q"])
    winner, reached = _masked_vote_winner(draws["votes"], table,
                                          k_proposers, use_kernel)
    masks = {k: table[k] for k in MASK_KEYS}
    rec_phase = "p2c" if recovery == "coordinated" else "p2f"
    return jax.vmap(lambda m, w, r: _decide_masked(draws, m, w, r, rec_phase),
                    in_axes=(0, 1, 1))(masks, winner, reached)


@functools.partial(jax.jit, static_argnames=("n", "k_proposers", "samples",
                                             "use_kernel", "recovery"))
def _race(key: jax.Array, table: Dict[str, jax.Array], offsets: jax.Array,
          delay, *, n: int, k_proposers: int, samples: int,
          use_kernel: bool,
          recovery: str = "coordinated") -> Dict[str, jax.Array]:
    TRACE_COUNTS["race"] += 1
    return _race_outcomes(key, table, offsets, delay, n=n,
                          k_proposers=k_proposers, samples=samples,
                          use_kernel=use_kernel, recovery=recovery)


def race(key: jax.Array, table, offsets: jax.Array, delay=None, *, n: int,
         k_proposers: int, samples: int, use_kernel: bool = False,
         recovery: str = "coordinated") -> Dict[str, jax.Array]:
    """K proposals race for one instance, scored under M quorum systems at
    once.

    key      PRNG key (delays are shared across systems — common random
             numbers, so system-vs-system deltas carry no sampling noise)
    table    ``build_mask_table`` dict — per-phase (M, G, n) weights and
             (M, G) thresholds, all traced: same-shape tables reuse one
             compile.  All-cardinality tables carry a ``"q"`` entry and
             lower to k-th-order-statistic gathers (bit-identical).  A raw
             (M, 3) threshold array is still accepted but deprecated.
    offsets  (K,) proposer submission times in ms (traced)
    delay    a ``repro.montecarlo.latency`` model (traced pytree)
    recovery collision-recovery rule (static): "coordinated" (classic q2c
             round trip, the default) or "uncoordinated" (q2f one-way
             round-2 votes, arXiv 1710.08047).  The fast path and the
             recovery *entry* condition are identical across rules — only
             the recovery commit latency changes.

    Returns per-system-per-sample arrays, each (M, S):
      fast_winner   proposer id that won on the fast path, -1 otherwise
      reached_fast  some value gathered a full fast phase-2 quorum of votes
      recovery      collision recovery decided the instance
      undecided     not enough votes ever arrived (message loss)
      latency_ms    decision latency from proposer 0's submission
    """
    _check_mask_table(table, n)
    _check_recovery(recovery)
    return _race(key, table, offsets, delay, n=n, k_proposers=k_proposers,
                 samples=samples, use_kernel=use_kernel, recovery=recovery)


def _fast_path_draws(key: jax.Array, delay, n: int,
                     samples: int) -> jax.Array:
    """(S, n) conflict-free client -> acceptor -> learner path times, lost
    hops at the sentinel.  Shared by both ``fast_path`` lowerings so they
    draw identical delays by construction (the bit-identity contract rests
    on it)."""
    k1, k2 = jax.random.split(key)
    d1 = delay.sample_hops(k1, (samples, n, 1), lat_mod.PROPOSAL)[..., 0]
    d2 = delay.sample_hops(k2, (samples, n), lat_mod.TO_LEARNER)
    path = d1 + d2
    return jnp.where(path < UNDECIDED_MS, path, BIG)   # lost => never arrives


def _fast_path_outcomes(key: jax.Array, table: Dict[str, jax.Array], delay,
                        *, n: int, samples: int,
                        k_sat: Optional[Tuple[int, int, int]] = None
                        ) -> jax.Array:
    if delay is None:
        delay = default_delay()
    k2f = k_sat[2] if k_sat is not None else None
    path = _fast_path_draws(key, delay, n, samples)
    if "q" in table:
        srt = _sorted_prefix(path, k2f)
        return jax.vmap(lambda q: _kth(srt, q[2]))(table["q"])
    srt, perm = _topk_ascending(path, k2f)
    return jax.vmap(lambda m: _sat_time(srt, perm, m["p2f_w"], m["p2f_t"]))(
        {k: table[k] for k in MASK_KEYS})


@functools.partial(jax.jit, static_argnames=("n", "samples"))
def _fast_path(key: jax.Array, table: Dict[str, jax.Array], delay, *,
               n: int, samples: int) -> jax.Array:
    TRACE_COUNTS["fast_path"] += 1
    return _fast_path_outcomes(key, table, delay, n=n, samples=samples)


def fast_path(key: jax.Array, table, delay=None, *, n: int,
              samples: int) -> jax.Array:
    """(M, S) conflict-free fast-path commit latencies: the saturation
    instant of each system's phase-2f quorums over the client -> acceptor
    -> learner paths (the q2f-th order statistic on cardinality tables);
    one compile for the whole table."""
    _check_mask_table(table, n)
    return _fast_path(key, table, delay, n=n, samples=samples)


def _classic_path_draws(key: jax.Array, delay, n: int, samples: int):
    """((S,) client->leader hop, (S, n) leader round-trip times); shared by
    the materializing and streamed classic-path lowerings."""
    k0, k1, k2 = jax.random.split(key, 3)
    d0 = delay.sample_hops(k0, (samples,), lat_mod.CLIENT_TO_LEADER)
    d1 = delay.sample_hops(k1, (samples, n), lat_mod.FROM_COORDINATOR)
    d2 = delay.sample_hops(k2, (samples, n), lat_mod.TO_COORDINATOR)
    path = d1 + d2
    return d0, jnp.where(path < UNDECIDED_MS, path, BIG)  # lost => never


def _classic_path_outcomes(key: jax.Array, table: Dict[str, jax.Array],
                           delay, *, n: int, samples: int,
                           k_sat: Optional[Tuple[int, int, int]] = None
                           ) -> jax.Array:
    if delay is None:
        delay = default_delay()
    k2c = k_sat[1] if k_sat is not None else None
    d0, path = _classic_path_draws(key, delay, n, samples)
    if "q" in table:
        srt = _sorted_prefix(path, k2c)
        return jax.vmap(lambda q: d0 + _kth(srt, q[1]))(table["q"])
    srt, perm = _topk_ascending(path, k2c)
    return jax.vmap(lambda m: d0 + _sat_time(srt, perm, m["p2c_w"],
                                             m["p2c_t"]))(
        {k: table[k] for k in MASK_KEYS})


@functools.partial(jax.jit, static_argnames=("n", "samples"))
def _classic_path(key: jax.Array, table: Dict[str, jax.Array], delay, *,
                  n: int, samples: int) -> jax.Array:
    TRACE_COUNTS["classic_path"] += 1
    return _classic_path_outcomes(key, table, delay, n=n, samples=samples)


def classic_path(key: jax.Array, table, delay=None, *, n: int,
                 samples: int) -> jax.Array:
    """(M, S) leader-relayed classic commit latencies (phase-2c quorum
    saturation after the client -> leader hop)."""
    _check_mask_table(table, n)
    return _classic_path(key, table, delay, n=n, samples=samples)


# ---------------------------------------------------------------------------
# Summaries.
# ---------------------------------------------------------------------------

def summarize(out, axis: int = -1) -> Dict[str, jax.Array]:
    """Latency quantiles over the sample axis; works on (S,) or (M, S).

    ``out`` may be a raw latency array or an outcome dict as returned by
    ``race`` / ``Scenario.run``.  For dicts, instances that never decided
    (message loss / crashes) are *excluded* from the latency statistics —
    they would otherwise drag the LOST_MS sentinel into every quantile —
    and reported separately as ``undecided_rate``, alongside
    ``fast_rate``/``recovery_rate`` decide-bit rates."""
    if isinstance(out, dict):
        lat = jnp.where(out["undecided"], jnp.nan, out["latency_ms"])
        extra = {
            "fast_rate": out["reached_fast"].mean(axis=axis),
            "recovery_rate": out["recovery"].mean(axis=axis),
            "undecided_rate": out["undecided"].mean(axis=axis),
        }
    else:
        lat, extra = out, {}
    q = jnp.nanquantile(lat, jnp.array([0.5, 0.95, 0.99, 0.999, 0.9999]),
                        axis=axis)
    return {
        "mean_ms": jnp.nanmean(lat, axis=axis),
        "p50_ms": q[0],
        "p95_ms": q[1],
        "p99_ms": q[2],
        "p999_ms": q[3],
        "p9999_ms": q[4],
        "max_ms": jnp.nanmax(lat, axis=axis),
        **extra,
    }
