"""Training loop: jit'd train_step factory (grad accumulation, compression,
remat) plus a host-level ``Trainer`` integrating the consensus control plane
(checkpoint manifests, straggler verdicts, elastic epochs).

``make_train_step`` is the function the multi-pod dry-run lowers — its
signature and sharding are identical on CPU smoke tests and the 512-chip
mesh.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import DecoderLM
from repro.parallel.sharding import constrain

from . import compress as compress_mod
from .optimizer import Optimizer, apply_updates, global_norm

Params = Any


def make_train_step(model: DecoderLM, opt: Optimizer,
                    n_microbatches: int = 1,
                    compression: Optional[str] = None,
                    param_axes=None,
                    ) -> Callable:
    """Returns train_step(params, opt_state, residual, batch, rng) ->
    (params, opt_state, residual, metrics).

    Microbatching: when ``n_microbatches > 1`` the batch must arrive with a
    leading microbatch dim — (n_micro, B/n_micro, ...) — shaped by the host
    data pipeline (reshaping a batch-sharded dim inside the program forces a
    resharding GSPMD handles poorly).  Microbatches are accumulated with
    lax.scan into f32 grad buffers sharded like the params.  Compression
    round-trips grads through int8/top-k with error feedback before the
    optimizer — emulating what crosses the pod-level DCN all-reduce.

    ``param_axes`` (the logical-axis pytree from model.init / abstract_params)
    makes each microbatch's grads get a sharding constraint MATCHING the FSDP
    param sharding before accumulation.  Without it GSPMD materializes the
    batch-partial grads with a ring all-reduce (2x bytes) and then discards
    15/16 of every buffer into the sharded accumulator; with it the partial
    sums go through a reduce-scatter at half the link bytes
    (EXPERIMENTS.md §Perf, deepseek_7b iteration 1).
    """

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def shard_like_params(g):
        if param_axes is None:
            return g
        from repro.parallel.sharding import constrain
        return jax.tree.map(lambda x, ax: constrain(x, ax), g, param_axes)

    def train_step(params, opt_state, residual, batch, rng):
        if n_microbatches == 1:
            loss, grads = grads_of(params, batch)
            grads = shard_like_params(grads)
        else:
            def micro(carry, mb):
                acc, = carry
                l, g = grads_of(params, mb)
                g = shard_like_params(g)
                acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32) / n_microbatches,
                    acc, g)
                return (acc,), l

            zero = shard_like_params(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads,), losses = jax.lax.scan(micro, (zero,), batch)
            loss = losses.mean()

        if compression == "int8":
            grads, residual = compress_mod.int8_compress(grads, residual, rng)
        elif compression == "topk":
            grads, residual = compress_mod.topk_compress(grads, residual)

        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": loss, "grad_norm": global_norm(grads),
                   "update_norm": global_norm(updates)}
        return params, opt_state, residual, metrics

    return train_step


def make_serve_step(model: DecoderLM) -> Callable:
    """serve_step(params, cache, tokens) -> (logits, cache) — the function
    lowered for decode_* / long_* shapes."""

    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return serve_step


def make_prefill(model: DecoderLM) -> Callable:
    def prefill(params, cache, batch):
        return model.prefill(params, batch, cache)
    return prefill


@dataclass
class TrainerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    n_microbatches: int = 1
    compression: Optional[str] = None
    log_every: int = 10


class Trainer:
    """Host-level loop: data cursor, checkpoints through the control plane,
    preemption-safe resume.  Used by examples/train_lm.py (which also
    simulates failures/stragglers around it)."""

    def __init__(self, model: DecoderLM, opt: Optimizer, pipeline,
                 tcfg: TrainerConfig, plane=None):
        self.model = model
        self.opt = opt
        self.pipe = pipeline
        self.tcfg = tcfg
        self.plane = plane
        self.step_fn = jax.jit(make_train_step(
            model, opt, tcfg.n_microbatches, tcfg.compression),
            donate_argnums=(0, 1, 2))
        self.params: Optional[Params] = None
        self.opt_state = None
        self.residual = None
        self.step = 0
        self.cursor = 0
        self.history: list = []

    def init(self, key) -> None:
        self.params, self.axes = self.model.init(key)
        self.opt_state = self.opt.init(self.params)
        self.residual = (compress_mod.init_residual(self.params)
                         if self.tcfg.compression else
                         jax.tree.map(lambda p: jnp.zeros((1,), jnp.float32),
                                      {"_": 0}))

    def try_restore(self) -> bool:
        from . import checkpoint as ckpt
        manifest = ckpt.latest_manifest(self.tcfg.ckpt_dir, self.plane)
        if manifest is None:
            return False
        state, step, cursor = ckpt.restore(
            {"params": self.params, "opt": self.opt_state}, manifest)
        self.params, self.opt_state = state["params"], state["opt"]
        self.step, self.cursor = step, cursor
        return True

    def save(self) -> None:
        from . import checkpoint as ckpt
        ckpt.save(self.tcfg.ckpt_dir, self.step,
                  {"params": self.params, "opt": self.opt_state},
                  self.cursor, self.plane)

    def run(self, n_steps: int, rng=None) -> Dict[str, float]:
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        last = {}
        nm = self.tcfg.n_microbatches
        for _ in range(n_steps):
            batch = self.pipe.batch_at(self.cursor)
            if nm > 1:
                batch = jax.tree.map(
                    lambda x: x.reshape((nm, x.shape[0] // nm) + x.shape[1:]),
                    batch)
            rng, sub = jax.random.split(rng)
            t0 = time.perf_counter()
            (self.params, self.opt_state, self.residual,
             metrics) = self.step_fn(self.params, self.opt_state,
                                     self.residual, batch, sub)
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["step_s"] = time.perf_counter() - t0
            self.step += 1
            self.cursor += 1
            self.history.append(metrics)
            last = metrics
            if self.tcfg.ckpt_every and self.step % self.tcfg.ckpt_every == 0:
                self.save()
        return last
