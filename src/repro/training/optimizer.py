"""Optimizers implemented from scratch (no optax): AdamW and Adafactor.

API mirrors the optax convention so the trainer can swap them:

    opt = adamw(lr=3e-4)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Optimizer state mirrors the parameter pytree, so the FSDP sharding specs of
the params apply verbatim to the moments (ZeRO-style sharded optimizer
state) — ``state_axes(param_axes)`` returns the matching logical-axes trees.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


class Optimizer(NamedTuple):
    init: Callable[[Params], Any]
    update: Callable[[Params, Any, Params], Tuple[Params, Any]]
    state_axes: Callable[[Any], Any]


def apply_updates(params: Params, updates: Params) -> Params:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads: Params, max_norm: float) -> Tuple[Params, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# AdamW.
# ---------------------------------------------------------------------------

class AdamWState(NamedTuple):
    step: jax.Array
    mu: Params
    nu: Params


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          max_grad_norm: Optional[float] = 1.0,
          schedule: Optional[Callable[[jax.Array], jax.Array]] = None
          ) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(zeros, params),
                          jax.tree.map(zeros, params))

    def update(grads, state, params):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        lr_t = lr if schedule is None else lr * schedule(step)
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                          state.nu, g32)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            u = -lr_t * ((m / bc1) / (jnp.sqrt(v / bc2) + eps)
                         + weight_decay * p.astype(jnp.float32))
            return u.astype(jnp.float32)

        updates = jax.tree.map(upd, params, mu, nu)
        return updates, AdamWState(step, mu, nu)

    def state_axes(param_axes):
        return AdamWState((), param_axes, param_axes)

    return Optimizer(init, update, state_axes)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; memory-lean for giant models).
# ---------------------------------------------------------------------------

class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Params      # row second-moment (or full moment for <2D leaves)
    vc: Params      # col second-moment (zeros-like placeholder for <2D)


def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor(lr: float = 1e-2, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        def vr0(p):
            return (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p)
                    else jnp.zeros(p.shape, jnp.float32))

        def vc0(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if _factored(p) else jnp.zeros((1,), jnp.float32))

        return AdafactorState(jnp.zeros((), jnp.int32),
                              jax.tree.map(vr0, params),
                              jax.tree.map(vc0, params))

    def update(grads, state, params):
        step = state.step + 1
        beta = 1.0 - (step.astype(jnp.float32)) ** (-decay)

        def upd(g, p, vr, vc):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(g):
                nvr = beta * vr + (1 - beta) * g2.mean(-1)
                nvc = beta * vc + (1 - beta) * g2.mean(-2)
                r = nvr / jnp.maximum(nvr.mean(-1, keepdims=True), eps)
                pre = (r[..., None] * nvc[..., None, :])
                u = g * jax.lax.rsqrt(jnp.maximum(pre, eps))
            else:
                nvr, nvc = beta * vr + (1 - beta) * g2, vc
                u = g * jax.lax.rsqrt(jnp.maximum(nvr, eps))
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            u = -lr * (u + weight_decay * p.astype(jnp.float32))
            return u, nvr, nvc

        out = jax.tree.map(upd, grads, params, state.vr, state.vc)
        updates = jax.tree.map(lambda t: t[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        vr = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        vc = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        return updates, AdafactorState(step, vr, vc)

    def state_axes(param_axes):
        def row_axes(ax):
            return ax[:-1] if isinstance(ax, tuple) and len(ax) >= 2 else ax

        def col_axes(ax):
            return (ax[:-2] + ax[-1:]
                    if isinstance(ax, tuple) and len(ax) >= 2 else (None,))

        is_ax = lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
        return AdafactorState(
            (),
            jax.tree.map(row_axes, param_axes, is_leaf=is_ax),
            jax.tree.map(col_axes, param_axes, is_leaf=is_ax))

    return Optimizer(init, update, state_axes)


def cosine_schedule(warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(warmup, 1), 1.0)
        frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return warm * cos
    return fn
