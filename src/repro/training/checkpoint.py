"""Sharded checkpointing with consensus-committed manifests.

Save path: every pytree leaf is written as its own ``.npy`` shard (the unit
a host would write in parallel on a real cluster), then the *manifest* —
step, shard listing + digest, and the data-pipeline cursor — is committed
through the Fast Flexible Paxos control plane.  A checkpoint exists iff its
manifest committed: a host that dies mid-write leaves garbage shards but no
manifest, so restore can never see a torn checkpoint (the paper's fast path
makes this commit one leaderless round trip to q2f acceptors).

Restore: read the control plane's latest manifest, verify the digest over
shard files, load leaves into the caller's pytree template.
"""
from __future__ import annotations

import hashlib
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.cluster.coordinator import ControlPlane

Params = Any


def _leaf_name(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return re.sub(r"[^A-Za-z0-9_.-]", "_", ".".join(out)) or "leaf"


def _flatten(tree: Params):
    return jax.tree_util.tree_flatten_with_path(tree)


def save(root: str, step: int, state: Params, data_cursor: int,
         plane: Optional[ControlPlane] = None, host: int = 0) -> str:
    """Write shards for ``state`` and commit the manifest.  Returns ckpt dir."""
    d = os.path.join(root, f"step-{step:08d}")
    os.makedirs(d, exist_ok=True)
    leaves, _ = _flatten(state)
    digest = hashlib.sha256()
    names = []
    for path, leaf in leaves:
        name = _leaf_name(path)
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(d, name + ".npy"), arr)
        digest.update(name.encode())
        digest.update(arr.tobytes()[:4096])    # sampled digest (fast)
        names.append(name)
    manifest_shards = {"dir": d, "n_shards": len(names),
                       "digest": digest.hexdigest()}
    if plane is not None:
        plane.commit_checkpoint(step, manifest_shards, data_cursor, host=host)
    else:  # stand-alone mode: manifest file is the commit point
        with open(os.path.join(d, "MANIFEST"), "w") as f:
            f.write(f"{step} {data_cursor} {len(names)} {digest.hexdigest()}")
    return d


def latest_manifest(root: str, plane: Optional[ControlPlane] = None
                    ) -> Optional[Dict]:
    if plane is not None:
        return plane.latest_checkpoint()
    best = None
    if not os.path.isdir(root):
        return None
    for name in sorted(os.listdir(root)):
        mf = os.path.join(root, name, "MANIFEST")
        if os.path.exists(mf):
            step, cursor, n, dg = open(mf).read().split()
            best = {"step": int(step), "data_cursor": int(cursor),
                    "shards": {"dir": os.path.join(root, name),
                               "n_shards": int(n), "digest": dg}}
    return best


def restore(template: Params, manifest: Dict) -> Tuple[Params, int, int]:
    """Load a checkpoint into ``template``'s structure.

    Returns (state, step, data_cursor).  Raises if shards are missing or the
    sampled digest mismatches (torn/corrupt checkpoint)."""
    d = manifest["shards"]["dir"]
    leaves, treedef = _flatten(template)
    digest = hashlib.sha256()
    out = []
    for path, leaf in leaves:
        name = _leaf_name(path)
        arr = np.load(os.path.join(d, name + ".npy"))
        digest.update(name.encode())
        digest.update(arr.tobytes()[:4096])
        out.append(jax.numpy.asarray(arr, dtype=leaf.dtype)
                   if hasattr(leaf, "dtype") else arr)
    if digest.hexdigest() != manifest["shards"]["digest"]:
        raise ValueError("checkpoint digest mismatch — torn or corrupt")
    state = jax.tree_util.tree_unflatten(treedef, out)
    return state, int(manifest["step"]), int(manifest["data_cursor"])
