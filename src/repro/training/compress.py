"""Gradient compression for the cross-pod (DCN) all-reduce.

Two standard schemes, both with error feedback so compression error
accumulates into the next step instead of being lost:

* ``int8_compress`` — per-tensor symmetric int8 with stochastic rounding
  (4x fewer DCN bytes than f32; unbiased in expectation).
* ``topk_compress`` — keep the largest k fraction of entries by magnitude
  (sparsity encodes as values+indices; ~2/k reduction).

The trainer applies compress->decompress around the gradient aggregation
point; on real multi-pod hardware the compressed representation is what
crosses the DCN link (the decompressed all-reduce is mathematically
equivalent under layer-wise scales).  Error-feedback residuals live in a
pytree mirroring the grads and are carried in the train state.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


def init_residual(grads_like: Params) -> Params:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


# ---------------------------------------------------------------------------
# int8 stochastic rounding.
# ---------------------------------------------------------------------------

def _int8_roundtrip(g: jax.Array, key: jax.Array) -> jax.Array:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = g / scale
    noise = jax.random.uniform(key, g.shape, jnp.float32, -0.5, 0.5)
    q8 = jnp.clip(jnp.round(q + noise), -127, 127).astype(jnp.int8)
    return q8.astype(jnp.float32) * scale


def int8_compress(grads: Params, residual: Params, key: jax.Array
                  ) -> Tuple[Params, Params]:
    """Returns (compressed-roundtripped grads, new residual)."""
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    keys_tree = jax.tree.unflatten(treedef, list(keys))

    def one(g, r, k):
        g32 = g.astype(jnp.float32) + r
        out = _int8_roundtrip(g32, k)
        return out, g32 - out

    pairs = jax.tree.map(one, grads, residual, keys_tree)
    comp = jax.tree.map(lambda t: t[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return comp, res


# ---------------------------------------------------------------------------
# top-k with error feedback.
# ---------------------------------------------------------------------------

def topk_compress(grads: Params, residual: Params, frac: float = 0.05
                  ) -> Tuple[Params, Params]:
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        flat = g32.reshape(-1)
        k = max(1, int(flat.shape[0] * frac))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        kept = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0).reshape(g32.shape)
        return kept, g32 - kept

    pairs = jax.tree.map(one, grads, residual)
    comp = jax.tree.map(lambda t: t[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return comp, res


def compressed_bytes(grads: Params, scheme: Optional[str], frac: float = 0.05) -> int:
    """DCN bytes per grad sync under a scheme (for the roofline's pod term)."""
    n = sum(int(jnp.size(g)) for g in jax.tree.leaves(grads))
    if scheme is None:
        return 4 * n
    if scheme == "int8":
        return n + 4 * len(jax.tree.leaves(grads))
    if scheme == "topk":
        return int(n * frac) * 8          # value + index
    raise ValueError(scheme)
