"""Deterministic synthetic data pipeline with exact-resume cursors.

Real deployments stream tokenized shards; offline we synthesize a structured
token stream (a stationary order-2 Markov-ish mixture — learnable, so loss
visibly decreases) deterministically from (seed, cursor).  The pipeline is
*stateless*: ``batch_at(cursor)`` is a pure function, so exact resume after
preemption needs only the cursor integer, which the checkpoint manifest
commits through the consensus control plane alongside the weights.

Per-host sharding: host h of H draws rows ``cursor*B + h::H`` of the global
batch — elastic rescaling (H changes at a membership epoch) re-partitions
rows without changing the global stream.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    structure: int = 97        # period of the synthetic structure


class SyntheticPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, cursor: int, host: int = 0, n_hosts: int = 1
                 ) -> Dict[str, jax.Array]:
        """Global batch at ``cursor`` (rows for this host's slice)."""
        c = self.cfg
        rows = np.arange(host, c.global_batch, n_hosts)
        key = jax.random.fold_in(jax.random.PRNGKey(c.seed), cursor)
        # one key per global row: hosts draw disjoint, reproducible slices
        row_keys = jax.random.split(key, c.global_batch)[rows]
        toks = jax.vmap(self._row)(row_keys)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def _row(self, key: jax.Array) -> jax.Array:
        c = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        base = jax.random.randint(k1, (c.seq_len + 1,), 0, c.vocab)
        # learnable structure: every other token repeats (shifted) context
        phase = jax.random.randint(k2, (), 0, c.structure)
        pos = jnp.arange(c.seq_len + 1)
        periodic = (pos + phase) % c.structure % c.vocab
        use_periodic = jax.random.bernoulli(k3, 0.7, (c.seq_len + 1,))
        return jnp.where(use_periodic, periodic, base).astype(jnp.int32)

    def frontend_batch_at(self, cursor: int, d_model: int,
                          frontend: str, vision_tokens: int = 0,
                          host: int = 0, n_hosts: int = 1) -> Dict[str, jax.Array]:
        """Batches for stub-frontend archs (audio frames / vision patches)."""
        c = self.cfg
        base = self.batch_at(cursor, host, n_hosts)
        key = jax.random.fold_in(jax.random.PRNGKey(c.seed + 1), cursor)
        B = base["tokens"].shape[0]
        if frontend == "audio_frames":
            emb = jax.random.normal(key, (B, c.seq_len, d_model), jnp.bfloat16)
            return {"frame_emb": emb,
                    "labels": base["labels"][:, :c.seq_len]}
        if frontend == "vision_patches":
            V = vision_tokens
            emb = jax.random.normal(key, (B, V, d_model), jnp.bfloat16)
            return {"patch_emb": emb,
                    "tokens": base["tokens"][:, :c.seq_len - V],
                    "labels": base["labels"][:, :c.seq_len - V]}
        raise ValueError(frontend)
