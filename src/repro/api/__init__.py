"""Declarative experiment layer over the three evaluation backends.

One ``Experiment`` — a batch of quorum systems, a workload, a fault set —
runs unmodified against:

  ``montecarlo``  the batched mask-table engine (``repro.montecarlo``),
                  hardware-speed latency/outcome distributions;
  ``des``         the discrete-event simulator running the verified
                  protocol state machines (``repro.core.simulator``);
  ``modelcheck``  exhaustive TLC-lite safety checking for n <= 5
                  (``repro.core.model_check``).

Quorum systems are anything satisfying the ``QuorumSystem`` protocol
(``QuorumSpec``, ``ExplicitQuorumSystem``, ``WeightedQuorumSystem``, raw
``QuorumMasks`` for the Monte-Carlo backend); the Monte-Carlo lowering is
always the membership-mask table (DESIGN.md §2/§6).

``Experiment(..., trials=10_000_000)`` streams the Monte-Carlo backend:
chunked trial reduction into a fixed-size quantile sketch
(``StreamSummary``), sharded over local devices — memory stays one chunk
no matter the trial count (DESIGN.md §7).

``frontier(systems, ...)`` / ``Experiment.frontier()`` score a whole
family batch through the streaming engine and return its Pareto frontier
(``repro.frontier``, DESIGN.md §8).

``plan(...)`` / ``Experiment.plan()`` run the successive-halving planner
(``repro.planner``, DESIGN.md §11): search a family for the cheapest
system meeting a fault budget under a workload, through a process-wide
warm engine cache — repeat same-geometry calls recompile nothing.
"""
from repro.montecarlo.streaming import StreamSummary  # noqa: F401

from .experiment import (BACKENDS, Experiment, Results,  # noqa: F401
                         Workload, frontier, plan, sweep)
