"""``Experiment``: declare once — model-check, simulate, and sweep.

The paper's contribution is a *space* of quorum systems (Eqs. 13/14)
evaluated for safety and performance; the interesting experiments compare
the *same* system across checkers, simulators and samplers (following the
methodology of Flexible Paxos and Relaxed Paxos).  This module is the one
front door for that comparison:

    exp = Experiment(systems=[QuorumSpec.paper_headline(11),
                              ExplicitQuorumSystem.grid(3).to_masks().embed(11),
                              weighted_system],
                     workload=Workload.race(k=2, delta_ms=0.2),
                     samples=50_000)
    mc  = exp.run("montecarlo")     # mask-table engine, one compile
    des = exp.run("des")            # protocol state machines, per system
    mc.to_dict()                    # flat {label.metric: float} for benches

Layering (DESIGN.md §6):

    declare        Experiment(systems, workload, faults, ...)
    lower          QuorumMasks via build_mask_table — the single quorum
                   lowering for the Monte-Carlo backend; to_explicit() for
                   the set-level backends (DES, model checker)
    dispatch       one backend call; Results normalizes the outputs

``Results`` is a registered pytree: latency percentiles and decide/
undecided rates are leaves (so it composes with ``jax.tree_util``), labels
and host-side verdicts ride as aux data.
"""
from __future__ import annotations

import inspect
import json
import random
from dataclasses import dataclass, field, replace
from pathlib import Path
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.model_check import explore
from repro.core.quorum import (ExplicitQuorumSystem, QuorumMasks, QuorumSpec,
                               WeightedQuorumSystem)
from repro.core.simulator import FastPaxosSim, LatencyModel
from repro.montecarlo import engine, streaming
from repro.montecarlo.latency import (LossyDelay, ShiftedLognormalDelay,
                                      WanDelay, delay_from_config,
                                      delay_to_config)
from repro.montecarlo.regimes import MarkovRegimes
from repro.montecarlo.scenarios import Scenario

BACKENDS = ("montecarlo", "des", "modelcheck")

# Instances this far apart are independent races in the DES (delays are a
# few ms); matches the spacing the cross-validation suite uses.
_DES_GAP_MS = 50.0

# Brute-force crash-set enumeration is exponential; past this n it is
# skipped and Results.fault_tolerance is None.
_FT_MAX_N = 14


# ---------------------------------------------------------------------------
# Workload: backend-independent race geometry + delay model.
# ---------------------------------------------------------------------------

def _check_workload_keys(cfg: Dict[str, Any], valid: set, what: str) -> None:
    """Reject unknown top-level keys with the offending names and the valid
    set — ``cls(**cfg)`` alone would surface a typo as an opaque TypeError
    deep in the dataclass machinery."""
    unknown = sorted(set(cfg) - valid)
    if unknown:
        raise ValueError(f"unknown {what} key(s) {unknown}; "
                         f"valid keys: {sorted(valid)}")


def _check_delay_config(d) -> None:
    """Validate serialized delay-model ``kind`` names (recursively through
    wrapper ``inner`` configs) against the latency registry at parse time."""
    from repro.montecarlo.latency import delay_kinds
    while isinstance(d, dict):
        kind = d.get("kind")
        if kind not in delay_kinds():
            raise ValueError(f"unknown delay kind {kind!r}; "
                             f"known kinds: {delay_kinds()}")
        d = d.get("inner")

@dataclass(frozen=True)
class Workload:
    """What the cluster is asked to do, independent of any quorum system.

    ``k_proposers`` values race for each instance (k=1: conflict-free),
    proposer i submitting at ``i * delta_ms``; a ``conflict_frac`` < 1
    mixes in conflict-free commands (Fig. 2b).  ``delay`` is a
    ``repro.montecarlo.latency`` pytree OR its serialized config dict
    (``None`` = the §6 EC2 fit, the one distribution the DES backend
    shares); ``inter_region_ms`` instead builds a WAN placement once the
    cluster size is known, and ``loss_prob`` wraps the model with i.i.d.
    message loss.  ``regimes`` (a ``MarkovRegimes`` or its config dict)
    Markov-modulates streamed runs through failure epochs (DESIGN.md §12).
    ``recovery`` picks the collision-recovery rule
    (``engine.RECOVERY_MODES``): coordinated (the paper's §6 deployment)
    or uncoordinated (arXiv 1710.08047 — detecting acceptors vote directly
    in the next fast round).

    A workload is declarative data: ``to_dict()`` / ``from_dict()``
    round-trip every constructor — trace-driven delays and regime chains
    included — through plain JSON, the schema ``examples/scenarios/*.json``
    and ``Experiment.from_config`` consume.
    """

    name: str = "conflict_free"
    k_proposers: int = 1
    delta_ms: float = 0.0
    conflict_frac: float = 1.0
    delay: object = None
    inter_region_ms: Optional[float] = None
    n_regions: int = 3
    loss_prob: float = 0.0
    des_requests: int = 1200        # DES backend sample count (per system)
    regimes: object = None          # MarkovRegimes | config dict | None
    recovery: str = "coordinated"   # collision-recovery rule

    def __post_init__(self) -> None:
        if self.k_proposers < 1:
            raise ValueError(
                f"k_proposers must be >= 1 (1 = conflict-free), "
                f"got {self.k_proposers}")
        engine._check_recovery(self.recovery)

    # -- constructors ------------------------------------------------------
    @classmethod
    def conflict_free(cls, delay=None, **kw) -> "Workload":
        """Fig. 2a: a steady conflict-free stream."""
        return cls(name="conflict_free", delay=delay, **kw)

    @classmethod
    def race(cls, k: int = 2, delta_ms: float = 0.5, delay=None,
             **kw) -> "Workload":
        """K proposals race for every instance, staggered by Δ (Fig. 2c)."""
        if k < 2:
            raise ValueError("a race needs at least 2 proposers")
        return cls(name=f"{k}_way_race", k_proposers=k, delta_ms=delta_ms,
                   delay=delay, **kw)

    @classmethod
    def mixed(cls, conflict_frac: float = 0.10, delta_ms: float = 0.5,
              k: int = 2, delay=None, **kw) -> "Workload":
        """Fig. 2b: ``conflict_frac`` of commands race, the rest are clean."""
        return cls(name="mixed_workload", k_proposers=k, delta_ms=delta_ms,
                   conflict_frac=conflict_frac, delay=delay, **kw)

    @classmethod
    def wan(cls, k: int = 2, inter_region_ms: float = 30.0,
            n_regions: int = 3, delta_ms: float = 0.5, **kw) -> "Workload":
        """Geo-distributed acceptors round-robin across regions."""
        return cls(name="wan", k_proposers=k, delta_ms=delta_ms,
                   inter_region_ms=inter_region_ms, n_regions=n_regions,
                   **kw)

    @classmethod
    def lossy(cls, loss_prob: float = 0.01, k: int = 2,
              delta_ms: float = 0.5, delay=None, **kw) -> "Workload":
        """Every hop independently drops with ``loss_prob``."""
        return cls(name="lossy", k_proposers=k, delta_ms=delta_ms,
                   loss_prob=loss_prob, delay=delay, **kw)

    # -- declarative config (DESIGN.md §12) --------------------------------
    def to_dict(self) -> Dict[str, Any]:
        """Serialize to a plain JSON-ready dict (the scenario-config
        schema).  ``from_dict`` inverts it; fields at their defaults are
        dropped for readability."""
        regimes = self.regimes
        if isinstance(regimes, MarkovRegimes):
            regimes = regimes.to_config()
        cfg: Dict[str, Any] = {
            "name": self.name, "k_proposers": self.k_proposers,
            "delta_ms": float(self.delta_ms),
            "conflict_frac": float(self.conflict_frac),
            "delay": (self.delay if isinstance(self.delay, dict)
                      else delay_to_config(self.delay)),
            "inter_region_ms": (None if self.inter_region_ms is None
                                else float(self.inter_region_ms)),
            "n_regions": self.n_regions,
            "loss_prob": float(self.loss_prob),
            "des_requests": self.des_requests, "regimes": regimes,
            "recovery": self.recovery}
        defaults = Workload()
        return {k: v for k, v in cfg.items()
                if v is not None and v != getattr(defaults, k, None)
                or k == "name"}

    @classmethod
    def from_dict(cls, cfg: Dict[str, Any]) -> "Workload":
        """Build from ``to_dict`` output or the ``{"kind": ...}``
        constructor shorthand (``race``/``mixed``/``wan``/``lossy``/
        ``conflict_free`` with that constructor's keywords).  Delay and
        regime configs stay declarative until a cluster size is known
        (``delay_for`` / ``scenario`` resolve them), but their *registry
        names* are validated here — a typo in a serialized config fails at
        parse time with the offending key and the valid set, not deep
        inside a later lowering."""
        cfg = dict(cfg)
        kind = cfg.pop("kind", None)
        if kind is not None:
            ctors = {"conflict_free": cls.conflict_free, "race": cls.race,
                     "mixed": cls.mixed, "wan": cls.wan, "lossy": cls.lossy}
            if kind not in ctors:
                raise ValueError(f"unknown workload kind {kind!r}; "
                                 f"pick one of {sorted(ctors)}")
            ctor = ctors[kind]
            named = [p.name for p in
                     inspect.signature(ctor).parameters.values()
                     if p.kind is not inspect.Parameter.VAR_KEYWORD]
            valid = set(named) | (set(cls.__dataclass_fields__) - {"name"})
            _check_workload_keys(cfg, valid, f"workload kind {kind!r}")
            _check_delay_config(cfg.get("delay"))
            return ctor(**cfg)
        _check_workload_keys(cfg, set(cls.__dataclass_fields__), "workload")
        _check_delay_config(cfg.get("delay"))
        return cls(**cfg)

    # -- lowering ----------------------------------------------------------
    def delay_for(self, n: int):
        d = self.delay
        if isinstance(d, dict):             # serialized form: resolve now
            d = delay_from_config(d, n)
        if d is None and self.inter_region_ms is not None:
            d = WanDelay.symmetric(self.inter_region_ms, n,
                                   self.k_proposers, self.n_regions)
        if d is None:
            d = ShiftedLognormalDelay()
        if self.loss_prob:
            d = LossyDelay(d, self.loss_prob)
        return d

    def regimes_for(self, n: int) -> Optional[MarkovRegimes]:
        """The regime chain with config dicts resolved for a cluster of
        ``n`` (base-delay inheritance stays deferred until the stream
        binds its model)."""
        if self.regimes is None:
            return None
        if isinstance(self.regimes, MarkovRegimes):
            return self.regimes.validate()
        return MarkovRegimes.from_config(self.regimes, n)

    def scenario(self, n: int, faults: Sequence[int] = ()) -> Scenario:
        """Lower to a Monte-Carlo ``Scenario`` for a cluster of ``n``."""
        offs = self.delta_ms * jnp.arange(self.k_proposers,
                                          dtype=jnp.float32)
        scen = Scenario(self.name, n, self.k_proposers, offs,
                        self.delay_for(n), self.conflict_frac)
        scen = scen.with_spec(recovery=self.recovery)
        regimes = self.regimes_for(n)
        if regimes is not None:
            scen = scen.with_spec(regimes=regimes)
        return scen.with_faults(faults)

    def des_latency(self) -> LatencyModel:
        """Lower the delay model for the discrete-event backend (which
        speaks the shifted-lognormal EC2 fit, optionally lossy)."""
        d = self.delay if self.delay is not None else ShiftedLognormalDelay()
        if isinstance(d, dict):
            d = delay_from_config(d)
        if self.inter_region_ms is not None or not isinstance(
                d, ShiftedLognormalDelay):
            raise ValueError(
                f"the des backend models the §6 single-region network "
                f"(ShiftedLognormalDelay); workload {self.name!r} uses "
                f"{type(d).__name__ if self.delay is not None else 'WAN'} — "
                f"run it on the montecarlo backend")
        return LatencyModel(base_ms=d.base_ms, mu=d.mu, sigma=d.sigma,
                            loss_prob=self.loss_prob)


# ---------------------------------------------------------------------------
# Results: one normalized shape for all three backends.
# ---------------------------------------------------------------------------

@dataclass
class Results:
    """Structured outcome of one ``Experiment.run``.

    ``summary``          metric name -> length-M vector (one entry per
                         system): latency percentiles (decided instances
                         only) and fast/recovery/undecided rates; for the
                         modelcheck backend, ``safe``/``states``.
    ``raw``              materializing montecarlo only: the per-sample
                         (M, S) decide bits and latencies straight from
                         the engine (None when streamed — per-trial arrays
                         are never materialized at streaming trial counts).
    ``stream``           streamed montecarlo only: the mergeable
                         ``StreamSummary`` (counts + quantile sketch), for
                         further merging or custom quantile queries.
    ``fault_tolerance``  per-system crash budgets per phase (brute force
                         over the masks; None above n=14).
    ``safety``           modelcheck only: per-system verdict dicts
                         (ok / states explored / violation / trace).
    """

    backend: str
    labels: Tuple[str, ...]
    summary: Dict[str, Any]
    raw: Optional[Dict[str, jax.Array]] = None
    fault_tolerance: Optional[Tuple[Dict[str, int], ...]] = None
    safety: Optional[Tuple[Dict[str, Any], ...]] = None
    stream: Optional[streaming.StreamSummary] = None

    def system(self, which) -> Dict[str, float]:
        """Per-system scalar view, by label or index."""
        i = which if isinstance(which, int) else self.labels.index(which)
        out = {k: _scalar(v[i]) for k, v in self.summary.items()}
        if self.fault_tolerance is not None:
            out.update({f"ft_{k}": v for k, v in
                        self.fault_tolerance[i].items()})
        if self.safety is not None:
            out.update({f"safety_{k}": v for k, v in
                        self.safety[i].items() if k != "trace"})
        return out

    def to_dict(self) -> Dict[str, float]:
        """Flatten to ``{label.metric: scalar}`` (benchmark CSV shape)."""
        flat: Dict[str, float] = {}
        for i, label in enumerate(self.labels):
            for k, v in self.summary.items():
                flat[f"{label}.{k}"] = _scalar(v[i])
            if self.fault_tolerance is not None:
                ft = self.fault_tolerance[i]
                flat[f"{label}.ft_fast"] = ft["phase2_fast"]
                flat[f"{label}.ft_classic"] = ft["phase2_classic"]
                flat[f"{label}.ft_phase1"] = ft["phase1"]
            if self.safety is not None:
                flat[f"{label}.safe"] = float(self.safety[i]["ok"])
        return flat


def _scalar(v):
    try:
        return float(v)
    except (TypeError, ValueError):
        return v


def _results_flatten(r: Results):
    return ((r.summary, r.raw, r.stream),
            (r.backend, r.labels, r.fault_tolerance, r.safety))


def _results_unflatten(aux, children):
    return Results(aux[0], aux[1], children[0], children[1], aux[2], aux[3],
                   children[2])


jax.tree_util.register_pytree_node(Results, _results_flatten,
                                   _results_unflatten)


# ---------------------------------------------------------------------------
# Experiment.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Experiment:
    """A declarative evaluation: systems x workload x faults -> Results.

    ``systems`` is any mix of ``QuorumSpec`` / ``ExplicitQuorumSystem`` /
    ``WeightedQuorumSystem`` / raw ``QuorumMasks``, all on one cluster
    size.  ``faults`` crashes the named acceptors (every hop touching them
    is lost) on the montecarlo and des backends; the modelcheck backend
    ignores it — losing messages only removes behaviours, so safety
    verdicts already cover every crash pattern.

    The same object runs against all three backends; only ``backend``
    (or the ``run`` argument) selects the execution engine.

    ``trials`` switches the montecarlo backend to the streaming engine
    (``repro.montecarlo.streaming``): trials are drawn, decided and
    reduced chunk-by-chunk into a fixed-size quantile sketch, sharded over
    the global device grid — 10^7+ trials in one-chunk memory, with
    ``Results`` exposing the same normalized summary keys (plus
    ``p999_ms``/``p9999_ms``, which only streaming trial counts make
    meaningful) and ``Results.raw`` None.  ``precision`` is the sketch's
    guaranteed relative quantile error; ``chunk`` the per-step trial
    block; ``shard`` toggles the trial-axis ``shard_map`` — ``True`` uses
    all visible devices (every process's, once
    ``repro.parallel.distributed.initialize()`` has joined a multi-host
    grid), or pass an explicit 1-D ``jax.sharding.Mesh`` to pin the
    layout (honored even with a single device).  When ``trials`` is None
    the materializing path runs unchanged on ``samples``.
    """

    systems: Tuple
    workload: Workload = field(default_factory=Workload)
    faults: Tuple[int, ...] = ()
    backend: str = "montecarlo"
    samples: int = 20_000
    seed: int = 0
    use_kernel: bool = False
    max_states: int = 200_000      # modelcheck BFS cap
    compute_fault_tolerance: bool = True   # brute-force crash budgets
    trials: Optional[int] = None   # streaming trial count (montecarlo)
    precision: float = streaming.DEFAULT_PRECISION
    chunk: int = streaming.DEFAULT_CHUNK
    shard: bool = True
    # Sort-free streamed lowering (DESIGN.md §9): "auto" derives the
    # per-phase top-k selection depths from the mask table, None keeps the
    # full-sort reference path, an int / 3-tuple pins the depths.  Integer
    # outputs (decide bits, counts, histograms) are identical either way.
    k_max: object = "auto"

    def __post_init__(self) -> None:
        object.__setattr__(self, "systems", tuple(self.systems))
        object.__setattr__(self, "faults", tuple(self.faults))
        if not self.systems:
            raise ValueError("Experiment needs at least one quorum system")
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"pick one of {BACKENDS}")
        if self.trials is not None and self.trials < 1:
            raise ValueError(f"trials must be >= 1, got {self.trials}")

    # -- lowering ----------------------------------------------------------
    def masks(self) -> Tuple[QuorumMasks, ...]:
        # memoized: n/labels/lower/fault-tolerance all consume the masks,
        # and the systems tuple is frozen with the dataclass
        cached = self.__dict__.get("_masks")
        if cached is None:
            cached = tuple(s if isinstance(s, QuorumMasks) else s.to_masks()
                           for s in self.systems)
            object.__setattr__(self, "_masks", cached)
        return cached

    @property
    def n(self) -> int:
        ns = {m.n for m in self.masks()}
        if len(ns) != 1:
            raise ValueError(f"systems mix cluster sizes {sorted(ns)}; "
                             f"use QuorumMasks.embed() to align them")
        return ns.pop()

    @property
    def labels(self) -> Tuple[str, ...]:
        labels, seen = [], {}
        for i, m in enumerate(self.masks()):
            lab = m.label or f"system{i}"
            if lab in seen:                      # keep to_dict keys unique
                seen[lab] += 1
                lab = f"{lab}#{seen[lab]}"
            else:
                seen[lab] = 0
            labels.append(lab)
        return tuple(labels)

    def lower(self, *, specialize: bool = True) -> Dict[str, jax.Array]:
        """The single quorum lowering: the batched membership-mask table
        every Monte-Carlo path consumes (all-cardinality batches carry the
        ``"q"`` k-th-order-statistic specialization).  Memoized per
        ``specialize`` flag so repeated runs re-upload nothing."""
        cache = self.__dict__.setdefault("_lowered", {})
        if specialize not in cache:
            cache[specialize] = engine.build_mask_table(
                self.masks(), specialize=specialize)
        return cache[specialize]

    # -- declarative config (DESIGN.md §12) --------------------------------
    @classmethod
    def from_config(cls, path_or_dict) -> "Experiment":
        """Build a whole experiment from declarative data: a JSON file
        path or an already-parsed dict (the ``examples/scenarios/*.json``
        schema) —

            {"systems": [{"kind": "cardinality", "preset": "paper_headline",
                          "n": 11}, ...],
             "workload": {"kind": "race", "k": 2, "delta_ms": 0.2,
                          "regimes": {...}},
             "trials": 1000000, "seed": 0}

        ``systems`` entries lower through ``system_from_config``;
        ``workload`` through ``Workload.from_dict``; every remaining key
        is an ``Experiment`` field."""
        cfg = path_or_dict
        if isinstance(cfg, (str, Path)):
            with open(cfg) as f:
                cfg = json.load(f)
        cfg = dict(cfg)
        systems = [system_from_config(s) for s in cfg.pop("systems")]
        wl = cfg.pop("workload", None)
        workload = (Workload.from_dict(wl) if isinstance(wl, dict)
                    else wl if wl is not None else Workload())
        cfg["faults"] = tuple(cfg.get("faults", ()))
        return cls(systems=systems, workload=workload, **cfg)

    # -- execution ---------------------------------------------------------
    def run(self, backend: Optional[str] = None) -> Results:
        """Evaluate on ``backend`` (default: the declared one)."""
        backend = backend or self.backend
        if backend == "montecarlo":
            return self._run_montecarlo()
        if backend == "des":
            return self._run_des()
        if backend == "modelcheck":
            return self._run_modelcheck()
        raise ValueError(f"unknown backend {backend!r}; "
                         f"pick one of {BACKENDS}")

    def frontier(self, axes=None, trials: Optional[int] = None):
        """Streamed quorum-space Pareto frontier over this experiment's
        systems (``repro.frontier``): one ``fast_path_stream`` pass and one
        ``race_stream`` pass score the whole batch under common random
        numbers, and the dominance kernel returns a ``FrontierResult``.

        The race geometry comes from the declared workload when it races
        (``k_proposers >= 2``); conflict-free workloads fall back to the
        standard 2-way race at Δ=0.2 ms, since the frontier's recovery and
        tail axes need collisions to measure.  The experiment's ``faults``
        crash the named acceptors for the whole scoring run (every hop
        touching them is lost), exactly as on the montecarlo backend.
        ``trials`` defaults to the experiment's streaming trial count (or
        10^6)."""
        return frontier(self.systems, self.workload, n=self.n,
                        faults=self.faults,
                        trials=trials if trials is not None else self.trials,
                        chunk=self.chunk, precision=self.precision,
                        shard=self.shard, seed=self.seed,
                        use_kernel=self.use_kernel, k_max=self.k_max,
                        axes=axes)

    def plan(self, family: str = "cardinality", *,
             faults: Optional[Dict[str, int]] = None,
             trials: Optional[int] = None,
             objective: str = "race_p999_ms", planner=None, **query_kw):
        """Search ``family`` for the best system under THIS experiment's
        workload and engine knobs (``repro.planner``, DESIGN.md §11).

        ``faults`` is the minimum crash-budget triple the recommendation
        must satisfy (``{"fast": 1, "phase1": 2, "classic": 2}``; missing
        keys 0) — distinct from the experiment's ``faults`` tuple, whose
        named acceptors are *crashed for the whole scoring run* (their
        hops are lost), exactly as on the montecarlo backend.  ``trials``
        is the final successive-halving budget (default: the experiment's
        streaming trial count, or 10^6).  Queries route through the
        process-wide planner (or an explicit ``planner``), so repeat
        same-geometry plans re-enter warm compiles and cached searches.
        Returns a ``repro.planner.PlanResult``."""
        wl = self.workload
        if self.faults:
            from repro.montecarlo.latency import CrashedDelay
            from repro.montecarlo.scenarios import _crash_mask
            wl = replace(wl,
                         delay=CrashedDelay(wl.delay_for(self.n),
                                            _crash_mask(self.n, self.faults)),
                         loss_prob=0.0)
        query = dict(n=self.n, family=family, workload=wl,
                     faults=faults or {},
                     trials=(trials if trials is not None
                             else self.trials or 1_000_000),
                     objective=objective, chunk=self.chunk,
                     precision=self.precision, seed=self.seed,
                     shard=self.shard, use_kernel=self.use_kernel,
                     k_max=self.k_max, **query_kw)
        return plan(query, planner=planner)

    def _fault_tolerance(self) -> Optional[Tuple[Dict[str, int], ...]]:
        if not self.compute_fault_tolerance or self.n > _FT_MAX_N:
            return None
        cached = self.__dict__.get("_ft")
        if cached is None:
            cached = tuple(m.fault_tolerance() for m in self.masks())
            object.__setattr__(self, "_ft", cached)
        return cached

    def _run_montecarlo(self) -> Results:
        scen = self.workload.scenario(self.n, self.faults)
        key = jax.random.PRNGKey(self.seed)
        if self.trials is not None:
            state = scen.with_spec(
                trials=self.trials, chunk=self.chunk,
                precision=self.precision, use_kernel=self.use_kernel,
                shard=self.shard, k_max=self.k_max).stream(
                    key, self.lower())
            return Results(backend="montecarlo", labels=self.labels,
                           summary=state.summary(), stream=state,
                           fault_tolerance=self._fault_tolerance())
        out = scen.with_spec(samples=self.samples,
                             use_kernel=self.use_kernel).run(
                                 key, self.lower())
        return Results(backend="montecarlo", labels=self.labels,
                       summary=engine.summarize(out), raw=out,
                       fault_tolerance=self._fault_tolerance())

    # -- discrete-event backend --------------------------------------------
    def _set_level(self, system, backend: str):
        """Lower one system for the set-level backends (DES, checker)."""
        if isinstance(system, QuorumMasks):
            raise ValueError(
                f"raw QuorumMasks ({system.label or 'unlabelled'}) only "
                f"lower to the montecarlo engine; pass the originating "
                f"QuorumSpec/ExplicitQuorumSystem/WeightedQuorumSystem "
                f"for the {backend} backend")
        return system

    def _run_des(self) -> Results:
        lat = self.workload.des_latency()
        per_sys = [self._des_one(self._set_level(s, "des"), lat)
                   for s in self.systems]
        summary = {k: [d[k] for d in per_sys] for k in per_sys[0]}
        return Results(backend="des", labels=self.labels, summary=summary,
                       fault_tolerance=self._fault_tolerance())

    def _des_one(self, system, lat: LatencyModel) -> Dict[str, float]:
        wl = self.workload
        sim = FastPaxosSim(system, latency=lat, seed=self.seed,
                           crashed=self.faults, recovery=wl.recovery)
        rng = random.Random(self.seed + 1)
        k = wl.k_proposers
        t = 0.0
        for i in range(wl.des_requests):
            kk = k if (k > 1 and rng.random() < wl.conflict_frac) else 1
            for p in range(kk):
                sim.submit(t + p * wl.delta_ms, instance=i,
                           value=f"v{i}_{p}", proposer=p)
            t += _DES_GAP_MS           # isolate instances (independent races)
        sim.run()

        by_inst: Dict[int, list] = {}
        for r in sim.results.values():
            by_inst.setdefault(r.instance, []).append(r)
        lats, fast, rec = [], 0, 0
        for rs in by_inst.values():
            win = next((r for r in rs
                        if r.outcome in ("fast", "recovered")), None)
            if win is None:
                continue
            lats.append(win.latency_ms)
            fast += win.outcome == "fast"
            rec += win.outcome == "recovered"
        m = len(by_inst)
        lats.sort()
        q = lambda p: lats[min(len(lats) - 1, int(p * len(lats)))] \
            if lats else float("nan")
        return {
            "mean_ms": sum(lats) / len(lats) if lats else float("nan"),
            "p50_ms": q(0.50), "p95_ms": q(0.95), "p99_ms": q(0.99),
            "p999_ms": q(0.999), "p9999_ms": q(0.9999),
            "max_ms": lats[-1] if lats else float("nan"),
            "fast_rate": fast / m, "recovery_rate": rec / m,
            "undecided_rate": (m - fast - rec) / m,
        }

    # -- model-check backend -----------------------------------------------
    def _run_modelcheck(self) -> Results:
        if self.n > 5:
            raise ValueError(
                f"the modelcheck backend explores the full state space and "
                f"is capped at n<=5 acceptors (got n={self.n}); check a "
                f"small congruent system and sweep the big one on the "
                f"montecarlo backend")
        verdicts = []
        for s in self.systems:
            r = explore(self._set_level(s, "modelcheck"),
                        max_states=self.max_states)
            verdicts.append({"ok": r.ok, "states": r.states,
                             "violation": r.violation,
                             "truncated": r.truncated, "trace": r.trace})
        summary = {"safe": [float(v["ok"]) for v in verdicts],
                   "states": [float(v["states"]) for v in verdicts]}
        return Results(backend="modelcheck", labels=self.labels,
                       summary=summary,
                       fault_tolerance=self._fault_tolerance(),
                       safety=tuple(verdicts))


def system_from_config(cfg):
    """One quorum system from declarative data (the ``systems`` entries of
    the scenario-config schema):

      {"kind": "cardinality", "n": 11, "q1": 9, "q2c": 3, "q2f": 7}
      {"kind": "cardinality", "preset": "paper_headline", "n": 11}
      {"kind": "relaxed", "n": 11, "q1": 5, "q2c": 2, "q2f": 9}
      {"kind": "grid", "cols": 3, "rows": 3, "n": 11}      # n: embed target
      {"kind": "weighted", "weights": [...], "t1": ..., "t2c": ..., "t2f": ...}
    """
    cfg = dict(cfg)
    kind = cfg.pop("kind", "cardinality")
    if kind == "relaxed":
        from repro.core.quorum import RelaxedQuorumSpec
        return RelaxedQuorumSpec(**cfg).validate()
    if kind == "cardinality":
        preset = cfg.pop("preset", None)
        if preset is not None:
            ctor = getattr(QuorumSpec, preset, None)
            if ctor is None:
                raise ValueError(f"unknown QuorumSpec preset {preset!r}")
            return ctor(**cfg).validate()
        return QuorumSpec(**cfg).validate()
    if kind == "grid":
        n = cfg.pop("n", None)
        sys_ = ExplicitQuorumSystem.grid(int(cfg.pop("cols", 3)),
                                         int(cfg.pop("rows", 3))).validate()
        return sys_ if n is None or n == sys_.n else sys_.embed(int(n))
    if kind == "weighted":
        return WeightedQuorumSystem(
            tuple(int(w) for w in cfg["weights"]), int(cfg["t1"]),
            int(cfg["t2c"]), int(cfg["t2f"])).validate()
    raise ValueError(f"unknown system kind {kind!r}; pick one of "
                     f"('cardinality', 'relaxed', 'grid', 'weighted')")


def sweep(experiment: Experiment, backends: Sequence[str] = BACKENDS
          ) -> Dict[str, Results]:
    """Run one experiment across several backends: {backend: Results}."""
    return {b: experiment.run(b) for b in backends}


def frontier(systems: Sequence, workload: Optional[Workload] = None, *,
             n: Optional[int] = None, faults: Sequence[int] = (),
             trials: Optional[int] = None,
             chunk: Optional[int] = None, precision: Optional[float] = None,
             shard: bool = True, seed: int = 0, use_kernel: bool = False,
             k_max="auto", axes=None):
    """One-call quorum-space Pareto frontier (``repro.frontier``).

    ``systems`` is any mix of ``repro.frontier.families.Member``, quorum
    systems, or raw ``QuorumMasks`` — smaller systems embed into the
    largest cluster present (or an explicit ``n``).  ``workload`` supplies
    the race geometry and delay model when it races; conflict-free /
    omitted workloads score under the standard 2-way race at Δ=0.2 ms.
    ``faults`` crashes the named acceptors for the whole run (every hop
    touching them is lost) — note the crash budgets on the ft axes still
    describe the *intact* systems.  Returns a ``FrontierResult``
    (``.table()``, ``.to_dict()``, ``.frontier_labels``)."""
    from repro.frontier import score as fscore
    from repro.montecarlo.latency import CrashedDelay
    from repro.montecarlo.scenarios import _crash_mask

    systems = list(systems)          # may be a generator: consume once
    wl = workload if workload is not None else Workload.race(
        k=2, delta_ms=fscore.DEFAULT_DELTA_MS)
    if n is None:
        n = fscore._as_masks(systems, None)[2]
    delay = wl.delay_for(n)
    if len(tuple(faults)):
        delay = CrashedDelay(delay, _crash_mask(n, faults))
    racing = wl.k_proposers >= 2
    return fscore.score_systems(
        systems, n=n,
        trials=trials if trials is not None else fscore.DEFAULT_TRIALS,
        k_proposers=wl.k_proposers if racing else 2,
        delta_ms=wl.delta_ms if racing else fscore.DEFAULT_DELTA_MS,
        delay=delay,
        chunk=chunk if chunk is not None else fscore.DEFAULT_CHUNK,
        precision=(precision if precision is not None
                   else streaming.DEFAULT_PRECISION),
        shard=shard, seed=seed, use_kernel=use_kernel, k_max=k_max,
        axes=axes, regimes=wl.regimes_for(n), recovery=wl.recovery)


# Process-wide planner behind ``plan()``: one warm engine pool + search
# LRU shared by every in-process query, so the second same-geometry call
# recompiles nothing (the planner service holds its own instance).
_PLANNER = None


def default_planner():
    """The lazily-created process-wide ``repro.planner.Planner``."""
    global _PLANNER
    if _PLANNER is None:
        from repro.planner import Planner
        _PLANNER = Planner()
    return _PLANNER


def plan(query=None, *, planner=None, **query_kw):
    """One-call quorum planning (``repro.planner``, DESIGN.md §11).

    Successive-halving search over a family, answered from the
    process-wide warm planner: pass a ``repro.planner.PlanQuery``, a dict,
    or its fields as keywords —

        plan(n=11, family="cardinality",
             workload=Workload.race(k=2, delta_ms=0.2),
             faults={"fast": 1, "classic": 2}, trials=1_000_000)

    ``faults`` is the minimum crash-budget triple the recommendation must
    satisfy; ``objective`` ranks the budget-satisfying frontier members
    (``race_p999_ms`` default).  Returns a ``repro.planner.PlanResult``
    (recommended system, predicted p50/p99.9/p99.99, fault-tolerance
    triple, search telemetry).  Repeat same-geometry calls hit the search
    cache and add zero engine compiles."""
    if planner is None:
        planner = default_planner()
    return planner.plan(query, **query_kw)
