"""Warm compiled-engine pool for planner queries (DESIGN.md §11).

``montecarlo.streaming._stream`` compiles once per engine *geometry* —
the static signature (table shapes, pair-layout width, chunk count,
precision, resolved saturation depths, mesh) — and JAX's jit cache keeps
that compile warm for the life of the process.  What a long-lived planner
needs on top is bookkeeping and memoization:

  EngineKey     the geometry a scoring query lowers to, computed host-side
                without touching the engine — two queries with equal keys
                are guaranteed to re-enter the same compiles.
  EngineCache   routes ``frontier.score.score_systems`` calls through a
                per-key ledger (queries seen, compiles actually paid,
                measured via the ``engine.TRACE_COUNTS`` delta around the
                call) plus an LRU of full ``FrontierResult``s keyed by a
                *content* fingerprint (table bytes + delay leaves + every
                parameter), so a bit-identical repeat query returns
                without running the engine at all.

The planner service keeps one ``EngineCache`` for its whole lifetime; the
successive-halving search threads one through all its rungs.  The
"second same-shape query adds zero compiles" acceptance criterion is
asserted against ``TRACE_COUNTS`` in tests/test_planner.py and the CI
planner smoke job.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, replace
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

import jax

from repro.montecarlo import engine, streaming

# The base per-path trace counters.  The ``*_sortfree`` / ``*_fused`` keys
# increment ALONGSIDE their base key (they pin which lowering ran), so
# summing everything would double-count one trace.
BASE_TRACE_KEYS = ("race", "fast_path", "classic_path",
                   "race_stream", "fast_path_stream", "classic_path_stream")


def trace_total() -> int:
    """Total engine traces so far (jit cache misses across all paths)."""
    return sum(engine.TRACE_COUNTS[k] for k in BASE_TRACE_KEYS)


@dataclass(frozen=True)
class EngineKey:
    """The static geometry one scoring query lowers to.

    Mirrors ``streaming._stream``'s static argnames plus everything that
    feeds them: equal keys ⇒ the query re-enters already-traced compiles
    (shapes and statics identical; table *contents* are traced).  The
    materializing T <= chunk fallback jits on ``samples`` instead of a
    chunk count, so ``mode`` + ``n_chunks`` carries either geometry.
    """

    table_sig: Tuple[Tuple[str, Tuple[int, ...], str], ...]
    layout_pairs: int               # P of the cardinality pair layout (0: n/a)
    n: int
    k_proposers: int
    chunk: int
    n_chunks: int                   # streamed: chunks; materializing: samples
    mode: str                       # "stream" | "materialize"
    precision: float
    k_sat: Optional[Tuple[int, int, int]]
    use_kernel: bool
    ndev: int
    # Markov regime modulation changes the lowered scan geometry (R regime
    # environments, epoch length in trials); (R, epoch_trials) or None.
    regimes_sig: Optional[Tuple[int, int]] = None
    # Collision-recovery rule: static on the stream jits, AND it changes the
    # cardinality pair layout (q2c vs q2f columns), so equal keys require it.
    recovery: str = "coordinated"


def _resolve_ndev(shard) -> int:
    """Device count a ``shard`` setting will actually run on (without the
    loud single-device warning — key computation is not a run)."""
    if shard is False or shard is None:
        return 1
    if shard is True:
        n = len(jax.devices())
        return n if n > 1 else 1
    from repro.parallel import sharding as psharding
    return shard.shape[psharding.TRIAL_AXIS]


def engine_key(table: Dict, *, n: int, k_proposers: int, trials: int,
               chunk: int, precision: float, shard, use_kernel: bool,
               k_max, regimes=None,
               recovery: str = "coordinated") -> EngineKey:
    """Compute the warm-pool key for one scoring query, host-side."""
    sig = tuple(sorted((k, tuple(v.shape), str(v.dtype))
                       for k, v in table.items()))
    ndev = _resolve_ndev(shard)
    if regimes is None and ndev == 1 and trials <= chunk:
        # materializing fallback: ``samples`` itself is the jit static
        return EngineKey(sig, 0, n, k_proposers, chunk, trials,
                         "materialize", precision, None, use_kernel, 1,
                         recovery=recovery)
    k_sat = streaming._resolve_k_sat(table, k_max, n)
    pairs = 0
    if "q" in table and k_sat is not None:
        # the recovery rule picks which q-column pairs with q1 in the
        # cardinality layout, so the pair count is rule-dependent
        cols = [0, 1] if recovery == "coordinated" else [0, 2]
        pairs = int(np.unique(np.asarray(table["q"])[:, cols],
                              axis=0).shape[0])
    per_device = -(-trials // ndev)
    n_chunks = -(-per_device // chunk)
    rsig = (None if regimes is None
            else (len(regimes.names), int(regimes.epoch_trials)))
    return EngineKey(sig, pairs, n, k_proposers, chunk, n_chunks, "stream",
                     precision, k_sat, use_kernel, ndev, rsig, recovery)


def _delay_token(delay) -> bytes:
    """Content fingerprint of a delay-model pytree (class + leaf bytes)."""
    if delay is None:
        return b"default"
    leaves, treedef = jax.tree_util.tree_flatten(delay)
    h = hashlib.sha256(str(treedef).encode())
    h.update(type(delay).__name__.encode())
    for leaf in leaves:
        arr = np.asarray(leaf)
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.digest()


class EngineCache:
    """Warm engine pool + result memo for a long-lived planner process.

    ``score`` has the same semantics as ``frontier.score.score_systems``
    (same arguments, same ``FrontierResult``, bit-identical values) with
    three additions: a per-``EngineKey`` ledger of queries vs compiles
    paid, an ``engine_compiles`` attribute on the returned result (the
    TRACE_COUNTS delta this call caused), and an LRU memo of results so a
    bit-identical repeat query skips the engine entirely (memo hits report
    ``engine_compiles == 0`` without even entering jit dispatch).
    """

    def __init__(self, memo_size: int = 64):
        self.memo_size = memo_size
        self.stats: Dict[EngineKey, Dict[str, int]] = {}
        self._memo: "OrderedDict[bytes, object]" = OrderedDict()
        self.memo_hits = 0
        self.memo_misses = 0

    # -- introspection -----------------------------------------------------
    def warm(self, key: EngineKey) -> bool:
        """Has this geometry been scored (hence traced) before?"""
        return key in self.stats

    @property
    def total_compiles(self) -> int:
        return sum(s["compiles"] for s in self.stats.values())

    def stats_dict(self) -> Dict[str, float]:
        return {"engine_keys": float(len(self.stats)),
                "engine_compiles": float(self.total_compiles),
                "memo_hits": float(self.memo_hits),
                "memo_misses": float(self.memo_misses)}

    # -- the one entry point ----------------------------------------------
    def score(self, systems: Sequence, *, trials: int,
              n: Optional[int] = None, k_proposers: int = 2,
              delta_ms: Optional[float] = None, delay=None,
              chunk: Optional[int] = None, precision: Optional[float] = None,
              shard=False, use_kernel: bool = False, k_max="auto",
              seed: int = 0, regimes=None, recovery: str = "coordinated",
              axes=None):
        from repro.frontier import score as fscore
        from repro.montecarlo.regimes import MarkovRegimes

        delta_ms = (fscore.DEFAULT_DELTA_MS if delta_ms is None
                    else delta_ms)
        chunk = fscore.DEFAULT_CHUNK if chunk is None else chunk
        precision = (streaming.DEFAULT_PRECISION if precision is None
                     else precision)

        masks, _, n = fscore._as_masks(list(systems), n)
        if isinstance(regimes, dict):        # serialized chain: resolve once
            regimes = MarkovRegimes.from_config(regimes, n)
        table = engine.build_mask_table(masks)
        key = engine_key(table, n=n, k_proposers=k_proposers, trials=trials,
                         chunk=chunk, precision=precision, shard=shard,
                         use_kernel=use_kernel, k_max=k_max, regimes=regimes,
                         recovery=recovery)
        labels = tuple(m.label or f"system{i}" for i, m in enumerate(masks))
        fp = self._fingerprint(table, key, labels=labels, trials=trials,
                               seed=seed, delta_ms=delta_ms, delay=delay,
                               regimes=regimes, axes=axes)
        hit = self._memo.get(fp)
        if hit is not None:
            self._memo.move_to_end(fp)
            self.memo_hits += 1
            st = self.stats.setdefault(key, {"queries": 0, "compiles": 0})
            st["queries"] += 1
            out = replace(hit)                  # fresh wrapper, shared arrays
            out.engine_compiles = 0
            return out
        self.memo_misses += 1

        before = trace_total()
        result = fscore.score_systems(
            list(systems), trials=trials, n=n, k_proposers=k_proposers,
            delta_ms=delta_ms, delay=delay, chunk=chunk, precision=precision,
            shard=shard, use_kernel=use_kernel, k_max=k_max, seed=seed,
            regimes=regimes, recovery=recovery, axes=axes)
        compiles = trace_total() - before
        st = self.stats.setdefault(key, {"queries": 0, "compiles": 0})
        st["queries"] += 1
        st["compiles"] += compiles
        result.engine_compiles = compiles

        self._memo[fp] = result
        while len(self._memo) > self.memo_size:
            self._memo.popitem(last=False)
        return result

    # -- internals ---------------------------------------------------------
    def _fingerprint(self, table: Dict, key: EngineKey, *,
                     labels: Tuple[str, ...], trials: int, seed: int,
                     delta_ms: float, delay, axes, regimes=None) -> bytes:
        h = hashlib.sha256(repr(key).encode())
        h.update(repr((labels, trials, seed, delta_ms)).encode())
        for name in sorted(table):
            arr = np.asarray(table[name])
            h.update(name.encode())
            h.update(arr.tobytes())
        h.update(_delay_token(delay))
        h.update(_delay_token(regimes))     # content token works per-pytree
        h.update(repr(tuple(axes) if axes is not None else None).encode())
        return h.digest()
