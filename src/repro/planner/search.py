"""Successive-halving search over quorum-system families (DESIGN.md §11).

Exhaustive enumeration (``benchmarks.quorum_sweep``) scores every family
member at the full trial budget; that dies combinatorially past n ~ 20 for
weighted/grid families.  This module spends the budget where it matters:

  rung 0        score the WHOLE candidate batch cheaply (e.g. 10^5 streamed
                trials) and prune every system that is dominated *beyond
                what the cheap measurement can resolve*;
  rung 1..k-1   re-score the survivors at geometrically growing budgets,
                pruning again with correspondingly tighter margins;
  final rung    score the remaining systems at the full budget and return
                their exact Pareto frontier (``frontier.pareto``) — by the
                soundness argument below, it equals the frontier of the
                full exhaustive sweep.

The schedule (``Rung`` / ``default_schedule``) is plain data and the
control flow (``successive_halving``) takes an injected ``scorer``, so the
halving logic is testable without ever touching JAX; the engine-backed
scorer lives behind ``planner.cache.EngineCache``.

Pruning soundness.  A rung prunes candidate i only when some candidate j
*margin-dominates* it: j is weakly better on every exact axis (the
integral fault-tolerance budgets, which are trial-independent) and better
by more than the rung's noise margin on EVERY stochastic axis.  The margin
covers both the sketch's quantization cell and the Monte-Carlo noise at
the rung's trial count (``quantile_margin_cells`` / ``rate_margin``), so
margin-dominance at a cheap rung implies dominance at the full budget:

  * a pruned system is full-budget-dominated by the candidate that pruned
    it; following the (transitive, acyclic) chain of pruners lands on a
    survivor, so every pruned system is dominated by some survivor;
  * hence no member of the full-budget Pareto set is ever pruned, and the
    Pareto set *of the survivors* equals the Pareto set of the full space
    (property-tested against the direct sweep in tests/test_planner.py).

Within-margin ties — systems the cheap rung cannot tell apart, including
the bit-exact ties common-random-number scoring produces for structurally
identical columns — are never split: both ride to the next rung, where a
tighter margin (or the final exact frontier) separates them.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.frontier.pareto import Axis, _REL_MIN, pareto_mask

# Margin multiplier: 1.0 = one sketch quantization cell plus ~1 sigma of
# Monte-Carlo noise per stochastic axis.  Common random numbers mean both
# estimates in a comparison share their trials, so the *difference* noise
# is far below the independent-estimate bound — empirically the n=11
# acceptance frontier survives intact down to slack 0.5 (2x headroom).
DEFAULT_SLACK = 1.0
# A quantile estimate is considered fully resolved once this many trials
# land past it; below that the pruning margin widens like 1/sqrt(tail).
_TAIL_RESOLVED = 50.0


# ---------------------------------------------------------------------------
# Plain-data schedule.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Rung:
    """One successive-halving rung: a trial budget and a pruning slack.

    ``slack`` scales the per-axis noise margin (in measurement cells /
    sigma units) a competitor must clear on *every* stochastic axis to
    prune a candidate here.  The final rung's slack is irrelevant — it
    computes the exact frontier instead of pruning.
    """

    trials: int
    slack: float = DEFAULT_SLACK

    def __post_init__(self) -> None:
        if self.trials < 1:
            raise ValueError(f"rung trials must be >= 1, got {self.trials}")
        if self.slack <= 0:
            raise ValueError(f"rung slack must be > 0, got {self.slack}")


@dataclass(frozen=True)
class RungReport:
    """What one rung did (plain data, serializable)."""

    trials: int
    n_scored: int
    n_survivors: int
    wall_s: float = 0.0
    engine_compiles: int = 0

    def to_dict(self) -> Dict[str, float]:
        return {"trials": self.trials, "n_scored": self.n_scored,
                "n_survivors": self.n_survivors, "wall_s": self.wall_s,
                "engine_compiles": self.engine_compiles}


def default_schedule(final_trials: int, *, eta: int = 10,
                     min_trials: int = 10_000,
                     slack: float = DEFAULT_SLACK) -> Tuple[Rung, ...]:
    """Geometric rungs ``final/eta^k, ..., final/eta, final`` (ascending),
    stopping once another division would drop below ``min_trials``."""
    if final_trials < 1:
        raise ValueError(f"final_trials must be >= 1, got {final_trials}")
    if eta < 2:
        raise ValueError(f"eta must be >= 2, got {eta}")
    trials = [final_trials]
    while trials[-1] // eta >= max(min_trials, 1):
        trials.append(trials[-1] // eta)
    return tuple(Rung(t, slack) for t in reversed(trials))


# ---------------------------------------------------------------------------
# Noise margins: how far apart two estimates must be before a cheap rung
# may call them "really different".
# ---------------------------------------------------------------------------

# Stochastic-axis semantics of the standard frontier (score.AXIS_NAMES):
# quantile axes carry the tail mass that determines their effective sample
# count; rate axes are binomial.  Axes not listed here (the integral
# fault-tolerance budgets) are exact and trial-independent.
STOCHASTIC_AXES: Dict[str, Tuple[str, float]] = {
    "fast_p50_ms": ("quantile", 0.5),
    "race_p999_ms": ("quantile", 0.001),
    "p_recovery": ("rate", 0.0),
}


def quantile_margin_cells(slack: float, trials: int, tail: float) -> float:
    """Pruning margin for a sketch-quantile axis, in log-gamma cells.

    One cell is the sketch's own relative error; on top of that the
    quantile estimate wobbles with the number of trials that land in the
    deciding tail (~ ``trials * tail``), widening like 1/sqrt(tail_n)
    until ``_TAIL_RESOLVED`` trials resolve the quantile to cell accuracy.
    """
    tail_n = max(float(trials) * tail, 1.0)
    return slack * (1.0 + math.sqrt(_TAIL_RESOLVED / tail_n))


def rate_margin(slack: float, trials: int) -> float:
    """Pruning margin for a binomial rate axis: slack x 3 sigma at the
    rung's trial count (worst-case p = 1/2 variance)."""
    return slack * 3.0 * math.sqrt(0.25 / max(trials, 1))


def _orient(values: np.ndarray, axes: Sequence[Axis]) -> np.ndarray:
    """(M, A) raw -> oriented "larger is better" float64; relative
    (sketch-valued) axes move to log-gamma space so margins are in cells;
    NaN (nothing decided) orients to -inf, i.e. worst."""
    v = np.asarray(values, np.float64)
    if v.ndim != 2 or v.shape[1] != len(axes):
        raise ValueError(f"values {v.shape} inconsistent with "
                         f"{len(axes)} axes")
    out = np.empty_like(v)
    with np.errstate(invalid="ignore", divide="ignore"):
        for a, ax in enumerate(axes):
            col = v[:, a]
            if ax.relative:
                gamma = (1.0 + ax.eps) / (1.0 - ax.eps)
                col = np.log(np.maximum(col, _REL_MIN)) / math.log(gamma)
            oriented = col if ax.maximize else -col
            out[:, a] = np.where(np.isnan(v[:, a]), -np.inf, oriented)
    return out


def prune_survivors(values: np.ndarray, axes: Sequence[Axis], rung: Rung,
                    ) -> np.ndarray:
    """(M,) bool: True = candidate survives this rung.

    Candidate i is pruned iff some j margin-dominates it:

      exact axes        (eps == 0, trial-independent)  j >= i
      stochastic axes   j better than i by more than the rung margin —
                        ``quantile_margin_cells`` cells on sketch axes,
                        ``rate_margin`` on rate axes — on EVERY one, with
                        at least one strictly-better finite comparison
                        (two systems that both never decide tie at -inf
                        and can prune nothing).

    Margin-dominance is irreflexive and asymmetric (the margin is strict
    somewhere), so duplicates and within-margin ties always survive
    together; pure numpy, O(M^2 A), no JAX.
    """
    o = _orient(values, axes)
    m = o.shape[0]
    if m <= 1:
        return np.ones(m, bool)
    margins = np.zeros(len(axes))
    for a, ax in enumerate(axes):
        kind = STOCHASTIC_AXES.get(ax.name)
        if kind is None and ax.eps == 0.0:
            margins[a] = 0.0                       # exact axis
        elif kind is not None and kind[0] == "rate":
            margins[a] = rate_margin(rung.slack, rung.trials)
        elif kind is not None and kind[0] == "quantile":
            margins[a] = quantile_margin_cells(rung.slack, rung.trials,
                                               kind[1])
        else:
            # unknown stochastic axis: eps-scaled fallback margin
            margins[a] = rung.slack * max(ax.eps, 1.0 if ax.relative else 0.0)
    stoch = np.array([ax.name in STOCHASTIC_AXES or ax.eps > 0
                      for ax in axes])

    # [j, i, a]: does j clear the bar against i on axis a?
    with np.errstate(invalid="ignore"):
        diff = o[:, None, :] - o[None, :, :]       # j - i, (M, M, A)
        ok_exact = (o[:, None, ~stoch] >= o[None, :, ~stoch]).all(-1)
        # -inf vs -inf gives diff NaN: a tie, not a margin win — but it
        # must not veto domination either (both-never-decided axes carry
        # no information).  Treat NaN diff as "bar met, not strict".
        beyond = np.where(np.isnan(diff[:, :, stoch]), True,
                          diff[:, :, stoch] > margins[stoch][None, None, :])
        strict = np.where(np.isnan(diff[:, :, stoch]), False,
                          diff[:, :, stoch] > margins[stoch][None, None, :])
    dominated = (ok_exact & beyond.all(-1) & strict.any(-1)).any(axis=0)
    return ~dominated


# ---------------------------------------------------------------------------
# The halving loop (scorer injected — no JAX in this file).
# ---------------------------------------------------------------------------

@dataclass
class SearchResult:
    """Outcome of one successive-halving search.

    ``frontier``           final-rung ``FrontierResult`` over the
                           survivors; its mask is the exact Pareto set of
                           the whole starting space (soundness argument in
                           the module docstring)
    ``members``            surviving candidates, aligned with
                           ``frontier.labels`` rows
    ``rungs``              per-rung reports (plain data)
    ``scored_trials``      sum over rungs of n_scored x trials (per engine
                           pass — fast and race scale identically)
    ``exhaustive_trials``  what the direct sweep would have cost:
                           n_candidates x final trials
    """

    frontier: object                       # FrontierResult
    members: List
    rungs: Tuple[RungReport, ...]
    scored_trials: int
    exhaustive_trials: int

    @property
    def budget_fraction(self) -> float:
        return self.scored_trials / max(self.exhaustive_trials, 1)

    @property
    def frontier_labels(self) -> Tuple[str, ...]:
        return self.frontier.frontier_labels

    def to_dict(self) -> Dict[str, float]:
        out = {"n_candidates": float(self.rungs[0].n_scored),
               "n_survivors": float(self.rungs[-1].n_scored),
               "n_frontier": float(len(self.frontier.frontier_indices)),
               "scored_trials": float(self.scored_trials),
               "exhaustive_trials": float(self.exhaustive_trials),
               "budget_fraction": float(self.budget_fraction),
               "engine_compiles": float(sum(r.engine_compiles
                                            for r in self.rungs))}
        for i, r in enumerate(self.rungs):
            for k, v in r.to_dict().items():
                out[f"rung{i}.{k}"] = float(v)
        return out


Scorer = Callable[[Sequence, int], object]


def successive_halving(candidates: Sequence, schedule: Sequence[Rung],
                       scorer: Scorer) -> SearchResult:
    """Run the rung schedule over ``candidates`` with an injected scorer.

    ``scorer(members, trials)`` returns a ``FrontierResult``-shaped object
    (``.values`` (M, A), ``.axes``, ``.mask``, ``.labels``) whose per-row
    scores must not depend on which other members share the batch (the
    streamed engine guarantees this via common random numbers); the last
    rung's result — restricted to survivors — is returned as the search's
    frontier.  Plain control flow: loops, numpy, no JAX.
    """
    schedule = tuple(schedule)
    if not schedule:
        raise ValueError("schedule needs at least one rung")
    if any(a.trials >= b.trials for a, b in zip(schedule, schedule[1:])):
        raise ValueError(
            f"rung trials must be strictly ascending, got "
            f"{tuple(r.trials for r in schedule)}")
    alive = list(candidates)
    if not alive:
        raise ValueError("successive_halving needs at least one candidate")
    n0 = len(alive)
    reports: List[RungReport] = []
    scored = 0
    result = None
    for idx, rung in enumerate(schedule):
        t0 = time.perf_counter()
        result = scorer(alive, rung.trials)
        wall = time.perf_counter() - t0
        scored += len(alive) * rung.trials
        compiles = int(getattr(result, "engine_compiles", 0) or 0)
        if idx + 1 == len(schedule):
            keep = np.asarray(result.mask, bool)    # exact final frontier
            n_surv = len(alive)                     # nothing pruned here
        else:
            keep = prune_survivors(np.asarray(result.values), result.axes,
                                   rung)
            n_surv = int(keep.sum())
        reports.append(RungReport(trials=rung.trials, n_scored=len(alive),
                                  n_survivors=n_surv, wall_s=wall,
                                  engine_compiles=compiles))
        if idx + 1 < len(schedule):
            alive = [mbr for mbr, k in zip(alive, keep) if k]
    return SearchResult(frontier=result, members=alive,
                        rungs=tuple(reports), scored_trials=scored,
                        exhaustive_trials=n0 * schedule[-1].trials)


# ---------------------------------------------------------------------------
# Engine-backed front door.
# ---------------------------------------------------------------------------

def search(systems: Sequence, *, final_trials: int = 1_000_000,
           schedule: Optional[Sequence[Rung]] = None,
           n: Optional[int] = None, k_proposers: int = 2,
           delta_ms: Optional[float] = None, delay=None,
           chunk: Optional[int] = None, precision: Optional[float] = None,
           shard: bool = False, use_kernel: bool = False, k_max="auto",
           seed: int = 0, slack: float = DEFAULT_SLACK,
           regimes=None, recovery: str = "coordinated",
           cache=None) -> SearchResult:
    """Successive-halving search through the streamed scorer.

    ``systems`` is any mix of ``frontier.families.Member``, quorum
    systems, or raw masks (the same front door as ``score_systems``); the
    scorer runs every rung through ``planner.cache.EngineCache`` so repeat
    table geometries re-enter warm compiles (pass ``cache`` to share the
    pool across searches — the planner service does).  All rungs score
    with the SAME seed/chunk/precision, so the final rung's per-system
    values are bit-identical to a direct ``score_systems`` call over the
    full space at ``final_trials`` — the search changes *which* systems
    get the full budget, never their scores.
    """
    from repro.frontier import score as fscore
    from .cache import EngineCache

    if schedule is None:
        schedule = default_schedule(final_trials, slack=slack)
    cache = cache if cache is not None else EngineCache()
    kwargs = dict(
        n=n, k_proposers=k_proposers,
        delta_ms=(delta_ms if delta_ms is not None
                  else fscore.DEFAULT_DELTA_MS),
        delay=delay,
        chunk=chunk if chunk is not None else fscore.DEFAULT_CHUNK,
        precision=precision, shard=shard, use_kernel=use_kernel,
        k_max=k_max, seed=seed, regimes=regimes, recovery=recovery)
    scorer = lambda members, trials: cache.score(members, trials=trials,
                                                 **kwargs)
    return successive_halving(list(systems), schedule, scorer)
