"""CLI for the planner service (DESIGN.md §11).

  python -m repro.planner serve [--host H] [--port P] [--batch-window S]
      run the persistent search-and-serve process; prints one
      ``planner: listening on H:P`` line once the socket is bound
      (``--port 0`` picks a free port — watch that line for the choice).

  python -m repro.planner query [--host H] [--port P] (--json '{...}' |
      query flags)
      send one JSON request to a running server and print the reply.
      ``--op stats|ping|shutdown`` for the control verbs.

  python -m repro.planner plan (query flags)
      one-shot in-process planning — same query surface, no server.

Query flags (query/plan): --n, --family, --trials, --objective,
--faults FAST,PHASE1,CLASSIC, --workload-k, --workload-delta-ms,
--chunk, --precision, --seed.
"""
from __future__ import annotations

import argparse
import json
import sys


def _add_query_flags(p: argparse.ArgumentParser) -> None:
    p.add_argument("--n", type=int, default=11)
    p.add_argument("--family", default="cardinality",
                   help="cardinality | grid | weighted | all")
    p.add_argument("--trials", type=int, default=None,
                   help="final successive-halving budget "
                        "(default 10^6; 10^5 with --quick)")
    p.add_argument("--objective", default="race_p999_ms",
                   help="race_p999_ms | fast_p50_ms | p_recovery")
    p.add_argument("--faults", default="0,0,0", metavar="F,P1,C",
                   help="minimum crash budgets fast,phase1,classic")
    p.add_argument("--workload-k", type=int, default=2,
                   help="racing proposers (race workload)")
    p.add_argument("--workload-delta-ms", type=float, default=0.2)
    p.add_argument("--chunk", type=int, default=None)
    p.add_argument("--precision", type=float, default=None)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--quick", action="store_true",
                   help="10^5 final trials (smoke scale)")


def _query_dict(args) -> dict:
    try:
        f_fast, f_p1, f_classic = (int(x) for x in args.faults.split(","))
    except ValueError:
        raise SystemExit(f"--faults wants FAST,PHASE1,CLASSIC integers, "
                         f"got {args.faults!r}")
    trials = args.trials
    if trials is None:
        trials = 100_000 if args.quick else 1_000_000
    q = {"n": args.n, "family": args.family, "trials": trials,
         "objective": args.objective,
         "faults": {"fast": f_fast, "phase1": f_p1, "classic": f_classic},
         "workload": {"kind": "race", "k": args.workload_k,
                      "delta_ms": args.workload_delta_ms},
         "seed": args.seed}
    if args.chunk is not None:
        q["chunk"] = args.chunk
    if args.precision is not None:
        q["precision"] = args.precision
    return q


def _print_result(r: dict) -> None:
    print(json.dumps(r, indent=2, sort_keys=True, default=float))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.planner",
                                 description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    s = sub.add_parser("serve", help="run the persistent planner service")
    s.add_argument("--host", default="127.0.0.1")
    s.add_argument("--port", type=int, default=None,
                   help=f"default {7421}; 0 picks a free port")
    s.add_argument("--batch-window", type=float, default=0.05,
                   help="seconds to let concurrent requests batch")

    q = sub.add_parser("query", help="query a running planner")
    q.add_argument("--host", default="127.0.0.1")
    q.add_argument("--port", type=int, default=None)
    q.add_argument("--op", default="plan",
                   help="plan | stats | ping | shutdown")
    q.add_argument("--json", dest="json_query", default=None,
                   help="full JSON request (overrides the query flags)")
    _add_query_flags(q)

    p = sub.add_parser("plan", help="one-shot in-process planning")
    _add_query_flags(p)

    args = ap.parse_args(argv)

    if args.cmd == "serve":
        from .service import DEFAULT_PORT, PlannerServer
        port = args.port if args.port is not None else DEFAULT_PORT
        server = PlannerServer(host=args.host, port=port,
                               batch_window_s=args.batch_window)
        print(f"planner: listening on {server.host}:{server.port}",
              flush=True)
        try:
            server.serve_forever()
        except KeyboardInterrupt:
            server.shutdown()
        return 0

    if args.cmd == "query":
        from .service import DEFAULT_PORT, query_server
        port = args.port if args.port is not None else DEFAULT_PORT
        if args.json_query is not None:
            payload = json.loads(args.json_query)
        elif args.op != "plan":
            payload = {"op": args.op}
        else:
            payload = {"op": "plan", **_query_dict(args)}
        reply = query_server(payload, host=args.host, port=port)
        _print_result(reply)
        return 0 if reply.get("ok") else 1

    # plan: in-process one-shot
    from .service import Planner
    result = Planner().plan(_query_dict(args))
    _print_result(result.to_dict())
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
