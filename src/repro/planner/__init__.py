"""repro.planner: successive-halving quorum search and a persistent
search-and-serve planner (DESIGN.md §11).

Three layers, importable separately:

  search    plain-data rung schedules + margin-dominance pruning +
            the ``successive_halving`` loop (no JAX in the control flow)
  cache     ``EngineCache`` — warm compiled-engine pool keyed by scoring
            geometry, with a content-fingerprint result memo
  service   ``Planner`` (in-process), ``PlannerServer`` (JSON lines over
            TCP, batched by geometry), ``query_server`` client

CLI: ``python -m repro.planner serve | query | plan``.
"""
from .cache import EngineCache, EngineKey, engine_key, trace_total
from .search import (Rung, RungReport, SearchResult, default_schedule,
                     prune_survivors, search, successive_halving)
from .service import (PlanQuery, PlanResult, Planner, PlannerServer,
                      query_server, resolve_workload)

__all__ = [
    "EngineCache", "EngineKey", "engine_key", "trace_total",
    "Rung", "RungReport", "SearchResult", "default_schedule",
    "prune_survivors", "search", "successive_halving",
    "PlanQuery", "PlanResult", "Planner", "PlannerServer",
    "query_server", "resolve_workload",
]
