"""Search-and-serve planner: queries in, recommended quorum systems out.

The long-lived half of DESIGN.md §11.  A ``Planner`` holds one
``EngineCache`` (warm compiles + score memo) and an LRU of finished
``SearchResult``s keyed by search *geometry* — everything that determines
which systems get scored and how (n, family, workload, trial budget,
engine knobs), deliberately EXCLUDING the fault budget and the objective:
two queries that differ only in how they rank the frontier share one
search, one mask-table compile, one frontier.

  Planner.plan(query)        in-process front door (``api.plan`` and
                             ``Experiment.plan`` land here)
  Planner.plan_group([...])  one search answering many queries — the
                             batching primitive the server uses
  PlannerServer              JSON-lines-over-TCP wrapper: a single worker
                             thread drains the request queue in small
                             windows, groups concurrent requests by
                             geometry, and answers each with its own
                             fault-budget/objective ranking
  query_server               client helper (the CLI's ``query`` verb)

A query names a *minimum* crash-budget triple; filtering only the
frontier for it is complete — any valid system meeting the budget is
dominated by (or is) a frontier member whose maximize axes are at least
as large, hence also meeting the budget.

Every response carries ``engine_compiles`` — the number of fresh engine
traces this query caused — so callers (and the CI smoke job) can assert
that a repeat same-geometry query is answered entirely from warm state.
"""
from __future__ import annotations

import json
import socket
import socketserver
import threading
import time
from collections import OrderedDict
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.quorum import QuorumSpec

from .cache import EngineCache, _delay_token
from .search import (DEFAULT_SLACK, Rung, SearchResult, default_schedule,
                     search)

DEFAULT_PORT = 7421
DEFAULT_TRIALS = 1_000_000
_OBJECTIVES = ("race_p999_ms", "fast_p50_ms", "p_recovery")


# ---------------------------------------------------------------------------
# Query / result records (JSON in, JSON out).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PlanQuery:
    """One planning request.

    ``workload`` is a ``Workload`` (in-process) or, over the wire, any
    dict ``Workload.from_dict`` accepts: the ``{"kind": ...}`` constructor
    shorthand (``{"kind": "race", "k": 3, "delta_ms": 0.5}``,
    ``{"kind": "wan", "inter_region_ms": 30.0}``) or a full serialized
    ``Workload.to_dict()`` — trace-driven delays and Markov regime chains
    included.  ``faults`` is the
    minimum crash-budget triple the recommendation must satisfy:
    ``{"fast": 1, "phase1": 2, "classic": 2}`` (missing keys default 0).
    ``objective`` ranks the budget-satisfying frontier members:
    one of ``race_p999_ms`` (default), ``fast_p50_ms``, ``p_recovery``
    (all minimized).  ``trials`` is the FINAL successive-halving budget;
    the schedule below it is derived (``search.default_schedule``) unless
    ``schedule`` pins explicit ``[trials, slack]`` rungs.
    """

    n: int = 11
    family: str = "cardinality"       # a families.FAMILIES name, or "all"
    workload: object = None
    faults: Dict[str, int] = field(default_factory=dict)
    trials: int = DEFAULT_TRIALS
    objective: str = "race_p999_ms"
    schedule: Optional[Tuple[Tuple[int, float], ...]] = None
    chunk: Optional[int] = None
    precision: Optional[float] = None
    seed: int = 0
    shard: bool = False
    use_kernel: bool = False
    k_max: object = "auto"
    slack: float = DEFAULT_SLACK

    def __post_init__(self) -> None:
        if self.n < 1:
            raise ValueError(f"n must be >= 1, got {self.n}")
        if self.trials < 1:
            raise ValueError(f"trials must be >= 1, got {self.trials}")
        if self.objective not in _OBJECTIVES:
            raise ValueError(f"unknown objective {self.objective!r}; "
                             f"pick one of {_OBJECTIVES}")
        unknown = set(self.faults) - {"fast", "phase1", "classic"}
        if unknown:
            raise ValueError(f"unknown fault-budget keys {sorted(unknown)}; "
                             f"use fast/phase1/classic")
        if self.schedule is not None:
            object.__setattr__(self, "schedule", tuple(
                (int(t), float(s)) for t, s in self.schedule))

    @classmethod
    def from_dict(cls, d: Dict) -> "PlanQuery":
        known = {f for f in cls.__dataclass_fields__}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown query fields {sorted(unknown)}; "
                             f"known: {sorted(known)}")
        return cls(**d)


def resolve_workload(workload):
    """None / ``Workload`` / workload dict -> a ``Workload``.

    Dicts take either form ``Workload.from_dict`` accepts: the
    ``{"kind": ...}`` constructor shorthand (``{"kind": "race", "k": 3}``)
    or a full serialized ``Workload.to_dict()`` — so WAN placements, lossy
    links, trace-driven delays and regime chains all travel over the
    planner socket as plain JSON.  The default is the standard frontier
    race (2-way, Δ=0.2 ms) — the geometry PR 5's sweep and the scorer's
    tail axes assume."""
    from repro.api.experiment import Workload
    from repro.frontier import score as fscore

    if workload is None:
        return Workload.race(k=2, delta_ms=fscore.DEFAULT_DELTA_MS)
    if isinstance(workload, Workload):
        return workload
    if not isinstance(workload, dict):
        raise TypeError(f"workload must be a Workload or a dict, "
                        f"got {type(workload).__name__}")
    return Workload.from_dict(workload)


@dataclass
class PlanResult:
    """One planning answer (JSON-ready via ``to_dict``).

    ``ok`` False means no frontier member met the fault budget (``reason``
    says so); otherwise ``recommended`` names the winning system,
    ``system`` describes it (cardinality triples carry (q1, q2c, q2f)),
    ``predicted_ms`` the fast-path p50 and race-path p99.9 / p99.99,
    ``fault_tolerance`` the crash-budget triple, ``alternatives`` the
    other budget-satisfying frontier members, and ``search`` the halving
    telemetry (budget fraction, rungs, compile counts).  ``cold`` is
    whether this query had to run the search (vs. a warm geometry hit);
    ``engine_compiles`` the fresh engine traces it caused.
    """

    ok: bool
    recommended: Optional[str] = None
    system: Dict = field(default_factory=dict)
    predicted_ms: Dict[str, float] = field(default_factory=dict)
    p_recovery: Optional[float] = None
    fault_tolerance: Dict[str, int] = field(default_factory=dict)
    alternatives: List[str] = field(default_factory=list)
    frontier_labels: List[str] = field(default_factory=list)
    search: Dict[str, float] = field(default_factory=dict)
    cold: bool = True
    engine_compiles: int = 0
    wall_s: float = 0.0
    reason: str = ""

    def to_dict(self) -> Dict:
        return asdict(self)


def _describe_system(member) -> Dict:
    system = getattr(member, "system", member)
    out = {"label": getattr(member, "label", "") or "",
           "type": type(system).__name__}
    if isinstance(system, QuorumSpec):
        out.update(n=system.n, q1=system.q1, q2c=system.q2c,
                   q2f=system.q2f)
    return out


# ---------------------------------------------------------------------------
# The in-process planner.
# ---------------------------------------------------------------------------

class Planner:
    """Search-and-serve core: one engine cache, one search LRU, no sockets.

    Thread-safe for the server's single worker thread + stats readers; the
    search lock serializes plan_group so concurrent in-process callers
    cannot duplicate a search.
    """

    def __init__(self, engines: Optional[EngineCache] = None,
                 search_cache_size: int = 16):
        self.engines = engines if engines is not None else EngineCache()
        self.search_cache_size = search_cache_size
        self._searches: "OrderedDict[tuple, SearchResult]" = OrderedDict()
        self._lock = threading.Lock()
        self.search_hits = 0
        self.search_misses = 0

    # -- geometry ----------------------------------------------------------
    def geometry_key(self, q: PlanQuery) -> tuple:
        """Everything that determines which systems get scored and how —
        fault budget and objective deliberately excluded, so queries that
        only rank differently share one search."""
        wl = resolve_workload(q.workload)
        racing = wl.k_proposers >= 2
        from repro.frontier import score as fscore
        from repro.montecarlo import streaming
        k_eff = wl.k_proposers if racing else 2
        d_eff = wl.delta_ms if racing else fscore.DEFAULT_DELTA_MS
        # None knobs resolve to the scorer's defaults before keying, so a
        # query spelling the default explicitly still shares the search
        chunk = q.chunk if q.chunk is not None else fscore.DEFAULT_CHUNK
        precision = (q.precision if q.precision is not None
                     else streaming.DEFAULT_PRECISION)
        return (q.n, q.family, k_eff, d_eff,
                _delay_token(wl.delay_for(q.n)),
                _delay_token(wl.regimes_for(q.n)),
                q.trials, q.schedule,
                chunk, precision, q.seed, bool(q.shard), q.use_kernel,
                repr(q.k_max), q.slack, wl.recovery)

    # -- planning ----------------------------------------------------------
    def plan(self, query=None, **kw) -> PlanResult:
        """Answer one query (a ``PlanQuery``, a dict, or keyword fields)."""
        if query is None:
            query = PlanQuery(**kw)
        elif isinstance(query, dict):
            query = PlanQuery.from_dict(query)
        return self.plan_group([query])[0]

    def plan_group(self, queries: Sequence[PlanQuery]) -> List[PlanResult]:
        """Answer a batch of same-geometry queries with ONE search (hence
        one mask-table compile set).  Raises if geometries differ — the
        server groups before calling."""
        if not queries:
            return []
        keys = [self.geometry_key(q) for q in queries]
        if len(set(keys)) != 1:
            raise ValueError("plan_group needs same-geometry queries; "
                             "group by Planner.geometry_key first")
        t0 = time.perf_counter()
        with self._lock:
            sr, cold, compiles = self._search_for(queries[0], keys[0])
        wall = time.perf_counter() - t0
        out = []
        for i, q in enumerate(queries):
            r = self._recommend(q, sr)
            r.cold = cold
            # the one cold search's compiles are attributed to the first
            # query of the batch; everyone else rode along for free
            r.engine_compiles = compiles if (cold and i == 0) else 0
            r.wall_s = wall if i == 0 else 0.0
            out.append(r)
        return out

    def _search_for(self, q: PlanQuery,
                    gkey: tuple) -> Tuple[SearchResult, bool, int]:
        hit = self._searches.get(gkey)
        if hit is not None:
            self._searches.move_to_end(gkey)
            self.search_hits += 1
            return hit, False, 0
        self.search_misses += 1
        from repro.frontier import families
        members = (families.all_families(q.n) if q.family == "all"
                   else families.family(q.family, q.n))
        wl = resolve_workload(q.workload)
        racing = wl.k_proposers >= 2
        from repro.frontier import score as fscore
        schedule = (tuple(Rung(t, s) for t, s in q.schedule)
                    if q.schedule is not None else
                    default_schedule(q.trials, slack=q.slack))
        sr = search(
            members, final_trials=q.trials, schedule=schedule, n=q.n,
            k_proposers=wl.k_proposers if racing else 2,
            delta_ms=wl.delta_ms if racing else fscore.DEFAULT_DELTA_MS,
            delay=wl.delay_for(q.n), chunk=q.chunk, precision=q.precision,
            shard=q.shard, use_kernel=q.use_kernel, k_max=q.k_max,
            seed=q.seed, slack=q.slack, regimes=wl.regimes_for(q.n),
            recovery=wl.recovery, cache=self.engines)
        self._searches[gkey] = sr
        while len(self._searches) > self.search_cache_size:
            self._searches.popitem(last=False)
        return sr, True, sum(r.engine_compiles for r in sr.rungs)

    def _recommend(self, q: PlanQuery, sr: SearchResult) -> PlanResult:
        from repro.frontier.score import AXIS_NAMES
        fr = sr.frontier
        vals = np.asarray(fr.values, np.float64)
        names = list(fr.axis_names)
        col = {a: names.index(a) for a in AXIS_NAMES}
        need = (q.faults.get("fast", 0), q.faults.get("phase1", 0),
                q.faults.get("classic", 0))
        eligible = [i for i in fr.frontier_indices
                    if vals[i, col["ft_fast"]] >= need[0]
                    and vals[i, col["ft_phase1"]] >= need[1]
                    and vals[i, col["ft_classic"]] >= need[2]]
        base = PlanResult(ok=False,
                          frontier_labels=list(fr.frontier_labels),
                          search=sr.to_dict())
        if not eligible:
            base.reason = (f"no frontier system tolerates "
                           f"fast>={need[0]}, phase1>={need[1]}, "
                           f"classic>={need[2]} crashes at n={q.n} "
                           f"(family={q.family}); relax the budget or "
                           f"grow the cluster")
            return base
        obj = col[q.objective]
        # deterministic ranking: objective, then the other two stochastic
        # axes, then label (NaN — never decided — sorts last)
        rank_cols = [obj] + [col[a] for a in
                             ("race_p999_ms", "fast_p50_ms", "p_recovery")
                             if col[a] != obj]

        def rank(i):
            vs = [vals[i, c] for c in rank_cols]
            return tuple(np.inf if np.isnan(v) else v for v in vs) \
                + (fr.labels[i],)

        best = min(eligible, key=rank)
        race = fr.streams["race"] if fr.streams else None
        p9999 = (float(np.asarray(race.quantile(0.9999))[best])
                 if race is not None else float("nan"))
        base.ok = True
        base.recommended = fr.labels[best]
        base.system = _describe_system(sr.members[best])
        base.predicted_ms = {
            "fast_p50": float(vals[best, col["fast_p50_ms"]]),
            "race_p999": float(vals[best, col["race_p999_ms"]]),
            "race_p9999": p9999,
        }
        base.p_recovery = float(vals[best, col["p_recovery"]])
        base.fault_tolerance = {
            "fast": int(vals[best, col["ft_fast"]]),
            "phase1": int(vals[best, col["ft_phase1"]]),
            "classic": int(vals[best, col["ft_classic"]]),
        }
        base.alternatives = [fr.labels[i] for i in eligible if i != best]
        return base

    # -- telemetry ---------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        from repro.montecarlo import engine
        out = {"search_hits": float(self.search_hits),
               "search_misses": float(self.search_misses),
               "searches_cached": float(len(self._searches))}
        out.update(self.engines.stats_dict())
        out["trace_counts"] = dict(engine.TRACE_COUNTS)
        return out


# ---------------------------------------------------------------------------
# The persistent service: JSON lines over TCP, batched by geometry.
# ---------------------------------------------------------------------------

@dataclass
class _Pending:
    query: PlanQuery
    gkey: tuple
    event: threading.Event = field(default_factory=threading.Event)
    response: Optional[Dict] = None

    def respond(self, payload: Dict) -> None:
        self.response = payload
        self.event.set()


class PlannerServer:
    """JSON-lines planner service.

    One line in, one line out per connection.  Ops:

      {"op": "plan", ...PlanQuery fields}   -> PlanResult dict
      {"op": "stats"}                       -> planner + engine telemetry
      {"op": "ping"}                        -> {"ok": true}
      {"op": "shutdown"}                    -> stops the server

    Plan requests enqueue to a single worker thread that drains the queue
    in ``batch_window_s`` windows and groups by search geometry — N
    concurrent same-geometry queries cost ONE search (one mask-table
    compile set), each answered under its own fault budget and objective.
    """

    def __init__(self, planner: Optional[Planner] = None,
                 host: str = "127.0.0.1", port: int = DEFAULT_PORT,
                 batch_window_s: float = 0.05):
        self.planner = planner if planner is not None else Planner()
        self.batch_window_s = batch_window_s
        self._pending: List[_Pending] = []
        self._pending_lock = threading.Lock()
        self._wake = threading.Event()
        self._stop = threading.Event()

        outer = self

        class Handler(socketserver.StreamRequestHandler):
            def handle(self) -> None:
                line = self.rfile.readline()
                if not line.strip():
                    return
                payload = outer._handle_line(line)
                self.wfile.write(json.dumps(payload).encode() + b"\n")

        class Server(socketserver.ThreadingTCPServer):
            allow_reuse_address = True
            daemon_threads = True

        self._server = Server((host, port), Handler)
        self.host, self.port = self._server.server_address[:2]
        self._worker = threading.Thread(target=self._drain, daemon=True,
                                        name="planner-worker")

    # -- lifecycle ---------------------------------------------------------
    def serve_forever(self) -> None:
        """Run until ``shutdown`` (op or call).  Blocks."""
        self._worker.start()
        try:
            self._server.serve_forever(poll_interval=0.1)
        finally:
            self.shutdown()

    def start(self) -> None:
        """Run in background threads (tests / embedding)."""
        self._worker.start()
        threading.Thread(target=self._server.serve_forever,
                         kwargs={"poll_interval": 0.1}, daemon=True,
                         name="planner-accept").start()

    def shutdown(self) -> None:
        if not self._stop.is_set():
            self._stop.set()
            self._wake.set()
            self._server.shutdown()
            self._server.server_close()

    # -- request handling --------------------------------------------------
    def _handle_line(self, line: bytes) -> Dict:
        try:
            msg = json.loads(line)
            op = msg.pop("op", "plan")
            if op == "ping":
                return {"ok": True, "op": "ping"}
            if op == "stats":
                return {"ok": True, **self.planner.stats()}
            if op == "shutdown":
                threading.Thread(target=self.shutdown, daemon=True).start()
                return {"ok": True, "op": "shutdown"}
            if op != "plan":
                return {"ok": False, "error": f"unknown op {op!r}"}
            query = PlanQuery.from_dict(msg)
            gkey = self.planner.geometry_key(query)
        except Exception as e:                  # malformed request
            return {"ok": False, "error": f"{type(e).__name__}: {e}"}
        item = _Pending(query, gkey)
        with self._pending_lock:
            self._pending.append(item)
        self._wake.set()
        item.event.wait()
        return item.response

    def _drain(self) -> None:
        """Single worker: collect a window of requests, group by geometry,
        one ``plan_group`` per group."""
        while not self._stop.is_set():
            self._wake.wait(timeout=0.1)
            self._wake.clear()
            with self._pending_lock:
                if not self._pending:
                    continue
            time.sleep(self.batch_window_s)     # let the batch accumulate
            with self._pending_lock:
                batch, self._pending = self._pending, []
            groups: "OrderedDict[tuple, List[_Pending]]" = OrderedDict()
            for it in batch:
                groups.setdefault(it.gkey, []).append(it)
            for items in groups.values():
                try:
                    results = self.planner.plan_group(
                        [it.query for it in items])
                    for it, r in zip(items, results):
                        it.respond({"ok": True, **r.to_dict()})
                except Exception as e:
                    for it in items:
                        it.respond({"ok": False,
                                    "error": f"{type(e).__name__}: {e}"})


def query_server(payload: Dict, host: str = "127.0.0.1",
                 port: int = DEFAULT_PORT, timeout_s: float = 600.0) -> Dict:
    """Send one JSON request line to a running planner and return the
    decoded response (the CLI's ``query`` verb)."""
    with socket.create_connection((host, port), timeout=timeout_s) as conn:
        conn.sendall(json.dumps(payload).encode() + b"\n")
        buf = b""
        while not buf.endswith(b"\n"):
            got = conn.recv(65536)
            if not got:
                break
            buf += got
    if not buf:
        raise ConnectionError("planner closed the connection w/o replying")
    return json.loads(buf)
