"""Unit tests of the protocol state machines and the IsPickableVal rule."""
import pytest

from repro.core.protocol import (ANY, NONE, Acceptor, Coordinator, Learner,
                                 Phase1a, Phase1b, Phase2a, Phase2b,
                                 RoundSystem, choose_value, p2b_to_p1b,
                                 pick_values)
from repro.core.quorum import QuorumSpec


def rs11():
    return RoundSystem(QuorumSpec.paper_headline(11), fast_rounds="odd")


# ---------------------------------------------------------------------------
# pick_values (TLA+ IsPickableVal).
# ---------------------------------------------------------------------------

def test_pick_k0_classic_round_offers_proposed():
    rs = rs11()
    msgs = [Phase1b(2, 0, ANY, a) for a in range(9)]
    picks = pick_values(rs, 2, msgs, {"a", "b"})
    assert picks == {"a", "b"}       # no ANY in classic rounds is enforced
    assert ANY not in pick_values(rs, 2, msgs, {"a"})


def test_pick_k0_fast_round_offers_any():
    rs = rs11()
    msgs = [Phase1b(3, 0, ANY, a) for a in range(9)]
    picks = pick_values(rs, 3, msgs, {"a"})
    assert ANY in picks and "a" in picks


def test_pick_single_value_must_be_chosen():
    rs = rs11()
    msgs = [Phase1b(2, 1, "v", a) for a in range(3)] + \
           [Phase1b(2, 0, ANY, a) for a in range(3, 9)]
    assert pick_values(rs, 2, msgs, {"x"}) == {"v"}


def test_pick_o4_elimination():
    """Paper §4 Property 3: with q1=9, q2f=7 on n=11, a value voted by 5
    in-quorum acceptors (5 + 2 outside = 7 >= q2f) passes O4; a value voted
    by 2 (2 + 2 < 7) is eliminated."""
    rs = rs11()
    msgs = ([Phase1b(2, 1, "A", a) for a in range(5)]
            + [Phase1b(2, 1, "B", a) for a in range(5, 7)]
            + [Phase1b(2, 0, ANY, a) for a in range(7, 9)])
    picks = pick_values(rs, 2, msgs, {"A", "B"})
    assert picks == {"A"}


def test_pick_no_o4_winner_falls_back_to_proposed():
    rs = rs11()
    # 3/3 split with 3 unheard: 3+2=5 < 7 for both -> neither decidable.
    msgs = ([Phase1b(2, 1, "A", a) for a in range(3)]
            + [Phase1b(2, 1, "B", a) for a in range(3, 6)]
            + [Phase1b(2, 0, ANY, a) for a in range(6, 9)])
    picks = pick_values(rs, 2, msgs, {"A", "B", "C"})
    assert picks == {"A", "B", "C"}  # free choice — nothing was decided


def test_choose_value_deterministic():
    assert choose_value({"b", "a"}) == "a"
    assert choose_value({ANY, "z"}) == "z"
    assert choose_value({ANY}) == ANY


# ---------------------------------------------------------------------------
# Acceptor.
# ---------------------------------------------------------------------------

def test_acceptor_promise_monotone():
    a = Acceptor(0, rs11())
    assert a.on_phase1a(Phase1a(3)) == Phase1b(3, 0, ANY, 0)
    assert a.on_phase1a(Phase1a(2)) is None      # smaller round refused
    assert a.rnd == 3


def test_acceptor_vote_and_refuse():
    a = Acceptor(0, rs11())
    out = a.on_phase2a(Phase2a(1, "v"))
    assert out == Phase2b(1, "v", 0)
    assert (a.rnd, a.vrnd, a.vval) == (1, 1, "v")
    assert a.on_phase2a(Phase2a(1, "w")) is None  # already voted this round


def test_acceptor_any_vote_uses_client_value():
    a = Acceptor(0, rs11())
    assert a.on_phase2a(Phase2a(1, ANY), proposed_val="c") == Phase2b(1, "c", 0)
    assert a.on_phase2a(Phase2a(1, ANY), proposed_val=None) is None


def test_acceptor_last_msg():
    a = Acceptor(0, rs11())
    a.on_phase1a(Phase1a(2))
    assert a.last_msg() == Phase1b(2, 0, ANY, 0)
    a.on_phase2a(Phase2a(3, "v"))
    assert a.last_msg() == Phase2b(3, "v", 0)


# ---------------------------------------------------------------------------
# Coordinator + Learner end-to-end (in-memory happy paths).
# ---------------------------------------------------------------------------

def test_classic_round_end_to_end():
    rs = RoundSystem(QuorumSpec.paper_headline(11), fast_rounds="none")
    acceptors = [Acceptor(i, rs) for i in range(11)]
    c = Coordinator(0, rs)
    learner = Learner(rs)

    m1a = c.start_round(2)
    assert m1a == Phase1a(2)
    for a in acceptors:
        m = a.on_phase1a(m1a)
        if m:
            c.on_phase1b(m)
    m2a = c.try_phase2a({"v"})
    assert m2a is not None and m2a.val == "v"
    decided = None
    for a in acceptors:
        m = a.on_phase2a(m2a)
        if m:
            decided = learner.on_phase2b(m) or decided
    assert decided == "v"


def test_fast_round_collision_and_coordinated_recovery():
    rs = rs11()
    acceptors = [Acceptor(i, rs) for i in range(11)]
    c = Coordinator(0, rs)
    c.crnd, c.cval = 1, ANY          # steady state: ANY already sent
    learner = Learner(rs)
    # split vote 5/6 — 6 < q2f=7: no fast decision
    for i, a in enumerate(acceptors):
        v = "A" if i < 5 else "B"
        m = a.on_phase2a(Phase2a(1, ANY), proposed_val=v)
        learner.on_phase2b(m)
        c.on_phase2b(m)
    assert not learner.learned
    assert learner.collision_suspected(1)
    m2a = c.coordinated_recovery({"A", "B"})
    assert m2a is not None and m2a.rnd == 2
    # B had 6 votes: 6 + 2 outside any 9-quorum >= 7 -> B passes O4.
    assert m2a.val == "B"
    decided = None
    for a in acceptors:
        m = a.on_phase2a(m2a)
        if m:
            decided = learner.on_phase2b(m) or decided
    assert decided == "B"


def test_p2b_to_p1b():
    msgs = [Phase2b(1, "v", 3), Phase2b(2, "w", 4)]
    out = p2b_to_p1b(msgs, 1)
    assert out == [Phase1b(2, 1, "v", 3)]
