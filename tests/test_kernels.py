"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU), swept over
shapes and dtypes per the assignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.quorum_tally import ops as qt_ops, ref as qt_ref
from repro.kernels.rmsnorm import ops as rn_ops, ref as rn_ref
from repro.kernels.ssd_scan import ops as ssd_ops, ref as ssd_ref

KEY = jax.random.PRNGKey(0)

from repro.montecarlo.streaming import sketch_bins
_BINS = sketch_bins(0.01)


# ---------------------------------------------------------------------------
# quorum_tally
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,n,V", [(100, 11, 2), (1024, 11, 3), (3000, 7, 2),
                                   (5000, 32, 5)])
def test_quorum_tally_shapes(S, n, V):
    votes = jax.random.randint(KEY, (S, n), 0, V)
    np.testing.assert_array_equal(np.asarray(qt_ops.tally_votes(votes, V)),
                                  np.asarray(qt_ref.tally_votes(votes, V)))


@settings(max_examples=20, deadline=None)
@given(S=st.integers(1, 300), n=st.integers(1, 24), V=st.integers(1, 4),
       q=st.integers(1, 12))
def test_quorum_tally_property(S, n, V, q):
    votes = jax.random.randint(jax.random.PRNGKey(S * 31 + n), (S, n), 0, V)
    kq = qt_ops.quorum_reached(votes, V, q)
    rq = qt_ref.quorum_reached(votes, V, q)
    np.testing.assert_array_equal(np.asarray(kq), np.asarray(rq))


@pytest.mark.parametrize("V", [2, 3, 4])
@pytest.mark.parametrize("S,n,q", [(100, 11, 7), (2049, 11, 9), (500, 7, 4)])
def test_quorum_tally_decide_fused(S, n, q, V):
    """Fused tally+decide kernel vs its pure-jnp oracle for K values."""
    votes = jax.random.randint(jax.random.PRNGKey(S + V), (S, n), 0, V)
    kc, kw, km, kr = qt_ops.tally_decide(votes, V, jnp.int32(q))
    rc, rw, rm, rr = qt_ref.tally_decide(votes, V, q)
    np.testing.assert_array_equal(np.asarray(kc), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(kw), np.asarray(rw))
    np.testing.assert_array_equal(np.asarray(km), np.asarray(rm))
    np.testing.assert_array_equal(np.asarray(kr), np.asarray(rr))


def test_quorum_tally_decide_ignores_missing_votes():
    """Entries of -1 (acceptor never voted) count toward no value."""
    votes = jnp.array([[0, 1, -1, -1, 0], [-1, -1, -1, -1, -1]], jnp.int32)
    counts, winner, max_cnt, reached = qt_ops.tally_decide(votes, 2,
                                                           jnp.int32(2))
    np.testing.assert_array_equal(np.asarray(counts), [[2, 1], [0, 0]])
    np.testing.assert_array_equal(np.asarray(max_cnt), [2, 0])
    assert int(winner[0]) == 0
    assert bool(reached[0]) and not bool(reached[1])


# ---------------------------------------------------------------------------
# masked tally (general quorum systems)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("G", [1, 4, 12])
@pytest.mark.parametrize("S,n,V", [(257, 9, 2), (1100, 11, 3), (100, 6, 4)])
def test_masked_tally_kernel_vs_ref(S, n, V, G):
    """Kernel vs jnp oracle over random weights/thresholds, including no-vote
    -1 entries and (for G >= 4) an all-padding quorum row that must never be
    satisfied."""
    kv, kw, kt = jax.random.split(jax.random.PRNGKey(S * 7 + G), 3)
    votes = jax.random.randint(kv, (S, n), -1, V)        # -1 = no vote
    w = jax.random.randint(kw, (G, n), 0, 4).astype(jnp.float32)
    t = jax.random.randint(kt, (G,), 1, n + 2).astype(jnp.float32)
    if G >= 4:                                           # all-padding row
        w = w.at[-1].set(0.0)
        t = t.at[-1].set(float(2 ** 30))
    got = qt_ops.masked_tally(votes, w, t, V)
    want = qt_ref.masked_tally(votes, w, t, V)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    if G >= 4:
        assert bool((got[:, -1] == -1).all())            # padding inert


def test_masked_tally_explicit_grid_rows():
    """Deterministic check on the §6 grid: a fast row-pair quorum is
    satisfied only when every member votes the same value."""
    from repro.core.quorum import ExplicitQuorumSystem
    masks = ExplicitQuorumSystem.grid(3).to_masks()      # n=9, fast = 2 rows
    w, t = jnp.asarray(masks.p2f_w), jnp.asarray(masks.p2f_t)
    rows01 = [0, 1, 2, 3, 4, 5]
    votes = np.full((3, 9), -1, np.int32)
    votes[0, rows01] = 1                                 # rows 0+1 vote v1
    votes[1, rows01] = 1
    votes[1, 3] = 0                                      # one defector
    votes[2, :] = 0                                      # unanimous v0
    got = np.asarray(qt_ops.masked_tally(jnp.asarray(votes), w, t, 2))
    want = np.asarray(qt_ref.masked_tally(jnp.asarray(votes), w, t, 2))
    np.testing.assert_array_equal(got, want)
    assert got[0].max() == 1 and (got[0] >= 0).sum() == 1   # exactly {0,1}
    assert (got[1] == -1).all()                             # defector breaks
    assert (got[2] == 0).all()                              # every pair


def test_masked_tally_lowest_value_wins_ties():
    """When a (non-FFP) row is satisfiable by two values at once, the kernel
    must report the smallest value id, matching the oracle."""
    votes = jnp.array([[0, 0, 1, 1]], jnp.int32)
    w = jnp.ones((1, 4), jnp.float32)
    t = jnp.array([2.0], jnp.float32)
    got = qt_ops.masked_tally(votes, w, t, 2)
    want = qt_ref.masked_tally(votes, w, t, 2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(got[0, 0]) == 0


# ---------------------------------------------------------------------------
# fused streaming megakernel (selection network + masked tally + decide +
# block histogram) over a *raw* unsorted chunk
# ---------------------------------------------------------------------------

def _stream_inputs(seed: int, S: int, n: int, M: int, G: int, K: int):
    """Raw draw block + three-phase mask tables with *integral* f32 weights
    (the bit-identity contract of the selection network holds for integral
    weights — f32 partial sums are then exact in any order).  Arrival times
    are quantized to force ties, and ~10% of the 2b lanes sit at the LOST
    sentinel (crashed / never cast)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 9)
    und = 5e8
    votes = jax.random.randint(ks[0], (S, n), -1, K)
    arrive = jnp.floor(jnp.exp(jax.random.normal(ks[1], (S, n))) * 8.0) / 4.0
    classic = jnp.floor(jnp.exp(jax.random.normal(ks[2], (S, n))) * 8.0) / 4.0
    val_arr = jnp.floor(
        jnp.exp(jax.random.normal(ks[3], (S, K, n))) * 8.0) / 4.0 + 0.25
    lost = (votes[:, None, :] != jnp.arange(K)[None, :, None]) \
        | (jax.random.uniform(ks[4], (S, K, n)) < 0.1)
    val_arr = jnp.where(lost, jnp.float32(1e9), val_arr)
    masks = []
    for i, kk in enumerate(jax.random.split(ks[5], 3)):
        kw_, kt_ = jax.random.split(kk)
        w = jax.random.randint(kw_, (M, G, n), 0, 3).astype(jnp.float32)
        t = jax.random.randint(kt_, (M, G), 1, n + 2).astype(jnp.float32)
        masks += [w, t]
    valid = (jnp.arange(S) < S - S // 7)      # trailing padding trials
    return (votes, val_arr, arrive, classic, *masks, valid), und


@pytest.mark.parametrize("S,n,M,G,K,k_sat", [
    (300, 11, 2, 3, 2, (4, 5, 6)),
    (1025, 9, 1, 6, 3, (9, 9, 9)),        # k = n: selection IS a full sort
    (513, 7, 3, 1, 2, (2, 3, 2)),
    (700, 11, 4, 2, 2, (11, 1, 7)),       # mixed extreme depths
])
def test_stream_tally_decide_hist_kernel_vs_ref(S, n, M, G, K, k_sat):
    """Fused streaming megakernel vs jnp oracle across (M, chunk, k_sat)
    shapes: histogram and outcome counts bit-identical, float reductions
    (sum/max) to tolerance (the kernel accumulates block-by-block)."""
    args, und = _stream_inputs(S * 13 + M, S, n, M, G, K)
    kw = dict(n_values=K, k_sat=k_sat, precision=0.01, bins=_BINS,
              undecided_ms=und)
    h_k, s_k = qt_ops.stream_tally_decide_hist(*args, **kw)
    h_r, s_r = qt_ref.stream_tally_decide_hist(*args, **kw)
    np.testing.assert_array_equal(np.asarray(h_k), np.asarray(h_r))
    for f in ("n_fast", "n_recovery", "n_undecided"):
        np.testing.assert_array_equal(np.asarray(s_k[f]), np.asarray(s_r[f]),
                                      f)
    assert np.allclose(np.asarray(s_k["sum_ms"]), np.asarray(s_r["sum_ms"]),
                       rtol=1e-5)
    assert np.allclose(np.asarray(s_k["max_ms"]), np.asarray(s_r["max_ms"]))
    # accounting: histogram mass == decided == valid - undecided
    valid = args[-1]
    n_valid = int(np.asarray(valid).sum())
    per_sys = np.asarray(s_r["n_fast"]) + np.asarray(s_r["n_recovery"]) \
        + np.asarray(s_r["n_undecided"])
    np.testing.assert_array_equal(per_sys, np.full((M,), n_valid))
    np.testing.assert_array_equal(np.asarray(h_k).sum(-1),
                                  np.asarray(s_k["n_fast"])
                                  + np.asarray(s_k["n_recovery"]))


def test_stream_megakernel_depth_saturation_invariance():
    """Once every phase depth covers the table's saturation depths
    (``engine.saturation_depths``), the decide bits (and hence counts and
    histogram) stop depending on k_sat — deeper selection only re-extracts
    arrivals no quorum can still need."""
    from repro.montecarlo.engine import saturation_depths
    S, n, M, G, K = 400, 9, 2, 2, 2
    args, und = _stream_inputs(11, S, n, M, G, K)
    (w1, t1, w2c, t2c, w2f, t2f) = args[4:10]
    depths = saturation_depths({"p1_w": w1, "p1_t": t1, "p2c_w": w2c,
                                "p2c_t": t2c, "p2f_w": w2f, "p2f_t": t2f})
    kw = dict(n_values=K, precision=0.01, bins=_BINS, undecided_ms=und)
    h_a, s_a = qt_ref.stream_tally_decide_hist(*args, k_sat=depths, **kw)
    h_b, s_b = qt_ref.stream_tally_decide_hist(*args, k_sat=(n, n, n), **kw)
    np.testing.assert_array_equal(np.asarray(h_a), np.asarray(h_b))
    for f in ("n_fast", "n_recovery", "n_undecided"):
        np.testing.assert_array_equal(np.asarray(s_a[f]), np.asarray(s_b[f]))
    # and the kernel at the derived depths matches too
    h_k, s_k = qt_ops.stream_tally_decide_hist(*args, k_sat=depths, **kw)
    np.testing.assert_array_equal(np.asarray(h_k), np.asarray(h_a))
    for f in ("n_fast", "n_recovery", "n_undecided"):
        np.testing.assert_array_equal(np.asarray(s_k[f]), np.asarray(s_a[f]))


def test_stream_tally_decide_hist_all_invalid_block():
    """A fully padded chunk contributes nothing — counts zero, histogram
    empty, max at the -inf identity."""
    args, und = _stream_inputs(3, 128, 5, 1, 2, 2)
    args = args[:-1] + (jnp.zeros((128,), bool),)
    h, s = qt_ops.stream_tally_decide_hist(
        *args, n_values=2, k_sat=(3, 3, 3), precision=0.01, bins=_BINS,
        undecided_ms=und)
    assert int(np.asarray(h).sum()) == 0
    assert int(np.asarray(s["n_fast"]).sum()) == 0
    assert np.isneginf(np.asarray(s["max_ms"])).all()


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (B, H, KV, S, T, hd, causal, window, dtype)
    (2, 4, 2, 256, 256, 64, True, None, jnp.float32),
    (1, 8, 8, 128, 128, 128, True, None, jnp.float32),
    (1, 4, 1, 128, 128, 64, True, 64, jnp.float32),
    (2, 2, 2, 64, 512, 32, True, None, jnp.float32),     # decode-style S<T
    (1, 4, 2, 256, 256, 64, False, None, jnp.float32),
    (2, 4, 2, 256, 256, 64, True, None, jnp.bfloat16),
    (1, 2, 2, 128, 128, 256, True, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("B,H,KV,S,T,hd,causal,window,dtype", ATTN_CASES)
def test_flash_attention_vs_ref(B, H, KV, S, T, hd, causal, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, T, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, T, hd), dtype)
    out = fa_ops.attention(q, k, v, causal=causal, window=window,
                           block_q=64, block_k=64)
    exp = fa_ref.attention(q.astype(jnp.float32), k.astype(jnp.float32),
                           v.astype(jnp.float32), causal=causal,
                           window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    err = float(jnp.abs(out.astype(jnp.float32) - exp).max())
    assert err < tol, err


def test_flash_attention_block_shape_independent():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    outs = [fa_ops.attention(q, k, v, block_q=bq, block_k=bk)
            for bq, bk in [(64, 64), (128, 64), (256, 128), (64, 256)]]
    for o in outs[1:]:
        assert float(jnp.abs(o - outs[0]).max()) < 1e-5


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    (2, 128, 4, 16, 32, 32, jnp.float32),
    (1, 256, 8, 64, 128, 64, jnp.float32),
    (2, 64, 24, 64, 128, 64, jnp.float32),
    (1, 128, 4, 32, 64, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,nh,hd,ds,chunk,dtype", SSD_CASES)
def test_ssd_vs_recurrence(B, S, nh, hd, ds, chunk, dtype):
    ks = jax.random.split(KEY, 5)
    xw = (jax.random.normal(ks[0], (B, S, nh, hd)) * 0.5).astype(dtype)
    da = -jnp.abs(jax.random.normal(ks[1], (B, S, nh))) * 0.3
    Bm = jax.random.normal(ks[2], (B, S, ds)) * 0.5
    Cm = jax.random.normal(ks[3], (B, S, ds)) * 0.5
    s0 = jax.random.normal(ks[4], (B, nh, hd, ds)) * 0.1
    y1, f1 = ssd_ops.ssd(xw, da, Bm, Cm, chunk=chunk, init_state=s0)
    y2, f2 = ssd_ref.ssd(xw.astype(jnp.float32), da, Bm, Cm, init_state=s0)
    tol = 1e-3 if dtype == jnp.float32 else 3e-2
    assert float(jnp.abs(y1.astype(jnp.float32) - y2.astype(jnp.float32)).max()) < tol
    assert float(jnp.abs(f1 - f2).max()) < tol


def test_ssd_chunk_invariance():
    ks = jax.random.split(KEY, 4)
    B, S, nh, hd, ds = 1, 128, 2, 16, 16
    xw = jax.random.normal(ks[0], (B, S, nh, hd)) * 0.5
    da = -jnp.abs(jax.random.normal(ks[1], (B, S, nh))) * 0.3
    Bm = jax.random.normal(ks[2], (B, S, ds)) * 0.5
    Cm = jax.random.normal(ks[3], (B, S, ds)) * 0.5
    outs = [ssd_ops.ssd(xw, da, Bm, Cm, chunk=c)[0] for c in (16, 32, 64, 128)]
    for o in outs[1:]:
        assert float(jnp.abs(o - outs[0]).max()) < 1e-4


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,dtype", [
    ((4, 64, 256), jnp.float32),
    ((2, 100, 384), jnp.bfloat16),
    ((8, 300), jnp.float32),
    ((1, 7, 130), jnp.bfloat16),          # pad both rows and lanes
])
def test_rmsnorm_vs_ref(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    s = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],))
    out = rn_ops.rmsnorm(x, s)
    exp = rn_ref.rmsnorm(x, s)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    assert float(jnp.abs(out.astype(jnp.float32)
                         - exp.astype(jnp.float32)).max()) < tol


# ---------------------------------------------------------------------------
# kernels wired into the model paths
# ---------------------------------------------------------------------------

def test_ssd_kernel_inside_mamba_block():
    from repro.configs import get_config, reduced_config
    from repro.models.model import DecoderLM
    cfg = reduced_config(get_config("mamba2_130m"))
    toks = jax.random.randint(KEY, (1, 64), 0, cfg.vocab)
    m_ref = DecoderLM(cfg, remat=False, use_ssd_kernel=False)
    m_ker = DecoderLM(cfg, remat=False, use_ssd_kernel=True)
    params, _ = m_ref.init(jax.random.PRNGKey(0))
    l1 = m_ref.forward(params, {"tokens": toks}).astype(jnp.float32)
    l2 = m_ker.forward(params, {"tokens": toks}).astype(jnp.float32)
    assert float(jnp.abs(l1 - l2).max()) < 0.1
