"""Frontier subsystem tests (``repro.frontier``): property suite for the
dominance kernel, family-generator validity (intersection requirements +
model checking at small n), streamed-vs-materializing cross-validation,
the legacy per-spec reference containment, and the fixed-seed n=11
frontier anchor that makes silent frontier drift fail loudly."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from benchmarks.quorum_sweep import enumerate_valid, minimal_frontier
from repro.core.model_check import explore
from repro.core.quorum import (QuorumSpec, RelaxedQuorumSpec, ffp_card_ok,
                               ffp_min_q2c, relaxed_card_ok)
from repro.frontier import (Axis, FrontierResult, cardinality_family,
                            default_axes, dominates, grid_family,
                            maximal_mask, pareto_mask, quantize,
                            relaxed_family, score_systems, weighted_family)
from repro.montecarlo import build_mask_table, engine, streaming
from repro.montecarlo.streaming import StreamSummary

MIXED_AXES = (Axis("lat"), Axis("ft", maximize=True), Axis("rate"))


def _rand_values(seed: int, m: int, a: int = 3) -> np.ndarray:
    """Small integer grid so ties and duplicate vectors actually occur."""
    rng = np.random.RandomState(seed)
    return rng.randint(0, 5, size=(m, a)).astype(np.float64)


# ---------------------------------------------------------------------------
# dominance kernel properties
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10 ** 6), m=st.integers(1, 40))
def test_frontier_maximal_and_covering(seed, m):
    """No frontier point is dominated, and every excluded point is
    dominated by some *frontier* point (quantized dominance is a strict
    partial order, so chains terminate at maximal elements)."""
    v = _rand_values(seed, m)
    q = quantize(v, MIXED_AXES)
    mask = maximal_mask(q)
    assert mask.any()
    for i in range(m):
        if mask[i]:
            assert not any(dominates(q, j, i) for j in range(m))
        else:
            assert any(mask[j] and dominates(q, j, i) for j in range(m))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10 ** 6), m=st.integers(2, 40))
def test_frontier_invariant_under_permutation(seed, m):
    v = _rand_values(seed, m)
    mask = pareto_mask(v, MIXED_AXES)
    perm = np.random.RandomState(seed + 1).permutation(m)
    np.testing.assert_array_equal(pareto_mask(v[perm], MIXED_AXES),
                                  mask[perm])


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10 ** 6), m=st.integers(2, 30))
def test_frontier_invariant_under_duplicate_rows(seed, m):
    """Appending copies of existing rows changes no membership: ties never
    dominate each other, so a duplicate lands on the same side as its
    original."""
    v = _rand_values(seed, m)
    mask = pareto_mask(v, MIXED_AXES)
    dup = np.random.RandomState(seed + 2).randint(0, m, size=5)
    mask2 = pareto_mask(np.vstack([v, v[dup]]), MIXED_AXES)
    np.testing.assert_array_equal(mask2[:m], mask)
    np.testing.assert_array_equal(mask2[m:], mask[dup])


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10 ** 6), m=st.integers(2, 30))
def test_equal_quantized_vectors_share_membership(seed, m):
    """Epsilon quantization collapses ties: rows indistinguishable at the
    measurement's precision (equal quantized vectors) are kept or excluded
    together."""
    axes = (Axis("lat", eps=0.05, relative=True),
            Axis("ft", maximize=True),
            Axis("rate", eps=0.1))
    rng = np.random.RandomState(seed)
    v = np.stack([np.exp(rng.uniform(-1, 1, m)),
                  rng.randint(0, 3, m).astype(float),
                  rng.uniform(0, 1, m)], axis=1)
    q = quantize(v, axes)
    mask = pareto_mask(v, axes)
    for i in range(m):
        for j in range(m):
            if (q[i] == q[j]).all():
                assert mask[i] == mask[j]


def test_epsilon_collapses_within_sketch_error_ties():
    """A point worse by far less than the sketch's relative error must tie
    with (not be dominated by) the exact point once eps matches the sketch
    precision — and still be dominated with eps=0."""
    exact_axes = (Axis("lat"), Axis("ft", maximize=True))
    eps_axes = (Axis("lat", eps=0.01, relative=True),
                Axis("ft", maximize=True))
    v = np.array([[1.0, 3.0],
                  [1.002, 3.0]])     # 0.2% slower: inside 1% sketch error
    np.testing.assert_array_equal(pareto_mask(v, exact_axes),
                                  [True, False])
    np.testing.assert_array_equal(pareto_mask(v, eps_axes), [True, True])
    # well outside the sketch error the domination comes back
    v[1, 0] = 1.1
    np.testing.assert_array_equal(pareto_mask(v, eps_axes), [True, False])


def test_absolute_epsilon_on_rate_axis():
    axes = (Axis("rate", eps=0.01), Axis("ft", maximize=True))
    v = np.array([[0.500, 2.0], [0.502, 2.0], [0.520, 2.0]])
    mask = pareto_mask(v, axes)
    assert mask[0] and mask[1] and not mask[2]


def test_nan_scores_are_worst_on_any_orientation():
    """NaN (nothing decided) loses on minimize AND maximize axes, and an
    all-NaN batch still returns a frontier (all tied-worst)."""
    axes = (Axis("lat"), Axis("ft", maximize=True))
    v = np.array([[1.0, 2.0], [np.nan, 3.0], [1.0, np.nan]])
    q = quantize(v, axes)
    assert q[1, 0] == -np.inf and q[2, 1] == -np.inf
    mask = pareto_mask(v, axes)
    assert mask[0] and mask[1] and not mask[2]
    assert pareto_mask(np.full((3, 2), np.nan), axes).all()


def test_quantize_validates_shapes_and_axes():
    with pytest.raises(ValueError, match="axes"):
        quantize(np.zeros((3, 2)), MIXED_AXES)
    with pytest.raises(ValueError, match="eps"):
        Axis("bad", eps=-1.0)
    with pytest.raises(ValueError, match="relative"):
        Axis("bad", relative=True)


# ---------------------------------------------------------------------------
# family generators: validity + model checking at small n
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [4, 5, 7, 11])
def test_cardinality_family_is_the_full_valid_space(n):
    mem = cardinality_family(n)
    triples = {(m.system.q1, m.system.q2c, m.system.q2f) for m in mem}
    brute = {(q1, q2c, q2f)
             for q1 in range(1, n + 1) for q2c in range(1, n + 1)
             for q2f in range(1, n + 1) if ffp_card_ok(n, q1, q2c, q2f)}
    assert triples == brute
    assert len(mem) == len(triples)                  # no duplicates
    assert all(m.system.is_valid() for m in mem)
    labels = [m.label for m in mem]
    assert len(set(labels)) == len(labels)


def test_sweep_enumeration_matches_family():
    legacy = {(s.q1, s.q2c, s.q2f) for s in enumerate_valid(11)}
    fam = {(m.system.q1, m.system.q2c, m.system.q2f)
           for m in cardinality_family(11)}
    assert legacy == fam


def test_grid_family_valid_and_embedding_invariant_ft():
    mem = grid_family(12)
    assert [m.label for m in mem] == ["grid.3x1", "grid.3x2", "grid.3x3",
                                      "grid.3x4"]
    for m in mem:
        assert m.system.is_valid()                   # Eqs. 11/12 exactly
        ft = m.masks(12).fault_tolerance()
        # two crashes in distinct rows break every row-pair fast quorum
        assert ft["phase2_fast"] == 1
        # zero-weight embed acceptors never help a crash set kill a
        # quorum: budgets are embedding-invariant
        assert ft == m.masks(14).fault_tolerance()


def test_weighted_family_valid_weight_inequalities():
    for n in (5, 11):
        mem = weighted_family(n)
        assert mem
        for m in mem:
            w = m.system
            W = w.total
            assert w.t1 + w.t2c > W                  # Eq. 13, weight space
            assert w.t1 + 2 * w.t2f > 2 * W          # Eq. 14, weight space
            assert m.masks(n).n == n


@pytest.mark.parametrize("n,count", [(4, 7), (5, 13), (11, 125)])
def test_relaxed_family_is_the_relaxed_only_space(n, count):
    """``relaxed_family`` enumerates exactly the triples that satisfy the
    Relaxed Paxos predicate (Eq.14 alone) but NOT the FFP pair — the
    systems the joint frontier can only reach by relaxing intersection."""
    mem = relaxed_family(n)
    assert len(mem) == count
    triples = {(m.system.q1, m.system.q2c, m.system.q2f) for m in mem}
    brute = {(q1, q2c, q2f)
             for q1 in range(1, n + 1) for q2c in range(1, n + 1)
             for q2f in range(1, n + 1)
             if relaxed_card_ok(n, q1, q2c, q2f)
             and not ffp_card_ok(n, q1, q2c, q2f)}
    assert triples == brute
    labels = [m.label for m in mem]
    assert len(set(labels)) == len(labels)
    for m in mem:
        assert isinstance(m.system, RelaxedQuorumSpec)
        assert m.system.is_valid()
        # the honest recovery-phase-1 budget: rounds above a classic round
        # need q1_full = max(q1, n + 1 - q2c)
        ft = m.system.fault_tolerance()
        assert ft["phase1"] == n - m.system.q1_full


def test_relaxed_system_survives_joint_frontier():
    """At least one relaxed-valid / FFP-invalid system is Pareto-optimal
    on the joint n=11 frontier — the paper-level payoff of relaxing
    intersection (the full assertion set runs in benchmarks.quorum_sweep
    .run_relaxed)."""
    members = cardinality_family(11) + relaxed_family(11)
    r = score_systems(members, trials=24_576, chunk=8_192, shard=False,
                      seed=ANCHOR_SEED)
    relaxed_on = [l for l in r.frontier_labels if l.startswith("relaxed[")]
    assert relaxed_on, "no relaxed member survived the joint reduction"
    # relaxed[5,2,9] strictly beats every FFP triple at q1=5 on ft_classic
    # (FFP forces q2c >= 7 at q1=5) while matching its latency axes
    assert "relaxed[5,2,9]" in r.labels
    row = r.row("relaxed[5,2,9]")
    assert row["ft_classic"] == 9.0 and row["ft_phase1"] == 1.0


def test_relaxed_spec_to_explicit_refuses():
    """Lowering a relaxed spec to an explicit set system would silently
    flatten the per-round phase-1 semantics — it must refuse."""
    with pytest.raises(TypeError, match="per-round"):
        RelaxedQuorumSpec(5, 1, 1, 5).to_explicit()


def test_small_grid_and_weighted_members_model_check_clean():
    """Every n<=5 grid/weighted member explores clean: the set-level
    safety backstop behind the frontier's Monte-Carlo scores."""
    small = [m for m in grid_family(5) + weighted_family(5, (1, 2))
             + weighted_family(4, (1,)) if m.system.n <= 5]
    assert small                                     # grid.3x1 at least
    for m in small:
        r = explore(m.system, max_states=150_000)
        assert r.ok and r.violation is None, (m.label, r.violation)


# ---------------------------------------------------------------------------
# cross-validation: streamed scorer vs the materializing path
# ---------------------------------------------------------------------------

def test_score_small_trials_bit_identical_to_materializing():
    """Satellite contract: for T <= chunk (single device) the scorer's
    streams ARE the materializing engine plus a reduction — sketch state
    bit-for-bit, quantile axes bit-for-bit."""
    specs = [QuorumSpec.paper_headline(11), QuorumSpec.fast_paxos(11)]
    trials, seed = 3_000, 7
    r = score_systems(specs, trials=trials, chunk=8_192, shard=False,
                      seed=seed)

    key = jax.random.PRNGKey(seed)
    k_fast, k_race = jax.random.split(key)
    table = build_mask_table([s.to_masks() for s in specs])
    ref_fast = StreamSummary.from_outcomes(
        streaming._lat_only_outcomes(
            engine.fast_path(k_fast, table, n=11, samples=trials),
            fast=True))
    offs = 0.2 * jnp.arange(2, dtype=jnp.float32)
    ref_race = StreamSummary.from_outcomes(
        engine.race(k_race, table, offs, n=11, k_proposers=2,
                    samples=trials))
    for ref, got in ((ref_fast, r.streams["fast"]),
                     (ref_race, r.streams["race"])):
        for f in ("n_trials", "n_fast", "n_recovery", "n_undecided",
                  "hist"):
            np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                          np.asarray(getattr(ref, f)), f)
    vals = np.asarray(r.values)
    np.testing.assert_array_equal(vals[:, 0],
                                  np.asarray(ref_fast.quantile(0.5),
                                             np.float64))
    np.testing.assert_array_equal(vals[:, 1],
                                  np.asarray(ref_race.quantile(0.999),
                                             np.float64))


# ---------------------------------------------------------------------------
# the n=11 cardinality frontier: legacy containment + fixed-seed anchor
# ---------------------------------------------------------------------------

# Anchor parameters — mirrored in tests/regen_anchors.py::frontier.
ANCHOR_TRIALS = 49_152
ANCHOR_CHUNK = 16_384
ANCHOR_SEED = 0

# Regenerate with ``PYTHONPATH=src python tests/regen_anchors.py`` when the
# engine's sampling or the axis set changes on purpose.
ANCHOR_MEMBERS = [
    "card[1,11,11]", "card[10,2,7]", "card[11,1,6]", "card[2,10,11]",
    "card[3,9,10]", "card[4,8,10]", "card[4,8,11]", "card[5,7,10]",
    "card[5,7,9]", "card[6,6,11]", "card[6,6,9]", "card[7,5,8]",
    "card[8,4,8]", "card[9,3,7]",
]
ANCHOR_ROW = {                       # card[9,3,7], the paper's headline
    "fast_p50_ms": 1.2031513452529907,
    "race_p999_ms": 2.7318320274353027,
    "p_recovery": 0.046549479166666664,
    "ft_fast": 4.0, "ft_phase1": 2.0, "ft_classic": 8.0,
}


@pytest.fixture(scope="module")
def scored_n11():
    return score_systems(cardinality_family(11), trials=ANCHOR_TRIALS,
                         chunk=ANCHOR_CHUNK, shard=False, seed=ANCHOR_SEED)


def test_frontier_contains_legacy_minimal_reference(scored_n11):
    """Satellite: the scored n=11 frontier contains every member of the
    legacy quorum-size-minimal reference (quorum_sweep.minimal_frontier),
    and every scored member carries the minimal valid q2c for its q1 (a
    smaller-q2c sibling dominates via ft_classic under common random
    numbers)."""
    members = set(scored_n11.frontier_labels)
    minimal = {s.label for s in minimal_frontier(enumerate_valid(11))}
    assert minimal <= members, sorted(minimal - members)
    fam = cardinality_family(11)
    for i in scored_n11.frontier_indices:
        spec = fam[i].system
        assert spec.q2c == ffp_min_q2c(11, spec.q1), spec


def test_fixed_seed_frontier_anchor(scored_n11):
    """Fixed-seed anchor: frontier membership + the paper-headline row.
    Anything that moves these without an intentional sampling/axis change
    is silently reshaping the benchmark — exactly what this test exists
    to catch.  Regenerate via tests/regen_anchors.py::frontier."""
    assert sorted(scored_n11.frontier_labels) == ANCHOR_MEMBERS
    row = scored_n11.row("card[9,3,7]")
    assert row["on_frontier"]
    for k, v in ANCHOR_ROW.items():
        assert row[k] == pytest.approx(v, rel=1e-6), (k, row[k], v)


def test_frontier_single_compile_per_stream_path(scored_n11):
    """Scoring a second same-shape batch re-enters the same compiles."""
    before = dict(engine.TRACE_COUNTS)
    score_systems(cardinality_family(11), trials=ANCHOR_TRIALS,
                  chunk=ANCHOR_CHUNK, shard=False, seed=ANCHOR_SEED + 1)
    assert engine.TRACE_COUNTS == before


# ---------------------------------------------------------------------------
# FrontierResult + front doors
# ---------------------------------------------------------------------------

def test_frontier_result_pytree_table_and_to_dict(scored_n11):
    leaves, treedef = jax.tree_util.tree_flatten(scored_n11)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.labels == scored_n11.labels
    assert rebuilt.axes == scored_n11.axes
    np.testing.assert_array_equal(np.asarray(rebuilt.mask),
                                  np.asarray(scored_n11.mask))

    d = scored_n11.to_dict()
    assert d["n_systems"] == len(scored_n11.labels)
    assert d["n_frontier"] == len(scored_n11.frontier_indices)
    assert d["card[9,3,7].on_frontier"] == 1.0
    assert "card[9,3,7].race_p999_ms" in d

    tab = scored_n11.table()
    assert "card[9,3,7]" in tab and "race_p999_ms" in tab
    assert len(scored_n11.table(frontier_only=False).splitlines()) \
        == len(scored_n11.labels) + 2


def test_experiment_frontier_front_door():
    """``Experiment.frontier()`` / ``api.frontier`` run the scorer with
    the experiment's systems and config."""
    from repro.api import Experiment, Workload, frontier
    systems = [QuorumSpec.paper_headline(11), QuorumSpec.fast_paxos(11)]
    exp = Experiment(systems=systems,
                     workload=Workload.race(k=2, delta_ms=0.2),
                     trials=20_000, chunk=8_192, shard=False,
                     compute_fault_tolerance=False)
    fr = exp.frontier()
    assert fr.labels == ("card[9,3,7]", "card[6,6,9]")
    # the two landmarks trade fault tolerance for latency: both survive
    assert fr.frontier_labels == fr.labels
    fr2 = frontier(systems, trials=20_000, chunk=8_192, shard=False)
    np.testing.assert_array_equal(np.asarray(fr2.values),
                                  np.asarray(fr.values))


def test_experiment_frontier_honors_faults():
    """A faulted experiment scores the frontier with the crashes applied:
    killing more acceptors than the fast path tolerates leaves nothing
    decided on the fast stream (NaN latency axis, which orients to
    worst)."""
    from repro.api import Experiment, Workload
    spec = QuorumSpec.paper_headline(11)          # q2f=7: tolerates 4
    base = Experiment(systems=[spec], workload=Workload.race(k=2),
                      trials=4_000, chunk=8_192, shard=False,
                      compute_fault_tolerance=False)
    import dataclasses
    faulty = dataclasses.replace(base, faults=(0, 1, 2, 3, 4))
    fr_ok, fr_bad = base.frontier(), faulty.frontier()
    assert int(np.asarray(fr_bad.streams["fast"].n_undecided)[0]) == 4_000
    assert np.isnan(np.asarray(fr_bad.values)[0, 0])
    assert not np.isnan(np.asarray(fr_ok.values)[0, 0])


def test_default_axes_match_axis_names():
    from repro.frontier.score import AXIS_NAMES
    axes = default_axes()
    assert tuple(a.name for a in axes) == AXIS_NAMES
    assert axes[0].relative and axes[0].eps == streaming.DEFAULT_PRECISION


# ---------------------------------------------------------------------------
# sharded scoring (real under the CI 8-device job)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >1 device (run under "
                           "--xla_force_host_platform_device_count)")
def test_sharded_score_counts_exact_and_members_sane():
    specs = [QuorumSpec.paper_headline(11), QuorumSpec.fast_paxos(11),
             QuorumSpec.majority_fast(11)]
    trials = 30_011                      # deliberately not divisible
    r = score_systems(specs, trials=trials, chunk=2_048, shard=True)
    for s in r.streams.values():
        assert [int(x) for x in np.asarray(s.n_trials)] == [trials] * 3
    # neither landmark dominates the other whatever the device count
    assert {"card[9,3,7]", "card[6,6,9]"} <= set(r.frontier_labels)
