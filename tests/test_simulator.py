"""Discrete-event simulator tests: §6 workloads, crash tolerance, and the
paper's headline behaviours (FFP latency < FP; recovery ratio ~1/3)."""
import pytest

from repro.core.quorum import QuorumSpec
from repro.core.simulator import (FastPaxosSim, LatencyModel,
                                  conflict_free_workload, conflict_workload,
                                  latency_stats)

FFP = QuorumSpec.paper_headline(11)
FP = QuorumSpec.fast_paxos(11)


def test_conflict_free_all_fast():
    sim = FastPaxosSim(FFP, seed=1)
    conflict_free_workload(sim, 500, rate_per_s=1400)
    res = sim.run()
    assert len(res) == 500
    assert all(r.outcome == "fast" for r in res)
    assert sim.recovery_entries == 0


def test_ffp_latency_beats_fp():
    """Fig. 2a: smaller q2f -> lower order statistic -> lower latency."""
    stats = {}
    for name, spec in [("ffp", FFP), ("fp", FP)]:
        sim = FastPaxosSim(spec, seed=7)
        conflict_free_workload(sim, 1500, rate_per_s=1400)
        stats[name] = latency_stats(sim.run())
    assert stats["ffp"]["mean_ms"] < stats["fp"]["mean_ms"]
    assert stats["ffp"]["p50_ms"] < stats["fp"]["p50_ms"]


def test_conflict_recovery_ratio_about_one_third():
    """§6: 'Fast Flexible Paxos entered the conflict recovery almost
    one-third as frequently as Fast Paxos'."""
    rec = {}
    for name, spec in [("ffp", FFP), ("fp", FP)]:
        sim = FastPaxosSim(spec, seed=13)
        conflict_workload(sim, 4000, rate_per_s=2700, conflict_frac=0.10)
        sim.run()
        rec[name] = sim.recovery_entries
    assert rec["fp"] > 0
    ratio = rec["ffp"] / rec["fp"]
    assert ratio < 0.6, (rec, "FFP must recover far less often than FP")


def test_recovered_instances_decide_single_value():
    sim = FastPaxosSim(FFP, seed=3)
    # two racing proposals on the same instance, tiny interval
    sim.submit(0.0, instance=0, value="A", proposer=0)
    sim.submit(0.05, instance=0, value="B", proposer=1)
    res = sim.run()
    decided = {sim.instances[0].decided}
    assert len(decided) == 1 and decided <= {"A", "B"}
    outcomes = {r.value: r.outcome for r in res}
    assert sorted(outcomes.values()) in (["aborted", "fast"],
                                         ["aborted", "recovered"])


def test_crash_tolerance_fast_path():
    # q2f=7 on n=11 tolerates 4 crashes on the steady-state fast path
    sim = FastPaxosSim(FFP, seed=5, crashed=[0, 1, 2, 3])
    conflict_free_workload(sim, 200, rate_per_s=1000)
    res = sim.run()
    assert all(r.outcome == "fast" for r in res)


def test_crash_beyond_q2f_stalls():
    # 5 crashes leave only 6 < q2f=7 acceptors: no fast decision possible
    sim = FastPaxosSim(FFP, seed=5, crashed=[0, 1, 2, 3, 4])
    sim.submit(0.0, instance=0, value="A")
    res = sim.run()
    assert res[0].outcome == "lost"


def test_message_loss_delays_but_safe():
    lat = LatencyModel(loss_prob=0.05)
    sim = FastPaxosSim(FFP, latency=lat, seed=9)
    conflict_free_workload(sim, 300, rate_per_s=500)
    res = sim.run()
    decided = [r for r in res if r.outcome == "fast"]
    assert len(decided) > 250          # most still decide
    # no instance decides two values (safety under loss)
    per_inst = {}
    for r in res:
        if r.instance in sim.instances:
            d = sim.instances[r.instance].decided
            per_inst.setdefault(r.instance, set()).add(d)
    assert all(len(v) == 1 for v in per_inst.values())


def test_latency_stats_fields():
    sim = FastPaxosSim(FFP, seed=2)
    conflict_free_workload(sim, 100, rate_per_s=1000)
    s = latency_stats(sim.run())
    for k in ("mean_ms", "p50_ms", "p95_ms", "p99_ms"):
        assert s[k] > 0
    assert s["p95_ms"] >= s["p50_ms"]
