"""Multi-host trial mesh acceptance (``repro.parallel.distributed``,
DESIGN.md §10).

The contract under test: the merged ``StreamSummary`` of a streamed run
depends only on the *global* key and the *global* device count — never on
how those devices are laid out across processes.  A 2-process x 4-device
local grid (forced host devices + gloo CPU collectives) must therefore be
bit-identical in decide counts and sketch histogram to the 1-process x
8-device run, and its quantiles (computed from that identical histogram)
within the sketch's guaranteed relative error of any other layout's.

Tests here launch real subprocesses (each pays a fresh jax import +
compile), so they are deliberately few and small; platforms whose jax/CPU
backend cannot do multi-process collectives skip instead of failing.

The 10^9-trial fixed-memory criterion is env-gated (hours of wall time on
a small CPU):  REPRO_GIGATRIAL=1 PYTHONPATH=src python -m pytest
tests/test_multihost.py -k gigatrial
"""
import os
import tempfile

import numpy as np
import pytest

from repro.parallel import distributed

pytestmark = pytest.mark.slow

TRIALS = 50_011                           # odd: exercises remainder splits
CHUNK = 2_048


def _layout(procs, dev_per_proc, path):
    try:
        return distributed.run_stream_layout(procs, dev_per_proc, path,
                                             trials=TRIALS, chunk=CHUNK)
    except NotImplementedError as e:      # no gloo multi-process collectives
        pytest.skip(f"platform lacks multi-process CPU collectives: "
                    f"{str(e).splitlines()[0]}")


def test_two_by_four_bit_identical_to_one_by_eight():
    with tempfile.TemporaryDirectory() as td:
        multi = _layout(2, 4, os.path.join(td, "p2x4.npz"))
        single = _layout(1, 8, os.path.join(td, "p1x8.npz"))

    assert int(multi["process_count"]) == 2
    assert int(multi["global_devices"]) == 8
    assert int(single["process_count"]) == 1
    assert int(single["global_devices"]) == 8

    # integer state: bit-identical across layouts (exact psum merge over
    # global-index-derived per-device streams)
    for k in ("n_trials", "n_fast", "n_recovery", "n_undecided", "hist"):
        np.testing.assert_array_equal(multi[k], single[k], err_msg=k)
    assert (multi["n_trials"] == TRIALS).all()
    assert (multi["n_fast"] + multi["n_recovery"]
            + multi["n_undecided"] == TRIALS).all()

    # float state: max is a pmax of identical per-device values (equal);
    # quantiles come from the identical hist, so they agree to within the
    # sketch's relative-error guarantee (trivially: exactly)
    np.testing.assert_array_equal(multi["max_ms"], single["max_ms"])
    for q in ("p50_ms", "p999_ms", "p9999_ms"):
        np.testing.assert_allclose(multi[q], single[q], rtol=0.01,
                                   err_msg=q)
        assert np.isfinite(multi[q]).all(), q


def test_single_process_forced_devices_layout_runs():
    """The degenerate 1-process 'grid' works through the same launcher
    path (coordinator env set, gloo selected, 2 forced devices) — the
    shape every multihost CI job debugs with first."""
    with tempfile.TemporaryDirectory() as td:
        out = _layout(1, 2, os.path.join(td, "p1x2.npz"))
    assert int(out["global_devices"]) == 2
    assert (out["n_trials"] == TRIALS).all()
    assert np.isfinite(out["p9999_ms"]).all()


@pytest.mark.skipif(os.environ.get("REPRO_GIGATRIAL") != "1",
                    reason="10^9-trial run takes CPU-hours; set "
                           "REPRO_GIGATRIAL=1 to enable")
def test_gigatrial_race_stream_fixed_memory_p9999():
    """ISSUE 7 acceptance: a 10^9-trial ``race_stream`` completes in fixed
    memory with the p99.99 tail populated.  Runs in-process on whatever
    devices are visible (shard=True picks them up; a 1-device host warns
    and streams unsharded — same fixed-size state either way)."""
    import jax
    import jax.numpy as jnp

    from repro.core.quorum import QuorumSpec
    from repro.montecarlo import build_mask_table, streaming

    table = build_mask_table([QuorumSpec.paper_headline(11)])
    offsets = jnp.array([0.0, 0.2], jnp.float32)
    state = streaming.race_stream(jax.random.PRNGKey(0), table, offsets,
                                  n=11, k_proposers=2, trials=1_000_000_000,
                                  chunk=262_144)
    assert int(state.n_trials[0]) == 1_000_000_000
    s = state.summary()
    assert np.isfinite(float(s["p9999_ms"][0]))
    assert float(s["p9999_ms"][0]) >= float(s["p999_ms"][0]) > 0
