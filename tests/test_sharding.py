"""Logical-axis sharding rules: divisibility fallbacks, mesh-awareness,
no-mesh no-ops, and the dry-run's abstract-state machinery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config, reduced_config
from repro.launch.abstract import abstract_params, eval_shape_with_axes
from repro.models.model import DecoderLM
from repro.parallel.sharding import (constrain, default_rules, named_sharding,
                                     sharding_ctx, spec_for, tree_shardings)


def tiny_mesh():
    # single device, two named axes — rule resolution works identically
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_spec_divisible_dims_shard():
    mesh = tiny_mesh()
    rules = default_rules()
    spec = spec_for((256, 4096), ("batch", None), mesh, rules)
    assert spec == P(("data",), None)
    spec = spec_for((4096, 16384), ("embed", "mlp"), mesh, rules)
    assert spec == P(("data",), ("model",))


def test_spec_fallback_on_indivisible():
    """With a conceptual 16-way model axis, 56 heads can't shard; with the
    1x1 test mesh everything divides — emulate by checking the rule engine
    skips candidates whose axis size doesn't divide."""
    import numpy as np
    from repro.parallel import sharding as sh

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    rules = default_rules()
    spec = sh.spec_for((56,), ("heads",), FakeMesh(), rules)
    assert spec == P(None)                       # 56 % 16 != 0 -> replicated
    spec = sh.spec_for((48,), ("heads",), FakeMesh(), rules)
    assert spec == P(("model",))
    # batch picks ('pod','data') only when 'pod' exists:
    spec = sh.spec_for((256,), ("batch",), FakeMesh(), rules)
    assert spec == P(("data",))

    class PodMesh:
        shape = {"pod": 2, "data": 16, "model": 16}

    spec = sh.spec_for((256,), ("batch",), PodMesh(), rules)
    assert spec == P(("pod", "data"))


def test_no_double_use_of_mesh_axis():
    from repro.parallel import sharding as sh

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    # experts take 'model'; the expert-mlp dim must then fall back.
    spec = sh.spec_for((64, 2048, 1408), ("experts", "embed", "mlp"),
                       FakeMesh(), default_rules())
    assert spec == P(("model",), ("data",), None)


def test_cache_seq_prefers_widest_free():
    from repro.parallel import sharding as sh

    class PodlessMesh:
        shape = {"data": 16, "model": 16}

    # decode: batch on data -> cache_seq takes model
    spec = sh.spec_for((8, 128, 32768, 8, 256),
                       (None, "batch", "cache_seq", "kv_heads", "head_dim"),
                       PodlessMesh(), default_rules())
    assert spec == P(None, ("data",), ("model",), None, None)
    # long-context: batch=1 replicated -> cache spreads over data x model
    spec = sh.spec_for((8, 1, 524288, 8, 256),
                       (None, "batch", "cache_seq", "kv_heads", "head_dim"),
                       PodlessMesh(), default_rules())
    assert spec == P(None, None, ("data", "model"), None, None)


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 8))
    y = constrain(x, ("batch", None))
    assert y is x


def test_constrain_applies_in_ctx():
    mesh = tiny_mesh()
    with sharding_ctx(mesh):
        y = jax.jit(lambda x: constrain(x, ("batch", None)))(jnp.ones((4, 8)))
    assert y.shape == (4, 8)


def test_abstract_params_no_allocation():
    """480B-parameter arctic 'initializes' abstractly in well under a
    second and reports full shapes."""
    import time
    model = DecoderLM(get_config("arctic_480b"))
    t0 = time.time()
    shapes, axes = abstract_params(model)
    assert time.time() - t0 < 30.0
    total = sum(np.prod(s.shape) for s in jax.tree.leaves(shapes))
    assert total > 4e11                     # ~480B params present as specs
    leaves_ax = jax.tree.leaves(
        axes, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    assert all(isinstance(a, tuple) for a in leaves_ax)


def test_tree_shardings_structure_matches():
    mesh = tiny_mesh()
    model = DecoderLM(reduced_config(get_config("olmo_1b")))
    shapes, axes = abstract_params(model)
    sh = tree_shardings(shapes, axes, mesh)
    assert jax.tree.structure(sh) == jax.tree.structure(shapes)


def test_eval_shape_with_axes_captures():
    def fn(key):
        return {"w": jax.random.normal(key, (4, 4))}, {"w": ("embed", None)}

    shapes, axes = eval_shape_with_axes(fn, jax.random.PRNGKey(0))
    assert shapes["w"].shape == (4, 4)
    assert axes == {"w": ("embed", None)}
