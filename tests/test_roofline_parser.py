"""Unit tests for the roofline HLO parsers — the measurement instrument
behind §Roofline/§Perf (EXPERIMENTS.md §Method)."""
import benchmarks.roofline as rl


def test_shape_bytes():
    assert rl._shape_bytes("f32[8,4096,4096]") == 8 * 4096 * 4096 * 4
    assert rl._shape_bytes("bf16[16,24]") == 16 * 24 * 2
    assert rl._shape_bytes("(f32[2,2], bf16[4])") == 16 + 8
    assert rl._shape_bytes("pred[7]") == 7


def test_group_info_iota_within_pod():
    g, c = rl._group_info("replica_groups=[32,16]<=[512]")
    assert (g, c) == (16, 0)
    g, c = rl._group_info("replica_groups=[32,16]<=[32,16]T(1,0)")
    assert (g, c) == (16, 0)


def test_group_info_iota_cross_pod():
    g, c = rl._group_info("replica_groups=[16,32]<=[32,16]T(1,0)")
    assert (g, c) == (32, 1)
    g, c = rl._group_info("replica_groups=[1,512]<=[512]")
    assert (g, c) == (512, 1)


def test_group_info_brace():
    assert rl._group_info("replica_groups={{0,1,2,3}}") == (4, 0)
    assert rl._group_info("replica_groups={{0,256},{1,257}}") == (2, 1)


def test_link_bytes_ring_conversions():
    ag = rl.CollectiveOp("all-gather", 1600, 16, 0)
    assert ag.link_bytes == 1600 * 15 / 16
    ar = rl.CollectiveOp("all-reduce", 1600, 16, 0)
    assert ar.link_bytes == 2 * 1600 * 15 / 16
    rs = rl.CollectiveOp("reduce-scatter", 100, 16, 0)
    assert rs.link_bytes == 100 * 15


def test_promoted_reduction_counted_at_bf16():
    hlo = """
  %convert_fusion.1 = f32[8,4096]{1,0} fusion(%dot.3)
  %ar = f32[8,4096]{1,0} all-reduce(%convert_fusion.1), replica_groups=[32,16]<=[512], to_apply=%add_promoted
  %ar2 = f32[8,4096]{1,0} all-reduce(%plain.2), replica_groups=[32,16]<=[512], to_apply=%add
"""
    ops = rl.parse_collectives(hlo)
    assert len(ops) == 2
    promoted = [o for o in ops if o.promoted]
    plain = [o for o in ops if not o.promoted]
    assert len(promoted) == 1 and len(plain) == 1
    assert promoted[0].out_bytes * 2 == plain[0].out_bytes


def test_collective_summary_buckets_dcn():
    ops = [rl.CollectiveOp("all-reduce", 100, 16, 0),
           rl.CollectiveOp("all-reduce", 100, 32, 1)]
    s = rl.collective_summary(ops)
    assert s["link_bytes"] > 0 and s["dcn_bytes"] > 0
    assert s["count"] == 2


def test_roofline_terms_dominant():
    t = rl.roofline_terms({"flops": 197e12, "bytes accessed": 819e9 * 10},
                          {"link_bytes": 50e9, "dcn_bytes": 0.0})
    assert t["dominant"] == "memory_s"
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 10.0) < 1e-9
    assert abs(t["collective_s"] - 1.0) < 1e-9
