"""Property-based system invariants (hypothesis)."""
import itertools

from _hypothesis_compat import given, settings, strategies as st

from repro.cluster import ConsensusLog
from repro.core.quorum import QuorumSpec, all_valid_specs


@st.composite
def valid_spec(draw):
    n = draw(st.integers(3, 11))
    specs = list(itertools.islice(all_valid_specs(n), 200))
    return specs[draw(st.integers(0, len(specs) - 1))]


@settings(max_examples=40, deadline=None)
@given(spec=valid_spec(),
       orders_seed=st.integers(0, 10_000),
       n_values=st.integers(1, 3))
def test_consensus_log_single_value_per_slot(spec, orders_seed, n_values):
    """For ANY valid quorum spec and ANY racing delivery order, a slot
    decides at most one value, and that value was proposed."""
    import random
    rng = random.Random(orders_seed)
    log = ConsensusLog(spec, seed=orders_seed)
    values = [f"v{i}" for i in range(n_values)]
    orders = [rng.sample(range(spec.n), spec.n) for _ in values]
    out = log.propose_racing(values, arrival_orders=orders)
    assert out.value in values
    # re-proposing the slot cannot change the decision
    out2 = log.propose_racing(list(reversed(values)), slot=out.slot)
    assert out2.value == out.value


@settings(max_examples=30, deadline=None)
@given(spec=valid_spec(), crash_seed=st.integers(0, 1000))
def test_consensus_log_safe_under_crashes(spec, crash_seed):
    """Crashing up to n - max(q1, q2f) acceptors never loses a decided
    value; decisions made before the crash remain visible."""
    import random
    rng = random.Random(crash_seed)
    log = ConsensusLog(spec, seed=crash_seed)
    out = log.propose("before")
    assert out.value == "before"
    budget = spec.n - max(spec.q1, spec.q2f)
    for a in rng.sample(range(spec.n), budget):
        log.crash(a)
    # decided slot still reads back
    assert log.decided[out.slot].value == "before"
    # and the cluster is still live
    out2 = log.propose("after")
    assert out2.value == "after"


@settings(max_examples=60, deadline=None)
@given(n=st.integers(3, 30))
def test_paper_policy_spec_always_valid(n):
    from repro.cluster.membership import quorum_policy
    spec = quorum_policy(n)
    assert spec.is_valid()
    # phase-2 quorums are minimal given q1 (the §5 tradeoff)
    from repro.core.quorum import ffp_min_q2c, ffp_min_q2f
    assert spec.q2f == ffp_min_q2f(n, spec.q1)
    assert spec.q2c == ffp_min_q2c(n, spec.q1)
