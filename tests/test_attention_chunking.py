"""The q-chunked attention paths (python-unrolled with static banded k
slices, and the lax.map long-prefill path) must agree exactly with the
single-chunk reference — causal, sliding-window, cached, and padded-head
cases."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

import repro.models.layers as L
from repro.configs import get_config, reduced_config
from repro.models.model import DecoderLM


def _logits(cfg, toks, q_chunk):
    old = L.Q_CHUNK
    try:
        L.Q_CHUNK = q_chunk
        model = DecoderLM(cfg, remat=False)
        params, _ = model.init(jax.random.PRNGKey(0))
        params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
        import repro.models.model as mm
        prev = mm.COMPUTE_DTYPE
        try:
            mm.COMPUTE_DTYPE = jnp.float32
            return model.forward(params, {"tokens": toks})
        finally:
            mm.COMPUTE_DTYPE = prev
    finally:
        L.Q_CHUNK = old


@pytest.mark.parametrize("arch", ["deepseek_7b", "gemma3_12b",
                                  "musicgen_medium"])
def test_chunked_matches_unchunked(arch):
    cfg = reduced_config(get_config(arch))
    if cfg.frontend == "audio_frames":
        cfg = dataclasses.replace(cfg, frontend=None)
    S = 64
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, cfg.vocab)
    full = _logits(cfg, toks, q_chunk=S)          # single chunk (reference)
    unrolled = _logits(cfg, toks, q_chunk=16)     # nc=4 -> unrolled, banded
    mapped = _logits(cfg, toks, q_chunk=4)        # nc=16 -> lax.map path
    assert float(jnp.abs(full - unrolled).max()) < 1e-4
    assert float(jnp.abs(full - mapped).max()) < 1e-4


def test_chunked_matches_in_prefill_cache():
    """Chunked prefill against a cache (T > S) slices k by position bound."""
    cfg = reduced_config(get_config("gemma3_12b"))
    model = DecoderLM(cfg, remat=False)
    params, _ = model.init(jax.random.PRNGKey(0))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 64), 0, cfg.vocab)
    import repro.models.model as mm
    old_cd, old_qc = mm.COMPUTE_DTYPE, L.Q_CHUNK
    try:
        mm.COMPUTE_DTYPE = jnp.float32
        full = model.forward(params, {"tokens": toks})
        L.Q_CHUNK = 16
        cache, _ = model.init_cache(1, 96)
        cache, lg = model.prefill(params, {"tokens": toks}, cache)
    finally:
        mm.COMPUTE_DTYPE, L.Q_CHUNK = old_cd, old_qc
    assert float(jnp.abs(lg[:, 0] - full[:, -1]).max()) < 1e-4
