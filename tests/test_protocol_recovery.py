"""Deeper protocol coverage: uncoordinated recovery, plurality preference,
retransmission semantics, and liveness edge conditions around the quorum
thresholds (§5 of the paper: liveness depends on BOTH phase quorums)."""
import pytest

from repro.core.protocol import (ANY, Acceptor, Coordinator, Learner,
                                 Phase1a, Phase1b, Phase2a, Phase2b,
                                 RoundSystem, choose_value, p2b_to_p1b,
                                 pick_values)
from repro.core.quorum import QuorumSpec


def rs11():
    return RoundSystem(QuorumSpec.paper_headline(11), n_coordinators=1,
                       fast_rounds="odd")


def _split_vote(accs, split):
    """Make acceptors vote in fast round 1 per `split` = {val: count}."""
    msgs = []
    i = 0
    for val, cnt in split.items():
        for _ in range(cnt):
            m = accs[i].on_phase2a(Phase2a(1, ANY), proposed_val=val)
            msgs.append(m)
            i += 1
    return msgs


def test_uncoordinated_recovery_round2_must_be_fast():
    """Uncoordinated recovery jumps to round i+1 only if it is fast; with
    fast_rounds='odd', round 2 is classic, so acceptors refuse."""
    rs = rs11()
    accs = [Acceptor(i, rs) for i in range(11)]
    votes = _split_vote(accs, {"A": 6, "B": 5})
    p1b = p2b_to_p1b(votes, 1)
    out = accs[0].uncoordinated_recovery(1, p1b, {"A", "B"})
    assert out is None                      # round 2 is classic here


def test_uncoordinated_recovery_in_fast_round():
    rs = RoundSystem(QuorumSpec.paper_headline(11), n_coordinators=1,
                     fast_rounds="all")
    accs = [Acceptor(i, rs) for i in range(11)]
    votes = _split_vote(accs, {"A": 6, "B": 5})
    p1b = p2b_to_p1b(votes, 1)
    out = accs[0].uncoordinated_recovery(1, p1b, {"A", "B"})
    assert out is not None and out.rnd == 2
    # plurality preference: A had 6 of 11 votes
    assert out.val == "A"


def test_plurality_preference_only_in_free_choice():
    """When one value passes O4 it MUST be picked even against plurality."""
    rs = rs11()
    # 9-message phase-1 quorum: 7 voted A (>= q2f among Q + outside), 2 B
    msgs = [Phase1b(2, 1, "A", a) for a in range(7)]
    msgs += [Phase1b(2, 1, "B", a) for a in range(7, 9)]
    picks = pick_values(rs, 2, msgs, {"A", "B"})
    # outside = 2, votes_A = 7 -> 9 >= q2f=7 passes; votes_B = 2+2=4 < 7
    assert picks == {"A"}
    # counts can't override an O4 winner (singleton set)
    assert choose_value(picks, {"B": 100}) == "A"


def test_coordinated_recovery_waits_for_phase1_quorum():
    rs = rs11()
    accs = [Acceptor(i, rs) for i in range(11)]
    c = Coordinator(0, rs)
    c.crnd, c.cval = 1, ANY
    votes = _split_vote(accs, {"A": 5, "B": 3})      # only 8 < q1=9 votes
    for m in votes:
        c.on_phase2b(m)
    assert c.coordinated_recovery({"A", "B"}) is None


def test_retransmission_is_idempotent():
    rs = rs11()
    a = Acceptor(3, rs)
    a.on_phase1a(Phase1a(2))
    m1 = a.last_msg()
    m2 = a.last_msg()
    assert m1 == m2
    assert isinstance(m1, Phase1b) and m1.rnd == 2


def test_learner_needs_exact_q2():
    rs = rs11()
    learner = Learner(rs)
    # classic round 2: q2c = 3
    assert learner.on_phase2b(Phase2b(2, "v", 0)) is None
    assert learner.on_phase2b(Phase2b(2, "v", 1)) is None
    assert learner.on_phase2b(Phase2b(2, "v", 2)) == "v"


def test_learner_fast_round_needs_q2f():
    rs = rs11()
    learner = Learner(rs)
    for a in range(6):
        assert learner.on_phase2b(Phase2b(1, "v", a)) is None
    assert learner.on_phase2b(Phase2b(1, "v", 6)) == "v"   # 7th = q2f


def test_duplicate_votes_not_double_counted():
    rs = rs11()
    learner = Learner(rs)
    for _ in range(10):
        assert learner.on_phase2b(Phase2b(1, "v", 0)) is None
    assert not learner.learned


def test_choose_value_numeric_tie_break():
    """CHOOSE sorts by (-count, canonical key): numbers order numerically,
    not by repr (the old lexicographic order picked 10 before 2)."""
    assert choose_value({10, 2}) == 2
    assert choose_value({10, 2, 100}) == 2


def test_choose_value_type_stable():
    """Heterogeneous pick sets must not raise (int vs str comparison) and
    must order deterministically: numbers < strings < other types."""
    assert choose_value({"b", 1}) == 1
    assert choose_value({"b", "a"}) == "a"
    assert choose_value({("t",), "a"}) == "a"
    assert choose_value({ANY}) == ANY
    assert choose_value(set()) == ANY


def test_choose_value_plurality_beats_key_order():
    """Counts dominate the canonical key; key breaks exact count ties."""
    assert choose_value({10, 2}, {10: 3, 2: 1}) == 10
    assert choose_value({"b", "a"}, {"a": 2, "b": 2}) == "a"
    assert choose_value({"b", "a"}, {"b": 3}) == "b"


def test_uncoordinated_recovery_promised_acceptor_can_vote():
    """TLA+ Phase2b enabling is ``rnd <= i+1 /\\ vrnd < i+1``: an acceptor
    that already *promised* round 2 (rnd == 2 via Phase1a) but has not
    voted may still cast the round-2 recovery vote.  The old ``rnd > i``
    guard wrongly excluded it."""
    rs = RoundSystem(QuorumSpec.paper_headline(11), n_coordinators=1,
                     fast_rounds="all")
    accs = [Acceptor(i, rs) for i in range(11)]
    votes = _split_vote(accs, {"A": 6, "B": 5})
    p1b = p2b_to_p1b(votes, 1)

    promised = Acceptor(0, rs, rnd=2, vrnd=1, vval="A")
    out = promised.uncoordinated_recovery(1, p1b, {"A", "B"})
    assert out is not None and out.rnd == 2 and out.val == "A"

    voted_r2 = Acceptor(1, rs, rnd=2, vrnd=2, vval="B")
    assert voted_r2.uncoordinated_recovery(1, p1b, {"A", "B"}) is None

    promised_r3 = Acceptor(2, rs, rnd=3, vrnd=1, vval="A")
    assert promised_r3.uncoordinated_recovery(1, p1b, {"A", "B"}) is None


def test_choose_value_change_leaves_exploration_deterministic():
    """The tie-break rewrite must not perturb the model checker: two
    explorations of the same spec see the identical state count (CHOOSE is
    only a liveness heuristic; the checker branches over the full pick
    set, so determinism — not the specific choice — is what safety
    rests on)."""
    from repro.core.model_check import explore
    a = explore(QuorumSpec(3, 2, 2, 3), max_states=200_000)
    b = explore(QuorumSpec(3, 2, 2, 3), max_states=200_000)
    assert a.ok and b.ok
    assert a.states == b.states


@pytest.mark.parametrize("n", [4, 5, 7, 11, 16])
def test_generalized_headline_valid(n):
    spec = QuorumSpec.paper_headline(n)
    assert spec.is_valid()
    # §5: fast quorums at least as large as classic phase-2 quorums
    assert spec.q2f >= spec.q2c or spec.q1 == n
