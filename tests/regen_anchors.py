"""Regenerate the fixed-seed regression anchors used by the test suite.

Run when the engine's *sampling* is changed on purpose (key splits, draw
order, presort layout) and the anchored numbers legitimately move:

    PYTHONPATH=src python tests/regen_anchors.py

then paste the printed values into
``tests/test_montecarlo.py::test_summarize_fixed_seed_regression_anchor``
and ``tests/test_frontier.py`` (``ANCHOR_MEMBERS`` / ``ANCHOR_ROW``).
Anything that moves these numbers *without* an intentional sampling change
is a silent behavioural regression — that is what the anchor exists to
catch.
"""
import jax
import jax.numpy as jnp


def montecarlo():
    from repro.core.quorum import QuorumSpec
    from repro.montecarlo import build_mask_table, engine

    out = engine.race(jax.random.PRNGKey(123),
                      build_mask_table([QuorumSpec.paper_headline(11)]),
                      jnp.array([0.0, 0.25]), n=11, k_proposers=2,
                      samples=20_000)
    s = engine.summarize(out)
    print(f"p50_ms          = {float(s['p50_ms'][0]):.6g}")
    print(f"recovery_rate   = {float(s['recovery_rate'][0]):.6g}")
    print(f"latency_ms[0,0] = {float(out['latency_ms'][0, 0]):.7g}")
    print(f"latency_ms[0,1] = {float(out['latency_ms'][0, 1]):.7g}")


def frontier():
    """The n=11 frontier anchor: membership set + the paper-headline row.
    Parameters mirror tests/test_frontier.py (ANCHOR_TRIALS/CHUNK/SEED);
    shard=False keeps the numbers identical on 1 and 8 devices."""
    from repro.frontier import cardinality_family, score_systems

    r = score_systems(cardinality_family(11), trials=49_152, chunk=16_384,
                      shard=False, seed=0)
    print("ANCHOR_MEMBERS = [")
    for lab in sorted(r.frontier_labels):
        print(f"    {lab!r},")
    print("]")
    print("ANCHOR_ROW = {   # card[9,3,7]")
    for k, v in r.row("card[9,3,7]").items():
        if k != "on_frontier":
            print(f"    {k!r}: {v!r},")
    print("}")


if __name__ == "__main__":
    montecarlo()
    frontier()
