"""Regenerate ALL fixed-seed regression anchors in one command.

    PYTHONPATH=src python tests/regen_anchors.py

then paste the printed values into
``tests/test_montecarlo.py::test_summarize_fixed_seed_regression_anchor``
and ``tests/test_frontier.py`` (``ANCHOR_MEMBERS`` / ``ANCHOR_ROW``).
Anything that moves these numbers *without* an intentional sampling change
is a silent behavioural regression — that is what the anchor exists to
catch.

Which anchors are layout-sensitive (and to what):

* ``test_montecarlo`` fixed-seed anchor (``montecarlo()`` below) —
  sensitive to the *draw layout*: PRNG key-split order and draw shapes in
  ``engine._draw_race`` (``fold_in`` sequence, per-hop sample shapes).
  NOT sensitive to how arrivals are subsequently sorted/selected: the
  sort-free lowering (DESIGN.md §9 — ``lax.top_k`` prefixes, cardinality
  column reductions, the fused megakernel) is bit-identical on decide
  bits and order statistics, so it must NOT move this anchor.
* ``test_frontier`` ``ANCHOR_MEMBERS`` / ``ANCHOR_ROW`` (``frontier()``
  below) — additionally sensitive to the *streamed chunk layout*: chunk
  size, per-chunk ``fold_in`` indices, device count when sharded
  (shard=False here precisely so 1 and 8 devices agree), and the sketch
  precision (frontier axes read quantiles + counts only, never the f32
  latency-sum whose accumulation order the sort-free paths do change).
  The PR 7 device-key derivation change (``fold_in(key, 0x5eed+d)`` →
  the two-level ``fold_in(fold_in(key, DEVICE_FOLD_DOMAIN), d)``,
  DESIGN.md §10) moved NO anchors: both anchors run shard=False, and
  only *sharded* streams draw from the device key domain.  Sharded
  results keyed by the global device index are layout-invariant across
  process grids (2x4 == 1x8) but DO differ from the pre-PR-7 sharded
  numbers — any future sharded anchor must be regenerated if the
  device-domain derivation changes again.
  ``k_max`` settings must NOT move it either — the streamed sort-free
  paths are integer-bit-identical (asserted in
  ``tests/test_streaming.py::
  test_sortfree_card_streams_bit_identical_to_full_sort``).

Run when the draw or chunk layout changes on purpose (new key splits,
different per-chunk folding, reshaped hop draws); do NOT regenerate to
absorb a change that only claims to be a lowering — bit-identity is the
contract, and a moved anchor means that contract broke.
"""
import jax
import jax.numpy as jnp


def montecarlo():
    from repro.core.quorum import QuorumSpec
    from repro.montecarlo import build_mask_table, engine

    out = engine.race(jax.random.PRNGKey(123),
                      build_mask_table([QuorumSpec.paper_headline(11)]),
                      jnp.array([0.0, 0.25]), n=11, k_proposers=2,
                      samples=20_000)
    s = engine.summarize(out)
    print(f"p50_ms          = {float(s['p50_ms'][0]):.6g}")
    print(f"recovery_rate   = {float(s['recovery_rate'][0]):.6g}")
    print(f"latency_ms[0,0] = {float(out['latency_ms'][0, 0]):.7g}")
    print(f"latency_ms[0,1] = {float(out['latency_ms'][0, 1]):.7g}")


def frontier():
    """The n=11 frontier anchor: membership set + the paper-headline row.
    Parameters mirror tests/test_frontier.py (ANCHOR_TRIALS/CHUNK/SEED);
    shard=False keeps the numbers identical on 1 and 8 devices."""
    from repro.frontier import cardinality_family, score_systems

    r = score_systems(cardinality_family(11), trials=49_152, chunk=16_384,
                      shard=False, seed=0)
    print("ANCHOR_MEMBERS = [")
    for lab in sorted(r.frontier_labels):
        print(f"    {lab!r},")
    print("]")
    print("ANCHOR_ROW = {   # card[9,3,7]")
    for k, v in r.row("card[9,3,7]").items():
        if k != "on_frontier":
            print(f"    {k!r}: {v!r},")
    print("}")


def regimes():
    """The 3-regime fixed-seed anchor for tests/test_regimes.py
    (``REGIME_ANCHOR``): occupancy and decide counts are integer-exact,
    so any drift means the regime chain's key domain, the epoch->trial
    mapping, or the per-regime scatter changed.  shard=False: only
    sharded streams draw device keys, so the anchor is layout-invariant;
    the chain itself steps from the REGIME_FOLD_DOMAIN sub-key of the
    per-device key, so a sharded anchor WOULD move with the device grid."""
    from repro.core.quorum import QuorumSpec
    from repro.montecarlo import build_mask_table, streaming
    from repro.montecarlo.regimes import gray_failure

    table = build_mask_table([QuorumSpec.paper_headline(11)])
    s = streaming.race_stream(
        jax.random.PRNGKey(42), table, jnp.array([0.0, 0.25], jnp.float32),
        None, n=11, k_proposers=2, trials=100_000, chunk=16_384,
        shard=False, regimes=gray_failure(11, epoch_trials=2048))
    import numpy as np
    print("REGIME_ANCHOR = {")
    print(f"    'occupancy': {np.asarray(s.occupancy).tolist()!r},")
    print(f"    'n_fast': {int(np.asarray(s.n_fast)[0])},")
    print(f"    'n_recovery': {int(np.asarray(s.n_recovery)[0])},")
    print(f"    'n_undecided': {int(np.asarray(s.n_undecided)[0])},")
    print(f"    'p50_ms': {float(np.asarray(s.quantile(0.5))[0]):.6g},")
    print("}")


if __name__ == "__main__":
    montecarlo()
    frontier()
    regimes()
