"""Quorum-system tests: the paper's Eqs. 1-14, set-level vs cardinality
equivalence, and the strict-relaxation claims of §3/§5."""
import itertools

import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.quorum import (ExplicitQuorumSystem, QuorumSpec,
                               WeightedQuorumSystem, all_valid_specs,
                               fast_paxos_card_ok, fast_paxos_suggested,
                               ffp_card_ok, ffp_min_q2c, ffp_min_q2f,
                               flexible_card_ok, pairwise_intersect,
                               paxos_card_ok, triple_intersect)


# ---------------------------------------------------------------------------
# Cardinality <-> set-level equivalence (small n, exhaustive).
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(n=st.integers(3, 7), q1=st.integers(1, 7), q2c=st.integers(1, 7),
       q2f=st.integers(1, 7))
def test_ffp_cardinality_matches_set_semantics(n, q1, q2c, q2f):
    q1, q2c, q2f = min(q1, n), min(q2c, n), min(q2f, n)
    spec = QuorumSpec(n, q1, q2c, q2f)
    assert spec.is_valid() == spec.check_sets()


@settings(max_examples=40, deadline=None)
@given(n=st.integers(3, 7), q=st.integers(1, 7))
def test_paxos_cardinality_matches_sets(n, q):
    q = min(q, n)
    quorums = [frozenset(c) for c in itertools.combinations(range(n), q)]
    assert paxos_card_ok(n, q) == pairwise_intersect(quorums)


# ---------------------------------------------------------------------------
# The paper's headline configs (§5/§6).
# ---------------------------------------------------------------------------

def test_paper_headline_n11():
    spec = QuorumSpec.paper_headline(11)
    assert (spec.q1, spec.q2c, spec.q2f) == (9, 3, 7)
    assert spec.is_valid()
    # ... but this config violates Fast Paxos' own requirements (Eq. 9/10):
    assert not fast_paxos_card_ok(11, qc=spec.q2c, qf=spec.q2f)


def test_fast_paxos_suggested_configs_are_ffp_valid():
    # §5: Fast Paxos' suggestions are conservative — both satisfy FFP.
    for n in range(3, 30):
        for variant in ("three_quarters", "two_thirds"):
            qc, qf = fast_paxos_suggested(n, variant)
            assert fast_paxos_card_ok(n, qc, qf), (n, variant)
            assert ffp_card_ok(n, q1=qc, q2c=qc, q2f=qf), (n, variant)


def test_ffp_strictly_weaker_than_fast_paxos():
    # every FP-valid (qc, qf) is FFP-valid with q1=qc; and there exist
    # FFP-valid configs that are not FP-valid (the relaxation is strict).
    strictly_weaker = False
    for n in range(3, 12):
        for qc in range(1, n + 1):
            for qf in range(1, n + 1):
                if fast_paxos_card_ok(n, qc, qf):
                    assert ffp_card_ok(n, qc, qc, qf)
        for spec in all_valid_specs(n):
            if not fast_paxos_card_ok(n, spec.q2c, spec.q2f):
                strictly_weaker = True
    assert strictly_weaker


def test_section5_implications():
    # "a simple majority of acceptors is sufficient for phase-1 of fast
    #  rounds" given q_f = ceil(3n/4):
    for n in range(3, 30):
        import math
        qf = math.ceil(3 * n / 4)
        q1_majority = n // 2 + 1
        assert ffp_card_ok(n, q1_majority, q2c=n - q1_majority + 1, q2f=qf)
    # "only one third of acceptors are needed for phase-2 of classic rounds"
    # given q1 = qf = floor(2n/3)+1:
    for n in range(3, 30):
        import math
        q = (2 * n) // 3 + 1
        q2c = math.ceil(n / 3)
        assert ffp_card_ok(n, q1=q, q2c=q2c, q2f=q)


def test_minimal_phase2_quorums():
    for n in range(3, 20):
        for q1 in range(n // 2 + 1, n + 1):
            q2f = ffp_min_q2f(n, q1)
            q2c = ffp_min_q2c(n, q1)
            assert ffp_card_ok(n, q1, q2c, q2f)
            # minimality: one less breaks the requirement
            if q2f > 1:
                assert not ffp_card_ok(n, q1, q2c, q2f - 1)
            if q2c > 1:
                assert not ffp_card_ok(n, q1, q2c - 1, q2f)


def test_fault_tolerance_accounting():
    spec = QuorumSpec.paper_headline(11)
    ft = spec.fault_tolerance()
    assert ft["phase1"] == 2          # 11 - 9
    assert ft["steady_state_fast"] == 4   # 11 - 7
    assert ft["steady_state_classic"] == 8  # 11 - 3


# ---------------------------------------------------------------------------
# Non-cardinality systems (§6 closing remark).
# ---------------------------------------------------------------------------

def test_grid_system_valid_for_three_rows():
    for cols in (2, 3, 4):
        g = ExplicitQuorumSystem.grid(cols)
        assert g.is_valid()


def test_grid_requires_three_rows():
    with pytest.raises(ValueError):
        ExplicitQuorumSystem.grid(3, rows=4)


def test_weighted_system():
    w = WeightedQuorumSystem(weights=(2, 2, 1, 1, 1), t1=6, t2c=2, t2f=5)
    assert w.is_valid()
    # its minimal fast quorums are genuinely non-uniform in cardinality:
    sizes = {len(q) for q in w.enumerate("p2f")}
    assert len(sizes) > 1
    # set-level check of Eq.11/12 on the enumerated quorums:
    p1 = list(w.enumerate("p1"))
    p2c = list(w.enumerate("p2c"))
    p2f = list(w.enumerate("p2f"))
    assert pairwise_intersect(p1, p2c)
    assert triple_intersect(p1, p2f, p2f)


def test_invalid_weighted_rejected():
    with pytest.raises(ValueError):
        WeightedQuorumSystem(weights=(1, 1, 1), t1=1, t2c=1, t2f=1).validate()


@settings(max_examples=50, deadline=None)
@given(st.integers(3, 25))
def test_all_valid_specs_really_valid(n):
    count = 0
    for spec in itertools.islice(all_valid_specs(n), 50):
        assert spec.is_valid()
        count += 1
    assert count > 0
