import sys
import os

# repo root on sys.path so `benchmarks.*` imports resolve in tests
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running end-to-end test")
