"""Property-testing front-end: real ``hypothesis`` when installed, otherwise a
deterministic fallback that drives the same tests from a fixed-seed PRNG.

The container this repo targets does not ship hypothesis, and installing
packages is off-limits, so the suite gates the dependency here.  Only the
surface the tests use is provided: ``given`` (positional and keyword
strategies), ``settings(max_examples=..., deadline=...)``, ``st.integers`` and
``st.composite``.  The fallback enumerates ``max_examples`` pseudo-random
draws per test — less adversarial than hypothesis (no shrinking, no coverage
guidance) but exercising the identical property bodies.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401
except ImportError:
    import random

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rng: random.Random):
            return self._draw_fn(rng)

    class strategies:  # noqa: N801 — mimics the hypothesis module name
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value: float, max_value: float) -> _Strategy:
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def composite(fn):
            def build(*args, **kwargs):
                return _Strategy(
                    lambda rng: fn(lambda s: s.draw(rng), *args, **kwargs))
            return build

    def settings(max_examples: int = 20, **_ignored):
        def deco(f):
            f._max_examples = max_examples
            return f
        return deco

    def given(*arg_strats, **kw_strats):
        def deco(f):
            def wrapper():
                n = getattr(wrapper, "_max_examples",
                            getattr(f, "_max_examples", 20))
                rng = random.Random(0xFFB)
                for _ in range(n):
                    pos = tuple(s.draw(rng) for s in arg_strats)
                    kw = {k: s.draw(rng) for k, s in kw_strats.items()}
                    f(*pos, **kw)
            # plain attribute copies (not functools.wraps) so pytest sees a
            # zero-arg signature instead of the strategy parameters
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            wrapper.__module__ = f.__module__
            return wrapper
        return deco
