"""TLC-lite model checking of the protocol (the paper's Appendix A, §4)."""
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.model_check import explore
from repro.core.quorum import (QuorumSpec, RelaxedQuorumSpec,
                               all_relaxed_specs, ffp_card_ok,
                               relaxed_card_ok)


def test_valid_n3_safe():
    # q1=2, q2c=2, q2f=3: Eqs. 13/14 hold -> no reachable violation.
    r = explore(QuorumSpec(3, 2, 2, 3), max_states=500_000)
    assert r.ok and not r.truncated
    assert r.states > 5_000          # non-trivial exploration


def test_broken_eq14_violates_consistency():
    # q1=2, q2f=2 on n=3 violates Eq.14 only (q1+2*q2f = 6, not > 6):
    # the checker must find two values decided.
    spec = QuorumSpec(3, 2, 2, 2)
    assert not spec.is_valid()
    r = explore(spec, max_states=500_000)
    assert not r.ok
    assert r.violation == "Consistency"
    assert r.trace and r.trace[0] == "Init"


def test_broken_eq13_violates_consistency():
    # q1=1, q2c=2 on n=3 violates Eq.13 (1+2 = 3, not > 3): a classic round
    # can decide without intersecting the next phase-1 quorum.
    spec = QuorumSpec(3, 1, 2, 3)
    assert not spec.is_valid()
    r = explore(spec, fast_rounds="none", max_states=500_000)
    assert not r.ok
    assert r.violation == "Consistency"


def test_valid_asymmetric_n4():
    # n=4: q1=4, q2c=1, q2f=3 (4+1>4; 4+6>8) — extreme §5-style tradeoff.
    spec = QuorumSpec(4, 4, 1, 3)
    assert spec.is_valid()
    r = explore(spec, max_states=400_000)
    assert r.ok


def test_uncoordinated_recovery_safe():
    spec = QuorumSpec(3, 2, 2, 3)
    r = explore(spec, max_round=3, fast_rounds="odd",
                uncoordinated=True, max_states=250_000)
    assert r.ok     # truncation acceptable; no violation within the cap


def test_nontriviality_always_holds_in_valid_configs():
    r = explore(QuorumSpec(3, 3, 1, 3), max_states=300_000)
    assert r.ok and r.violation is None


def test_relaxed_family_bounded_safe_n4():
    """Every relaxed-valid / FFP-invalid triple at n=4 explores clean under
    the bounded budget (the full-family sweep at n <= 5 runs in the CI
    relaxed-smoke job)."""
    specs = list(all_relaxed_specs(4))
    assert len(specs) == 7
    for spec in specs:
        assert relaxed_card_ok(spec.n, spec.q1, spec.q2c, spec.q2f)
        assert not ffp_card_ok(spec.n, spec.q1, spec.q2c, spec.q2f)
        r = explore(spec, max_states=120_000)
        assert r.ok, (spec, r.violation, r.trace)


def test_relaxed_flat_interpretation_unsafe():
    """The differential that makes RelaxedQuorumSpec a distinct type: the
    same (q1, q2c, q2f) numbers read as a *flat* FFP spec (q1 for every
    round's phase 1) violate Consistency once a classic round can decide —
    the relaxed predicate only drops Eq.13 for phase-1 quorums that pick
    from a FAST round, so rounds above a classic one must re-grow to
    q1_full = n + 1 - q2c."""
    flat = QuorumSpec(3, 1, 1, 3)
    assert not flat.is_valid()
    r = explore(flat, max_round=3, max_states=500_000)
    assert not r.ok
    assert r.violation == "Consistency"

    relaxed = RelaxedQuorumSpec(3, 1, 1, 3)
    assert relaxed.is_valid()
    assert relaxed.q1_full == 3          # n + 1 - q2c
    r = explore(relaxed, max_round=3, max_states=500_000)
    assert r.ok, (r.violation, r.trace)


def test_relaxed_uncoordinated_bounded_safe():
    """Recovery-rule x intersection-rule cross product: the uncoordinated
    vote guard stays safe over a relaxed system too."""
    r = explore(RelaxedQuorumSpec(3, 1, 1, 3), max_round=3,
                fast_rounds="odd", uncoordinated=True, max_states=250_000)
    assert r.ok, (r.violation, r.trace)


@pytest.mark.parametrize("spec", [QuorumSpec(4, 4, 1, 3),
                                  QuorumSpec(4, 2, 3, 4)])
def test_uncoordinated_guard_differential_n4(spec):
    """Differential audit of the Phase2b-enabling guards: the same valid
    spec explored with and without the UncoordRecovery action must both be
    violation-free — divergence would mean the python guard admits a vote
    the TLA+ enabling condition forbids (or vice versa)."""
    assert spec.is_valid()
    base = explore(spec, max_round=3, max_states=150_000)
    unco = explore(spec, max_round=3, uncoordinated=True,
                   max_states=150_000)
    assert base.ok, (base.violation, base.trace)
    assert unco.ok, (unco.violation, unco.trace)
    # the extra action only ADDS transitions: the uncoordinated state
    # graph must be at least as large wherever neither run truncated
    if not (base.truncated or unco.truncated):
        assert unco.states >= base.states


@settings(max_examples=8, deadline=None)
@given(q1=st.integers(1, 4), q2c=st.integers(1, 4), q2f=st.integers(1, 4))
def test_valid_specs_never_violate(q1, q2c, q2f):
    """Property (paper Property 1-3): any spec satisfying Eqs.13/14 is safe
    under bounded exploration."""
    n = 4
    spec = QuorumSpec(n, min(q1 + 1, n), q2c, q2f)
    if not spec.is_valid():
        return
    r = explore(spec, max_states=120_000)
    assert r.ok, (spec, r.violation, r.trace)
