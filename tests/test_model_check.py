"""TLC-lite model checking of the protocol (the paper's Appendix A, §4)."""
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.model_check import explore
from repro.core.quorum import QuorumSpec, ffp_card_ok


def test_valid_n3_safe():
    # q1=2, q2c=2, q2f=3: Eqs. 13/14 hold -> no reachable violation.
    r = explore(QuorumSpec(3, 2, 2, 3), max_states=500_000)
    assert r.ok and not r.truncated
    assert r.states > 5_000          # non-trivial exploration


def test_broken_eq14_violates_consistency():
    # q1=2, q2f=2 on n=3 violates Eq.14 only (q1+2*q2f = 6, not > 6):
    # the checker must find two values decided.
    spec = QuorumSpec(3, 2, 2, 2)
    assert not spec.is_valid()
    r = explore(spec, max_states=500_000)
    assert not r.ok
    assert r.violation == "Consistency"
    assert r.trace and r.trace[0] == "Init"


def test_broken_eq13_violates_consistency():
    # q1=1, q2c=2 on n=3 violates Eq.13 (1+2 = 3, not > 3): a classic round
    # can decide without intersecting the next phase-1 quorum.
    spec = QuorumSpec(3, 1, 2, 3)
    assert not spec.is_valid()
    r = explore(spec, fast_rounds="none", max_states=500_000)
    assert not r.ok
    assert r.violation == "Consistency"


def test_valid_asymmetric_n4():
    # n=4: q1=4, q2c=1, q2f=3 (4+1>4; 4+6>8) — extreme §5-style tradeoff.
    spec = QuorumSpec(4, 4, 1, 3)
    assert spec.is_valid()
    r = explore(spec, max_states=400_000)
    assert r.ok


def test_uncoordinated_recovery_safe():
    spec = QuorumSpec(3, 2, 2, 3)
    r = explore(spec, max_round=3, fast_rounds="odd",
                uncoordinated=True, max_states=250_000)
    assert r.ok     # truncation acceptable; no violation within the cap


def test_nontriviality_always_holds_in_valid_configs():
    r = explore(QuorumSpec(3, 3, 1, 3), max_states=300_000)
    assert r.ok and r.violation is None


@settings(max_examples=8, deadline=None)
@given(q1=st.integers(1, 4), q2c=st.integers(1, 4), q2f=st.integers(1, 4))
def test_valid_specs_never_violate(q1, q2c, q2f):
    """Property (paper Property 1-3): any spec satisfying Eqs.13/14 is safe
    under bounded exploration."""
    n = 4
    spec = QuorumSpec(n, min(q1 + 1, n), q2c, q2f)
    if not spec.is_valid():
        return
    r = explore(spec, max_states=120_000)
    assert r.ok, (spec, r.violation, r.trace)
