"""Monte-Carlo engine vs discrete-event simulator cross-validation.

The batched engine (``repro.montecarlo``) is an *analytic* model — order
statistics over sampled delays — while ``repro.core.simulator`` runs the
actual protocol state machines over a simulated network.  They share one
delay distribution (the §6 EC2 shifted-lognormal fit), so on the paper's
n=11 configurations — and on a 3x2 *grid* quorum system exercising the
general masked path — they must agree, within Monte-Carlo tolerance, on

  * conflict-free fast-path p50 latency, and
  * P(coordinated recovery) in K-proposer races, K ∈ {2, 3}.

Agreement here is what licenses the benchmarks to sweep the quorum space
with the (much faster) engine.

The recovery-rule sweep extends the same licence to the PR-10 axes: both
collision-recovery rules (coordinated q2c commit vs uncoordinated q2f
vote, arXiv 1710.08047), on both an FFP and a Relaxed-Paxos system
(arXiv 2203.03058), must agree between backends on P(recovery) and on
race-commit p50.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core.quorum import (ExplicitQuorumSystem, QuorumSpec,
                               RelaxedQuorumSpec)
from repro.core.simulator import (FastPaxosSim, conflict_free_workload,
                                  latency_stats)
from repro.montecarlo import build_mask_table, engine

FFP = QuorumSpec.paper_headline(11)
FP = QuorumSpec.fast_paxos(11)
GRID = ExplicitQuorumSystem.grid(2)          # 3x2 grid, n=6
RELAXED = RelaxedQuorumSpec(11, 5, 2, 9)     # relaxed-valid, FFP-invalid
KEY = jax.random.PRNGKey(3)
DELTA_MS = 0.2
MC_SAMPLES = 60_000
DES_PAIRS = 800


def _des_recovery_prob(spec, k_proposers: int, delta_ms: float,
                       pairs: int, seed: int = 0) -> float:
    """K proposals race per instance in the event simulator; instances are
    spaced far apart so races are independent."""
    sim = FastPaxosSim(spec, seed=seed)
    t = 0.0
    for i in range(pairs):
        for k in range(k_proposers):
            sim.submit(t + k * delta_ms, instance=i, value=f"v{i}_{k}",
                       proposer=k)
        t += 50.0
    sim.run()
    return sim.recovery_entries / pairs


@pytest.mark.parametrize("spec", [FFP, FP], ids=["ffp", "fp"])
def test_fast_path_p50_matches_des(spec):
    table = build_mask_table([spec])
    mc_p50 = float(jnp.median(
        engine.fast_path(KEY, table, n=spec.n, samples=MC_SAMPLES)[0]))
    sim = FastPaxosSim(spec, seed=11)
    conflict_free_workload(sim, 3000, rate_per_s=1400)
    des_p50 = latency_stats(sim.run())["p50_ms"]
    assert abs(mc_p50 - des_p50) / des_p50 < 0.05, (mc_p50, des_p50)


@pytest.mark.parametrize("spec", [FFP, FP], ids=["ffp", "fp"])
@pytest.mark.parametrize("k_proposers", [2, 3])
def test_recovery_probability_matches_des(spec, k_proposers):
    table = build_mask_table([spec])
    offsets = DELTA_MS * jnp.arange(k_proposers, dtype=jnp.float32)
    out = engine.race(KEY, table, offsets, n=spec.n,
                      k_proposers=k_proposers, samples=MC_SAMPLES)
    p_mc = float(out["recovery"][0].mean())
    p_des = _des_recovery_prob(spec, k_proposers, DELTA_MS, DES_PAIRS)
    # binomial noise at 800 DES races is ~0.017 std at p=0.4; 0.05 gives
    # ~3 sigma headroom while still catching modelling drift
    assert abs(p_mc - p_des) < 0.05, (spec, k_proposers, p_mc, p_des)


def test_grid_fast_path_p50_matches_des():
    """General-quorum cross-validation: the masked engine and the DES (both
    running the 3x2 grid system — fast quorums are *specific* row pairs, not
    counts) must agree on conflict-free fast-path p50 within 5%."""
    table = build_mask_table([GRID])
    mc_p50 = float(jnp.median(
        engine.fast_path(KEY, table, n=GRID.n, samples=MC_SAMPLES)[0]))
    sim = FastPaxosSim(GRID, seed=11)
    conflict_free_workload(sim, 3000, rate_per_s=1400)
    des_p50 = latency_stats(sim.run())["p50_ms"]
    assert abs(mc_p50 - des_p50) / des_p50 < 0.05, (mc_p50, des_p50)


@pytest.mark.parametrize("k_proposers", [2, 3])
def test_grid_recovery_probability_matches_des(k_proposers):
    """P(coordinated recovery) on the grid for K-proposer races: the DES runs
    the generalized set-level protocol (contains_q1/contains_q2), the engine
    the masked saturation path — agreement within 0.05 absolute."""
    table = build_mask_table([GRID])
    offsets = DELTA_MS * jnp.arange(k_proposers, dtype=jnp.float32)
    out = engine.race(KEY, table, offsets, n=GRID.n,
                             k_proposers=k_proposers, samples=MC_SAMPLES)
    p_mc = float(out["recovery"][0].mean())
    p_des = _des_recovery_prob(GRID, k_proposers, DELTA_MS, DES_PAIRS)
    assert abs(p_mc - p_des) < 0.05, (k_proposers, p_mc, p_des)


def _des_race_stats(spec, k_proposers: int, delta_ms: float, pairs: int,
                    seed: int = 0, recovery: str = "coordinated"):
    """(P(recovery), decided-commit p50) for K-proposer races in the DES.
    Latency is measured from the instance's FIRST submit — the engine's
    t=0 reference — so the two backends price the same clock."""
    sim = FastPaxosSim(spec, seed=seed, recovery=recovery)
    base = {}
    t = 0.0
    for i in range(pairs):
        base[i] = t
        for k in range(k_proposers):
            sim.submit(t + k * delta_ms, instance=i, value=f"v{i}_{k}",
                       proposer=k)
        t += 50.0
    sim.run()
    lats = sorted(ist.decide_time - base[i]
                  for i, ist in sim.instances.items()
                  if ist.decided is not None)
    assert lats, "no decided instances"
    return (sim.recovery_entries / pairs, lats[len(lats) // 2])


@pytest.mark.parametrize("recovery", ["coordinated", "uncoordinated"])
@pytest.mark.parametrize("k_proposers", [2, 3])
@pytest.mark.parametrize("spec", [FFP, RELAXED], ids=["ffp", "relaxed"])
def test_recovery_rules_match_des(spec, k_proposers, recovery):
    """Both recovery rules, both intersection predicates: the analytic
    engine and the protocol-state-machine DES agree on P(recovery) within
    0.05 absolute and on race-commit p50 within 5% for K in {2, 3}."""
    table = build_mask_table([spec])
    offsets = DELTA_MS * jnp.arange(k_proposers, dtype=jnp.float32)
    out = engine.race(KEY, table, offsets, n=spec.n,
                      k_proposers=k_proposers, samples=MC_SAMPLES,
                      recovery=recovery)
    p_mc = float(out["recovery"][0].mean())
    mc_p50 = float(jnp.median(out["latency_ms"][0]))
    p_des, des_p50 = _des_race_stats(spec, k_proposers, DELTA_MS,
                                     DES_PAIRS, recovery=recovery)
    assert abs(p_mc - p_des) < 0.05, (spec, k_proposers, recovery,
                                      p_mc, p_des)
    assert abs(mc_p50 - des_p50) / des_p50 < 0.05, (
        spec, k_proposers, recovery, mc_p50, des_p50)


def test_more_proposers_mean_more_recoveries():
    """Sanity on the K generalization: contention can only hurt."""
    table = build_mask_table([FFP])
    rates = []
    for k in (2, 3, 4):
        offsets = DELTA_MS * jnp.arange(k, dtype=jnp.float32)
        out = engine.race(KEY, table, offsets, n=11, k_proposers=k,
                          samples=MC_SAMPLES)
        rates.append(float(out["recovery"][0].mean()))
    assert rates[0] <= rates[1] + 0.01 <= rates[2] + 0.02, rates
