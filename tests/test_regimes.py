"""Scenario-regime tests (``repro.montecarlo.traces`` / ``regimes`` and the
declarative ``Workload`` config API, DESIGN.md §12): trace-replay quantile
fidelity, Markov-chain validation, the single-regime i.i.d. degeneracy
contract, chunk-invariance of regime occupancy, compile discipline,
``Workload``/``Experiment`` config round-trips, and the ``RunSpec``
deprecation shims."""
import dataclasses
import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.quorum import QuorumSpec
from repro.montecarlo import build_mask_table, engine, streaming
from repro.montecarlo.regimes import (MarkovRegimes, RegimeStreamSummary,
                                      gray_failure)
from repro.montecarlo.scenarios import RunSpec, k_way_race
from repro.montecarlo.traces import EmpiricalDelay

KEY = jax.random.PRNGKey(0)
FFP = QuorumSpec.paper_headline(11)
TABLE = build_mask_table([FFP])
OFFS = jnp.array([0.0, 0.25], jnp.float32)

EXAMPLES = os.path.join(os.path.dirname(__file__), "..", "examples",
                        "scenarios")

# Fixed-seed regression anchor — regenerate via tests/regen_anchors.py
# (``regimes()``).  Occupancy and decide counts are integer-exact: any
# drift means the regime key domain, the epoch->trial mapping, or the
# per-regime scatter changed.
REGIME_ANCHOR = {
    'occupancy': [89760, 0, 10240],
    'n_fast': 94869,
    'n_recovery': 1502,
    'n_undecided': 3629,
    'p50_ms': 1.22746,
}


def _race(key, trials, chunk, regimes=None, k_max="auto"):
    return streaming.race_stream(key, TABLE, OFFS, None, n=11,
                                 k_proposers=2, trials=trials, chunk=chunk,
                                 shard=False, k_max=k_max, regimes=regimes)


# ---------------------------------------------------------------------------
# EmpiricalDelay: trace replay as an inverse-CDF quantile table
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 16), size=st.integers(5, 2_000),
       scale=st.floats(0.2, 20.0))
def test_empirical_sampled_quantiles_match_trace(seed, size, scale):
    """Satellite: sampled quantiles land between the model's own quantile
    values one grid step around the target probability (grid resolution
    1/(Q-1) — below the stream sketch's default precision) plus Monte-
    Carlo rank noise at the sample count."""
    rng = np.random.default_rng(seed)
    d = EmpiricalDelay.from_trace(scale * rng.lognormal(0.0, 0.6, size)
                                  + 0.05)
    samp = np.asarray(d.sample_hops(jax.random.PRNGKey(seed), (100_000,)))
    for q in (0.1, 0.5, 0.9, 0.99):
        got = float(np.quantile(samp, q))
        # bracket by the model's quantile function +-1% in probability
        # (grid step 1/255 ~ 0.4%, plus ~3 sigma of rank noise at 1e5)
        lo = float(d.quantile(max(0.0, q - 0.01)))
        hi = float(d.quantile(min(1.0, q + 0.01)))
        assert lo - 1e-3 <= got <= hi + 1e-3, (q, got, lo, hi)


def test_empirical_degenerate_single_point_trace():
    d = EmpiricalDelay.from_trace([0.7])
    samp = np.asarray(d.sample_hops(KEY, (4, 257, 11)))
    assert samp.shape == (4, 257, 11)
    np.testing.assert_allclose(samp, 0.7, rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), size=st.integers(1, 500))
def test_empirical_grid_monotone_by_construction(seed, size):
    """from_trace always yields a strictly increasing prob grid and
    non-decreasing quantile values — validate() accepts its own output."""
    rng = np.random.default_rng(seed)
    d = EmpiricalDelay.from_trace(rng.exponential(2.0, size)).validate()
    p = np.asarray(d.probs)
    v = np.asarray(d.values_ms)
    assert np.all(np.diff(p) > 0) and p[0] == 0.0 and p[-1] == 1.0
    assert np.all(np.diff(v) >= 0)


def test_empirical_rejects_non_monotone_grid():
    good = EmpiricalDelay.from_trace([1.0, 2.0, 3.0, 4.0], n_quantiles=4)
    with pytest.raises(ValueError, match="non-decreasing"):
        EmpiricalDelay(probs=good.probs,
                       values_ms=jnp.array([1.0, 3.0, 2.0, 4.0],
                                           jnp.float32)).validate()
    with pytest.raises(ValueError, match="strictly increasing"):
        EmpiricalDelay(probs=jnp.array([0.0, 0.6, 0.4, 1.0], jnp.float32),
                       values_ms=good.values_ms).validate()
    with pytest.raises(ValueError, match="non-finite"):
        EmpiricalDelay.from_trace([1.0, np.inf])
    with pytest.raises(ValueError, match="negative"):
        EmpiricalDelay.from_trace([1.0, -0.5])


def test_empirical_config_roundtrip_exact():
    from repro.montecarlo.latency import delay_from_config, delay_to_config
    d = EmpiricalDelay.from_trace(
        np.random.default_rng(3).lognormal(0.5, 0.4, 300), n_quantiles=64)
    cfg = json.loads(json.dumps(delay_to_config(d)))
    d2 = delay_from_config(cfg)
    np.testing.assert_array_equal(np.asarray(d.probs, np.float32),
                                  np.asarray(d2.probs, np.float32))
    np.testing.assert_array_equal(np.asarray(d.values_ms, np.float32),
                                  np.asarray(d2.values_ms, np.float32))
    # raw-trace form fits at load time
    d3 = delay_from_config({"kind": "empirical",
                            "trace_ms": [0.3, 0.4, 0.5], "n_quantiles": 8})
    assert np.asarray(d3.probs).shape == (8,)


def test_empirical_composes_with_wrappers_in_stream():
    from repro.montecarlo.latency import LOST_MS, LossyDelay
    d = LossyDelay(EmpiricalDelay.from_trace([0.3, 0.4, 0.5, 0.9]),
                   loss_prob=0.5)
    s = streaming.fast_path_stream(KEY, TABLE, d, n=11, trials=20_000,
                                   chunk=8_192, shard=False)
    # heavy loss must produce undecided trials (lost hops reach LOST_MS)
    assert int(np.asarray(s.n_undecided)[0]) > 0
    assert float(np.asarray(s.max_ms)[0]) < LOST_MS


# ---------------------------------------------------------------------------
# MarkovRegimes: validation + the chain itself
# ---------------------------------------------------------------------------

def test_transition_row_sum_validation():
    bad = dataclasses.replace(
        gray_failure(11),
        transition=jnp.array([[0.9, 0.2, 0.0],
                              [0.1, 0.9, 0.0],
                              [0.2, 0.0, 0.8]], jnp.float32))
    with pytest.raises(ValueError, match="sum to 1"):
        bad.validate()
    with pytest.raises(ValueError, match=">= 0"):
        dataclasses.replace(
            gray_failure(11),
            transition=jnp.array([[1.5, -0.5, 0.0],
                                  [0.1, 0.9, 0.0],
                                  [0.2, 0.0, 0.8]],
                                 jnp.float32)).validate()
    with pytest.raises(ValueError, match="unique"):
        dataclasses.replace(gray_failure(11),
                            names=("a", "a", "b")).validate()
    with pytest.raises(ValueError, match="start"):
        dataclasses.replace(gray_failure(11), start=3).validate()


def test_chain_prefix_property():
    """z[e] does not depend on the scan length — the property that makes
    regime occupancy chunk-invariant (longer runs only append epochs)."""
    reg = gray_failure(11, p_fail=0.2, p_recover=0.3)
    k = jax.random.PRNGKey(9)
    short = np.asarray(reg.sequence(k, 10))
    long = np.asarray(reg.sequence(k, 50))
    np.testing.assert_array_equal(short, long[:10])
    assert short[0] == reg.start


def test_identity_transition_pins_chain():
    reg = dataclasses.replace(gray_failure(11), start=1,
                              transition=jnp.eye(3, dtype=jnp.float32))
    zs = np.asarray(reg.sequence(KEY, 20))
    np.testing.assert_array_equal(zs, np.ones(20, np.int32))


def test_regimes_config_roundtrip():
    reg = gray_failure(11, epoch_trials=1024)
    cfg = json.loads(json.dumps(reg.to_config()))
    reg2 = MarkovRegimes.from_config(cfg, 11)
    assert reg2.to_config() == reg.to_config()
    assert reg2.names == reg.names
    np.testing.assert_allclose(np.asarray(reg2.transition),
                               np.asarray(reg.transition))
    with pytest.raises(ValueError, match="cluster size"):
        MarkovRegimes.from_config(cfg)          # crashed list needs n


# ---------------------------------------------------------------------------
# Regime-modulated streaming: degeneracy, invariance, compile discipline
# ---------------------------------------------------------------------------

def test_single_regime_bit_identical_to_iid():
    """The acceptance contract: a 1-regime chain inheriting the base delay
    is bit-identical to the plain i.i.d. stream on decide bits, counts and
    histogram (the mixed-delay wrapper passes the key through unfolded)."""
    only = MarkovRegimes(names=("only",), delays=(None,),
                         transition=jnp.ones((1, 1), jnp.float32))
    plain = _race(KEY, trials=50_000, chunk=8_192)
    mod = _race(KEY, trials=50_000, chunk=8_192, regimes=only)
    assert isinstance(mod, RegimeStreamSummary)
    assert int(np.asarray(mod.occupancy).sum()) == 50_000
    for attr in ("n_trials", "n_fast", "n_recovery", "n_undecided", "hist"):
        np.testing.assert_array_equal(np.asarray(getattr(plain, attr)),
                                      np.asarray(getattr(mod, attr)),
                                      err_msg=attr)


def test_single_regime_bit_identical_on_full_sort_path():
    """Same degeneracy under k_max=None (the full-sort reference path):
    the regime layer must not disturb either lowering."""
    only = MarkovRegimes(names=("only",), delays=(None,),
                         transition=jnp.ones((1, 1), jnp.float32))
    plain = _race(KEY, trials=30_000, chunk=8_192, k_max=None)
    mod = _race(KEY, trials=30_000, chunk=8_192, k_max=None, regimes=only)
    for attr in ("n_trials", "n_fast", "n_recovery", "n_undecided", "hist"):
        np.testing.assert_array_equal(np.asarray(getattr(plain, attr)),
                                      np.asarray(getattr(mod, attr)),
                                      err_msg=attr)


def test_regime_occupancy_chunk_invariant():
    """Trial t's regime is z[t // epoch_trials] in TRIAL index space, so
    the occupancy split cannot depend on how trials are chunked."""
    reg = gray_failure(11, epoch_trials=1_000, p_fail=0.1, p_recover=0.3)
    occ = {}
    for chunk in (4_096, 8_192, 16_384):
        s = _race(jax.random.PRNGKey(5), trials=37_111, chunk=chunk,
                  regimes=reg)
        occ[chunk] = np.asarray(s.occupancy)
        assert int(occ[chunk].sum()) == 37_111
    np.testing.assert_array_equal(occ[4_096], occ[8_192])
    np.testing.assert_array_equal(occ[4_096], occ[16_384])


def test_regime_slices_merge_to_marginal_totals():
    reg = gray_failure(11, epoch_trials=2_048, p_fail=0.1, p_recover=0.3)
    s = _race(jax.random.PRNGKey(3), trials=60_000, chunk=8_192,
              regimes=reg)
    tot = s.total()
    per = [s.regime(i) for i in range(s.n_regimes)]
    assert int(np.asarray(tot.n_trials)[0]) == 60_000
    for attr in ("n_trials", "n_fast", "n_recovery", "n_undecided"):
        merged = sum(int(np.asarray(getattr(p, attr))[0]) for p in per)
        assert merged == int(np.asarray(getattr(tot, attr))[0]), attr
    np.testing.assert_array_equal(
        sum(np.asarray(p.hist) for p in per), np.asarray(tot.hist))
    # occupancy counts every trial exactly once
    np.testing.assert_array_equal(
        np.asarray(s.occupancy),
        np.asarray([int(np.asarray(p.n_trials)[0]) for p in per]))


def test_regime_merge_and_report():
    reg = gray_failure(11, epoch_trials=1_024, p_fail=0.1, p_recover=0.3)
    a = _race(jax.random.PRNGKey(1), trials=20_000, chunk=8_192,
              regimes=reg)
    b = _race(jax.random.PRNGKey(2), trials=20_000, chunk=8_192,
              regimes=reg)
    m = a.merge(b)
    assert int(np.asarray(m.occupancy).sum()) == 40_000
    rep = m.report()
    assert rep["names"] == ["baseline", "degraded", "partitioned"]
    assert abs(sum(rep["occupancy_frac"]) - 1.0) < 1e-9
    other = dataclasses.replace(a, names=("x", "y", "z"))
    with pytest.raises(ValueError, match="regime sets"):
        a.merge(other)


def test_regime_stream_single_compile_per_geometry():
    """One fresh trace for a new (table shape, chunk count, R, epoch)
    geometry; transition weights, environment parameters and the seed are
    traced operands — re-weighting the chain adds ZERO compiles."""
    kw = dict(trials=40_000, chunk=8_192)
    t0 = engine.TRACE_COUNTS["race_stream_regimes"]
    _race(jax.random.PRNGKey(1), regimes=gray_failure(11, epoch_trials=2_048),
          **kw)
    assert engine.TRACE_COUNTS["race_stream_regimes"] - t0 == 1
    _race(jax.random.PRNGKey(2),
          regimes=gray_failure(11, epoch_trials=2_048, p_fail=0.2,
                               p_recover=0.5, loss_prob=0.1,
                               degraded_scale_ms=2.0),
          **kw)
    assert engine.TRACE_COUNTS["race_stream_regimes"] - t0 == 1


def test_regime_fixed_seed_anchor():
    """Fixed-seed regression anchor (tests/regen_anchors.py): integer-
    exact occupancy + decide counts pin the regime key domain and the
    epoch->trial mapping; p50 pins the sketch within float tolerance."""
    s = streaming.race_stream(
        jax.random.PRNGKey(42), TABLE, OFFS, None, n=11, k_proposers=2,
        trials=100_000, chunk=16_384, shard=False,
        regimes=gray_failure(11, epoch_trials=2_048))
    assert np.asarray(s.occupancy).tolist() == REGIME_ANCHOR["occupancy"]
    assert int(np.asarray(s.n_fast)[0]) == REGIME_ANCHOR["n_fast"]
    assert int(np.asarray(s.n_recovery)[0]) == REGIME_ANCHOR["n_recovery"]
    assert (int(np.asarray(s.n_undecided)[0])
            == REGIME_ANCHOR["n_undecided"])
    assert (abs(float(np.asarray(s.quantile(0.5))[0])
                - REGIME_ANCHOR["p50_ms"])
            < 1e-4 * REGIME_ANCHOR["p50_ms"])


@pytest.mark.slow
def test_ten_million_trial_three_regime_single_compile():
    """The ISSUE acceptance row: a 10^7-trial 3-regime race sweep in ONE
    compile per table shape, fixed-size per-regime state."""
    t0 = engine.TRACE_COUNTS["race_stream_regimes"]
    s = _race(jax.random.PRNGKey(7), trials=10_000_000, chunk=262_144,
              regimes=gray_failure(11, epoch_trials=8_192))
    assert engine.TRACE_COUNTS["race_stream_regimes"] - t0 == 1
    occ = np.asarray(s.occupancy, np.int64)
    assert int(occ.sum()) == 10_000_000
    assert occ[0] > 0                     # the chain spends time in baseline
    assert int(np.asarray(s.n_trials)[0]) == 10_000_000
    assert s.by_regime.hist.shape == (3, 1,
                                      streaming.sketch_bins(s.precision))


# ---------------------------------------------------------------------------
# Workload / Experiment declarative configs
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(pick=st.integers(0, 4), k=st.integers(2, 4),
       delta=st.floats(0.0, 1.0), p=st.floats(0.001, 0.2))
def test_workload_to_dict_roundtrips_every_constructor(pick, k, delta, p):
    """Satellite acceptance: Workload.from_dict(w.to_dict()) is a fixpoint
    for every constructor (compare dicts — delay fields hold arrays)."""
    from repro.api.experiment import Workload
    wl = [Workload.conflict_free(),
          Workload.race(k=k, delta_ms=delta),
          Workload.mixed(conflict_frac=p, k=k, delta_ms=delta),
          Workload.wan(k=k, inter_region_ms=10.0 + 100.0 * p),
          Workload.lossy(loss_prob=p, k=k, delta_ms=delta)][pick]
    d = wl.to_dict()
    d2 = Workload.from_dict(json.loads(json.dumps(d))).to_dict()
    assert d == d2


def test_workload_roundtrip_with_trace_and_regimes():
    from repro.api.experiment import Workload
    wl = Workload.race(
        k=2, delta_ms=0.2,
        delay=EmpiricalDelay.from_trace([0.3, 0.5, 0.4, 0.9],
                                        n_quantiles=16),
        regimes=gray_failure(7, epoch_trials=512))
    d = wl.to_dict()
    wl2 = Workload.from_dict(json.loads(json.dumps(d)))
    assert wl2.to_dict() == d
    # lazy configs resolve once the cluster size is known
    assert isinstance(wl2.delay_for(7), EmpiricalDelay)
    r = wl2.regimes_for(7)
    assert isinstance(r, MarkovRegimes) and r.names == (
        "baseline", "degraded", "partitioned")


def test_workload_kind_shorthand():
    from repro.api.experiment import Workload
    wl = Workload.from_dict({"kind": "race", "k": 3, "delta_ms": 0.1})
    assert wl.k_proposers == 3 and wl.delta_ms == 0.1
    with pytest.raises(ValueError, match="unknown workload kind"):
        Workload.from_dict({"kind": "nope"})
    with pytest.raises(ValueError, match="at least 2"):
        Workload.from_dict({"kind": "race", "k": 1})


def test_workload_from_dict_rejects_unknown_keys():
    """Satellite: a typo'd key raises a ValueError naming the offending
    key AND the valid set — on both the kind-shorthand and plain paths —
    instead of an opaque ctor TypeError or a silently dropped knob."""
    from repro.api.experiment import Workload
    with pytest.raises(ValueError) as ei:
        Workload.from_dict({"kind": "race", "k": 2, "delta_mss": 0.1})
    assert "delta_mss" in str(ei.value) and "delta_ms" in str(ei.value)
    with pytest.raises(ValueError) as ei:
        Workload.from_dict({"k_proposers": 2, "trialz": 7})
    assert "trialz" in str(ei.value) and "valid keys" in str(ei.value)
    # recovery is a real key on both paths
    wl = Workload.from_dict({"kind": "race", "k": 2,
                             "recovery": "uncoordinated"})
    assert wl.recovery == "uncoordinated"
    with pytest.raises(ValueError, match="unknown recovery rule"):
        Workload.from_dict({"kind": "race", "k": 2, "recovery": "oracle"})


def test_workload_from_dict_rejects_unknown_delay_kind():
    """A delay config whose registry name does not resolve fails up front
    with the known-kind list, including when nested under a wrapper."""
    from repro.api.experiment import Workload
    from repro.montecarlo.latency import delay_kinds
    with pytest.raises(ValueError) as ei:
        Workload.from_dict({"kind": "race", "k": 2,
                            "delay": {"kind": "warp"}})
    msg = str(ei.value)
    assert "warp" in msg
    for known in delay_kinds():
        assert known in msg
    with pytest.raises(ValueError, match="warp"):
        Workload.from_dict({
            "k_proposers": 2,
            "delay": {"kind": "lossy", "loss_prob": 0.1,
                      "inner": {"kind": "warp"}}})


def test_workload_recovery_roundtrip():
    """recovery serializes (dropped at default), round-trips, and reaches
    the scenario spec."""
    from repro.api.experiment import Workload
    wl = Workload.race(k=2, delta_ms=0.2, recovery="uncoordinated")
    d = wl.to_dict()
    assert d["recovery"] == "uncoordinated"
    assert "recovery" not in Workload.race(k=2, delta_ms=0.2).to_dict()
    wl2 = Workload.from_dict(json.loads(json.dumps(d)))
    assert wl2.recovery == "uncoordinated"
    assert wl2.scenario(5).spec.recovery == "uncoordinated"


@pytest.mark.parametrize("name", ["diurnal_wan.json", "trace_replay.json"])
def test_experiment_from_committed_config(name):
    """The committed example scenario configs load, lower and stream; the
    regime decomposition covers every trial exactly once."""
    from repro.api.experiment import Experiment
    exp = Experiment.from_config(os.path.join(EXAMPLES, name))
    exp = dataclasses.replace(exp, trials=20_000, chunk=8_192, shard=False)
    r = exp.run("montecarlo")
    assert isinstance(r.stream, RegimeStreamSummary)
    assert int(np.asarray(r.stream.occupancy).sum()) == 20_000
    assert int(np.asarray(r.stream.n_trials).sum()) \
        == 20_000 * len(exp.systems)
    for v in r.summary.values():
        assert np.asarray(v).shape == (len(exp.systems),)


def test_experiment_from_config_dict_and_system_kinds():
    from repro.api.experiment import Experiment, system_from_config
    from repro.core.quorum import (ExplicitQuorumSystem,
                                  WeightedQuorumSystem)
    s = system_from_config({"kind": "cardinality", "preset":
                            "paper_headline", "n": 11})
    assert isinstance(s, QuorumSpec) and (s.q1, s.q2c, s.q2f) == (9, 3, 7)
    g = system_from_config({"kind": "grid", "cols": 3, "rows": 3, "n": 11})
    assert isinstance(g, ExplicitQuorumSystem) and g.n == 11
    w = system_from_config({"kind": "weighted",
                            "weights": [2, 2, 1, 1, 1], "t1": 6, "t2c": 2,
                            "t2f": 5})
    assert isinstance(w, WeightedQuorumSystem)
    with pytest.raises(ValueError, match="unknown system kind"):
        system_from_config({"kind": "pyramid"})

    exp = Experiment.from_config({
        "systems": [{"kind": "cardinality", "n": 5, "q1": 4, "q2c": 2,
                     "q2f": 4}],
        "workload": {"kind": "race", "k": 2, "delta_ms": 0.2},
        "samples": 2_000, "seed": 3})
    r = exp.run("montecarlo")
    assert r.backend == "montecarlo" and len(r.labels) == 1


def test_planner_accepts_serialized_workload_dict():
    """Satellite: the planner's wire format takes full Workload.to_dict()
    payloads (not just the ctor shorthand), and regime chains change the
    search geometry key."""
    from repro.api.experiment import Workload
    from repro.planner import Planner
    from repro.planner.service import PlanQuery, resolve_workload

    full = Workload.lossy(loss_prob=0.05, k=3, delta_ms=0.4).to_dict()
    wl = resolve_workload(json.loads(json.dumps(full)))
    assert wl.to_dict() == full
    assert resolve_workload({"kind": "wan",
                             "inter_region_ms": 25.0}).inter_region_ms \
        == 25.0

    p = Planner()
    base = dict(n=7, family="cardinality", trials=10_000, chunk=8_192,
                shard=False)
    with_reg = Workload.race(
        k=2, delta_ms=0.2,
        regimes=gray_failure(7, epoch_trials=512).to_config()).to_dict()
    q1 = PlanQuery(workload=json.loads(json.dumps(with_reg)), **base)
    q2 = PlanQuery(workload={"kind": "race", "k": 2, "delta_ms": 0.2},
                   **base)
    assert p.geometry_key(q1) != p.geometry_key(q2)


# ---------------------------------------------------------------------------
# RunSpec: one spec object carries the engine knobs; legacy kwargs are gone
# ---------------------------------------------------------------------------

def test_runspec_is_the_only_knob_path():
    """The PR-9 keyword shims are deleted: run/summary/stream take exactly
    (key, table), and any legacy engine-knob keyword is a plain
    TypeError, not a DeprecationWarning."""
    scen = k_way_race(2, 0.25)
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # no warning of any kind
        new = scen.with_spec(trials=20_000, chunk=8_192,
                             shard=False).stream(KEY, TABLE)
    assert int(np.asarray(new.n_trials)[0]) == 20_000
    with pytest.raises(TypeError):
        scen.stream(KEY, TABLE, trials=20_000)
    with pytest.raises(TypeError):
        scen.stream(KEY, TABLE, k_max=None)
    with pytest.raises(TypeError):
        scen.run(KEY, TABLE, samples=4_000)
    with pytest.raises(TypeError):
        scen.summary(KEY, TABLE, trials=20_000)


def test_runspec_merged_and_sentinel_k_max():
    spec = RunSpec().merged(trials=5, chunk=1_024)
    assert spec.trials == 5 and spec.chunk == 1_024
    assert spec.merged().trials == 5          # no-op merge keeps values
    # explicit k_max=None (full-sort reference) survives the spec plumbing
    scen = k_way_race(2, 0.25).with_spec(trials=12_000, chunk=8_192,
                                      shard=False)
    full = scen.with_spec(k_max=None).stream(KEY, TABLE)
    auto = scen.stream(KEY, TABLE)
    np.testing.assert_array_equal(np.asarray(full.hist),
                                  np.asarray(auto.hist))


def test_runspec_carries_recovery_rule():
    """``recovery`` rides the spec like every other knob: the entry rate is
    rule-invariant, the streamed histograms differ, and an unknown rule
    raises before any engine work."""
    scen = k_way_race(2, 0.25).with_spec(trials=20_000, chunk=8_192,
                                         shard=False)
    sc = scen.stream(KEY, TABLE)
    su = scen.with_spec(recovery="uncoordinated").stream(KEY, TABLE)
    np.testing.assert_array_equal(np.asarray(sc.n_recovery),
                                  np.asarray(su.n_recovery))
    assert not np.array_equal(np.asarray(sc.hist), np.asarray(su.hist))
    with pytest.raises(ValueError, match="unknown recovery rule"):
        scen.with_spec(recovery="oracle").stream(KEY, TABLE)


def test_scenario_spec_carries_regimes_through_workload():
    from repro.api.experiment import Workload
    wl = Workload.race(k=2, delta_ms=0.25,
                       regimes=gray_failure(11, epoch_trials=1_024))
    scen = wl.scenario(11)
    assert scen.spec.regimes is not None
    s = scen.with_spec(trials=20_000, chunk=8_192, shard=False).stream(
        KEY, TABLE)
    assert isinstance(s, RegimeStreamSummary)
    assert int(np.asarray(s.occupancy).sum()) == 20_000
