"""Cluster control-plane tests: consensus log, typed records, elastic
membership, failure detection, straggler verdicts."""
import pytest

from repro.cluster import (ConsensusLog, ControlPlane, MembershipManager,
                           PhiAccrualDetector, StragglerPolicy)
from repro.cluster.membership import plan_mesh, quorum_policy
from repro.core.quorum import QuorumSpec

SPEC = QuorumSpec.paper_headline(11)


def test_fast_path_commit():
    log = ConsensusLog(SPEC, seed=0)
    out = log.propose("x")
    assert out.fast and out.value == "x" and out.slot == 0
    assert log.stats["fast"] == 1


def test_race_resolves_to_single_value():
    log = ConsensusLog(SPEC, seed=1)
    out = log.propose_racing(["a", "b"])
    assert out.value in ("a", "b")
    assert log.decided[out.slot].value == out.value


def test_forced_collision_recovery():
    log = ConsensusLog(SPEC, seed=2)
    # interleave arrivals so neither value reaches q2f=7 of 11:
    order_a = [0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10]
    order_b = list(reversed(order_a))
    out = log.propose_racing(["a", "b"], arrival_orders=[order_a, order_b])
    assert out.recovered and not out.fast
    assert out.value in ("a", "b")
    # round-robin interleave: a gets 0..4 + 5, b gets 10..6 -> 6/5 split < 7
    assert log.stats["recovered"] == 1


def test_slot_already_decided_aborts_later_proposals():
    log = ConsensusLog(SPEC, seed=3)
    out1 = log.propose("a", slot=5)
    out2 = log.propose("b", slot=5)
    assert out2.value == "a"
    assert log.stats["aborted_proposals"] == 1


def test_crash_tolerance_and_liveness_loss():
    log = ConsensusLog(SPEC, seed=4)
    for a in range(4):
        log.crash(a)                 # 7 live = exactly q2f
    out = log.propose("x")
    assert out.value == "x"
    log.crash(4)                     # 6 live < q2f AND < q1=9 -> stuck
    with pytest.raises(RuntimeError):
        log.propose("y")


def test_control_plane_records_and_views():
    cp = ControlPlane(SPEC, seed=0)
    cp.commit_checkpoint(10, {"dir": "/ckpt/a"}, data_cursor=10)
    cp.commit_cursor(11, 11)
    cp.commit_checkpoint(20, {"dir": "/ckpt/b"}, data_cursor=20)
    last = cp.latest_checkpoint()
    assert last["step"] == 20 and last["shards"]["dir"] == "/ckpt/b"
    assert cp.latest_cursor()["cursor"] == 11
    kinds = [h["kind"] for h in cp.history()]
    assert kinds == ["checkpoint", "cursor", "checkpoint"]


def test_membership_epochs_and_quorum_rescaling():
    cp = ControlPlane(SPEC, seed=0)
    mm = MembershipManager(cp, initial_hosts=range(8), model_parallel=16,
                           devices_per_host=4)
    e1 = mm.current()
    assert e1.mesh_shape == (2, 16)
    assert e1.quorums.is_valid()
    e2 = mm.scale_up(range(8, 16))
    assert e2.mesh_shape == (4, 16)
    assert e2.epoch == e1.epoch + 1
    e3 = mm.evict_failed([0, 1, 2, 3])
    assert e3.mesh_shape == (3, 16)
    assert len(e3.hosts) == 12
    # acceptor quorums always satisfy the paper's Eqs. 13/14
    for e in (e1, e2, e3):
        assert e.quorums.is_valid()


def test_quorum_policy_valid_across_sizes():
    for n in range(3, 40):
        assert quorum_policy(n).is_valid()


def test_plan_mesh():
    assert plan_mesh(8, 16, 4) == (2, 16)
    with pytest.raises(ValueError):
        plan_mesh(1, 16, 4)


def test_phi_accrual_detector():
    d = PhiAccrualDetector(threshold=8.0)
    for t in range(0, 2000, 100):
        d.heartbeat(1, float(t))
        d.heartbeat(2, float(t) + (t % 300) * 0.1)   # jittery but alive
    assert d.phi(1, 2050.0) < 8.0
    assert d.phi(1, 9000.0) > 8.0
    assert d.suspected([1, 2], 9000.0) == [1, 2]
    assert d.suspected([1, 2], 2050.0) == []


def test_straggler_policy_commits_verdict():
    cp = ControlPlane(SPEC, seed=0)
    sp = StragglerPolicy(cp, patience=3)
    verdicts = []
    for step in range(4):
        times = {h: 100.0 + h * 0.1 for h in range(8)}
        times[5] = 900.0
        v = sp.observe_step(step, times)
        if v:
            verdicts.append((step, v))
    assert verdicts == [(2, [5])]
    hist = cp.history()
    assert hist[-1]["kind"] == "straggler" and hist[-1]["slow_hosts"] == [5]


def test_straggler_transient_spike_not_verdicted():
    cp = ControlPlane(SPEC, seed=0)
    sp = StragglerPolicy(cp, patience=3)
    for step in range(6):
        times = {h: 100.0 for h in range(8)}
        if step == 2:
            times[4] = 900.0          # single spike
        assert sp.observe_step(step, times) is None
