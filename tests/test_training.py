"""Training substrate: optimizers, data determinism, checkpoint/restore,
gradient compression, end-to-end convergence."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import ControlPlane
from repro.configs import get_config, reduced_config
from repro.core.quorum import QuorumSpec
from repro.models.model import DecoderLM
from repro.training import checkpoint as ckpt
from repro.training import compress
from repro.training.data import DataConfig, SyntheticPipeline
from repro.training.optimizer import (adafactor, adamw, apply_updates,
                                      clip_by_global_norm, cosine_schedule,
                                      global_norm)
from repro.training.trainer import Trainer, TrainerConfig, make_train_step


# ---------------------------------------------------------------------------
# Optimizers.
# ---------------------------------------------------------------------------

def test_adamw_matches_reference_math():
    params = {"w": jnp.array([1.0, -2.0])}
    grads = {"w": jnp.array([0.1, 0.2])}
    opt = adamw(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                max_grad_norm=None)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    # step 1: mhat = g, vhat = g^2  ->  update = -lr * g/(|g|+eps)
    np.testing.assert_allclose(np.asarray(updates["w"]),
                               -0.1 * np.sign([0.1, 0.2]), rtol=1e-4)


def test_adamw_weight_decay():
    params = {"w": jnp.array([1.0])}
    grads = {"w": jnp.array([0.0])}
    opt = adamw(lr=0.1, weight_decay=0.5, max_grad_norm=None)
    state = opt.init(params)
    updates, _ = opt.update(grads, state, params)
    np.testing.assert_allclose(np.asarray(updates["w"]), [-0.05], rtol=1e-5)


def test_adafactor_factored_state_shapes():
    params = {"m": jnp.zeros((8, 16)), "v": jnp.zeros((5,))}
    opt = adafactor()
    state = opt.init(params)
    assert state.vr["m"].shape == (8,)
    assert state.vc["m"].shape == (16,)
    assert state.vr["v"].shape == (5,)
    grads = jax.tree.map(jnp.ones_like, params)
    updates, state = opt.update(grads, state, params)
    assert updates["m"].shape == (8, 16)
    assert all(bool(jnp.isfinite(u).all()) for u in jax.tree.leaves(updates))


def test_clip_by_global_norm():
    grads = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule():
    fn = cosine_schedule(warmup=10, total=100)
    assert float(fn(jnp.int32(0))) == pytest.approx(0.0)
    assert float(fn(jnp.int32(10))) == pytest.approx(1.0)
    assert float(fn(jnp.int32(100))) == pytest.approx(0.1, rel=1e-3)


# ---------------------------------------------------------------------------
# Data pipeline.
# ---------------------------------------------------------------------------

def test_data_deterministic_and_distinct():
    pipe = SyntheticPipeline(DataConfig(vocab=128, seq_len=32, global_batch=8))
    b1 = pipe.batch_at(5)
    b2 = pipe.batch_at(5)
    b3 = pipe.batch_at(6)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # tokens/labels are each seq_len long (drawn from a seq_len+1 window),
    # matching the train_step/input_specs contract: tokens (B, seq).
    assert b1["tokens"].shape == (8, 32)
    assert b1["labels"].shape == (8, 32)
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))


def test_data_host_sharding_partitions_global_batch():
    pipe = SyntheticPipeline(DataConfig(vocab=128, seq_len=16, global_batch=8))
    full = np.asarray(pipe.batch_at(3)["tokens"])
    h0 = np.asarray(pipe.batch_at(3, host=0, n_hosts=2)["tokens"])
    h1 = np.asarray(pipe.batch_at(3, host=1, n_hosts=2)["tokens"])
    np.testing.assert_array_equal(np.concatenate([h0, h1])[np.argsort(
        np.concatenate([np.arange(0, 8, 2), np.arange(1, 8, 2)]))], full)


def test_frontend_batches():
    pipe = SyntheticPipeline(DataConfig(vocab=128, seq_len=32, global_batch=4))
    a = pipe.frontend_batch_at(0, d_model=64, frontend="audio_frames")
    assert a["frame_emb"].shape == (4, 32, 64)
    v = pipe.frontend_batch_at(0, d_model=64, frontend="vision_patches",
                               vision_tokens=8)
    assert v["patch_emb"].shape == (4, 8, 64)
    assert v["tokens"].shape == (4, 24)


# ---------------------------------------------------------------------------
# Gradient compression.
# ---------------------------------------------------------------------------

def test_int8_roundtrip_bounded_error():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (256, 256))}
    r = compress.init_residual(g)
    out, res = compress.int8_compress(g, r, jax.random.PRNGKey(1))
    scale = float(jnp.abs(g["w"]).max()) / 127.0
    assert float(jnp.abs(out["w"] - g["w"]).max()) <= scale * 1.01
    # error feedback: residual holds exactly what was lost
    np.testing.assert_allclose(np.asarray(res["w"]),
                               np.asarray(g["w"] - out["w"]), atol=1e-6)


def test_error_feedback_recovers_signal():
    """A tiny constant gradient below one quantization step must eventually
    pass through thanks to error feedback."""
    g = {"w": jnp.full((64,), 1e-3)}
    big = {"w": jnp.zeros((64,)).at[0].set(1.0)}   # sets the scale
    grads = jax.tree.map(lambda a, b: a + b, g, big)
    r = compress.init_residual(g)
    total = jnp.zeros((64,))
    key = jax.random.PRNGKey(0)
    for i in range(50):
        key, k = jax.random.split(key)
        out, r = compress.int8_compress(grads, r, k)
        total = total + out["w"]
    mean_passed = float(total[1:].mean()) / 50
    assert mean_passed == pytest.approx(1e-3, rel=0.2)


def test_topk_keeps_largest():
    g = {"w": jnp.arange(100.0)}
    r = compress.init_residual(g)
    out, res = compress.topk_compress(g, r, frac=0.1)
    kept = np.asarray(out["w"])
    assert (kept[-10:] > 0).all() and (kept[:-10] == 0).all()
    np.testing.assert_allclose(np.asarray(res["w"])[:-10],
                               np.arange(90.0), atol=1e-6)


def test_compressed_bytes_accounting():
    g = {"w": jnp.zeros((1000,))}
    assert compress.compressed_bytes(g, None) == 4000
    assert compress.compressed_bytes(g, "int8") == 1004
    assert compress.compressed_bytes(g, "topk", 0.05) == 400


# ---------------------------------------------------------------------------
# Checkpoint + restore through the consensus control plane.
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_with_consensus_manifest(tmp_path):
    plane = ControlPlane(QuorumSpec.paper_headline(11))
    state = {"params": {"w": jnp.arange(8.0)},
             "opt": {"mu": jnp.zeros(8)}}
    ckpt.save(str(tmp_path), 7, state, data_cursor=42, plane=plane)
    manifest = ckpt.latest_manifest(str(tmp_path), plane)
    assert manifest["step"] == 7
    restored, step, cursor = ckpt.restore(state, manifest)
    assert step == 7 and cursor == 42
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.arange(8.0))


def test_checkpoint_detects_corruption(tmp_path):
    state = {"w": jnp.arange(16.0)}
    d = ckpt.save(str(tmp_path), 1, state, data_cursor=0)
    # corrupt the shard
    np.save(os.path.join(d, "w.npy"), np.zeros(16))
    manifest = ckpt.latest_manifest(str(tmp_path))
    with pytest.raises(ValueError, match="digest"):
        ckpt.restore(state, manifest)


def test_torn_checkpoint_invisible_without_manifest(tmp_path):
    # shards written but no manifest commit -> restore sees nothing
    os.makedirs(tmp_path / "step-00000009")
    np.save(tmp_path / "step-00000009" / "w.npy", np.zeros(4))
    assert ckpt.latest_manifest(str(tmp_path)) is None


# ---------------------------------------------------------------------------
# End-to-end: convergence, resume, microbatching, compression.
# ---------------------------------------------------------------------------

def _mk_trainer(tmp, plane=None, **kw):
    cfg = reduced_config(get_config("olmo_1b"))
    model = DecoderLM(cfg, remat=True)
    pipe = SyntheticPipeline(DataConfig(vocab=cfg.vocab, seq_len=32,
                                        global_batch=8))
    t = Trainer(model, adamw(lr=3e-3), pipe,
                TrainerConfig(ckpt_dir=str(tmp), **kw), plane=plane)
    t.init(jax.random.PRNGKey(0))
    return t


def test_loss_decreases(tmp_path):
    t = _mk_trainer(tmp_path, ckpt_every=0)
    first = t.run(1)["loss"]
    last = t.run(25)["loss"]
    assert last < first - 0.5


def test_preemption_resume_bit_exact(tmp_path):
    plane = ControlPlane(QuorumSpec.paper_headline(11))
    t1 = _mk_trainer(tmp_path, plane=plane, ckpt_every=5)
    t1.run(10)
    w10 = np.asarray(jax.tree.leaves(t1.params)[0])
    t1.run(3)      # lost to preemption
    t2 = _mk_trainer(tmp_path, plane=plane, ckpt_every=5)
    assert t2.try_restore()
    assert t2.step == 10 and t2.cursor == 10
    np.testing.assert_array_equal(np.asarray(jax.tree.leaves(t2.params)[0]),
                                  w10)


def test_microbatched_step_matches_full_batch(tmp_path):
    cfg = reduced_config(get_config("olmo_1b"))
    model = DecoderLM(cfg, remat=True)
    pipe = SyntheticPipeline(DataConfig(vocab=cfg.vocab, seq_len=32,
                                        global_batch=8))
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = adamw(lr=1e-3)
    batch = pipe.batch_at(0)

    s1 = make_train_step(model, opt, n_microbatches=1)
    p1, _, _, m1 = s1(params, opt.init(params), None, batch,
                      jax.random.PRNGKey(0))
    s2 = make_train_step(model, opt, n_microbatches=2)
    mb = jax.tree.map(lambda x: x.reshape((2, 4) + x.shape[1:]), batch)
    p2, _, _, m2 = s2(params, opt.init(params), None, mb,
                      jax.random.PRNGKey(0))
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-2)
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 2e-2   # bf16 accumulation-order differences only


def test_compressed_training_still_converges(tmp_path):
    t = _mk_trainer(tmp_path, ckpt_every=0, compression="int8")
    first = t.run(1)["loss"]
    last = t.run(25)["loss"]
    assert last < first - 0.4
