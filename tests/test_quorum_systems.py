"""Differential safety for general quorum systems (grids, weighted,
explicit): every system the masked engine accepts must (a) pass the
set-level Eq.11/12 checkers, (b) model-check clean, and (c) produce engine
decide-bits that match brute-force set semantics.  Per Relaxed Paxos
(Howard & Mortier 2022), exhaustive checking of small systems against the
simulator and model checker is what licenses the fast path.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.model_check import explore
from repro.core.quorum import (ExplicitQuorumSystem, QuorumSpec,
                               WeightedQuorumSystem, all_valid_specs)
from repro.kernels.quorum_tally import ref as qt_ref
from repro.montecarlo import build_mask_table, engine

KEY = jax.random.PRNGKey(11)


def _small_systems():
    """Every n <= 5 explicit/grid system exercised by the suite: the §6 grid
    construction, explicit enumerations of FFP-valid cardinality specs, and
    weighted systems converted to their minimal-quorum explicit form."""
    out = [("grid_1col", ExplicitQuorumSystem.grid(1))]           # n = 3
    for spec in [QuorumSpec(3, 2, 2, 3), QuorumSpec(4, 4, 1, 3),
                 QuorumSpec(4, 3, 2, 4), QuorumSpec(5, 4, 2, 4)]:
        out.append((f"card_{spec.n}_{spec.q1}{spec.q2c}{spec.q2f}",
                    ExplicitQuorumSystem.from_spec(spec.validate())))
    out.append(("weighted_n3",
                WeightedQuorumSystem((1, 1, 2), 3, 2, 3).validate()
                .to_explicit()))
    out.append(("weighted_n5",
                WeightedQuorumSystem((2, 1, 1, 1, 1), 5, 2, 4).validate()
                .to_explicit()))
    return out


SMALL_SYSTEMS = _small_systems()
IDS = [name for name, _ in SMALL_SYSTEMS]
SYSTEMS = [sys for _, sys in SMALL_SYSTEMS]


# ---------------------------------------------------------------------------
# (a) the engine accepts exactly the systems the set checkers accept
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("system", SYSTEMS, ids=IDS)
def test_engine_accepted_systems_are_set_valid(system):
    assert system.is_valid()                      # Eq.11 + Eq.12, exact sets
    table = build_mask_table([system])            # the engine's acceptance
    assert table["p1_w"].shape[-1] == system.n


# ---------------------------------------------------------------------------
# (b) model checker: no reachable safety violation for any accepted system
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("system", SYSTEMS, ids=IDS)
def test_accepted_systems_model_check_clean(system):
    cap = 120_000 if system.n <= 4 else 60_000
    r = explore(system, max_states=cap)
    assert r.ok, (r.violation, r.trace)
    assert r.states > 1_000                       # non-trivial exploration


def test_invalid_explicit_system_violates_consistency():
    """Teeth check: the explicit-system path must reproduce the cardinality
    counterexample — (3, 2, 2, 2) breaks Eq.14 and two values get decided."""
    bad = ExplicitQuorumSystem.from_spec(QuorumSpec(3, 2, 2, 2))
    assert not bad.is_valid()
    r = explore(bad, max_states=500_000)
    assert not r.ok and r.violation == "Consistency"
    assert r.trace and r.trace[0] == "Init"


# ---------------------------------------------------------------------------
# (c) engine decide-bits == brute-force set semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("system", SYSTEMS, ids=IDS)
def test_mask_satisfaction_matches_set_semantics(system):
    """masks.satisfied / the masked-tally oracle / _sat_time must all agree
    with 'the subset contains some enumerated quorum', for every subset."""
    masks = system.to_masks()
    quorums = {"p1": system.p1, "p2c": system.p2c, "p2f": system.p2f}
    for r in range(system.n + 1):
        for members in itertools.combinations(range(system.n), r):
            s = set(members)
            for phase in ("p1", "p2c", "p2f"):
                expect = any(q <= s for q in quorums[phase])
                assert masks.satisfied(s, phase) == expect, (s, phase)
            # engine decide bit: all members vote value 0, rest abstain
            votes = np.full((1, system.n), -1, np.int32)
            votes[0, list(s)] = 0
            got = qt_ref.masked_tally(jnp.asarray(votes),
                                      jnp.asarray(masks.p2f_w),
                                      jnp.asarray(masks.p2f_t), 1)
            assert bool((got[0] >= 0).any()) == \
                any(q <= s for q in system.p2f), s
            # arrival saturation: members arrive at 1ms, rest never
            arr = jnp.where(jnp.asarray(votes[0]) == 0, 1.0, engine.BIG)
            perm = jnp.argsort(arr).astype(jnp.int32)[None]
            tt = engine._sat_time(jnp.sort(arr)[None], perm,
                                  jnp.asarray(masks.p1_w),
                                  jnp.asarray(masks.p1_t))
            assert bool(tt[0] < engine.UNDECIDED_MS) == \
                any(q <= s for q in system.p1), s


# ---------------------------------------------------------------------------
# property tests: cardinality round-trips through to_masks()
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(q1=st.integers(1, 5), q2c=st.integers(1, 5), q2f=st.integers(1, 5),
       seed=st.integers(0, 10_000))
def test_masked_decide_equals_threshold_decide(q1, q2c, q2f, seed):
    """For any valid n=5 cardinality spec, the general masked lowering must
    be bit-identical to the k-th-order-statistic specialization ("q" table)
    on the same sampled race (shapes are fixed, so the whole property run
    costs one compile per lowering)."""
    spec = QuorumSpec(5, q1, q2c, q2f)
    if not spec.is_valid():
        return
    key = jax.random.PRNGKey(seed)
    offs = jnp.array([0.0, 0.25])
    kw = dict(n=5, k_proposers=2, samples=512)
    thr = engine.race(key, build_mask_table([spec]), offs, **kw)
    msk = engine.race(key, build_mask_table([spec], specialize=False),
                      offs, **kw)
    for k in thr:
        assert bool((thr[k] == msk[k]).all()), (k, spec)


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 24), q=st.integers(1, 24), seed=st.integers(0, 9999))
def test_sat_time_on_ones_row_is_kth_order_statistic(n, q, seed):
    """An all-ones mask row with threshold q <= n saturates exactly at the
    q-th order statistic (the threshold path's gather)."""
    q = min(q, n)
    x = jnp.sort(jax.random.uniform(jax.random.PRNGKey(seed), (7, n)),
                 axis=-1)
    perm = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (7, n))
    got = engine._sat_time(x, perm, jnp.ones((1, n)),
                           jnp.array([float(q)]))
    want = engine._kth(x, jnp.int32(q))
    assert bool((got == want).all()), (n, q)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(3, 6), q1=st.integers(1, 6), q2c=st.integers(1, 6),
       q2f=st.integers(1, 6))
def test_arithmetic_validity_equals_set_validity(n, q1, q2c, q2f):
    """Eq.13/14 arithmetic == Eq.11/12 on the enumerated explicit system."""
    q1, q2c, q2f = min(q1, n), min(q2c, n), min(q2f, n)
    spec = QuorumSpec(n, q1, q2c, q2f)
    assert spec.is_valid() == ExplicitQuorumSystem.from_spec(spec).is_valid()


# ---------------------------------------------------------------------------
# mask-table plumbing
# ---------------------------------------------------------------------------

def test_mask_table_padding_and_embedding():
    grid = ExplicitQuorumSystem.grid(3).to_masks().embed(11)   # 9 -> 11
    card = QuorumSpec.paper_headline(11)
    table = build_mask_table([card, grid])
    g1 = max(1, len(ExplicitQuorumSystem.grid(3).p1))
    assert table["p1_w"].shape == (2, g1, 11)
    # padded rows are never satisfiable: zero weight, huge threshold
    assert float(table["p1_w"][0, 1:].sum()) == 0.0
    assert bool((table["p1_t"][0, 1:] > 1e6).all())
    # embedded acceptors 9, 10 carry no weight in any grid quorum
    assert float(table["p1_w"][1, :, 9:].sum()) == 0.0


def test_mask_table_rejects_mixed_n_and_garbage():
    with pytest.raises(ValueError, match="system 1"):
        build_mask_table([QuorumSpec.paper_headline(11), QuorumSpec(7, 6, 2, 6)])
    with pytest.raises(ValueError):
        engine.race(KEY, {"p1_w": jnp.ones((1, 1, 5))},
                    jnp.array([0.0, 0.1]), n=5, k_proposers=2,
                    samples=8)


def test_mask_table_mixed_n_error_names_offender():
    """Satellite: the n-mismatch error must say *which* system is wrong,
    not surface as an opaque XLA broadcast error."""
    grid = ExplicitQuorumSystem.grid(3)          # n = 9
    with pytest.raises(ValueError) as exc:
        build_mask_table([QuorumSpec.paper_headline(11), grid])
    msg = str(exc.value)
    assert "system 1" in msg and "n=9" in msg and "n=11" in msg
    assert "embed" in msg                        # actionable hint


def test_fast_and_classic_path_lowerings_bit_identical():
    specs = [QuorumSpec.paper_headline(11), QuorumSpec.fast_paxos(11)]
    spec_t = build_mask_table(specs)                       # "q" gathers
    gen_t = build_mask_table(specs, specialize=False)      # masked saturation
    assert bool((engine.fast_path(KEY, spec_t, n=11, samples=8_000)
                 == engine.fast_path(KEY, gen_t, n=11, samples=8_000)).all())
    assert bool((engine.classic_path(KEY, spec_t, n=11, samples=8_000)
                 == engine.classic_path(KEY, gen_t, n=11,
                                        samples=8_000)).all())


def test_all_valid_n4_specs_roundtrip_masked():
    """Whole n=4 valid space: general lowering == "q" specialization, one
    compile per lowering, one table."""
    specs = list(all_valid_specs(4))
    assert specs
    offs = jnp.array([0.0, 0.3])
    kw = dict(n=4, k_proposers=2, samples=1_000)
    thr = engine.race(KEY, build_mask_table(specs), offs, **kw)
    before = engine.TRACE_COUNTS["race"]
    msk = engine.race(KEY, build_mask_table(specs, specialize=False),
                      offs, **kw)
    assert engine.TRACE_COUNTS["race"] - before == 1
    for k in thr:
        assert bool((thr[k] == msk[k]).all()), k
