"""Planner subsystem tests (``repro.planner``, DESIGN.md §11).

Four layers:

  * schedule + pruning kernel: plain-data rungs, margin-dominance
    soundness properties (a hypothesis property drives synthetic noisy
    rung scores bounded by the margins and asserts the full-budget
    Pareto set is never pruned), deterministic within-margin tie cases;
  * engine cache: warm-pool keys, zero-compile repeat scoring, memo hits;
  * service: in-process planner + TCP server round trips, geometry
    batching, fault-budget filtering;
  * acceptance: the n=11 successive-halving search finds EXACTLY the
    direct sweep's Pareto set at the same final budget (common random
    numbers make final-rung scores bit-identical per system) while
    scoring <= 40% of the exhaustive trial budget, and a second
    same-geometry ``plan()`` adds zero ``TRACE_COUNTS`` compiles.
"""
import math
from dataclasses import dataclass
from typing import Tuple

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.frontier import cardinality_family, default_axes, pareto_mask
from repro.frontier.score import AXIS_NAMES, score_systems
from repro.montecarlo import engine
from repro.planner import (EngineCache, PlanQuery, Planner, PlannerServer,
                           Rung, default_schedule, engine_key,
                           prune_survivors, query_server, search,
                           successive_halving)
from repro.planner.search import quantile_margin_cells, rate_margin

# ---------------------------------------------------------------------------
# Shared scoring configs.
# ---------------------------------------------------------------------------

# Small, fast geometry for cache/service tests.
SMALL = dict(n=7, chunk=4_096, shard=False, seed=0)
SMALL_SCHEDULE = ((2_000, 2.0), (20_000, 2.0))

# The acceptance geometry: PR 5's sweep at --smoke scale (n=11, 10^6
# trials, chunk 16384, 2-way race at delta 0.2, seed 0).
ACC_N = 11
ACC_TRIALS = 1_000_000
ACC_CHUNK = 16_384
ACC_SCHEDULE = (Rung(100_000), Rung(ACC_TRIALS))

_TRUTH = {}


def _truth():
    """The exhaustive n=11 direct frontier at the acceptance budget,
    scored once per test session (module-level memo — the hypothesis
    fallback wrapper passes no fixtures)."""
    if "fr" not in _TRUTH:
        members = cardinality_family(ACC_N)
        _TRUTH["members"] = members
        _TRUTH["fr"] = score_systems(members, n=ACC_N, trials=ACC_TRIALS,
                                     chunk=ACC_CHUNK, shard=False, seed=0)
    return _TRUTH["members"], _TRUTH["fr"]


# ---------------------------------------------------------------------------
# Schedules are plain data.
# ---------------------------------------------------------------------------

def test_default_schedule_geometric_ascending():
    sched = default_schedule(10_000_000)
    assert [r.trials for r in sched] == [10_000, 100_000, 1_000_000,
                                         10_000_000]
    from repro.planner.search import DEFAULT_SLACK
    assert all(r.slack == DEFAULT_SLACK for r in sched)
    assert default_schedule(5_000, min_trials=10_000) == (Rung(5_000),)
    assert [r.trials for r in default_schedule(1_000_000, eta=100)] \
        == [10_000, 1_000_000]


def test_rung_validation():
    with pytest.raises(ValueError):
        Rung(0)
    with pytest.raises(ValueError):
        Rung(100, slack=0.0)
    with pytest.raises(ValueError):
        default_schedule(0)
    with pytest.raises(ValueError):
        default_schedule(100, eta=1)


def test_successive_halving_rejects_bad_schedules():
    with pytest.raises(ValueError):
        successive_halving(["a"], [], lambda m, t: None)
    with pytest.raises(ValueError):
        successive_halving(["a"], [Rung(100), Rung(100)],
                           lambda m, t: None)
    with pytest.raises(ValueError):
        successive_halving([], [Rung(100)], lambda m, t: None)


# ---------------------------------------------------------------------------
# Margin-dominance pruning: deterministic cases.
# ---------------------------------------------------------------------------

# A compact synthetic axis tuple matching the scorer's shape: two relative
# latency axes, one rate axis, one exact maximize axis.
SYN_AXES = default_axes(precision=0.01, trials=ACC_TRIALS)


def _vals(*rows):
    return np.array(rows, np.float64)


def _gamma(eps=0.01):
    return (1.0 + eps) / (1.0 - eps)


def test_prune_within_margin_tie_survives_together():
    """Two systems inside the rung's sketch/noise margin on a stochastic
    axis are indistinguishable there — neither may prune the other, even
    though one is weakly better everywhere."""
    rung = Rung(10_000, slack=2.0)
    m_cells = quantile_margin_cells(2.0, 10_000, 0.5)
    # row 1 is better on fast_p50 by *half* the margin, ties elsewhere
    g = _gamma()
    base = _vals([1.0, 2.0, 0.1, 1, 1, 1],
                 [1.0 * g ** (-m_cells / 2), 2.0, 0.1, 1, 1, 1])
    keep = prune_survivors(base, SYN_AXES, rung)
    assert keep.tolist() == [True, True]


def test_prune_beyond_margin_dominated_is_pruned():
    rung = Rung(10_000, slack=2.0)
    g = _gamma()
    mq = quantile_margin_cells(2.0, 10_000, 0.5)
    mt = quantile_margin_cells(2.0, 10_000, 0.001)
    mr = rate_margin(2.0, 10_000)
    # row 1 beats row 0 beyond the margin on EVERY stochastic axis and
    # ties the exact axes -> row 0 prunable
    worse = [1.0, 2.0, 0.5, 1, 1, 1]
    better = [1.0 * g ** (-(mq + 1)), 2.0 * g ** (-(mt + 1)),
              0.5 - (mr * 1.5), 1, 1, 1]
    keep = prune_survivors(_vals(worse, better), SYN_AXES, rung)
    assert keep.tolist() == [False, True]
    # ...but an exact-axis advantage for row 0 vetoes the prune
    worse_ft = list(worse)
    worse_ft[3] = 2
    keep = prune_survivors(_vals(worse_ft, better), SYN_AXES, rung)
    assert keep.tolist() == [True, True]


def test_prune_exact_duplicates_survive_together():
    """CRN scoring produces bit-exact duplicate rows for structurally
    identical systems; margin dominance is irreflexive so they can never
    prune each other."""
    rung = Rung(1_000, slack=2.0)
    row = [1.5, 3.0, 0.2, 2, 1, 3]
    keep = prune_survivors(_vals(row, row, row), SYN_AXES, rung)
    assert keep.all()


def test_prune_never_decided_ties_cannot_prune():
    """Two systems that never decide (NaN -> -inf) tie at -inf on the
    latency axes; the -inf vs -inf comparison carries no information and
    must neither count as a strict win nor veto other axes."""
    rung = Rung(10_000, slack=2.0)
    nan = float("nan")
    a = [nan, nan, 0.5, 1, 1, 1]
    b = [nan, nan, 0.5, 1, 1, 1]
    keep = prune_survivors(_vals(a, b), SYN_AXES, rung)
    assert keep.tolist() == [True, True]
    # a decided system beats an undecided one beyond any margin on the
    # latency axes; with a rate edge too, the undecided row is pruned
    c = [1.0, 2.0, 0.1, 1, 1, 1]
    keep = prune_survivors(_vals(a, c), SYN_AXES, rung)
    assert keep.tolist() == [False, True]


def test_prune_singleton_and_empty():
    rung = Rung(1_000)
    assert prune_survivors(np.zeros((1, 6)), SYN_AXES, rung).tolist() \
        == [True]
    assert prune_survivors(np.zeros((0, 6)), SYN_AXES, rung).shape == (0,)


# ---------------------------------------------------------------------------
# Pruning soundness property: bounded-noise rung scores never prune a
# member of the full-budget Pareto set.
# ---------------------------------------------------------------------------

@dataclass
class _FakeResult:
    labels: Tuple[str, ...]
    axes: tuple
    values: np.ndarray

    @property
    def mask(self):
        return pareto_mask(self.values, self.axes)

    @property
    def axis_names(self):
        return tuple(a.name for a in self.axes)

    @property
    def frontier_labels(self):
        return tuple(l for l, m in zip(self.labels, self.mask) if m)


def _noisy(truth: np.ndarray, axes, rung: Rung,
           rng: np.random.RandomState) -> np.ndarray:
    """Rung-scale estimates: truth +/- noise bounded so that margin
    dominance at the rung implies >1-final-cell dominance in truth (the
    soundness precondition the margins are sized for)."""
    out = truth.copy()
    for a, ax in enumerate(axes):
        if ax.name in ("fast_p50_ms", "race_p999_ms"):
            tail = 0.5 if ax.name == "fast_p50_ms" else 0.001
            cells = (quantile_margin_cells(rung.slack, rung.trials, tail)
                     - 1.0) / 2.0
            g = (1.0 + ax.eps) / (1.0 - ax.eps)
            u = rng.uniform(-cells, cells, size=truth.shape[0])
            out[:, a] = truth[:, a] * g ** u
        elif ax.name == "p_recovery":
            bound = (rate_margin(rung.slack, rung.trials) - ax.eps) / 2.0
            bound = max(bound, 0.0)
            out[:, a] = truth[:, a] + rng.uniform(-bound, bound,
                                                  size=truth.shape[0])
    return out


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=2, max_value=4))
def test_halving_never_prunes_full_budget_frontier_n11(noise_seed, n_rungs):
    """The ISSUE acceptance property, against REAL n=11 scores: run
    successive halving where each cheap rung sees the true full-budget
    scores perturbed by noise within the rung's margins (the regime the
    margins are sized for), the final rung sees the exact scores — and
    the search's frontier must equal the direct sweep's, every run."""
    members, fr = _truth()
    truth = np.asarray(fr.values, np.float64)
    labels = tuple(fr.labels)
    truth_frontier = set(fr.frontier_labels)
    rng = np.random.RandomState(noise_seed)

    ladder = [ACC_TRIALS // (10 ** k) for k in range(n_rungs - 1, 0, -1)]
    schedule = tuple(Rung(t) for t in ladder) + (Rung(ACC_TRIALS),)
    pruned_log = []

    def scorer(alive, trials):
        idx = [labels.index(m.label) for m in alive]
        vals = (truth[idx] if trials == ACC_TRIALS
                else _noisy(truth[idx], fr.axes, Rung(trials), rng))
        pruned_log.append((trials, len(alive)))
        return _FakeResult(tuple(labels[i] for i in idx), fr.axes, vals)

    result = successive_halving(list(members), schedule, scorer)
    got = set(result.frontier.frontier_labels)
    assert got == truth_frontier, (
        f"noise_seed={noise_seed}, rungs={n_rungs}: "
        f"lost {truth_frontier - got}, gained {got - truth_frontier}")
    # survivors shrink monotonically and every rung scored someone
    counts = [n for _, n in pruned_log]
    assert counts[0] == len(members)
    assert all(a >= b for a, b in zip(counts, counts[1:]))


# ---------------------------------------------------------------------------
# Engine cache.
# ---------------------------------------------------------------------------

def _small_members():
    return cardinality_family(7)


def test_engine_key_modes():
    table = engine.build_mask_table([m.masks() for m in _small_members()])
    streamed = engine_key(table, n=7, k_proposers=2, trials=50_000,
                          chunk=4_096, precision=0.01, shard=False,
                          use_kernel=False, k_max="auto")
    assert streamed.mode == "stream"
    assert streamed.n_chunks == -(-50_000 // 4_096)
    assert streamed.layout_pairs > 0          # cardinality pair layout
    mat = engine_key(table, n=7, k_proposers=2, trials=1_000,
                     chunk=4_096, precision=0.01, shard=False,
                     use_kernel=False, k_max="auto")
    assert mat.mode == "materialize" and mat.n_chunks == 1_000
    # same geometry, different trials but same chunk count -> same key
    same = engine_key(table, n=7, k_proposers=2, trials=52_000,
                      chunk=4_096, precision=0.01, shard=False,
                      use_kernel=False, k_max="auto")
    assert same == streamed


def test_engine_cache_second_same_shape_scores_zero_compiles():
    cache = EngineCache()
    members = _small_members()
    r1 = cache.score(members, trials=30_000, n=7, chunk=4_096, shard=False,
                     seed=0)
    assert r1.engine_compiles > 0             # cold: fast + race traces
    before = dict(engine.TRACE_COUNTS)
    r2 = cache.score(members, trials=30_000, n=7, chunk=4_096, shard=False,
                     seed=0)
    assert engine.TRACE_COUNTS == before      # memo hit: engine untouched
    assert r2.engine_compiles == 0
    assert cache.memo_hits == 1
    np.testing.assert_array_equal(np.asarray(r1.values),
                                  np.asarray(r2.values))
    # different seed: memo miss, but the jit cache stays warm -> zero
    # NEW compiles even though the engine actually runs
    r3 = cache.score(members, trials=30_000, n=7, chunk=4_096, shard=False,
                     seed=1)
    assert r3.engine_compiles == 0
    assert cache.memo_misses == 2
    assert not np.array_equal(np.asarray(r1.values)[:, :2],
                              np.asarray(r3.values)[:, :2])


def test_engine_cache_scores_match_direct():
    """Routing through the cache changes bookkeeping, never values."""
    cache = EngineCache()
    members = _small_members()[:10]
    via = cache.score(members, trials=9_000, n=7, chunk=4_096, shard=False,
                      seed=3)
    direct = score_systems(members, trials=9_000, n=7, chunk=4_096,
                           shard=False, seed=3)
    np.testing.assert_array_equal(np.asarray(via.values),
                                  np.asarray(direct.values))
    assert via.labels == direct.labels


# ---------------------------------------------------------------------------
# Search through the real engine (small scale).
# ---------------------------------------------------------------------------

def test_search_small_matches_direct_frontier():
    members = _small_members()
    sr = search(members, final_trials=20_000,
                schedule=(Rung(2_000), Rung(20_000)), **SMALL)
    direct = score_systems(members, trials=20_000, **{
        k: v for k, v in SMALL.items()})
    assert set(sr.frontier_labels) == set(direct.frontier_labels)
    assert 0 < sr.budget_fraction < 1.0
    assert sr.scored_trials < sr.exhaustive_trials
    # final-rung rows are bit-identical to the direct scores (CRN batch
    # independence): compare every surviving system's vector
    dvals = np.asarray(direct.values)
    svals = np.asarray(sr.frontier.values)
    didx = {l: i for i, l in enumerate(direct.labels)}
    for row, label in enumerate(sr.frontier.labels):
        np.testing.assert_array_equal(svals[row], dvals[didx[label]])


# ---------------------------------------------------------------------------
# Planner + service.
# ---------------------------------------------------------------------------

def _small_query(**over):
    q = dict(n=7, family="cardinality", trials=20_000,
             schedule=SMALL_SCHEDULE, chunk=4_096, shard=False, seed=0)
    q.update(over)
    return q


def test_planner_second_same_geometry_plan_zero_compiles():
    planner = Planner()
    r1 = planner.plan(_small_query(faults={"classic": 1}))
    assert r1.ok and r1.cold
    before = dict(engine.TRACE_COUNTS)
    r2 = planner.plan(_small_query(faults={"fast": 1}))
    assert engine.TRACE_COUNTS == before
    assert not r2.cold and r2.engine_compiles == 0
    assert r2.ok
    # recommendation respects the budget it was asked for
    assert r2.fault_tolerance["fast"] >= 1
    assert r1.fault_tolerance["classic"] >= 1


def test_planner_impossible_budget_reports_not_ok():
    planner = Planner()
    r = planner.plan(_small_query(faults={"fast": 7}))
    assert not r.ok and "no frontier system" in r.reason
    assert r.frontier_labels                 # the frontier is still reported


def test_planner_objective_changes_recommendation_ranking():
    planner = Planner()
    r_tail = planner.plan(_small_query(objective="race_p999_ms"))
    r_fast = planner.plan(_small_query(objective="fast_p50_ms"))
    fr_labels = set(r_tail.frontier_labels)
    assert r_fast.recommended in fr_labels
    assert r_tail.recommended in fr_labels
    # both objectives answered from one cached search
    assert planner.search_misses == 1 and planner.search_hits >= 1


def test_plan_group_batches_same_geometry():
    planner = Planner()
    qs = [PlanQuery.from_dict(_small_query(faults={"classic": 1})),
          PlanQuery.from_dict(_small_query(faults={"fast": 1}))]
    rs = planner.plan_group(qs)
    assert len(rs) == 2 and all(r.ok for r in rs)
    assert planner.search_misses == 1        # ONE search for the batch
    with pytest.raises(ValueError):
        planner.plan_group([qs[0],
                            PlanQuery.from_dict(_small_query(seed=5))])


def test_query_validation():
    with pytest.raises(ValueError):
        PlanQuery(objective="p42")
    with pytest.raises(ValueError):
        PlanQuery(faults={"phase9": 1})
    with pytest.raises(ValueError):
        PlanQuery.from_dict({"nope": 1})
    with pytest.raises(ValueError):
        PlanQuery(trials=0)


def test_server_round_trip_batching_and_zero_compile_repeat():
    srv = PlannerServer(port=0, batch_window_s=0.01)
    srv.start()
    try:
        assert query_server({"op": "ping"}, port=srv.port)["ok"]
        q = {"op": "plan", **_small_query(faults={"classic": 1})}
        q["schedule"] = [list(r) for r in SMALL_SCHEDULE]
        r1 = query_server(q, port=srv.port)
        assert r1["ok"] and r1["cold"]
        before = dict(engine.TRACE_COUNTS)
        r2 = query_server(q, port=srv.port)
        assert engine.TRACE_COUNTS == before
        assert r2["ok"] and not r2["cold"] and r2["engine_compiles"] == 0
        assert r2["recommended"] == r1["recommended"]
        stats = query_server({"op": "stats"}, port=srv.port)
        assert stats["ok"] and stats["search_misses"] == 1
        bad = query_server({"op": "plan", "objective": "nope"},
                           port=srv.port)
        assert not bad["ok"] and "objective" in bad["error"]
    finally:
        srv.shutdown()


def test_api_plan_and_experiment_plan():
    from repro.api import Experiment, Workload, plan
    from repro.core.quorum import QuorumSpec

    planner = Planner()
    r = plan(_small_query(faults={"classic": 1}), planner=planner)
    assert r.ok and r.system["type"] == "QuorumSpec"
    assert r.predicted_ms["fast_p50"] > 0
    assert r.predicted_ms["race_p9999"] >= r.predicted_ms["race_p999"]

    exp = Experiment(systems=[QuorumSpec.paper_headline(7)],
                     workload=Workload.race(k=2, delta_ms=0.2),
                     chunk=4_096, shard=False)
    r2 = exp.plan(faults={"classic": 1}, trials=20_000,
                  schedule=SMALL_SCHEDULE, planner=planner)
    assert r2.ok
    # same geometry as the direct query (n=7, default race workload,
    # same knobs) -> answered from the cached search
    assert not r2.cold and r2.engine_compiles == 0


# ---------------------------------------------------------------------------
# launch_local free-port race (satellite): EADDRINUSE retries.
# ---------------------------------------------------------------------------

def test_launch_local_retries_on_eaddrinuse(monkeypatch):
    from repro.parallel import distributed

    calls = []

    def fake_once(n, d, argv, *, env, timeout_s):
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("coordinator: Address already in use "
                               "(EADDRINUSE)")
        return ["ok"] * n

    monkeypatch.setattr(distributed, "_launch_once", fake_once)
    out = distributed.launch_local(2, 1, ["true"])
    assert out == ["ok", "ok"] and len(calls) == 3


def test_launch_local_exhausts_retries(monkeypatch):
    from repro.parallel import distributed

    def always_busy(n, d, argv, *, env, timeout_s):
        raise RuntimeError("bind failed: EADDRINUSE")

    monkeypatch.setattr(distributed, "_launch_once", always_busy)
    with pytest.raises(RuntimeError, match="EADDRINUSE"):
        distributed.launch_local(1, 1, ["true"], port_retries=2)


def test_launch_local_does_not_retry_other_failures(monkeypatch):
    from repro.parallel import distributed

    calls = []

    def fake_once(n, d, argv, *, env, timeout_s):
        calls.append(1)
        raise RuntimeError("worker exploded for unrelated reasons")

    monkeypatch.setattr(distributed, "_launch_once", fake_once)
    with pytest.raises(RuntimeError, match="unrelated"):
        distributed.launch_local(1, 1, ["true"])
    assert len(calls) == 1

    def unsupported(n, d, argv, *, env, timeout_s):
        calls.append(1)
        raise NotImplementedError("no gloo here")

    calls.clear()
    monkeypatch.setattr(distributed, "_launch_once", unsupported)
    with pytest.raises(NotImplementedError):
        distributed.launch_local(1, 1, ["true"])
    assert len(calls) == 1


# ---------------------------------------------------------------------------
# Acceptance: exact n=11 sweep frontier at <= 40% of the exhaustive
# budget; repeat plan() adds zero compiles.
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_acceptance_n11_exact_frontier_under_budget():
    members, direct = _truth()
    sr = search(members, final_trials=ACC_TRIALS, schedule=ACC_SCHEDULE,
                n=ACC_N, chunk=ACC_CHUNK, shard=False, seed=0)
    assert set(sr.frontier_labels) == set(direct.frontier_labels), (
        f"search missed {set(direct.frontier_labels) - set(sr.frontier_labels)}"
        f", invented {set(sr.frontier_labels) - set(direct.frontier_labels)}")
    assert sr.budget_fraction <= 0.40, sr.budget_fraction
    # final-rung scores are bit-identical to the direct sweep's rows
    dvals = np.asarray(direct.values)
    svals = np.asarray(sr.frontier.values)
    didx = {l: i for i, l in enumerate(direct.labels)}
    for row, label in enumerate(sr.frontier.labels):
        np.testing.assert_array_equal(svals[row], dvals[didx[label]])


@pytest.mark.slow
def test_acceptance_second_plan_query_zero_compiles():
    planner = Planner()
    sched = tuple((r.trials, r.slack) for r in ACC_SCHEDULE)
    q = dict(n=ACC_N, family="cardinality", trials=ACC_TRIALS,
             schedule=sched, chunk=ACC_CHUNK, shard=False, seed=0)
    r1 = planner.plan(dict(q, faults={"classic": 1}))
    assert r1.ok and r1.cold
    before = dict(engine.TRACE_COUNTS)
    r2 = planner.plan(dict(q, faults={"fast": 1, "phase1": 1}))
    assert engine.TRACE_COUNTS == before, "warm plan() traced the engine"
    assert r2.ok and not r2.cold and r2.engine_compiles == 0
    _, direct = _truth()
    assert set(r1.frontier_labels) == set(direct.frontier_labels)
