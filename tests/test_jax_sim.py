"""Vectorized Monte-Carlo model: internal invariants + cross-validation
against the discrete-event simulator (same latency distribution)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import jax_sim
from repro.core.quorum import QuorumSpec
from repro.core.simulator import (FastPaxosSim, conflict_free_workload,
                                  latency_stats)

FFP = QuorumSpec.paper_headline(11)
FP = QuorumSpec.fast_paxos(11)
KEY = jax.random.PRNGKey(0)


def test_fast_path_monotone_in_quorum_size():
    lat7 = jax_sim.fast_path_latency(KEY, 11, 7, 50_000)
    lat9 = jax_sim.fast_path_latency(KEY, 11, 9, 50_000)
    assert float(lat7.mean()) < float(lat9.mean())


def test_cross_validation_with_discrete_event_sim():
    """The analytic order-statistic model and the event-driven simulator
    must agree on mean fast-path latency within a few percent."""
    mc = float(jax_sim.fast_path_latency(KEY, 11, FFP.q2f, 200_000).mean())
    sim = FastPaxosSim(FFP, seed=11)
    conflict_free_workload(sim, 3000, rate_per_s=1400)
    des = latency_stats(sim.run())["mean_ms"]
    assert abs(mc - des) / des < 0.05, (mc, des)


def test_conflict_probability_decreasing_in_interval():
    """Fig. 2c: larger inter-command intervals -> fewer recoveries."""
    ps = [jax_sim.conflict_probability(KEY, FFP, d, samples=30_000)
          for d in (0.0, 0.3, 0.8, 2.0)]
    assert ps[0] >= ps[1] >= ps[2] >= ps[3]
    assert ps[3] < 0.01


def test_ffp_recovers_less_than_fp():
    p_ffp = jax_sim.conflict_probability(KEY, FFP, 0.3, samples=50_000)
    p_fp = jax_sim.conflict_probability(KEY, FP, 0.3, samples=50_000)
    assert p_ffp < p_fp


def test_race_outcomes_partition():
    out = jax_sim.conflict_race(KEY, 11, FFP.q1, FFP.q2f, FFP.q2c,
                                10_000, 0.3)
    total = (out["a_wins_fast"].astype(jnp.int32)
             + out["b_wins_fast"].astype(jnp.int32)
             + out["recovery"].astype(jnp.int32))
    assert bool((total == 1).all())
    assert bool(jnp.isfinite(out["latency_ms"]).all())


def test_kernel_path_matches_ref_path():
    o1 = jax_sim.conflict_race(KEY, 11, FFP.q1, FFP.q2f, FFP.q2c,
                               5_000, 0.3, use_kernel=True)
    o2 = jax_sim.conflict_race(KEY, 11, FFP.q1, FFP.q2f, FFP.q2c,
                               5_000, 0.3, use_kernel=False)
    assert bool((o1["recovery"] == o2["recovery"]).all())
    assert float(jnp.abs(o1["latency_ms"] - o2["latency_ms"]).max()) < 1e-5


def test_mixed_workload_summary():
    s = jax_sim.mixed_workload_latency(KEY, FFP, conflict_frac=0.01,
                                       delta_ms=0.3, samples=20_000)
    assert s["p99_ms"] >= s["p95_ms"] >= s["p50_ms"] > 0
    assert 0.0 <= s["recovery_rate"] <= 0.01


def test_classic_path_slower_than_fast():
    fast = jax_sim.fast_path_latency(KEY, 11, FFP.q2f, 30_000)
    classic = jax_sim.classic_path_latency(KEY, 11, 6, 30_000)
    # classic adds the client->leader relay hop
    assert float(classic.mean()) > float(fast.mean())
