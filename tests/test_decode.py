"""Serving-path correctness: prefill + incremental decode must reproduce the
full-forward logits (exact for deterministic paths; tolerance for MoE whose
capacity-dropping legitimately differs between batched and incremental
modes)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced_config
from repro.models.model import DecoderLM

EXACT = ["olmo_1b", "gemma3_12b", "mamba2_130m", "zamba2_2_7b",
         "deepseek_7b", "nemotron_4_15b"]


def run_consistency(cfg, S=16, extra=4, T=32):
    model = DecoderLM(cfg, remat=False)
    params, _ = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, S + extra),
                              0, cfg.vocab)
    full = model.forward(params, {"tokens": toks}).astype(jnp.float32)
    cache, _ = model.init_cache(2, T)
    cache, lg = model.prefill(params, {"tokens": toks[:, :S]}, cache)
    errs = [float(jnp.abs(lg[:, 0].astype(jnp.float32)
                          - full[:, S - 1]).max())]
    for t in range(S, S + extra):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        errs.append(float(jnp.abs(lg[:, 0].astype(jnp.float32)
                                  - full[:, t]).max()))
    return max(errs)


@pytest.mark.parametrize("arch", EXACT)
def test_decode_matches_forward_exact(arch):
    cfg = reduced_config(get_config(arch))
    # The cached decode path sums attention in a different order than the
    # batched forward; for most norms the bf16 round-trip still lands on the
    # same bits, but OLMo's mean-subtracting non-parametric LN amplifies the
    # f32 accumulation difference to ~1 bf16 ulp at logit scale (0.0156 in
    # [2,4)) for occasional tokens — allow 2 ulp there, exact elsewhere.
    tol = 0.04 if cfg.norm == "nonparam_ln" else 1e-4
    assert run_consistency(cfg) < tol


def test_mla_decode_exact_without_moe():
    cfg = reduced_config(get_config("deepseek_v2_lite_16b"))
    cfg = dataclasses.replace(cfg, moe=None, d_ff=128, family="dense")
    assert run_consistency(cfg) < 1e-4


def test_moe_decode_close():
    # capacity dropping differs between batched scoring and one-token decode
    cfg = reduced_config(get_config("deepseek_v2_lite_16b"))
    assert run_consistency(cfg) < 1.0


def test_sliding_window_ring_buffer():
    """Decode past the window: ring overwrite must agree with the full
    forward (the window mask hides evicted slots either way).  Run in f32 —
    the cached path softmaxes over (buffer ∥ current) with masked slots, a
    different bf16 accumulation order than the full forward — so any residual
    is ring-buffer *logic*, not rounding."""
    cfg = reduced_config(get_config("gemma3_12b"))  # window=32 after reduce
    model = DecoderLM(cfg, remat=False)
    params, _ = model.init(jax.random.PRNGKey(0))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    total = 48                                     # > window 32
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, total), 0, cfg.vocab)
    import repro.models.model as mm
    old = mm.COMPUTE_DTYPE
    try:
        mm.COMPUTE_DTYPE = jnp.float32
        full = model.forward(params, {"tokens": toks}).astype(jnp.float32)
        cache, _ = model.init_cache(1, 64)
        cache, lg = model.prefill(params, {"tokens": toks[:, :40]}, cache)
        errs = [float(jnp.abs(lg[:, 0].astype(jnp.float32)
                              - full[:, 39]).max())]
        for t in range(40, total):
            lg, cache = model.decode_step(params, cache, toks[:, t:t + 1])
            errs.append(float(jnp.abs(lg[:, 0].astype(jnp.float32)
                                      - full[:, t]).max()))
    finally:
        mm.COMPUTE_DTYPE = old
    assert max(errs) < 1e-4, errs


def test_mamba_state_long_decode():
    """SSM decode is O(1) state: decode 3x the train chunk length."""
    cfg = reduced_config(get_config("mamba2_130m"))
    model = DecoderLM(cfg, remat=False)
    params, _ = model.init(jax.random.PRNGKey(0))
    total = 3 * cfg.ssm.chunk
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, total), 0, cfg.vocab)
    full = model.forward(params, {"tokens": toks}).astype(jnp.float32)
    cache, _ = model.init_cache(1, total)
    cache, _ = model.prefill(params, {"tokens": toks[:, :8]}, cache)
    errs = []
    for t in range(8, total):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        errs.append(float(jnp.abs(lg[:, 0].astype(jnp.float32)
                                  - full[:, t]).max()))
    assert max(errs) < 1e-3, max(errs)


def test_absorbed_mla_equivalent_in_f32():
    """The beyond-paper absorbed-MLA decode is algebraically identical; in
    f32 the two formulations agree tightly."""
    cfg = reduced_config(get_config("deepseek_v2_lite_16b"))
    cfg = dataclasses.replace(cfg, moe=None, d_ff=64, family="dense")
    cfg_abs = dataclasses.replace(
        cfg, mla=dataclasses.replace(cfg.mla, absorbed_decode=True))
    m1 = DecoderLM(cfg, remat=False)
    m2 = DecoderLM(cfg_abs, remat=False)
    params, _ = m1.init(jax.random.PRNGKey(0))
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    import repro.models.model as mm
    old = mm.COMPUTE_DTYPE
    try:
        mm.COMPUTE_DTYPE = jnp.float32
        l1 = m1.forward(params, {"tokens": toks})
        l2 = m2.forward(params, {"tokens": toks})
    finally:
        mm.COMPUTE_DTYPE = old
    assert float(jnp.abs(l1 - l2).max()) < 1e-3
