"""The declarative Experiment layer (``repro.api``): one object, three
backends.  Cross-backend consistency is the point — a quorum system
declared once must model-check clean, agree between the Monte-Carlo engine
and the discrete-event simulator, and expose one normalized Results shape.
"""
import jax
import pytest

from repro.api import BACKENDS, Experiment, Results, Workload, sweep
from repro.core.quorum import (ExplicitQuorumSystem, QuorumSpec,
                               WeightedQuorumSystem)
from repro.montecarlo import engine

# Small enough for the modelcheck backend, rich enough to span all three
# system families.
SYSTEMS = [QuorumSpec(5, 4, 2, 4),
           ExplicitQuorumSystem.grid(1).embed(5),            # n=3 grid in 5
           WeightedQuorumSystem((2, 1, 1, 1, 1), 5, 2, 4)]


@pytest.fixture(scope="module")
def race_exp():
    return Experiment(systems=SYSTEMS,
                      workload=Workload.race(k=2, delta_ms=0.3),
                      samples=20_000)


# ---------------------------------------------------------------------------
# one Experiment object, unmodified, against all three backends
# ---------------------------------------------------------------------------

def test_one_experiment_runs_on_all_three_backends(race_exp):
    res = sweep(race_exp, BACKENDS)
    assert set(res) == {"montecarlo", "des", "modelcheck"}
    for backend, r in res.items():
        assert isinstance(r, Results) and r.backend == backend
        assert r.labels == race_exp.labels
    # montecarlo and des agree on the workload's physics (§4 contract):
    # fast-path p50 within 5% relative, P(recovery) within 0.05 absolute
    mc, des = res["montecarlo"], res["des"]
    for i in range(len(SYSTEMS)):
        p50_mc = float(mc.summary["p50_ms"][i])
        p50_des = float(des.summary["p50_ms"][i])
        assert abs(p50_mc - p50_des) / p50_des < 0.05, (i, p50_mc, p50_des)
        rec_mc = float(mc.summary["recovery_rate"][i])
        rec_des = float(des.summary["recovery_rate"][i])
        assert abs(rec_mc - rec_des) < 0.05, (i, rec_mc, rec_des)
    # the model checker signs off on every declared system
    assert all(v["ok"] for v in res["modelcheck"].safety)
    # fault tolerance is backend-independent (computed from the masks)
    assert mc.fault_tolerance == des.fault_tolerance
    assert mc.fault_tolerance[0]["phase2_fast"] == 1        # n=5, q2f=4


def test_modelcheck_backend_flags_invalid_system():
    """Teeth: an Eq.14-violating system must come back unsafe, with the
    violating trace attached."""
    bad = ExplicitQuorumSystem.from_spec(QuorumSpec(3, 2, 2, 2))
    r = Experiment(systems=[bad], max_states=500_000).run("modelcheck")
    assert r.safety[0]["ok"] is False
    assert r.safety[0]["violation"] == "Consistency"
    assert r.safety[0]["trace"]
    assert r.summary["safe"][0] == 0.0


def test_modelcheck_backend_rejects_large_n():
    exp = Experiment(systems=[QuorumSpec.paper_headline(11)])
    with pytest.raises(ValueError, match="n<=5"):
        exp.run("modelcheck")


def test_montecarlo_single_compile_and_masked_lowering(race_exp):
    """The declarative layer must not cost extra compiles: re-running the
    same experiment reuses the engine's jit cache, and its lowering is the
    mask table (general, since the batch mixes families)."""
    table = race_exp.lower()
    assert "q" not in table                       # mixed families
    assert table["p1_w"].shape == (3, table["p1_w"].shape[1], 5)
    race_exp.run("montecarlo")
    before = dict(engine.TRACE_COUNTS)
    race_exp.run("montecarlo")
    assert engine.TRACE_COUNTS == before


def test_cardinality_experiment_lowers_to_q_specialization():
    exp = Experiment(systems=[QuorumSpec(5, 4, 2, 4), QuorumSpec(5, 5, 1, 4)],
                     workload=Workload.race(k=2, delta_ms=0.3),
                     samples=2_000)
    assert "q" in exp.lower()
    out = exp.run("montecarlo")
    assert out.raw["latency_ms"].shape == (2, 2_000)


# ---------------------------------------------------------------------------
# Results shape
# ---------------------------------------------------------------------------

def test_results_to_dict_and_system_view(race_exp):
    r = race_exp.run("montecarlo")
    d = r.to_dict()
    lab = r.labels[0]
    assert f"{lab}.p50_ms" in d and f"{lab}.ft_fast" in d
    assert d[f"{lab}.p50_ms"] == pytest.approx(float(r.summary["p50_ms"][0]))
    view = r.system(lab)
    assert view["p50_ms"] == d[f"{lab}.p50_ms"]
    assert view["ft_phase2_fast"] == r.fault_tolerance[0]["phase2_fast"]


def test_results_is_a_pytree(race_exp):
    r = race_exp.run("montecarlo")
    leaves, treedef = jax.tree_util.tree_flatten(r)
    assert leaves
    r2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(r2, Results)
    assert r2.labels == r.labels and r2.backend == r.backend
    doubled = jax.tree_util.tree_map(lambda x: x * 2, r)
    assert float(doubled.summary["p50_ms"][0]) == pytest.approx(
        2 * float(r.summary["p50_ms"][0]))


def test_duplicate_labels_are_disambiguated():
    exp = Experiment(systems=[QuorumSpec(5, 4, 2, 4), QuorumSpec(5, 4, 2, 4)])
    assert len(set(exp.labels)) == 2


# ---------------------------------------------------------------------------
# faults and guardrails
# ---------------------------------------------------------------------------

def test_faults_cross_backend_agreement():
    """Crashing past the phase-1 budget (q1=4 of n=5, two crashes) must kill
    liveness identically on both executable backends."""
    exp = Experiment(systems=[QuorumSpec(5, 4, 2, 4)],
                     workload=Workload.race(k=2, delta_ms=0.3),
                     faults=(0, 1), samples=4_000)
    mc = exp.run("montecarlo")
    des = exp.run("des")
    assert float(mc.summary["undecided_rate"][0]) == 1.0
    assert des.summary["undecided_rate"][0] == 1.0


def test_mixed_cluster_sizes_rejected():
    with pytest.raises(ValueError, match="system 1"):
        Experiment(systems=[QuorumSpec(5, 4, 2, 4),
                            ExplicitQuorumSystem.grid(1)]).lower()


def test_raw_masks_rejected_on_set_level_backends():
    masks_only = ExplicitQuorumSystem.grid(1).to_masks().embed(5)
    exp = Experiment(systems=[QuorumSpec(5, 4, 2, 4), masks_only],
                     samples=500)
    exp.run("montecarlo")                         # engine path is fine
    with pytest.raises(ValueError, match="montecarlo"):
        exp.run("des")


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="backend"):
        Experiment(systems=[QuorumSpec(5, 4, 2, 4)], backend="paxi")
    with pytest.raises(ValueError, match="backend"):
        Experiment(systems=[QuorumSpec(5, 4, 2, 4)]).run("paxi")


def test_wan_workload_refuses_des_backend():
    exp = Experiment(systems=[QuorumSpec(5, 4, 2, 4)],
                     workload=Workload.wan(k=2), samples=500)
    exp.run("montecarlo")
    with pytest.raises(ValueError, match="montecarlo backend"):
        exp.run("des")


# ---------------------------------------------------------------------------
# streaming (trials=): fixed-memory trial scaling through the same front door
# ---------------------------------------------------------------------------

def test_streamed_experiment_matches_materialized_summary():
    """``trials=`` must expose the same normalized keys with values that
    agree with the materializing path at the same sample count (within the
    sketch's relative error + Monte-Carlo noise across PRNG layouts)."""
    kw = dict(systems=SYSTEMS, workload=Workload.race(k=2, delta_ms=0.3),
              compute_fault_tolerance=False)
    mat = Experiment(samples=40_000, **kw).run("montecarlo")
    stream = Experiment(trials=40_000, chunk=8_192, **kw).run("montecarlo")
    assert stream.raw is None and stream.stream is not None
    assert set(mat.summary) <= set(stream.summary)
    assert "p999_ms" in stream.summary
    for i in range(len(SYSTEMS)):
        p50_m = float(mat.summary["p50_ms"][i])
        p50_s = float(stream.summary["p50_ms"][i])
        assert abs(p50_s - p50_m) / p50_m < 0.05, (i, p50_m, p50_s)
        rec_m = float(mat.summary["recovery_rate"][i])
        rec_s = float(stream.summary["recovery_rate"][i])
        assert abs(rec_s - rec_m) < 0.02, (i, rec_m, rec_s)


def test_streamed_experiment_is_a_pytree_with_stream_state():
    r = Experiment(systems=[QuorumSpec(5, 4, 2, 4)], trials=3_000,
                   chunk=1_024, compute_fault_tolerance=False
                   ).run("montecarlo")
    assert int(r.stream.n_trials[0]) == 3_000
    leaves, treedef = jax.tree_util.tree_flatten(r)
    r2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(r2, Results) and r2.stream is not None
    assert int(r2.stream.n_trials[0]) == 3_000


def test_streamed_experiment_rejects_bad_trials():
    with pytest.raises(ValueError, match="trials"):
        Experiment(systems=[QuorumSpec(5, 4, 2, 4)], trials=0)
