"""Unit tests for the attention shard plan, head padding and fsdp_use —
the §Perf levers (EXPERIMENTS.md).  Uses a small host-device mesh so the
logic is exercised without the 512-device dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.layers as L
from repro.parallel.sharding import (default_rules, fsdp_use, sharding_ctx,
                                     spec_for)

pytestmark = pytest.mark.skipif(
    jax.device_count() != 1, reason="smoke tests expect 1 device")


def _mesh2d():
    # 1x1 host mesh keeps semantics; shard-plan logic only reads axis SIZES,
    # so we fake sizes via a Mesh of the real single device reshaped 1x1.
    return jax.make_mesh((1, 1), ("data", "model"))


class _FakeMesh:
    """Minimal mesh stand-in for _attn_shard_plan (reads .shape only)."""

    def __init__(self, model):
        self.shape = {"data": 16, "model": model}


def test_shard_plan_divisible_heads(monkeypatch):
    monkeypatch.setattr(L, "active_mesh", lambda: _FakeMesh(16))
    assert L._attn_shard_plan(16) == ("seq", 16)
    assert L._attn_shard_plan(32) == ("seq", 32)
    assert L._attn_shard_plan(48) == ("seq", 48)


def test_shard_plan_pads_when_waste_small(monkeypatch):
    monkeypatch.setattr(L, "active_mesh", lambda: _FakeMesh(16))
    # musicgen: 24 -> 32 (33% waste, <= 50%)
    assert L._attn_shard_plan(24) == ("seq", 32)
    # 12 -> 16 (33%)
    assert L._attn_shard_plan(12) == ("seq", 16)


def test_shard_plan_seq_sp_when_waste_large(monkeypatch):
    monkeypatch.setattr(L, "active_mesh", lambda: _FakeMesh(16))
    # 9 heads -> pad 16 would waste 78% -> context-parallel instead
    assert L._attn_shard_plan(9) == ("seq_sp", 9)


def test_shard_plan_no_mesh():
    assert L._attn_shard_plan(24) == ("seq", 24)


def test_pad_heads_zero_contribution():
    """Dead (zero-weight) heads contribute exactly 0 to the output."""
    key = jax.random.PRNGKey(0)
    wo = jax.random.normal(key, (24, 16, 32))
    wo_pad = L._pad_heads(wo, 32, 0)
    o = jax.random.normal(key, (2, 8, 32, 16))          # padded-head attn out
    y_pad = jnp.einsum("bshk,hkd->bsd", o, wo_pad)
    y_ref = jnp.einsum("bshk,hkd->bsd", o[:, :, :24], wo)
    np.testing.assert_allclose(np.asarray(y_pad), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_fsdp_use_releases_embed_dim():
    mesh = _mesh2d()
    with sharding_ctx(mesh, default_rules()):
        w = jnp.ones((64, 32), jnp.float32)
        out = fsdp_use(w, ("embed", "mlp"), jnp.bfloat16)
        assert out.dtype == jnp.bfloat16
    # spec resolution: embed_full is never sharded
    spec = spec_for((64, 32), ("embed_full", "mlp"), mesh, default_rules())
    assert spec[0] is None


def test_fsdp_use_no_mesh_is_plain_cast():
    w = jnp.ones((8, 8))
    out = fsdp_use(w, ("embed", "mlp"), jnp.bfloat16)
    assert out.dtype == jnp.bfloat16
