"""Streaming engine tests (``repro.montecarlo.streaming``): sketch
correctness against exact percentiles, merge algebra, chunked-vs-
materialized identity, trial-axis sharding, and the fixed-memory scaling
contract (state size independent of trial count)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.quorum import ExplicitQuorumSystem, QuorumSpec
from repro.montecarlo import build_mask_table, engine, streaming
from repro.montecarlo.streaming import (StreamSummary, bucket_index,
                                        bucket_value, sketch_bins,
                                        sketch_gamma)

KEY = jax.random.PRNGKey(0)
FFP = QuorumSpec.paper_headline(11)
FP = QuorumSpec.fast_paxos(11)
OFFS = jnp.array([0.0, 0.25], jnp.float32)


def _lat_summary(lat, precision=0.01):
    """Wrap a latency vector as an all-decided StreamSummary."""
    lat = jnp.asarray(lat, jnp.float32).reshape(1, -1)
    out = {"latency_ms": lat,
           "undecided": jnp.zeros_like(lat, bool),
           "reached_fast": jnp.ones_like(lat, bool),
           "recovery": jnp.zeros_like(lat, bool)}
    return StreamSummary.from_outcomes(out, precision)


# ---------------------------------------------------------------------------
# sketch: quantiles within the guaranteed relative error
# ---------------------------------------------------------------------------

def test_bucket_roundtrip_relative_error():
    """bucket_value(bucket_index(x)) is within ``precision`` of x across
    the covered range — the DDSketch invariant the quantile bound rests
    on."""
    for precision in (0.005, 0.01, 0.05):
        x = jnp.logspace(-1.5, 5.5, 4_000, dtype=jnp.float32)
        est = bucket_value(bucket_index(x, precision), precision)
        rel = jnp.abs(est - x) / x
        # float32 log/pow rounding eats a hair of the analytic bound
        assert float(rel.max()) < precision * 1.02, (precision,
                                                     float(rel.max()))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16), size=st.integers(200, 20_000),
       scale=st.floats(0.2, 50.0))
def test_sketch_quantiles_converge_to_exact(seed, size, scale):
    """Satellite: streamed p50/p99 within the sketch's guaranteed relative
    error of exact ``jnp.percentile`` (plus one-sample rank slack)."""
    precision = 0.01
    lat = scale * jnp.exp(
        0.6 * jax.random.normal(jax.random.PRNGKey(seed), (size,))) + 0.05
    s = _lat_summary(lat, precision)
    for q in (0.5, 0.99):
        exact = float(jnp.percentile(lat, 100.0 * q))
        # the sketch uses the ceil(q*n)-th order statistic; percentile
        # interpolates — allow one rank of drift on top of the error bound
        lo = float(jnp.sort(lat)[max(0, int(np.ceil(q * size)) - 2)])
        hi = float(jnp.sort(lat)[min(size - 1, int(np.ceil(q * size)))])
        est = float(s.quantile(q)[0])
        assert (1 - 1.05 * precision) * lo <= est <= (1 + 1.05 * precision) \
            * hi, (q, est, exact, lo, hi)


def test_sketch_precision_knob_tightens_error():
    lat = jnp.exp(0.8 * jax.random.normal(KEY, (50_000,))) + 0.3
    exact = float(jnp.percentile(lat, 99.0))
    err = {}
    for precision in (0.05, 0.005):
        est = float(_lat_summary(lat, precision).quantile(0.99)[0])
        err[precision] = abs(est - exact) / exact
        assert err[precision] < precision * 1.1
    assert err[0.005] < err[0.05]


# ---------------------------------------------------------------------------
# merge algebra: exact, associative, commutative
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_sketch_merge_commutative_and_associative(seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    parts = [_lat_summary(jnp.exp(jax.random.normal(k, (s,))) + 0.1)
             for k, s in zip(ks, (400, 1_300, 77))]
    a, b, c = parts
    ab, ba = a.merge(b), b.merge(a)
    # integer state merges bit-for-bit in either order
    np.testing.assert_array_equal(np.asarray(ab.hist), np.asarray(ba.hist))
    np.testing.assert_array_equal(np.asarray(ab.n_fast),
                                  np.asarray(ba.n_fast))
    assert np.allclose(np.asarray(ab.mean_ms), np.asarray(ba.mean_ms),
                       rtol=1e-6)
    abc1, abc2 = a.merge(b).merge(c), a.merge(b.merge(c))
    np.testing.assert_array_equal(np.asarray(abc1.hist),
                                  np.asarray(abc2.hist))
    np.testing.assert_array_equal(np.asarray(abc1.n_trials),
                                  np.asarray(abc2.n_trials))
    assert np.allclose(np.asarray(abc1.mean_ms), np.asarray(abc2.mean_ms),
                       rtol=1e-5)
    assert np.allclose(np.asarray(abc1.max_ms), np.asarray(abc2.max_ms))
    # merged quantiles == quantiles of the concatenated sample's sketch
    whole = _lat_summary(jnp.concatenate(
        [jnp.exp(jax.random.normal(k, (s,))) + 0.1
         for k, s in zip(ks, (400, 1_300, 77))]))
    np.testing.assert_array_equal(np.asarray(abc1.hist),
                                  np.asarray(whole.hist))


def test_merge_rejects_mismatched_precision():
    a = _lat_summary(jnp.ones((10,)), 0.01)
    b = _lat_summary(jnp.ones((10,)), 0.02)
    with pytest.raises(ValueError, match="precision"):
        a.merge(b)


# ---------------------------------------------------------------------------
# chunked streaming vs the materializing engine
# ---------------------------------------------------------------------------

def test_single_chunk_bit_identical_to_materialized():
    """Satellite: for T <= chunk the stream IS the materializing path plus
    a reduction — integer state and the max match bit-for-bit."""
    out = engine.race(KEY, build_mask_table([FFP, FP]), OFFS, n=11,
                      k_proposers=2, samples=5_000)
    ref = StreamSummary.from_outcomes(out)
    st_ = streaming.race_stream(KEY, build_mask_table([FFP, FP]), OFFS,
                                n=11, k_proposers=2, trials=5_000,
                                chunk=8_192, shard=False)
    for f in ("n_trials", "n_fast", "n_recovery", "n_undecided", "hist"):
        np.testing.assert_array_equal(np.asarray(getattr(st_, f)),
                                      np.asarray(getattr(ref, f)), f)
    np.testing.assert_array_equal(np.asarray(st_.max_ms),
                                  np.asarray(ref.max_ms))
    assert np.allclose(np.asarray(st_.mean_ms), np.asarray(ref.mean_ms),
                       rtol=1e-6)


@settings(max_examples=8, deadline=None)
@given(trials=st.integers(1, 9_000), chunk=st.integers(64, 4_096))
def test_chunk_overhang_accounting(trials, chunk):
    """Every trial is counted exactly once whatever the chunk overhang."""
    table = build_mask_table([FFP])
    st_ = streaming.fast_path_stream(jax.random.PRNGKey(trials), table,
                                     n=11, trials=trials, chunk=chunk,
                                     shard=False)
    assert int(st_.n_trials[0]) == trials
    assert int(st_.n_fast[0] + st_.n_recovery[0]
               + st_.n_undecided[0]) == trials
    assert int(np.asarray(st_.hist.sum())) == int(st_.n_decided[0])


def test_multichunk_agrees_with_materialized_statistics():
    table = build_mask_table([FFP, FP])
    st_ = streaming.race_stream(KEY, table, OFFS, n=11, k_proposers=2,
                                trials=40_000, chunk=8_192, shard=False)
    out = engine.race(jax.random.PRNGKey(5), table, OFFS, n=11,
                      k_proposers=2, samples=40_000)
    exact = engine.summarize(out)
    got = st_.summary()
    for i in range(2):
        assert abs(float(got["p50_ms"][i]) - float(exact["p50_ms"][i])) \
            / float(exact["p50_ms"][i]) < 0.05
        assert abs(float(got["recovery_rate"][i])
                   - float(exact["recovery_rate"][i])) < 0.02


def test_stream_masked_tables_and_fused_kernel_agree():
    """The fused Pallas chunk reduction (masked tally + decide + histogram
    in one kernel pass) must match the jnp scatter path: integer state
    bit-for-bit, float reductions to tolerance."""
    grid = ExplicitQuorumSystem.grid(3).to_masks().embed(11)
    table = build_mask_table([FFP.to_masks(), grid])
    assert "q" not in table
    kw = dict(n=11, k_proposers=2, trials=6_000, chunk=2_048, shard=False)
    ref = streaming.race_stream(KEY, table, OFFS, use_kernel=False, **kw)
    ker = streaming.race_stream(KEY, table, OFFS, use_kernel=True, **kw)
    for f in ("n_trials", "n_fast", "n_recovery", "n_undecided", "hist"):
        np.testing.assert_array_equal(np.asarray(getattr(ref, f)),
                                      np.asarray(getattr(ker, f)), f)
    assert np.allclose(np.asarray(ref.mean_ms), np.asarray(ker.mean_ms),
                       rtol=1e-5)
    assert np.allclose(np.asarray(ref.max_ms), np.asarray(ker.max_ms))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 16),
       G=st.integers(1, 5))
def test_topk_prefix_saturation_equals_full_sort(seed, n, G):
    """Satellite: for any masked table, cutting the sort to the
    ``saturation_depths`` prefix leaves ``_sat_time`` bit-identical to the
    full sort — quantized delays force ties (the top-k tie-break must match
    stable argsort order) and some arrivals sit at the crashed/lost ``inf``
    sentinel."""
    rng = np.random.default_rng(seed)
    S = 64
    w = rng.integers(0, 4, size=(G, n)).astype(np.float32)
    # mix of saturable and unsaturable rows (threshold above total weight)
    t = np.maximum(1.0, rng.integers(1, max(2, int(w.sum(-1).max()) + 3),
                                     size=(G,))).astype(np.float32)
    x = np.floor(rng.exponential(4.0, size=(S, n)) * 4.0) / 4.0   # ties
    x[rng.random((S, n)) < 0.15] = float(engine.BIG)   # crashed / lost
    xw, tw = jnp.asarray(w), jnp.asarray(t)
    xj = jnp.asarray(x, jnp.float32)

    tbl = {"p1_w": xw[None], "p1_t": tw[None], "p2c_w": xw[None],
           "p2c_t": tw[None], "p2f_w": xw[None], "p2f_t": tw[None]}
    k = engine.saturation_depths(tbl)[0]
    srt_full, perm_full = engine._topk_ascending(xj, None)
    srt_k, perm_k = engine._topk_ascending(xj, k)
    full = engine._sat_time(srt_full, perm_full, xw, tw)
    pref = engine._sat_time(srt_k, perm_k, xw, tw)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(pref))
    # top-k prefix itself matches the stable full sort element-for-element
    np.testing.assert_array_equal(np.asarray(srt_full[:, :k]),
                                  np.asarray(srt_k))
    np.testing.assert_array_equal(np.asarray(perm_full[:, :k]),
                                  np.asarray(perm_k))


def test_sortfree_card_streams_bit_identical_to_full_sort():
    """Acceptance gate: on a cardinality batch, the sort-free streamed
    lowering (k_max="auto" — shared-column order-statistic reductions, no
    per-system sorted gathers) produces bit-identical integer state and
    histogram vs the retained full-sort reference path (k_max=None) on all
    three drivers."""
    table = build_mask_table([FFP, FP, QuorumSpec.majority_fast(11)])
    assert "q" in table
    kw = dict(n=11, trials=20_000, chunk=4_096, shard=False)
    fields = ("n_trials", "n_fast", "n_recovery", "n_undecided", "hist")
    for name, call in (
        ("race", lambda km: streaming.race_stream(
            KEY, table, OFFS, n=11, k_proposers=2, trials=20_000,
            chunk=4_096, shard=False, k_max=km)),
        ("fast", lambda km: streaming.fast_path_stream(KEY, table,
                                                       k_max=km, **kw)),
        ("classic", lambda km: streaming.classic_path_stream(KEY, table,
                                                             k_max=km, **kw)),
    ):
        ref, new = call(None), call("auto")
        for f in fields:
            np.testing.assert_array_equal(np.asarray(getattr(new, f)),
                                          np.asarray(getattr(ref, f)),
                                          f"{name}.{f}")
        np.testing.assert_array_equal(np.asarray(new.max_ms),
                                      np.asarray(ref.max_ms), name)
        assert np.allclose(np.asarray(new.mean_ms), np.asarray(ref.mean_ms),
                           rtol=1e-5, equal_nan=True)


def test_recovery_rule_streamed_parity_and_invariants():
    """The collision-recovery rule as a dispatch axis: the entry condition
    (hence every count) is rule-invariant, fast-path latencies are
    bit-identical (recovery only re-prices the classic leg), the
    histograms DO move, and the sort-free lowering stays bit-identical to
    the full-sort reference under both rules."""
    table = build_mask_table([FFP, FP])
    kw = dict(n=11, k_proposers=2, trials=20_000, chunk=4_096, shard=False)
    sc = streaming.race_stream(KEY, table, OFFS, **kw)
    su = streaming.race_stream(KEY, table, OFFS,
                               recovery="uncoordinated", **kw)
    for f in ("n_trials", "n_fast", "n_recovery", "n_undecided"):
        np.testing.assert_array_equal(np.asarray(getattr(sc, f)),
                                      np.asarray(getattr(su, f)), f)
    assert not np.array_equal(np.asarray(sc.hist), np.asarray(su.hist))
    for mode in ("coordinated", "uncoordinated"):
        ref = streaming.race_stream(KEY, table, OFFS, k_max=None,
                                    recovery=mode, **kw)
        new = streaming.race_stream(KEY, table, OFFS, k_max="auto",
                                    recovery=mode, **kw)
        np.testing.assert_array_equal(np.asarray(new.hist),
                                      np.asarray(ref.hist), mode)

    # materializing path: the fast-path latency samples are bit-identical
    # across rules; only recovered trials move
    oc = engine.race(KEY, table, OFFS, n=11, k_proposers=2, samples=4_000)
    ou = engine.race(KEY, table, OFFS, n=11, k_proposers=2, samples=4_000,
                     recovery="uncoordinated")
    np.testing.assert_array_equal(np.asarray(oc["reached_fast"]),
                                  np.asarray(ou["reached_fast"]))
    fast = np.asarray(oc["reached_fast"])
    np.testing.assert_array_equal(np.asarray(oc["latency_ms"])[fast],
                                  np.asarray(ou["latency_ms"])[fast])

    with pytest.raises(ValueError, match="unknown recovery rule"):
        streaming.race_stream(KEY, table, OFFS, recovery="oracle", **kw)
    with pytest.raises(ValueError, match="unknown recovery rule"):
        engine.race(KEY, table, OFFS, n=11, k_proposers=2, samples=100,
                    recovery="oracle")


def test_recovery_rule_fused_kernel_agrees():
    """The fused Pallas lowering under the uncoordinated rule (recovery
    saturation fed the p2f masks) matches the jnp scatter path."""
    grid = ExplicitQuorumSystem.grid(3).to_masks().embed(11)
    table = build_mask_table([FFP.to_masks(), grid])
    assert "q" not in table
    kw = dict(n=11, k_proposers=2, trials=6_000, chunk=2_048, shard=False,
              recovery="uncoordinated")
    ref = streaming.race_stream(KEY, table, OFFS, use_kernel=False, **kw)
    ker = streaming.race_stream(KEY, table, OFFS, use_kernel=True, **kw)
    for f in ("n_trials", "n_fast", "n_recovery", "n_undecided", "hist"):
        np.testing.assert_array_equal(np.asarray(getattr(ref, f)),
                                      np.asarray(getattr(ker, f)), f)
    assert np.allclose(np.asarray(ref.mean_ms), np.asarray(ker.mean_ms),
                       rtol=1e-5)


def test_k_max_below_saturation_depth_rejected():
    """An explicit k_max below the table's saturation depths would silently
    change semantics — the driver must refuse it."""
    table = build_mask_table([FFP, FP])
    with pytest.raises(ValueError, match="saturation depths"):
        streaming.fast_path_stream(KEY, table, n=11, trials=20_000,
                                   chunk=4_096, shard=False, k_max=(1, 1, 1))


def test_stream_single_compile_per_table_shape():
    """TRACE_COUNTS invariant: one compile per (table shape, chunk count) —
    different trial counts with the same chunking, different keys, and
    different same-shape tables all re-enter it (trials and table contents
    are traced; only the scan length is static)."""
    table = build_mask_table([FFP, FP])
    streaming.race_stream(KEY, table, OFFS, n=11, k_proposers=2,
                          trials=9_000, chunk=2_048, shard=False)
    before = dict(engine.TRACE_COUNTS)
    # 8_300..10_240 trials all scan 5 chunks of 2_048
    streaming.race_stream(jax.random.PRNGKey(1), table, OFFS, n=11,
                          k_proposers=2, trials=10_000, chunk=2_048,
                          shard=False)
    streaming.race_stream(KEY, build_mask_table([FP, FFP]), OFFS, n=11,
                          k_proposers=2, trials=8_500, chunk=2_048,
                          shard=False)
    assert engine.TRACE_COUNTS == before


def test_stream_state_size_independent_of_trials():
    """The fixed-memory contract at the state level: summary leaves have
    identical shapes at 3k and 300k trials (only chunk size matters)."""
    table = build_mask_table([FFP])
    small = streaming.fast_path_stream(KEY, table, n=11, trials=3_000,
                                       chunk=1_024, shard=False)
    big = streaming.fast_path_stream(KEY, table, n=11, trials=300_000,
                                     chunk=1_024, shard=False)
    shapes = lambda s: [leaf.shape for leaf in jax.tree_util.tree_leaves(s)]
    assert shapes(small) == shapes(big)
    assert int(big.n_trials[0]) == 300_000


def test_classic_path_stream_semantics():
    table = build_mask_table([FFP])
    st_ = streaming.classic_path_stream(KEY, table, n=11, trials=5_000,
                                        chunk=2_048, shard=False)
    assert int(st_.n_fast[0]) == 0
    assert int(st_.n_recovery[0]) == 5_000
    fast = streaming.fast_path_stream(KEY, table, n=11, trials=5_000,
                                      chunk=2_048, shard=False)
    # classic adds the client->leader relay hop
    assert float(st_.quantile(0.5)[0]) > float(fast.quantile(0.5)[0])


def test_empty_summary_is_nan_rates_zero():
    s = StreamSummary.zeros(2)
    d = s.summary()
    assert np.isnan(np.asarray(d["p50_ms"])).all()
    assert np.isnan(np.asarray(d["mean_ms"])).all()
    assert float(d["fast_rate"][0]) == 0.0


# ---------------------------------------------------------------------------
# sharding over the trial axis (exercised for real in the CI multi-device
# job via XLA_FLAGS=--xla_force_host_platform_device_count=8)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >1 device (run under "
                           "--xla_force_host_platform_device_count)")
def test_sharded_stream_counts_exact_and_stats_agree():
    table = build_mask_table([FFP, FP])
    trials = 30_011                      # deliberately not divisible
    sh = streaming.race_stream(KEY, table, OFFS, n=11, k_proposers=2,
                               trials=trials, chunk=2_048, shard=True)
    assert [int(x) for x in sh.n_trials] == [trials, trials]
    un = streaming.race_stream(KEY, table, OFFS, n=11, k_proposers=2,
                               trials=trials, chunk=2_048, shard=False)
    for i in range(2):
        assert abs(float(sh.quantile(0.5)[i]) - float(un.quantile(0.5)[i])) \
            / float(un.quantile(0.5)[i]) < 0.05
        assert abs(float(sh.summary()["recovery_rate"][i])
                   - float(un.summary()["recovery_rate"][i])) < 0.02


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >1 device (run under "
                           "--xla_force_host_platform_device_count)")
def test_sharded_fast_path_stream_exact_totals():
    table = build_mask_table([FFP])
    st_ = streaming.fast_path_stream(KEY, table, n=11, trials=10_001,
                                     chunk=512, shard=True)
    assert int(st_.n_trials[0]) == 10_001
    assert int(st_.n_fast[0]) == 10_001       # no loss model -> all decide


# ---------------------------------------------------------------------------
# acceptance: 10^7 trials, n=11, fixed memory, through the Experiment API
# ---------------------------------------------------------------------------

def test_experiment_ten_million_trials_fixed_memory():
    """The ISSUE acceptance criterion: an n=11 system streams 10^7 trials
    through ``Experiment`` with a fixed-size state, and the streamed p50/
    p99 sit within the sketch's documented error of exact percentiles
    measured on a materialized slice of the same workload."""
    from repro.api import Experiment, Workload
    exp = Experiment(systems=[FFP], workload=Workload.conflict_free(),
                     trials=10_000_000, chunk=262_144,
                     compute_fault_tolerance=False)
    r = exp.run("montecarlo")
    state = r.stream
    assert int(state.n_trials[0]) == 10_000_000
    assert state.hist.shape == (1, sketch_bins(exp.precision))
    # exact reference: a materialized 200k sample of the same distribution
    exact = engine.summarize(engine.fast_path(
        jax.random.PRNGKey(17), build_mask_table([FFP]), n=11,
        samples=200_000))
    for q in ("p50_ms", "p99_ms"):
        got, ref = float(r.summary[q][0]), float(exact[q][0])
        # sketch precision + cross-sample Monte-Carlo noise at 200k
        assert abs(got - ref) / ref < exp.precision + 0.02, (q, got, ref)


# ---------------------------------------------------------------------------
# RNG fold-in domains: device keys must never collide with chunk keys
# ---------------------------------------------------------------------------

def test_device_and_chunk_fold_in_domains_disjoint():
    """Regression (ISSUE 7 satellite): the sharded per-device keys used to
    be ``fold_in(key, 0x5eed + d)`` — the same fold-in space as the
    unsharded per-chunk keys ``fold_in(key, c)``, so chunk 0x5eed + d of a
    long stream replayed device d's draws.  The two-level derivation
    ``fold_in(fold_in(key, DEVICE_FOLD_DOMAIN), d)`` must be disjoint from
    every chunk key for n_chunks up to 2^20."""
    key = jax.random.PRNGKey(0)
    n_chunks, n_dev = 1 << 20, 4_096
    chunk_keys = jax.vmap(lambda c: jax.random.fold_in(key, c))(
        jnp.arange(n_chunks, dtype=jnp.int32))
    dev_base = jax.random.fold_in(
        key, jnp.int32(streaming.DEVICE_FOLD_DOMAIN))
    dev_keys = jax.vmap(lambda d: jax.random.fold_in(dev_base, d))(
        jnp.arange(n_dev, dtype=jnp.int32))

    def pack(ks):                         # (N, 2) uint32 -> (N,) uint64
        a = np.asarray(ks).astype(np.uint64)
        return (a[:, 0] << np.uint64(32)) | a[:, 1]

    assert np.intersect1d(pack(chunk_keys), pack(dev_keys)).size == 0
    # and the OLD single-level scheme demonstrably collided: device 0's
    # key WAS chunk key 0x5eed
    old_dev0 = jax.random.fold_in(key, jnp.int32(0x5eed) + jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(old_dev0),
                                  np.asarray(chunk_keys[0x5eed]))


# ---------------------------------------------------------------------------
# mesh resolution: loud single-device fallback, explicit meshes honored
# ---------------------------------------------------------------------------

def test_resolve_mesh_single_device_warns_or_shards():
    import warnings as _warnings
    with _warnings.catch_warnings(record=True) as w:
        _warnings.simplefilter("always")
        mesh = streaming._resolve_mesh(True)
    if len(jax.devices()) == 1:
        assert mesh is None
        assert any("only 1 device" in str(x.message) for x in w), \
            [str(x.message) for x in w]
    else:
        assert mesh is not None
        assert not w, [str(x.message) for x in w]
    # shard=False / None stay silent and unsharded
    with _warnings.catch_warnings(record=True) as w:
        _warnings.simplefilter("always")
        assert streaming._resolve_mesh(False) is None
        assert streaming._resolve_mesh(None) is None
    assert not w


def test_explicit_single_device_mesh_honored():
    """A deliberately-passed 1-device Mesh must run the sharded (collective)
    code path, not silently degrade to unsharded — multi-process workers
    depend on every process entering the same psum."""
    from repro.parallel import sharding as psharding
    mesh = psharding.trial_mesh(jax.devices()[:1])
    assert streaming._resolve_mesh(mesh) is mesh
    table = build_mask_table([FFP, FP])
    st_ = streaming.race_stream(KEY, table, OFFS, n=11, k_proposers=2,
                                trials=10_007, chunk=2_048, shard=mesh)
    assert [int(x) for x in st_.n_trials] == [10_007, 10_007]
    un = streaming.race_stream(KEY, table, OFFS, n=11, k_proposers=2,
                               trials=10_007, chunk=2_048, shard=False)
    # different (device-domain) key stream, same distribution
    for i in range(2):
        assert abs(float(st_.quantile(0.5)[i]) - float(un.quantile(0.5)[i])) \
            / float(un.quantile(0.5)[i]) < 0.05


# ---------------------------------------------------------------------------
# trials < ndev: empty devices contribute the exact zeros identity
# ---------------------------------------------------------------------------

def test_zero_summary_is_exact_merge_identity():
    """zeros() must be the identity of the merge algebra — counts/hist
    unchanged, max_ms not poisoned by the -inf init, mean not NaN — because
    on a wide mesh with trials < ndev the trailing devices contribute
    exactly this state to the cross-device psum/pmax."""
    table = build_mask_table([FFP, FP])
    st_ = streaming.race_stream(KEY, table, OFFS, n=11, k_proposers=2,
                                trials=4_000, chunk=1_024, shard=False)
    for merged in (st_.merge(StreamSummary.zeros(2, st_.precision)),
                   StreamSummary.zeros(2, st_.precision).merge(st_)):
        for f in ("n_trials", "n_fast", "n_recovery", "n_undecided", "hist"):
            np.testing.assert_array_equal(np.asarray(getattr(merged, f)),
                                          np.asarray(getattr(st_, f)), f)
        np.testing.assert_array_equal(np.asarray(merged.max_ms),
                                      np.asarray(st_.max_ms))
        assert np.isfinite(np.asarray(merged.mean_ms)).all()
        np.testing.assert_allclose(np.asarray(merged.mean_ms),
                                   np.asarray(st_.mean_ms), rtol=1e-6)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >1 device (run under "
                           "--xla_force_host_platform_device_count)")
def test_sharded_trials_below_device_count():
    """trials < ndev leaves devices empty: their short-circuited zero
    contribution must keep the merged summary exact (no -inf/NaN leakage)."""
    ndev = len(jax.devices())
    table = build_mask_table([FFP])
    st_ = streaming.fast_path_stream(KEY, table, n=11, trials=ndev - 1,
                                     chunk=64, shard=True)
    assert int(st_.n_trials[0]) == ndev - 1
    assert int(st_.n_fast[0]) == ndev - 1
    assert np.isfinite(np.asarray(st_.max_ms)).all()
    assert np.isfinite(np.asarray(st_.mean_ms)).all()
    assert int(np.asarray(st_.hist).sum()) == ndev - 1


# ---------------------------------------------------------------------------
# _resolve_k_sat edge cases: clip-vs-validate order pinned
# ---------------------------------------------------------------------------

def test_resolve_k_sat_clips_above_n_after_validation():
    """Components > n pass depth validation first and only then clip to n
    — an explicit (100, 100, 100) is a valid 'everything' request."""
    table = build_mask_table([FFP, FP])
    assert streaming._resolve_k_sat(table, (100, 100, 100), 11) \
        == (11, 11, 11)


def test_resolve_k_sat_validates_before_clipping():
    """The order is observable below 1: on a depth-(1,1,1) table a request
    of (0,0,0) must RAISE (validate first) — clip-first would silently lift
    it to the legal (1,1,1)."""
    table = build_mask_table([QuorumSpec(1, 1, 1, 1)])
    assert engine.saturation_depths(table) == (1, 1, 1)
    with pytest.raises(ValueError, match="saturation depths"):
        streaming._resolve_k_sat(table, (0, 0, 0), 1)
    # and the clipped legal request still resolves
    assert streaming._resolve_k_sat(table, (5, 5, 5), 1) == (1, 1, 1)


def test_resolve_k_sat_int_below_depths_raises():
    table = build_mask_table([FFP, FP])     # q2f depths reach 9 (FP)
    with pytest.raises(ValueError, match="saturation depths"):
        streaming._resolve_k_sat(table, 2, 11)


def test_resolve_k_sat_auto_on_mixed_table():
    """'auto' on a mixed cardinality+masked batch (no "q" specialization)
    must still equal the table's saturation depths."""
    grid = ExplicitQuorumSystem.grid(3).to_masks().embed(11)
    table = build_mask_table([FFP.to_masks(), grid])
    assert "q" not in table
    assert streaming._resolve_k_sat(table, "auto", 11) \
        == engine.saturation_depths(table)
