"""Per-architecture smoke tests (assignment requirement): a REDUCED config of
each family runs one forward and one train step on CPU; output shapes and
finiteness asserted.  Full configs are exercised only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.models.model import DecoderLM
from repro.training.optimizer import adamw, apply_updates

B, S = 2, 64


def make_batch(cfg, key):
    if cfg.frontend == "audio_frames":
        return {"frame_emb": jax.random.normal(key, (B, S, cfg.d_model),
                                               jnp.bfloat16),
                "labels": jnp.zeros((B, S), jnp.int32)}
    if cfg.frontend == "vision_patches":
        V = cfg.vision_tokens
        return {"patch_emb": jax.random.normal(key, (B, V, cfg.d_model),
                                               jnp.bfloat16),
                "tokens": jnp.ones((B, S - V), jnp.int32),
                "labels": jnp.zeros((B, S - V), jnp.int32)}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = reduced_config(get_config(arch))
    model = DecoderLM(cfg, remat=True)
    params, axes = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    logits = model.forward(params, batch)
    exp_seq = S if cfg.frontend != "vision_patches" else S
    assert logits.shape == (B, exp_seq, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch

    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss)), arch
    gnorms = [float(jnp.abs(g).max()) for g in jax.tree.leaves(grads)]
    assert all(jnp.isfinite(g) for g in gnorms), arch
    assert any(g > 0 for g in gnorms), f"{arch}: all-zero grads"

    opt = adamw(lr=1e-3)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    new_params = apply_updates(params, updates)
    loss2 = model.loss(new_params, batch)
    assert bool(jnp.isfinite(loss2)), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_metadata(arch):
    """Full configs expose the exact assigned dimensions."""
    cfg = get_config(arch)
    n = cfg.param_count()
    assert n > 0
    assert cfg.active_param_count() <= n
    # the assignment's headline sizes (rough order-of-magnitude guards)
    expected = {
        "gemma3_12b": (8e9, 20e9), "nemotron_4_15b": (12e9, 20e9),
        "deepseek_7b": (5e9, 9e9), "olmo_1b": (0.9e9, 1.6e9),
        "deepseek_v2_lite_16b": (10e9, 20e9), "arctic_480b": (380e9, 520e9),
        "zamba2_2_7b": (2e9, 3.5e9), "musicgen_medium": (1.2e9, 2.4e9),
        "mamba2_130m": (0.1e9, 0.22e9), "internvl2_26b": (17e9, 26e9),
    }[arch]
    assert expected[0] < n < expected[1], (arch, n)


def test_gemma3_pattern():
    cfg = get_config("gemma3_12b")
    assert cfg.pattern == ("local",) * 5 + ("global",)
    assert cfg.n_superblocks == 8


def test_zamba2_pattern_and_shared_params():
    cfg = get_config("zamba2_2_7b")
    assert cfg.pattern == ("mamba",) * 6 + ("shared_attn",)
    assert cfg.n_superblocks == 9
    red = reduced_config(cfg)
    model = DecoderLM(red, remat=False)
    params, _ = model.init(jax.random.PRNGKey(0))
    assert "shared" in params          # weight-shared attention block


def test_mamba2_attention_free():
    cfg = get_config("mamba2_130m")
    assert cfg.attention_free
    red = reduced_config(cfg)
    model = DecoderLM(red, remat=False)
    params, _ = model.init(jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    names = ["/".join(str(p) for p in path) for path, _ in flat]
    assert not any("attn" in n for n in names)


def test_long_500k_support_flags():
    from repro.configs.base import SHAPES
    long = SHAPES["long_500k"]
    runnable = {a for a in ARCH_IDS
                if get_config(a).supports_shape(long)[0]}
    assert runnable == {"gemma3_12b", "zamba2_2_7b", "mamba2_130m"}
