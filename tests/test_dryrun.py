"""Dry-run machinery tests.

The full 512-device sweep is a deliverable run via
``python -m repro.launch.dryrun --all --both-meshes``; here we verify the
pieces — HLO collective parsing, roofline arithmetic, extrapolation — plus
one real (subprocess) lower+compile on the production mesh for the fastest
cell, proving the end-to-end path inside the test suite.
"""
import json
import os
import subprocess
import sys

import pytest

import benchmarks.roofline as rl


def test_parse_collectives_brace_groups():
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %ar = (f32[64]{0}, f32[32]{0}) all-reduce(%a, %b), replica_groups={{0,1}}, to_apply=%sum
  %rs = f32[16]{0} reduce-scatter(%c), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = f32[4]{0} collective-permute(%d), source_target_pairs={{0,1}}
"""
    ops = rl.parse_collectives(hlo)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "reduce-scatter"]
    ag = next(o for o in ops if o.kind == "all-gather")
    assert ag.out_bytes == 8 * 128 * 2 and ag.group == 4
    assert ag.link_bytes == pytest.approx(8 * 128 * 2 * 3 / 4)
    ar = next(o for o in ops if o.kind == "all-reduce")
    assert ar.out_bytes == 64 * 4 + 32 * 4
    rs = next(o for o in ops if o.kind == "reduce-scatter")
    assert rs.link_bytes == pytest.approx(16 * 4 * 3)


def test_parse_collectives_iota_groups_and_pod_detection():
    hlo = """
  %ar = f32[1024]{0} all-reduce(%a), replica_groups=[16,32]<=[512], to_apply=%s
  %ag = f32[64]{0} all-gather(%b), replica_groups={{0,256},{1,257}}, dimensions={0}
"""
    ops = rl.parse_collectives(hlo)
    assert ops[0].group == 32
    assert not ops[0].crosses_pod
    assert ops[1].group == 2 and ops[1].crosses_pod
    summary = rl.collective_summary(ops)
    assert summary["dcn_bytes"] > 0 and summary["link_bytes"] > 0


def test_roofline_terms_and_dominance():
    cost = {"flops": 197e12, "bytes accessed": 819e9 * 2}
    coll = {"link_bytes": 50e9 * 0.5, "dcn_bytes": 0.0}
    t = rl.roofline_terms(cost, coll)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(2.0)
    assert t["collective_s"] == pytest.approx(0.5)
    assert t["dominant"] == "memory_s"


def test_model_flops_conventions():
    from repro.configs.base import SHAPES, get_config
    cfg = get_config("olmo_1b")
    tr = rl.model_flops(cfg, SHAPES["train_4k"], 256)
    pf = rl.model_flops(cfg, SHAPES["prefill_32k"], 256)
    dc = rl.model_flops(cfg, SHAPES["decode_32k"], 256)
    assert tr == pytest.approx(3 * pf, rel=1e-6)      # 6ND vs 2ND, same tokens
    assert dc < pf / 1000                             # one token per seq


def test_zamba2_shared_block_flops_multiplicity():
    from repro.configs.base import _param_count, get_config
    cfg = get_config("zamba2_2_7b")
    storage = _param_count(cfg)
    flops_n = _param_count(cfg, flops_multiplicity=True)
    assert flops_n > storage          # shared block executes 9x, stored 1x


def test_lerp_extrapolation():
    from repro.launch import dryrun as dr
    c1 = {"cost": {"flops": 10.0}, "collectives": {
        "link_bytes": 4.0, "dcn_bytes": 0.0, "count": 2,
        "by_kind": {"all-reduce": 4.0}}}
    c2 = {"cost": {"flops": 16.0}, "collectives": {
        "link_bytes": 7.0, "dcn_bytes": 0.0, "count": 3,
        "by_kind": {"all-reduce": 7.0}}}
    out = dr._lerp_costs(c1, c2, 5)
    assert out["cost"]["flops"] == pytest.approx(10 + 4 * 6)
    assert out["collectives"]["link_bytes"] == pytest.approx(4 + 4 * 3)


@pytest.mark.slow
def test_real_dryrun_subprocess(tmp_path):
    """End-to-end: 512 host devices, production mesh, smallest cell."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src:."
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "olmo_1b", "--shape", "decode_32k",
         "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, cwd=os.getcwd(),
        timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.load(open(tmp_path / "olmo_1b.decode_32k.single.json"))
    assert rec["status"] == "ok"
    assert rec["cost"]["flops"] > 0
    assert rec["roofline"]["dominant"] in ("compute_s", "memory_s",
                                           "collective_s")
    assert rec["memory"]["per_device_total"] < 16 * 2**30
