"""Unit tests for the batched Monte-Carlo scenario engine
(``repro.montecarlo``): the unified mask-table lowering, delay models,
scenarios, summaries, and batched-vs-solo agreement."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.quorum import QuorumSpec, all_valid_specs
from repro.montecarlo import (CrashedDelay, LossyDelay, ParetoDelay,
                              Scenario, ShiftedLognormalDelay, WanDelay,
                              build_mask_table, engine, scenarios)

KEY = jax.random.PRNGKey(7)
FFP = QuorumSpec.paper_headline(11)
FP = QuorumSpec.fast_paxos(11)


# ---------------------------------------------------------------------------
# tables + traced batching
# ---------------------------------------------------------------------------

def test_mask_table_mixed_n_rejected():
    with pytest.raises(ValueError, match="mixes cluster sizes"):
        build_mask_table([FFP, QuorumSpec(7, 6, 2, 6)])


def test_mask_table_specializes_cardinality_batches():
    t = build_mask_table([FFP, FP])
    assert t["q"].shape == (2, 3) and t["q"].dtype == jnp.int32
    assert bool((t["q"][0] == jnp.array([9, 3, 7])).all())
    assert "q" not in build_mask_table([FFP, FP], specialize=False)


def test_raw_spec_tables_rejected():
    """The pre-mask-table (M, 3) signature was removed after its
    deprecation release: entry points demand a build_mask_table dict."""
    raw = jnp.array([[9, 3, 7]], jnp.int32)
    with pytest.raises(TypeError, match="build_mask_table"):
        engine.race(KEY, raw, jnp.array([0.0, 0.3]), n=11, k_proposers=2,
                    samples=64)
    with pytest.raises(TypeError, match="build_mask_table"):
        engine.fast_path(KEY, raw, n=11, samples=64)
    assert not hasattr(engine, "race_masked")        # aliases gone too
    assert not hasattr(engine, "fast_path_masked")
    with pytest.raises(ImportError):
        import repro.core.jax_sim  # noqa: F401 — shim deleted


def test_batched_fast_path_matches_solo_tables():
    """Common random numbers: every spec of a batch sees the same sampled
    delays, so scoring a spec alone must reproduce its batch row exactly."""
    specs = [FP, FFP, QuorumSpec(11, 11, 1, 6)]
    table = build_mask_table(specs)
    batched = engine.fast_path(KEY, table, n=11, samples=40_000)
    for i, s in enumerate(specs):
        solo = engine.fast_path(KEY, build_mask_table([s]), n=11,
                                samples=40_000)[0]
        assert float(jnp.abs(batched[i] - solo).max()) < 1e-6


def test_batched_race_matches_solo_tables():
    specs = [FP, FFP]
    table = build_mask_table(specs)
    out = engine.race(KEY, table, jnp.array([0.0, 0.3]), n=11,
                      k_proposers=2, samples=30_000)
    for i, s in enumerate(specs):
        solo = engine.race(KEY, build_mask_table([s]), jnp.array([0.0, 0.3]),
                           n=11, k_proposers=2, samples=30_000)
        assert bool((out["recovery"][i] == solo["recovery"][0]).all())
        assert float(jnp.abs(out["latency_ms"][i]
                             - solo["latency_ms"][0]).max()) < 1e-6


def test_fast_path_monotone_in_quorum_size():
    table = build_mask_table([QuorumSpec(11, 11, 1, 7),
                              QuorumSpec(11, 11, 1, 9)])
    lat = engine.fast_path(KEY, table, n=11, samples=50_000)
    assert float(lat[0].mean()) < float(lat[1].mean())


def test_classic_path_slower_than_fast():
    table = build_mask_table([FFP])
    fast = engine.fast_path(KEY, table, n=11, samples=30_000)
    classic = engine.classic_path(KEY, table, n=11, samples=30_000)
    # classic adds the client->leader relay hop
    assert float(classic.mean()) > float(fast.mean())


def test_recovery_probability_decreasing_in_interval():
    """Fig. 2c: larger inter-command intervals -> fewer recoveries."""
    table = build_mask_table([FFP])
    ps = []
    for d in (0.0, 0.3, 0.8, 2.0):
        out = engine.race(KEY, table, jnp.array([0.0, d]), n=11,
                          k_proposers=2, samples=30_000)
        ps.append(float(out["recovery"].mean()))
    assert ps[0] >= ps[1] >= ps[2] >= ps[3]
    assert ps[3] < 0.01


def test_full_valid_space_single_trace():
    """The whole Eq.13/14-valid space for n=7 (hundreds of specs) must cost
    one race trace, and a different same-shape table must cost zero."""
    specs = list(all_valid_specs(7))
    assert len(specs) > 50
    table = build_mask_table(specs)
    before = engine.TRACE_COUNTS["race"]
    out = engine.race(KEY, table, jnp.array([0.0, 0.2]), n=7,
                      k_proposers=2, samples=2_000)
    assert out["latency_ms"].shape == (len(specs), 2_000)
    assert engine.TRACE_COUNTS["race"] - before == 1
    table2 = build_mask_table(list(reversed(specs)))
    engine.race(KEY, table2, jnp.array([0.0, 0.7]), n=7,
                k_proposers=2, samples=2_000)
    assert engine.TRACE_COUNTS["race"] - before == 1


def test_race_outcomes_partition_k3():
    table = build_mask_table([FFP])
    out = engine.race(KEY, table, jnp.array([0.0, 0.2, 0.4]), n=11,
                      k_proposers=3, samples=10_000)
    total = (out["reached_fast"].astype(jnp.int32)
             + out["recovery"].astype(jnp.int32)
             + out["undecided"].astype(jnp.int32))
    assert bool((total == 1).all())
    assert bool((out["fast_winner"][out["reached_fast"]] >= 0).all())
    assert bool((out["fast_winner"][~out["reached_fast"]] == -1).all())


def test_race_outcomes_partition_under_loss():
    """With lossy hops the three outcomes must still partition: a quorum of
    acceptor votes whose 2bs never reach the learner is NOT a fast commit —
    it falls back to recovery (or undecided), never both flags at once."""
    from repro.montecarlo.latency import default_delay
    table = build_mask_table([FFP])
    out = engine.race(KEY, table, jnp.array([0.0, 0.3]),
                      LossyDelay(default_delay(), 0.4),
                      n=11, k_proposers=2, samples=20_000)
    total = (out["reached_fast"].astype(jnp.int32)
             + out["recovery"].astype(jnp.int32)
             + out["undecided"].astype(jnp.int32))
    assert bool((total == 1).all())
    decided = ~out["undecided"]
    assert bool((out["latency_ms"][decided] < engine.UNDECIDED_MS).all())
    assert bool(out["undecided"].any())          # 40% loss must bite
    assert bool((out["fast_winner"][~out["reached_fast"]] == -1).all())


def test_kernel_and_ref_paths_identical():
    table = build_mask_table([FFP, FP])
    kw = dict(n=11, k_proposers=2, samples=8_000)
    offs = jnp.array([0.0, 0.3])
    o_ref = engine.race(KEY, table, offs, use_kernel=False, **kw)
    o_ker = engine.race(KEY, table, offs, use_kernel=True, **kw)
    assert bool((o_ref["fast_winner"] == o_ker["fast_winner"]).all())
    assert float(jnp.abs(o_ref["latency_ms"]
                         - o_ker["latency_ms"]).max()) < 1e-6


# ---------------------------------------------------------------------------
# delay models
# ---------------------------------------------------------------------------

def test_delay_models_are_pytrees():
    for model in (ShiftedLognormalDelay(), ParetoDelay(),
                  LossyDelay(ShiftedLognormalDelay(), 0.05),
                  WanDelay.symmetric(30.0, n=11, k_proposers=2)):
        leaves = jax.tree_util.tree_leaves(model)
        assert leaves, model
        rebuilt = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(model), leaves)
        assert type(rebuilt) is type(model)


def test_pareto_tail_heavier_than_lognormal():
    ln = ShiftedLognormalDelay().sample_hops(KEY, (200_000,))
    pa = ParetoDelay().sample_hops(KEY, (200_000,))
    tail = lambda x: float(jnp.quantile(x, 0.999) / jnp.quantile(x, 0.5))
    assert tail(pa) > tail(ln)


def test_wan_delay_topology():
    wan = WanDelay.symmetric(30.0, n=6, k_proposers=2, n_regions=3)
    d = wan.sample_hops(KEY, (1000, 6), kind="to_learner")
    # acceptors 0 and 3 share the learner's region (round-robin): no 30 ms hop
    assert float(d[:, 0].mean()) < 5.0 < float(d[:, 1].mean())
    prop = wan.sample_hops(KEY, (1000, 6, 2), kind="proposal")
    assert prop.shape == (1000, 6, 2)
    # proposer 1 (region 1) is local to acceptors 1 and 4 only
    assert float(prop[:, 1, 1].mean()) < 5.0 < float(prop[:, 0, 1].mean())


def test_lossy_delay_marks_losses():
    d = LossyDelay(ShiftedLognormalDelay(), 0.2).sample_hops(KEY, (50_000,))
    frac = float((d >= 1e8).mean())
    assert 0.17 < frac < 0.23


# ---------------------------------------------------------------------------
# scenarios
# ---------------------------------------------------------------------------

def test_conflict_free_scenario_equals_fast_path():
    table = build_mask_table([FFP])
    scen = scenarios.conflict_free(n=11)
    out = scen.with_spec(samples=5_000).run(KEY, table)
    direct = engine.fast_path(KEY, table, n=11, samples=5_000)
    assert float(jnp.abs(out["latency_ms"] - direct).max()) < 1e-6
    assert not bool(out["recovery"].any())


def test_mixed_workload_blend():
    table = build_mask_table([FFP])
    s = scenarios.mixed_workload(0.01, 0.3, n=11).with_spec(
        samples=20_000).summary(KEY, table)
    assert float(s["p99_ms"][0]) >= float(s["p50_ms"][0]) > 0
    assert 0.0 <= float(s["recovery_rate"][0]) <= 0.01


def test_wan_scenario_latency_dominated_by_geography():
    table = build_mask_table([FFP])
    local = scenarios.conflict_free(n=11).with_spec(
        samples=5_000).summary(KEY, table)
    geo = scenarios.wan(n=11, inter_region_ms=30.0)
    geo = Scenario(geo.name, geo.n, 1, geo.offsets_ms[:1], geo.delay)
    far = geo.with_spec(samples=5_000).summary(KEY, table)
    assert float(far["p50_ms"][0]) > 10 * float(local["p50_ms"][0])


def test_lossy_scenario_increases_recovery():
    table = build_mask_table([FFP])
    clean = scenarios.k_way_race(2, 0.3, n=11).with_spec(
        samples=30_000).run(KEY, table)
    lossy = scenarios.lossy_acceptors(0.15, delta_ms=0.3, n=11).with_spec(
        samples=30_000).run(KEY, table)
    p_clean = float(clean["recovery"].mean() + clean["undecided"].mean())
    p_lossy = float(lossy["recovery"].mean() + lossy["undecided"].mean())
    assert p_lossy > p_clean + 0.05
    # with 15% loss per hop some instances can still decide via recovery
    assert bool(lossy["reached_fast"].any())


# ---------------------------------------------------------------------------
# summaries (engine.summarize is the one summary path for all layers)
# ---------------------------------------------------------------------------

def test_summarize_shapes():
    lat = jax.random.uniform(KEY, (3, 1000)) + 1.0
    s = engine.summarize(lat)
    for v in s.values():
        assert v.shape == (3,)


def test_summarize_percentiles_monotone():
    out = engine.race(KEY, build_mask_table([FFP, FP]),
                      jnp.array([0.0, 0.3]), n=11, k_proposers=2,
                      samples=20_000)
    s = engine.summarize(out)
    for i in range(2):
        p50, p95 = float(s["p50_ms"][i]), float(s["p95_ms"][i])
        p99, mx = float(s["p99_ms"][i]), float(s["max_ms"][i])
        assert 0 < p50 <= p95 <= p99 <= mx, (i, p50, p95, p99, mx)


def test_summarize_excludes_undecided_from_latency_stats():
    """Undecided instances (LOST_MS sentinel latencies) must not drag the
    sentinel into the quantiles — they are reported as a rate instead."""
    lat = jnp.array([[1.0, 2.0, 3.0, engine.BIG]])
    out = {"latency_ms": lat,
           "undecided": lat >= engine.UNDECIDED_MS,
           "reached_fast": jnp.array([[True, True, False, False]]),
           "recovery": jnp.array([[False, False, True, False]])}
    s = engine.summarize(out)
    assert float(s["max_ms"][0]) == 3.0
    assert float(s["p99_ms"][0]) < 3.01
    assert float(s["mean_ms"][0]) == pytest.approx(2.0)
    assert float(s["undecided_rate"][0]) == pytest.approx(0.25)
    assert float(s["fast_rate"][0]) == pytest.approx(0.5)
    assert float(s["recovery_rate"][0]) == pytest.approx(0.25)


def test_summarize_fixed_seed_regression_anchor():
    """Fixed-seed anchor: engine refactors that silently change the sampled
    race structure (key splits, draw order, presort layout) move these
    numbers far outside tolerance; refactors that only re-lower the decide
    step keep them bit-stable.  Regenerate with
    tests/regen_anchors.py::montecarlo if sampling changes *on purpose*."""
    out = engine.race(jax.random.PRNGKey(123), build_mask_table([FFP]),
                      jnp.array([0.0, 0.25]), n=11, k_proposers=2,
                      samples=20_000)
    s = engine.summarize(out)
    assert float(s["p50_ms"][0]) == pytest.approx(1.22011, rel=1e-3)
    assert float(s["recovery_rate"][0]) == pytest.approx(0.01645, rel=1e-3)
    assert float(out["latency_ms"][0, 0]) == pytest.approx(1.258696,
                                                           rel=1e-5)
    assert float(out["latency_ms"][0, 1]) == pytest.approx(1.37547,
                                                           rel=1e-5)


# ---------------------------------------------------------------------------
# general quorum systems through the scenario layer
# ---------------------------------------------------------------------------

def test_crashed_delay_loses_every_hop_of_crashed_acceptors():
    crashed = jnp.zeros((6,), bool).at[2].set(True)
    d = CrashedDelay(ShiftedLognormalDelay(), crashed)
    hops = d.sample_hops(KEY, (500, 6), kind="to_learner")
    assert bool((hops[:, 2] >= 1e8).all())
    assert bool((hops[:, 0] < 1e8).all())
    prop = d.sample_hops(KEY, (500, 6, 2), kind="proposal")
    assert bool((prop[:, 2, :] >= 1e8).all())
    leaves = jax.tree_util.tree_leaves(d)
    assert leaves                      # registered pytree (traced crash set)


def test_scenario_with_faults_matches_manual_crash_wrap():
    scen = scenarios.k_way_race(2, 0.3, n=11)
    wrapped = scen.with_faults((0, 5))
    assert isinstance(wrapped.delay, CrashedDelay)
    assert bool(wrapped.delay.crashed[0]) and bool(wrapped.delay.crashed[5])
    assert scen.with_faults(()) is scen


def test_grid_wan_scenario_masked_outcomes_partition():
    scen, masks = scenarios.grid_wan(cols=3, k=2, delta_ms=0.3)
    out = scen.with_spec(samples=4_000).run(
        KEY, build_mask_table([masks]))
    total = (out["reached_fast"].astype(jnp.int32)
             + out["recovery"].astype(jnp.int32)
             + out["undecided"].astype(jnp.int32))
    assert bool((total == 1).all())
    assert out["latency_ms"].shape == (1, 4_000)
    # two full rows = two full regions: a fast commit pays the WAN hop
    lat = jnp.where(out["undecided"], jnp.nan, out["latency_ms"])
    assert float(jnp.nanmedian(lat)) > 30.0


def test_weighted_scenario_beats_uniform_on_fast_path():
    """Concentrating weight shrinks the fast-path *cardinality*: with three
    weight-2 acceptors a fast quorum needs fewer machines than the uniform
    q2f = ceil(3n/4), so its order statistic (p50) can only be lower or
    equal; sanity-check the masked scenario wiring end-to-end."""
    scen, masks = scenarios.weighted_acceptors(delta_ms=0.3)
    table = build_mask_table([masks, QuorumSpec.fast_paxos(11)])
    s = scen.with_spec(samples=8_000).summary(KEY, table)
    assert float(s["p50_ms"][0]) <= float(s["p50_ms"][1]) + 1e-6
    assert float(s["undecided_rate"][0]) == 0.0


def test_weighted_heavy_crash_hurts_more_than_light():
    heavy, masks = scenarios.weighted_acceptors(crashed=(0, 1))   # two 2s
    light, _ = scenarios.weighted_acceptors(crashed=(9, 10))      # two 1s
    table = build_mask_table([masks])
    s_heavy = heavy.with_spec(samples=6_000).summary(KEY, table)
    s_light = light.with_spec(samples=6_000).summary(KEY, table)
    assert float(s_heavy["p50_ms"][0]) >= float(s_light["p50_ms"][0]) - 1e-6
