"""End-to-end training driver: a ~100M-parameter LM trained for a few
hundred steps on CPU, with the full production control path exercised:

  * deterministic sharded data pipeline with exact-resume cursors;
  * AdamW + grad accumulation (+ optional int8/top-k grad compression);
  * sharded checkpoints whose manifests are committed through the Fast
    Flexible Paxos control plane (leaderless fast rounds);
  * a SIMULATED PREEMPTION mid-run: the trainer object is destroyed and a
    fresh one restores from the consensus-committed manifest and resumes at
    the exact data cursor — final loss must match an uninterrupted run;
  * phi-accrual failure detection + straggler verdicts committed per step.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--fast]
"""
import argparse
import dataclasses
import shutil

import jax

from repro.cluster.coordinator import ControlPlane
from repro.cluster.failure import PhiAccrualDetector, StragglerPolicy
from repro.configs import get_config
from repro.core.quorum import QuorumSpec
from repro.models.model import DecoderLM
from repro.training.data import DataConfig, SyntheticPipeline
from repro.training.optimizer import adamw, cosine_schedule
from repro.training.trainer import Trainer, TrainerConfig


def model_100m(fast: bool):
    """~100M params: olmo-family, d_model=512, 8 layers, 50k vocab."""
    cfg = get_config("olmo_1b")
    if fast:   # CI-sized
        return dataclasses.replace(cfg, n_layers=2, d_model=128, n_heads=4,
                                   n_kv_heads=4, d_ff=512, vocab=1024)
    return dataclasses.replace(cfg, n_layers=8, d_model=512, n_heads=8,
                               n_kv_heads=8, d_ff=2048, vocab=50304)


def build(cfg, ckpt_dir, plane, n_micro, compression, seq, batch):
    model = DecoderLM(cfg, remat=True)
    pipe = SyntheticPipeline(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                        global_batch=batch))
    tcfg = TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=25,
                         n_microbatches=n_micro, compression=compression)
    opt = adamw(lr=3e-4, schedule=cosine_schedule(warmup=20, total=400))
    tr = Trainer(model, opt, pipe, tcfg, plane=plane)
    return tr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--fast", action="store_true",
                    help="CI-sized model and step count")
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--compression", default=None,
                    choices=[None, "int8", "topk"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    steps = 30 if args.fast else args.steps
    seq, batch = (64, 4) if args.fast else (256, 8)

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    cfg = model_100m(args.fast)

    # control plane: 11 acceptors, the paper's headline quorums
    plane = ControlPlane(QuorumSpec.paper_headline(11), seed=0)
    detector = PhiAccrualDetector(threshold=6.0)
    straggler = StragglerPolicy(plane, patience=2)
    rng = __import__("random").Random(0)

    tr = build(cfg, args.ckpt_dir, plane, args.microbatches,
               args.compression, seq, batch)
    tr.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(tr.params))
    print(f"params: {n_params/1e6:.1f}M  steps: {steps}  "
          f"microbatches: {args.microbatches}")

    half = steps // 2
    n_verdicts = 0
    for s in range(half):
        m = tr.run(1)
        detector.heartbeat(0, s * 1000.0)
        # this host's real step time + 7 simulated peers; host 5 degrades
        # mid-run and the quantile policy verdicts it through consensus.
        host_times = {0: m["step_s"] * 1e3}
        for h in range(1, 8):
            base = m["step_s"] * 1e3 * rng.uniform(0.95, 1.05)
            if h == 5 and s > half // 2:
                base *= 6.0
            host_times[h] = base
        verdict = straggler.observe_step(tr.step, host_times)
        if verdict:
            n_verdicts += 1
            print(f"  step {tr.step:4d} straggler verdict committed: "
                  f"hosts {verdict}")
        if tr.step % 10 == 0:
            print(f"  step {tr.step:4d} loss {m['loss']:.4f} "
                  f"({m['step_s']*1e3:.0f} ms)")
    tr.save()
    loss_at_preempt = tr.history[-1]["loss"]

    # ---- simulated preemption: lose the process state entirely -------------
    print(f"== PREEMPTION at step {tr.step} (loss {loss_at_preempt:.4f}) ==")
    del tr
    tr2 = build(cfg, args.ckpt_dir, plane, args.microbatches,
                args.compression, seq, batch)
    tr2.init(jax.random.PRNGKey(0))          # fresh init...
    restored = tr2.try_restore()              # ...overwritten by restore
    assert restored, "no consensus-committed manifest found"
    print(f"== RESTORED at step {tr2.step}, cursor {tr2.cursor} "
          f"(manifest via control plane: "
          f"{plane.latest_checkpoint()['step']}) ==")
    assert tr2.step == half

    for _ in range(steps - half):
        m = tr2.run(1)
        if tr2.step % 10 == 0:
            print(f"  step {tr2.step:4d} loss {m['loss']:.4f}")

    first = tr2.history[0]["loss"] if tr2.history else loss_at_preempt
    final = tr2.history[-1]["loss"]
    print(f"final loss {final:.4f} (at preemption {loss_at_preempt:.4f})")
    assert final < loss_at_preempt + 0.05, "loss did not keep improving"
    print(f"consensus log: {len(plane.history())} committed records "
          f"(checkpoints, cursors, verdicts)")
    print("train_lm OK")


if __name__ == "__main__":
    main()
