"""Serving example: batched request serving with prefill + incremental
decode over ring-buffer KV caches — the same serve_step the decode_32k /
long_500k dry-run cells lower to 256 chips.

A small request queue with different prompt lengths is served in one
continuous batch: prompts are left-aligned, prefilled together, then decoded
token-by-token with per-request stop handling.  Reports tokens/s.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch olmo_1b] [--tokens 32]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.models.model import DecoderLM


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b",
                    choices=["olmo_1b", "deepseek_7b", "mamba2_130m",
                             "zamba2_2_7b", "gemma3_12b"])
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    model = DecoderLM(cfg, remat=False)
    params, _ = model.init(jax.random.PRNGKey(0))

    # request queue: different prompt lengths, one shared decode batch
    key = jax.random.PRNGKey(1)
    lens = [8, 12, 16, 10][: args.batch]
    B, P = len(lens), max(lens)
    prompts = jax.random.randint(key, (B, P), 1, cfg.vocab)
    # left-align: pad *front* with token 0; track each row's true start
    toks = jnp.stack([
        jnp.concatenate([jnp.zeros((P - l,), jnp.int32), prompts[i, :l]])
        for i, l in enumerate(lens)])

    max_len = P + args.tokens + 8
    cache, _ = model.init_cache(B, max_len)
    t0 = time.perf_counter()
    cache, logits = model.prefill(params, {"tokens": toks}, cache)
    prefill_s = time.perf_counter() - t0
    print(f"{args.arch}: prefilled {B}x{P} in {prefill_s*1e3:.0f} ms")

    decode = jax.jit(model.decode_step)
    out_tokens = []
    nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for _ in range(args.tokens):
        logits, cache = decode(params, cache, nxt)
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(nxt)
    jax.block_until_ready(nxt)
    dt = time.perf_counter() - t0
    seqs = jnp.concatenate(out_tokens, axis=1)
    assert seqs.shape == (B, args.tokens)
    assert bool((seqs >= 0).all()) and bool((seqs < cfg.vocab).all())
    tps = B * args.tokens / dt
    print(f"decoded {args.tokens} tokens x {B} requests in {dt*1e3:.0f} ms "
          f"({tps:.0f} tok/s, {dt/args.tokens*1e3:.1f} ms/step)")
    print(f"sample continuation (req 0): {seqs[0, :8].tolist()}")
    print("serve_lm OK")


if __name__ == "__main__":
    main()
