"""Declare one Experiment, run it on all three backends (<40 lines).

Paper-headline cardinality vs a 3x3 grid vs weighted voting (the §6
closing remark), through ``repro.api``:

Run:  PYTHONPATH=src python examples/experiment_quickstart.py
"""
from repro.api import Experiment, Workload
from repro.core.quorum import (ExplicitQuorumSystem, QuorumSpec,
                               WeightedQuorumSystem)

exp = Experiment(
    systems=[QuorumSpec.paper_headline(11),              # (q1,q2c,q2f)=(9,3,7)
             ExplicitQuorumSystem.grid(3).embed(11),     # fast = two grid rows
             WeightedQuorumSystem((2, 2, 2) + (1,) * 8, 12, 3, 9)],
    workload=Workload.race(k=2, delta_ms=0.2),           # two proposers race
    samples=20_000,
)

# Monte-Carlo: all three systems lower to ONE mask table, scored in ONE
# compiled engine call (common random numbers across systems).
mc = exp.run("montecarlo")
for label in mc.labels:
    row = mc.system(label)
    print(f"[mc]  {label:24s} p50={row['p50_ms']:.2f}ms "
          f"p_recovery={row['recovery_rate']:.3f} "
          f"ft_fast={row['ft_phase2_fast']}")

# Discrete-event simulator: same systems, same workload, the actual
# protocol state machines over a simulated network.
des = exp.run("des")
for label in des.labels:
    print(f"[des] {label:24s} p50={des.system(label)['p50_ms']:.2f}ms")

# Model checker needs n <= 5: check a congruent small batch exhaustively.
small = Experiment(systems=[QuorumSpec(5, 4, 2, 4),
                            ExplicitQuorumSystem.grid(1).embed(5),
                            WeightedQuorumSystem((2, 1, 1, 1, 1), 5, 2, 4)])
print("[modelcheck] safe per system:", small.run("modelcheck").summary["safe"])
