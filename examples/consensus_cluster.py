"""The paper live: an 11-acceptor Fast Flexible Paxos cluster driving the
cluster control plane through its failure modes.

  1. leaderless fast-round commits (checkpoint manifests);
  2. racing proposals -> conflict -> coordinated recovery (the plurality
     value wins, per IsPickableVal/O4);
  3. crashes up to the fault budget; commits keep flowing;
  4. elastic membership: scale 11 -> 13 -> 9 hosts, quorum sizes recomputed
     per Eqs. 13/14 at each epoch, all epochs committed through consensus;
  5. side-by-side conflict-entry rate: Fast Paxos vs FFP quorums on an
     identical racing workload.

Run:  PYTHONPATH=src python examples/consensus_cluster.py
"""
from repro.cluster.coordinator import ConsensusLog, ControlPlane
from repro.cluster.membership import MembershipManager, quorum_policy
from repro.core.quorum import QuorumSpec

# ---------------------------------------------------------------- 1. commits
spec = QuorumSpec.paper_headline(11)
plane = ControlPlane(spec, seed=42)
for step in (50, 100, 150):
    out = plane.commit_checkpoint(step, {"params": f"gs://ckpt/{step}"},
                                  data_cursor=step)
    assert out.outcome == "fast", out
print(f"[1] 3 manifests committed in fast rounds "
      f"(quorums q1={spec.q1} q2c={spec.q2c} q2f={spec.q2f})")

# ------------------------------------------------------------- 2. collision
log = ConsensusLog(spec, seed=7)
outcome = log.propose_racing(["cursor=512", "cursor=640"])
print(f"[2] racing proposals -> outcome={outcome.outcome} "
      f"decided={outcome.value!r}")
assert outcome.value in ("cursor=512", "cursor=640")

# --------------------------------------------------------------- 3. crashes
for a in (1, 4, 6, 9):                     # 4 crashes = n - q2f budget
    plane.log.crash(a)
out = plane.commit_checkpoint(200, {"params": "gs://ckpt/200"},
                              data_cursor=200)
print(f"[3] 4/11 acceptors down -> commit outcome={out.outcome} "
      f"(fast path needs q2f={spec.q2f} of 7 live)")
assert out.outcome in ("fast", "recovered")
plane.log.recover_node(1)

# ------------------------------------------------------------ 4. elasticity
mgr = MembershipManager(ControlPlane(spec, seed=1), initial_hosts=range(11))
for hosts in (range(13), range(9)):
    ep = mgr.commit(list(hosts))
    q = ep.quorums
    print(f"[4] epoch {ep.epoch}: n={len(ep.hosts)} -> "
          f"q1={q.q1} q2c={q.q2c} q2f={q.q2f} "
          f"(valid={q.is_valid()})")
    assert q.is_valid()

# ------------------------------------------------- 5. FP vs FFP side by side
from repro.api import Experiment, Workload

res = Experiment(systems=[QuorumSpec.fast_paxos(11),
                          QuorumSpec.paper_headline(11)],
                 workload=Workload.race(k=2, delta_ms=0.2),
                 samples=50_000).run("montecarlo")
for name, i in (("fast_paxos", 0), ("ffp", 1)):
    p_rec = float(res.summary["recovery_rate"][i]
                  + res.summary["undecided_rate"][i])
    print(f"[5] {name:10s} P(recovery|race)={p_rec:.3f}"
          f"  mean latency={float(res.summary['mean_ms'][i]):.3f} ms")
print("consensus_cluster OK")
