"""Quickstart: the three layers of the framework in ~60 seconds on CPU.

  1. the paper — Fast Flexible Paxos quorum systems and a live consensus
     round (n=11, the §5/§6 headline config);
  2. the control plane — commit a checkpoint manifest leaderlessly, survive
     crashes within the fault budget;
  3. the model stack — one forward + one train step of a reduced assigned
     architecture under the same train_step the 512-chip dry-run lowers.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

# --------------------------------------------------------------------- 1
from repro.core.quorum import QuorumSpec

ffp = QuorumSpec.paper_headline(11)        # q1=9, q2c=3, q2f=7
fp = QuorumSpec.fast_paxos(11)             # qc=6,  qf=9
print(f"[1] FFP  {ffp} valid={ffp.is_valid()} ft={ffp.fault_tolerance()}")
print(f"    FP   {fp} (the conservative baseline the paper relaxes)")
assert ffp.check_sets()                    # Eqs. 11-12 by enumeration

from repro.api import Experiment, Workload

res = Experiment(systems=[fp, ffp], workload=Workload.conflict_free(),
                 samples=20_000).run("montecarlo")
for name, label in (("fast_paxos", res.labels[0]), ("ffp", res.labels[1])):
    print(f"    {name:10s} fast-path p50 = "
          f"{res.system(label)['p50_ms']:.3f} ms")

# --------------------------------------------------------------------- 2
from repro.cluster.coordinator import ControlPlane

plane = ControlPlane(ffp, seed=0)
out = plane.commit_checkpoint(step=100, shards={"params": "ckpt/step100"},
                              data_cursor=100)
print(f"[2] checkpoint manifest committed: outcome={out.outcome} "
      f"(fast round, no leader round-trip)")
plane.log.crash(3)
plane.log.crash(7)                          # q2f=7 tolerates 4 crashes
out = plane.commit_checkpoint(step=200, shards={"params": "ckpt/step200"},
                              data_cursor=200)
print(f"    after 2 crashes: outcome={out.outcome} "
      f"latest={plane.latest_checkpoint()['step']}")

# --------------------------------------------------------------------- 3
from repro.configs import get_config, reduced_config
from repro.models.model import DecoderLM
from repro.training.optimizer import adamw
from repro.training.trainer import make_train_step

cfg = reduced_config(get_config("olmo_1b"))
model = DecoderLM(cfg, remat=True)
params, _ = model.init(jax.random.PRNGKey(0))
opt = adamw(lr=1e-3)
opt_state = opt.init(params)
step = jax.jit(make_train_step(model, opt))
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                      cfg.vocab),
         "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 64), 0,
                                      cfg.vocab)}
params, opt_state, _, m = step(params, opt_state, None, batch,
                               jax.random.PRNGKey(3))
print(f"[3] olmo_1b (reduced) train step: loss={float(m['loss']):.3f} "
      f"grad_norm={float(m['grad_norm']):.3f}")
print("quickstart OK")
