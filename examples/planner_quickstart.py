"""Plan quorum systems instead of sweeping them (DESIGN.md §11).

Successive-halving search over the n=11 cardinality space: score all 271
valid systems cheaply, prune what is dominated beyond the cheap rung's
noise margin, spend the full budget only on the survivors — same Pareto
frontier as the exhaustive sweep, a fraction of the trials.  Repeat
questions are answered from warm state (cached search, memoized scores,
zero new engine compiles).

Run:  PYTHONPATH=src python examples/planner_quickstart.py
"""
import time

from repro.api import Experiment, Workload, plan
from repro.core.quorum import QuorumSpec
from repro.planner import Planner, PlannerServer, query_server

# One-call front door: search the family, filter the frontier for the
# fault budget, rank by the objective.  (10^5 final trials keeps this
# example quick; the planner defaults to 10^6.)
t0 = time.perf_counter()
r = plan(n=11, family="cardinality", trials=100_000,
         faults={"classic": 1},           # must survive 1 classic-path crash
         objective="race_p999_ms")        # cheapest contended tail
print(f"[plan] {r.recommended}  (q1={r.system['q1']}, "
      f"q2c={r.system['q2c']}, q2f={r.system['q2f']})")
print(f"[plan] fast p50 {r.predicted_ms['fast_p50']:.2f}ms, "
      f"race p99.9 {r.predicted_ms['race_p999']:.2f}ms, "
      f"crash budget {r.fault_tolerance}")
print(f"[plan] scored {r.search['budget_fraction']:.0%} of the exhaustive "
      f"trial budget in {time.perf_counter() - t0:.1f}s "
      f"({r.engine_compiles} engine compiles)")

# Same geometry, different question: answered from the cached search —
# no new search, no new compiles, milliseconds.
t0 = time.perf_counter()
r2 = plan(n=11, family="cardinality", trials=100_000,
          faults={"fast": 1, "phase1": 1}, objective="fast_p50_ms")
print(f"[warm] {r2.recommended} in {time.perf_counter() - t0 :.3f}s "
      f"(cold={r2.cold}, compiles={r2.engine_compiles})")

# An Experiment asks under ITS workload and engine knobs (crashed
# acceptors are folded into the scored delay model).
exp = Experiment(systems=[QuorumSpec.paper_headline(11)],
                 workload=Workload.race(k=3, delta_ms=0.5), shard=False)
r3 = exp.plan(faults={"classic": 2}, trials=100_000)
print(f"[exp]  3-way race @0.5ms, classic>=2: {r3.recommended}")

# As a persistent service: concurrent queries that differ only in fault
# budget / objective batch into ONE search.  (CLI equivalent:
#   python -m repro.planner serve &  /  python -m repro.planner query)
srv = PlannerServer(planner=Planner(), port=0, batch_window_s=0.02)
srv.start()
try:
    q = dict(op="plan", n=11, family="cardinality", trials=100_000)
    a = query_server(dict(q, faults={"classic": 1}), port=srv.port)
    b = query_server(dict(q, faults={"classic": 1}), port=srv.port)
    print(f"[serve] {a['recommended']} on :{srv.port}; repeat query "
          f"cold={b['cold']}, compiles={b['engine_compiles']}")
finally:
    srv.shutdown()
