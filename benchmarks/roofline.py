"""Roofline-term derivation from compiled dry-run artifacts.

CPU-only container: TPU v5e is the *target*, so terms are derived from the
compiled SPMD program rather than measured:

  compute term    = HLO_FLOPs(per device) / 197 TFLOP/s (bf16)
  memory term     = HLO_bytes(per device) / 819 GB/s (HBM)
  collective term = link_bytes(per device) / 50 GB/s (ICI link)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (the per-device
SPMD module).  Collective bytes are NOT in cost_analysis — we parse the
optimized HLO text and convert each collective op into ring-algorithm link
bytes:

  all-gather       out_bytes * (g-1)/g
  reduce-scatter   in_bytes  * (g-1)/g      (= out_bytes * (g-1))
  all-reduce       2 * bytes * (g-1)/g      (RS + AG)
  all-to-all       bytes * (g-1)/g
  collective-permute  bytes

Cross-pod (DCN) collectives are reported separately when the op's replica
groups contain devices from different pods (exact membership
reconstruction of iota/brace replica groups).

MODEL_FLOPS uses the 6*N*D convention (2*N*D for inference passes) with N =
active params counted at execution multiplicity (MoE: top-k experts;
zamba2's shared block: once per application).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

HW = {
    "flops_bf16": 197e12,      # per chip
    "hbm_bps": 819e9,          # per chip
    "ici_bps": 50e9,           # per link
    "chips_per_pod": 256,
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_info(line: str) -> Tuple[int, int]:
    """Returns (group_size, crosses_pod_flag as 0/1).

    Iota-form groups ``[G,S]<=[dims]T(perm)`` are reconstructed exactly:
    build the iota array, apply the transpose, reshape to (G, S) and check
    whether any group's members live in different pods (id // chips_per_pod
    differs).  Brace-form groups are checked directly.
    """
    cpp = HW["chips_per_pod"]
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        import numpy as np
        ngroups, gsize = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        arr = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            arr = arr.transpose([int(x) for x in m.group(4).split(",")])
        groups = arr.reshape(ngroups, gsize)
        crosses = bool(((groups // cpp).max(axis=1)
                        != (groups // cpp).min(axis=1)).any())
        return max(gsize, 1), int(crosses)
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        ids = [int(x) for x in m.group(1).split(",")]
        crosses = (max(ids) // cpp) != (min(ids) // cpp)
        return max(len(ids), 1), int(crosses)
    return 1, 0


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    out_bytes: int
    group: int
    crosses: int               # 1 if any replica group spans pods
    promoted: bool = False     # CPU-only f32 promotion of a bf16 reduction

    @property
    def link_bytes(self) -> float:
        g = max(self.group, 2)
        if self.kind == "all-gather":
            return self.out_bytes * (g - 1) / g
        if self.kind == "reduce-scatter":
            return self.out_bytes * (g - 1)          # out = in/g
        if self.kind == "all-reduce":
            return 2 * self.out_bytes * (g - 1) / g
        if self.kind == "all-to-all":
            return self.out_bytes * (g - 1) / g
        return float(self.out_bytes)                 # collective-permute

    @property
    def crosses_pod(self) -> bool:
        return bool(self.crosses)


_PROMOTED_RE = re.compile(r"(?:all-reduce|reduce-scatter)\(%?[\w.\-]*convert")


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    """Parse collectives; reductions whose operand is a convert fusion are
    counted at bf16 width.

    XLA:CPU cannot execute bf16 reductions, so float-normalization promotes
    them: the HLO shows ``f32 all-reduce(%convert_*_fusion)`` where the
    source value is a bf16 dot.  On the TPU pipeline the same reduction runs
    natively in bf16 (the MaxText-standard choice for activation/grad
    reductions), so counting the promoted ops at f32 would double their link
    bytes.  The correction is tracked per-op (``promoted``) and surfaced in
    the summary as ``promoted_count``.
    """
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        shape_str, kind, _ = m.group(1), m.group(2), m.group(3)
        g, crosses = _group_info(line)
        if g <= 1:
            continue
        nbytes = _shape_bytes(shape_str)
        promoted = bool("f32" in shape_str and _PROMOTED_RE.search(line))
        if promoted:
            nbytes //= 2
        ops.append(CollectiveOp(kind, nbytes, g, crosses, promoted))
    return ops


def collective_summary(ops: List[CollectiveOp]) -> Dict[str, float]:
    out: Dict[str, float] = {"link_bytes": 0.0, "dcn_bytes": 0.0, "count": 0,
                             "promoted_count": 0}
    by_kind: Dict[str, float] = {}
    for op in ops:
        out["count"] += 1
        out["promoted_count"] += int(op.promoted)
        if op.crosses_pod:
            out["dcn_bytes"] += op.link_bytes
        else:
            out["link_bytes"] += op.link_bytes
        by_kind[op.kind] = by_kind.get(op.kind, 0.0) + op.link_bytes
    out["by_kind"] = by_kind
    return out


def model_flops(cfg, shape, chips: int) -> float:
    """6*N*D convention, per chip."""
    from repro.configs.base import _param_count
    n_flops_params = _param_count(cfg, active_only=True, flops_multiplicity=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_flops_params * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_flops_params * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_flops_params * shape.global_batch
    return total / chips


def roofline_terms(cost: Dict[str, float], coll: Dict[str, float],
                   cfg=None, shape=None, chips: int = 256) -> Dict[str, float]:
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / HW["flops_bf16"]
    t_memory = bytes_ / HW["hbm_bps"]
    t_coll = coll["link_bytes"] / HW["ici_bps"]
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll,
             "dcn_bytes": coll.get("dcn_bytes", 0.0)}
    dominant = max(("compute_s", "memory_s", "collective_s"),
                   key=lambda k: terms[k])
    terms["dominant"] = dominant
    terms["bound_s"] = terms[dominant]
    if cfg is not None and shape is not None:
        mf = model_flops(cfg, shape, chips)
        terms["model_flops"] = mf
        terms["useful_flops_ratio"] = mf / flops if flops else 0.0
        # roofline fraction: useful model FLOPs per second at the bound,
        # over peak — the score we hillclimb.
        terms["roofline_fraction"] = (
            (mf / terms["bound_s"]) / HW["flops_bf16"] if terms["bound_s"] else 0.0)
    return terms
