"""Fig. 2b — instance latency under 0.5-1.5% conflicts at 2700 req/s.

Paper claims: (1) FFP keeps a ~5% latency advantage under load; (2) FFP
enters coordinated recovery ~1/3 as often as Fast Paxos (q2f 7 vs 9 — fewer
races leave *neither* value able to reach the smaller fast quorum).

Reproduced with the discrete-event simulator (protocol state machines, racy
submissions to shared instances) and a mixed-workload
``repro.api.Experiment`` (both specs scored in one engine call).
"""
from __future__ import annotations

from repro.api import Experiment, Workload
from repro.core.quorum import QuorumSpec
from repro.core.simulator import (FastPaxosSim, conflict_workload,
                                  latency_stats)

N_REQUESTS = 4000
RATE = 2700.0
CONFLICT_FRAC = 0.10          # §6: ~10% of commands race for a shared slot
SAMPLES = 200_000


def run(quick: bool = False, seed: int = 0):
    n_req = 800 if quick else N_REQUESTS
    samples = 20_000 if quick else SAMPLES
    specs = {
        "fast_paxos": QuorumSpec.fast_paxos(11, "three_quarters"),
        "ffp": QuorumSpec.paper_headline(11),
    }
    rows = []

    de = {}
    for name, spec in specs.items():
        sim = FastPaxosSim(spec, seed=seed)
        pairs = conflict_workload(sim, n_req, RATE, CONFLICT_FRAC,
                                  seed=seed + 1)
        stats = latency_stats(sim.run())
        de[name] = {**stats, "recoveries": sim.recovery_entries,
                    "pairs": pairs}
        for k in ("mean_ms", "p50_ms", "p95_ms", "p99_ms"):
            rows.append((f"fig2b.sim.{name}.{k}", stats[k]))
        rows.append((f"fig2b.sim.{name}.recovery_entries",
                     sim.recovery_entries))

    gain = 1.0 - de["ffp"]["mean_ms"] / de["fast_paxos"]["mean_ms"]
    rows.append(("fig2b.sim.ffp_mean_latency_gain", gain))
    if de["ffp"]["recoveries"]:
        rows.append(("fig2b.sim.recovery_ratio_fp_over_ffp",
                     de["fast_paxos"]["recoveries"] / de["ffp"]["recoveries"]))

    # batched MC model at the observed effective conflict fraction
    exp = Experiment(systems=list(specs.values()),
                     workload=Workload.mixed(conflict_frac=0.01,
                                             delta_ms=0.2),
                     samples=samples, seed=seed)
    summ = exp.run("montecarlo").summary
    mc = {}
    for i, name in enumerate(specs):
        mc[name] = {k: float(v[i]) for k, v in summ.items()}
        for k in ("mean_ms", "p50_ms", "p99_ms", "recovery_rate"):
            rows.append((f"fig2b.mc.{name}.{k}", mc[name][k]))
    rows.append(("fig2b.mc.ffp_mean_latency_gain",
                 1.0 - mc["ffp"]["mean_ms"] / mc["fast_paxos"]["mean_ms"]))
    return rows


def main(quick: bool = False):
    rows = run(quick)
    for name, val in rows:
        print(f"{name},{val:.6g}")
    d = dict(rows)
    assert d["fig2b.sim.ffp_mean_latency_gain"] > 0.02, d
    # FFP must enter recovery substantially less often (paper: ~3x less)
    if "fig2b.sim.recovery_ratio_fp_over_ffp" in d:
        assert d["fig2b.sim.recovery_ratio_fp_over_ffp"] > 1.5, d
    return rows


if __name__ == "__main__":
    main()
