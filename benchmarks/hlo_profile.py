"""Structural HLO profile of a dry-run cell (the CPU-only 'profiler').

Compiles the 1-superblock unrolled probe of an (arch, shape) cell and ranks
HLO ops by output bytes, grouped by op kind — the closest thing to a memory
profile available without hardware.  Also prints collective ops and
duplicate-fusion counts (a proxy for remat recompute).

  PYTHONPATH=src:. python -m benchmarks.hlo_profile --arch musicgen_medium \
      --shape train_4k [--top 25] [--superblocks 1]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import collections
import dataclasses
import re
from typing import Dict, List, Tuple

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3": 1,
                "f8e5m2": 1, "s16": 2, "u16": 2}


def shape_bytes(s: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+ = ([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?|\([^)]*\)) ([\w\-]+)\(")


def profile_hlo(hlo: str, top: int = 25):
    by_kind: Dict[str, int] = collections.Counter()
    count: Dict[str, int] = collections.Counter()
    biggest: List[Tuple[int, str, str]] = []
    for line in hlo.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        out_shape, kind = m.group(1), m.group(2)
        if kind in ("parameter", "constant", "get-tuple-element", "tuple",
                    "bitcast"):
            continue
        b = shape_bytes(out_shape)
        by_kind[kind] += b
        count[kind] += 1
        if b > 2**20:
            biggest.append((b, kind, out_shape[:60]))
    biggest.sort(reverse=True)
    return by_kind, count, biggest[:top]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--superblocks", type=int, default=1)
    args = ap.parse_args()

    from repro.configs.base import SHAPES, get_config
    from repro.launch import dryrun as dr
    from repro.launch.mesh import make_production_mesh
    from repro.parallel.sharding import default_rules

    cfg = get_config(args.arch)
    per = len([k for k in cfg.pattern if k != "shared_attn"]) or 1
    cfg1 = dataclasses.replace(cfg, n_layers=args.superblocks * per)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    opt, _ = dr.choose_optimizer(cfg)
    compiled, times = dr._compile_one(cfg1, shape, mesh, default_rules(), opt)
    hlo = compiled.as_text()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    print(f"# {args.arch}.{args.shape} probe ({args.superblocks} superblock)"
          f" compile={times['compile_s']}s")
    print(f"flops={ca.get('flops', 0):.3e}  "
          f"bytes={ca.get('bytes accessed', 0):.3e}\n")

    by_kind, count, biggest = profile_hlo(hlo, args.top)
    print("## output bytes by op kind")
    for kind, b in sorted(by_kind.items(), key=lambda kv: -kv[1])[:15]:
        print(f"  {kind:24s} {b/2**30:8.2f} GiB  x{count[kind]}")
    print("\n## biggest single ops")
    for b, kind, shp in biggest:
        print(f"  {b/2**30:8.2f} GiB  {kind:20s} {shp}")

    import benchmarks.roofline as rl
    coll = rl.collective_summary(rl.parse_collectives(hlo))
    print(f"\n## collectives: link_bytes={coll['link_bytes']:.3e} "
          f"dcn={coll['dcn_bytes']:.3e}")
    for k, v in sorted(coll["by_kind"].items(), key=lambda kv: -kv[1]):
        print(f"  {k:20s} {v:.3e}")


if __name__ == "__main__":
    main()
