"""Beyond-paper: sweep every FFP-valid cardinality configuration on n=11.

The paper (§5) gives two example points in the (q1, q2c, q2f) tradeoff
space.  We enumerate the *whole* space permitted by Eqs. 13/14, score each
configuration on the axes a deployment cares about —

  fast-path p50 latency      (order statistic of q2f acceptor round trips)
  P(recovery | race)         (collision robustness at Δ=0.2 ms)
  steady-state fault tolerance (n - q2f live crashes on the fast path)
  phase-1 fault tolerance      (n - q1: crashes survivable for recovery)

— and report the Pareto-optimal set.  This is the flexibility the paper's
relaxation buys: Fast Paxos admits exactly one point (q1=q2c=6, q2f=9).

Evaluation runs on ``repro.montecarlo``: quorum thresholds are traced, so
the whole frontier is scored by ONE compiled fast-path program and ONE
compiled race program (the old per-spec path re-jitted for every config).
Every spec sees identical sampled delays (common random numbers), so the
frontier ordering carries no cross-spec sampling noise.  The sweep asserts
both the single-compile property (via ``engine.TRACE_COUNTS``) and agreement
of the batched numbers with the legacy per-spec shim within Monte-Carlo
tolerance.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core.quorum import QuorumSpec, ffp_card_ok
from repro.montecarlo import build_mask_table, engine

N = 11
SAMPLES = 50_000
DELTA_MS = 0.2


# ---------------------------------------------------------------------------
# Independent per-spec reference: the pre-refactor static-threshold
# implementation (one jit per spec, python-int order statistics).  Kept here
# verbatim so the batched engine is checked against a *different* code path,
# not a shim that now shares its internals.
# ---------------------------------------------------------------------------

def _legacy_one_way(key, shape, base=0.25, mu=-1.20, sigma=0.55):
    return base + jnp.exp(mu + sigma * jax.random.normal(key, shape))


def _legacy_fast_p50(key, n: int, q2f: int, samples: int) -> float:
    k1, k2 = jax.random.split(key)
    d = _legacy_one_way(k1, (samples, n)) + _legacy_one_way(k2, (samples, n))
    return float(jnp.median(jnp.sort(d, axis=-1)[:, q2f - 1]))


def _legacy_recovery_prob(key, spec: QuorumSpec, delta_ms: float,
                          samples: int) -> float:
    kA, kB = jax.random.split(key)
    tA = _legacy_one_way(kA, (samples, spec.n))
    tB = delta_ms + _legacy_one_way(kB, (samples, spec.n))
    votes = (tB < tA).astype(jnp.int32)
    b_cnt = votes.sum(axis=-1)
    a_cnt = spec.n - b_cnt
    return float((~((a_cnt >= spec.q2f) | (b_cnt >= spec.q2f))).mean())


def enumerate_valid(n: int = N) -> List[QuorumSpec]:
    out = []
    for q1 in range(1, n + 1):
        for q2c in range(1, n + 1):
            for q2f in range(1, n + 1):
                if ffp_card_ok(n, q1, q2c, q2f):
                    out.append(QuorumSpec(n, q1, q2c, q2f))
    return out


def minimal_frontier(specs: List[QuorumSpec]) -> List[QuorumSpec]:
    """Drop specs dominated in (q1, q2c, q2f) — larger quorums are never
    better on any axis we score."""
    keep = []
    for s in specs:
        if not any(o.q1 <= s.q1 and o.q2c <= s.q2c and o.q2f <= s.q2f
                   and (o.q1, o.q2c, o.q2f) != (s.q1, s.q2c, s.q2f)
                   for o in specs):
            keep.append(s)
    return keep


def run(quick: bool = False, seed: int = 0):
    samples = 5_000 if quick else SAMPLES
    valid = enumerate_valid()
    frontier = minimal_frontier(valid)
    rows: List[Tuple[str, float]] = [
        ("sweep.n_valid_configs", len(valid)),
        ("sweep.n_minimal_configs", len(frontier)),
    ]
    key = jax.random.PRNGKey(seed)
    k_fast, k_race = jax.random.split(key)
    # all-cardinality batch: the mask lowering carries the "q" entry, so the
    # engine keeps the k-th-order-statistic gathers for the whole frontier
    table = build_mask_table(frontier)

    # -- the entire frontier in two engine calls (one compile each) --------
    t0 = dict(engine.TRACE_COUNTS)
    lat = engine.fast_path(k_fast, table, n=N, samples=samples)    # (M, S)
    race = engine.race(k_race, table, jnp.array([0.0, DELTA_MS]),
                       n=N, k_proposers=2, samples=samples)
    p50 = jnp.median(lat, axis=-1)
    p_rec = race["recovery"].mean(axis=-1)
    fast_traces = engine.TRACE_COUNTS["fast_path"] - t0["fast_path"]
    race_traces = engine.TRACE_COUNTS["race"] - t0["race"]
    assert fast_traces <= 1 and race_traces <= 1, (
        f"per-spec re-jit crept back in: {fast_traces} fast-path traces, "
        f"{race_traces} race traces for {len(frontier)} specs")
    rows.append(("sweep.engine_compiles", fast_traces + race_traces))

    scored = []
    for i, s in enumerate(frontier):
        ft = s.fault_tolerance()
        scored.append((s, float(p50[i]), float(p_rec[i]), ft))
        tag = f"q1={s.q1},q2c={s.q2c},q2f={s.q2f}"
        rows.append((f"sweep.[{tag}].fast_p50_ms", float(p50[i])))
        rows.append((f"sweep.[{tag}].p_recovery", float(p_rec[i])))
        rows.append((f"sweep.[{tag}].ft_fast", ft["steady_state_fast"]))
        rows.append((f"sweep.[{tag}].ft_phase1", ft["phase1"]))

    # -- batched vs independent per-spec reference (Monte-Carlo tolerance):
    # different implementation, different PRNG stream, so agreement is a
    # real check on the engine's order statistics, not a tautology.
    k_check = jax.random.PRNGKey(1234)
    # difference of two independent p-estimates has sd <= sqrt(0.5/samples);
    # 4.5 sigma keeps the check meaningful at full samples without making the
    # --quick CI smoke job (5k samples) flaky across jax/platform PRNG rolls
    tol_rec = 4.5 * (0.5 / samples) ** 0.5
    for i in (0, len(frontier) // 2, len(frontier) - 1):
        s = frontier[i]
        old_p50 = _legacy_fast_p50(jax.random.fold_in(k_check, i),
                                   s.n, s.q2f, samples)
        old_rec = _legacy_recovery_prob(jax.random.fold_in(k_check, 100 + i),
                                        s, DELTA_MS, samples)
        assert abs(old_p50 - float(p50[i])) < 0.05, (s, old_p50, float(p50[i]))
        assert abs(old_rec - float(p_rec[i])) < tol_rec, (s, old_rec,
                                                          float(p_rec[i]))
    rows.append(("sweep.batched_vs_perspec_checked", 3))

    # sanity: latency is monotone in q2f on the frontier
    by_q2f = sorted(scored, key=lambda t: t[0].q2f)
    lats = [t[1] for t in by_q2f]
    assert all(a <= b + 0.05 for a, b in zip(lats, lats[1:])), lats
    return rows


def main(quick: bool = False):
    rows = run(quick)
    for name, val in rows:
        print(f"{name},{val:.6g}")
    return rows


if __name__ == "__main__":
    main()
