"""Beyond-paper: sweep every FFP-valid cardinality configuration on n=11.

The paper (§5) gives two example points in the (q1, q2c, q2f) tradeoff
space.  We enumerate the *whole* space permitted by Eqs. 13/14, score each
configuration on the axes a deployment cares about —

  fast-path p50 latency      (order statistic of q2f acceptor round trips)
  P(recovery | race)         (collision robustness at Δ=0.2 ms)
  steady-state fault tolerance (n - q2f live crashes on the fast path)
  phase-1 fault tolerance      (n - q1: crashes survivable for recovery)

— and report the Pareto-optimal set.  This is the flexibility the paper's
relaxation buys: Fast Paxos admits exactly one point (q1=q2c=6, q2f=9).
"""
from __future__ import annotations

from typing import List, Tuple

import jax

from repro.core.jax_sim import (conflict_probability, fast_path_latency,
                                latency_summary)
from repro.core.quorum import QuorumSpec, ffp_card_ok

N = 11
SAMPLES = 50_000


def enumerate_valid(n: int = N) -> List[QuorumSpec]:
    out = []
    for q1 in range(1, n + 1):
        for q2c in range(1, n + 1):
            for q2f in range(1, n + 1):
                if ffp_card_ok(n, q1, q2c, q2f):
                    out.append(QuorumSpec(n, q1, q2c, q2f))
    return out


def minimal_frontier(specs: List[QuorumSpec]) -> List[QuorumSpec]:
    """Drop specs dominated in (q1, q2c, q2f) — larger quorums are never
    better on any axis we score."""
    keep = []
    for s in specs:
        if not any(o.q1 <= s.q1 and o.q2c <= s.q2c and o.q2f <= s.q2f
                   and (o.q1, o.q2c, o.q2f) != (s.q1, s.q2c, s.q2f)
                   for o in specs):
            keep.append(s)
    return keep


def run(quick: bool = False, seed: int = 0):
    samples = 5_000 if quick else SAMPLES
    valid = enumerate_valid()
    frontier = minimal_frontier(valid)
    rows: List[Tuple[str, float]] = [
        ("sweep.n_valid_configs", len(valid)),
        ("sweep.n_minimal_configs", len(frontier)),
    ]
    key = jax.random.PRNGKey(seed)
    scored = []
    for s in frontier:
        lat = latency_summary(fast_path_latency(key, s.n, s.q2f, samples))
        p_rec = conflict_probability(key, s, 0.2, samples)
        ft = s.fault_tolerance()
        scored.append((s, lat["p50_ms"], p_rec, ft))
        tag = f"q1={s.q1},q2c={s.q2c},q2f={s.q2f}"
        rows.append((f"sweep.[{tag}].fast_p50_ms", lat["p50_ms"]))
        rows.append((f"sweep.[{tag}].p_recovery", p_rec))
        rows.append((f"sweep.[{tag}].ft_fast", ft["steady_state_fast"]))
        rows.append((f"sweep.[{tag}].ft_phase1", ft["phase1"]))
    # sanity: latency is monotone in q2f on the frontier
    by_q2f = sorted(scored, key=lambda t: t[0].q2f)
    lats = [t[1] for t in by_q2f]
    assert all(a <= b + 0.05 for a, b in zip(lats, lats[1:])), lats
    return rows


def main(quick: bool = False):
    rows = run(quick)
    for name, val in rows:
        print(f"{name},{val:.6g}")
    return rows


if __name__ == "__main__":
    main()
