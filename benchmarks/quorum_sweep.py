"""Beyond-paper: the full FFP-valid quorum space on n=11 as a *streamed*
Pareto frontier.

The paper (§5) gives two example points in the (q1, q2c, q2f) tradeoff
space.  We enumerate the *whole* space permitted by Eqs. 13/14 (271
systems at n=11 — ``repro.frontier.families.cardinality_family``) and
score every one through the streaming engine (``repro.frontier.score``):
one ``fast_path_stream`` pass and one ``race_stream`` pass over the whole
batch — 10^7 trials each in the full run (10^6 under ``--smoke``), fixed
memory, common random numbers, ONE compile per engine path — extracting
the six frontier axes a deployment cares about:

  fast_p50_ms    conflict-free fast-path median
  race_p999_ms   p99.9 commit latency under a 2-way race at Δ=0.2 ms —
                 the tail axis only streamed trial counts make meaningful
  p_recovery     P(coordinated recovery | race)
  ft_fast / ft_phase1 / ft_classic   per-phase crash budgets

The Pareto-optimal set under ``repro.frontier.pareto`` (epsilon ties
matched to sketch precision) is the flexibility the paper's relaxation
buys: Fast Paxos admits exactly one point (q1=q2c=6, q2f=9).

The sweep asserts the single-compile property (``engine.TRACE_COUNTS``),
agreement of the streamed numbers with the legacy per-spec reference
below (different implementation, different PRNG stream), and that the
legacy quorum-size-minimal set is contained in the scored frontier.

``run_relaxed`` (the ``relaxed`` section of ``benchmarks.run``) widens the
space to Relaxed Paxos (arXiv 2203.03058): the 125 relaxed-valid /
FFP-invalid triples at n=11 join the 271 FFP systems on ONE streamed
frontier, scored under both collision-recovery rules (coordinated q2c
commit vs the uncoordinated q2f rule of arXiv 1710.08047).  It asserts at
least one relaxed system survives to the joint frontier, that the second
recovery rule costs exactly one extra ``race_stream`` compile (the fast
path is rule-invariant and shares its compile), and that fast-path
latencies are bit-identical across rules.

Usage:  PYTHONPATH=src python -m benchmarks.quorum_sweep [--smoke]
                                                         [--relaxed]
"""
from __future__ import annotations

import argparse
import os
import time
from typing import List, Tuple

import jax

# Join a multi-process grid BEFORE anything touches the jax backend: the
# repro imports below create module-level arrays (engine.BIG), and both
# the gloo CPU-collectives selection and jax.distributed.initialize only
# take effect pre-backend.  No-op without the REPRO_* launch env.
if os.environ.get("REPRO_COORDINATOR"):
    from repro.parallel import distributed as _distributed
    _distributed.initialize()

import jax.numpy as jnp
import numpy as np

from repro.core.quorum import QuorumSpec, ffp_card_ok
from repro.frontier import cardinality_family, relaxed_family, score_systems
from repro.montecarlo import engine

N = 11
TRIALS = 10_000_000
TRIALS_SMOKE = 1_000_000
CHUNK = 16_384
DELTA_MS = 0.2
LEGACY_SAMPLES = 50_000


# ---------------------------------------------------------------------------
# Independent per-spec reference: the pre-refactor static-threshold
# implementation (one jit per spec, python-int order statistics).  Kept here
# verbatim so the streamed engine is checked against a *different* code path,
# not a shim that now shares its internals.
# ---------------------------------------------------------------------------

def _legacy_one_way(key, shape, base=0.25, mu=-1.20, sigma=0.55):
    return base + jnp.exp(mu + sigma * jax.random.normal(key, shape))


def _legacy_fast_p50(key, n: int, q2f: int, samples: int) -> float:
    k1, k2 = jax.random.split(key)
    d = _legacy_one_way(k1, (samples, n)) + _legacy_one_way(k2, (samples, n))
    return float(jnp.median(jnp.sort(d, axis=-1)[:, q2f - 1]))


def _legacy_recovery_prob(key, spec: QuorumSpec, delta_ms: float,
                          samples: int) -> float:
    kA, kB = jax.random.split(key)
    tA = _legacy_one_way(kA, (samples, spec.n))
    tB = delta_ms + _legacy_one_way(kB, (samples, spec.n))
    votes = (tB < tA).astype(jnp.int32)
    b_cnt = votes.sum(axis=-1)
    a_cnt = spec.n - b_cnt
    return float((~((a_cnt >= spec.q2f) | (b_cnt >= spec.q2f))).mean())


def enumerate_valid(n: int = N) -> List[QuorumSpec]:
    """Brute-force triple loop over Eqs. 13/14 — the independent check on
    ``families.cardinality_family``'s enumeration."""
    out = []
    for q1 in range(1, n + 1):
        for q2c in range(1, n + 1):
            for q2f in range(1, n + 1):
                if ffp_card_ok(n, q1, q2c, q2f):
                    out.append(QuorumSpec(n, q1, q2c, q2f))
    return out


def minimal_frontier(specs: List[QuorumSpec]) -> List[QuorumSpec]:
    """Legacy per-spec reference: drop specs dominated in (q1, q2c, q2f) —
    larger quorums are never better on any axis we score.  Retained as the
    cross-check the scored frontier's membership is validated against."""
    keep = []
    for s in specs:
        if not any(o.q1 <= s.q1 and o.q2c <= s.q2c and o.q2f <= s.q2f
                   and (o.q1, o.q2c, o.q2f) != (s.q1, s.q2c, s.q2f)
                   for o in specs):
            keep.append(s)
    return keep


def run(quick: bool = False, seed: int = 0, shard=True):
    trials = TRIALS_SMOKE if quick else TRIALS
    legacy_samples = 5_000 if quick else LEGACY_SAMPLES

    members = cardinality_family(N)
    specs = [m.system for m in members]
    # family generator vs the independent brute-force enumeration
    assert ({(s.q1, s.q2c, s.q2f) for s in specs}
            == {(s.q1, s.q2c, s.q2f) for s in enumerate_valid(N)})

    rows: List[Tuple[str, float]] = [
        ("sweep.n_valid_configs", len(members)),
        ("sweep.trials", trials),
    ]

    # -- the entire space in two streamed engine calls (one compile each) --
    t0 = dict(engine.TRACE_COUNTS)
    wall0 = time.perf_counter()
    result = score_systems(members, trials=trials, chunk=CHUNK,
                           delta_ms=DELTA_MS, shard=shard, seed=seed)
    jax.block_until_ready(result.streams["race"].hist)
    wall = time.perf_counter() - wall0
    traced = {k: engine.TRACE_COUNTS[k] - t0[k] for k in t0}
    # exactly one compile per stream path, and both on the sort-free
    # lowering — a second trace (or a silent fall-back to the full-sort
    # path) is a perf regression the trials/sec row would only show late
    for k in ("fast_path_stream", "race_stream",
              "fast_path_stream_sortfree", "race_stream_sortfree"):
        assert traced[k] == 1, (
            f"expected exactly one {k} trace for {len(members)} specs, got "
            f"{traced[k]} (all deltas: { {a: b for a, b in traced.items() if b} })")
    rows.append(("sweep.engine_compiles",
                 traced["fast_path_stream"] + traced["race_stream"]))
    # streamed throughput across both passes (fast + race trials / wall);
    # _is_throughput in check_regression treats this as higher-is-better
    rows.append(("sweep.trials_per_sec", 2.0 * trials / wall))

    mask = np.asarray(result.mask)
    rows.append(("sweep.n_frontier_systems", int(mask.sum())))
    for i in result.frontier_indices:
        row = result.row(i)
        tag = result.labels[i]
        for axis in ("fast_p50_ms", "race_p999_ms", "p_recovery",
                     "ft_fast", "ft_phase1", "ft_classic"):
            rows.append((f"sweep.[{tag}].{axis}", row[axis]))

    # -- streamed vs independent per-spec reference (Monte-Carlo + sketch
    # tolerance): different implementation, different PRNG stream, so
    # agreement is a real check on the engine, not a tautology.
    k_check = jax.random.PRNGKey(1234)
    # difference of two independent p-estimates has sd <= sqrt(0.5/samples)
    # (the legacy sample count dominates); 4.5 sigma keeps the check
    # meaningful without making the CI smoke job flaky
    tol_rec = 4.5 * (0.5 / legacy_samples) ** 0.5
    front = result.frontier_indices
    for i in (front[0], front[len(front) // 2], front[-1]):
        s = specs[i]
        row = result.row(i)
        old_p50 = _legacy_fast_p50(jax.random.fold_in(k_check, i),
                                   s.n, s.q2f, legacy_samples)
        old_rec = _legacy_recovery_prob(jax.random.fold_in(k_check, 100 + i),
                                        s, DELTA_MS, legacy_samples)
        assert abs(old_p50 - row["fast_p50_ms"]) < 0.05, (s, old_p50, row)
        assert abs(old_rec - row["p_recovery"]) < tol_rec, (s, old_rec, row)
    rows.append(("sweep.streamed_vs_perspec_checked", 3))

    # -- membership cross-check: every quorum-size-minimal spec is
    # undominated on the scored axes (one spec per q1; see tests/
    # test_frontier.py for the fixed-seed anchor of the full set)
    minimal = {(s.q1, s.q2c, s.q2f) for s in minimal_frontier(specs)}
    scored = {(specs[i].q1, specs[i].q2c, specs[i].q2f) for i in front}
    assert minimal <= scored, sorted(minimal - scored)
    rows.append(("sweep.minimal_subset_of_frontier", len(minimal)))

    # sanity: fast-path latency is monotone in q2f on the frontier
    by_q2f = sorted(front, key=lambda i: specs[i].q2f)
    lats = [result.row(i)["fast_p50_ms"] for i in by_q2f]
    assert all(a <= b + 0.05 for a, b in zip(lats, lats[1:])), lats
    return rows


def run_relaxed(quick: bool = False, seed: int = 0, shard=True):
    """Joint FFP + Relaxed frontier under both collision-recovery rules."""
    trials = TRIALS_SMOKE if quick else TRIALS

    ffp = cardinality_family(N)
    relaxed = relaxed_family(N)
    members = ffp + relaxed
    ffp_count = len(ffp)
    rows: List[Tuple[str, float]] = [
        ("relaxed.n_valid_configs", len(relaxed)),
        ("relaxed.n_joint_systems", len(members)),
        ("relaxed.trials", trials),
    ]

    # -- coordinated rule: the whole joint space, one compile per path --
    t0 = dict(engine.TRACE_COUNTS)
    wall0 = time.perf_counter()
    coord = score_systems(members, trials=trials, chunk=CHUNK,
                          delta_ms=DELTA_MS, shard=shard, seed=seed)
    jax.block_until_ready(coord.streams["race"].hist)
    wall = time.perf_counter() - wall0
    traced = {k: engine.TRACE_COUNTS[k] - t0[k] for k in t0}
    for k in ("fast_path_stream", "race_stream",
              "fast_path_stream_sortfree", "race_stream_sortfree"):
        assert traced[k] == 1, (
            f"joint sweep expected one {k} trace, got {traced[k]}")
    rows.append(("relaxed.engine_compiles",
                 traced["fast_path_stream"] + traced["race_stream"]))
    rows.append(("relaxed.trials_per_sec", 2.0 * trials / wall))

    front = coord.frontier_indices
    on_front = [i for i in front if i >= ffp_count]
    rows.append(("relaxed.n_frontier_systems", len(front)))
    rows.append(("relaxed.n_relaxed_on_frontier", len(on_front)))
    # the paper-level claim: relaxing quorum intersection buys points FFP
    # cannot express — at least one survives the joint Pareto reduction
    assert on_front, (
        "no relaxed-valid/FFP-invalid system on the joint frontier")
    for i in on_front[:3]:
        row = coord.row(i)
        tag = coord.labels[i]
        for axis in ("fast_p50_ms", "race_p999_ms", "p_recovery",
                     "ft_fast", "ft_phase1", "ft_classic"):
            rows.append((f"relaxed.[{tag}].{axis}", row[axis]))

    # -- uncoordinated rule: same batch, only the race pass re-lowers --
    t1 = dict(engine.TRACE_COUNTS)
    uncoord = score_systems(members, trials=trials, chunk=CHUNK,
                            delta_ms=DELTA_MS, shard=shard, seed=seed,
                            recovery="uncoordinated")
    jax.block_until_ready(uncoord.streams["race"].hist)
    traced = {k: engine.TRACE_COUNTS[k] - t1[k] for k in t1}
    assert traced["race_stream"] == 1, (
        f"uncoordinated rule expected one race_stream trace, got "
        f"{traced['race_stream']}")
    assert traced["fast_path_stream"] == 0, (
        f"fast path is recovery-invariant but re-traced "
        f"{traced['fast_path_stream']} times")
    rows.append(("relaxed.uncoord_engine_compiles", traced["race_stream"]))

    # the fast path (and the recovery *entry* condition) must not depend on
    # the rule; only the recovery tail may move
    cv, uv = np.asarray(coord.values), np.asarray(uncoord.values)
    names = list(coord.axis_names)
    assert np.array_equal(cv[:, names.index("fast_p50_ms")],
                          uv[:, names.index("fast_p50_ms")])
    assert np.array_equal(cv[:, names.index("p_recovery")],
                          uv[:, names.index("p_recovery")])
    rows.append(("relaxed.rule_invariants_checked", 2))

    # tail reprice: the uncoordinated rule commits recovery at q2f instead
    # of q2c — report the joint-frontier witness under both rules
    i = on_front[0]
    rows.append((f"relaxed.[{coord.labels[i]}].race_p999_ms.uncoordinated",
                 uncoord.row(i)["race_p999_ms"]))
    return rows


def main(quick: bool = False, shard=True):
    rows = run(quick, shard=shard)
    if jax.process_index() == 0:        # one copy of the CSV per grid
        for name, val in rows:
            print(f"{name},{val:.6g}")
    return rows


def main_relaxed(quick: bool = False, shard=True):
    rows = run_relaxed(quick, shard=shard)
    if jax.process_index() == 0:
        for name, val in rows:
            print(f"{name},{val:.6g}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="10^6 streamed trials instead of 10^7; asserts "
                         "and frontier membership only")
    ap.add_argument("--relaxed", action="store_true",
                    help="also run the joint FFP + Relaxed Paxos frontier "
                         "under both collision-recovery rules")
    ap.add_argument("--shard", action="store_true",
                    help="join the multi-process grid configured via "
                         "REPRO_COORDINATOR/REPRO_NUM_PROCESSES/"
                         "REPRO_PROCESS_ID (repro.parallel.distributed; "
                         "no-op env -> this process's devices) and sweep "
                         "on an explicit global trial mesh — honored even "
                         "with a single device")
    args = ap.parse_args()
    if args.shard:
        # Grid membership was established at import (see top of module);
        # the explicit mesh pins the sweep to ALL global devices and is
        # honored even when only one is visible.
        from repro.parallel import sharding
        mesh = sharding.trial_mesh()
        main(quick=args.smoke, shard=mesh)
        if args.relaxed:
            main_relaxed(quick=args.smoke, shard=mesh)
    else:
        main(quick=args.smoke)
        if args.relaxed:
            main_relaxed(quick=args.smoke)
