"""Fig. 2c — probability of entering conflict recovery vs the interval
between two racing commands.

A command pair races for one instance with inter-arrival Δ; recovery happens
iff *neither* value reaches a fast phase-2 quorum.  Smaller q2f (FFP's 7 vs
Fast Paxos' 9 on n=11) makes a split that blocks both values much rarer.
Swept with the batched Monte-Carlo engine: both specs live in one spec
table and the inter-command interval is a *traced* proposer offset, so the
whole two-curve sweep reuses a single compiled race program.  Spot-checked
against the discrete-event simulator.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quorum import QuorumSpec
from repro.core.simulator import FastPaxosSim
from repro.montecarlo import build_mask_table, engine

DELTAS_MS = (0.0, 0.1, 0.2, 0.4, 0.8, 1.6, 3.2)
SAMPLES = 100_000


def _sim_recovery_prob(spec: QuorumSpec, delta_ms: float, pairs: int,
                       seed: int = 0) -> float:
    sim = FastPaxosSim(spec, seed=seed)
    t = 0.0
    for i in range(pairs):
        sim.submit(t, instance=i, value=f"a{i}", proposer=0)
        sim.submit(t + delta_ms, instance=i, value=f"b{i}", proposer=1)
        t += 50.0                      # isolate pairs
    sim.run()
    return sim.recovery_entries / pairs


def run(quick: bool = False, seed: int = 0):
    samples = 10_000 if quick else SAMPLES
    pairs = 200 if quick else 1000
    specs = {
        "fast_paxos": QuorumSpec.fast_paxos(11, "three_quarters"),
        "ffp": QuorumSpec.paper_headline(11),
    }
    rows = []
    table = build_mask_table(list(specs.values()))   # all-cardinality: "q"
    t0 = engine.TRACE_COUNTS["race"]
    curves = {name: [] for name in specs}
    for d in DELTAS_MS:
        out = engine.race(jax.random.PRNGKey(seed), table,
                          jnp.array([0.0, d], jnp.float32),
                          n=11, k_proposers=2, samples=samples)
        p_rec = out["recovery"].mean(axis=-1)
        for i, name in enumerate(specs):
            curves[name].append(float(p_rec[i]))
    assert engine.TRACE_COUNTS["race"] - t0 <= 1, "Δ sweep must not re-jit"
    for name in specs:
        for d, p in zip(DELTAS_MS, curves[name]):
            rows.append((f"fig2c.mc.{name}.p_recovery@{d}ms", p))
    # spot-check two points against the event simulator
    for name, spec in specs.items():
        for d in (0.0, 0.4):
            p = _sim_recovery_prob(spec, d, pairs, seed)
            rows.append((f"fig2c.sim.{name}.p_recovery@{d}ms", p))
    # headline ratio at the most contended point (Δ=0: simultaneous)
    if curves["ffp"][0] > 0:
        rows.append(("fig2c.mc.recovery_ratio_fp_over_ffp@0ms",
                     curves["fast_paxos"][0] / curves["ffp"][0]))
    return rows, curves


def main(quick: bool = False):
    rows, curves = run(quick)
    for name, val in rows:
        print(f"{name},{val:.6g}")
    # monotone decreasing in Δ, and FFP below FP pointwise
    for name, c in curves.items():
        assert all(a >= b - 0.01 for a, b in zip(c, c[1:])), (name, c)
    assert all(f <= p + 1e-6 for f, p in
               zip(curves["ffp"], curves["fast_paxos"])), curves
    return rows


if __name__ == "__main__":
    main()
