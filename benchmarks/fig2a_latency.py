"""Fig. 2a — instance latency, conflict-free workload at 1400 req/s.

Paper setup: Paxi on 11 EC2 m5a.large VMs; Fast Paxos (qc=6, qf=9) vs Fast
Flexible Paxos (q1=9, q2f=7, q2c=3).  Claim: FFP's smaller fast quorum (7 vs
9) cuts mean/median latency by 5-8%.

We reproduce it two ways (DESIGN.md §2):
  1. the discrete-event simulator running the actual protocol state machines
     over sampled EC2-like delays (common random numbers across algorithms);
  2. one declarative ``repro.api.Experiment``: both specs go into one
     mask-table lowering and are scored by a single compiled
     order-statistics program over identical sampled delays (10^5
     instances).
Both must agree on the *ratio*, which is the paper's claim.
"""
from __future__ import annotations

from repro.api import Experiment, Workload
from repro.core.quorum import QuorumSpec
from repro.core.simulator import (FastPaxosSim, conflict_free_workload,
                                  latency_stats)

N_REQUESTS = 3000
RATE = 1400.0
SAMPLES = 200_000


def run(quick: bool = False, seed: int = 0):
    n_req = 500 if quick else N_REQUESTS
    samples = 20_000 if quick else SAMPLES
    specs = {
        "fast_paxos": QuorumSpec.fast_paxos(11, "three_quarters"),
        "ffp": QuorumSpec.paper_headline(11),
    }
    rows = []

    # -- discrete-event simulation (identical seeds = common random numbers)
    de = {}
    for name, spec in specs.items():
        sim = FastPaxosSim(spec, seed=seed)
        conflict_free_workload(sim, n_req, RATE, seed=seed + 1)
        stats = latency_stats(sim.run())
        de[name] = stats
        for k in ("mean_ms", "p50_ms", "p95_ms", "p99_ms"):
            rows.append((f"fig2a.sim.{name}.{k}", stats[k]))

    # -- batched Monte-Carlo cross-check: both specs, one Experiment
    exp = Experiment(systems=list(specs.values()),
                     workload=Workload.conflict_free(),
                     samples=samples, seed=seed)
    summ = exp.run("montecarlo").summary
    mc = {}
    for i, name in enumerate(specs):
        mc[name] = {k: float(v[i]) for k, v in summ.items()}
        for k in ("mean_ms", "p50_ms", "p99_ms"):
            rows.append((f"fig2a.mc.{name}.{k}", mc[name][k]))

    for src, d in (("sim", de), ("mc", mc)):
        gain = 1.0 - d["ffp"]["mean_ms"] / d["fast_paxos"]["mean_ms"]
        rows.append((f"fig2a.{src}.ffp_mean_latency_gain", gain))
        med = 1.0 - d["ffp"]["p50_ms"] / d["fast_paxos"]["p50_ms"]
        rows.append((f"fig2a.{src}.ffp_median_latency_gain", med))
    return rows


def main(quick: bool = False):
    rows = run(quick)
    for name, val in rows:
        print(f"{name},{val:.6g}")
    gains = {n: v for n, v in rows if n.endswith("latency_gain")}
    # the paper reports 5-8%; the simulated network is a fit, not a trace,
    # so we assert the qualitative claim with slack.
    assert all(v > 0.02 for v in gains.values()), gains
    return rows


if __name__ == "__main__":
    main()
