"""Diff a benchmark record against the committed baseline.

  PYTHONPATH=src python -m benchmarks.check_regression \\
      BENCH_quick.json BENCH_baseline.json

CI runs ``benchmarks.run --quick --json BENCH_quick.json`` and feeds the
result here with ``BENCH_baseline.json`` (committed at the repo root,
regenerated with the same command whenever a change moves the numbers on
purpose).  Tolerances are deliberately generous — baseline and CI runners
are different machines — so the gate catches *order-of-magnitude*
regressions and structural breaks mechanically, while ±30% drifts are
reported as warnings for a human to eyeball in the job log:

  wall/latency timings (``*_us``, ``*_s``)   FAIL when > 10x the baseline;
                                             WARN when > 1.3x
  throughputs (``*trials_per_s*``)           FAIL when < baseline/10;
                                             WARN when < baseline/1.3
  compile counts (``trace_counts``,          FAIL on any increase — a
  ``*compiles*``)                            per-system re-jit never comes
                                             back silently
  everything else (figure stats, rates)      FAIL when outside ±30%
                                             (absolute floor 0.05 so
                                             near-zero rates don't trip)
  metric present in baseline but missing     FAIL — a benchmark section
  from the current run                       silently disappeared
  ``stream.multihost*`` missing either way   WARN only — the multi-process
                                             section needs working gloo
                                             collectives (and exists only
                                             from PR 7 on), so runners
                                             without it must not fail the
                                             gate

Improvements are reported too: any timing that got faster (or throughput
that got higher) by more than the warning ratio shows up in a
"faster by Nx" section, so deliberate speedups are visible in the same
diff that would catch their regression later.

Exit status 0 = clean (warnings and improvements allowed), 1 = regression.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple

TIMING_SUFFIXES = ("_us", "_s")
ABS_FLOOR = 0.05
RATIO_FAIL = 10.0
RATIO_WARN = 1.3
REL_TOL = 0.30
# Metric prefixes that may legitimately be absent from one side of the
# diff: the multihost section self-skips on platforms without
# multi-process CPU collectives (and pre-PR-7 baselines don't record it
# at all); the planner section exists only from PR 8 on and binds a
# localhost socket for its service round trip, which sandboxed runners
# may forbid; the regimes section exists only from PR 9 on and the
# relaxed section from PR 10 on.  Missing -> warn, never fail.
OPTIONAL_PREFIXES = ("stream.multihost", "planner", "regimes", "relaxed")


def _is_timing(name: str) -> bool:
    base = name.split("[")[0]
    return base.endswith(TIMING_SUFFIXES) and "trials_per_s" not in name


def _is_throughput(name: str) -> bool:
    return "trials_per_s" in name


def _is_count(name: str) -> bool:
    return "compile" in name or name.startswith("trace_counts.")


def compare(current: Dict, baseline: Dict
            ) -> Tuple[List[str], List[str], List[str]]:
    """Returns (failures, warnings, improvements) as human-readable
    lines.  Improvements never affect the exit status; they exist so a
    deliberate speedup is visible in the diff output (and nudges a
    baseline refresh so the gain is locked in)."""
    fails: List[str] = []
    warns: List[str] = []
    better: List[str] = []

    cur = dict(current.get("metrics", {}))
    base = dict(baseline.get("metrics", {}))
    for scope in ("current", "baseline"):
        rec = current if scope == "current" else baseline
        tgt = cur if scope == "current" else base
        for k, v in rec.get("trace_counts", {}).items():
            tgt[f"trace_counts.{k}"] = v

    for name, b in sorted(base.items()):
        if name not in cur:
            if name.startswith(OPTIONAL_PREFIXES):
                warns.append(f"missing  {name} (baseline {b:.6g}) — "
                             f"optional section skipped on this runner")
            else:
                fails.append(f"MISSING  {name} (baseline {b:.6g}) — section "
                             f"dropped or renamed without a baseline refresh")
            continue
        c = cur[name]
        if _is_count(name):
            if c > b:
                fails.append(f"COMPILES {name}: {c:.0f} > baseline {b:.0f} "
                             f"— a re-jit crept in")
            continue
        if _is_timing(name):
            if b > 0 and c > RATIO_FAIL * b:
                fails.append(f"SLOWER   {name}: {c:.6g} vs {b:.6g} "
                             f"(> {RATIO_FAIL:.0f}x)")
            elif b > 0 and c > RATIO_WARN * b:
                warns.append(f"slower   {name}: {c:.6g} vs {b:.6g} "
                             f"({c / b:.2f}x)")
            elif c > 0 and b > RATIO_WARN * c:
                better.append(f"faster by {b / c:.2f}x  {name}: {c:.6g} "
                              f"vs baseline {b:.6g}")
            continue
        if _is_throughput(name):
            if b > 0 and c < b / RATIO_FAIL:
                fails.append(f"SLOWER   {name}: {c:.6g} vs {b:.6g} "
                             f"(< 1/{RATIO_FAIL:.0f}x)")
            elif b > 0 and c < b / RATIO_WARN:
                warns.append(f"slower   {name}: {c:.6g} vs {b:.6g} "
                             f"({c / b:.2f}x)")
            elif b > 0 and c > RATIO_WARN * b:
                better.append(f"faster by {c / b:.2f}x  {name}: {c:.6g} "
                              f"vs baseline {b:.6g}")
            continue
        tol = REL_TOL * max(abs(b), ABS_FLOOR)
        if abs(c - b) > tol:
            fails.append(f"DRIFT    {name}: {c:.6g} vs baseline {b:.6g} "
                         f"(|Δ| {abs(c - b):.6g} > {tol:.6g})")

    for name in sorted(set(cur) - set(base)):
        warns.append(f"new      {name} = {cur[name]:.6g} (not in baseline; "
                     f"refresh BENCH_baseline.json to start tracking)")
    return fails, warns, better


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="freshly generated record "
                                    "(benchmarks.run --json)")
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    args = ap.parse_args()
    with open(args.current) as fh:
        current = json.load(fh)
    with open(args.baseline) as fh:
        baseline = json.load(fh)

    fails, warns, better = compare(current, baseline)
    if better:
        print("improvements:")
        for line in better:
            print(f"[fast] {line}")
    for line in warns:
        print(f"[warn] {line}")
    for line in fails:
        print(f"[FAIL] {line}")
    n_base = len(baseline.get("metrics", {}))
    print(f"check_regression: {n_base} baseline metrics, "
          f"{len(warns)} warnings, {len(fails)} failures, "
          f"{len(better)} improvements")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
