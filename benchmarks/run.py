"""Benchmark harness — one entry per paper table/figure, plus the framework's
own microbenches and the roofline table summary.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--skip-kernels]
                                          [--json BENCH_quick.json]

Sections:
  fig2a / fig2b / fig2c   paper §6 reproduction (FP vs FFP, n=11)
  sweep                   beyond-paper quorum-space sweep (§5)
  qsys                    general quorum systems: cardinality vs grid vs
                          weighted in one masked compile (§6 closing remark)
  mc.*                    montecarlo engine end-to-end: whole spec table per
                          call, traced thresholds (DESIGN.md §2)
  stream.*                streaming engine: trials/sec at fixed memory,
                          10^7-trial acceptance row (DESIGN.md §7)
  stream.multihost.*      multi-host trial mesh: 2 procs x 4 forced host
                          devices vs 1 proc x 8 on the same global key —
                          bit-identity of the merged summary + throughput
                          (DESIGN.md §10; skipped where the platform has
                          no multi-process CPU collectives)
  frontier.*              mixed-family (grid + weighted + cardinality)
                          Pareto frontier on n=12 through the streamed
                          dominance scorer (DESIGN.md §8)
  planner.*               search-and-serve planner (DESIGN.md §11):
                          successive-halving search wall vs the exhaustive
                          sweep at the same final budget, cold vs warm
                          query latency, zero-compile warm queries, and a
                          service round trip
  regimes.*               Markov-modulated scenario regimes (DESIGN.md
                          §12): the committed trace_replay config streamed
                          end-to-end through Experiment.from_config —
                          throughput, per-regime occupancy split,
                          single-compile discipline
  relaxed.*               joint FFP + Relaxed Paxos frontier on n=11 under
                          both collision-recovery rules (DESIGN.md §13):
                          relaxed systems surviving the joint Pareto
                          reduction, one extra race compile for the
                          uncoordinated rule, rule-invariance checks
  kernel.*                per-kernel timing: jnp reference under jit (wall),
                          Pallas interpret-mode parity asserted in tests/
  roofline.*              aggregate of experiments/dryrun/*.json

Output: ``name,value`` CSV on stdout (timings in us where applicable).
``--json`` additionally writes the machine-readable benchmark record CI
diffs against ``BENCH_baseline.json`` (``benchmarks.check_regression``):
every metric row, per-section wall time and engine trace counts (compile
counts), plus environment metadata.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import statistics
import time

import jax
import jax.numpy as jnp


def _time_us(fn, *args, iters: int = 20) -> float:
    jax.block_until_ready(fn(*args))      # warm-up: compile once, any pytree
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(ts)


def kernel_benches(quick: bool):
    """Wall-time of the pure-jnp reference ops under jit (CPU).  The Pallas
    kernels themselves target TPU; on CPU they run in interpret mode (orders
    of magnitude slower by construction) so parity, not speed, is asserted —
    see tests/test_kernels.py."""
    rows = []
    key = jax.random.PRNGKey(0)

    from repro.kernels.flash_attention import ref as fa_ref
    B, H, S, D = (1, 4, 512, 64) if quick else (2, 8, 1024, 64)
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(key, (B, S, H, D), jnp.float32)
    v = jax.random.normal(key, (B, S, H, D), jnp.float32)
    fn = jax.jit(lambda q, k, v: fa_ref.attention(q, k, v, causal=True))
    rows.append((f"kernel.flash_attention.ref_us[{B}x{H}x{S}x{D}]",
                 _time_us(fn, q, k, v)))

    from repro.kernels.rmsnorm import ref as rn_ref
    x = jax.random.normal(key, (4096, 4096), jnp.float32)
    sc = jnp.ones((4096,))
    fn = jax.jit(lambda x, s: rn_ref.rmsnorm(x, s))
    rows.append(("kernel.rmsnorm.ref_us[4096x4096]", _time_us(fn, x, sc)))

    from repro.kernels.ssd_scan import ref as ssd_ref
    Bs, S2, nh, hd, ds = (1, 512, 4, 32, 32) if quick else (2, 1024, 8, 64, 64)
    xw = jax.random.normal(key, (Bs, S2, nh, hd), jnp.float32)
    da = -jnp.abs(jax.random.normal(key, (Bs, S2, nh), jnp.float32)) * 0.1
    Bm = jax.random.normal(key, (Bs, S2, ds), jnp.float32)
    Cm = jax.random.normal(key, (Bs, S2, ds), jnp.float32)
    fn = jax.jit(lambda *a: ssd_ref.ssd(*a)[0])
    rows.append((f"kernel.ssd_scan.ref_us[{Bs}x{S2}x{nh}x{hd}]",
                 _time_us(fn, xw, da, Bm, Cm)))

    from repro.kernels.quorum_tally import ref as qt_ref
    votes = jax.random.randint(key, (100_000, 11), 0, 2)
    fn = jax.jit(lambda v: qt_ref.tally_votes(v, 2))
    rows.append(("kernel.quorum_tally.ref_us[100000x11]", _time_us(fn, votes)))

    q = jnp.int32(7)
    fn = jax.jit(lambda v, q: qt_ref.tally_decide(v, 2, q))
    rows.append(("kernel.quorum_tally.decide_ref_us[100000x11]",
                 _time_us(fn, votes, q)))
    return rows


def montecarlo_benches(quick: bool):
    """End-to-end engine wall time: the whole n=11 minimal frontier (one
    mask-table lowering, "q"-specialized since the frontier is all
    cardinality) per call — the number the traced batching is meant to
    move.  Plus the declarative layer's overhead: one ``Experiment.run``
    against the same frontier, which should cost the same engine call."""
    import jax.numpy as jnp

    from benchmarks.quorum_sweep import enumerate_valid, minimal_frontier
    from repro.api import Experiment, Workload
    from repro.montecarlo import build_mask_table, engine

    frontier = minimal_frontier(enumerate_valid(11))
    table = build_mask_table(frontier)
    samples = 10_000 if quick else 100_000
    key = jax.random.PRNGKey(0)
    offs = jnp.array([0.0, 0.2], jnp.float32)
    rows = []

    fn = lambda k: engine.fast_path(k, table, n=11, samples=samples)
    rows.append((f"mc.engine.fast_path_us[{len(frontier)}specs.{samples}]",
                 _time_us(fn, key, iters=10)))
    fn = lambda k: engine.race(k, table, offs, n=11, k_proposers=2,
                               samples=samples)["latency_ms"]
    rows.append((f"mc.engine.race_us[{len(frontier)}specs.{samples}]",
                 _time_us(fn, key, iters=10)))

    exp = Experiment(systems=frontier, workload=Workload.race(k=2,
                                                              delta_ms=0.2),
                     samples=samples, compute_fault_tolerance=False)
    fn = lambda s: exp.run("montecarlo").raw["latency_ms"]
    rows.append((f"mc.api.experiment_us[{len(frontier)}specs.{samples}]",
                 _time_us(fn, 0, iters=10)))
    return rows


def streaming_benches(quick: bool):
    """Streaming engine throughput at fixed memory: trials/sec for the
    chunked fast-path and race drivers, and the 10^7-trial acceptance row
    through the Experiment front door (10^6 under --quick so the CI smoke
    job stays snappy).  Each timing is the second run — the first warms the
    one compile the scan reuses."""
    from repro.api import Experiment, Workload
    from repro.core.quorum import QuorumSpec
    from repro.montecarlo import build_mask_table, streaming

    rows = []
    key = jax.random.PRNGKey(0)
    table = build_mask_table([QuorumSpec.paper_headline(11),
                              QuorumSpec.fast_paxos(11)])
    t_fast = 1_000_000 if quick else 10_000_000
    t_race = 200_000 if quick else 2_000_000
    chunk = 131_072

    def timed(fn):
        jax.block_until_ready(jax.tree_util.tree_leaves(fn())[0])
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
        return out, time.perf_counter() - t0

    state, dt = timed(lambda: streaming.fast_path_stream(
        key, table, n=11, trials=t_fast, chunk=chunk))
    rows.append((f"stream.fast_path.trials_per_s[{t_fast}]", t_fast / dt))
    rows.append(("stream.fast_path.p999_ms", float(state.quantile(0.999)[0])))

    offs = jnp.array([0.0, 0.2], jnp.float32)
    state, dt = timed(lambda: streaming.race_stream(
        key, table, offs, n=11, k_proposers=2, trials=t_race, chunk=chunk))
    rows.append((f"stream.race.trials_per_s[{t_race}]", t_race / dt))
    rows.append(("stream.race.p99_ms", float(state.quantile(0.99)[0])))

    # the acceptance row: the declarative front door streams the same
    # trial count in one-chunk memory (fixed-size state asserted)
    exp = Experiment(systems=[QuorumSpec.paper_headline(11)],
                     workload=Workload.conflict_free(), trials=t_fast,
                     chunk=chunk, compute_fault_tolerance=False)
    t0 = time.perf_counter()
    r = exp.run("montecarlo")
    jax.block_until_ready(r.stream.hist)
    assert int(r.stream.n_trials[0]) == t_fast
    rows.append((f"stream.experiment.wall_s[{t_fast}]",
                 time.perf_counter() - t0))
    rows.append(("stream.experiment.p50_ms", float(r.summary["p50_ms"][0])))
    rows.append(("stream.experiment.p999_ms",
                 float(r.summary["p999_ms"][0])))
    return rows


def multihost_benches(quick: bool):
    """Multi-host trial mesh acceptance as a benchmark row (DESIGN.md §10):
    launch the fixed stream workload on 2 processes x 4 forced host devices
    and on 1 process x 8, same global key, and record (a) bit-identity of
    the merged decide counts/histogram across layouts and (b) the
    distributed layout's throughput.  Skipped (no rows, a printed note)
    where the platform lacks multi-process CPU collectives —
    ``check_regression`` tolerates the missing ``stream.multihost``
    section."""
    import tempfile

    import numpy as np

    from repro.parallel import distributed

    trials = 50_011 if quick else 200_003      # odd: exercises remainders
    rows = []
    try:
        with tempfile.TemporaryDirectory() as td:
            multi = distributed.run_stream_layout(
                2, 4, os.path.join(td, "p2x4.npz"), trials=trials)
            single = distributed.run_stream_layout(
                1, 8, os.path.join(td, "p1x8.npz"), trials=trials)
    except (NotImplementedError, RuntimeError) as e:
        print(f"# stream.multihost skipped: {type(e).__name__}: "
              f"{str(e).splitlines()[0]}")
        return []
    bit = all(np.array_equal(multi[k], single[k])
              for k in ("n_trials", "n_fast", "n_recovery", "n_undecided",
                        "hist"))
    rows.append(("stream.multihost.bit_identical", 1.0 if bit else 0.0))
    rows.append((f"stream.multihost.trials_per_s[{trials}.2x4]",
                 trials / float(multi["wall_s"])))
    # per-system vectors (headline, fast_paxos); report the headline system
    rows.append(("stream.multihost.p999_ms", float(multi["p999_ms"][0])))
    rows.append(("stream.multihost.p9999_ms", float(multi["p9999_ms"][0])))
    assert bit, "2x4 vs 1x8 merged StreamSummary diverged (layout variance)"
    return rows


def frontier_benches(quick: bool):
    """Mixed-family Pareto frontier (DESIGN.md §8) on an n=12 cluster:
    grid systems over the 3x4 factorization (plus narrower embeds),
    weighted voting, and the three cardinality landmarks, all in ONE mask
    batch — the general masked stream path, since a mixed batch carries no
    "q" specialization — scored by one ``fast_path_stream`` + one
    ``race_stream`` compile and reduced by the dominance kernel."""
    from repro.core.quorum import QuorumSpec
    from repro.frontier import families, score_systems
    from repro.montecarlo import engine

    n = 12
    members = (
        [families.Member(f"card.{t}", s) for t, s in
         (("headline", QuorumSpec.paper_headline(n)),
          ("fast_paxos", QuorumSpec.fast_paxos(n)),
          ("majority", QuorumSpec.majority_fast(n)))]
        + families.grid_family(n) + families.weighted_family(n))
    trials = 131_072 if quick else 2_000_000

    t0 = dict(engine.TRACE_COUNTS)
    s0 = time.perf_counter()
    fr = score_systems(members, n=n, trials=trials, chunk=8_192, shard=True,
                       seed=0)
    wall = time.perf_counter() - s0
    traces = (engine.TRACE_COUNTS["fast_path_stream"]
              - t0["fast_path_stream"],
              engine.TRACE_COUNTS["race_stream"] - t0["race_stream"])
    assert traces[0] <= 1 and traces[1] <= 1, (
        f"mixed-family frontier re-jitted: {traces}")

    rows = [("frontier.n_systems", len(fr.labels)),
            ("frontier.n_members", len(fr.frontier_indices)),
            ("frontier.engine_compiles", sum(traces)),
            (f"frontier.score_wall_s[{len(fr.labels)}sys.{trials}]", wall)]
    for i in fr.frontier_indices:
        row = fr.row(i)
        rows.append((f"frontier.[{fr.labels[i]}].fast_p50_ms",
                     row["fast_p50_ms"]))
        rows.append((f"frontier.[{fr.labels[i]}].race_p999_ms",
                     row["race_p999_ms"]))
    return rows


def planner_benches(quick: bool):
    """Search-and-serve planner (DESIGN.md §11): successive-halving over
    the full n=11 cardinality family vs the exhaustive sweep at the same
    final budget, then query latency cold vs warm.

    The cold query runs the whole search (every rung compiles fresh in a
    new ``EngineCache``); the warm query differs only in fault budget, so
    it must hit the search cache and add ZERO engine compiles — asserted
    here and regression-pinned via ``planner.warm_engine_compiles``.  The
    exhaustive pass scores all candidates at the final budget directly;
    the search's final-rung scores are bit-identical per system (common
    random numbers), so the frontier-set match is exact, not approximate.
    """
    import numpy as np

    from repro.frontier import families, score_systems
    from repro.planner import Planner, default_schedule

    n = 11
    final = 100_000 if quick else 1_000_000
    schedule = tuple((r.trials, r.slack)
                     for r in default_schedule(final, min_trials=10_000))
    planner = Planner()                     # fresh engine cache: clean cold
    query = dict(n=n, family="cardinality", trials=final, schedule=schedule,
                 chunk=16_384, shard=False, seed=0)

    t0 = time.perf_counter()
    cold = planner.plan(dict(query, faults={"classic": 1}))
    cold_wall = time.perf_counter() - t0
    warm_wall = float("inf")
    for _ in range(3):                      # best-of-3: stable on busy CI
        t0 = time.perf_counter()
        warm = planner.plan(dict(query, faults={"fast": 1, "phase1": 1}))
        warm_wall = min(warm_wall, time.perf_counter() - t0)
    assert warm.engine_compiles == 0 and not warm.cold, (
        f"warm same-geometry query recompiled: {warm.engine_compiles}")

    # the exhaustive sweep at the same final budget, for the wall-clock
    # and frontier-set comparison (scored after the search so no compile
    # is accidentally shared — the batch shapes differ anyway)
    members = families.cardinality_family(n)
    t0 = time.perf_counter()
    full = score_systems(members, n=n, trials=final, chunk=16_384,
                         shard=False, seed=0)
    exhaustive_wall = time.perf_counter() - t0
    sr = next(iter(planner._searches.values()))       # the cached search
    match = set(sr.frontier_labels) == set(full.frontier_labels)
    assert match, (f"search frontier {sorted(sr.frontier_labels)} != "
                   f"exhaustive {sorted(full.frontier_labels)}")

    rows = [
        ("planner.cold_query_wall_s", cold_wall),
        ("planner.warm_query_wall_s", warm_wall),
        ("planner.cold_engine_compiles", float(cold.engine_compiles)),
        ("planner.warm_engine_compiles", float(warm.engine_compiles)),
        ("planner.search_wall_s", float(sum(
            v for k, v in cold.search.items() if k.endswith(".wall_s")))),
        ("planner.exhaustive_wall_s", exhaustive_wall),
        ("planner.budget_fraction", float(cold.search["budget_fraction"])),
        ("planner.n_candidates", float(cold.search["n_candidates"])),
        ("planner.n_survivors", float(cold.search["n_survivors"])),
        ("planner.n_frontier", float(cold.search["n_frontier"])),
        ("planner.frontier_matches_exhaustive", 1.0 if match else 0.0),
    ]

    # service round-trip on the warm planner: JSON in, recommendation out
    from repro.planner import PlannerServer, query_server
    srv = PlannerServer(planner=planner, port=0, batch_window_s=0.01)
    srv.start()
    try:
        payload = {"op": "plan", **query, "faults": {"classic": 1},
                   "schedule": [list(r) for r in schedule]}
        t0 = time.perf_counter()
        reply = query_server(payload, port=srv.port)
        rt = time.perf_counter() - t0
        assert reply["ok"] and reply["engine_compiles"] == 0, reply
        rows.append(("planner.serve_warm_roundtrip_s", rt))
    finally:
        srv.shutdown()
    return rows


def regimes_benches(quick: bool):
    """Markov-modulated scenario regimes (DESIGN.md §12) from a committed
    scenario config: stream the ``trace_replay`` example (empirical
    trace-driven delay + 3-regime failure chain) end-to-end through
    ``Experiment.from_config`` and record throughput, the per-regime
    occupancy split, and the compile discipline — ONE fresh
    ``race_stream_regimes`` trace for the geometry, ZERO on a same-shape
    repeat (different seed re-enters the warm compile; trial counts and
    regime parameters are traced operands)."""
    import dataclasses

    from repro.api.experiment import Experiment
    from repro.montecarlo import engine

    cfg = os.path.join(os.path.dirname(__file__), "..",
                       "examples", "scenarios", "trace_replay.json")
    trials = 200_000 if quick else 1_000_000
    exp = dataclasses.replace(Experiment.from_config(cfg), trials=trials,
                              shard=len(jax.devices()) > 1)

    t0 = dict(engine.TRACE_COUNTS)
    s0 = time.perf_counter()
    r = exp.run("montecarlo")
    jax.block_until_ready(r.stream.occupancy)
    wall = time.perf_counter() - s0
    compiles = (engine.TRACE_COUNTS["race_stream_regimes"]
                - t0["race_stream_regimes"])
    assert compiles == 1, (
        f"3-regime stream took {compiles} compiles (expected 1)")

    t1 = dict(engine.TRACE_COUNTS)
    r2 = dataclasses.replace(exp, seed=exp.seed + 1).run("montecarlo")
    jax.block_until_ready(r2.stream.occupancy)
    repeat = (engine.TRACE_COUNTS["race_stream_regimes"]
              - t1["race_stream_regimes"])
    assert repeat == 0, (
        f"same-geometry regime stream re-jitted ({repeat} traces)")

    rep = r.stream.report()
    rows = [("regimes.n_regimes", float(len(rep["names"]))),
            ("regimes.engine_compiles", float(compiles)),
            ("regimes.repeat_engine_compiles", float(repeat)),
            (f"regimes.trials_per_s[{trials}]", trials / wall),
            ("regimes.p999_ms", float(r.summary["p999_ms"][0]))]
    import numpy as np
    for i, name in enumerate(rep["names"]):
        rows.append((f"regimes.occupancy_frac.{name}",
                     float(rep["occupancy_frac"][i])))
        # per-system vector (scalar when M == 1); report the first system
        rows.append((f"regimes.[{name}].p50_ms",
                     float(np.ravel(rep["per_regime"][name]["p50_ms"])[0])))
    return rows


def roofline_summary(dryrun_dir: str = "experiments/dryrun"):
    rows = []
    files = sorted(glob.glob(os.path.join(dryrun_dir, "*.single.json")))
    fracs = []
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        if rec.get("status") != "ok":
            continue
        tag = f"{rec['arch']}.{rec['shape']}"
        r = rec.get("roofline", {})
        rows.append((f"roofline.{tag}.dominant={r.get('dominant', '?')}",
                     r.get("roofline_fraction", 0.0)))
        fracs.append(r.get("roofline_fraction", 0.0))
    if fracs:
        rows.append(("roofline.cells", len(fracs)))
        rows.append(("roofline.mean_fraction",
                     sum(fracs) / len(fracs)))
        rows.append(("roofline.min_fraction", min(fracs)))
        rows.append(("roofline.max_fraction", max(fracs)))
    return rows


def _sections(args):
    """(name, runner, prints_itself) triples in execution order."""
    def fig2a(q):
        from benchmarks import fig2a_latency
        return fig2a_latency.main(quick=q)

    def fig2b(q):
        from benchmarks import fig2b_conflict_latency
        return fig2b_conflict_latency.main(quick=q)

    def fig2c(q):
        from benchmarks import fig2c_conflict_prob
        return fig2c_conflict_prob.main(quick=q)

    def sweep(q):
        from benchmarks import quorum_sweep
        return quorum_sweep.main(quick=q)

    def relaxed(q):
        from benchmarks import quorum_sweep
        return quorum_sweep.main_relaxed(quick=q)

    def qsys(q):
        from benchmarks import quorum_systems
        return quorum_systems.main(quick=q)

    out = [("fig2a", fig2a, True), ("fig2b", fig2b, True),
           ("fig2c", fig2c, True), ("sweep", sweep, True),
           ("qsys", qsys, True), ("mc", montecarlo_benches, False),
           ("stream", streaming_benches, False),
           ("multihost", multihost_benches, False),
           ("frontier", frontier_benches, False),
           ("planner", planner_benches, False),
           ("regimes", regimes_benches, False),
           ("relaxed", relaxed, True)]
    if not args.skip_kernels:
        out.append(("kernels", kernel_benches, False))
    out.append(("roofline", lambda q: roofline_summary(), False))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-kernels", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig2a,fig2b,fig2c,sweep,"
                         "qsys,mc,stream,multihost,frontier,planner,"
                         "regimes,relaxed,kernels,roofline")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the machine-readable benchmark record "
                         "(metrics + per-section wall time + compile "
                         "counts) for benchmarks.check_regression")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from repro.montecarlo import engine

    metrics, sections = {}, {}
    t0 = time.time()
    for name, fn, prints_itself in _sections(args):
        if only is not None and name not in only:
            continue
        tc0 = dict(engine.TRACE_COUNTS)
        s0 = time.perf_counter()
        rows = fn(args.quick) or []
        wall = time.perf_counter() - s0
        if not prints_itself:
            for rname, val in rows:
                print(f"{rname},{val:.6g}")
        metrics.update({rname: float(val) for rname, val in rows})
        sections[name] = {
            "wall_s": wall,
            "engine_compiles": {k: v - tc0[k]
                                for k, v in engine.TRACE_COUNTS.items()
                                if v - tc0[k]},
        }
    total = time.time() - t0
    print(f"bench.total_wall_s,{total:.1f}")

    if args.json:
        record = {
            "meta": {
                "quick": bool(args.quick),
                "jax": jax.__version__,
                "platform": jax.default_backend(),
                "device_count": len(jax.devices()),
            },
            "sections": sections,
            "trace_counts": dict(engine.TRACE_COUNTS),
            "metrics": {**metrics, "bench.total_wall_s": total},
        }
        with open(args.json, "w") as fh:
            json.dump(record, fh, indent=1, sort_keys=True)
        print(f"bench.json_written,{args.json}")


if __name__ == "__main__":
    main()
