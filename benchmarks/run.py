"""Benchmark harness — one entry per paper table/figure, plus the framework's
own microbenches and the roofline table summary.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--skip-kernels]

Sections:
  fig2a / fig2b / fig2c   paper §6 reproduction (FP vs FFP, n=11)
  sweep                   beyond-paper quorum-space sweep (§5)
  qsys                    general quorum systems: cardinality vs grid vs
                          weighted in one masked compile (§6 closing remark)
  mc.*                    montecarlo engine end-to-end: whole spec table per
                          call, traced thresholds (DESIGN.md §2)
  kernel.*                per-kernel timing: jnp reference under jit (wall),
                          Pallas interpret-mode parity asserted in tests/
  roofline.*              aggregate of experiments/dryrun/*.json

Output: ``name,value`` CSV on stdout (timings in us where applicable).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import statistics
import time

import jax
import jax.numpy as jnp


def _time_us(fn, *args, iters: int = 20) -> float:
    jax.block_until_ready(fn(*args))      # warm-up: compile once, any pytree
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(ts)


def kernel_benches(quick: bool):
    """Wall-time of the pure-jnp reference ops under jit (CPU).  The Pallas
    kernels themselves target TPU; on CPU they run in interpret mode (orders
    of magnitude slower by construction) so parity, not speed, is asserted —
    see tests/test_kernels.py."""
    rows = []
    key = jax.random.PRNGKey(0)

    from repro.kernels.flash_attention import ref as fa_ref
    B, H, S, D = (1, 4, 512, 64) if quick else (2, 8, 1024, 64)
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(key, (B, S, H, D), jnp.float32)
    v = jax.random.normal(key, (B, S, H, D), jnp.float32)
    fn = jax.jit(lambda q, k, v: fa_ref.attention(q, k, v, causal=True))
    rows.append((f"kernel.flash_attention.ref_us[{B}x{H}x{S}x{D}]",
                 _time_us(fn, q, k, v)))

    from repro.kernels.rmsnorm import ref as rn_ref
    x = jax.random.normal(key, (4096, 4096), jnp.float32)
    sc = jnp.ones((4096,))
    fn = jax.jit(lambda x, s: rn_ref.rmsnorm(x, s))
    rows.append(("kernel.rmsnorm.ref_us[4096x4096]", _time_us(fn, x, sc)))

    from repro.kernels.ssd_scan import ref as ssd_ref
    Bs, S2, nh, hd, ds = (1, 512, 4, 32, 32) if quick else (2, 1024, 8, 64, 64)
    xw = jax.random.normal(key, (Bs, S2, nh, hd), jnp.float32)
    da = -jnp.abs(jax.random.normal(key, (Bs, S2, nh), jnp.float32)) * 0.1
    Bm = jax.random.normal(key, (Bs, S2, ds), jnp.float32)
    Cm = jax.random.normal(key, (Bs, S2, ds), jnp.float32)
    fn = jax.jit(lambda *a: ssd_ref.ssd(*a)[0])
    rows.append((f"kernel.ssd_scan.ref_us[{Bs}x{S2}x{nh}x{hd}]",
                 _time_us(fn, xw, da, Bm, Cm)))

    from repro.kernels.quorum_tally import ref as qt_ref
    votes = jax.random.randint(key, (100_000, 11), 0, 2)
    fn = jax.jit(lambda v: qt_ref.tally_votes(v, 2))
    rows.append(("kernel.quorum_tally.ref_us[100000x11]", _time_us(fn, votes)))

    q = jnp.int32(7)
    fn = jax.jit(lambda v, q: qt_ref.tally_decide(v, 2, q))
    rows.append(("kernel.quorum_tally.decide_ref_us[100000x11]",
                 _time_us(fn, votes, q)))
    return rows


def montecarlo_benches(quick: bool):
    """End-to-end engine wall time: the whole n=11 minimal frontier (one
    mask-table lowering, "q"-specialized since the frontier is all
    cardinality) per call — the number the traced batching is meant to
    move.  Plus the declarative layer's overhead: one ``Experiment.run``
    against the same frontier, which should cost the same engine call."""
    import jax.numpy as jnp

    from benchmarks.quorum_sweep import enumerate_valid, minimal_frontier
    from repro.api import Experiment, Workload
    from repro.montecarlo import build_mask_table, engine

    frontier = minimal_frontier(enumerate_valid(11))
    table = build_mask_table(frontier)
    samples = 10_000 if quick else 100_000
    key = jax.random.PRNGKey(0)
    offs = jnp.array([0.0, 0.2], jnp.float32)
    rows = []

    fn = lambda k: engine.fast_path(k, table, n=11, samples=samples)
    rows.append((f"mc.engine.fast_path_us[{len(frontier)}specs.{samples}]",
                 _time_us(fn, key, iters=10)))
    fn = lambda k: engine.race(k, table, offs, n=11, k_proposers=2,
                               samples=samples)["latency_ms"]
    rows.append((f"mc.engine.race_us[{len(frontier)}specs.{samples}]",
                 _time_us(fn, key, iters=10)))

    exp = Experiment(systems=frontier, workload=Workload.race(k=2,
                                                              delta_ms=0.2),
                     samples=samples, compute_fault_tolerance=False)
    fn = lambda s: exp.run("montecarlo").raw["latency_ms"]
    rows.append((f"mc.api.experiment_us[{len(frontier)}specs.{samples}]",
                 _time_us(fn, 0, iters=10)))
    return rows


def roofline_summary(dryrun_dir: str = "experiments/dryrun"):
    rows = []
    files = sorted(glob.glob(os.path.join(dryrun_dir, "*.single.json")))
    fracs = []
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        if rec.get("status") != "ok":
            continue
        tag = f"{rec['arch']}.{rec['shape']}"
        r = rec.get("roofline", {})
        rows.append((f"roofline.{tag}.dominant={r.get('dominant', '?')}",
                     r.get("roofline_fraction", 0.0)))
        fracs.append(r.get("roofline_fraction", 0.0))
    if fracs:
        rows.append(("roofline.cells", len(fracs)))
        rows.append(("roofline.mean_fraction",
                     sum(fracs) / len(fracs)))
        rows.append(("roofline.min_fraction", min(fracs)))
        rows.append(("roofline.max_fraction", max(fracs)))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-kernels", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig2a,fig2b,fig2c,sweep,"
                         "qsys,mc,kernels,roofline")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name: str) -> bool:
        return only is None or name in only

    t0 = time.time()
    if want("fig2a"):
        from benchmarks import fig2a_latency
        fig2a_latency.main(quick=args.quick)
    if want("fig2b"):
        from benchmarks import fig2b_conflict_latency
        fig2b_conflict_latency.main(quick=args.quick)
    if want("fig2c"):
        from benchmarks import fig2c_conflict_prob
        fig2c_conflict_prob.main(quick=args.quick)
    if want("sweep"):
        from benchmarks import quorum_sweep
        quorum_sweep.main(quick=args.quick)
    if want("qsys"):
        from benchmarks import quorum_systems
        quorum_systems.main(quick=args.quick)
    if want("mc"):
        for name, val in montecarlo_benches(args.quick):
            print(f"{name},{val:.6g}")
    if not args.skip_kernels and want("kernels"):
        for name, val in kernel_benches(args.quick):
            print(f"{name},{val:.6g}")
    if want("roofline"):
        for name, val in roofline_summary():
            print(f"{name},{val:.6g}")
    print(f"bench.total_wall_s,{time.time() - t0:.1f}")


if __name__ == "__main__":
    main()
