"""Render EXPERIMENTS.md tables from experiments/dryrun/*.json.

  PYTHONPATH=src:. python -m benchmarks.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirname: str):
    recs = {}
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        recs[(r["arch"], r["shape"], "multi" in os.path.basename(f))] = r
    return recs


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def dryrun_table(recs) -> str:
    lines = ["| arch | shape | mesh | status | mem/dev | compile | HLO flops | link bytes | DCN bytes | promoted |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for (a, s, mp), r in sorted(recs.items()):
        mesh = r.get("mesh", "?")
        st = r.get("status", "?")
        if st == "ok":
            mem = r["memory"].get("per_device_total", 0) / 2**30
            flag = " ⚠" if mem > 16 else ""
            lines.append(
                f"| {a} | {s} | {mesh} | ok | {mem:.2f} GiB{flag} "
                f"| {r.get('compile_s', 0):.0f}s | {r['cost']['flops']:.3g} "
                f"| {r['collectives']['link_bytes']:.3g} "
                f"| {r['collectives']['dcn_bytes']:.3g} "
                f"| {r['collectives'].get('promoted_count', 0)} |")
        elif st == "skipped":
            lines.append(f"| {a} | {s} | {mesh} | skipped "
                         f"({r.get('reason','')[:40]}) | | | | | | |")
        else:
            lines.append(f"| {a} | {s} | {mesh} | ERROR "
                         f"{r.get('error','')[:40]} | | | | | | |")
    return "\n".join(lines)


def roofline_table(recs) -> str:
    lines = ["| arch | shape | compute | memory | collective | dominant | MODEL_FLOPS | useful ratio | roofline frac |",
             "|---|---|---|---|---|---|---|---|---|"]
    for (a, s, mp), r in sorted(recs.items()):
        if mp or r.get("status") != "ok":
            continue
        t = r["roofline"]
        lines.append(
            f"| {a} | {s} | {fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} "
            f"| {fmt_s(t['collective_s'])} | **{t['dominant'].replace('_s','')}** "
            f"| {t['model_flops']:.3g} | {t['useful_flops_ratio']:.2f} "
            f"| {t['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--which", default="both",
                    choices=["dryrun", "roofline", "both"])
    args = ap.parse_args()
    recs = load(args.dir)
    n_ok = sum(1 for r in recs.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in recs.values() if r.get("status") == "skipped")
    n_err = len(recs) - n_ok - n_skip
    print(f"<!-- {len(recs)} records: {n_ok} ok, {n_skip} skipped, "
          f"{n_err} error -->\n")
    if args.which in ("dryrun", "both"):
        print("### Dry-run records\n")
        print(dryrun_table(recs))
        print()
    if args.which in ("roofline", "both"):
        print("### Roofline (single-pod 16x16, per train/serve step)\n")
        print(roofline_table(recs))


if __name__ == "__main__":
    main()
