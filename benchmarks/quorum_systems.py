"""Beyond-paper: the §6 closing remark made runnable — score *alternative
quorum systems* (grid, weighted voting) against the paper's cardinality
configurations on one cluster, in one compile, through the declarative
``repro.api.Experiment`` layer.

The paper closes by noting that relaxed intersection (Eqs. 11-14) lets Fast
Paxos adopt quorum systems "not based solely on quorum cardinality" to trade
performance against fault-tolerance.  This benchmark walks that design
space for n = 11:

  card.headline      (q1, q2c, q2f) = (9, 3, 7) — the paper's §5 example
  card.fast_paxos    (6, 6, 9) — Fast Paxos' own three-quarters suggestion
  card.majority      majority fast quorums (q1 = 11 extreme)
  grid.3x3           3x3 grid (§6 construction) embedded in the 11-node
                     cluster: fast = two full rows, classic = one column
  weighted           Gifford-style weighted voting, three heavy acceptors

All five go into ONE ``Experiment``; its mask-table lowering (the single
quorum lowering, DESIGN.md §2) scores them with ONE ``fast_path`` compile
plus ONE ``race`` compile (asserted via ``engine.TRACE_COUNTS``).  The
cardinality rows are then re-run as their own all-cardinality experiment —
which lowers to the k-th-order-statistic specialization — and asserted
bit-identical: the differential anchor that licenses the general masked
path.  Axes reported per system: fast-path p50/p99, P(recovery | race), and
brute-force crash tolerance per phase; plus a fault-injection coda (a grid
row outage vs the same crash count scattered) showing why *placement* starts
to matter once quorums have structure.

Usage:  PYTHONPATH=src python -m benchmarks.quorum_systems [--smoke]
"""
from __future__ import annotations

import argparse
from typing import List, Tuple

import jax

from repro.api import Experiment, Workload
from repro.core.quorum import ExplicitQuorumSystem, QuorumSpec
from repro.montecarlo import build_mask_table, engine
from repro.montecarlo.scenarios import grid_wan, weighted_acceptors

N = 11
SAMPLES = 50_000
DELTA_MS = 0.2


def systems() -> List[Tuple[str, object]]:
    """(name, masks) for every scored family; all masks share n = 11."""
    grid = ExplicitQuorumSystem.grid(3).to_masks().embed(N)
    _, weighted = weighted_acceptors()          # default 3-heavy weighting
    return [
        ("card.headline", QuorumSpec.paper_headline(N).to_masks()),
        ("card.fast_paxos", QuorumSpec.fast_paxos(N).to_masks()),
        ("card.majority", QuorumSpec.majority_fast(N).to_masks()),
        ("grid.3x3", grid),
        ("weighted.3heavy", weighted),
    ]


def run(quick: bool = False, seed: int = 0):
    samples = 4_000 if quick else SAMPLES
    named = systems()
    cards = [QuorumSpec.paper_headline(N), QuorumSpec.fast_paxos(N),
             QuorumSpec.majority_fast(N)]
    rows: List[Tuple[str, float]] = [("qsys.n_systems", len(named))]

    # -- one declared experiment, two workloads, two engine calls (one
    # compile each): the whole mixed-family table per call
    exp = Experiment(systems=[m for _, m in named],
                     workload=Workload.conflict_free(),
                     samples=samples, seed=seed)
    t0 = dict(engine.TRACE_COUNTS)
    fast = exp.run("montecarlo")
    race = Experiment(systems=exp.systems,
                      workload=Workload.race(k=2, delta_ms=DELTA_MS),
                      samples=samples, seed=seed).run("montecarlo")
    traces = (engine.TRACE_COUNTS["fast_path"] - t0["fast_path"],
              engine.TRACE_COUNTS["race"] - t0["race"])
    assert traces[0] <= 1 and traces[1] <= 1, (
        f"per-system re-jit crept back in: {traces} traces for "
        f"{len(named)} quorum systems")
    rows.append(("qsys.engine_compiles", sum(traces)))

    # -- differential anchor: the cardinality rows re-declared as their own
    # all-cardinality experiment lower to the "q" (k-th-order-statistic)
    # specialization, and must be bit-identical under the same seed (common
    # random numbers) — the parity that licenses the general masked path.
    fast_q = Experiment(systems=cards, workload=Workload.conflict_free(),
                        samples=samples, seed=seed).run("montecarlo")
    race_q = Experiment(systems=cards,
                        workload=Workload.race(k=2, delta_ms=DELTA_MS),
                        samples=samples, seed=seed).run("montecarlo")
    assert "q" in engine.build_mask_table(cards), \
        "all-cardinality batch must carry the kth-gather specialization"
    assert bool((fast.raw["latency_ms"][: len(cards)]
                 == fast_q.raw["latency_ms"]).all()), \
        "masked fast path diverged from cardinality specialization"
    for k in race_q.raw:
        assert bool((race.raw[k][: len(cards)] == race_q.raw[k]).all()), (
            f"masked race output {k!r} diverged from cardinality "
            f"specialization")
    rows.append(("qsys.masked_matches_threshold_bitwise", len(cards)))

    # -- per-system frontier rows
    for i, (name, _) in enumerate(named):
        ft = fast.fault_tolerance[i]
        rows.append((f"qsys.[{name}].fast_p50_ms",
                     float(fast.summary["p50_ms"][i])))
        rows.append((f"qsys.[{name}].fast_p99_ms",
                     float(fast.summary["p99_ms"][i])))
        rows.append((f"qsys.[{name}].p_recovery",
                     float(race.summary["recovery_rate"][i])))
        rows.append((f"qsys.[{name}].ft_fast", ft["phase2_fast"]))
        rows.append((f"qsys.[{name}].ft_classic", ft["phase2_classic"]))
        rows.append((f"qsys.[{name}].ft_phase1", ft["phase1"]))

    # -- fault-injection coda: with structured quorums, *which* acceptors
    # fail matters, not just how many.  A full grid-row outage (one WAN
    # region down) leaves a fast quorum intact; the same three crashes
    # scattered one-per-row break every fast AND phase-1 quorum.
    inj_samples = min(samples, 4_000)
    kk = jax.random.PRNGKey(seed + 1)
    undecided = {}
    for tag, crashed in (("row_outage", (3, 4, 5)),
                         ("scattered", (0, 4, 8))):
        scen, masks = grid_wan(cols=3, k=2, delta_ms=DELTA_MS,
                               crashed=crashed)
        out = scen.with_spec(samples=inj_samples).run(
            kk, build_mask_table([masks]))
        undecided[tag] = float(out["undecided"].mean())
        rows.append((f"qsys.grid_wan.{tag}.undecided_rate", undecided[tag]))
        rows.append((f"qsys.grid_wan.{tag}.p_recovery",
                     float(out["recovery"].mean())))
    # a row outage also takes out every phase-1 quorum (each column crosses
    # the dead row), so recovery is off — but the surviving row pair still
    # fast-commits the large majority of instances, whereas the scattered
    # crash set leaves no live quorum of any kind.
    assert undecided["scattered"] > 0.99, \
        "scattered 3-crash must break every grid quorum"
    assert undecided["row_outage"] < 0.2, \
        "a single-row outage must leave the grid's fast path mostly live"

    # -- frontier coda: the same five systems through the streamed Pareto
    # scorer (repro.frontier via api.frontier) — which of the §6 families
    # survive dominance once the tail axis is measurable?
    from repro.api import frontier as api_frontier
    trials = 131_072 if quick else 2_000_000
    fr = api_frontier([m for _, m in named], trials=trials, chunk=16_384,
                      seed=seed)
    rows.append(("qsys.frontier.n_systems", len(fr.labels)))
    rows.append(("qsys.frontier.n_members", len(fr.frontier_indices)))
    for (name, _), lab in zip(named, fr.labels):
        rows.append((f"qsys.[{name}].on_frontier",
                     float(fr.row(lab)["on_frontier"])))
    # the paper's headline point trades tail latency against phase-1
    # fault tolerance in a way nothing in this batch dominates
    assert fr.row(fr.labels[0])["on_frontier"], fr.table(False)

    return rows


def main(quick: bool = False):
    rows = run(quick)
    for name, val in rows:
        print(f"{name},{val:.6g}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sample count; asserts only")
    args = ap.parse_args()
    main(quick=args.smoke)
