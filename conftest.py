# Root conftest: makes the `benchmarks` package importable from tests
# (pytest inserts conftest directories into sys.path).  Deliberately empty
# otherwise — in particular no XLA_FLAGS here: smoke tests and benches must
# see 1 device; only launch/dryrun.py requests 512 placeholder devices.
